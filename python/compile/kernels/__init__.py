"""L1 Pallas kernels for DCI's padded mini-batch GNN compute.

Two kernels form the hot path that the L3 dual cache feeds:

- ``gather_aggregate``: fused neighbor gather + masked sum/mean
  aggregation over a padded neighbor-index matrix (the operation whose
  *input bytes* DCI's feature cache optimizes).
- ``tiled_matmul``: the per-layer dense transform, tiled for an
  MXU-shaped systolic array (see DESIGN.md §Hardware-Adaptation).

All kernels run under ``interpret=True`` — the CPU PJRT plugin cannot
execute Mosaic custom-calls; real-TPU performance is estimated in
DESIGN.md from the BlockSpec VMEM footprint instead.
"""

from .sage_agg import gather_aggregate, tiled_matmul  # noqa: F401
from . import ref  # noqa: F401
