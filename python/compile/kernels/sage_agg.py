"""Pallas kernels: fused neighbor gather+aggregate, and a tiled matmul.

Hardware adaptation (see DESIGN.md §Hardware-Adaptation): the paper's
CUDA hot spot — warps doing coalesced gathers of neighbor features — is
re-thought for a TPU-style memory hierarchy:

- ``gather_aggregate`` blocks over *destination-node tiles*; each grid
  step holds the destination tile's neighbor indices + mask and the
  (padded) source feature table in VMEM, produces one aggregated tile.
  The HBM→VMEM schedule that a CUDA kernel expresses with threadblocks
  is expressed here with BlockSpec index maps.
- ``tiled_matmul`` is a classic (i, j, k) MXU tiling with an f32 VMEM
  accumulator, shaped for the 128×128 systolic array.

Both are lowered with ``interpret=True``: the image's CPU PJRT plugin
cannot run Mosaic custom-calls, so interpret mode is the correctness
path and TPU efficiency is reasoned about from the block shapes.
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

# Tile sizes. DST_TILE × K gathers and DST_TILE × F accumulators must fit
# VMEM (~16 MiB/core budget) *together with* the resident source-feature
# block. For wide features (Reddit's 602-d) the whole table does not fit,
# so gather_aggregate also blocks the feature dimension (grid axis 1):
# each grid step holds an [N, F_TILE] slice of the table — the
# HBM↔VMEM schedule of DESIGN.md §Hardware-Adaptation.
DST_TILE = 128
# Feature-dim tile budget: keep the resident table slice under ~12 MiB,
# leaving headroom for idx/mask/out tiles.
VMEM_TABLE_BUDGET = 12 * 1024 * 1024
MM_TILE_M = 128
MM_TILE_N = 128
MM_TILE_K = 128


def _ceil_to(x: int, m: int) -> int:
    return (x + m - 1) // m * m


def _gather_agg_kernel(h_ref, idx_ref, mask_ref, o_ref, *, mean: bool):
    """One destination tile: o[i, :] = agg_k mask[i,k] * h[idx[i,k], :].

    h_ref holds the full (padded) source feature table for the batch —
    the "already staged in fast memory" operand that L3's feature cache
    is responsible for producing cheaply.
    """
    idx = idx_ref[...]                       # [T, K] int32
    mask = mask_ref[...]                     # [T, K] f32 (1 valid, 0 pad)
    h = h_ref[...]                           # [N, F]
    g = jnp.take(h, idx, axis=0)             # [T, K, F] gather
    s = jnp.sum(g * mask[..., None], axis=1)  # masked sum
    if mean:
        cnt = jnp.maximum(jnp.sum(mask, axis=1, keepdims=True), 1.0)
        s = s / cnt
    o_ref[...] = s


def gather_aggregate(h: jax.Array, idx: jax.Array, mask: jax.Array,
                     *, mode: str = "sum", dst_tile: int = DST_TILE) -> jax.Array:
    """Masked neighbor aggregation: out[i] = agg_k mask[i,k]*h[idx[i,k]].

    Args:
      h:    [N, F] f32 source node features (padded rows are zero).
      idx:  [M, K] i32 neighbor indices into ``h`` (pad entries may be 0,
            their mask is 0).
      mask: [M, K] f32 validity mask.
      mode: "sum" (GraphSAGE, Table III) or "mean" (GCN-style average,
            excluding the self term which the model adds separately).

    Returns [M, F] f32 aggregated features.
    """
    if mode not in ("sum", "mean"):
        raise ValueError(f"unknown aggregation mode: {mode!r}")
    m, k = idx.shape
    n, f = h.shape
    if mask.shape != (m, k):
        raise ValueError(f"mask shape {mask.shape} != idx shape {(m, k)}")
    tile = min(dst_tile, m) or 1
    mp = _ceil_to(m, tile)
    if mp != m:  # pad destination dim to a whole number of tiles
        idx = jnp.pad(idx, ((0, mp - m), (0, 0)))
        mask = jnp.pad(mask, ((0, mp - m), (0, 0)))

    # Feature-dim blocking: shrink the resident table slice until it
    # fits the VMEM budget (mean mode needs the full mask either way,
    # which is per-dst-tile and cheap).
    f_tile = feature_tile(n, f)
    fp = _ceil_to(f, f_tile)
    if fp != f:
        h = jnp.pad(h, ((0, 0), (0, fp - f)))
    grid = (mp // tile, fp // f_tile)
    out = pl.pallas_call(
        functools.partial(_gather_agg_kernel, mean=(mode == "mean")),
        grid=grid,
        in_specs=[
            pl.BlockSpec((n, f_tile), lambda i, j: (0, j)),   # table slice
            pl.BlockSpec((tile, k), lambda i, j: (i, 0)),     # dst tile idx
            pl.BlockSpec((tile, k), lambda i, j: (i, 0)),     # dst tile mask
        ],
        out_specs=pl.BlockSpec((tile, f_tile), lambda i, j: (i, j)),
        out_shape=jax.ShapeDtypeStruct((mp, fp), h.dtype),
        interpret=True,
    )(h, idx, mask)
    return out[:m, :f]


def feature_tile(n_src: int, feat: int, budget: int = VMEM_TABLE_BUDGET) -> int:
    """Largest feature-dim tile whose [n_src, f_tile] f32 slice fits the
    VMEM table budget (multiples of 128 lanes where possible)."""
    if n_src * feat * 4 <= budget:
        return feat
    max_f = max(1, budget // (n_src * 4))
    if max_f >= 128:
        max_f = (max_f // 128) * 128
    return min(feat, max_f)


def _matmul_kernel(a_ref, b_ref, o_ref, *, k_steps: int):
    """(i, j, k) MXU tiling; the output tile doubles as the accumulator
    (stays resident in VMEM across the k steps of one (i, j) tile)."""
    kk = pl.program_id(2)

    @pl.when(kk == 0)
    def _init():
        o_ref[...] = jnp.zeros_like(o_ref)

    o_ref[...] += jnp.dot(
        a_ref[...], b_ref[...], preferred_element_type=jnp.float32
    ).astype(o_ref.dtype)


def tiled_matmul(a: jax.Array, b: jax.Array,
                 *, tm: int = MM_TILE_M, tn: int = MM_TILE_N,
                 tk: int = MM_TILE_K) -> jax.Array:
    """C = A @ B with MXU-shaped tiling (pads every dim to tile multiples)."""
    m, k = a.shape
    k2, n = b.shape
    if k != k2:
        raise ValueError(f"inner dims mismatch: {a.shape} @ {b.shape}")
    tm = min(tm, _ceil_to(m, 8))
    tn = min(tn, _ceil_to(n, 8))
    tk = min(tk, _ceil_to(k, 8))
    mp, np_, kp = _ceil_to(m, tm), _ceil_to(n, tn), _ceil_to(k, tk)
    a = jnp.pad(a, ((0, mp - m), (0, kp - k)))
    b = jnp.pad(b, ((0, kp - k), (0, np_ - n)))
    k_steps = kp // tk
    out = pl.pallas_call(
        functools.partial(_matmul_kernel, k_steps=k_steps),
        grid=(mp // tm, np_ // tn, k_steps),
        in_specs=[
            pl.BlockSpec((tm, tk), lambda i, j, kk: (i, kk)),
            pl.BlockSpec((tk, tn), lambda i, j, kk: (kk, j)),
        ],
        out_specs=pl.BlockSpec((tm, tn), lambda i, j, kk: (i, j)),
        out_shape=jax.ShapeDtypeStruct((mp, np_), a.dtype),
        interpret=True,
    )(a, b)
    return out[:m, :n]
