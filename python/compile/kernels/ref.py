"""Pure-jnp oracles for the Pallas kernels (the correctness ground truth).

Every Pallas kernel in this package has an exact reference here; pytest
(+ hypothesis shape/dtype sweeps) asserts allclose between the two. The
Rust runtime is in turn cross-checked against the same semantics via
``rust/src/runtime/reference.rs``.
"""

from __future__ import annotations

import jax
import jax.numpy as jnp


def gather_aggregate_ref(h: jax.Array, idx: jax.Array, mask: jax.Array,
                         *, mode: str = "sum") -> jax.Array:
    """out[i] = agg_k mask[i,k] * h[idx[i,k]] — see sage_agg.gather_aggregate."""
    g = jnp.take(h, idx, axis=0)                  # [M, K, F]
    s = jnp.sum(g * mask[..., None], axis=1)      # [M, F]
    if mode == "mean":
        cnt = jnp.maximum(jnp.sum(mask, axis=1, keepdims=True), 1.0)
        s = s / cnt
    elif mode != "sum":
        raise ValueError(f"unknown aggregation mode: {mode!r}")
    return s


def matmul_ref(a: jax.Array, b: jax.Array) -> jax.Array:
    """C = A @ B, f32 accumulation — see sage_agg.tiled_matmul."""
    return jnp.dot(a, b, preferred_element_type=jnp.float32).astype(a.dtype)
