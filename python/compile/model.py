"""L2: GraphSAGE / GCN inference forward over padded mini-batch blocks.

The models follow Table III of the paper: 3 layers, hidden dim 128,
GraphSAGE with sum aggregation + fully-connected apply, GCN with average
aggregation. They are *inference* graphs (weights are baked into the HLO
at AOT time — a trained, frozen model, as in the paper's serving
setting).

Block convention (mirrored by ``rust/src/sampler/block.rs``):

- ``x``: ``[n0, F]`` features of the layer-0 (input-most, widest) node
  array; padded rows are zero.
- For layer ``l`` in 1..=3: ``idx_l [n_l, K_l] i32`` neighbor indices
  into the *previous* layer's node array, ``mask_l [n_l, K_l] f32``
  validity mask (0 for sampling/padding slots).
- Destination-nodes-first: layer ``l``'s dst nodes are exactly the first
  ``n_l`` entries of layer ``l-1``'s node array, so the self/residual
  term is ``h_prev[:n_l]`` and no separate self-index input is needed.

The neighbor aggregation — the operation whose input bytes DCI's dual
cache optimizes — is the L1 Pallas kernel ``kernels.gather_aggregate``,
so it lowers into the same HLO artifact the Rust runtime executes.
"""

from __future__ import annotations

from typing import Any, Dict, List, Sequence, Tuple

import jax
import jax.numpy as jnp

from .kernels import gather_aggregate

Params = Dict[str, Any]

MODELS = ("graphsage", "gcn")


def _glorot(key: jax.Array, fan_in: int, fan_out: int) -> jax.Array:
    scale = jnp.sqrt(2.0 / (fan_in + fan_out))
    return scale * jax.random.normal(key, (fan_in, fan_out), jnp.float32)


def init_params(model: str, feat_dim: int, hidden: int, classes: int,
                n_layers: int = 3, seed: int = 0) -> Params:
    """Deterministic 'trained' weights for the frozen inference graph."""
    if model not in MODELS:
        raise ValueError(f"unknown model {model!r}; expected one of {MODELS}")
    key = jax.random.PRNGKey(seed)
    dims = [feat_dim] + [hidden] * (n_layers - 1) + [classes]
    layers: List[Dict[str, jax.Array]] = []
    for l in range(n_layers):
        key, k1, k2, k3 = jax.random.split(key, 4)
        d_in, d_out = dims[l], dims[l + 1]
        layer = {"w_neigh": _glorot(k1, d_in, d_out),
                 "b": jnp.zeros((d_out,), jnp.float32)}
        if model == "graphsage":
            layer["w_self"] = _glorot(k2, d_in, d_out)
        del k3
        layers.append(layer)
    return {"model": model, "layers": layers}


def _sage_layer(layer: Params, h: jax.Array, idx: jax.Array,
                mask: jax.Array, *, last: bool) -> jax.Array:
    """GraphSAGE: h' = act(W_self h_dst + W_neigh * sum_k h_neigh)."""
    n_dst = idx.shape[0]
    h_dst = h[:n_dst]
    agg = gather_aggregate(h, idx, mask, mode="sum")
    out = h_dst @ layer["w_self"] + agg @ layer["w_neigh"] + layer["b"]
    return out if last else jax.nn.relu(out)


def _gcn_layer(layer: Params, h: jax.Array, idx: jax.Array,
               mask: jax.Array, *, last: bool) -> jax.Array:
    """GCN: h' = act(W * avg(neighbors ∪ self))."""
    n_dst = idx.shape[0]
    h_dst = h[:n_dst]
    s = gather_aggregate(h, idx, mask, mode="sum")
    deg = jnp.sum(mask, axis=1, keepdims=True)
    agg = (s + h_dst) / (deg + 1.0)
    out = agg @ layer["w_neigh"] + layer["b"]
    return out if last else jax.nn.relu(out)


def forward(params: Params, x: jax.Array,
            blocks: Sequence[Tuple[jax.Array, jax.Array]]) -> jax.Array:
    """Run the stacked model; returns logits ``[n_last, classes]``.

    ``blocks`` is ``[(idx_1, mask_1), ..., (idx_L, mask_L)]`` ordered
    from the input-most layer to the seed layer.
    """
    layers = params["layers"]
    if len(blocks) != len(layers):
        raise ValueError(f"{len(blocks)} blocks but {len(layers)} layers")
    layer_fn = _sage_layer if params["model"] == "graphsage" else _gcn_layer
    h = x
    for l, (idx, mask) in enumerate(blocks):
        h = layer_fn(layers[l], h, idx, mask, last=(l == len(layers) - 1))
    return h


def forward_flat(params: Params, x: jax.Array, *flat: jax.Array) -> Tuple[jax.Array]:
    """Flat-argument wrapper used for AOT lowering (and by the Rust side:
    positional args are ``x, idx_1, mask_1, ..., idx_L, mask_L``)."""
    if len(flat) % 2 != 0:
        raise ValueError("expected (idx, mask) pairs after x")
    blocks = [(flat[i], flat[i + 1]) for i in range(0, len(flat), 2)]
    return (forward(params, x, blocks),)


def block_shapes(dims: Sequence[int], ks: Sequence[int], feat_dim: int):
    """ShapeDtypeStructs for lowering: dims = [n0, n1, ..., nL] padded node
    counts, ks = [K_1..K_L] neighbor slots per layer."""
    if len(dims) != len(ks) + 1:
        raise ValueError("dims must have one more entry than ks")
    specs = [jax.ShapeDtypeStruct((dims[0], feat_dim), jnp.float32)]
    for l, k in enumerate(ks):
        n = dims[l + 1]
        specs.append(jax.ShapeDtypeStruct((n, k), jnp.int32))
        specs.append(jax.ShapeDtypeStruct((n, k), jnp.float32))
    return specs
