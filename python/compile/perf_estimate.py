"""L1/L2 performance estimation (the TPU-side half of the perf pass).

``interpret=True`` Pallas gives CPU-numpy timings that say nothing about
real accelerator behaviour, so — per DESIGN.md — kernel performance is
reasoned about *structurally*: VMEM residency per grid step, bytes moved
HBM↔VMEM per step, arithmetic intensity, and the roofline bound that
implies for each AOT variant. Run:

    python -m compile.perf_estimate            # table for all variants
    python -m compile.perf_estimate --hlo      # + L2 HLO op census

The L2 census also checks the fusion/no-recompute properties the perf
targets call for: each layer lowers exactly one gather (no redundant
re-gather), and the interpret-mode grid loop is the only while op.
"""

from __future__ import annotations

import argparse
import sys
from dataclasses import dataclass
from typing import Dict, List

from .aot import VARIANTS, to_hlo_text, worst_case_dims
from .kernels.sage_agg import feature_tile, DST_TILE

# TPU-v4-ish envelope used for the structural estimates (the repo's
# simulated serving device is an RTX 4090; the kernel *authoring* target
# is a TPU-style memory hierarchy — DESIGN.md §Hardware-Adaptation).
VMEM_BYTES = 16 * 1024 * 1024          # per-core VMEM budget
HBM_GBPS = 1200.0                      # HBM bandwidth
MXU_TFLOPS = 100.0                     # bf16 systolic peak (per core, approx)


@dataclass
class KernelEstimate:
    name: str
    vmem_step_bytes: int
    hbm_bytes_per_step: int
    flops_per_step: float
    grid_steps: int

    @property
    def vmem_ok(self) -> bool:
        return self.vmem_step_bytes <= VMEM_BYTES

    @property
    def intensity(self) -> float:
        """flops per HBM byte — the roofline x-axis."""
        return self.flops_per_step / max(self.hbm_bytes_per_step, 1)

    @property
    def bound(self) -> str:
        knee = MXU_TFLOPS * 1e12 / (HBM_GBPS * 1e9)
        return "compute" if self.intensity > knee else "memory"

    @property
    def mxu_utilization(self) -> float:
        """Fraction of MXU peak achievable under the memory roofline."""
        knee = MXU_TFLOPS * 1e12 / (HBM_GBPS * 1e9)
        return min(1.0, self.intensity / knee)


def estimate_gather(n_src: int, feat: int, n_dst: int, k: int) -> KernelEstimate:
    """gather_aggregate: grid (dst tiles × feature tiles); VMEM holds an
    [n_src, f_tile] slice of the source table + one dst tile of
    idx/mask/out. HBM traffic per step: the table slice is resident
    across the dst-tile axis (counted once per feature tile, amortized),
    idx/mask/out stream per tile. Mirrors the kernel's feature_tile
    blocking — the fix the perf pass introduced for F=602."""
    tile = min(DST_TILE, n_dst)
    f_tile = feature_tile(n_src, feat)
    dst_steps = max(1, -(-n_dst // tile))
    f_steps = max(1, -(-feat // f_tile))
    steps = dst_steps * f_steps
    vmem = n_src * f_tile * 4 + tile * k * (4 + 4) + tile * f_tile * 4
    # amortized: each table slice read once over its dst-tile sweep
    hbm = (n_src * f_tile * 4) // dst_steps + tile * k * 8 + tile * f_tile * 4
    flops = 2.0 * tile * k * f_tile
    return KernelEstimate("gather_aggregate", vmem, hbm, flops, steps)


def estimate_matmul(m: int, k: int, n: int, tm=128, tn=128, tk=128) -> KernelEstimate:
    """tiled_matmul: (i, j, kk) grid; VMEM holds one A, B, and C tile."""
    tm, tn, tk = min(tm, m), min(tn, n), min(tk, k)
    steps = max(1, (-(-m // tm)) * (-(-n // tn)) * (-(-k // tk)))
    vmem = (tm * tk + tk * tn + tm * tn) * 4
    hbm = (tm * tk + tk * tn) * 4 + (tm * tn * 4) // max(1, -(-k // tk))
    flops = 2.0 * tm * tn * tk
    return KernelEstimate("tiled_matmul", vmem, hbm, flops, steps)


def variant_estimates(name: str) -> List[KernelEstimate]:
    spec = VARIANTS[name]
    dims = worst_case_dims(spec["batch_size"], spec["ks"])
    feat, hidden = spec["feat_dim"], spec["hidden"]
    out: List[KernelEstimate] = []
    d_in = feat
    for l, k in enumerate(spec["ks"]):
        n_src, n_dst = dims[l], dims[l + 1]
        out.append(estimate_gather(n_src, d_in, n_dst, k))
        d_out = spec["classes"] if l == len(spec["ks"]) - 1 else hidden
        out.append(estimate_matmul(n_dst, d_in, d_out))
        d_in = d_out
    return out


def hlo_census(name: str) -> Dict[str, int]:
    """Lower the variant and count the op classes the L2 perf targets
    care about (gathers per layer, loop structure, dots)."""
    import jax

    from . import model as M

    spec = VARIANTS[name]
    dims = worst_case_dims(spec["batch_size"], spec["ks"])
    params = M.init_params(spec["model"], spec["feat_dim"], spec["hidden"],
                           spec["classes"], seed=spec["seed"])

    def fn(x, *flat):
        return M.forward_flat(params, x, *flat)

    lowered = jax.jit(fn).lower(*M.block_shapes(dims, spec["ks"], spec["feat_dim"]))
    text = to_hlo_text(lowered)
    return {
        "gather": text.count(" gather("),
        "while": text.count(" while("),
        "dot": text.count(" dot("),
        "bytes": len(text),
    }


def main(argv=None) -> int:
    ap = argparse.ArgumentParser(description=__doc__)
    ap.add_argument("--hlo", action="store_true", help="also run the L2 HLO census")
    ap.add_argument("--variants", nargs="*", default=None)
    args = ap.parse_args(argv)
    names = args.variants or [n for n in VARIANTS if not n.startswith("smoke")]

    print(f"{'variant':<28} {'kernel':<18} {'VMEM/step':>10} {'ok':>3} "
          f"{'int(fl/B)':>9} {'bound':>8} {'MXU util':>8}")
    for name in names:
        for e in variant_estimates(name):
            print(f"{name:<28} {e.name:<18} {e.vmem_step_bytes/1e6:>8.2f}MB "
                  f"{'y' if e.vmem_ok else 'N':>3} {e.intensity:>9.1f} "
                  f"{e.bound:>8} {e.mxu_utilization:>7.1%}")
    if args.hlo:
        print("\nL2 HLO census (one gather per layer = no redundant re-gather):")
        for name in names:
            c = hlo_census(name)
            print(f"  {name}: gather={c['gather']} while={c['while']} "
                  f"dot={c['dot']} hlo={c['bytes']/1e6:.1f}MB")
    return 0


if __name__ == "__main__":
    sys.exit(main())
