"""AOT lowering: JAX model (+ Pallas kernels) -> HLO *text* artifacts.

This is the only place Python touches the system; the Rust coordinator
loads the emitted ``artifacts/*.hlo.txt`` via the ``xla`` crate's PJRT
CPU client and never imports Python at runtime.

Interchange format is HLO **text**, not a serialized HloModuleProto:
jax >= 0.5 emits protos with 64-bit instruction ids which xla_extension
0.5.1 rejects (``proto.id() <= INT_MAX``); the text parser reassigns ids
and round-trips cleanly (see /opt/xla-example/README.md).

Each artifact is one (model, feat_dim, classes, padded-shape) variant;
``manifest.json`` describes them all for ``rust/src/runtime/artifacts.rs``.
"""

from __future__ import annotations

import argparse
import json
import os
import sys
from typing import Dict, List, Sequence

import jax
import jax.numpy as jnp
import numpy as np
from jax._src.lib import xla_client as xc

from . import model as M


def to_hlo_text(lowered) -> str:
    """StableHLO -> XlaComputation -> HLO text (return_tuple=True; the
    Rust side unwraps with ``to_tuple1()``).

    ``as_hlo_text(True)`` = print_large_constants: the frozen model
    weights are baked into the HLO as constants, and the default printer
    elides anything big as ``constant({...})`` — which the text parser
    on the Rust side would silently turn into zeros. Full printing is
    REQUIRED for correct numerics (pinned by the golden-file test).
    """
    mlir_mod = lowered.compiler_ir("stablehlo")
    comp = xc._xla.mlir.mlir_module_to_xla_computation(
        str(mlir_mod), use_tuple_args=False, return_tuple=True
    )
    return comp.as_hlo_text(True)


def worst_case_dims(batch_size: int, ks: Sequence[int]) -> List[int]:
    """Padded node-array sizes per layer, input-most first.

    dims[L] = batch; dims[l-1] = dims[l] * (K_l + 1) — every dst node
    contributes itself (dst-first convention) plus up to K_l sampled
    neighbors. Real batches are far smaller after dedup; the Rust
    padding layer (runtime/padding.rs) buckets into these caps.
    """
    dims = [batch_size]
    for k in reversed(list(ks)):
        dims.append(dims[-1] * (k + 1))
    return list(reversed(dims))


# name -> variant spec. `ks` are neighbor slots per layer, input-most
# first (the paper's fan-out strings, e.g. '8,4,2', use the same order).
VARIANTS: Dict[str, Dict] = {
    # Tiny smoke variants: fast to compile, used by rust unit/integration
    # tests so `cargo test` exercises the real PJRT path cheaply.
    "smoke_sage": dict(model="graphsage", feat_dim=8, hidden=16, classes=4,
                       batch_size=8, ks=[2, 2, 2], seed=7),
    "smoke_gcn": dict(model="gcn", feat_dim=8, hidden=16, classes=4,
                      batch_size=8, ks=[2, 2, 2], seed=7),
    # products-sim (Table II: F=100, 47 classes) serving variants.
    "sage_f100_c47_bs256_k842": dict(model="graphsage", feat_dim=100,
                                     hidden=128, classes=47, batch_size=256,
                                     ks=[8, 4, 2], seed=1),
    "gcn_f100_c47_bs256_k842": dict(model="gcn", feat_dim=100, hidden=128,
                                    classes=47, batch_size=256,
                                    ks=[8, 4, 2], seed=1),
    "sage_f100_c47_bs1024_k222": dict(model="graphsage", feat_dim=100,
                                      hidden=128, classes=47,
                                      batch_size=1024, ks=[2, 2, 2], seed=1),
    # reddit-sim (Table II: F=602, 41 classes).
    "sage_f602_c41_bs256_k222": dict(model="graphsage", feat_dim=602,
                                     hidden=128, classes=41, batch_size=256,
                                     ks=[2, 2, 2], seed=1),
}


def write_golden(name: str, spec: Dict, params, dims: List[int], out_dir: str) -> None:
    """Golden input/output pair for the Rust runtime's numerics test
    (rust/tests/runtime_pjrt.rs): random padded inputs + the eager-JAX
    logits. The Rust side executes the HLO artifact on the same inputs
    and asserts allclose."""
    rng = np.random.default_rng(12345)
    x = rng.normal(size=(dims[0], spec["feat_dim"])).astype(np.float32)
    flat, blocks_json = [], []
    for l, k in enumerate(spec["ks"]):
        n_src, n_dst = dims[l], dims[l + 1]
        idx = rng.integers(0, n_src, size=(n_dst, k)).astype(np.int32)
        mask = (rng.random((n_dst, k)) < 0.8).astype(np.float32)
        flat.extend([idx, mask])
        blocks_json.append({"idx": idx.flatten().tolist(),
                            "mask": mask.flatten().tolist()})
    (logits,) = M.forward_flat(params, jnp.asarray(x),
                               *[jnp.asarray(a) for a in flat])
    doc = {
        "variant": name,
        "x": x.flatten().tolist(),
        "blocks": blocks_json,
        "logits": np.asarray(logits).flatten().tolist(),
    }
    with open(os.path.join(out_dir, f"{name}.golden.json"), "w") as f:
        json.dump(doc, f)


def build_variant(name: str, spec: Dict, out_dir: str) -> Dict:
    dims = worst_case_dims(spec["batch_size"], spec["ks"])
    params = M.init_params(spec["model"], spec["feat_dim"], spec["hidden"],
                           spec["classes"], n_layers=len(spec["ks"]),
                           seed=spec["seed"])

    def fn(x, *flat):
        return M.forward_flat(params, x, *flat)

    arg_specs = M.block_shapes(dims, spec["ks"], spec["feat_dim"])
    lowered = jax.jit(fn).lower(*arg_specs)
    text = to_hlo_text(lowered)
    fname = f"{name}.hlo.txt"
    with open(os.path.join(out_dir, fname), "w") as f:
        f.write(text)
    if name.startswith("smoke"):
        write_golden(name, spec, params, dims, out_dir)
    entry = dict(name=name, file=fname, model=spec["model"],
                 feat_dim=spec["feat_dim"], hidden=spec["hidden"],
                 classes=spec["classes"], batch_size=spec["batch_size"],
                 ks=spec["ks"], dims=dims, seed=spec["seed"],
                 hlo_bytes=len(text))
    print(f"  {name}: dims={dims} ks={spec['ks']} "
          f"({len(text) / 1e6:.1f} MB hlo text)")
    return entry


def main(argv: Sequence[str] | None = None) -> int:
    ap = argparse.ArgumentParser(description=__doc__)
    ap.add_argument("--out", default="../artifacts",
                    help="output directory (default: ../artifacts)")
    ap.add_argument("--variants", nargs="*", default=None,
                    help="subset of variant names (default: all)")
    ap.add_argument("--list", action="store_true", help="list variants")
    args = ap.parse_args(argv)

    if args.list:
        for name, spec in VARIANTS.items():
            print(f"{name}: {spec}")
        return 0

    names = args.variants or list(VARIANTS)
    unknown = [n for n in names if n not in VARIANTS]
    if unknown:
        ap.error(f"unknown variants: {unknown}; see --list")

    out_dir = args.out
    os.makedirs(out_dir, exist_ok=True)
    print(f"lowering {len(names)} variants -> {out_dir}")
    entries = [build_variant(n, VARIANTS[n], out_dir) for n in names]
    manifest = dict(version=1, artifacts=entries)
    with open(os.path.join(out_dir, "manifest.json"), "w") as f:
        json.dump(manifest, f, indent=2)
    print(f"wrote manifest.json with {len(entries)} artifacts")
    return 0


if __name__ == "__main__":
    sys.exit(main())
