"""AOT path: lowering to HLO text + manifest schema (what Rust consumes)."""

import json
import os

import numpy as np
import pytest

import jax
import jax.numpy as jnp

from compile import aot, model as M


def test_worst_case_dims():
    assert aot.worst_case_dims(8, [2, 2, 2]) == [216, 72, 24, 8]
    assert aot.worst_case_dims(256, [8, 4, 2]) == [34560, 3840, 768, 256]
    assert aot.worst_case_dims(4, []) == [4]


def test_variant_table_is_well_formed():
    for name, spec in aot.VARIANTS.items():
        assert spec["model"] in M.MODELS, name
        assert len(spec["ks"]) == 3, name
        assert spec["batch_size"] >= 1 and spec["feat_dim"] >= 1


def test_smoke_variant_lowers_and_manifest(tmp_path):
    entry = aot.build_variant("smoke_sage", aot.VARIANTS["smoke_sage"],
                              str(tmp_path))
    path = tmp_path / entry["file"]
    text = path.read_text()
    assert text.startswith("HloModule")
    assert "ENTRY" in text
    # 1 feature input + (idx, mask) per layer = 7 entry params
    header = text.splitlines()[0]
    args = header.split("->")[0]
    assert args.count("f32[") + args.count("s32[") == 7
    # no Mosaic custom-calls: must be runnable by the CPU PJRT client
    assert "mosaic" not in text.lower()
    assert entry["dims"] == [216, 72, 24, 8]


def test_main_writes_manifest(tmp_path):
    rc = aot.main(["--out", str(tmp_path), "--variants", "smoke_gcn"])
    assert rc == 0
    manifest = json.loads((tmp_path / "manifest.json").read_text())
    assert manifest["version"] == 1
    (e,) = manifest["artifacts"]
    assert e["name"] == "smoke_gcn" and e["model"] == "gcn"
    assert os.path.exists(tmp_path / e["file"])


def test_main_rejects_unknown_variant(tmp_path):
    with pytest.raises(SystemExit):
        aot.main(["--out", str(tmp_path), "--variants", "nope"])


def test_lowered_hlo_numerics_match_eager(tmp_path):
    """Compile the lowered StableHLO with jax's own CPU client and compare
    against eager execution — the same check the Rust runtime test does."""
    spec = aot.VARIANTS["smoke_sage"]
    dims = aot.worst_case_dims(spec["batch_size"], spec["ks"])
    params = M.init_params(spec["model"], spec["feat_dim"], spec["hidden"],
                           spec["classes"], seed=spec["seed"])

    def fn(x, *flat):
        return M.forward_flat(params, x, *flat)

    rng = np.random.default_rng(0)
    x = rng.normal(size=(dims[0], spec["feat_dim"])).astype(np.float32)
    flat = []
    for l, k in enumerate(spec["ks"]):
        n_src, n_dst = dims[l], dims[l + 1]
        flat.append(rng.integers(0, n_src, size=(n_dst, k)).astype(np.int32))
        flat.append((rng.random((n_dst, k)) < 0.8).astype(np.float32))
    compiled = jax.jit(fn).lower(x, *flat).compile()
    (got,) = compiled(x, *flat)
    (want,) = fn(jnp.asarray(x), *[jnp.asarray(a) for a in flat])
    np.testing.assert_allclose(np.asarray(got), np.asarray(want),
                               rtol=1e-4, atol=1e-4)
