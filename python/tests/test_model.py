"""L2 correctness: model forward semantics, shapes, and conventions."""

import numpy as np
import pytest

import jax
import jax.numpy as jnp

from compile import model as M
from compile.kernels.ref import gather_aggregate_ref


def _blocks(rng, dims, ks):
    """Random valid blocks for padded dims=[n0..nL], ks=[K1..KL]."""
    out = []
    for l, k in enumerate(ks):
        n_src, n_dst = dims[l], dims[l + 1]
        idx = jnp.asarray(rng.integers(0, n_src, size=(n_dst, k)).astype(np.int32))
        mask = jnp.asarray((rng.random((n_dst, k)) < 0.8).astype(np.float32))
        out.append((idx, mask))
    return out


@pytest.mark.parametrize("model", ["graphsage", "gcn"])
def test_forward_shapes(model):
    rng = np.random.default_rng(0)
    dims, ks, f, c = [40, 20, 10, 5], [3, 2, 2], 12, 7
    params = M.init_params(model, f, 16, c)
    x = jnp.asarray(rng.normal(size=(dims[0], f)).astype(np.float32))
    logits = M.forward(params, x, _blocks(rng, dims, ks))
    assert logits.shape == (5, c)
    assert bool(jnp.isfinite(logits).all())


def test_init_params_structure_and_determinism():
    p1 = M.init_params("graphsage", 10, 16, 4, seed=3)
    p2 = M.init_params("graphsage", 10, 16, 4, seed=3)
    assert p1["model"] == "graphsage" and len(p1["layers"]) == 3
    for l1, l2 in zip(p1["layers"], p2["layers"]):
        np.testing.assert_array_equal(l1["w_neigh"], l2["w_neigh"])
        assert "w_self" in l1
    # gcn has no self weight
    pg = M.init_params("gcn", 10, 16, 4)
    assert all("w_self" not in l for l in pg["layers"])
    with pytest.raises(ValueError):
        M.init_params("gat", 10, 16, 4)


def test_sage_single_layer_manual_reference():
    """One GraphSAGE layer against a hand-written formula."""
    rng = np.random.default_rng(1)
    n_src, n_dst, k, f, c = 9, 4, 3, 6, 5
    params = M.init_params("graphsage", f, 16, c, n_layers=1, seed=0)
    h = jnp.asarray(rng.normal(size=(n_src, f)).astype(np.float32))
    idx = jnp.asarray(rng.integers(0, n_src, size=(n_dst, k)).astype(np.int32))
    mask = jnp.asarray((rng.random((n_dst, k)) < 0.6).astype(np.float32))
    got = M.forward(params, h, [(idx, mask)])
    layer = params["layers"][0]
    agg = gather_aggregate_ref(h, idx, mask, mode="sum")
    want = h[:n_dst] @ layer["w_self"] + agg @ layer["w_neigh"] + layer["b"]
    np.testing.assert_allclose(got, want, rtol=1e-5, atol=1e-5)


def test_gcn_single_layer_manual_reference():
    rng = np.random.default_rng(2)
    n_src, n_dst, k, f, c = 9, 4, 3, 6, 5
    params = M.init_params("gcn", f, 16, c, n_layers=1, seed=0)
    h = jnp.asarray(rng.normal(size=(n_src, f)).astype(np.float32))
    idx = jnp.asarray(rng.integers(0, n_src, size=(n_dst, k)).astype(np.int32))
    mask = jnp.asarray((rng.random((n_dst, k)) < 0.6).astype(np.float32))
    got = M.forward(params, h, [(idx, mask)])
    layer = params["layers"][0]
    s = gather_aggregate_ref(h, idx, mask, mode="sum")
    deg = np.asarray(mask).sum(axis=1, keepdims=True)
    want = (s + h[:n_dst]) / (deg + 1.0) @ layer["w_neigh"] + layer["b"]
    np.testing.assert_allclose(got, want, rtol=1e-5, atol=1e-5)


def test_padding_rows_do_not_leak():
    """Zero-padded input rows + zero masks must yield identical logits for
    the real rows regardless of padded garbage in idx slots."""
    rng = np.random.default_rng(3)
    dims, ks, f, c = [30, 12, 6, 3], [2, 2, 2], 8, 4
    params = M.init_params("graphsage", f, 16, c)
    x = rng.normal(size=(dims[0], f)).astype(np.float32)
    x[20:] = 0.0  # padded tail
    blocks = _blocks(rng, dims, ks)
    base = M.forward(params, jnp.asarray(x), blocks)
    # retarget masked-out slots at arbitrary indices: must not matter
    blocks2 = []
    for idx, mask in blocks:
        scrambled = np.asarray(idx).copy()
        dead = np.asarray(mask) == 0.0
        scrambled[dead] = (scrambled[dead] + 13) % dims[0] % idx.shape[0]
        blocks2.append((jnp.asarray(scrambled), mask))
    got = M.forward(params, jnp.asarray(x), blocks2)
    np.testing.assert_allclose(got, base, rtol=1e-5, atol=1e-5)


def test_forward_flat_matches_forward():
    rng = np.random.default_rng(4)
    dims, ks, f, c = [40, 20, 10, 5], [3, 2, 2], 12, 7
    params = M.init_params("gcn", f, 16, c)
    x = jnp.asarray(rng.normal(size=(dims[0], f)).astype(np.float32))
    blocks = _blocks(rng, dims, ks)
    flat = [a for b in blocks for a in b]
    (got,) = M.forward_flat(params, x, *flat)
    want = M.forward(params, x, blocks)
    np.testing.assert_array_equal(np.asarray(got), np.asarray(want))
    with pytest.raises(ValueError):
        M.forward_flat(params, x, flat[0])


def test_block_shapes_validation():
    specs = M.block_shapes([40, 20, 10, 5], [3, 2, 2], 12)
    assert len(specs) == 7
    assert specs[0].shape == (40, 12)
    assert specs[1].shape == (20, 3) and specs[1].dtype == jnp.int32
    with pytest.raises(ValueError):
        M.block_shapes([40, 20], [3, 2, 2], 12)


def test_forward_wrong_block_count():
    params = M.init_params("graphsage", 4, 8, 2)
    x = jnp.zeros((10, 4), jnp.float32)
    with pytest.raises(ValueError):
        M.forward(params, x, [])
