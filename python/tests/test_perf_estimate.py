"""Perf-estimate layer: VMEM/roofline numbers are sane and the L2
lowering has the structural properties the perf targets require."""

import pytest

from compile import perf_estimate as pe
from compile.aot import VARIANTS


def test_gather_estimate_fits_vmem_for_all_variants():
    for name in VARIANTS:
        for e in pe.variant_estimates(name):
            assert e.vmem_step_bytes > 0
            assert e.grid_steps >= 1
            assert e.vmem_ok, (
                f"{name}/{e.name}: {e.vmem_step_bytes/1e6:.1f}MB exceeds VMEM — "
                "shrink DST_TILE or block the feature table"
            )


def test_matmul_roofline():
    e = pe.estimate_matmul(512, 512, 512)
    # 3 tiles of 128x128 f32
    assert e.vmem_step_bytes == 3 * 128 * 128 * 4
    # honest finding: 128-tiles at f32 are memory-bound under the
    # envelope (intensity = tm/4 = 32 fl/B < 83 knee); larger tiles are
    # what buys compute-boundness
    assert e.bound == "memory"
    big = pe.estimate_matmul(2048, 2048, 2048, tm=512, tn=512, tk=512)
    assert big.bound == "compute"
    assert big.vmem_ok
    assert big.mxu_utilization == 1.0


def test_gather_is_memory_bound():
    # gather+aggregate does 2 flops per gathered element: always memory
    # bound; its MXU utilization estimate must reflect that honestly
    e = pe.estimate_gather(n_src=34560, feat=100, n_dst=3840, k=8)
    assert e.bound == "memory"
    assert e.mxu_utilization < 0.2
    assert e.intensity > 0.0


def test_intensity_monotone_in_k():
    # more neighbors per dst row amortize the table reads
    lo = pe.estimate_gather(10_000, 100, 1000, 2)
    hi = pe.estimate_gather(10_000, 100, 1000, 16)
    assert hi.intensity > lo.intensity


def test_hlo_census_one_gather_per_layer():
    c = pe.hlo_census("smoke_sage")
    # 3 layers -> exactly 3 gathers (no redundant re-gather); while-loop
    # count is one interpret-mode grid loop per pallas_call
    assert c["gather"] == 3
    assert c["while"] >= 3
    assert c["dot"] >= 6  # w_self + w_neigh per layer


def test_main_runs():
    assert pe.main(["--variants", "smoke_sage"]) == 0
