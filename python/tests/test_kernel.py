"""L1 correctness: Pallas kernels vs the pure-jnp oracle (ref.py).

This is the CORE correctness signal for the compute layer: the same
kernels lower into the AOT HLO artifacts the Rust runtime executes.
Hypothesis sweeps shapes/dtypes; fixed cases pin the edge conditions.
"""

import numpy as np
import pytest

import jax
import jax.numpy as jnp
from hypothesis import given, settings, strategies as st

from compile.kernels import gather_aggregate, tiled_matmul
from compile.kernels.ref import gather_aggregate_ref, matmul_ref


def _rng(seed):
    return np.random.default_rng(seed)


def _agg_case(seed, n, f, m, k, density=0.7):
    r = _rng(seed)
    h = jnp.asarray(r.normal(size=(n, f)).astype(np.float32))
    idx = jnp.asarray(r.integers(0, n, size=(m, k)).astype(np.int32))
    mask = jnp.asarray((r.random((m, k)) < density).astype(np.float32))
    return h, idx, mask


# ---------------------------------------------------------------- gather


@pytest.mark.parametrize("mode", ["sum", "mean"])
@pytest.mark.parametrize("n,f,m,k", [
    (1, 1, 1, 1),          # degenerate
    (5, 3, 7, 2),          # m > n
    (128, 100, 128, 8),    # exact tile
    (129, 7, 130, 5),      # one past tile boundary
    (300, 602, 64, 15),    # reddit-like feature width
])
def test_gather_aggregate_matches_ref(mode, n, f, m, k):
    h, idx, mask = _agg_case(42, n, f, m, k)
    got = gather_aggregate(h, idx, mask, mode=mode)
    want = gather_aggregate_ref(h, idx, mask, mode=mode)
    np.testing.assert_allclose(got, want, rtol=1e-5, atol=1e-5)


def test_gather_aggregate_all_masked_rows_are_zero_sum():
    h, idx, _ = _agg_case(1, 10, 4, 6, 3)
    mask = jnp.zeros((6, 3), jnp.float32)
    out = gather_aggregate(h, idx, mask, mode="sum")
    np.testing.assert_array_equal(np.asarray(out), np.zeros((6, 4), np.float32))


def test_gather_aggregate_mean_all_masked_guards_div0():
    h, idx, _ = _agg_case(2, 10, 4, 6, 3)
    mask = jnp.zeros((6, 3), jnp.float32)
    out = np.asarray(gather_aggregate(h, idx, mask, mode="mean"))
    assert np.isfinite(out).all()
    np.testing.assert_array_equal(out, np.zeros((6, 4), np.float32))


def test_gather_aggregate_single_neighbor_identity():
    # K=1, full mask, idx=i -> output == input rows.
    r = _rng(3)
    h = jnp.asarray(r.normal(size=(9, 5)).astype(np.float32))
    idx = jnp.arange(9, dtype=jnp.int32)[:, None]
    mask = jnp.ones((9, 1), jnp.float32)
    out = gather_aggregate(h, idx, mask, mode="mean")
    np.testing.assert_allclose(out, h, rtol=1e-6)


def test_gather_aggregate_rejects_bad_mode_and_shape():
    h, idx, mask = _agg_case(4, 8, 3, 4, 2)
    with pytest.raises(ValueError):
        gather_aggregate(h, idx, mask, mode="max")
    with pytest.raises(ValueError):
        gather_aggregate(h, idx, mask[:, :1])


@settings(max_examples=25, deadline=None)
@given(
    n=st.integers(1, 200), f=st.integers(1, 64),
    m=st.integers(1, 200), k=st.integers(1, 16),
    mode=st.sampled_from(["sum", "mean"]),
    seed=st.integers(0, 2**31 - 1),
)
def test_gather_aggregate_hypothesis(n, f, m, k, mode, seed):
    h, idx, mask = _agg_case(seed, n, f, m, k)
    got = gather_aggregate(h, idx, mask, mode=mode)
    want = gather_aggregate_ref(h, idx, mask, mode=mode)
    np.testing.assert_allclose(got, want, rtol=1e-4, atol=1e-4)


@settings(max_examples=10, deadline=None)
@given(tile=st.sampled_from([1, 2, 32, 64, 128, 256]),
       seed=st.integers(0, 2**31 - 1))
def test_gather_aggregate_tile_invariance(tile, seed):
    # The dst tile size is a schedule knob; results must not depend on it.
    h, idx, mask = _agg_case(seed, 61, 9, 77, 4)
    base = gather_aggregate(h, idx, mask, mode="sum", dst_tile=128)
    got = gather_aggregate(h, idx, mask, mode="sum", dst_tile=tile)
    np.testing.assert_allclose(got, base, rtol=1e-5, atol=1e-5)


# ---------------------------------------------------------------- matmul


@pytest.mark.parametrize("m,k,n", [
    (1, 1, 1),
    (128, 128, 128),     # exact MXU tile
    (129, 130, 131),     # just past tiles
    (7, 300, 5),         # wide inner dim
    (256, 100, 128),     # layer-transform shape (F=100 -> H=128)
])
def test_tiled_matmul_matches_ref(m, k, n):
    r = _rng(7)
    a = jnp.asarray(r.normal(size=(m, k)).astype(np.float32))
    b = jnp.asarray(r.normal(size=(k, n)).astype(np.float32))
    np.testing.assert_allclose(tiled_matmul(a, b), matmul_ref(a, b),
                               rtol=1e-4, atol=1e-4)


def test_tiled_matmul_rejects_mismatched_inner():
    a = jnp.zeros((3, 4), jnp.float32)
    b = jnp.zeros((5, 2), jnp.float32)
    with pytest.raises(ValueError):
        tiled_matmul(a, b)


@settings(max_examples=20, deadline=None)
@given(m=st.integers(1, 200), k=st.integers(1, 200), n=st.integers(1, 200),
       seed=st.integers(0, 2**31 - 1))
def test_tiled_matmul_hypothesis(m, k, n, seed):
    r = _rng(seed)
    a = jnp.asarray(r.normal(size=(m, k)).astype(np.float32))
    b = jnp.asarray(r.normal(size=(k, n)).astype(np.float32))
    np.testing.assert_allclose(tiled_matmul(a, b), matmul_ref(a, b),
                               rtol=1e-3, atol=1e-3)


def test_kernels_lower_into_jit_without_callbacks():
    # interpret=True must lower to plain HLO ops executable by any PJRT
    # backend (no mosaic custom-calls) — this is what makes the Rust CPU
    # runtime possible.
    h, idx, mask = _agg_case(11, 32, 8, 16, 4)
    f = jax.jit(lambda h, i, m: gather_aggregate(h, i, m, mode="sum"))
    text = f.lower(h, idx, mask).compile().as_text()
    assert "custom-call" not in text.lower() or "mosaic" not in text.lower()
