#!/usr/bin/env python3
"""Cross-PR run-bundle diffing: this run's sealed bundle vs the prior one.

CI uploads every sealed run bundle as an artifact; this script takes the
bundle a previous run produced (downloaded via `actions/download-artifact`
with a run id, or the `gh run download` fallback) and the bundle the
current run just sealed, re-verifies BOTH manifests with
`ci/verify_bundle.py`'s digest logic, and renders a per-file metric
delta table into the job summary — the cross-PR perf trajectory next to
the code that changed it.

Tolerates a missing prior bundle (first run on a branch, expired
artifact, fork PR without artifact access): the diff is skipped with a
note, never a failure. A *current* bundle that fails verification is a
hard failure — the diff must not launder a broken seal.

Usage:
    python3 ci/diff_bundle.py --current DIR [--previous DIR]
                              [--summary FILE]

`--summary` defaults to $GITHUB_STEP_SUMMARY when set (appended), else
stdout. Stdlib only.
"""

import argparse
import json
import os
import sys

sys.path.insert(0, os.path.dirname(os.path.abspath(__file__)))
import verify_bundle  # noqa: E402


def flatten_numeric(doc, prefix=""):
    """Dotted-path -> numeric value over a parsed JSON document.

    Booleans are skipped (a flipped flag is not a metric delta); list
    indices are part of the path, which is stable because bundles are
    sealed from deterministic runs.
    """
    out = {}
    if isinstance(doc, dict):
        for key in sorted(doc):
            path = f"{prefix}.{key}" if prefix else str(key)
            out.update(flatten_numeric(doc[key], path))
    elif isinstance(doc, list):
        for i, item in enumerate(doc):
            out.update(flatten_numeric(item, f"{prefix}[{i}]"))
    elif isinstance(doc, (int, float)) and not isinstance(doc, bool):
        out[prefix] = float(doc)
    return out


def bundle_metrics(bundle_dir):
    """File name -> {metric path -> value} for every JSON member.

    The manifest itself is excluded (its hashes differ by construction);
    unparsable members are skipped — verification already ruled on their
    integrity, and a non-JSON member is simply not a metrics source.
    """
    out = {}
    for name in sorted(os.listdir(bundle_dir)):
        if name == "manifest.json" or not name.endswith(".json"):
            continue
        try:
            with open(os.path.join(bundle_dir, name)) as f:
                doc = json.load(f)
        except (json.JSONDecodeError, OSError):
            continue
        metrics = flatten_numeric(doc)
        if metrics:
            out[name] = metrics
    return out


def fmt(value):
    if value == int(value):
        return str(int(value))
    return f"{value:.6g}"


def diff_table(prev, curr):
    """Markdown lines for the per-file metric delta table."""
    lines = [
        "| file | metric | prev | curr | delta |",
        "| --- | --- | ---: | ---: | ---: |",
    ]
    changed = 0
    for name in sorted(set(prev) | set(curr)):
        if name not in prev:
            lines.append(f"| `{name}` | *(new file)* | — | — | — |")
            continue
        if name not in curr:
            lines.append(f"| `{name}` | *(removed)* | — | — | — |")
            continue
        p, c = prev[name], curr[name]
        for metric in sorted(set(p) | set(c)):
            if metric not in p:
                lines.append(f"| `{name}` | `{metric}` | — | {fmt(c[metric])} | new |")
                continue
            if metric not in c:
                lines.append(f"| `{name}` | `{metric}` | {fmt(p[metric])} | — | gone |")
                continue
            pv, cv = p[metric], c[metric]
            if pv == cv:
                continue
            changed += 1
            if pv != 0:
                delta = f"{100.0 * (cv - pv) / abs(pv):+.1f}%"
            else:
                delta = f"{cv - pv:+g}"
            lines.append(
                f"| `{name}` | `{metric}` | {fmt(pv)} | {fmt(cv)} | {delta} |"
            )
    if changed == 0:
        lines.append("| — | *(no metric changed)* | — | — | — |")
    return lines


def emit(summary_path, lines):
    text = "\n".join(lines) + "\n"
    if summary_path:
        with open(summary_path, "a") as f:
            f.write(text)
    else:
        sys.stdout.write(text)


def main():
    ap = argparse.ArgumentParser(description=__doc__.splitlines()[0])
    ap.add_argument("--current", required=True, help="this run's sealed bundle")
    ap.add_argument("--previous", help="prior run's bundle (may be absent)")
    ap.add_argument(
        "--summary",
        default=os.environ.get("GITHUB_STEP_SUMMARY", ""),
        help="markdown output path (appended); default $GITHUB_STEP_SUMMARY "
        "or stdout",
    )
    args = ap.parse_args()

    lines = ["## Run-bundle diff", ""]

    # the current bundle gates: a broken seal fails the job here even
    # though verify_bundle.py also runs as its own step (defense in
    # depth — this script may be wired into other workflows)
    failures = verify_bundle.verify(args.current)
    if failures:
        print(f"current bundle {args.current} FAILED verification:",
              file=sys.stderr)
        for failure in failures:
            print(f"  - {failure}", file=sys.stderr)
        return 1
    with open(os.path.join(args.current, "manifest.json")) as f:
        curr_digest = json.load(f)["manifest_sha256"]
    lines.append(f"current: `{args.current}` manifest_sha256 `{curr_digest}`")

    # the prior bundle is best-effort: absent or unverifiable skips the
    # diff with a note, because the first run on a branch (or an expired
    # artifact) is not a regression
    prev_dir = args.previous
    if not prev_dir or not os.path.isdir(prev_dir):
        lines += ["", "*No prior bundle available — diff skipped.*"]
        emit(args.summary, lines)
        print("no prior bundle; diff skipped")
        return 0
    failures = verify_bundle.verify(prev_dir)
    if failures:
        lines += [
            "",
            f"*Prior bundle `{prev_dir}` failed verification "
            f"({len(failures)} problem(s)) — diff skipped.*",
        ]
        emit(args.summary, lines)
        print(f"prior bundle {prev_dir} failed verification; diff skipped")
        return 0
    with open(os.path.join(prev_dir, "manifest.json")) as f:
        prev_digest = json.load(f)["manifest_sha256"]
    lines.append(f"previous: `{prev_dir}` manifest_sha256 `{prev_digest}`")
    lines.append("")

    if prev_digest == curr_digest:
        lines.append("*Bundles are byte-identical.*")
    else:
        lines += diff_table(bundle_metrics(prev_dir), bundle_metrics(args.current))
    emit(args.summary, lines)
    print(f"diffed {args.current} against {prev_dir}")
    return 0


if __name__ == "__main__":
    sys.exit(main())
