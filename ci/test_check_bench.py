#!/usr/bin/env python3
"""Unit tests for the bench gate itself (ci/check_bench.py).

The gate is the last line of defense for every perf and correctness
threshold in CI; a bug here silently un-gates the whole bench fleet.
Stdlib unittest only — run as a gating CI step:

    python3 ci/test_check_bench.py
"""

import contextlib
import io
import json
import os
import sys
import tempfile
import unittest

sys.path.insert(0, os.path.dirname(os.path.abspath(__file__)))
import check_bench  # noqa: E402


def write_json(dirname, name, doc):
    path = os.path.join(dirname, name)
    with open(path, "w") as f:
        json.dump(doc, f)
    return path


SCENARIO_DOC = {
    "bench": "scenarios",
    "scenarios": 5,
    "swap_stalls_total": 0,
    "rows": [
        {"scenario": "flash_crowd", "recovered_hit_ratio": 0.97, "swap_stalls": 0},
        {"scenario": "diurnal", "recovered_hit_ratio": 0.95, "swap_stalls": 0},
        {"scenario": "scan_storm", "swap_stalls": 0},
    ],
}


class FlattenTest(unittest.TestCase):
    def test_top_level_and_rows_merge_last_wins(self):
        doc = {"a": 1, "rows": [{"b": 2}, {"b": 3, "c": 4}]}
        self.assertEqual(check_bench.flatten(doc), {"a": 1, "b": 3, "c": 4})

    def test_non_dict_rows_are_skipped(self):
        doc = {"rows": [[1, 2], {"x": 9}, "junk"]}
        self.assertEqual(check_bench.flatten(doc), {"x": 9})

    def test_scenario_rows_merge_by_id(self):
        by = check_bench.scenario_rows(SCENARIO_DOC)
        self.assertEqual(sorted(by), ["diurnal", "flash_crowd", "scan_storm"])
        self.assertEqual(by["flash_crowd"]["recovered_hit_ratio"], 0.97)
        # repeated scenario rows dict-merge, last wins
        doc = {"rows": [{"scenario": "x", "v": 1}, {"scenario": "x", "v": 2}]}
        self.assertEqual(check_bench.scenario_rows(doc)["x"]["v"], 2)


class CheckFileTest(unittest.TestCase):
    def check(self, doc, bounds):
        with tempfile.TemporaryDirectory() as d:
            return check_bench.check_file(write_json(d, "b.json", doc), bounds)

    def test_in_bound_value_passes(self):
        cells, failures = self.check({"speedup": 2.0}, {"speedup": {"min": 1.5}})
        self.assertEqual(failures, [])
        self.assertIn("speedup=2 [>=1.5 ok]", cells)

    def test_missing_key_fails(self):
        cells, failures = self.check({"other": 1}, {"speedup": {"min": 1.5}})
        self.assertEqual(len(failures), 1)
        self.assertIn("missing key 'speedup'", failures[0])
        self.assertIn("speedup=MISSING", cells)

    def test_out_of_bound_fails(self):
        _, failures = self.check({"speedup": 1.0}, {"speedup": {"min": 1.5}})
        self.assertEqual(len(failures), 1)
        self.assertIn("out of bounds", failures[0])

    def test_max_bound_fails_high_values(self):
        _, failures = self.check({"stalls": 3}, {"stalls": {"max": 0}})
        self.assertEqual(len(failures), 1)

    def test_non_numeric_value_fails(self):
        _, failures = self.check({"speedup": "fast"}, {"speedup": {"min": 1}})
        self.assertEqual(len(failures), 1)
        self.assertIn("not numeric", failures[0])

    def test_missing_file_fails(self):
        cells, failures = check_bench.check_file("/nonexistent/b.json", {"x": {}})
        self.assertEqual(cells, [])
        self.assertEqual(len(failures), 1)

    def test_unparsable_json_fails(self):
        with tempfile.TemporaryDirectory() as d:
            path = os.path.join(d, "b.json")
            with open(path, "w") as f:
                f.write("{not json")
            _, failures = check_bench.check_file(path, {"x": {}})
        self.assertEqual(len(failures), 1)
        self.assertIn("unparsable", failures[0])


class ScenarioMatrixTest(unittest.TestCase):
    BOUNDS = {
        "scenarios": {"min": 5},
        "swap_stalls_total": {"max": 0},
        "per_scenario": {
            "flash_crowd": {
                "recovered_hit_ratio": {"min": 0.9},
                "swap_stalls": {"max": 0},
            },
            "diurnal": {"recovered_hit_ratio": {"min": 0.9}},
            "scan_storm": {"swap_stalls": {"max": 0}},
        },
    }

    def check(self, doc, bounds=None):
        with tempfile.TemporaryDirectory() as d:
            path = write_json(d, "BENCH_scenarios.json", doc)
            return check_bench.check_file(path, bounds or self.BOUNDS)

    def test_matrix_expands_and_passes(self):
        cells, failures = self.check(SCENARIO_DOC)
        self.assertEqual(failures, [])
        # flat metrics plus one cell per (scenario, metric) pair
        self.assertIn("recovered_hit_ratio[flash_crowd]=0.97 [>=0.9 ok]", cells)
        self.assertIn("swap_stalls[scan_storm]=0 [<=0 ok]", cells)
        self.assertEqual(len(cells), 2 + 4)

    def test_scenario_regression_fails(self):
        doc = json.loads(json.dumps(SCENARIO_DOC))
        doc["rows"][1]["recovered_hit_ratio"] = 0.5  # diurnal regressed
        _, failures = self.check(doc)
        self.assertEqual(len(failures), 1)
        self.assertIn("recovered_hit_ratio[diurnal]=0.5", failures[0])

    def test_dropped_scenario_row_fails(self):
        doc = json.loads(json.dumps(SCENARIO_DOC))
        doc["rows"] = [r for r in doc["rows"] if r["scenario"] != "diurnal"]
        cells, failures = self.check(doc)
        self.assertEqual(len(failures), 1)
        self.assertIn("no row for scenario 'diurnal'", failures[0])
        self.assertIn("[diurnal]=MISSING", cells)

    def test_per_scenario_key_is_not_a_flat_metric(self):
        # "per_scenario" must never be looked up as a metric name
        cells, failures = self.check(SCENARIO_DOC)
        self.assertEqual(failures, [])
        self.assertFalse(any("per_scenario=" in c for c in cells))

    def test_scenario_metric_missing_from_row_fails(self):
        doc = json.loads(json.dumps(SCENARIO_DOC))
        del doc["rows"][0]["recovered_hit_ratio"]
        _, failures = self.check(doc)
        self.assertEqual(len(failures), 1)
        self.assertIn("recovered_hit_ratio[flash_crowd]", failures[0])


class LiveGraphGateTest(unittest.TestCase):
    """The shipped thresholds must actually gate the live-graph bench:
    a clean run passes, and each mutation-specific regression (logits
    divergence, a swap stall, zero compactions) fails on its own."""

    GOOD = {
        "bench": "live_graph",
        "rows": [
            {"wave": 0, "logits_match": 1, "p99_ms": 0.4},
            {
                "epochs_checked": 8,
                "edges_inserted": 400,
                "compactions": 2,
                "logits_match": 1,
                "swap_stalls": 0,
                "graph_swap_stalls": 0,
                "compaction_p99_inflation": 1.2,
            },
        ],
    }

    def bounds(self):
        thresholds = os.path.join(
            os.path.dirname(os.path.abspath(__file__)), "bench_thresholds.json"
        )
        with open(thresholds) as f:
            return json.load(f)["BENCH_live_graph.json"]

    def check(self, doc):
        with tempfile.TemporaryDirectory() as d:
            path = write_json(d, "BENCH_live_graph.json", doc)
            return check_bench.check_file(path, self.bounds())

    def test_shipped_thresholds_gate_the_required_keys(self):
        for key in ("logits_match", "swap_stalls", "graph_swap_stalls",
                    "compactions", "compaction_p99_inflation"):
            self.assertIn(key, self.bounds())

    def test_clean_run_passes(self):
        _, failures = self.check(self.GOOD)
        self.assertEqual(failures, [])

    def test_logits_divergence_fails(self):
        doc = json.loads(json.dumps(self.GOOD))
        doc["rows"][1]["logits_match"] = 0
        _, failures = self.check(doc)
        self.assertTrue(any("logits_match" in x for x in failures))

    def test_graph_swap_stall_fails(self):
        doc = json.loads(json.dumps(self.GOOD))
        doc["rows"][1]["graph_swap_stalls"] = 1
        _, failures = self.check(doc)
        self.assertTrue(any("graph_swap_stalls" in x for x in failures))

    def test_unbounded_compaction_inflation_fails(self):
        doc = json.loads(json.dumps(self.GOOD))
        doc["rows"][1]["compaction_p99_inflation"] = 80.0
        _, failures = self.check(doc)
        self.assertTrue(any("compaction_p99_inflation" in x for x in failures))

    def test_missing_compaction_fails(self):
        doc = json.loads(json.dumps(self.GOOD))
        doc["rows"][1]["compactions"] = 0
        _, failures = self.check(doc)
        self.assertTrue(any("compactions" in x for x in failures))


class MainTest(unittest.TestCase):
    def run_main(self, argv):
        stdout, stderr = io.StringIO(), io.StringIO()
        old = sys.argv
        sys.argv = ["check_bench.py"] + argv
        try:
            with contextlib.redirect_stdout(stdout), \
                    contextlib.redirect_stderr(stderr):
                code = check_bench.main()
        finally:
            sys.argv = old
        return code, stdout.getvalue(), stderr.getvalue()

    def test_trend_table_renders_and_gate_passes(self):
        with tempfile.TemporaryDirectory() as d:
            thresholds = write_json(d, "thresholds.json", {
                "BENCH_a.json": {"speedup": {"min": 1.0}},
                "BENCH_scenarios.json": self.scenario_bounds(),
            })
            write_json(d, "BENCH_a.json", {"speedup": 2.5})
            write_json(d, "BENCH_scenarios.json", SCENARIO_DOC)
            cwd = os.getcwd()
            os.chdir(d)
            try:
                code, out, _ = self.run_main(["--thresholds", thresholds])
            finally:
                os.chdir(cwd)
        self.assertEqual(code, 0)
        self.assertIn("speedup=2.5 [>=1 ok]", out)
        self.assertIn("recovered_hit_ratio[flash_crowd]=0.97", out)
        self.assertIn("bench gate ok: 2 file(s)", out)

    def test_failing_bench_exits_nonzero(self):
        with tempfile.TemporaryDirectory() as d:
            thresholds = write_json(d, "thresholds.json", {
                "BENCH_a.json": {"speedup": {"min": 10.0}},
            })
            bench = write_json(d, "BENCH_a.json", {"speedup": 2.5})
            code, _, err = self.run_main(["--thresholds", thresholds, bench])
        self.assertEqual(code, 1)
        self.assertIn("out of bounds", err)

    def test_unregistered_file_fails(self):
        with tempfile.TemporaryDirectory() as d:
            thresholds = write_json(d, "thresholds.json", {})
            bench = write_json(d, "BENCH_rogue.json", {"x": 1})
            code, _, err = self.run_main(["--thresholds", thresholds, bench])
        self.assertEqual(code, 1)
        self.assertIn("no thresholds registered", err)

    def test_empty_thresholds_and_no_files_is_a_clean_pass(self):
        # regression: `max(len(p) for p in files)` raised ValueError on
        # an empty file list before the `default=0` fix
        with tempfile.TemporaryDirectory() as d:
            thresholds = write_json(d, "thresholds.json", {})
            code, out, _ = self.run_main(["--thresholds", thresholds])
        self.assertEqual(code, 0)
        self.assertIn("0 file(s)", out)

    def scenario_bounds(self):
        return {
            "scenarios": {"min": 5},
            "per_scenario": {
                "flash_crowd": {"recovered_hit_ratio": {"min": 0.9}},
            },
        }


if __name__ == "__main__":
    unittest.main()
