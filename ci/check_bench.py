#!/usr/bin/env python3
"""Value-checking gate over the BENCH_*.json artifacts.

Replaces the old grep-for-key-presence CI steps: every bench named in
the thresholds file must (a) exist, (b) carry every gated metric, and
(c) hold each metric inside its [min, max] bound. Prints a one-line
trend table per bench either way, so the CI log doubles as the
cross-PR perf trajectory.

Usage:
    python3 ci/check_bench.py [--thresholds ci/bench_thresholds.json]
                              [FILE ...]

With no FILE arguments, every bench listed in the thresholds file is
checked (paths resolved relative to the current directory — CI runs
from rust/, where the benches write). Stdlib only; exits non-zero on
any missing file, missing key, unparsable value, or out-of-bound
value.

Thresholds format (per file, per metric):
    { "BENCH_foo.json": { "metric": { "min": 0.95, "max": 1.0 } } }
Either bound may be omitted. Metrics are looked up across every row of
the bench's `rows` array (last occurrence wins), plus top-level keys.

Scenario matrix: the reserved key "per_scenario" maps a scenario id to
its own metric bounds, checked against the row(s) whose "scenario"
field carries that id (rows for the same scenario dict-merge, last
wins). A gated scenario with no row at all is a failure — a bench that
silently drops a scenario must not pass. Matrix cells render as
`metric[scenario]=value` in the trend line:
    { "BENCH_scenarios.json": {
        "scenarios": { "min": 5 },
        "per_scenario": {
          "flash_crowd": { "recovered_hit_ratio": { "min": 0.9 } } } } }
"""

import argparse
import json
import sys


def flatten(doc):
    """Metric name -> value over top-level keys and all rows (last wins)."""
    out = {}
    for key, value in doc.items():
        if key != "rows":
            out[key] = value
    for row in doc.get("rows", []):
        if isinstance(row, dict):
            out.update(row)
    return out


def scenario_rows(doc):
    """Scenario id -> merged row dict, from rows tagged with "scenario"."""
    out = {}
    for row in doc.get("rows", []):
        if isinstance(row, dict) and "scenario" in row:
            out.setdefault(str(row["scenario"]), {}).update(row)
    return out


def check_bounds(path, metrics, bounds, suffix=""):
    """Check one metric dict against its bounds; returns (cells, failures).

    `suffix` labels scenario-matrix cells (e.g. "[flash_crowd]") so the
    trend line distinguishes them from the flat metrics.
    """
    cells, failures = [], []
    for name in sorted(bounds):
        bound = bounds[name]
        label = f"{name}{suffix}"
        if name not in metrics:
            cells.append(f"{label}=MISSING")
            failures.append(f"{path}: missing key {label!r}")
            continue
        try:
            value = float(metrics[name])
        except (TypeError, ValueError):
            cells.append(f"{label}=NON-NUMERIC")
            failures.append(f"{path}: {label} is not numeric ({metrics[name]!r})")
            continue
        lo, hi = bound.get("min"), bound.get("max")
        ok = (lo is None or value >= lo) and (hi is None or value <= hi)
        want = " ".join(
            w for w in (
                f">={lo:g}" if lo is not None else "",
                f"<={hi:g}" if hi is not None else "",
            ) if w
        )
        cells.append(f"{label}={value:g} [{want} {'ok' if ok else 'FAIL'}]")
        if not ok:
            failures.append(f"{path}: {label}={value:g} out of bounds ({want})")
    return cells, failures


def check_file(path, bounds):
    """Returns (trend_cells, failures) for one bench JSON."""
    try:
        with open(path) as f:
            doc = json.load(f)
    except FileNotFoundError:
        return [], [f"{path}: missing (bench did not write its JSON)"]
    except json.JSONDecodeError as e:
        return [], [f"{path}: unparsable JSON ({e})"]
    flat_bounds = {k: v for k, v in bounds.items() if k != "per_scenario"}
    cells, failures = check_bounds(path, flatten(doc), flat_bounds)
    per_scenario = bounds.get("per_scenario") or {}
    by_scenario = scenario_rows(doc)
    for sid in sorted(per_scenario):
        row = by_scenario.get(sid)
        if row is None:
            cells.append(f"[{sid}]=MISSING")
            failures.append(f"{path}: no row for scenario {sid!r}")
            continue
        c, f = check_bounds(path, row, per_scenario[sid], suffix=f"[{sid}]")
        cells.extend(c)
        failures.extend(f)
    return cells, failures


def main():
    ap = argparse.ArgumentParser(description=__doc__.splitlines()[0])
    ap.add_argument("--thresholds", default="ci/bench_thresholds.json")
    ap.add_argument("files", nargs="*", help="bench JSONs (default: all gated)")
    args = ap.parse_args()
    with open(args.thresholds) as f:
        thresholds = json.load(f)

    files = args.files or sorted(thresholds)
    all_failures = []
    width = max((len(p) for p in files), default=0)
    for path in files:
        # threshold lookup by basename so CI can pass rust/BENCH_x.json
        base = path.rsplit("/", 1)[-1]
        bounds = thresholds.get(base)
        if bounds is None:
            print(f"{path:<{width}}  (no thresholds registered)")
            all_failures.append(f"{path}: no thresholds registered for {base!r}")
            continue
        cells, failures = check_file(path, bounds)
        line = "  ".join(cells) if cells else "UNREADABLE"
        print(f"{path:<{width}}  {line}")
        all_failures.extend(failures)

    if all_failures:
        print("\nbench gate FAILED:", file=sys.stderr)
        for failure in all_failures:
            print(f"  - {failure}", file=sys.stderr)
        return 1
    print(f"\nbench gate ok: {len(files)} file(s) within bounds")
    return 0


if __name__ == "__main__":
    sys.exit(main())
