#!/usr/bin/env python3
"""Independent (non-Rust) verifier for deterministic run bundles.

A run bundle is a directory sealed by `bench_support::bundle` with a
`manifest.json` listing every member file's size and sha256 plus a
`manifest_sha256` self-digest. This script re-derives everything from
the bytes on disk with Python's stdlib only, so CI proves the bundle
contract holds *after* the artifact upload round-trip, with none of the
producing code in the loop.

The cross-language digest works because manifests are float-free by
construction (strings, bools, integral numbers only — enforced by the
Rust side's `finalize`):

    json.dumps(obj, sort_keys=True, separators=(",", ":"),
               ensure_ascii=False)

then byte-for-byte matches Rust's canonical writer.

Usage:
    python3 ci/verify_bundle.py BUNDLE_DIR [BUNDLE_DIR ...]

Exits non-zero on any digest mismatch, size mismatch, listed-but-
missing file, or unlisted file in the bundle directory.
"""

import hashlib
import json
import os
import sys

BUNDLE_SCHEMA = 1


def canonical(obj):
    """Rust `util::json` canonical bytes for a float-free JSON value."""
    return json.dumps(
        obj, sort_keys=True, separators=(",", ":"), ensure_ascii=False
    ).encode("utf-8")


def verify(bundle_dir):
    """Returns a list of failure strings (empty = bundle verified)."""
    manifest_path = os.path.join(bundle_dir, "manifest.json")
    try:
        with open(manifest_path, "rb") as f:
            manifest = json.load(f)
    except FileNotFoundError:
        return [f"{bundle_dir}: no manifest.json"]
    except json.JSONDecodeError as e:
        return [f"{manifest_path}: unparsable ({e})"]

    failures = []
    if manifest.get("bundle_schema") != BUNDLE_SCHEMA:
        failures.append(
            f"{manifest_path}: bundle_schema "
            f"{manifest.get('bundle_schema')!r} != {BUNDLE_SCHEMA}"
        )

    claimed = manifest.get("manifest_sha256")
    body = {k: v for k, v in manifest.items() if k != "manifest_sha256"}
    derived = hashlib.sha256(canonical(body)).hexdigest()
    if claimed != derived:
        failures.append(
            f"{manifest_path}: manifest_sha256 mismatch "
            f"(claimed {claimed}, derived {derived})"
        )

    # a zero-member bundle verifies nothing: the Rust sealer refuses to
    # finalize one, so an empty (or absent) files list here means the
    # manifest was tampered with or the seal path was bypassed — hard
    # failure, never a vacuous pass
    members = manifest.get("files")
    if not members:
        failures.append(
            f"{manifest_path}: manifest lists no member files "
            "(empty bundles must not verify)"
        )
        members = []

    listed = set()
    for entry in members:
        name = entry.get("path", "?")
        listed.add(name)
        path = os.path.join(bundle_dir, name)
        try:
            with open(path, "rb") as f:
                data = f.read()
        except FileNotFoundError:
            failures.append(f"{bundle_dir}: listed file missing: {name}")
            continue
        if len(data) != entry.get("bytes"):
            failures.append(
                f"{path}: size {len(data)} != manifest {entry.get('bytes')}"
            )
        digest = hashlib.sha256(data).hexdigest()
        if digest != entry.get("sha256"):
            failures.append(
                f"{path}: sha256 mismatch "
                f"(manifest {entry.get('sha256')}, file {digest})"
            )

    for name in sorted(os.listdir(bundle_dir)):
        if name != "manifest.json" and name not in listed:
            failures.append(f"{bundle_dir}: unlisted file in bundle: {name}")

    return failures


def main():
    if len(sys.argv) < 2:
        print(__doc__.strip(), file=sys.stderr)
        return 2
    all_failures = []
    for bundle_dir in sys.argv[1:]:
        failures = verify(bundle_dir)
        if failures:
            all_failures.extend(failures)
        else:
            with open(os.path.join(bundle_dir, "manifest.json")) as f:
                digest = json.load(f)["manifest_sha256"]
            n = len(os.listdir(bundle_dir)) - 1
            print(f"{bundle_dir}: verified, {n} file(s), "
                  f"manifest_sha256={digest}")
    if all_failures:
        print("\nbundle verification FAILED:", file=sys.stderr)
        for failure in all_failures:
            print(f"  - {failure}", file=sys.stderr)
        return 1
    return 0


if __name__ == "__main__":
    sys.exit(main())
