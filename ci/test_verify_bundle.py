#!/usr/bin/env python3
"""Unit tests for the bundle verifier (ci/verify_bundle.py) and the
cross-PR bundle differ (ci/diff_bundle.py).

The verifier is the independent half of the bundle contract — a bug
here lets a tampered or vacuous artifact pass as "verified". Stdlib
unittest only — run as a gating CI step:

    python3 ci/test_verify_bundle.py
"""

import hashlib
import io
import contextlib
import json
import os
import sys
import tempfile
import unittest

sys.path.insert(0, os.path.dirname(os.path.abspath(__file__)))
import diff_bundle  # noqa: E402
import verify_bundle  # noqa: E402


def seal(dirname, members, files_key="files"):
    """Write member files + a self-consistent manifest, like the Rust
    sealer would — except no refusal on zero members, so the tests can
    build exactly the degenerate manifests the verifier must reject."""
    entries = []
    for name in sorted(members):
        data = members[name].encode("utf-8")
        with open(os.path.join(dirname, name), "wb") as f:
            f.write(data)
        entries.append({
            "path": name,
            "bytes": len(data),
            "sha256": hashlib.sha256(data).hexdigest(),
        })
    manifest = {"bundle_schema": verify_bundle.BUNDLE_SCHEMA}
    if files_key is not None:
        manifest[files_key] = entries
    manifest["manifest_sha256"] = hashlib.sha256(
        verify_bundle.canonical(manifest)
    ).hexdigest()
    with open(os.path.join(dirname, "manifest.json"), "w") as f:
        json.dump(manifest, f)
    return manifest


class VerifyTest(unittest.TestCase):
    def test_well_formed_bundle_verifies(self):
        with tempfile.TemporaryDirectory() as d:
            seal(d, {"a.json": '{"x":1}', "b.txt": "hello"})
            self.assertEqual(verify_bundle.verify(d), [])

    def test_tampered_member_fails(self):
        with tempfile.TemporaryDirectory() as d:
            seal(d, {"a.json": '{"x":1}'})
            with open(os.path.join(d, "a.json"), "w") as f:
                f.write('{"x":2}')
            failures = verify_bundle.verify(d)
            self.assertTrue(any("sha256 mismatch" in x for x in failures))

    def test_unlisted_file_fails(self):
        with tempfile.TemporaryDirectory() as d:
            seal(d, {"a.json": '{"x":1}'})
            with open(os.path.join(d, "rogue.txt"), "w") as f:
                f.write("stowaway")
            failures = verify_bundle.verify(d)
            self.assertTrue(any("unlisted file" in x for x in failures))

    def test_empty_files_list_is_a_hard_failure(self):
        # regression: a manifest whose `files` array is empty used to
        # verify vacuously — zero checked members, exit 0. An empty
        # bundle proves nothing and must never pass.
        with tempfile.TemporaryDirectory() as d:
            seal(d, {})
            failures = verify_bundle.verify(d)
            self.assertTrue(
                any("no member files" in x for x in failures),
                f"empty bundle verified: {failures}",
            )

    def test_missing_files_key_is_a_hard_failure(self):
        with tempfile.TemporaryDirectory() as d:
            seal(d, {}, files_key=None)
            failures = verify_bundle.verify(d)
            self.assertTrue(any("no member files" in x for x in failures))

    def test_empty_bundle_fails_through_main_too(self):
        with tempfile.TemporaryDirectory() as d:
            seal(d, {})
            old = sys.argv
            sys.argv = ["verify_bundle.py", d]
            try:
                with contextlib.redirect_stdout(io.StringIO()), \
                        contextlib.redirect_stderr(io.StringIO()):
                    code = verify_bundle.main()
            finally:
                sys.argv = old
            self.assertEqual(code, 1)


class DiffTest(unittest.TestCase):
    def run_diff(self, argv):
        stdout, stderr = io.StringIO(), io.StringIO()
        old = sys.argv
        sys.argv = ["diff_bundle.py"] + argv
        try:
            with contextlib.redirect_stdout(stdout), \
                    contextlib.redirect_stderr(stderr):
                code = diff_bundle.main()
        finally:
            sys.argv = old
        return code, stdout.getvalue(), stderr.getvalue()

    def test_flatten_numeric_skips_bools_and_walks_rows(self):
        doc = {"a": 1, "ok": True, "rows": [{"p99": 2.5}, {"p99": 3.5}]}
        flat = diff_bundle.flatten_numeric(doc)
        self.assertEqual(
            flat, {"a": 1.0, "rows[0].p99": 2.5, "rows[1].p99": 3.5}
        )

    def test_missing_previous_is_tolerated(self):
        with tempfile.TemporaryDirectory() as d:
            curr = os.path.join(d, "curr")
            os.makedirs(curr)
            seal(curr, {"m.json": '{"v": 1}'})
            summary = os.path.join(d, "summary.md")
            code, out, _ = self.run_diff([
                "--current", curr,
                "--previous", os.path.join(d, "never_downloaded"),
                "--summary", summary,
            ])
            self.assertEqual(code, 0)
            self.assertIn("diff skipped", out)
            with open(summary) as f:
                self.assertIn("No prior bundle", f.read())

    def test_broken_current_bundle_fails(self):
        with tempfile.TemporaryDirectory() as d:
            curr = os.path.join(d, "curr")
            os.makedirs(curr)
            seal(curr, {})  # empty = broken by the verifier's rules
            code, _, err = self.run_diff(["--current", curr, "--summary",
                                          os.path.join(d, "s.md")])
            self.assertEqual(code, 1)
            self.assertIn("FAILED verification", err)

    def test_delta_table_reports_changed_metrics(self):
        with tempfile.TemporaryDirectory() as d:
            prev = os.path.join(d, "prev")
            curr = os.path.join(d, "curr")
            os.makedirs(prev)
            os.makedirs(curr)
            seal(prev, {"m.json": '{"p99": 2.0, "stalls": 0}'})
            seal(curr, {"m.json": '{"p99": 3.0, "stalls": 0}'})
            summary = os.path.join(d, "summary.md")
            code, _, _ = self.run_diff([
                "--current", curr, "--previous", prev, "--summary", summary,
            ])
            self.assertEqual(code, 0)
            with open(summary) as f:
                text = f.read()
            self.assertIn("`p99`", text)
            self.assertIn("+50.0%", text)
            # unchanged metrics stay out of the table
            self.assertNotIn("`stalls`", text)

    def test_identical_bundles_short_circuit(self):
        with tempfile.TemporaryDirectory() as d:
            prev = os.path.join(d, "prev")
            curr = os.path.join(d, "curr")
            os.makedirs(prev)
            os.makedirs(curr)
            seal(prev, {"m.json": '{"v": 1}'})
            seal(curr, {"m.json": '{"v": 1}'})
            summary = os.path.join(d, "summary.md")
            code, _, _ = self.run_diff([
                "--current", curr, "--previous", prev, "--summary", summary,
            ])
            self.assertEqual(code, 0)
            with open(summary) as f:
                self.assertIn("byte-identical", f.read())


if __name__ == "__main__":
    unittest.main()
