//! RAIN baseline (Liu et al., IEEE TSC 2024), per the paper's §II.D/§V:
//! an inference system that (a) orders target nodes by degree, (b)
//! clusters similar mini-batches with MinHash LSH over their sampled
//! neighborhoods, and (c) runs similar batches consecutively so node
//! features can be reused between neighboring batches.
//!
//! The preprocessing here does the real work — degree sort, per-batch
//! neighborhood signatures (UVA reads of the adjacency), LSH banding —
//! so the Table IV comparison measures an honest O(n) pipeline, and the
//! cluster-resident reuse sets reproduce RAIN's memory blow-up
//! (Table V's OOM row) through the simulated device arena.

use std::collections::HashMap;
use std::time::Instant;

use anyhow::Result;

use crate::config::{RunConfig, SystemKind};
use crate::graph::{Dataset, NodeId};
use crate::mem::{CostModel, TransferLedger};
use crate::util::Rng;

use super::PreparedSystem;

/// MinHash signature width.
const N_HASHES: usize = 8;
/// LSH banding: rows per band (N_HASHES / N_BANDS).
const N_BANDS: usize = 4;

fn hash64(x: u64, salt: u64) -> u64 {
    let mut z = x.wrapping_add(salt).wrapping_mul(0x9e37_79b9_7f4a_7c15);
    z = (z ^ (z >> 30)).wrapping_mul(0xbf58_476d_1ce4_e5b9);
    z = (z ^ (z >> 27)).wrapping_mul(0x94d0_49bb_1331_11eb);
    z ^ (z >> 31)
}

pub fn prepare(
    ds: &Dataset,
    cfg: &RunConfig,
    cost: &CostModel,
    _rng: &mut Rng,
) -> Result<PreparedSystem> {
    let wall0 = Instant::now();
    let mut ledger = TransferLedger::new();

    // (a) degree-ordered targets (RAIN's adaptive target sampling)
    let mut seeds: Vec<NodeId> = ds.test_nodes.clone();
    seeds.sort_unstable_by(|&a, &b| {
        ds.csc.degree(b).cmp(&ds.csc.degree(a)).then(a.cmp(&b))
    });

    // (b) partition + MinHash signatures over the **full** 1-hop
    // neighborhoods (RAIN clusters by the actual sampled-subgraph
    // content, so preprocessing walks every batch's neighborhood — this
    // is why its cost scales with the whole inference sweep while DCI's
    // 8-batch profile does not). It also materializes each batch's
    // 1-hop feature set on the device to seed the reuse plan, which is
    // where its preprocessing transfer volume comes from.
    let batches: Vec<Vec<NodeId>> =
        seeds.chunks(cfg.batch_size).map(|c| c.to_vec()).collect();
    let row_bytes = ds.features.row_bytes();
    let row_txns = row_bytes.div_ceil(cost.uva_line_bytes).max(1);
    let mut signatures: Vec<[u64; N_HASHES]> = Vec::with_capacity(batches.len());
    let mut hop_scratch: Vec<NodeId> = Vec::new();
    for batch in &batches {
        ledger.launch(); // per-batch sampling/signature kernel
        let mut sig = [u64::MAX; N_HASHES];
        hop_scratch.clear();
        for &v in batch {
            for &u in ds.csc.neighbors(v) {
                // UVA read of the adjacency element (preprocessing cost)
                ledger.miss(4, 1);
                hop_scratch.push(u);
                for (h, slot) in sig.iter_mut().enumerate() {
                    let hv = hash64(u as u64, h as u64 * 0x5bd1_e995);
                    if hv < *slot {
                        *slot = hv;
                    }
                }
            }
        }
        // stage the (deduplicated) 1-hop feature set for reuse planning
        hop_scratch.sort_unstable();
        hop_scratch.dedup();
        for _ in &hop_scratch {
            ledger.miss(row_bytes, row_txns);
        }
        signatures.push(sig);
    }

    // (c) LSH banding: batches sharing any band bucket form a cluster.
    let rows = N_HASHES / N_BANDS;
    let mut bucket_of: HashMap<(usize, u64), usize> = HashMap::new();
    let mut cluster_of: Vec<usize> = (0..batches.len()).collect();
    // union-find (path halving)
    let mut parent: Vec<usize> = (0..batches.len()).collect();
    fn find(parent: &mut Vec<usize>, mut x: usize) -> usize {
        while parent[x] != x {
            parent[x] = parent[parent[x]];
            x = parent[x];
        }
        x
    }
    for (bi, sig) in signatures.iter().enumerate() {
        for band in 0..N_BANDS {
            let mut key = 0u64;
            for r in 0..rows {
                key = key
                    .wrapping_mul(0x100_0000_01b3)
                    .wrapping_add(sig[band * rows + r]);
            }
            match bucket_of.entry((band, key)) {
                std::collections::hash_map::Entry::Occupied(e) => {
                    let root_a = find(&mut parent, *e.get());
                    let root_b = find(&mut parent, bi);
                    parent[root_b] = root_a;
                }
                std::collections::hash_map::Entry::Vacant(e) => {
                    e.insert(bi);
                }
            }
        }
    }
    for bi in 0..batches.len() {
        cluster_of[bi] = find(&mut parent, bi);
    }

    // candidate verification: same-cluster batch pairs get an exact
    // seed-set similarity check (RAIN verifies LSH candidates before
    // committing to a reuse order)
    {
        use std::collections::HashSet;
        let mut by_cluster: HashMap<usize, Vec<usize>> = HashMap::new();
        for bi in 0..batches.len() {
            by_cluster.entry(cluster_of[bi]).or_default().push(bi);
        }
        let mut verified = 0u64;
        for members in by_cluster.values() {
            for w in members.windows(2) {
                let a: HashSet<NodeId> = batches[w[0]].iter().copied().collect();
                let inter = batches[w[1]].iter().filter(|v| a.contains(v)).count();
                verified += inter as u64;
            }
        }
        std::hint::black_box(verified);
    }

    // order batches so same-cluster batches are consecutive (stable by
    // cluster root, then original order)
    let mut order: Vec<usize> = (0..batches.len()).collect();
    order.sort_by_key(|&bi| (cluster_of[bi], bi));
    let ordered_batches: Vec<Vec<NodeId>> =
        order.iter().map(|&bi| batches[bi].clone()).collect();
    // re-number clusters densely in visit order
    let mut dense: HashMap<usize, usize> = HashMap::new();
    let ordered_clusters: Vec<usize> = order
        .iter()
        .map(|&bi| {
            let next = dense.len();
            *dense.entry(cluster_of[bi]).or_insert(next)
        })
        .collect();

    let wall_ns = wall0.elapsed().as_nanos() as f64;
    let modeled_ns = ledger.modeled_ns(cost);

    let mut p = PreparedSystem::bare(SystemKind::Rain);
    p.batch_order = Some((ordered_batches, ordered_clusters));
    p.inter_batch_reuse = true;
    p.preprocess_ns = wall_ns + modeled_ns;
    p.preprocess_wall_ns = wall_ns;
    Ok(p)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::graph::datasets;
    use crate::sampler::Fanout;

    fn run_prepare() -> (crate::graph::Dataset, PreparedSystem) {
        let ds = datasets::spec("tiny").unwrap().build();
        let mut cfg = RunConfig::default();
        cfg.dataset = "tiny".into();
        cfg.batch_size = 64;
        cfg.fanout = Fanout::parse("3,2").unwrap();
        let p = prepare(&ds, &cfg, &CostModel::default(), &mut Rng::new(1)).unwrap();
        (ds, p)
    }

    #[test]
    fn reorders_all_seeds_without_loss() {
        let (ds, p) = run_prepare();
        let (batches, clusters) = p.batch_order.as_ref().unwrap();
        assert_eq!(batches.len(), clusters.len());
        let mut all: Vec<NodeId> = batches.iter().flatten().copied().collect();
        let mut want = ds.test_nodes.clone();
        all.sort_unstable();
        want.sort_unstable();
        assert_eq!(all, want, "every test node appears exactly once");
        assert!(p.inter_batch_reuse);
        assert!(p.preprocess_ns > 0.0);
    }

    #[test]
    fn first_batch_holds_high_degree_targets() {
        let (ds, p) = run_prepare();
        let (batches, _) = p.batch_order.as_ref().unwrap();
        // the degree-ordered partitioning puts hubs in early batches;
        // with cluster-grouped ordering the max-degree node stays in
        // whichever batch comes first for its cluster — check that the
        // global max degree appears in some batch whose mean degree is
        // far above the dataset mean.
        let max_deg_node = (0..ds.csc.n_nodes() as NodeId)
            .max_by_key(|&v| ds.csc.degree(v))
            .unwrap();
        let holder = batches
            .iter()
            .find(|b| b.contains(&max_deg_node));
        // the hub may not be a test node; only assert when it is
        if let Some(b) = holder {
            let mean: f64 =
                b.iter().map(|&v| ds.csc.degree(v) as f64).sum::<f64>() / b.len() as f64;
            assert!(mean > ds.csc.avg_degree());
        }
    }

    #[test]
    fn clusters_are_consecutive() {
        let (_, p) = run_prepare();
        let (_, clusters) = p.batch_order.as_ref().unwrap();
        // dense renumbering in visit order must be non-decreasing in
        // first occurrence: cluster ids form contiguous runs
        let mut seen_max = 0usize;
        let mut last = usize::MAX;
        for &c in clusters {
            if c != last {
                assert!(c <= seen_max, "cluster {c} reopened");
                if c == seen_max {
                    seen_max += 1;
                }
                last = c;
            }
        }
    }

    #[test]
    fn deterministic() {
        let (_, a) = run_prepare();
        let (_, b) = run_prepare();
        assert_eq!(
            a.batch_order.as_ref().unwrap().0,
            b.batch_order.as_ref().unwrap().0
        );
    }
}
