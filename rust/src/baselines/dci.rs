//! DCI preparation: the paper's §IV pipeline.
//!
//! 1. Pre-sample `cfg.n_presample` batches of the real workload
//!    ([`crate::sampler::presample`]), collecting stage times, node
//!    visit counts, and the CSC element `Counts` array.
//! 2. Determine the total cache budget `C` (workload-aware: device
//!    memory minus reserve minus the workload's own peak, §IV.A) and
//!    split it per Eq. (1).
//! 3. Fill the feature cache (average-visit threshold, §IV.B) and the
//!    adjacency cache (Algorithm 1).
//!
//! The returned `preprocess_ns` covers all three steps — this is the
//! number Tables IV / Fig. 10 compare.

use std::time::Instant;

use anyhow::Result;

use crate::cache::{adj_cache::AdjCache, alloc, feat_cache::FeatCache};
use crate::config::{RunConfig, SystemKind};
use crate::graph::Dataset;
use crate::mem::{CostModel, DeviceMemory};
use crate::sampler::presample_threads;
use crate::util::Rng;

use super::{auto_budget, PreparedSystem};

pub fn prepare(
    ds: &Dataset,
    cfg: &RunConfig,
    device: &DeviceMemory,
    cost: &CostModel,
    rng: &mut Rng,
) -> Result<PreparedSystem> {
    // 1. pre-sampling. Its *simulated* cost is the modeled t_sample +
    // t_feature (on the paper's testbed this phase runs on the GPU);
    // the CPU wall of simulating it is simulator overhead and excluded
    // (same discipline as the serving stages — DESIGN.md).
    let stats = presample_threads(
        &ds.csc,
        &ds.features,
        &ds.test_nodes,
        cfg.batch_size.min(super::PRESAMPLE_BS_CAP),
        &cfg.fanout,
        cfg.n_presample,
        cost,
        rng,
        cfg.sample_threads,
    );

    // 2. budget + Eq. (1) split
    // explicit budgets are clamped to what the device can actually hold
    let total = cfg
        .budget
        .unwrap_or_else(|| auto_budget(device, &stats, ds.features.row_bytes(), cfg.hidden, ds.spec.scale))
        .min(device.available_for_cache());
    let split = alloc::allocate(total, &stats);

    // 3. lightweight fills — genuine host-side coordinator work, so
    // their wall time counts toward preprocessing
    let wall0 = Instant::now();
    let (adj, adj_ledger) = AdjCache::fill(&ds.csc, &stats.elem_counts, split.c_adj);
    let (feat, feat_ledger) =
        FeatCache::fill(&ds.features, &stats.node_visits, split.c_feat);
    let wall_ns = wall0.elapsed().as_nanos() as f64;
    let modeled_ns = stats.t_sample_ns + stats.t_feature_ns
        + adj_ledger.modeled_ns(cost)
        + feat_ledger.modeled_ns(cost);

    Ok(PreparedSystem {
        kind: SystemKind::Dci,
        adj_cache: Some(adj),
        feat_cache: Some(feat),
        alloc: Some(split),
        presample: Some(stats),
        batch_order: None,
        inter_batch_reuse: false,
        preprocess_ns: wall_ns + modeled_ns,
        preprocess_wall_ns: wall_ns,
    })
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::graph::datasets;
    use crate::sampler::Fanout;

    fn cfg(budget: u64) -> RunConfig {
        let mut c = RunConfig::default();
        c.dataset = "tiny".into();
        c.batch_size = 64;
        c.fanout = Fanout::parse("3,2").unwrap();
        c.budget = Some(budget);
        c
    }

    #[test]
    fn prepares_both_caches_within_budget() {
        let ds = datasets::spec("tiny").unwrap().build();
        let device = DeviceMemory::new(1 << 30, 1 << 20);
        let p = prepare(&ds, &cfg(300_000), &device, &CostModel::default(),
                        &mut Rng::new(1))
            .unwrap();
        let split = p.alloc.unwrap();
        assert_eq!(split.total(), 300_000);
        assert!(split.c_adj > 0 && split.c_feat > 0,
                "both stages take time, so both caches get capacity: {split:?}");
        assert!(p.cache_bytes() <= 300_000 + ds.csc.bytes_total());
        assert!(p.preprocess_ns >= p.preprocess_wall_ns);
        assert!(p.feat_cache.as_ref().unwrap().n_cached() > 0);
    }

    #[test]
    fn zero_budget_still_prepares() {
        let ds = datasets::spec("tiny").unwrap().build();
        let device = DeviceMemory::new(1 << 30, 1 << 20);
        let p = prepare(&ds, &cfg(0), &device, &CostModel::default(),
                        &mut Rng::new(2))
            .unwrap();
        assert_eq!(p.cache_bytes(), 0);
    }

    #[test]
    fn auto_budget_path() {
        let ds = datasets::spec("tiny").unwrap().build();
        let device = DeviceMemory::new(1 << 30, 1 << 20);
        let mut c = cfg(0);
        c.budget = None;
        let p = prepare(&ds, &c, &device, &CostModel::default(), &mut Rng::new(3))
            .unwrap();
        // tiny dataset on a 1 GiB device: everything fits, adj cache
        // takes the full-CSC fast path
        assert!(p.adj_cache.as_ref().unwrap().is_full_csc());
    }
}
