//! DCI preparation: the paper's §IV pipeline.
//!
//! 1. Pre-sample `cfg.n_presample` batches of the real workload
//!    ([`crate::sampler::presample`]), collecting stage times, node
//!    visit counts, and the CSC element `Counts` array.
//! 2. Determine the total cache budget `C` (workload-aware: device
//!    memory minus reserve minus the workload's own peak, §IV.A).
//! 3. Run [`DciPlanner`] — Eq. (1) split, then the lightweight fills
//!    (average-visit threshold §IV.B, Algorithm 1).
//!
//! The returned `preprocess_ns` covers all three steps — this is the
//! number Tables IV / Fig. 10 compare. The same planner re-runs online
//! when the refresh loop detects workload drift.

use anyhow::Result;

use crate::cache::planner::{DciPlanner, WorkloadProfile};
use crate::cache::shard::{plan_sharded_with_budgets, ShardRouter};
use crate::config::{RunConfig, SystemKind};
use crate::graph::Dataset;
use crate::mem::{CostModel, DeviceMemory};
use crate::sampler::presample_threads;
use crate::util::Rng;

use super::{resolve_budget, PreparedSystem};

pub fn prepare(
    ds: &Dataset,
    cfg: &RunConfig,
    device: &DeviceMemory,
    cost: &CostModel,
    rng: &mut Rng,
) -> Result<PreparedSystem> {
    // 1. pre-sampling. Its *simulated* cost is the modeled t_sample +
    // t_feature (on the paper's testbed this phase runs on the GPU);
    // the CPU wall of simulating it is simulator overhead and excluded
    // (same discipline as the serving stages — DESIGN.md).
    let stats = presample_threads(
        &ds.csc,
        &ds.features,
        &ds.test_nodes,
        cfg.batch_size.min(super::PRESAMPLE_BS_CAP),
        &cfg.fanout,
        cfg.n_presample,
        cost,
        rng,
        cfg.sample_threads,
    );

    // 2. budget — node-global, clamped so every shard's share fits its
    // own device (`device` is the per-shard prototype)
    let total = resolve_budget(cfg, device, &stats, ds.features.row_bytes(), ds.spec.scale);

    // 3. per-shard Eq. (1) split + lightweight fills, behind the
    // planner trait (fill wall is genuine host-side coordinator work
    // and counts toward preprocessing; one shard = the paper's
    // single-device pipeline exactly). Heterogeneous nodes split the
    // budget by tier weight instead of evenly.
    let router = ShardRouter::new(cfg.shards.max(1));
    let plans = plan_sharded_with_budgets(
        &DciPlanner,
        ds,
        &WorkloadProfile::from_presample(&stats),
        super::shard_budget_split(cfg, total, router.n_shards()),
        &router,
    );
    let profiling_ns = stats.t_sample_ns + stats.t_feature_ns;
    Ok(PreparedSystem::from_plans(
        SystemKind::Dci,
        plans,
        router,
        Some(stats),
        total,
        profiling_ns,
        cost,
    ))
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::graph::datasets;
    use crate::sampler::Fanout;

    fn cfg(budget: u64) -> RunConfig {
        let mut c = RunConfig::default();
        c.dataset = "tiny".into();
        c.batch_size = 64;
        c.fanout = Fanout::parse("3,2").unwrap();
        c.budget = Some(budget);
        c
    }

    #[test]
    fn prepares_both_caches_within_budget() {
        let ds = datasets::spec("tiny").unwrap().build();
        let device = DeviceMemory::new(1 << 30, 1 << 20);
        let p = prepare(&ds, &cfg(300_000), &device, &CostModel::default(), &mut Rng::new(1))
            .unwrap();
        let split = p.alloc().unwrap();
        assert_eq!(split.total(), 300_000);
        assert!(
            split.c_adj > 0 && split.c_feat > 0,
            "both stages take time, so both caches get capacity: {split:?}"
        );
        assert!(p.cache_bytes() <= 300_000 + ds.csc.bytes_total());
        assert!(p.preprocess_ns >= p.preprocess_wall_ns);
        assert!(p.runtime.load().feat.as_ref().unwrap().n_cached() > 0);
        assert_eq!(p.cache_budget, 300_000);
    }

    #[test]
    fn zero_budget_still_prepares() {
        let ds = datasets::spec("tiny").unwrap().build();
        let device = DeviceMemory::new(1 << 30, 1 << 20);
        let p =
            prepare(&ds, &cfg(0), &device, &CostModel::default(), &mut Rng::new(2)).unwrap();
        assert_eq!(p.cache_bytes(), 0);
    }

    #[test]
    fn sharded_prepare_splits_budget_across_devices() {
        let ds = datasets::spec("tiny").unwrap().build();
        let device = DeviceMemory::new(1 << 30, 1 << 20);
        let mut c = cfg(400_000);
        c.shards = 4;
        let p = prepare(&ds, &c, &device, &CostModel::default(), &mut Rng::new(5)).unwrap();
        assert_eq!(p.runtime.n_shards(), 4);
        assert_eq!(p.shard_budgets.len(), 4);
        assert_eq!(p.shard_budgets.iter().sum::<u64>(), 400_000);
        assert_eq!(p.cache_budget, 400_000);
        // each shard planned its own Eq. (1) split within its share
        let mut seen_feat = 0;
        for (s, snap) in p.runtime.snapshots().iter().enumerate() {
            let split = snap.alloc.unwrap();
            assert_eq!(split.total(), p.shard_budgets[s]);
            if snap.feat.as_ref().unwrap().n_cached() > 0 {
                seen_feat += 1;
            }
        }
        assert!(seen_feat >= 2, "multiple shards should hold features");
        assert_eq!(p.alloc().unwrap().total(), 400_000);
    }

    #[test]
    fn auto_budget_path() {
        let ds = datasets::spec("tiny").unwrap().build();
        let device = DeviceMemory::new(1 << 30, 1 << 20);
        let mut c = cfg(0);
        c.budget = None;
        let p = prepare(&ds, &c, &device, &CostModel::default(), &mut Rng::new(3)).unwrap();
        // tiny dataset on a 1 GiB device: everything fits, adj cache
        // takes the full-CSC fast path
        assert!(p.runtime.load().adj.as_ref().unwrap().is_full_csc());
    }
}
