//! DUCATI's dual-cache population strategy (Zhang et al., SIGMOD 2023),
//! adapted to inference exactly as the paper's §V.C does: "isolating
//! and incorporating its cache allocation and filling algorithms,
//! replacing DCI's algorithms".
//!
//! DUCATI was built for *training*, where preprocessing amortizes over
//! epochs, so its pipeline is deliberately heavier than DCI's:
//!
//! 1. Epoch-grade profiling — `DUCATI_PROFILE_FACTOR ×` more profiled
//!    batches than DCI's 8 (DUCATI derives per-entry value estimates
//!    from full traversals).
//! 2.–4. Value curves, slope fitting, and the greedy knapsack fill —
//!    [`crate::cache::planner::DucatiPlanner`], behind the same
//!    `CachePlanner` trait as DCI's lightweight fills.
//!
//! Steady-state behaviour ends up close to DCI (Fig. 9: <4% runtime
//! difference); the preprocessing cost gap (Fig. 10) is the point.

use anyhow::Result;

use crate::cache::planner::{DucatiPlanner, WorkloadProfile};
use crate::cache::shard::{plan_sharded_with_budgets, ShardRouter};
use crate::config::{RunConfig, SystemKind};
use crate::graph::Dataset;
use crate::mem::{CostModel, DeviceMemory};
use crate::sampler::presample_threads;
use crate::util::Rng;

use super::{resolve_budget, PreparedSystem};

/// How many times more profiling batches DUCATI consumes vs. DCI.
pub const DUCATI_PROFILE_FACTOR: usize = 8;

pub fn prepare(
    ds: &Dataset,
    cfg: &RunConfig,
    device: &DeviceMemory,
    cost: &CostModel,
    rng: &mut Rng,
) -> Result<PreparedSystem> {
    // 1. epoch-grade profiling (simulated cost = modeled stage times,
    // as for DCI — but 8x more of them)
    let stats = presample_threads(
        &ds.csc,
        &ds.features,
        &ds.test_nodes,
        cfg.batch_size.min(super::PRESAMPLE_BS_CAP),
        &cfg.fanout,
        cfg.n_presample * DUCATI_PROFILE_FACTOR,
        cost,
        rng,
        cfg.sample_threads,
    );

    // node-global budget, clamped per shard (see `resolve_budget`)
    let total = resolve_budget(cfg, device, &stats, ds.features.row_bytes(), ds.spec.scale);

    // 2.-4. sorts, curve fits, knapsack, fills — all host-side
    // preprocessing work whose wall time counts (the planner measures
    // it as plan_wall_ns); under sharding the knapsack runs once per
    // shard over the shard-masked profile
    let router = ShardRouter::new(cfg.shards.max(1));
    let plans = plan_sharded_with_budgets(
        &DucatiPlanner,
        ds,
        &WorkloadProfile::from_presample(&stats),
        super::shard_budget_split(cfg, total, router.n_shards()),
        &router,
    );
    let profiling_ns = stats.t_sample_ns + stats.t_feature_ns;
    Ok(PreparedSystem::from_plans(
        SystemKind::Ducati,
        plans,
        router,
        Some(stats),
        total,
        profiling_ns,
        cost,
    ))
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::graph::datasets;
    use crate::sampler::Fanout;

    fn cfg(budget: u64) -> RunConfig {
        let mut c = RunConfig::default();
        c.dataset = "tiny".into();
        c.batch_size = 64;
        c.fanout = Fanout::parse("3,2").unwrap();
        c.budget = Some(budget);
        c
    }

    #[test]
    fn prepares_dual_caches_within_budget() {
        let ds = datasets::spec("tiny").unwrap().build();
        let device = DeviceMemory::new(1 << 30, 1 << 20);
        let p = prepare(&ds, &cfg(400_000), &device, &CostModel::default(), &mut Rng::new(1))
            .unwrap();
        let split = p.alloc().unwrap();
        assert!(split.total() <= 400_000 + ds.csc.n_nodes() as u64 * 12);
        assert!(p.runtime.load().feat.as_ref().unwrap().n_cached() > 0);
        assert!(p.preprocess_ns > 0.0);
    }

    #[test]
    fn heavier_preprocessing_than_dci() {
        let ds = datasets::spec("tiny").unwrap().build();
        let device = DeviceMemory::new(1 << 30, 1 << 20);
        let cost = CostModel::default();
        let d = super::super::dci::prepare(&ds, &cfg(200_000), &device, &cost, &mut Rng::new(2))
            .unwrap();
        let u = prepare(&ds, &cfg(200_000), &device, &cost, &mut Rng::new(2)).unwrap();
        // on `tiny` the 8x profiling request is capped by available
        // batches (15 vs DCI's 8) — full-size benches show the real gap
        assert!(
            u.preprocess_ns > 1.4 * d.preprocess_ns,
            "DUCATI {:.0} should exceed DCI {:.0}",
            u.preprocess_ns,
            d.preprocess_ns
        );
    }
}
