//! DUCATI's dual-cache population strategy (Zhang et al., SIGMOD 2023),
//! adapted to inference exactly as the paper's §V.C does: "isolating
//! and incorporating its cache allocation and filling algorithms,
//! replacing DCI's algorithms".
//!
//! DUCATI was built for *training*, where preprocessing amortizes over
//! epochs, so its pipeline is deliberately heavier than DCI's:
//!
//! 1. Epoch-grade profiling — `DUCATI_PROFILE_FACTOR ×` more profiled
//!    batches than DCI's 8 (DUCATI derives per-entry value estimates
//!    from full traversals).
//! 2. Value curves for 'nfeat' and 'adj' entries: every entry gets a
//!    value/size density; both entry lists are fully sorted
//!    (O(n log n) — the knapsack) and cumulative value curves built.
//! 3. Slope fitting on the curves (least-squares per decile segment,
//!    the "determining slopes through curve fitting" step) to pick the
//!    split point.
//! 4. Greedy knapsack fill: walk the two sorted lists merging by
//!    density until the budget is spent.
//!
//! Steady-state behaviour ends up close to DCI (Fig. 9: <4% runtime
//! difference); the preprocessing cost gap (Fig. 10) is the point.

use std::time::Instant;

use anyhow::Result;

use crate::cache::{adj_cache::AdjCache, feat_cache::FeatCache, CacheAllocation};
use crate::config::{RunConfig, SystemKind};
use crate::graph::{Dataset, NodeId};
use crate::mem::{CostModel, DeviceMemory};
use crate::sampler::presample_threads;
use crate::util::Rng;

use super::{auto_budget, PreparedSystem};

/// How many times more profiling batches DUCATI consumes vs. DCI.
pub const DUCATI_PROFILE_FACTOR: usize = 8;

/// Least-squares slope of (0..n, ys) — the curve-fitting step.
fn fit_slope(ys: &[f64]) -> f64 {
    let n = ys.len() as f64;
    if ys.len() < 2 {
        return 0.0;
    }
    let mean_x = (n - 1.0) / 2.0;
    let mean_y = ys.iter().sum::<f64>() / n;
    let mut num = 0.0;
    let mut den = 0.0;
    for (i, &y) in ys.iter().enumerate() {
        let dx = i as f64 - mean_x;
        num += dx * (y - mean_y);
        den += dx * dx;
    }
    if den == 0.0 {
        0.0
    } else {
        num / den
    }
}

pub fn prepare(
    ds: &Dataset,
    cfg: &RunConfig,
    device: &DeviceMemory,
    cost: &CostModel,
    rng: &mut Rng,
) -> Result<PreparedSystem> {
    // 1. epoch-grade profiling (simulated cost = modeled stage times,
    // as for DCI — but 8x more of them)
    let stats = presample_threads(
        &ds.csc,
        &ds.features,
        &ds.test_nodes,
        cfg.batch_size.min(super::PRESAMPLE_BS_CAP),
        &cfg.fanout,
        cfg.n_presample * DUCATI_PROFILE_FACTOR,
        cost,
        rng,
        cfg.sample_threads,
    );

    // explicit budgets are clamped to what the device can actually hold
    let total = cfg
        .budget
        .unwrap_or_else(|| auto_budget(device, &stats, ds.features.row_bytes(), cfg.hidden, ds.spec.scale))
        .min(device.available_for_cache());

    // everything from here is host-side preprocessing work: sorts,
    // curve fits, knapsack, fills — wall time counts
    let wall0 = Instant::now();

    // 2. value curves
    let n = ds.csc.n_nodes();
    let row_cost = (ds.features.row_bytes() + 16) as f64;
    let mut nfeat: Vec<(f64, NodeId)> = (0..n)
        .map(|v| (stats.node_visits[v] as f64 / row_cost, v as NodeId))
        .collect();
    let mut adj: Vec<(f64, NodeId)> = (0..n)
        .map(|v| {
            let span = ds.csc.col_ptr[v] as usize..ds.csc.col_ptr[v + 1] as usize;
            let total: u64 = stats.elem_counts[span].iter().map(|&c| c as u64).sum();
            let size = (ds.csc.degree(v as NodeId) * 4 + 12) as f64;
            (total as f64 / size, v as NodeId)
        })
        .collect();
    // full sorts — the O(n log n) knapsack cost the paper cites
    nfeat.sort_unstable_by(|a, b| b.0.partial_cmp(&a.0).unwrap().then(a.1.cmp(&b.1)));
    adj.sort_unstable_by(|a, b| b.0.partial_cmp(&a.0).unwrap().then(a.1.cmp(&b.1)));

    // 3. cumulative curves + decile slope fits (the split heuristic)
    let cum = |xs: &[(f64, NodeId)]| -> Vec<f64> {
        let mut acc = 0.0;
        xs.iter().map(|&(d, _)| {
            acc += d;
            acc
        }).collect()
    };
    let nfeat_curve = cum(&nfeat);
    let adj_curve = cum(&adj);
    let decile_slopes = |curve: &[f64]| -> Vec<f64> {
        let step = (curve.len() / 10).max(1);
        curve.chunks(step).map(fit_slope).collect()
    };
    let _nf_slopes = decile_slopes(&nfeat_curve);
    let _adj_slopes = decile_slopes(&adj_curve);

    // 4. greedy merge by density until the budget is spent
    let mut budget = total;
    let (mut fi, mut ai) = (0usize, 0usize);
    let mut feat_order: Vec<NodeId> = Vec::new();
    let mut adj_order: Vec<u32> = Vec::new();
    let mut c_feat = 0u64;
    let mut c_adj = n as u64 * 12; // adj metadata charged up front
    let adj_meta_ok = budget > c_adj;
    if adj_meta_ok {
        budget -= c_adj; // metadata must come out of the budget too
    }
    while budget > 0 && (fi < nfeat.len() || ai < adj.len()) {
        let fd = nfeat.get(fi).map(|x| x.0).unwrap_or(f64::NEG_INFINITY);
        let ad = if adj_meta_ok {
            adj.get(ai).map(|x| x.0).unwrap_or(f64::NEG_INFINITY)
        } else {
            f64::NEG_INFINITY
        };
        if fd == f64::NEG_INFINITY && ad == f64::NEG_INFINITY {
            break;
        }
        if fd >= ad {
            let v = nfeat[fi].1;
            let sz = ds.features.row_bytes() + 16;
            if nfeat[fi].0 > 0.0 && budget >= sz {
                feat_order.push(v);
                c_feat += sz;
                budget -= sz;
            }
            fi += 1;
            if nfeat.get(fi - 1).map(|x| x.0 <= 0.0).unwrap_or(true) && fd <= 0.0 {
                // exhausted useful nfeat entries
                if ad <= 0.0 {
                    break;
                }
            }
        } else {
            let v = adj[ai].1;
            let sz = ds.csc.degree(v) as u64 * 4;
            if adj[ai].0 > 0.0 && budget >= sz {
                adj_order.push(v);
                c_adj += sz;
                budget -= sz;
            }
            ai += 1;
        }
    }

    // fill caches with the knapsack-chosen orders
    let (adj_cache, adj_ledger) = if ds.csc.bytes_total() <= c_adj {
        AdjCache::fill(&ds.csc, &stats.elem_counts, c_adj)
    } else {
        AdjCache::fill_with_order(&ds.csc, &stats.elem_counts, &adj_order, c_adj)
    };
    let (feat_cache, feat_ledger) =
        FeatCache::fill_with_order(&ds.features, &feat_order, c_feat);

    let wall_ns = wall0.elapsed().as_nanos() as f64;
    let modeled_ns = stats.t_sample_ns + stats.t_feature_ns
        + adj_ledger.modeled_ns(cost)
        + feat_ledger.modeled_ns(cost);

    Ok(PreparedSystem {
        kind: SystemKind::Ducati,
        adj_cache: Some(adj_cache),
        feat_cache: Some(feat_cache),
        alloc: Some(CacheAllocation { c_adj, c_feat }),
        presample: Some(stats),
        batch_order: None,
        inter_batch_reuse: false,
        preprocess_ns: wall_ns + modeled_ns,
        preprocess_wall_ns: wall_ns,
    })
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::graph::datasets;
    use crate::sampler::Fanout;

    fn cfg(budget: u64) -> RunConfig {
        let mut c = RunConfig::default();
        c.dataset = "tiny".into();
        c.batch_size = 64;
        c.fanout = Fanout::parse("3,2").unwrap();
        c.budget = Some(budget);
        c
    }

    #[test]
    fn fit_slope_exact_line() {
        let ys: Vec<f64> = (0..10).map(|i| 3.0 * i as f64 + 1.0).collect();
        assert!((fit_slope(&ys) - 3.0).abs() < 1e-9);
        assert_eq!(fit_slope(&[1.0]), 0.0);
        assert_eq!(fit_slope(&[2.0, 2.0, 2.0]), 0.0);
    }

    #[test]
    fn prepares_dual_caches_within_budget() {
        let ds = datasets::spec("tiny").unwrap().build();
        let device = DeviceMemory::new(1 << 30, 1 << 20);
        let p = prepare(&ds, &cfg(400_000), &device, &CostModel::default(),
                        &mut Rng::new(1))
            .unwrap();
        let split = p.alloc.unwrap();
        assert!(split.total() <= 400_000 + ds.csc.n_nodes() as u64 * 12);
        assert!(p.feat_cache.as_ref().unwrap().n_cached() > 0);
        assert!(p.preprocess_ns > 0.0);
    }

    #[test]
    fn heavier_preprocessing_than_dci() {
        let ds = datasets::spec("tiny").unwrap().build();
        let device = DeviceMemory::new(1 << 30, 1 << 20);
        let cost = CostModel::default();
        let d = super::super::dci::prepare(&ds, &cfg(200_000), &device, &cost,
                                           &mut Rng::new(2))
            .unwrap();
        let u = prepare(&ds, &cfg(200_000), &device, &cost, &mut Rng::new(2))
            .unwrap();
        // on `tiny` the 8x profiling request is capped by available
        // batches (15 vs DCI's 8) — full-size benches show the real gap
        assert!(
            u.preprocess_ns > 1.4 * d.preprocess_ns,
            "DUCATI {:.0} should exceed DCI {:.0}",
            u.preprocess_ns,
            d.preprocess_ns
        );
    }
}
