//! System preparation: DCI and the four comparison systems of §V.A.
//!
//! Every system's preprocessing is implemented honestly — the work the
//! paper attributes to it is actually performed — so the preprocessing
//! comparisons (Table IV, Fig. 10) are measured, not asserted:
//!
//! - [`dci`]: pre-sample `n` batches → `DciPlanner` (Eq. (1) split +
//!   lightweight fills).
//! - [`sci`]: same pre-sampling, whole budget to the feature cache
//!   (`SciPlanner`).
//! - DGL: no preparation at all (prepared inline here).
//! - [`rain`]: degree-ordered targets, MinHash/LSH batch clustering.
//! - [`ducati`]: heavier profiling + `DucatiPlanner` (value-curve
//!   fitting + knapsack fill).
//!
//! The cache-owning strategies live behind the
//! [`crate::cache::CachePlanner`] trait so the online refresh loop can
//! re-run exactly the strategy the system was prepared with; what a
//! `prepare` adds on top is *how much to profile* and the preprocessing
//! accounting. The produced caches are installed as the first epoch of
//! an [`crate::cache::DualCacheRuntime`], which every engine path reads
//! through per-batch snapshots.

pub mod dci;
pub mod ducati;
pub mod rain;
pub mod sci;

use std::sync::Arc;

use anyhow::Result;

use crate::cache::runtime::CacheSnapshot;
use crate::cache::shard::{ShardRouter, ShardedPlan, ShardedRuntime};
use crate::cache::CacheAllocation;
use crate::config::{RunConfig, SystemKind};
use crate::graph::{Dataset, NodeId};
use crate::mem::{CostModel, DeviceMemory};
use crate::sampler::PresampleStats;
use crate::util::Rng;

pub use crate::cache::planner::planner_for;

/// What a system's preprocessing produced; the engine consumes this.
pub struct PreparedSystem {
    pub kind: SystemKind,
    /// Epoch-swappable dual-cache state, sharded across the node's
    /// simulated devices (one shard for single-device systems).
    /// Execution paths never hold `&AdjCache`/`&FeatCache` directly —
    /// they acquire per-shard snapshots per batch through a
    /// `ShardedHandle`, so a background refresh can hot-swap any
    /// shard's caches without stalling them.
    pub runtime: Arc<ShardedRuntime>,
    /// Total byte budget the initial plan ran with (re-plans stay
    /// within it; 0 for cacheless systems).
    pub cache_budget: u64,
    /// Exact-integer per-shard split of `cache_budget` (len =
    /// `runtime.n_shards()`; Σ == `cache_budget`). Per-shard re-plans
    /// stay within their own entry.
    pub shard_budgets: Vec<u64>,
    /// Pre-sampling statistics (reporting + refresh baseline;
    /// DCI/SCI/DUCATI).
    pub presample: Option<PresampleStats>,
    /// RAIN: reordered seed batches (cluster-grouped) and, parallel to
    /// it, each batch's cluster id.
    pub batch_order: Option<(Vec<Vec<NodeId>>, Vec<usize>)>,
    /// RAIN: reuse features resident from the previous batch.
    pub inter_batch_reuse: bool,
    /// Total preprocessing time, ns (measured wall + modeled transfer).
    pub preprocess_ns: f64,
    /// Wall-only component (reporting).
    pub preprocess_wall_ns: f64,
}

impl PreparedSystem {
    /// Wrap an initial single-shard snapshot (the common constructor;
    /// callers then fill in ordering/accounting fields as needed).
    pub fn from_snapshot(
        kind: SystemKind,
        snapshot: CacheSnapshot,
        presample: Option<PresampleStats>,
        cache_budget: u64,
    ) -> Self {
        PreparedSystem {
            kind,
            runtime: Arc::new(ShardedRuntime::single(snapshot)),
            cache_budget,
            shard_budgets: vec![cache_budget],
            presample,
            batch_order: None,
            inter_batch_reuse: false,
            preprocess_ns: 0.0,
            preprocess_wall_ns: 0.0,
        }
    }

    /// A no-preparation system (the DGL baseline).
    pub fn bare(kind: SystemKind) -> Self {
        Self::from_snapshot(kind, CacheSnapshot::empty(), None, 0)
    }

    /// Wrap a sharded plan's output, folding every shard's fill
    /// accounting into the preprocessing totals (`extra_modeled_ns`
    /// carries the profiling stage times the plans themselves do not
    /// know about).
    pub fn from_plans(
        kind: SystemKind,
        sharded: ShardedPlan,
        router: ShardRouter,
        presample: Option<PresampleStats>,
        cache_budget: u64,
        extra_modeled_ns: f64,
        cost: &CostModel,
    ) -> Self {
        let ShardedPlan { plans, budgets } = sharded;
        let mut wall_ns = 0.0;
        let mut modeled_ns = extra_modeled_ns;
        let mut snapshots = Vec::with_capacity(plans.len());
        for plan in plans {
            wall_ns += plan.plan_wall_ns;
            modeled_ns += plan.fill_ledger.modeled_ns(cost);
            snapshots.push(plan.snapshot);
        }
        PreparedSystem {
            kind,
            runtime: Arc::new(ShardedRuntime::new(router, snapshots)),
            cache_budget,
            shard_budgets: budgets,
            presample,
            batch_order: None,
            inter_batch_reuse: false,
            preprocess_ns: wall_ns + modeled_ns,
            preprocess_wall_ns: wall_ns,
        }
    }

    /// Device bytes the live snapshots' caches occupy, summed across
    /// shards.
    pub fn cache_bytes(&self) -> u64 {
        self.runtime.snapshots().iter().map(|s| s.bytes_used()).sum()
    }

    /// The allocation split of the live snapshots (reporting; summed
    /// across the shards that carry one).
    pub fn alloc(&self) -> Option<CacheAllocation> {
        let mut total: Option<CacheAllocation> = None;
        for snap in self.runtime.snapshots() {
            if let Some(a) = snap.alloc {
                let t = total.get_or_insert(CacheAllocation { c_adj: 0, c_feat: 0 });
                t.c_adj += a.c_adj;
                t.c_feat += a.c_feat;
            }
        }
        total
    }
}

/// Pre-sampling profiles with small batches regardless of the serving
/// batch size: Eq. (1) consumes a *time ratio* (batch-size invariant)
/// and the fills consume visit *counts* (coverage matters, not batch
/// geometry), so profiling 8 x 256-seed batches gives the same split
/// decisions at a fraction of the cost — this also reproduces the
/// paper's Table IV observation that DCI's preprocessing is nearly
/// flat in batch size (0.26→0.32 s on Reddit) while ours would
/// otherwise grow ~4x from bs=256 to bs=4096.
pub const PRESAMPLE_BS_CAP: usize = 256;

/// Workload-aware total cache budget: what is left of device memory
/// after the reserve and the workload's own peak claim (§IV.A). The
/// peak claim is estimated from pre-sampling: input features + block
/// tensors + activations for the largest observed batch.
///
/// The claim model itself ([`crate::mem::workload_claim_bytes`] over
/// [`crate::mem::per_node_claim_bytes`]) is shared with the refresh
/// loop's per-epoch re-evaluation
/// ([`crate::cache::refresh::AutoBudgetPolicy`]) so the startup budget
/// and its online re-evaluations can never disagree on the formula.
pub fn auto_budget(
    device: &DeviceMemory,
    stats: &PresampleStats,
    row_bytes: u64,
    hidden: usize,
    scale: f64,
) -> u64 {
    let claim = crate::mem::workload_claim_bytes(
        stats.max_input_nodes as u64,
        crate::mem::per_node_claim_bytes(row_bytes, hidden),
        scale,
    );
    device.available_for_cache().saturating_sub(claim)
}

/// Resolve the node-global cache budget for a cache-owning system.
/// Explicit budgets are global across the node's shards, clamped so
/// that the per-shard split can never exceed the devices' combined
/// headroom: uniform nodes clamp to `n × per-device` (so every
/// [`split_budget`] share fits, remainder byte included);
/// heterogeneous nodes (`device-tiers=`) clamp to the *sum* of the
/// tiers' headrooms, with [`shard_budget_split`]'s per-device caps
/// keeping each share inside its own card. Auto budgets apply the
/// workload-aware claim (§IV.A) per device — every card stages the
/// same peak batch, so each pays the claim out of its own headroom.
///
/// [`split_budget`]: crate::cache::split_budget
pub fn resolve_budget(
    cfg: &RunConfig,
    device: &DeviceMemory,
    stats: &PresampleStats,
    row_bytes: u64,
    scale: f64,
) -> u64 {
    if let Some(tiers) = &cfg.device_tiers {
        let claim = crate::mem::workload_claim_bytes(
            stats.max_input_nodes as u64,
            crate::mem::per_node_claim_bytes(row_bytes, cfg.hidden),
            scale,
        );
        let cap: u64 = tiers.iter().map(|t| t.headroom()).sum();
        return cfg
            .budget
            .unwrap_or_else(|| tiers.iter().map(|t| t.headroom().saturating_sub(claim)).sum())
            .min(cap);
    }
    let n = cfg.shards.max(1) as u64;
    let per_device = device.available_for_cache();
    cfg.budget
        .unwrap_or_else(|| {
            auto_budget(device, stats, row_bytes, cfg.hidden, scale).saturating_mul(n)
        })
        .min(per_device.saturating_mul(n))
}

/// Per-shard split of the node-global budget. Uniform nodes split
/// evenly ([`split_budget`]); heterogeneous nodes (`device-tiers=`,
/// one tier per shard) split by tier weight — headroom × relative
/// bandwidth, the same formula as
/// [`DeviceGroup::tier_weights`](crate::mem::DeviceGroup::tier_weights)
/// — so budget flows toward devices that are both big (can hold it)
/// and fast (can re-fill it cheaply), then each share is capped by its
/// own device's headroom
/// ([`cap_shares_per_device`](crate::cache::cap_shares_per_device)).
/// Conservation (`Σ shares == total`) holds because [`resolve_budget`]
/// clamps the total to the summed headrooms. A tier list whose length
/// does not match the shard count falls back to the even split (the
/// engine rejects that configuration before serving anyway).
///
/// [`split_budget`]: crate::cache::split_budget
pub fn shard_budget_split(cfg: &RunConfig, total: u64, n: usize) -> Vec<u64> {
    use crate::cache::planner::{cap_shares_per_device, split_budget, split_budget_weighted};
    match &cfg.device_tiers {
        Some(tiers) if tiers.len() == n && n > 1 => {
            let max_gbps = tiers.iter().map(|t| t.h2d_gbps).fold(f64::MIN, f64::max);
            let weights: Vec<f64> = tiers
                .iter()
                .map(|t| {
                    let share =
                        if max_gbps > 0.0 { t.h2d_gbps / max_gbps } else { 1.0 };
                    t.headroom() as f64 * share
                })
                .collect();
            let mut shares = split_budget_weighted(total, &weights, 0.0);
            let headrooms: Vec<u64> = tiers.iter().map(|t| t.headroom()).collect();
            cap_shares_per_device(&mut shares, &headrooms);
            shares
        }
        _ => split_budget(total, n),
    }
}

/// Dispatch: run `cfg.system`'s preprocessing.
pub fn prepare(
    ds: &Dataset,
    cfg: &RunConfig,
    device: &DeviceMemory,
    cost: &CostModel,
    rng: &mut Rng,
) -> Result<PreparedSystem> {
    // systems without a cache plan have nothing to shard; silently
    // running them on one device while the cache-owning systems get N
    // would corrupt any cross-system comparison at shards>1
    if cfg.shards > 1 && planner_for(cfg.system).is_none() {
        anyhow::bail!(
            "system={} has no shardable cache state; run it with shards=1",
            cfg.system.as_str()
        );
    }
    match cfg.system {
        SystemKind::Dgl => Ok(PreparedSystem::bare(SystemKind::Dgl)),
        SystemKind::Dci => dci::prepare(ds, cfg, device, cost, rng),
        SystemKind::Sci => sci::prepare(ds, cfg, device, cost, rng),
        SystemKind::Rain => rain::prepare(ds, cfg, cost, rng),
        SystemKind::Ducati => ducati::prepare(ds, cfg, device, cost, rng),
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::graph::datasets;
    use crate::sampler::{presample, Fanout};

    #[test]
    fn bare_has_no_caches() {
        let p = PreparedSystem::bare(SystemKind::Dgl);
        assert_eq!(p.cache_bytes(), 0);
        assert_eq!(p.preprocess_ns, 0.0);
        let snap = p.runtime.load();
        assert!(snap.adj.is_none() && snap.feat.is_none());
        assert_eq!(p.cache_budget, 0);
    }

    #[test]
    fn auto_budget_subtracts_workload() {
        let ds = datasets::spec("tiny").unwrap().build();
        let stats = presample(
            &ds.csc,
            &ds.features,
            &ds.test_nodes,
            64,
            &Fanout::parse("3,2").unwrap(),
            4,
            &CostModel::default(),
            &mut Rng::new(1),
        );
        let device = DeviceMemory::new(1 << 30, 1 << 20);
        let b = auto_budget(&device, &stats, ds.features.row_bytes(), 128, 1.0);
        assert!(b > 0 && b < device.available_for_cache());
        // tiny device -> zero budget, never underflow
        let small = DeviceMemory::new(1 << 16, 1 << 10);
        assert_eq!(auto_budget(&small, &stats, ds.features.row_bytes(), 128, 1.0), 0);
        // scaling the claim returns budget on small devices
        assert!(auto_budget(&small, &stats, ds.features.row_bytes(), 128, 0.0001) > 0);
    }

    #[test]
    fn cacheless_systems_reject_sharding() {
        let ds = datasets::spec("tiny").unwrap().build();
        let device = DeviceMemory::new(1 << 30, 1 << 20);
        let cost = CostModel::default();
        for kind in [SystemKind::Dgl, SystemKind::Rain] {
            let mut cfg = RunConfig::default();
            cfg.dataset = "tiny".into();
            cfg.system = kind;
            cfg.batch_size = 64;
            cfg.fanout = Fanout::parse("3,2").unwrap();
            cfg.shards = 2;
            let err = prepare(&ds, &cfg, &device, &cost, &mut Rng::new(3)).unwrap_err();
            assert!(err.to_string().contains("shards=1"), "{kind:?}: {err}");
        }
    }

    #[test]
    fn dispatch_all_systems_on_tiny() {
        let ds = datasets::spec("tiny").unwrap().build();
        let device = DeviceMemory::new(1 << 30, 1 << 20);
        let cost = CostModel::default();
        for kind in SystemKind::all() {
            let mut cfg = RunConfig::default();
            cfg.dataset = "tiny".into();
            cfg.system = kind;
            cfg.batch_size = 64;
            cfg.fanout = Fanout::parse("3,2").unwrap();
            cfg.budget = Some(200_000);
            let p = prepare(&ds, &cfg, &device, &cost, &mut Rng::new(3)).unwrap();
            assert_eq!(p.kind, kind);
            let snap = p.runtime.load();
            match kind {
                SystemKind::Dgl => assert_eq!(p.cache_bytes(), 0),
                SystemKind::Sci => {
                    assert!(snap.feat.is_some() && snap.adj.is_none())
                }
                SystemKind::Dci | SystemKind::Ducati => {
                    assert!(snap.feat.is_some());
                    assert!(p.preprocess_ns > 0.0);
                    assert_eq!(p.cache_budget, 200_000);
                }
                SystemKind::Rain => {
                    assert!(p.batch_order.is_some() && p.inter_batch_reuse)
                }
            }
        }
    }
}
