//! System preparation: DCI and the four comparison systems of §V.A.
//!
//! Every system's preprocessing is implemented honestly — the work the
//! paper attributes to it is actually performed — so the preprocessing
//! comparisons (Table IV, Fig. 10) are measured, not asserted:
//!
//! - [`dci`]: pre-sample `n` batches → Eq. (1) split → lightweight fills.
//! - [`sci`]: same pre-sampling, whole budget to the feature cache.
//! - DGL: no preparation at all (prepared inline here).
//! - [`rain`]: degree-ordered targets, MinHash/LSH batch clustering.
//! - [`ducati`]: heavier profiling + value-curve fitting + knapsack fill.

pub mod dci;
pub mod ducati;
pub mod rain;
pub mod sci;

use anyhow::Result;

use crate::cache::{AdjCache, CacheAllocation, FeatCache};
use crate::config::{RunConfig, SystemKind};
use crate::graph::{Dataset, NodeId};
use crate::mem::{CostModel, DeviceMemory};
use crate::sampler::PresampleStats;
use crate::util::Rng;

/// What a system's preprocessing produced; the engine consumes this.
pub struct PreparedSystem {
    pub kind: SystemKind,
    /// Adjacency cache (DCI, DUCATI; `None` = all sampling over UVA).
    pub adj_cache: Option<AdjCache>,
    /// Feature cache (DCI, SCI, DUCATI).
    pub feat_cache: Option<FeatCache>,
    /// The Eq.-(1)-style split that was applied (reporting).
    pub alloc: Option<CacheAllocation>,
    /// Pre-sampling statistics (reporting; DCI/SCI/DUCATI).
    pub presample: Option<PresampleStats>,
    /// RAIN: reordered seed batches (cluster-grouped) and, parallel to
    /// it, each batch's cluster id.
    pub batch_order: Option<(Vec<Vec<NodeId>>, Vec<usize>)>,
    /// RAIN: reuse features resident from the previous batch.
    pub inter_batch_reuse: bool,
    /// Total preprocessing time, ns (measured wall + modeled transfer).
    pub preprocess_ns: f64,
    /// Wall-only component (reporting).
    pub preprocess_wall_ns: f64,
}

impl PreparedSystem {
    /// A no-preparation system (the DGL baseline).
    pub fn bare(kind: SystemKind) -> Self {
        PreparedSystem {
            kind,
            adj_cache: None,
            feat_cache: None,
            alloc: None,
            presample: None,
            batch_order: None,
            inter_batch_reuse: false,
            preprocess_ns: 0.0,
            preprocess_wall_ns: 0.0,
        }
    }

    /// Device bytes the caches occupy.
    pub fn cache_bytes(&self) -> u64 {
        self.adj_cache.as_ref().map(|c| c.bytes_used()).unwrap_or(0)
            + self.feat_cache.as_ref().map(|c| c.bytes_used()).unwrap_or(0)
    }
}

/// Pre-sampling profiles with small batches regardless of the serving
/// batch size: Eq. (1) consumes a *time ratio* (batch-size invariant)
/// and the fills consume visit *counts* (coverage matters, not batch
/// geometry), so profiling 8 x 256-seed batches gives the same split
/// decisions at a fraction of the cost — this also reproduces the
/// paper's Table IV observation that DCI's preprocessing is nearly
/// flat in batch size (0.26→0.32 s on Reddit) while ours would
/// otherwise grow ~4x from bs=256 to bs=4096.
pub const PRESAMPLE_BS_CAP: usize = 256;

/// Workload-aware total cache budget: what is left of device memory
/// after the reserve and the workload's own peak claim (§IV.A). The
/// peak claim is estimated from pre-sampling: input features + block
/// tensors + activations for the largest observed batch.
pub fn auto_budget(
    device: &DeviceMemory,
    stats: &PresampleStats,
    row_bytes: u64,
    hidden: usize,
    scale: f64,
) -> u64 {
    let peak_inputs = stats.max_input_nodes as u64;
    // features + first-layer activations (hidden) + block index/mask,
    // with 2x slack for the allocator's transient copies
    let per_node = row_bytes + (hidden * 4) as u64 + 64;
    let workload = 2.0 * (peak_inputs * per_node) as f64;
    // The batch footprint does not shrink with the dataset stand-in,
    // but the simulated device does (rtx4090_scaled); scale the claim
    // by the same factor so the claim/device *ratio* matches the
    // paper's testbed (≈5% of a 24 GB card). See DESIGN.md.
    let workload = (workload * scale.min(1.0)) as u64;
    device.available_for_cache().saturating_sub(workload)
}

/// Dispatch: run `cfg.system`'s preprocessing.
pub fn prepare(
    ds: &Dataset,
    cfg: &RunConfig,
    device: &DeviceMemory,
    cost: &CostModel,
    rng: &mut Rng,
) -> Result<PreparedSystem> {
    match cfg.system {
        SystemKind::Dgl => Ok(PreparedSystem::bare(SystemKind::Dgl)),
        SystemKind::Dci => dci::prepare(ds, cfg, device, cost, rng),
        SystemKind::Sci => sci::prepare(ds, cfg, device, cost, rng),
        SystemKind::Rain => rain::prepare(ds, cfg, cost, rng),
        SystemKind::Ducati => ducati::prepare(ds, cfg, device, cost, rng),
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::graph::datasets;
    use crate::sampler::{presample, Fanout};

    #[test]
    fn bare_has_no_caches() {
        let p = PreparedSystem::bare(SystemKind::Dgl);
        assert_eq!(p.cache_bytes(), 0);
        assert_eq!(p.preprocess_ns, 0.0);
        assert!(p.adj_cache.is_none() && p.feat_cache.is_none());
    }

    #[test]
    fn auto_budget_subtracts_workload() {
        let ds = datasets::spec("tiny").unwrap().build();
        let stats = presample(
            &ds.csc,
            &ds.features,
            &ds.test_nodes,
            64,
            &Fanout::parse("3,2").unwrap(),
            4,
            &CostModel::default(),
            &mut Rng::new(1),
        );
        let device = DeviceMemory::new(1 << 30, 1 << 20);
        let b = auto_budget(&device, &stats, ds.features.row_bytes(), 128, 1.0);
        assert!(b > 0 && b < device.available_for_cache());
        // tiny device -> zero budget, never underflow
        let small = DeviceMemory::new(1 << 16, 1 << 10);
        assert_eq!(auto_budget(&small, &stats, ds.features.row_bytes(), 128, 1.0), 0);
        // scaling the claim returns budget on small devices
        assert!(auto_budget(&small, &stats, ds.features.row_bytes(), 128, 0.0001) > 0);
    }

    #[test]
    fn dispatch_all_systems_on_tiny() {
        let ds = datasets::spec("tiny").unwrap().build();
        let device = DeviceMemory::new(1 << 30, 1 << 20);
        let cost = CostModel::default();
        for kind in SystemKind::all() {
            let mut cfg = RunConfig::default();
            cfg.dataset = "tiny".into();
            cfg.system = kind;
            cfg.batch_size = 64;
            cfg.fanout = Fanout::parse("3,2").unwrap();
            cfg.budget = Some(200_000);
            let p = prepare(&ds, &cfg, &device, &cost, &mut Rng::new(3)).unwrap();
            assert_eq!(p.kind, kind);
            match kind {
                SystemKind::Dgl => assert_eq!(p.cache_bytes(), 0),
                SystemKind::Sci => {
                    assert!(p.feat_cache.is_some() && p.adj_cache.is_none())
                }
                SystemKind::Dci | SystemKind::Ducati => {
                    assert!(p.feat_cache.is_some());
                    assert!(p.preprocess_ns > 0.0);
                }
                SystemKind::Rain => {
                    assert!(p.batch_order.is_some() && p.inter_batch_reuse)
                }
            }
        }
    }
}
