//! SCI — the state-of-the-art single-cache inference baseline (§V.A):
//! identical architecture to DCI but the adjacency cache is disabled
//! and the *entire* budget goes to node features ([`SciPlanner`]).
//! This is the system Fig. 8 compares against, and Fig. 2's "more
//! feature cache stops helping" observation is its failure mode.

use anyhow::Result;

use crate::cache::planner::{SciPlanner, WorkloadProfile};
use crate::cache::shard::{plan_sharded_with_budgets, ShardRouter};
use crate::config::{RunConfig, SystemKind};
use crate::graph::Dataset;
use crate::mem::{CostModel, DeviceMemory};
use crate::sampler::presample_threads;
use crate::util::Rng;

use super::{resolve_budget, PreparedSystem};

pub fn prepare(
    ds: &Dataset,
    cfg: &RunConfig,
    device: &DeviceMemory,
    cost: &CostModel,
    rng: &mut Rng,
) -> Result<PreparedSystem> {
    let stats = presample_threads(
        &ds.csc,
        &ds.features,
        &ds.test_nodes,
        cfg.batch_size.min(super::PRESAMPLE_BS_CAP),
        &cfg.fanout,
        cfg.n_presample,
        cost,
        rng,
        cfg.sample_threads,
    );
    // node-global budget, clamped so every shard's share fits its own
    // device (see `resolve_budget`)
    let total = resolve_budget(cfg, device, &stats, ds.features.row_bytes(), ds.spec.scale);
    // single cache: everything to features (fill wall is real host work)
    let router = ShardRouter::new(cfg.shards.max(1));
    let plans = plan_sharded_with_budgets(
        &SciPlanner,
        ds,
        &WorkloadProfile::from_presample(&stats),
        super::shard_budget_split(cfg, total, router.n_shards()),
        &router,
    );
    let profiling_ns = stats.t_sample_ns + stats.t_feature_ns;
    Ok(PreparedSystem::from_plans(
        SystemKind::Sci,
        plans,
        router,
        Some(stats),
        total,
        profiling_ns,
        cost,
    ))
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::graph::datasets;
    use crate::sampler::Fanout;

    #[test]
    fn whole_budget_to_features() {
        let ds = datasets::spec("tiny").unwrap().build();
        let device = DeviceMemory::new(1 << 30, 1 << 20);
        let mut cfg = RunConfig::default();
        cfg.dataset = "tiny".into();
        cfg.batch_size = 64;
        cfg.fanout = Fanout::parse("3,2").unwrap();
        cfg.budget = Some(100_000);
        let p = prepare(&ds, &cfg, &device, &CostModel::default(), &mut Rng::new(1))
            .unwrap();
        let snap = p.runtime.load();
        assert!(snap.adj.is_none());
        let fc = snap.feat.as_ref().unwrap();
        assert!(fc.bytes_used() <= 100_000);
        // uses most of the budget (rows are 80B; fill to the brim)
        assert!(fc.bytes_used() > 100_000 - 2 * (ds.features.row_bytes() + 16));
    }
}
