//! Staged pipeline executor: a sampling worker pool, an in-order
//! feature-gather stage, and an in-order compute stage connected by
//! bounded channels, so up to `cfg.pipeline_depth` mini-batches are in
//! flight concurrently. Batch *i+1*'s sampling no longer waits for
//! batch *i*'s compute — the SALIENT/BGL overlap that hides the 56–92%
//! preparation share Fig. 1 measures (see EXPERIMENTS.md §Perf and the
//! `pipeline_overlap` bench).
//!
//! Topology (std::thread only; each inter-stage channel is an
//! `mpsc::sync_channel` with capacity `pipeline_depth`, so the total
//! number of in-flight batches is bounded by roughly
//! `2 × pipeline_depth + sample_threads + 2` — two queues plus one
//! batch held per worker and per stage thread):
//!
//! ```text
//!   sampling workers (cfg.sample_threads, pooled scratch)
//!        │  SampledBatch, any order
//!        ▼
//!   gather thread (reorder buffer → strictly batch-index order;
//!                  owns RAIN's previous-batch residency set; staged
//!                  mode leases its gather buffer from the pinned
//!                  staging pool and records coalesced copy plans)
//!        │  Gathered, in order
//!        ▼
//!   [transfer ring — staged mode only: a sync_channel(transfer_ring)
//!    forwarder holding up to K batches whose staged H2D copies are
//!    modeled in flight while earlier batches compute]
//!        │  Gathered, in order
//!        ▼
//!   caller thread: compute + report folding, in order; returns each
//!   staging buffer to the pool when its batch's compute completes
//!   (zero-copy: the staged buffer *is* the compute input)
//! ```
//!
//! Determinism: per-batch RNGs come from `stages::batch_rng`, the
//! gather and compute stages run in batch-index order, and every ledger
//! folds into the report in that same order — so counters, modeled
//! times, and the logits checksum are bit-identical to the serial path
//! at any `pipeline_depth` / `sample_threads` / `transfer_ring` setting
//! (the pipeline and transfer-engine equivalence tests assert exactly
//! this). Staging changes how moved bytes are *priced* (one coalesced
//! plan per batch) and when the modeled timeline says they moved (the
//! [`TransferSim`] fold, batch-index order), never which bytes move.

use std::collections::{HashMap, HashSet};
use std::panic::AssertUnwindSafe;
use std::sync::atomic::{AtomicU64, AtomicUsize, Ordering};
use std::sync::{mpsc, Arc, Mutex};

use anyhow::{Context, Result};

use crate::cache::shard::ShardedHandle;
use crate::coordinator::admission::TenantClass;
use crate::graph::{GraphHandle, NodeId};
use crate::mem::TransferLedger;
use crate::util::lock_unpoisoned;

use super::stages::{self, SampledBatch};
use super::transfer::TransferSim;
use super::{InferenceEngine, InferenceReport};

/// A batch that has cleared the gather stage.
struct Gathered {
    sb: SampledBatch,
    x: Vec<f32>,
    ledger: TransferLedger,
    wall_ns: f64,
    n_inputs: usize,
}

/// Run `batches[..n]` through the three-stage pipeline, folding results
/// into `report` exactly as the serial loop would.
pub(super) fn run_pipelined(
    engine: &mut InferenceEngine<'_>,
    batches: &[&[NodeId]],
    n: usize,
    report: &mut InferenceReport,
) -> Result<()> {
    let depth = engine.cfg.pipeline_depth;
    let workers = engine.cfg.sample_threads.max(1).min(n);
    let staged_on = engine.staged_enabled();
    let ring = engine.cfg.transfer_ring;
    // gather leases from the pool; this thread returns buffers after
    // compute (both clones taken before the &mut compute split below)
    let staging = engine.staging.clone();
    let staging_gather = staging.clone();

    // split the engine borrow: shared state for the stage threads,
    // the mutable compute backend for this thread
    let ds = engine.ds;
    let prepared = &engine.prepared;
    let runtime = &prepared.runtime;
    let cfg = &engine.cfg;
    let pool = &engine.pool;
    let compute = &mut engine.compute;
    let feat_dim = ds.features.dim();
    let classes = ds.spec.classes;

    let fault = engine.fault.clone();
    // shared live graph (if attached), cloned before the borrow split;
    // each sampling worker cursors its epochs through its own handle
    let live_graph = engine.graph.as_ref().map(|h| Arc::clone(h.live()));

    let next = AtomicUsize::new(0);
    // `None` marks a batch whose sampling panicked twice (panic
    // isolation below); it flows downstream in order so compute can
    // fail the run deterministically instead of deadlocking the
    // reorder buffer on a hole
    let (s_tx, s_rx) = mpsc::sync_channel::<(usize, Option<SampledBatch>)>(depth);
    let (g_tx, g_rx) = mpsc::sync_channel::<(usize, Option<Gathered>)>(depth);
    let retried = AtomicU64::new(0);

    // Claim-ahead tickets: a worker may not *start* a batch until fewer
    // than `depth + workers` batches are awaiting gather. This caps the
    // gather stage's reorder buffer (one slow straggler batch could
    // otherwise let fast workers race arbitrarily far ahead, stacking
    // up O(n) sampled batches in memory). Gather returns one ticket per
    // batch it finishes; dropping the sender doubles as shutdown.
    let (ticket_tx, ticket_rx) = mpsc::channel::<()>();
    for _ in 0..(depth + workers) {
        let _ = ticket_tx.send(());
    }
    let tickets = Mutex::new(ticket_rx);

    // Gather-buffer recycling: compute returns spent `x` buffers so the
    // pipelined gather stage is allocation-flat like the serial loop's
    // single reused buffer.
    let (recycle_tx, recycle_rx) = mpsc::channel::<Vec<f32>>();

    let result = std::thread::scope(|scope| -> Result<()> {
        // ---- stage 1: sampling worker pool -------------------------
        for _ in 0..workers {
            let s_tx = s_tx.clone();
            let next = &next;
            let tickets = &tickets;
            let retried = &retried;
            let fault = fault.clone();
            let live_graph = live_graph.clone();
            scope.spawn(move || {
                let mut sampler = pool.checkout();
                // each worker cursors every shard's epochs independently;
                // acquire is per batch, so one batch never mixes epochs
                // within a shard
                let mut snap = ShardedHandle::new(runtime);
                let mut graph = live_graph.as_ref().map(GraphHandle::new);
                loop {
                    // Err = ticket sender dropped = gather unwound
                    if lock_unpoisoned(tickets).recv().is_err() {
                        break;
                    }
                    let bi = next.fetch_add(1, Ordering::Relaxed);
                    if bi >= n {
                        break;
                    }
                    // panic isolation: a batch that panics (injected
                    // fault or real bug) is retried once with fresh
                    // scratch, then reported downstream as failed —
                    // the pool and the other workers keep running
                    let mut sample = || {
                        std::panic::catch_unwind(AssertUnwindSafe(|| {
                            if let Some(f) = &fault {
                                if f.batch_panic(bi) {
                                    panic!("injected fault: batch {bi} panicked");
                                }
                            }
                            let graph_epoch =
                                graph.as_mut().map(|h| h.acquire_arc());
                            let view = snap.acquire();
                            stages::sample_stage(
                                ds,
                                &view,
                                &mut sampler,
                                batches[bi],
                                bi,
                                cfg.seed,
                                None,
                                graph_epoch.as_deref(),
                            )
                        }))
                    };
                    let sb = match sample() {
                        Ok(sb) => Some(sb),
                        Err(_) => {
                            retried.fetch_add(1, Ordering::Relaxed);
                            sample().ok()
                        }
                    };
                    if s_tx.send((bi, sb)).is_err() {
                        break; // downstream unwound (compute error)
                    }
                }
                pool.checkin(sampler);
            });
        }
        drop(s_tx); // gather's recv loop ends when the workers finish

        // ---- stage 2: in-order feature gather ----------------------
        scope.spawn(move || {
            // workers finish out of order; a small reorder buffer
            // (bounded by depth + workers) restores batch order, which
            // both preserves RAIN's previous-batch reuse semantics and
            // keeps downstream folding deterministic
            let mut reorder: HashMap<usize, Option<SampledBatch>> = HashMap::new();
            let mut want = 0usize;
            let mut prev_inputs: HashSet<NodeId> = HashSet::new();
            let mut snap = ShardedHandle::new(runtime);
            for (idx, sb) in s_rx {
                reorder.insert(idx, sb);
                while let Some(slot) = reorder.remove(&want) {
                    let idx = want;
                    want += 1;
                    // recycle this batch's claim-ahead ticket (receiver
                    // may already be gone during orderly shutdown)
                    let _ = ticket_tx.send(());
                    let item = slot.map(|sb| {
                        // staged mode gathers straight into a leased
                        // staging buffer; otherwise reuse a spent
                        // buffer when compute returned one
                        let mut x = if staged_on {
                            staging_gather.lease()
                        } else {
                            recycle_rx.try_recv().unwrap_or_default()
                        };
                        let view = snap.acquire();
                        let (ledger, wall_ns, n_inputs) = stages::gather_stage(
                            ds,
                            &view,
                            prepared.inter_batch_reuse,
                            &cfg.cost,
                            &sb.mb,
                            &mut prev_inputs,
                            &mut x,
                            None,
                            TenantClass::Standard,
                            staged_on.then(|| stages::StagedGather {
                                fault: fault.as_deref(),
                                batch_index: idx,
                            }),
                        );
                        Gathered { sb, x, ledger, wall_ns, n_inputs }
                    });
                    if g_tx.send((idx, item)).is_err() {
                        return; // downstream unwound
                    }
                }
            }
            // dropping ticket_tx here wakes any worker still blocked
            // on a ticket so it can observe shutdown
        });

        // ---- stage 3: transfer ring (staged mode only) -------------
        // a bounded forwarder: at most `transfer_ring` gathered batches
        // sit here with their staged copies modeled in flight while
        // earlier batches compute downstream
        let in_rx = if staged_on {
            let (t_tx, t_rx) = mpsc::sync_channel::<(usize, Option<Gathered>)>(ring.max(1));
            scope.spawn(move || {
                for item in g_rx {
                    if t_tx.send(item).is_err() {
                        return; // downstream unwound
                    }
                }
            });
            t_rx
        } else {
            g_rx
        };

        // ---- stage 4: compute + report folding, on this thread -----
        // the ring clock is fed in batch-index order, same as the
        // serial fold, so occupancy is scheduler-independent
        let mut sim = staged_on.then(|| TransferSim::new(ring));
        for (idx, g) in in_rx {
            let Some(g) = g else {
                anyhow::bail!("batch {idx} panicked twice in the sampling stage");
            };
            let sb = g.sb;
            report.sample.add(sb.wall_ns, sb.ledger.modeled_ns(&cfg.cost));
            report.stats.sample.merge(&sb.ledger);
            report.loaded_nodes += g.n_inputs as u64;
            report.feature.add(g.wall_ns, g.ledger.modeled_ns(&cfg.cost));
            report.stats.feature.merge(&g.ledger);
            let staged_ns = g.ledger.staged_ns(&cfg.cost);

            let cb = stages::compute_stage(compute, cfg, classes, feat_dim, &sb.mb, &g.x)
                .with_context(|| format!("compute failed on batch {}", sb.index))?;
            // zero-copy: the buffer frees only now that its consumer's
            // compute is done — back to the pool (staged) or to gather
            // via the recycle channel (gone during shutdown: fine)
            if staged_on {
                staging.give_back(g.x);
            } else {
                let _ = recycle_tx.send(g.x);
            }
            report.compute.add(cb.wall_ns, cb.modeled_ns);
            if let Some(sim) = &mut sim {
                let hidden = sim.advance(staged_ns, cb.wall_ns + cb.modeled_ns);
                report.transfer_staged_ns += staged_ns;
                report.transfer_hidden_ns += hidden;
            }
            if let Some(l) = cb.logits {
                report.logits_checksum += l.iter().map(|v| v.abs() as f64).sum::<f64>();
            }
            report.n_batches += 1;
            report.n_seeds += batches[sb.index].len();
        }
        Ok(())
        // on error the receivers drop here: gather's send fails → it
        // returns → the workers' sends fail → they exit; scope joins all
    });
    // folded even when compute bailed: partial retry counts still show
    report.batch_retries += retried.load(Ordering::Relaxed);
    result
}
