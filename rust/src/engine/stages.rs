//! The three stage bodies — sampling, feature gather, compute — shared
//! by every execution mode of the engine: the serial batch loop, the
//! staged pipeline executor ([`super::pipeline`]), and the
//! coordinator's per-request path (`infer_once`). One implementation
//! per stage is what guarantees the pipelined engine is *semantically*
//! the serial engine, just scheduled differently.
//!
//! Cache state arrives as a [`ShardView`] — the immutable per-shard
//! epochs a caller acquired from its `ShardedHandle` for this batch —
//! never as bare `&AdjCache`/`&FeatCache` references, so a background
//! refresh can hot-swap any shard's caches between batches without the
//! stages noticing. The view routes every feature lookup and adjacency
//! read to the shard that owns the node; with one shard it degenerates
//! to the PR 2 single-snapshot path bit for bit. An optional
//! [`WorkloadTracker`] (the serving path's online-refresh input —
//! dense counters or the count-min sketch, see `cache::tracker`)
//! receives the same per-node / per-element counts pre-sampling
//! collects; `None` keeps the offline paths zero-overhead.
//!
//! Determinism contract: a batch's sampling RNG is [`batch_rng`]` =
//! Rng::for_stream(cfg.seed, batch_index)` — a pure function of the
//! run seed and the batch's position, never of which thread runs it or
//! when. Sampling position choices are independent of cache contents
//! (a cache changes *where* a neighbor is read from — which device,
//! which shard — never *which* neighbor), so stage outputs depend only
//! on `(snapshot-transparent dataset state, seeds, batch_index, seed)`
//! — any scheduler that folds per-batch ledgers in batch-index order
//! reproduces the serial run bit for bit, results are identical
//! before/during/after a snapshot swap, and sharded gathers produce
//! bit-identical logits at any shard count.

use std::collections::HashSet;
use std::time::Instant;

use anyhow::Result;

use crate::cache::shard::ShardView;
use crate::cache::tracker::WorkloadTracker;
use crate::config::RunConfig;
use crate::coordinator::admission::TenantClass;
use crate::graph::{Csc, Dataset, GraphEpoch, NodeId, OverlayAdj};
use crate::mem::{CopyPlan, CostModel, TransferLedger};
use crate::runtime::Compute;
use crate::sampler::{presample::row_txns, AdjSource, MiniBatch, NeighborSampler};
use crate::util::{FaultPlan, Rng};

use super::model_flops;

/// Per-batch sampling RNG (see the module docs for the contract).
pub fn batch_rng(seed: u64, batch_index: u64) -> Rng {
    Rng::for_stream(seed, batch_index)
}

/// Output of the sampling stage for one mini-batch.
pub struct SampledBatch {
    /// Position in the run's batch order (reordering key downstream).
    pub index: usize,
    pub mb: MiniBatch,
    pub ledger: TransferLedger,
    pub wall_ns: f64,
}

/// Stage 1: fan-out sampling over the view's routed adjacency source
/// (per-shard device prefixes hit, everything else falls back to UVA).
///
/// `graph: Some(epoch)` layers a live-mutation epoch's delta over the
/// cached reads ([`OverlayAdj`]): positions inside the
/// preprocessing-time CSC route through the view unchanged (prefix
/// stability keeps cached entries correct across compactions — see
/// `graph::delta`), delta positions read the epoch directly as host
/// misses. `None` is the frozen-graph path, bit-identical to before
/// the overlay existed. The epoch joins the determinism contract's
/// dataset state: outputs depend on `(dataset, epoch, seeds,
/// batch_index, seed)`, never on cache or scheduling state.
#[allow(clippy::too_many_arguments)]
pub fn sample_stage(
    ds: &Dataset,
    view: &ShardView<'_>,
    sampler: &mut NeighborSampler,
    seeds: &[NodeId],
    index: usize,
    seed: u64,
    tracker: Option<&dyn WorkloadTracker>,
    graph: Option<&GraphEpoch>,
) -> SampledBatch {
    let mut rng = batch_rng(seed, index as u64);
    let mut ledger = TransferLedger::new();
    // tracked runs buffer the touched CSC offsets locally and replay
    // them into the shared tracker after the timed section, so the
    // cross-thread atomic adds never inflate the stage's wall time
    // (same discipline as the gather stage)
    let mut touched: Vec<usize> = Vec::new();
    let src = view.adj_source(&ds.csc);
    let t0 = Instant::now();
    let mb = match graph {
        None => run_sampler(
            sampler,
            &src,
            &ds.csc,
            seeds,
            &mut rng,
            &mut ledger,
            tracker.is_some(),
            &mut touched,
        ),
        Some(epoch) => {
            let overlay = OverlayAdj { cached: src, epoch, orig: &ds.csc };
            run_sampler(
                sampler,
                &overlay,
                &ds.csc,
                seeds,
                &mut rng,
                &mut ledger,
                tracker.is_some(),
                &mut touched,
            )
        }
    };
    let wall_ns = t0.elapsed().as_nanos() as f64;
    if let Some(t) = tracker {
        for &at in &touched {
            t.record_elem(at);
        }
    }
    SampledBatch { index, mb, ledger, wall_ns }
}

/// The sampling inner call shared by the frozen and overlay adjacency
/// shapes. Tracked runs log touched CSC offsets for positions inside
/// the preprocessing-time CSC only — a delta position has no offset in
/// the planner's elem space (it stays a host read until a compaction
/// folds it into a future base; node-visit mass, not elem counts, is
/// what re-caches mutated nodes).
#[allow(clippy::too_many_arguments)]
fn run_sampler<A: AdjSource>(
    sampler: &mut NeighborSampler,
    src: &A,
    csc: &Csc,
    seeds: &[NodeId],
    rng: &mut Rng,
    ledger: &mut TransferLedger,
    tracked: bool,
    touched: &mut Vec<usize>,
) -> MiniBatch {
    if !tracked {
        sampler.sample_batch(src, seeds, rng, ledger)
    } else {
        let mut on_access = |v: NodeId, pos: usize| {
            if pos < csc.degree(v) {
                touched.push(csc.neighbor_offset(v) as usize + pos);
            }
        };
        sampler.sample_batch_counting(src, seeds, rng, ledger, &mut on_access)
    }
}

/// Staged-transfer mode for [`gather_stage`]: the batch's miss rows are
/// written into a leased staging buffer and accounted as one coalesced
/// copy plan instead of N per-row UVA charges (DESIGN.md §Transfer
/// engine). Carries the fault plan so an injected `stage@B` fault can
/// fail the staged copy and exercise the per-row fallback.
#[derive(Clone, Copy)]
pub struct StagedGather<'a> {
    /// Fault schedule with the `stage@B` site (usually the engine's).
    pub fault: Option<&'a FaultPlan>,
    /// Batch index the `stage@B` target matches against.
    pub batch_index: usize,
}

/// Stage 2: gather input-node features into `x` (reused across calls —
/// a leased staging buffer on the staged path), each row from the shard
/// that owns its node.
///
/// `prev_inputs` carries RAIN's previous-batch residency between
/// consecutive calls; it is read and then replaced only when
/// `inter_batch_reuse` is set, so callers that never serve RAIN can
/// pass any (empty) set.
///
/// `staged: Some(_)` switches miss accounting to the coalesced copy
/// plan (RAIN's reuse path never stages — its "misses" are the staged
/// tensor itself). Staging changes only *how the moved bytes are
/// priced*, never which rows are read or what lands in `x`, so logits
/// are bit-identical with staging on or off; hit/miss event counts are
/// identical too. A `stage@B` fault degrades that batch to the per-row
/// charges (byte-identical `x`, `staged_fallbacks` incremented).
///
/// `class` tags the tracker's node-visit records with the batch's
/// admission class (the multi-tenant refresh input — see
/// `cache::refresh`); it changes nothing else, and offline paths pass
/// [`TenantClass::Standard`].
///
/// Returns the stage's transfer ledger, wall ns, and the input-node
/// count.
#[allow(clippy::too_many_arguments)]
pub fn gather_stage(
    ds: &Dataset,
    view: &ShardView<'_>,
    inter_batch_reuse: bool,
    cost: &CostModel,
    mb: &MiniBatch,
    prev_inputs: &mut HashSet<NodeId>,
    x: &mut Vec<f32>,
    tracker: Option<&dyn WorkloadTracker>,
    class: TenantClass,
    staged: Option<StagedGather<'_>>,
) -> (TransferLedger, f64, usize) {
    let dim = ds.features.dim();
    let row_bytes = ds.features.row_bytes();
    let txns = row_txns(row_bytes, cost);
    let inputs = mb.input_nodes();
    let staged = if inter_batch_reuse { None } else { staged };
    // reuse capacity without zero-filling: every row is appended
    // exactly once below (debug-asserted), so the resize + overwrite
    // of the old path was pure waste
    x.clear();
    x.reserve(inputs.len() * dim);
    // staged mode defers miss charges: row ids collect here and become
    // one coalesced plan after the loop
    let mut miss_rows: Vec<u64> = Vec::new();

    let mut ledger = TransferLedger::new();
    ledger.launch();
    let t0 = Instant::now();
    if inter_batch_reuse {
        // RAIN: rows resident from the previous batch are free
        for &v in inputs {
            x.extend_from_slice(ds.features.row(v));
            if prev_inputs.contains(&v) {
                ledger.hit(row_bytes);
            } else {
                ledger.miss(row_bytes, txns);
            }
        }
    } else if view.has_feat_cache() {
        for &v in inputs {
            if let Some(row) = view.feat_lookup(v) {
                x.extend_from_slice(row);
                ledger.hit(row_bytes);
            } else {
                x.extend_from_slice(ds.features.row(v));
                if staged.is_some() {
                    miss_rows.push(v as u64);
                } else {
                    ledger.miss(row_bytes, txns);
                }
            }
        }
    } else {
        for &v in inputs {
            x.extend_from_slice(ds.features.row(v));
            if staged.is_some() {
                miss_rows.push(v as u64);
            } else {
                ledger.miss(row_bytes, txns);
            }
        }
    }
    // coalescing is part of the staged copy's real coordination work,
    // so it stays inside the timed section
    if let Some(sg) = staged {
        if !miss_rows.is_empty() {
            let fail = sg.fault.is_some_and(|f| f.staged_copy_error(sg.batch_index));
            if fail {
                // degraded mode: the staged copy errored after the rows
                // were already gathered — re-issue them as the per-row
                // UVA charges the non-staged path would have recorded
                for _ in 0..miss_rows.len() {
                    ledger.miss(row_bytes, txns);
                }
                ledger.staged_fallback();
            } else {
                let events = miss_rows.len() as u64;
                let plan = CopyPlan::coalesce(&mut miss_rows, row_bytes);
                debug_assert!(plan.is_partition());
                // miss *events* (pre-dedup) keep hit-ratio parity with
                // the per-row path; bytes move once per distinct row
                ledger.staged(events, plan.total_bytes(), plan.n_copies());
            }
        }
    }
    let wall_ns = t0.elapsed().as_nanos() as f64;
    debug_assert_eq!(
        x.len(),
        inputs.len() * dim,
        "gather must write every input row exactly once"
    );

    // online-refresh input (off the timed section: the tracker is
    // bookkeeping, not simulated transfer work; one virtual call for
    // the whole slice, not one per node)
    if let Some(t) = tracker {
        t.record_nodes_as(class, inputs);
    }

    if inter_batch_reuse {
        prev_inputs.clear();
        prev_inputs.extend(inputs.iter().copied());
    }
    (ledger, wall_ns, inputs.len())
}

/// Output of the compute stage for one mini-batch.
pub struct ComputedBatch {
    /// Logits (`None` when compute=skip).
    pub logits: Option<Vec<f32>>,
    /// Modeled transfer + (for compute=skip) modeled GPU execution ns.
    pub modeled_ns: f64,
    pub wall_ns: f64,
}

/// Stage 3: block-tensor upload accounting + model execution.
pub fn compute_stage(
    compute: &mut Compute,
    cfg: &RunConfig,
    classes: usize,
    feat_dim: usize,
    mb: &MiniBatch,
    x: &[f32],
) -> Result<ComputedBatch> {
    let mut ledger = TransferLedger::new();
    ledger.launch();
    // block tensors (idx + mask) upload
    let block_bytes: u64 = mb
        .layers
        .iter()
        .map(|b| (b.idx.len() * 4 + b.mask.len() * 4) as u64)
        .sum();
    ledger.upload(block_bytes);
    let t0 = Instant::now();
    let logits = compute.run(cfg.model, x, feat_dim, mb)?;
    let mut modeled_ns = ledger.modeled_ns(&cfg.cost);
    if matches!(compute, Compute::Skip) {
        // charge the modeled GPU execution time instead
        modeled_ns += cfg
            .cost
            .compute_ns(model_flops(cfg.model, mb, feat_dim, cfg.hidden, classes));
    }
    Ok(ComputedBatch { logits, modeled_ns, wall_ns: t0.elapsed().as_nanos() as f64 })
}
