//! Inference engine: the three-stage pipeline (sampling → feature
//! loading → computation) the paper decomposes in Fig. 1, over any of
//! the five prepared systems.
//!
//! Every stage accumulates *measured wall time* plus *modeled transfer
//! time* (see `crate::mem`); reports keep the two separate so benches
//! can show both and EXPERIMENTS.md can discuss the substitution.

use std::collections::HashSet;
use std::time::Instant;

use anyhow::{Context, Result};

use crate::baselines::{self, PreparedSystem};
use crate::cache::CacheStats;
use crate::config::{RunConfig, SystemKind};
use crate::graph::{datasets, Dataset, NodeId};
use crate::mem::{DeviceMemory, TransferLedger, PAPER_RESERVE_BYTES};
use crate::runtime::Compute;
use crate::sampler::{presample::row_txns, seed_batches, NeighborSampler, UvaAdj};
use crate::util::Rng;

/// Wall + modeled time of one pipeline stage.
#[derive(Debug, Clone, Copy, Default, PartialEq)]
pub struct StageTimes {
    pub wall_ns: f64,
    pub modeled_ns: f64,
}

impl StageTimes {
    pub fn total_ns(&self) -> f64 {
        self.wall_ns + self.modeled_ns
    }

    pub fn add(&mut self, wall_ns: f64, modeled_ns: f64) {
        self.wall_ns += wall_ns;
        self.modeled_ns += modeled_ns;
    }
}

/// Result of one full inference run.
#[derive(Debug, Clone)]
pub struct InferenceReport {
    pub system: SystemKind,
    pub preprocess_ns: f64,
    pub sample: StageTimes,
    pub feature: StageTimes,
    pub compute: StageTimes,
    pub stats: CacheStats,
    pub n_batches: usize,
    pub n_seeds: usize,
    /// Total input-node feature loads (Table I's Loaded-nodes).
    pub loaded_nodes: u64,
    /// Device bytes occupied by caches.
    pub cache_bytes: u64,
    /// Eq. (1) split actually applied (if the system allocates one).
    pub alloc: Option<crate::cache::CacheAllocation>,
    /// Simulated CUDA OOM (RAIN on papers100m-sim — Table V).
    pub oom: Option<String>,
    /// Σ|logits| over all executed batches (sanity; 0 when compute=skip).
    pub logits_checksum: f64,
}

impl InferenceReport {
    /// End-to-end inference time (the Fig. 7/8/Table V number —
    /// preprocessing excluded, as in §V.B).
    pub fn total_ns(&self) -> f64 {
        self.sample.total_ns() + self.feature.total_ns() + self.compute.total_ns()
    }

    /// Mini-batch preparation time (sampling + loading — Fig. 1).
    pub fn prep_ns(&self) -> f64 {
        self.sample.total_ns() + self.feature.total_ns()
    }

    /// **Simulated** preparation time: modeled transfer only. This is
    /// the RTX-4090-comparable number the benches report — the wall
    /// component is the *simulator's own* CPU cost (the gather/sampling
    /// work a real deployment runs on the GPU), whose run-to-run noise
    /// would otherwise wash out the transfer deltas the paper measures.
    /// See DESIGN.md §Substitutions and EXPERIMENTS.md §Calibration.
    pub fn sim_prep_ns(&self) -> f64 {
        self.sample.modeled_ns + self.feature.modeled_ns
    }

    /// Simulated end-to-end time: modeled preparation + real compute
    /// (the compute stage runs the actual AOT model, identical across
    /// systems).
    pub fn sim_total_ns(&self) -> f64 {
        self.sim_prep_ns() + self.compute.total_ns()
    }

    /// Fraction of total time spent preparing mini-batches (Fig. 1's
    /// 56–92% observation).
    pub fn prep_fraction(&self) -> f64 {
        let t = self.total_ns();
        if t == 0.0 {
            0.0
        } else {
            self.prep_ns() / t
        }
    }
}

/// Modeled FLOP count of one mini-batch forward pass (gather-aggregate
/// + dense transforms, per Table III's 3-layer models). Used to charge
/// a modeled GPU compute time when the compute stage is skipped so
/// end-to-end simulated totals exist for every configuration.
pub fn model_flops(
    model: crate::config::ModelKind,
    mb: &crate::sampler::MiniBatch,
    feat_dim: usize,
    hidden: usize,
    classes: usize,
) -> f64 {
    let l = mb.layers.len();
    let mut flops = 0.0;
    for (i, blk) in mb.layers.iter().enumerate() {
        let d_in = if i == 0 { feat_dim } else { hidden };
        let d_out = if i == l - 1 { classes } else { hidden };
        // gather + masked aggregate
        flops += (blk.n_dst * blk.k * d_in * 2) as f64;
        // dense transform(s)
        let mats = if model == crate::config::ModelKind::GraphSage { 2 } else { 1 };
        flops += (blk.n_dst * d_in * d_out * 2 * mats) as f64;
    }
    flops
}

/// The single-process inference pipeline.
pub struct InferenceEngine<'d> {
    pub ds: &'d Dataset,
    pub cfg: RunConfig,
    pub prepared: PreparedSystem,
    pub device: DeviceMemory,
    compute: Compute,
    rng: Rng,
}

impl<'d> InferenceEngine<'d> {
    /// Build the device, run the system's preprocessing, claim cache
    /// memory, and construct the compute backend.
    pub fn prepare(ds: &'d Dataset, cfg: RunConfig) -> Result<InferenceEngine<'d>> {
        let mut device = match cfg.device_capacity {
            Some(cap) => DeviceMemory::new(cap, (cap / 24).min(PAPER_RESERVE_BYTES)),
            None => DeviceMemory::rtx4090_scaled(ds.spec.scale),
        };
        let mut rng = Rng::new(cfg.seed);
        let prepared = baselines::prepare(ds, &cfg, &device, &cfg.cost, &mut rng)?;
        device
            .alloc(prepared.cache_bytes())
            .context("cache fill exceeds simulated device memory")?;
        let compute = Compute::build(
            cfg.compute,
            cfg.model,
            ds.features.dim(),
            cfg.hidden,
            ds.spec.classes,
            &cfg.artifacts_dir,
        )?;
        Ok(InferenceEngine { ds, cfg, prepared, device, compute, rng })
    }

    /// Build an engine around an externally prepared system (ablation
    /// studies that hand-craft cache splits).
    pub fn with_prepared(
        ds: &'d Dataset,
        cfg: RunConfig,
        prepared: PreparedSystem,
    ) -> Result<InferenceEngine<'d>> {
        let mut device = match cfg.device_capacity {
            Some(cap) => DeviceMemory::new(cap, (cap / 24).min(PAPER_RESERVE_BYTES)),
            None => DeviceMemory::rtx4090_scaled(ds.spec.scale),
        };
        device
            .alloc(prepared.cache_bytes())
            .context("cache fill exceeds simulated device memory")?;
        let compute = Compute::build(
            cfg.compute,
            cfg.model,
            ds.features.dim(),
            cfg.hidden,
            ds.spec.classes,
            &cfg.artifacts_dir,
        )?;
        let rng = Rng::new(cfg.seed.wrapping_add(1));
        Ok(InferenceEngine { ds, cfg, prepared, device, compute, rng })
    }

    /// Run inference over the full test set (or `max_batches`).
    pub fn run(&mut self) -> Result<InferenceReport> {
        // own the seed batches so `run_batches` can borrow self mutably
        let owned: Vec<Vec<NodeId>> = match &self.prepared.batch_order {
            Some((ordered, _)) => ordered.clone(),
            None => seed_batches(&self.ds.test_nodes, self.cfg.batch_size)
                .into_iter()
                .map(|b| b.to_vec())
                .collect(),
        };
        let views: Vec<&[NodeId]> = owned.iter().map(|b| b.as_slice()).collect();
        self.run_batches(&views)
    }

    fn run_batches(&mut self, batches: &[&[NodeId]]) -> Result<InferenceReport> {
        let n = self
            .cfg
            .max_batches
            .map(|m| m.min(batches.len()))
            .unwrap_or(batches.len());
        let clusters: Option<&[usize]> =
            self.prepared.batch_order.as_ref().map(|(_, c)| c.as_slice());

        let mut sampler =
            NeighborSampler::with_nodes(self.cfg.fanout.clone(), self.ds.csc.n_nodes());
        let dim = self.ds.features.dim();
        let row_bytes = self.ds.features.row_bytes();
        let txns = row_txns(row_bytes, &self.cfg.cost);

        let mut report = InferenceReport {
            system: self.prepared.kind,
            preprocess_ns: self.prepared.preprocess_ns,
            sample: StageTimes::default(),
            feature: StageTimes::default(),
            compute: StageTimes::default(),
            stats: CacheStats::new(),
            n_batches: 0,
            n_seeds: 0,
            loaded_nodes: 0,
            cache_bytes: self.prepared.cache_bytes(),
            alloc: self.prepared.alloc,
            oom: None,
            logits_checksum: 0.0,
        };

        // RAIN stages the entire node-feature tensor in device memory to
        // enable cross-batch reuse (the paper's Table V observes exactly
        // this: a 52.96 GB allocation attempt on Ogbn-papers100M ≈
        // 111M × 128 × 4 B). If it does not fit, RAIN fails up front.
        let mut rain_claim = 0u64;
        if self.prepared.inter_batch_reuse {
            let need = self.ds.features.bytes_total();
            if let Err(e) = self.device.alloc_unreserved(need) {
                report.oom = Some(e.to_string());
                return Ok(report);
            }
            rain_claim = need;
        }
        // previous batch's inputs (the LSH ordering makes consecutive
        // batches similar; reuse rate = overlap with the previous batch)
        let mut prev_inputs: HashSet<NodeId> = HashSet::new();
        let _ = clusters; // cluster ids grouped the order at prepare time

        let mut x: Vec<f32> = Vec::new();

        for bi in 0..n {
            let seeds = batches[bi];

            // ---- stage 1: sampling -------------------------------------
            let mut s_ledger = TransferLedger::new();
            let t0 = Instant::now();
            let mb = match &self.prepared.adj_cache {
                Some(c) => sampler.sample_batch(
                    &c.source(&self.ds.csc),
                    seeds,
                    &mut self.rng,
                    &mut s_ledger,
                ),
                None => sampler.sample_batch(
                    &UvaAdj { csc: &self.ds.csc },
                    seeds,
                    &mut self.rng,
                    &mut s_ledger,
                ),
            };
            report
                .sample
                .add(t0.elapsed().as_nanos() as f64, s_ledger.modeled_ns(&self.cfg.cost));
            report.stats.sample.merge(&s_ledger);

            // ---- stage 2: feature loading ------------------------------
            let inputs = mb.input_nodes();
            report.loaded_nodes += inputs.len() as u64;
            x.clear();
            x.resize(inputs.len() * dim, 0.0);
            let mut f_ledger = TransferLedger::new();
            f_ledger.launch();
            let t0 = Instant::now();
            if self.prepared.inter_batch_reuse {
                // RAIN: rows resident from the previous batch are free
                for (i, &v) in inputs.iter().enumerate() {
                    let out = &mut x[i * dim..(i + 1) * dim];
                    self.ds.features.copy_row_into(v, out);
                    if prev_inputs.contains(&v) {
                        f_ledger.hit(row_bytes);
                    } else {
                        f_ledger.miss(row_bytes, txns);
                    }
                }
            } else if let Some(cache) = &self.prepared.feat_cache {
                for (i, &v) in inputs.iter().enumerate() {
                    let out = &mut x[i * dim..(i + 1) * dim];
                    if let Some(row) = cache.lookup(v) {
                        out.copy_from_slice(row);
                        f_ledger.hit(row_bytes);
                    } else {
                        self.ds.features.copy_row_into(v, out);
                        f_ledger.miss(row_bytes, txns);
                    }
                }
            } else {
                for (i, &v) in inputs.iter().enumerate() {
                    self.ds.features.copy_row_into(v, &mut x[i * dim..(i + 1) * dim]);
                    f_ledger.miss(row_bytes, txns);
                }
            }
            report
                .feature
                .add(t0.elapsed().as_nanos() as f64, f_ledger.modeled_ns(&self.cfg.cost));
            report.stats.feature.merge(&f_ledger);

            if self.prepared.inter_batch_reuse {
                prev_inputs = inputs.iter().copied().collect();
            }

            // ---- stage 3: computation ----------------------------------
            let mut c_ledger = TransferLedger::new();
            c_ledger.launch();
            // block tensors (idx + mask) upload
            let block_bytes: u64 = mb
                .layers
                .iter()
                .map(|b| (b.idx.len() * 4 + b.mask.len() * 4) as u64)
                .sum();
            c_ledger.upload(block_bytes);
            let t0 = Instant::now();
            let logits = self
                .compute
                .run(self.cfg.model, &x, dim, &mb)
                .with_context(|| format!("compute failed on batch {bi}"))?;
            let mut modeled = c_ledger.modeled_ns(&self.cfg.cost);
            if matches!(self.compute, Compute::Skip) {
                // charge the modeled GPU execution time instead
                modeled += self.cfg.cost.compute_ns(model_flops(
                    self.cfg.model, &mb, dim, self.cfg.hidden, self.ds.spec.classes,
                ));
            }
            report
                .compute
                .add(t0.elapsed().as_nanos() as f64, modeled);
            if let Some(l) = logits {
                report.logits_checksum += l.iter().map(|v| v.abs() as f64).sum::<f64>();
            }

            report.n_batches += 1;
            report.n_seeds += seeds.len();
        }

        // release RAIN's staged feature tensor
        self.device.free(rain_claim);
        Ok(report)
    }
}

/// Output of a single served batch (the coordinator's unit of work).
#[derive(Debug, Clone)]
pub struct BatchOutput {
    pub logits: Option<Vec<f32>>,
    pub sample: StageTimes,
    pub feature: StageTimes,
    pub compute: StageTimes,
    pub n_inputs: usize,
}

impl<'d> InferenceEngine<'d> {
    /// Serve one batch of seed nodes (the coordinator's request path).
    /// RAIN's cluster-stateful mode is not servable this way.
    pub fn infer_once(&mut self, seeds: &[NodeId]) -> Result<BatchOutput> {
        anyhow::ensure!(
            !self.prepared.inter_batch_reuse,
            "RAIN's batch-stateful mode cannot serve ad-hoc requests"
        );
        let mut sampler =
            NeighborSampler::with_nodes(self.cfg.fanout.clone(), self.ds.csc.n_nodes());
        let dim = self.ds.features.dim();
        let row_bytes = self.ds.features.row_bytes();
        let txns = row_txns(row_bytes, &self.cfg.cost);

        // sample
        let mut s_ledger = TransferLedger::new();
        let t0 = Instant::now();
        let mb = match &self.prepared.adj_cache {
            Some(c) => sampler.sample_batch(&c.source(&self.ds.csc), seeds,
                                            &mut self.rng, &mut s_ledger),
            None => sampler.sample_batch(&UvaAdj { csc: &self.ds.csc }, seeds,
                                         &mut self.rng, &mut s_ledger),
        };
        let sample = StageTimes {
            wall_ns: t0.elapsed().as_nanos() as f64,
            modeled_ns: s_ledger.modeled_ns(&self.cfg.cost),
        };

        // gather
        let inputs = mb.input_nodes();
        let mut x = vec![0.0f32; inputs.len() * dim];
        let mut f_ledger = TransferLedger::new();
        f_ledger.launch();
        let t0 = Instant::now();
        if let Some(cache) = &self.prepared.feat_cache {
            for (i, &v) in inputs.iter().enumerate() {
                let out = &mut x[i * dim..(i + 1) * dim];
                if let Some(row) = cache.lookup(v) {
                    out.copy_from_slice(row);
                    f_ledger.hit(row_bytes);
                } else {
                    self.ds.features.copy_row_into(v, out);
                    f_ledger.miss(row_bytes, txns);
                }
            }
        } else {
            for (i, &v) in inputs.iter().enumerate() {
                self.ds.features.copy_row_into(v, &mut x[i * dim..(i + 1) * dim]);
                f_ledger.miss(row_bytes, txns);
            }
        }
        let feature = StageTimes {
            wall_ns: t0.elapsed().as_nanos() as f64,
            modeled_ns: f_ledger.modeled_ns(&self.cfg.cost),
        };

        // compute
        let mut c_ledger = TransferLedger::new();
        c_ledger.launch();
        let block_bytes: u64 = mb
            .layers
            .iter()
            .map(|b| (b.idx.len() * 4 + b.mask.len() * 4) as u64)
            .sum();
        c_ledger.upload(block_bytes);
        let t0 = Instant::now();
        let logits = self.compute.run(self.cfg.model, &x, dim, &mb)?;
        let mut modeled = c_ledger.modeled_ns(&self.cfg.cost);
        if matches!(self.compute, Compute::Skip) {
            modeled += self.cfg.cost.compute_ns(model_flops(
                self.cfg.model, &mb, dim, self.cfg.hidden, self.ds.spec.classes,
            ));
        }
        let compute = StageTimes {
            wall_ns: t0.elapsed().as_nanos() as f64,
            modeled_ns: modeled,
        };

        Ok(BatchOutput { logits, sample, feature, compute, n_inputs: inputs.len() })
    }
}

/// Convenience: build the dataset named by `cfg`, prepare, and run.
pub fn run_config(cfg: &RunConfig) -> Result<InferenceReport> {
    let ds = datasets::spec(&cfg.dataset)?.build();
    let mut engine = InferenceEngine::prepare(&ds, cfg.clone())?;
    engine.run()
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::config::ComputeKind;
    use crate::sampler::Fanout;

    fn tiny_cfg(system: SystemKind) -> RunConfig {
        let mut cfg = RunConfig::default();
        cfg.dataset = "tiny".into();
        cfg.system = system;
        cfg.batch_size = 64;
        cfg.fanout = Fanout::parse("3,2,2").unwrap();
        cfg.budget = Some(300_000);
        cfg.max_batches = Some(6);
        cfg.compute = ComputeKind::Skip;
        cfg
    }

    fn run(system: SystemKind) -> InferenceReport {
        let ds = datasets::spec("tiny").unwrap().build();
        let mut e = InferenceEngine::prepare(&ds, tiny_cfg(system)).unwrap();
        e.run().unwrap()
    }

    #[test]
    fn dgl_all_misses() {
        let r = run(SystemKind::Dgl);
        assert_eq!(r.n_batches, 6);
        assert_eq!(r.stats.feature.hits, 0);
        assert_eq!(r.stats.sample.hits, 0);
        assert!(r.stats.feature.misses > 0);
        assert_eq!(r.preprocess_ns, 0.0);
        assert!(r.prep_fraction() > 0.9); // compute skipped
    }

    #[test]
    fn dci_hits_both_caches_and_beats_dgl() {
        let dgl = run(SystemKind::Dgl);
        let dci = run(SystemKind::Dci);
        assert!(dci.stats.feature.hits > 0, "feature cache must hit");
        assert!(dci.stats.sample.hits > 0, "adjacency cache must hit");
        // compare modeled transfer time: deterministic, and the quantity
        // the caches actually optimize (wall noise on the tiny dataset
        // can exceed the win)
        let dci_m = dci.sample.modeled_ns + dci.feature.modeled_ns;
        let dgl_m = dgl.sample.modeled_ns + dgl.feature.modeled_ns;
        assert!(dci_m < dgl_m, "DCI modeled {dci_m:.0} should beat DGL {dgl_m:.0}");
        assert!(dci.alloc.is_some());
    }

    #[test]
    fn sci_beats_dgl_but_not_dci() {
        let dgl = run(SystemKind::Dgl);
        let sci = run(SystemKind::Sci);
        let dci = run(SystemKind::Dci);
        assert!(sci.stats.feature.hits > 0);
        assert_eq!(sci.stats.sample.hits, 0, "SCI has no adjacency cache");
        let m = |r: &InferenceReport| r.sample.modeled_ns + r.feature.modeled_ns;
        assert!(m(&sci) < m(&dgl), "SCI {:.0} beats DGL {:.0}", m(&sci), m(&dgl));
        assert!(m(&dci) < m(&sci),
                "dual cache {:.0} beats single cache {:.0}", m(&dci), m(&sci));
    }

    #[test]
    fn rain_reuses_across_batches() {
        let r = run(SystemKind::Rain);
        assert!(r.stats.feature.hits > 0, "inter-batch reuse should hit");
        assert!(r.oom.is_none());
        assert_eq!(r.n_batches, 6);
    }

    #[test]
    fn rain_ooms_on_small_device() {
        let ds = datasets::spec("tiny").unwrap().build();
        let mut cfg = tiny_cfg(SystemKind::Rain);
        cfg.max_batches = None;
        cfg.device_capacity = Some(40_000); // ~500 rows of 64B + overhead
        let mut e = InferenceEngine::prepare(&ds, cfg).unwrap();
        let r = e.run().unwrap();
        assert!(r.oom.is_some(), "expected simulated CUDA OOM");
        assert!(r.oom.unwrap().contains("CUDA out of memory"));
    }

    #[test]
    fn ducati_close_to_dci_steady_state() {
        let dci = run(SystemKind::Dci);
        let ducati = run(SystemKind::Ducati);
        assert!(ducati.stats.feature.hits > 0);
        // preprocessing gap is the point (Fig. 10); on `tiny` DUCATI's
        // 8x profiling request is capped by the 15 available batches,
        // so the honest ratio floor here is ~1.5x (full-size benches
        // show the paper's 5-10x)
        assert!(ducati.preprocess_ns > 1.4 * dci.preprocess_ns,
                "DUCATI {:.0} vs DCI {:.0}", ducati.preprocess_ns, dci.preprocess_ns);
    }

    #[test]
    fn reference_compute_runs_and_checksums() {
        let ds = datasets::spec("tiny").unwrap().build();
        let mut cfg = tiny_cfg(SystemKind::Dci);
        cfg.compute = ComputeKind::Reference;
        cfg.hidden = 16;
        let mut e = InferenceEngine::prepare(&ds, cfg).unwrap();
        let r = e.run().unwrap();
        assert!(r.logits_checksum > 0.0);
        assert!(r.compute.wall_ns > 0.0);
        assert_eq!(r.n_seeds, 6 * 64);
    }

    #[test]
    fn deterministic_given_seed() {
        // sampling and adjacency caching are bit-deterministic; the
        // Eq. (1) split depends on *measured* stage times (as in the
        // paper), so the feature cache contents may wobble slightly —
        // DGL (no time-dependent decisions) must be fully deterministic.
        let a = run(SystemKind::Dci);
        let b = run(SystemKind::Dci);
        assert_eq!(a.loaded_nodes, b.loaded_nodes);
        assert_eq!(a.stats.sample.hits, b.stats.sample.hits);
        let da = run(SystemKind::Dgl);
        let db = run(SystemKind::Dgl);
        assert_eq!(da.loaded_nodes, db.loaded_nodes);
        assert_eq!(da.stats.feature.misses, db.stats.feature.misses);
    }

    #[test]
    fn run_config_convenience() {
        let mut cfg = tiny_cfg(SystemKind::Dci);
        cfg.max_batches = Some(2);
        let r = run_config(&cfg).unwrap();
        assert_eq!(r.n_batches, 2);
    }
}
