//! Inference engine: the three-stage pipeline (sampling → feature
//! loading → computation) the paper decomposes in Fig. 1, over any of
//! the five prepared systems.
//!
//! The stage bodies live in [`stages`] and are shared by three
//! schedulers: the serial batch loop (`pipeline_depth = 1`), the
//! overlapped pipeline executor in [`pipeline`] (`pipeline_depth > 1`,
//! bit-identical results — see the pipeline equivalence tests), and the
//! coordinator's per-request path ([`InferenceEngine::infer_once`]).
//!
//! Every stage accumulates *measured wall time* plus *modeled transfer
//! time* (see `crate::mem`); reports keep the two separate so benches
//! can show both and EXPERIMENTS.md can discuss the substitution.

pub mod pipeline;
pub mod stages;
pub mod transfer;

use std::collections::HashSet;
use std::sync::Arc;
use std::time::Instant;

use anyhow::{Context, Result};

use crate::baselines::{self, PreparedSystem};
use crate::cache::shard::{ShardedHandle, ShardedRuntime};
use crate::cache::tracker::WorkloadTracker;
use crate::cache::CacheStats;
use crate::config::{RunConfig, SystemKind};
use crate::coordinator::admission::TenantClass;
use crate::graph::{datasets, Dataset, GraphHandle, LiveGraph, NodeId};
use crate::mem::{DeviceGroup, DeviceMemory, StagingPool, StagingStats, PAPER_RESERVE_BYTES};
use crate::runtime::Compute;
use crate::sampler::{seed_batches, SamplerPool};
use crate::util::{FaultPlan, Rng};

use self::transfer::TransferSim;

/// Wall + modeled time of one pipeline stage.
#[derive(Debug, Clone, Copy, Default, PartialEq)]
pub struct StageTimes {
    pub wall_ns: f64,
    pub modeled_ns: f64,
}

impl StageTimes {
    pub fn total_ns(&self) -> f64 {
        self.wall_ns + self.modeled_ns
    }

    pub fn add(&mut self, wall_ns: f64, modeled_ns: f64) {
        self.wall_ns += wall_ns;
        self.modeled_ns += modeled_ns;
    }
}

/// Result of one full inference run.
#[derive(Debug, Clone)]
pub struct InferenceReport {
    pub system: SystemKind,
    pub preprocess_ns: f64,
    pub sample: StageTimes,
    pub feature: StageTimes,
    pub compute: StageTimes,
    pub stats: CacheStats,
    pub n_batches: usize,
    pub n_seeds: usize,
    /// Total input-node feature loads (Table I's Loaded-nodes).
    pub loaded_nodes: u64,
    /// Device bytes occupied by caches.
    pub cache_bytes: u64,
    /// Eq. (1) split actually applied (if the system allocates one).
    pub alloc: Option<crate::cache::CacheAllocation>,
    /// Simulated CUDA OOM (RAIN on papers100m-sim — Table V).
    pub oom: Option<String>,
    /// Σ|logits| over all executed batches (sanity; 0 when compute=skip).
    pub logits_checksum: f64,
    /// Batches re-run after an isolated worker panic (pipeline panic
    /// isolation — each batch is retried once before erroring).
    pub batch_retries: u64,
    /// Wall time of the whole batch loop (serial or pipelined). Under
    /// the pipeline this is what shrinks while the per-stage `wall_ns`
    /// sums (stage *busy* time) stay put — their ratio is occupancy.
    pub run_wall_ns: f64,
    /// Modeled ns of coalesced staged H2D copies (0 when `transfer-ring`
    /// is off — misses are then priced per-row inside `feature`).
    pub transfer_staged_ns: f64,
    /// Staged ns the transfer ring hid under earlier batches' compute
    /// on the modeled timeline (`TransferSim`).
    pub transfer_hidden_ns: f64,
    /// Staging-pool lease/return counters (`None` when staging is off).
    pub staging: Option<StagingStats>,
}

impl InferenceReport {
    /// End-to-end inference time (the Fig. 7/8/Table V number —
    /// preprocessing excluded, as in §V.B).
    pub fn total_ns(&self) -> f64 {
        self.sample.total_ns() + self.feature.total_ns() + self.compute.total_ns()
    }

    /// Mini-batch preparation time (sampling + loading — Fig. 1).
    pub fn prep_ns(&self) -> f64 {
        self.sample.total_ns() + self.feature.total_ns()
    }

    /// **Simulated** preparation time: modeled transfer only. This is
    /// the RTX-4090-comparable number the benches report — the wall
    /// component is the *simulator's own* CPU cost (the gather/sampling
    /// work a real deployment runs on the GPU), whose run-to-run noise
    /// would otherwise wash out the transfer deltas the paper measures.
    /// See DESIGN.md §Substitutions and EXPERIMENTS.md §Calibration.
    pub fn sim_prep_ns(&self) -> f64 {
        self.sample.modeled_ns + self.feature.modeled_ns
    }

    /// Simulated end-to-end time: modeled preparation + real compute
    /// (the compute stage runs the actual AOT model, identical across
    /// systems).
    pub fn sim_total_ns(&self) -> f64 {
        self.sim_prep_ns() + self.compute.total_ns()
    }

    /// Fraction of total time spent preparing mini-batches (Fig. 1's
    /// 56–92% observation).
    pub fn prep_fraction(&self) -> f64 {
        let t = self.total_ns();
        if t == 0.0 {
            0.0
        } else {
            self.prep_ns() / t
        }
    }

    /// Stage busy time as a fraction of the run's wall time. Under the
    /// pipelined executor the sampling stage can exceed 1.0 (several
    /// workers sampling concurrently); the serial loop's stages sum to
    /// ~1.0 by construction.
    pub fn occupancy(&self, stage: &StageTimes) -> f64 {
        if self.run_wall_ns == 0.0 {
            0.0
        } else {
            stage.wall_ns / self.run_wall_ns
        }
    }

    /// Fraction of the modeled staged H2D that the transfer ring hid
    /// under compute (0 when nothing was staged; 0 at `transfer-ring=1`
    /// by construction — one slot is the serial timeline).
    pub fn transfer_occupancy(&self) -> f64 {
        if self.transfer_staged_ns == 0.0 {
            0.0
        } else {
            self.transfer_hidden_ns / self.transfer_staged_ns
        }
    }

    /// Simulated end-to-end time with the ring's overlap credited:
    /// [`InferenceReport::sim_total_ns`] minus the staged ns hidden
    /// under compute. Equals `sim_total_ns()` when staging is off or
    /// the ring is 1.
    pub fn sim_total_overlapped_ns(&self) -> f64 {
        self.sim_total_ns() - self.transfer_hidden_ns
    }
}

/// Modeled FLOP count of one mini-batch forward pass (gather-aggregate
/// + dense transforms, per Table III's 3-layer models). Used to charge
/// a modeled GPU compute time when the compute stage is skipped so
/// end-to-end simulated totals exist for every configuration.
pub fn model_flops(
    model: crate::config::ModelKind,
    mb: &crate::sampler::MiniBatch,
    feat_dim: usize,
    hidden: usize,
    classes: usize,
) -> f64 {
    let l = mb.layers.len();
    let mut flops = 0.0;
    for (i, blk) in mb.layers.iter().enumerate() {
        let d_in = if i == 0 { feat_dim } else { hidden };
        let d_out = if i == l - 1 { classes } else { hidden };
        // gather + masked aggregate
        flops += (blk.n_dst * blk.k * d_in * 2) as f64;
        // dense transform(s)
        let mats = if model == crate::config::ModelKind::GraphSage { 2 } else { 1 };
        flops += (blk.n_dst * d_in * d_out * 2 * mats) as f64;
    }
    flops
}

/// The single-process inference pipeline.
pub struct InferenceEngine<'d> {
    pub ds: &'d Dataset,
    pub cfg: RunConfig,
    pub prepared: PreparedSystem,
    /// One simulated device per cache shard; each shard's snapshot is
    /// claimed against the device that holds it. Shared (`Arc`) with
    /// the background refresh loop, which accounts every hot-swap
    /// install against the owning device in claim-before-release
    /// order — see `cache::refresh`.
    pub device: Arc<DeviceGroup>,
    compute: Compute,
    /// Shared sampler scratch: serial runs, pipeline workers, and
    /// served requests all check samplers out of here instead of
    /// allocating two O(n_nodes) arrays per use.
    pool: SamplerPool,
    /// Requests served via `infer_once` (indexes their RNG streams).
    served: u64,
    /// Reused gather buffer for the serving path.
    x_buf: Vec<f32>,
    /// This thread's cursor over every shard's cache epochs (serial
    /// loop + serving path; pipeline workers make their own).
    snap: ShardedHandle,
    /// Serving-time access counts for the online refresh loop
    /// (`None` = untracked: offline runs, refresh disabled).
    tracker: Option<Arc<dyn WorkloadTracker>>,
    /// Deterministic fault schedule parsed from `cfg.fault` (`None` =
    /// no faults; the injection sites cost one pointer null-check).
    fault: Option<Arc<FaultPlan>>,
    /// Pinned staging-buffer pool for the staged transfer path, sized
    /// from the presample peak claim (`cfg.staging_buffers` buffers of
    /// `max_input_nodes × dim` floats). Shared (`Arc`) with the
    /// pipeline's stage threads and the refresh loop's install fills.
    staging: Arc<StagingPool>,
    /// Persistent transfer-ring clock for the serving path (`None`
    /// when `transfer-ring` is off); batch runs use a fresh clock per
    /// run instead.
    serve_sim: Option<TransferSim>,
    /// This thread's cursor over the live graph's mutation epochs
    /// (`None` = frozen graph, the pre-live-mutation path bit for
    /// bit). Acquired once per batch alongside the cache snapshot;
    /// pipeline workers make their own handles from the shared
    /// [`LiveGraph`].
    graph: Option<GraphHandle>,
}

/// The per-device prototype arena `cfg` asks for (each shard of a
/// multi-device node gets its own copy).
fn proto_device(ds: &Dataset, cfg: &RunConfig) -> DeviceMemory {
    match cfg.device_capacity {
        Some(cap) => DeviceMemory::new(cap, (cap / 24).min(PAPER_RESERVE_BYTES)),
        None => DeviceMemory::rtx4090_scaled(ds.spec.scale),
    }
}

/// The (possibly tiered) device group for a prepared system: explicit
/// `device-tiers=` build a heterogeneous group (validated one tier per
/// shard here, where the shard count is finally known); otherwise the
/// uniform prototype is replicated.
fn device_group_for(
    proto: &DeviceMemory,
    cfg: &RunConfig,
    prepared: &PreparedSystem,
) -> Result<DeviceGroup> {
    let n = prepared.runtime.n_shards();
    match &cfg.device_tiers {
        Some(tiers) => {
            anyhow::ensure!(
                tiers.len() == n,
                "device-tiers lists {} device(s) but the run has {} shard(s) \
                 (one tier per shard)",
                tiers.len(),
                n
            );
            Ok(DeviceGroup::tiered(tiers))
        }
        None => Ok(DeviceGroup::replicate(proto, n)),
    }
}

/// Staging pool sized from the auto-budget claim inputs: each of the
/// `staging-buffers` buffers holds the largest presampled batch's
/// features (`max_input_nodes × dim` floats); systems with no
/// presample profile size on first use. The buffer count is floored at
/// the pipelined executor's maximum concurrent leases (`pipeline_depth
/// + transfer_ring + 2`: the gather→ring queue, the ring itself, and
/// one buffer in hand at each end) so steady state never falls off the
/// pinned pool into counted fresh allocations.
fn staging_pool_for(ds: &Dataset, cfg: &RunConfig, prepared: &PreparedSystem) -> StagingPool {
    let peak = prepared.presample.as_ref().map(|s| s.max_input_nodes).unwrap_or(0);
    let n = if cfg.transfer_ring >= 1 {
        cfg.staging_buffers.max(cfg.pipeline_depth + cfg.transfer_ring + 2)
    } else {
        cfg.staging_buffers
    };
    StagingPool::for_workload(n, peak, ds.features.dim())
}

/// Parse (and validate) the `fault=` knob into a shared plan.
fn parse_fault(cfg: &RunConfig) -> Result<Option<Arc<FaultPlan>>> {
    cfg.fault
        .as_deref()
        .map(|spec| FaultPlan::parse(spec).map(Arc::new))
        .transpose()
        .context("invalid fault= spec")
}

/// Claim each shard's snapshot against its own device.
fn claim_shards(device: &DeviceGroup, prepared: &PreparedSystem) -> Result<()> {
    for (i, snap) in prepared.runtime.snapshots().iter().enumerate() {
        device.alloc(i, snap.bytes_used()).with_context(|| {
            format!("shard {i} cache fill exceeds its simulated device memory")
        })?;
    }
    Ok(())
}

impl<'d> InferenceEngine<'d> {
    /// Build the devices (one per shard), run the system's
    /// preprocessing, claim each shard's cache memory on its own
    /// device, and construct the compute backend.
    pub fn prepare(ds: &'d Dataset, cfg: RunConfig) -> Result<InferenceEngine<'d>> {
        let fault = parse_fault(&cfg)?;
        let proto = proto_device(ds, &cfg);
        let mut rng = Rng::new(cfg.seed);
        let prepared = baselines::prepare(ds, &cfg, &proto, &cfg.cost, &mut rng)?;
        let device = Arc::new(device_group_for(&proto, &cfg, &prepared)?);
        claim_shards(&device, &prepared)?;
        let compute = Compute::build(
            cfg.compute,
            cfg.model,
            ds.features.dim(),
            cfg.hidden,
            ds.spec.classes,
            &cfg.artifacts_dir,
        )?;
        let pool = SamplerPool::new(cfg.fanout.clone(), ds.csc.n_nodes());
        let snap = ShardedHandle::new(&prepared.runtime);
        let staging = Arc::new(staging_pool_for(ds, &cfg, &prepared));
        let serve_sim = (cfg.transfer_ring >= 1).then(|| TransferSim::new(cfg.transfer_ring));
        Ok(InferenceEngine {
            ds,
            cfg,
            prepared,
            device,
            compute,
            pool,
            served: 0,
            x_buf: Vec::new(),
            snap,
            tracker: None,
            fault,
            staging,
            serve_sim,
            graph: None,
        })
    }

    /// Build an engine around an externally prepared system (ablation
    /// studies that hand-craft cache splits).
    pub fn with_prepared(
        ds: &'d Dataset,
        cfg: RunConfig,
        prepared: PreparedSystem,
    ) -> Result<InferenceEngine<'d>> {
        let fault = parse_fault(&cfg)?;
        let proto = proto_device(ds, &cfg);
        let device = Arc::new(device_group_for(&proto, &cfg, &prepared)?);
        claim_shards(&device, &prepared)?;
        let compute = Compute::build(
            cfg.compute,
            cfg.model,
            ds.features.dim(),
            cfg.hidden,
            ds.spec.classes,
            &cfg.artifacts_dir,
        )?;
        let pool = SamplerPool::new(cfg.fanout.clone(), ds.csc.n_nodes());
        let snap = ShardedHandle::new(&prepared.runtime);
        let staging = Arc::new(staging_pool_for(ds, &cfg, &prepared));
        let serve_sim = (cfg.transfer_ring >= 1).then(|| TransferSim::new(cfg.transfer_ring));
        Ok(InferenceEngine {
            ds,
            cfg,
            prepared,
            device,
            compute,
            pool,
            served: 0,
            x_buf: Vec::new(),
            snap,
            tracker: None,
            fault,
            staging,
            serve_sim,
            graph: None,
        })
    }

    /// The engine's swappable (possibly sharded) cache runtime — share
    /// it with a [`crate::cache::Refresher`] to re-plan online.
    pub fn runtime(&self) -> Arc<ShardedRuntime> {
        Arc::clone(&self.prepared.runtime)
    }

    /// The engine's per-shard device arenas — share them with a
    /// [`crate::cache::RefreshJob`] so hot-swap installs are accounted
    /// (claim-before-release) against the devices that actually hold
    /// the snapshots.
    pub fn device_group(&self) -> Arc<DeviceGroup> {
        Arc::clone(&self.device)
    }

    /// Attach a serving-time access tracker (dense or sketch — see
    /// `cache::tracker`): `infer_once` then records the same per-node
    /// / per-element counts pre-sampling collects, feeding the online
    /// refresh loop.
    pub fn set_tracker(&mut self, tracker: Arc<dyn WorkloadTracker>) {
        self.tracker = Some(tracker);
    }

    /// Attach a shared live graph (`graph.mutate=` serve runs): every
    /// subsequent batch samples base∪delta through the freshest epoch
    /// this thread can acquire without blocking, instead of the frozen
    /// preprocessing-time CSC. The dataset the engine was prepared on
    /// must be the graph's base (the overlay delegates prefix
    /// positions to the cached reads planned against it).
    pub fn set_live_graph(&mut self, graph: Arc<LiveGraph>) {
        self.graph = Some(GraphHandle::new(&graph));
    }

    /// The shared live graph, if one is attached (spawn per-thread
    /// handles from it; the mutation driver calls `mutate`/`compact`
    /// on it directly).
    pub fn live_graph(&self) -> Option<Arc<LiveGraph>> {
        self.graph.as_ref().map(|h| Arc::clone(h.live()))
    }

    /// The fault schedule parsed from `cfg.fault`, shared so the server
    /// can hand the same counted plan to the refresh loop — counts are
    /// consumed across *all* sites, keeping one spec one schedule.
    pub fn fault_plan(&self) -> Option<Arc<FaultPlan>> {
        self.fault.clone()
    }

    /// The engine's pinned staging pool — share it with a
    /// [`crate::cache::RefreshJob`] so hot-swap install fills reuse the
    /// same leased buffers (and show up in the same reuse counters) as
    /// the serving gathers.
    pub fn staging_pool(&self) -> Arc<StagingPool> {
        Arc::clone(&self.staging)
    }

    /// Whether gathers run the staged transfer path (`transfer-ring ≥
    /// 1`; RAIN's batch-stateful reuse never stages).
    fn staged_enabled(&self) -> bool {
        self.cfg.transfer_ring >= 1 && !self.prepared.inter_batch_reuse
    }

    /// Run inference over the full test set (or `max_batches`).
    pub fn run(&mut self) -> Result<InferenceReport> {
        // own the seed batches so `run_batches` can borrow self mutably
        let owned: Vec<Vec<NodeId>> = match &self.prepared.batch_order {
            Some((ordered, _)) => ordered.clone(),
            None => seed_batches(&self.ds.test_nodes, self.cfg.batch_size)
                .into_iter()
                .map(|b| b.to_vec())
                .collect(),
        };
        let views: Vec<&[NodeId]> = owned.iter().map(|b| b.as_slice()).collect();
        self.run_batches(&views)
    }

    /// Run inference over an explicit batch list (the trace-replay
    /// entry point: `tests/scenarios.rs` and the scenario bench drive
    /// the engine off [`Trace`](crate::bench_support::scenario::Trace)
    /// event seed lists instead of the dataset's test split). Honors
    /// `max_batches`; logits are bit-identical across execution shapes
    /// for the same batch list.
    pub fn run_batches(&mut self, batches: &[&[NodeId]]) -> Result<InferenceReport> {
        let n = self
            .cfg
            .max_batches
            .map(|m| m.min(batches.len()))
            .unwrap_or(batches.len());
        // batch_order's cluster ids were consumed at prepare time (they
        // grouped the RAIN batch order); only the order matters here

        let mut report = InferenceReport {
            system: self.prepared.kind,
            preprocess_ns: self.prepared.preprocess_ns,
            sample: StageTimes::default(),
            feature: StageTimes::default(),
            compute: StageTimes::default(),
            stats: CacheStats::new(),
            n_batches: 0,
            n_seeds: 0,
            loaded_nodes: 0,
            cache_bytes: self.prepared.cache_bytes(),
            alloc: self.prepared.alloc(),
            oom: None,
            logits_checksum: 0.0,
            batch_retries: 0,
            run_wall_ns: 0.0,
            transfer_staged_ns: 0.0,
            transfer_hidden_ns: 0.0,
            staging: None,
        };

        // RAIN stages the entire node-feature tensor in device memory to
        // enable cross-batch reuse (the paper's Table V observes exactly
        // this: a 52.96 GB allocation attempt on Ogbn-papers100M ≈
        // 111M × 128 × 4 B). If it does not fit, RAIN fails up front.
        // (RAIN is never sharded, so the claim lands on device 0.)
        let mut rain_claim = 0u64;
        if self.prepared.inter_batch_reuse {
            let need = self.ds.features.bytes_total();
            if let Err(e) = self.device.alloc_unreserved(0, need) {
                report.oom = Some(e.to_string());
                return Ok(report);
            }
            rain_claim = need;
        }

        let run0 = Instant::now();
        let result = if self.cfg.pipeline_depth > 1 && n > 1 {
            pipeline::run_pipelined(self, batches, n, &mut report)
        } else {
            self.run_serial(batches, n, &mut report)
        };
        report.run_wall_ns = run0.elapsed().as_nanos() as f64;
        if self.staged_enabled() {
            report.staging = Some(self.staging.stats());
        }

        // release RAIN's staged feature tensor
        self.device.free(0, rain_claim);
        result?;
        Ok(report)
    }

    /// The serial scheduler: one batch fully through all three stages
    /// before the next starts (the Fig. 1 baseline the pipeline hides).
    fn run_serial(
        &mut self,
        batches: &[&[NodeId]],
        n: usize,
        report: &mut InferenceReport,
    ) -> Result<()> {
        let mut sampler = self.pool.checkout();
        // previous batch's inputs (the LSH ordering makes consecutive
        // batches similar; reuse rate = overlap with the previous batch)
        let mut prev_inputs: HashSet<NodeId> = HashSet::new();
        let mut x: Vec<f32> = Vec::new();
        let dim = self.ds.features.dim();
        let staged_on = self.staged_enabled();
        let fault = self.fault.clone();
        // the ring's modeled-timeline clock: fed per batch in index
        // order, exactly as the pipelined fold feeds it — occupancy is
        // a property of the workload + ring, not of the scheduler
        let mut sim = staged_on.then(|| TransferSim::new(self.cfg.transfer_ring));

        for (bi, seeds) in batches.iter().take(n).enumerate() {
            // one snapshot per shard per batch: both stages of a batch
            // see the same cache epochs even if a refresh lands mid-batch
            let graph_epoch = self.graph.as_mut().map(|h| h.acquire_arc());
            let snap = self.snap.acquire();

            // ---- stage 1: sampling -------------------------------------
            let sb = stages::sample_stage(
                self.ds,
                &snap,
                &mut sampler,
                seeds,
                bi,
                self.cfg.seed,
                None,
                graph_epoch.as_deref(),
            );
            report.sample.add(sb.wall_ns, sb.ledger.modeled_ns(&self.cfg.cost));
            report.stats.sample.merge(&sb.ledger);

            // ---- stage 2: feature loading ------------------------------
            // staged mode gathers into a leased staging buffer (the
            // compute input, zero-copy), returned after compute
            if staged_on {
                debug_assert!(x.is_empty());
                x = self.staging.lease();
            }
            let (f_ledger, f_wall, n_inputs) = stages::gather_stage(
                self.ds,
                &snap,
                self.prepared.inter_batch_reuse,
                &self.cfg.cost,
                &sb.mb,
                &mut prev_inputs,
                &mut x,
                None,
                TenantClass::Standard,
                staged_on.then(|| stages::StagedGather {
                    fault: fault.as_deref(),
                    batch_index: bi,
                }),
            );
            report.loaded_nodes += n_inputs as u64;
            report.feature.add(f_wall, f_ledger.modeled_ns(&self.cfg.cost));
            report.stats.feature.merge(&f_ledger);

            // ---- stage 3: computation ----------------------------------
            let cb = match stages::compute_stage(
                &mut self.compute,
                &self.cfg,
                self.ds.spec.classes,
                dim,
                &sb.mb,
                &x,
            ) {
                Ok(cb) => cb,
                Err(e) => {
                    // keep the scratch pooled even on the error path
                    self.pool.checkin(sampler);
                    if staged_on {
                        self.staging.give_back(x);
                    }
                    return Err(e.context(format!("compute failed on batch {bi}")));
                }
            };
            if staged_on {
                // compute consumed the staged buffer; its ring slot is
                // free — return the lease and advance the ring clock
                self.staging.give_back(std::mem::take(&mut x));
                if let Some(sim) = sim.as_mut() {
                    let staged_ns = f_ledger.staged_ns(&self.cfg.cost);
                    let hidden = sim.advance(staged_ns, cb.wall_ns + cb.modeled_ns);
                    report.transfer_staged_ns += staged_ns;
                    report.transfer_hidden_ns += hidden;
                }
            }
            report.compute.add(cb.wall_ns, cb.modeled_ns);
            if let Some(l) = cb.logits {
                report.logits_checksum += l.iter().map(|v| v.abs() as f64).sum::<f64>();
            }

            report.n_batches += 1;
            report.n_seeds += seeds.len();
        }
        self.pool.checkin(sampler);
        Ok(())
    }
}

/// Serving requests draw from a different stream family than `run()`
/// batches and the presample profiler (which share `(seed, index)` by
/// design): without the tag, request `i` would replay profile batch
/// `i`'s exact neighbor draws, oracle-biasing measured serving hit
/// rates upward.
const SERVE_STREAM_XOR: u64 = 0x5eed_ca11_ab1e_0001;

/// Output of a single served batch (the coordinator's unit of work).
#[derive(Debug, Clone)]
pub struct BatchOutput {
    pub logits: Option<Vec<f32>>,
    pub sample: StageTimes,
    pub feature: StageTimes,
    pub compute: StageTimes,
    pub n_inputs: usize,
    /// The batch's transfer ledgers (live hit-ratio reporting and the
    /// refresh loop's drift telemetry).
    pub stats: CacheStats,
    /// Highest cache epoch across the shards the batch was served
    /// under (observability).
    pub cache_epoch: u64,
    /// Modeled ns of this request's coalesced staged copy (0 when the
    /// staged path is off).
    pub transfer_staged_ns: f64,
    /// Staged ns hidden under compute on the serving ring's clock.
    pub transfer_hidden_ns: f64,
}

impl<'d> InferenceEngine<'d> {
    /// Serve one batch of seed nodes (the coordinator's request path).
    /// RAIN's cluster-stateful mode is not servable this way.
    ///
    /// Records the batch's workload-tracker touches as
    /// [`TenantClass::Standard`]; class-aware callers (the coordinator's
    /// QoS path) use [`infer_once_as`](Self::infer_once_as).
    pub fn infer_once(&mut self, seeds: &[NodeId]) -> Result<BatchOutput> {
        self.infer_once_as(seeds, TenantClass::Standard)
    }

    /// [`infer_once`](Self::infer_once) with an explicit admission
    /// class. The class tags only what the [`WorkloadTracker`] learns
    /// about this batch (the multi-tenant refresh input — see
    /// `cache::refresh`); the computed logits are bit-identical across
    /// classes for the same seeds at the same stream position.
    ///
    /// Hot-path allocation: the sampler (two O(n_nodes) scratch arrays)
    /// comes from the engine's pool and the gather buffer is reused, so
    /// steady-state serving allocates only the mini-batch itself.
    pub fn infer_once_as(
        &mut self,
        seeds: &[NodeId],
        class: TenantClass,
    ) -> Result<BatchOutput> {
        anyhow::ensure!(
            !self.prepared.inter_batch_reuse,
            "RAIN's batch-stateful mode cannot serve ad-hoc requests"
        );
        // injected batch panic fires before any engine state moves
        // (stream index, pool, gather buffer), so a caller that catches
        // it and retries replays the identical request
        if let Some(f) = &self.fault {
            if f.batch_panic(self.served as usize) {
                panic!("injected fault: batch {} panicked", self.served);
            }
        }
        let request = self.served as usize;
        self.served += 1;

        // one snapshot per shard for the whole request; a concurrent
        // refresh install is picked up by the *next* request, never
        // mid-batch
        let tracker = self.tracker.clone();
        let staged_on = self.staged_enabled();
        // staged requests gather into a leased staging buffer (returned
        // after compute); otherwise the engine's reusable scratch
        let mut x = if staged_on {
            self.staging.lease()
        } else {
            std::mem::take(&mut self.x_buf)
        };
        let mut sampler = self.pool.checkout();
        // one graph epoch per request too: a concurrent mutation or
        // compaction lands on the *next* request, never mid-batch
        let graph_epoch = self.graph.as_mut().map(|h| h.acquire_arc());
        let snap = self.snap.acquire();
        let cache_epoch = snap.max_epoch();

        // sample
        let sb = stages::sample_stage(
            self.ds,
            &snap,
            &mut sampler,
            seeds,
            request,
            self.cfg.seed ^ SERVE_STREAM_XOR,
            tracker.as_deref(),
            graph_epoch.as_deref(),
        );
        self.pool.checkin(sampler);
        let sample = StageTimes {
            wall_ns: sb.wall_ns,
            modeled_ns: sb.ledger.modeled_ns(&self.cfg.cost),
        };

        // gather
        let mut no_prev: HashSet<NodeId> = HashSet::new();
        let (f_ledger, f_wall, n_inputs) = stages::gather_stage(
            self.ds,
            &snap,
            self.prepared.inter_batch_reuse,
            &self.cfg.cost,
            &sb.mb,
            &mut no_prev,
            &mut x,
            tracker.as_deref(),
            class,
            staged_on.then(|| stages::StagedGather {
                fault: self.fault.as_deref(),
                batch_index: request,
            }),
        );
        let feature = StageTimes {
            wall_ns: f_wall,
            modeled_ns: f_ledger.modeled_ns(&self.cfg.cost),
        };

        // the tracker's Eq.-(1) ratio input mirrors pre-sampling:
        // modeled stage times, not simulator wall; the input-node
        // count feeds the refresh loop's peak-claim tracking
        if let Some(t) = &tracker {
            t.record_batch(sample.modeled_ns, feature.modeled_ns, n_inputs as u32);
        }
        let mut stats = CacheStats::new();
        stats.sample.merge(&sb.ledger);
        stats.feature.merge(&f_ledger);

        // compute (restore/return the gather buffer before propagating
        // errors)
        let cb = stages::compute_stage(
            &mut self.compute,
            &self.cfg,
            self.ds.spec.classes,
            self.ds.features.dim(),
            &sb.mb,
            &x,
        );
        if staged_on {
            self.staging.give_back(x);
        } else {
            self.x_buf = x;
        }
        let cb = cb?;
        let compute = StageTimes { wall_ns: cb.wall_ns, modeled_ns: cb.modeled_ns };

        // advance the serving ring's persistent clock: requests arrive
        // in served order, so occupancy matches the batch runners'
        let (transfer_staged_ns, transfer_hidden_ns) = match &mut self.serve_sim {
            Some(sim) if staged_on => {
                let staged_ns = f_ledger.staged_ns(&self.cfg.cost);
                let hidden = sim.advance(staged_ns, cb.wall_ns + cb.modeled_ns);
                (staged_ns, hidden)
            }
            _ => (0.0, 0.0),
        };

        Ok(BatchOutput {
            logits: cb.logits,
            sample,
            feature,
            compute,
            n_inputs,
            stats,
            cache_epoch,
            transfer_staged_ns,
            transfer_hidden_ns,
        })
    }
}

/// Convenience: build the dataset named by `cfg`, prepare, and run.
pub fn run_config(cfg: &RunConfig) -> Result<InferenceReport> {
    let ds = datasets::spec(&cfg.dataset)?.build();
    let mut engine = InferenceEngine::prepare(&ds, cfg.clone())?;
    engine.run()
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::config::ComputeKind;
    use crate::sampler::Fanout;

    fn tiny_cfg(system: SystemKind) -> RunConfig {
        let mut cfg = RunConfig::default();
        cfg.dataset = "tiny".into();
        cfg.system = system;
        cfg.batch_size = 64;
        cfg.fanout = Fanout::parse("3,2,2").unwrap();
        cfg.budget = Some(300_000);
        cfg.max_batches = Some(6);
        cfg.compute = ComputeKind::Skip;
        cfg
    }

    fn run(system: SystemKind) -> InferenceReport {
        let ds = datasets::spec("tiny").unwrap().build();
        let mut e = InferenceEngine::prepare(&ds, tiny_cfg(system)).unwrap();
        e.run().unwrap()
    }

    #[test]
    fn dgl_all_misses() {
        let r = run(SystemKind::Dgl);
        assert_eq!(r.n_batches, 6);
        assert_eq!(r.stats.feature.hits, 0);
        assert_eq!(r.stats.sample.hits, 0);
        assert!(r.stats.feature.misses > 0);
        assert_eq!(r.preprocess_ns, 0.0);
        assert!(r.prep_fraction() > 0.9); // compute skipped
    }

    #[test]
    fn dci_hits_both_caches_and_beats_dgl() {
        let dgl = run(SystemKind::Dgl);
        let dci = run(SystemKind::Dci);
        assert!(dci.stats.feature.hits > 0, "feature cache must hit");
        assert!(dci.stats.sample.hits > 0, "adjacency cache must hit");
        // compare modeled transfer time: deterministic, and the quantity
        // the caches actually optimize (wall noise on the tiny dataset
        // can exceed the win)
        let dci_m = dci.sample.modeled_ns + dci.feature.modeled_ns;
        let dgl_m = dgl.sample.modeled_ns + dgl.feature.modeled_ns;
        assert!(dci_m < dgl_m, "DCI modeled {dci_m:.0} should beat DGL {dgl_m:.0}");
        assert!(dci.alloc.is_some());
    }

    #[test]
    fn sci_beats_dgl_but_not_dci() {
        let dgl = run(SystemKind::Dgl);
        let sci = run(SystemKind::Sci);
        let dci = run(SystemKind::Dci);
        assert!(sci.stats.feature.hits > 0);
        assert_eq!(sci.stats.sample.hits, 0, "SCI has no adjacency cache");
        let m = |r: &InferenceReport| r.sample.modeled_ns + r.feature.modeled_ns;
        assert!(m(&sci) < m(&dgl), "SCI {:.0} beats DGL {:.0}", m(&sci), m(&dgl));
        assert!(
            m(&dci) < m(&sci),
            "dual cache {:.0} beats single cache {:.0}",
            m(&dci),
            m(&sci)
        );
    }

    #[test]
    fn rain_reuses_across_batches() {
        let r = run(SystemKind::Rain);
        assert!(r.stats.feature.hits > 0, "inter-batch reuse should hit");
        assert!(r.oom.is_none());
        assert_eq!(r.n_batches, 6);
    }

    #[test]
    fn rain_ooms_on_small_device() {
        let ds = datasets::spec("tiny").unwrap().build();
        let mut cfg = tiny_cfg(SystemKind::Rain);
        cfg.max_batches = None;
        cfg.device_capacity = Some(40_000); // ~500 rows of 64B + overhead
        let mut e = InferenceEngine::prepare(&ds, cfg).unwrap();
        let r = e.run().unwrap();
        assert!(r.oom.is_some(), "expected simulated CUDA OOM");
        assert!(r.oom.unwrap().contains("CUDA out of memory"));
    }

    #[test]
    fn ducati_close_to_dci_steady_state() {
        let dci = run(SystemKind::Dci);
        let ducati = run(SystemKind::Ducati);
        assert!(ducati.stats.feature.hits > 0);
        // preprocessing gap is the point (Fig. 10); on `tiny` DUCATI's
        // 8x profiling request is capped by the 15 available batches,
        // so the honest ratio floor here is ~1.5x (full-size benches
        // show the paper's 5-10x)
        assert!(
            ducati.preprocess_ns > 1.4 * dci.preprocess_ns,
            "DUCATI {:.0} vs DCI {:.0}",
            ducati.preprocess_ns,
            dci.preprocess_ns
        );
    }

    #[test]
    fn reference_compute_runs_and_checksums() {
        let ds = datasets::spec("tiny").unwrap().build();
        let mut cfg = tiny_cfg(SystemKind::Dci);
        cfg.compute = ComputeKind::Reference;
        cfg.hidden = 16;
        let mut e = InferenceEngine::prepare(&ds, cfg).unwrap();
        let r = e.run().unwrap();
        assert!(r.logits_checksum > 0.0);
        assert!(r.compute.wall_ns > 0.0);
        assert!(r.run_wall_ns > 0.0);
        assert_eq!(r.n_seeds, 6 * 64);
    }

    #[test]
    fn deterministic_given_seed() {
        // sampling and adjacency caching are bit-deterministic; the
        // Eq. (1) split depends on *measured* stage times (as in the
        // paper), so the feature cache contents may wobble slightly —
        // DGL (no time-dependent decisions) must be fully deterministic.
        let a = run(SystemKind::Dci);
        let b = run(SystemKind::Dci);
        assert_eq!(a.loaded_nodes, b.loaded_nodes);
        assert_eq!(a.stats.sample.hits, b.stats.sample.hits);
        let da = run(SystemKind::Dgl);
        let db = run(SystemKind::Dgl);
        assert_eq!(da.loaded_nodes, db.loaded_nodes);
        assert_eq!(da.stats.feature.misses, db.stats.feature.misses);
    }

    #[test]
    fn pipelined_run_matches_serial_smoke() {
        // the full matrix lives in tests/pipeline_equivalence.rs; this
        // is the fast in-crate guard
        let ds = datasets::spec("tiny").unwrap().build();
        let mut cfg = tiny_cfg(SystemKind::Dci);
        let serial = InferenceEngine::prepare(&ds, cfg.clone()).unwrap().run().unwrap();
        cfg.pipeline_depth = 3;
        cfg.sample_threads = 2;
        let piped = InferenceEngine::prepare(&ds, cfg).unwrap().run().unwrap();
        assert_eq!(serial.loaded_nodes, piped.loaded_nodes);
        assert_eq!(serial.stats.sample.hits, piped.stats.sample.hits);
        assert_eq!(serial.stats.sample.misses, piped.stats.sample.misses);
        assert_eq!(serial.stats.feature.hits, piped.stats.feature.hits);
        assert_eq!(serial.stats.feature.misses, piped.stats.feature.misses);
        assert_eq!(serial.n_batches, piped.n_batches);
    }

    #[test]
    fn sharded_run_matches_unsharded_smoke() {
        // the full property matrix lives in tests/properties.rs; this
        // is the fast in-crate guard that shard routing is transparent
        let ds = datasets::spec("tiny").unwrap().build();
        let mut cfg = tiny_cfg(SystemKind::Dci);
        cfg.compute = ComputeKind::Reference;
        cfg.hidden = 16;
        let solo = InferenceEngine::prepare(&ds, cfg.clone()).unwrap().run().unwrap();
        cfg.shards = 4;
        let mut engine = InferenceEngine::prepare(&ds, cfg).unwrap();
        assert_eq!(engine.prepared.runtime.n_shards(), 4);
        assert_eq!(engine.device.n_devices(), 4);
        let sharded = engine.run().unwrap();
        // bit-identical results: sharding changes which device serves a
        // byte, never which byte
        assert_eq!(solo.logits_checksum, sharded.logits_checksum);
        assert_eq!(solo.loaded_nodes, sharded.loaded_nodes);
        assert_eq!(solo.n_batches, sharded.n_batches);
        // access totals match too (hit/miss split may differ: per-shard
        // budgets carve the same global budget differently)
        assert_eq!(
            solo.stats.feature.hits + solo.stats.feature.misses,
            sharded.stats.feature.hits + sharded.stats.feature.misses,
        );
        assert_eq!(
            solo.stats.sample.hits + solo.stats.sample.misses,
            sharded.stats.sample.hits + sharded.stats.sample.misses,
        );
        // the shard budgets sum back to the global budget
        assert_eq!(engine.prepared.shard_budgets.iter().sum::<u64>(), 300_000);
        assert_eq!(engine.prepared.alloc().unwrap().total(), 300_000);
    }

    #[test]
    fn serving_path_reuses_pooled_sampler() {
        let ds = datasets::spec("tiny").unwrap().build();
        let mut e = InferenceEngine::prepare(&ds, tiny_cfg(SystemKind::Dci)).unwrap();
        let seeds: Vec<NodeId> = ds.test_nodes[..16].to_vec();
        let a = e.infer_once(&seeds).unwrap();
        let b = e.infer_once(&seeds).unwrap();
        assert!(a.n_inputs > 0);
        assert!(b.sample.wall_ns > 0.0);
    }

    #[test]
    fn run_config_convenience() {
        let mut cfg = tiny_cfg(SystemKind::Dci);
        cfg.max_batches = Some(2);
        let r = run_config(&cfg).unwrap();
        assert_eq!(r.n_batches, 2);
    }
}
