//! Transfer-ring virtual clock: how much of each batch's staged H2D
//! copy hides under earlier batches' compute (DESIGN.md §Transfer
//! engine).
//!
//! The staged path is zero-copy — the leased staging buffer *is* the
//! compute input — so a ring slot is not free until the batch consuming
//! it finishes compute. With `ring = 1` there is exactly one slot:
//! batch *i*'s transfer cannot begin until batch *i−1*'s compute ends,
//! which is the serial timeline (zero overlap, the baseline). With
//! `ring ≥ 2`, batch *i*'s transfer runs while batch *i−1* computes and
//! the overlapped nanoseconds are "hidden".
//!
//! The clock is fed per-batch **in batch-index order** by every
//! scheduler (serial fold, pipelined fold, serving path), so the
//! modeled occupancy is a property of the workload and the ring depth —
//! not of which scheduler happened to run it. It never touches data:
//! which bytes move is decided by the gather stage; this only decides
//! *when* the modeled timeline says they moved.

use std::collections::VecDeque;

/// Virtual clock for a ring of `K` in-flight staged copies feeding a
/// single compute queue. See the module docs for slot semantics.
#[derive(Debug)]
pub struct TransferSim {
    ring: usize,
    /// When the (single) modeled H2D engine frees up.
    transfer_free: f64,
    /// When the (single) modeled compute queue frees up.
    compute_free: f64,
    /// Compute-end times of batches whose staging buffer is still
    /// held — `len() == ring` means the next transfer must wait for
    /// the oldest holder's compute to finish.
    slots: VecDeque<f64>,
    /// Recent compute busy intervals `(begin, end)` that a later
    /// transfer may still overlap; pruned as the clock advances.
    busy: VecDeque<(f64, f64)>,
    staged_ns: f64,
    hidden_ns: f64,
}

impl TransferSim {
    /// A clock with `ring` slots (clamped to at least 1).
    pub fn new(ring: usize) -> TransferSim {
        TransferSim {
            ring: ring.max(1),
            transfer_free: 0.0,
            compute_free: 0.0,
            slots: VecDeque::new(),
            busy: VecDeque::new(),
            staged_ns: 0.0,
            hidden_ns: 0.0,
        }
    }

    /// Advance the clock by one batch: a staged copy of `staged_ns`
    /// followed by that batch's compute of `compute_ns`. Returns the
    /// nanoseconds of the copy that overlapped earlier batches'
    /// compute (the hidden share).
    pub fn advance(&mut self, staged_ns: f64, compute_ns: f64) -> f64 {
        // wait for a ring slot: the oldest in-flight buffer frees when
        // its consumer's compute completes
        let slot_free = if self.slots.len() >= self.ring {
            self.slots.pop_front().unwrap_or(0.0)
        } else {
            0.0
        };
        let tb = self.transfer_free.max(slot_free);
        let te = tb + staged_ns;
        // overlap with *earlier* batches' compute only — this batch's
        // own compute starts after its transfer lands
        self.busy.retain(|&(_, ce)| ce > tb);
        let hidden: f64 = self
            .busy
            .iter()
            .map(|&(cb, ce)| (te.min(ce) - tb.max(cb)).max(0.0))
            .sum();
        let cb = self.compute_free.max(te);
        let ce = cb + compute_ns;
        self.transfer_free = te;
        self.compute_free = ce;
        self.busy.push_back((cb, ce));
        self.slots.push_back(ce);
        self.staged_ns += staged_ns;
        self.hidden_ns += hidden;
        hidden
    }

    /// Total staged-copy ns fed to the clock.
    pub fn staged_ns(&self) -> f64 {
        self.staged_ns
    }

    /// Total staged ns that overlapped compute.
    pub fn hidden_ns(&self) -> f64 {
        self.hidden_ns
    }

    /// Fraction of staged H2D hidden under compute (0 when nothing
    /// was staged).
    pub fn occupancy(&self) -> f64 {
        if self.staged_ns == 0.0 {
            0.0
        } else {
            self.hidden_ns / self.staged_ns
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn ring_of_one_is_the_serial_timeline() {
        let mut sim = TransferSim::new(1);
        for _ in 0..10 {
            assert_eq!(sim.advance(100.0, 300.0), 0.0);
        }
        assert_eq!(sim.hidden_ns(), 0.0);
        assert_eq!(sim.occupancy(), 0.0);
        assert_eq!(sim.staged_ns(), 1000.0);
    }

    #[test]
    fn ring_of_two_hides_transfer_under_compute() {
        let mut sim = TransferSim::new(2);
        // batch 0 has no earlier compute to hide under
        assert_eq!(sim.advance(100.0, 300.0), 0.0);
        // steady state: batch i's 100ns copy fits inside batch i−1's
        // 300ns compute entirely
        for _ in 1..10 {
            let h = sim.advance(100.0, 300.0);
            assert!((h - 100.0).abs() < 1e-9, "hidden {h}");
        }
        assert!(sim.occupancy() > 0.85, "occupancy {}", sim.occupancy());
    }

    #[test]
    fn transfer_longer_than_compute_is_partially_hidden() {
        let mut sim = TransferSim::new(2);
        sim.advance(500.0, 200.0);
        // the 500ns copy can hide at most the 200ns of compute running
        let h = sim.advance(500.0, 200.0);
        assert!((h - 200.0).abs() < 1e-9, "hidden {h}");
        assert!(sim.occupancy() < 0.5);
    }

    #[test]
    fn deeper_rings_never_hide_less() {
        let pattern: Vec<(f64, f64)> = (0..20)
            .map(|i| (100.0 + 7.0 * i as f64, 250.0 + 11.0 * (i % 3) as f64))
            .collect();
        let run = |ring: usize| {
            let mut sim = TransferSim::new(ring);
            for &(t, c) in &pattern {
                sim.advance(t, c);
            }
            sim.hidden_ns()
        };
        let (h1, h2, h4) = (run(1), run(2), run(4));
        assert_eq!(h1, 0.0);
        assert!(h2 > 0.0);
        assert!(h4 >= h2);
    }

    #[test]
    fn hidden_never_exceeds_staged() {
        let mut sim = TransferSim::new(4);
        for i in 0..50 {
            let staged = 50.0 * (1 + i % 5) as f64;
            let compute = 120.0 * (1 + i % 3) as f64;
            let h = sim.advance(staged, compute);
            assert!(h >= 0.0 && h <= staged + 1e-9);
        }
        assert!(sim.hidden_ns() <= sim.staged_ns());
        assert!(sim.occupancy() <= 1.0);
    }

    #[test]
    fn zero_ring_clamps_to_one() {
        let mut sim = TransferSim::new(0);
        sim.advance(10.0, 10.0);
        assert_eq!(sim.advance(10.0, 10.0), 0.0);
    }
}
