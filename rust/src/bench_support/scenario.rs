//! Seeded workload zoo: named adversarial request-stream shapes behind
//! one replayable trace format.
//!
//! DCI's claim is *workload-aware* allocation, so the planner / refresh
//! / rebalance / QoS machinery has to be stressed across workload
//! diversity, not one drift shape. Each [`Scenario`] is a seeded,
//! dataset-independent generator that turns a seed pool into a
//! [`Trace`]: a canonical-JSON event list that can be regenerated
//! bit-identically from `(scenario_id, seed, knobs, pool)` or replayed
//! from file through the serving stack (`benches/scenarios.rs`, `dci
//! serve scenario=…` / `trace=…`).
//!
//! Determinism contract (held by `tests/scenarios.rs`):
//! - `generate` is a pure function of `(pool, seed, dims)`: no clocks,
//!   no global RNG, no transcendental libm calls (the diurnal wave is a
//!   triangle approximation for exactly this reason — `sin` is not
//!   bit-stable across libm builds).
//! - `Trace::to_canonical_string` is byte-stable: sorted keys, the
//!   deterministic `util::json` writer, floats only in `knobs` (where
//!   Rust's shortest-round-trip formatting is platform-independent).
//! - parse ∘ serialize is the identity on traces.

use std::collections::BTreeMap;

use anyhow::{bail, ensure, Context, Result};

use crate::coordinator::TenantClass;
use crate::graph::NodeId;
use crate::util::json::{num, obj, s, Json};
use crate::util::{splitmix64, Rng};

/// Trace schema version (bumped on any breaking field change).
pub const TRACE_SCHEMA: u64 = 1;

/// Generation geometry shared by every scenario: how much warm-up
/// traffic precedes the shape-specific drift, and how large each
/// serving request is. Recorded into [`Trace::knobs`] so a trace is
/// self-describing.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub struct TraceDims {
    /// Waves of uniform warm-up traffic the deployment is planned
    /// against (the phase-A pool of the drift benches).
    pub warm_waves: usize,
    /// Shape-specific drift waves that follow.
    pub drift_waves: usize,
    /// Requests per wave.
    pub reqs_per_wave: usize,
    /// Seed nodes per request.
    pub req_size: usize,
}

impl TraceDims {
    /// CI-sized geometry (the `--quick` default).
    pub fn quick() -> Self {
        TraceDims { warm_waves: 2, drift_waves: 6, reqs_per_wave: 8, req_size: 24 }
    }

    /// Full bench geometry.
    pub fn full() -> Self {
        TraceDims { warm_waves: 3, drift_waves: 10, reqs_per_wave: 16, req_size: 64 }
    }
}

/// One serving request in a trace: the wave it belongs to (the
/// replayer's pacing / settle boundary), its admission class, and its
/// seed nodes.
#[derive(Debug, Clone, PartialEq, Eq)]
pub struct TraceEvent {
    /// Wave index (warm waves come first: `wave < warm_waves`).
    pub wave: u32,
    /// Admission class (`priority` | `standard` | `scan`).
    pub class: TenantClass,
    /// Seed node ids of the request.
    pub seeds: Vec<NodeId>,
}

/// A replayable workload trace: `(scenario_id, seed, knobs)` name the
/// generator invocation, `events` are its output. Canonical JSON via
/// [`Trace::to_canonical_string`].
#[derive(Debug, Clone, PartialEq)]
pub struct Trace {
    /// Generator name (`flash_crowd`, `diurnal`, …).
    pub scenario_id: String,
    /// Generator seed.
    pub seed: u64,
    /// Generator knobs (dims + shape parameters), name → value. All
    /// values are finite; integers stay integral so the canonical
    /// encoding is float-free where possible.
    pub knobs: BTreeMap<String, f64>,
    /// The request stream, in serving order.
    pub events: Vec<TraceEvent>,
}

impl Trace {
    /// The warm-up prefix (`wave < knobs["warm_waves"]`) — what the
    /// deployment's offline plan is built against.
    pub fn warm_events(&self) -> Vec<&TraceEvent> {
        let warm = self.knobs.get("warm_waves").copied().unwrap_or(0.0) as u32;
        self.events.iter().filter(|e| e.wave < warm).collect()
    }

    /// The drifted live phase (everything after the warm prefix).
    pub fn live_events(&self) -> Vec<&TraceEvent> {
        let warm = self.knobs.get("warm_waves").copied().unwrap_or(0.0) as u32;
        self.events.iter().filter(|e| e.wave >= warm).collect()
    }

    /// Events of the final wave — the "workload right now" slice the
    /// recovery measurements run on.
    pub fn last_wave_events(&self) -> Vec<&TraceEvent> {
        let last = self.events.iter().map(|e| e.wave).max().unwrap_or(0);
        self.events.iter().filter(|e| e.wave == last).collect()
    }

    /// The canonical JSON value (sorted keys via `util::json`).
    pub fn to_json(&self) -> Json {
        obj(vec![
            ("schema", num(TRACE_SCHEMA as f64)),
            ("scenario_id", s(&self.scenario_id)),
            ("seed", num(self.seed as f64)),
            (
                "knobs",
                Json::Obj(
                    self.knobs.iter().map(|(k, v)| (k.clone(), num(*v))).collect(),
                ),
            ),
            (
                "events",
                Json::Arr(
                    self.events
                        .iter()
                        .map(|e| {
                            obj(vec![
                                ("wave", num(e.wave as f64)),
                                ("class", s(e.class.as_str())),
                                (
                                    "seeds",
                                    Json::Arr(
                                        e.seeds
                                            .iter()
                                            .map(|&v| num(v as f64))
                                            .collect(),
                                    ),
                                ),
                            ])
                        })
                        .collect(),
                ),
            ),
        ])
    }

    /// The canonical byte encoding — what the determinism property
    /// tests compare and what `manifest_sha256` ultimately hashes.
    pub fn to_canonical_string(&self) -> String {
        self.to_json().to_string()
    }

    /// Parse a trace from its JSON value (schema-checked).
    pub fn from_json(v: &Json) -> Result<Trace> {
        let schema = v.req("schema")?.as_u64()?;
        ensure!(
            schema == TRACE_SCHEMA,
            "trace schema {schema} unsupported (this build reads {TRACE_SCHEMA})"
        );
        let scenario_id = v.req("scenario_id")?.as_str()?.to_string();
        let seed = v.req("seed")?.as_u64()?;
        let mut knobs = BTreeMap::new();
        match v.req("knobs")? {
            Json::Obj(m) => {
                for (k, kv) in m {
                    let x = kv.as_f64().with_context(|| format!("knob {k:?}"))?;
                    ensure!(x.is_finite(), "knob {k:?} is not finite");
                    knobs.insert(k.clone(), x);
                }
            }
            other => bail!("knobs must be an object, got {other:?}"),
        }
        let mut events = Vec::new();
        for (i, e) in v.req("events")?.as_arr()?.iter().enumerate() {
            let wave = e.req("wave")?.as_u64()? as u32;
            let class = TenantClass::parse(e.req("class")?.as_str()?)
                .with_context(|| format!("event {i}"))?;
            let seeds: Vec<NodeId> = e
                .req("seeds")?
                .as_arr()?
                .iter()
                .map(|x| Ok(x.as_u64()? as NodeId))
                .collect::<Result<_>>()
                .with_context(|| format!("event {i}"))?;
            ensure!(!seeds.is_empty(), "event {i} has no seeds");
            events.push(TraceEvent { wave, class, seeds });
        }
        ensure!(!events.is_empty(), "trace has no events");
        Ok(Trace { scenario_id, seed, knobs, events })
    }

    /// Parse a trace from canonical (or any valid) JSON text.
    pub fn parse(text: &str) -> Result<Trace> {
        Trace::from_json(&Json::parse(text).context("trace JSON")?)
    }

    /// Write the canonical encoding to `path`.
    pub fn write_file(&self, path: &str) -> Result<()> {
        std::fs::write(path, self.to_canonical_string())
            .with_context(|| format!("writing trace {path}"))
    }

    /// Read and parse a trace file.
    pub fn read_file(path: &str) -> Result<Trace> {
        let text = std::fs::read_to_string(path)
            .with_context(|| format!("reading trace {path}"))?;
        Trace::parse(&text).with_context(|| format!("parsing trace {path}"))
    }
}

/// A named, seeded workload generator. Implementations must be pure:
/// the same `(pool, seed, dims)` always yields the identical trace.
pub trait Scenario {
    /// Stable generator name (the trace's `scenario_id`).
    fn id(&self) -> &'static str;
    /// One-line description for tables and docs.
    fn describe(&self) -> &'static str;
    /// Generate the trace for `pool` (the candidate seed nodes, in a
    /// deterministic caller-chosen order) under `seed` and `dims`.
    fn generate(&self, pool: &[NodeId], seed: u64, dims: &TraceDims) -> Trace;
}

/// Every zoo scenario id, in registry order.
pub const SCENARIO_IDS: [&str; 5] =
    ["flash_crowd", "diurnal", "scan_storm", "powerlaw_fanout", "burst_locality"];

/// The full zoo, in [`SCENARIO_IDS`] order.
pub fn registry() -> Vec<Box<dyn Scenario>> {
    vec![
        Box::new(FlashCrowd),
        Box::new(Diurnal),
        Box::new(ScanStorm),
        Box::new(PowerlawFanout),
        Box::new(BurstLocality),
    ]
}

/// Look a scenario up by id.
pub fn by_id(id: &str) -> Option<Box<dyn Scenario>> {
    registry().into_iter().find(|sc| sc.id() == id)
}

/// Whether `id` names a zoo scenario (config-time validation for
/// `scenario=`).
pub fn is_known(id: &str) -> bool {
    SCENARIO_IDS.contains(&id)
}

/// Deterministic per-scenario RNG root: the scenario id is folded into
/// the seed so two scenarios on the same seed draw unrelated streams.
fn scenario_rng(id: &str, seed: u64) -> Rng {
    let tag = id
        .bytes()
        .fold(0xcbf2_9ce4_8422_2325u64, |h, b| splitmix64(h ^ b as u64));
    Rng::new(splitmix64(seed ^ tag))
}

/// Shared knob bookkeeping: every trace records its dims plus the pool
/// size it was generated against (a regeneration sanity check).
fn base_knobs(dims: &TraceDims, pool_len: usize) -> BTreeMap<String, f64> {
    let mut m = BTreeMap::new();
    m.insert("warm_waves".into(), dims.warm_waves as f64);
    m.insert("drift_waves".into(), dims.drift_waves as f64);
    m.insert("reqs_per_wave".into(), dims.reqs_per_wave as f64);
    m.insert("req_size".into(), dims.req_size as f64);
    m.insert("pool".into(), pool_len as f64);
    m
}

/// Uniform warm-up waves over the head half of the pool — identical
/// across scenarios so every deployment starts from the same planned
/// state shape.
fn warm_events(pool: &[NodeId], rng: &mut Rng, dims: &TraceDims) -> Vec<TraceEvent> {
    let warm_pool = &pool[..(pool.len() / 2).max(1)];
    let mut events = Vec::new();
    for wave in 0..dims.warm_waves {
        for _ in 0..dims.reqs_per_wave {
            let seeds = (0..dims.req_size)
                .map(|_| warm_pool[rng.gen_usize(warm_pool.len())])
                .collect();
            events.push(TraceEvent {
                wave: wave as u32,
                class: TenantClass::Standard,
                seeds,
            });
        }
    }
    events
}

/// Sudden 100× hot-set shift: warm traffic is uniform, then the stream
/// collapses onto a tiny hot set from the tail of the pool (each hot
/// seed served ~100× more often than any warm-phase node), with a
/// trickle of uniform background.
pub struct FlashCrowd;

impl Scenario for FlashCrowd {
    fn id(&self) -> &'static str {
        "flash_crowd"
    }

    fn describe(&self) -> &'static str {
        "sudden 100x hot-set shift onto a tiny tail working set"
    }

    fn generate(&self, pool: &[NodeId], seed: u64, dims: &TraceDims) -> Trace {
        let mut rng = scenario_rng(self.id(), seed);
        let mut events = warm_events(pool, &mut rng, dims);
        // the hot set: ~1% of the pool (floored at one request's worth),
        // drawn from the tail half the warm phase never touched
        let tail = &pool[pool.len() / 2..];
        let hot_n = (pool.len() / 100).max(dims.req_size).min(tail.len());
        let hot = &tail[..hot_n];
        let hot_fraction = 0.9;
        for wave in 0..dims.drift_waves {
            for _ in 0..dims.reqs_per_wave {
                let seeds = (0..dims.req_size)
                    .map(|_| {
                        if rng.f64() < hot_fraction {
                            hot[rng.gen_usize(hot.len())]
                        } else {
                            pool[rng.gen_usize(pool.len())]
                        }
                    })
                    .collect();
                events.push(TraceEvent {
                    wave: (dims.warm_waves + wave) as u32,
                    class: TenantClass::Standard,
                    seeds,
                });
            }
        }
        let mut knobs = base_knobs(dims, pool.len());
        knobs.insert("hot_set".into(), hot_n as f64);
        knobs.insert("hot_fraction".into(), hot_fraction);
        Trace { scenario_id: self.id().into(), seed, knobs, events }
    }
}

/// Slow sinusoidal drift: a window of `window_frac` of the pool slides
/// across it and back over the drift waves. The waveform is a triangle
/// approximation of the sinusoid — computed with exact arithmetic so
/// traces stay bit-identical across libm builds.
pub struct Diurnal;

impl Scenario for Diurnal {
    fn id(&self) -> &'static str {
        "diurnal"
    }

    fn describe(&self) -> &'static str {
        "slow sinusoidal (triangle) drift of a sliding hot window"
    }

    fn generate(&self, pool: &[NodeId], seed: u64, dims: &TraceDims) -> Trace {
        let mut rng = scenario_rng(self.id(), seed);
        let mut events = warm_events(pool, &mut rng, dims);
        let window_frac = 0.25;
        let window = ((pool.len() as f64 * window_frac) as usize).max(dims.req_size);
        let span = pool.len().saturating_sub(window).max(1);
        for wave in 0..dims.drift_waves {
            // triangle wave over the drift phase: 0 → 1 → 0 across
            // `drift_waves`, in exact rational arithmetic
            let half = dims.drift_waves.max(2) / 2;
            let phase = if wave <= half {
                wave as f64 / half as f64
            } else {
                (dims.drift_waves - wave) as f64 / (dims.drift_waves - half) as f64
            };
            let start = (phase * span as f64) as usize;
            let w = &pool[start..(start + window).min(pool.len())];
            for _ in 0..dims.reqs_per_wave {
                let seeds =
                    (0..dims.req_size).map(|_| w[rng.gen_usize(w.len())]).collect();
                events.push(TraceEvent {
                    wave: (dims.warm_waves + wave) as u32,
                    class: TenantClass::Standard,
                    seeds,
                });
            }
        }
        let mut knobs = base_knobs(dims, pool.len());
        knobs.insert("window_frac".into(), window_frac);
        Trace { scenario_id: self.id().into(), seed, knobs, events }
    }
}

/// Adversarial cache-busting sequential scans: after the warm phase,
/// requests sweep the pool in stride order under the `scan` admission
/// class, touching everything and re-using nothing — the workload QoS
/// weighting exists to keep *out* of the cache.
pub struct ScanStorm;

impl Scenario for ScanStorm {
    fn id(&self) -> &'static str {
        "scan_storm"
    }

    fn describe(&self) -> &'static str {
        "cache-busting sequential scans under the scan class"
    }

    fn generate(&self, pool: &[NodeId], seed: u64, dims: &TraceDims) -> Trace {
        let mut rng = scenario_rng(self.id(), seed);
        let mut events = warm_events(pool, &mut rng, dims);
        // stride chosen odd so consecutive scans cover different
        // residues before wrapping (coprime with any power-of-two-ish
        // pool layout)
        let stride = 3usize;
        let mut cursor = 0usize;
        for wave in 0..dims.drift_waves {
            for r in 0..dims.reqs_per_wave {
                // one standard request per wave keeps a live signal for
                // the planner; the rest is the storm
                let (class, seeds): (TenantClass, Vec<NodeId>) = if r == 0 {
                    let warm_pool = &pool[..(pool.len() / 2).max(1)];
                    (
                        TenantClass::Standard,
                        (0..dims.req_size)
                            .map(|_| warm_pool[rng.gen_usize(warm_pool.len())])
                            .collect(),
                    )
                } else {
                    let seeds = (0..dims.req_size)
                        .map(|i| pool[(cursor + i * stride) % pool.len()])
                        .collect();
                    cursor = (cursor + dims.req_size * stride) % pool.len();
                    (TenantClass::Scan, seeds)
                };
                events.push(TraceEvent {
                    wave: (dims.warm_waves + wave) as u32,
                    class,
                    seeds,
                });
            }
        }
        let mut knobs = base_knobs(dims, pool.len());
        knobs.insert("stride".into(), stride as f64);
        Trace { scenario_id: self.id().into(), seed, knobs, events }
    }
}

/// Skewed-degree seed selection: requests draw from the pool with a
/// power-law-ish head bias (P(rank < n/2^k) = 2^-k, by repeated
/// halving — pure integer arithmetic, no `powf`). Callers order the
/// pool hottest-first (the bench sorts by degree) so the skew lands on
/// the high-fanout nodes.
pub struct PowerlawFanout;

impl Scenario for PowerlawFanout {
    fn id(&self) -> &'static str {
        "powerlaw_fanout"
    }

    fn describe(&self) -> &'static str {
        "power-law head-biased seed selection over a degree-sorted pool"
    }

    fn generate(&self, pool: &[NodeId], seed: u64, dims: &TraceDims) -> Trace {
        let mut rng = scenario_rng(self.id(), seed);
        let mut events = warm_events(pool, &mut rng, dims);
        for wave in 0..dims.drift_waves {
            for _ in 0..dims.reqs_per_wave {
                let seeds = (0..dims.req_size)
                    .map(|_| {
                        // geometric range-halving: each coin flip halves
                        // the candidate prefix, biasing hard toward the
                        // head of the (degree-sorted) pool
                        let mut range = pool.len();
                        while range > 1 && rng.next_u64() & 1 == 1 {
                            range /= 2;
                        }
                        pool[rng.gen_usize(range)]
                    })
                    .collect();
                events.push(TraceEvent {
                    wave: (dims.warm_waves + wave) as u32,
                    class: TenantClass::Standard,
                    seeds,
                });
            }
        }
        let knobs = base_knobs(dims, pool.len());
        Trace { scenario_id: self.id().into(), seed, knobs, events }
    }
}

/// Temporally clustered repeats: traffic arrives in bursts, each burst
/// pinning a small locality set and replaying it for several
/// consecutive requests before moving on — high short-range reuse,
/// little long-range reuse.
pub struct BurstLocality;

impl Scenario for BurstLocality {
    fn id(&self) -> &'static str {
        "burst_locality"
    }

    fn describe(&self) -> &'static str {
        "temporally clustered repeats over per-burst locality sets"
    }

    fn generate(&self, pool: &[NodeId], seed: u64, dims: &TraceDims) -> Trace {
        let mut rng = scenario_rng(self.id(), seed);
        let mut events = warm_events(pool, &mut rng, dims);
        let burst_len = 4usize;
        let locality = (dims.req_size * 2).min(pool.len());
        let mut burst_left = 0usize;
        let mut set: Vec<NodeId> = Vec::new();
        for wave in 0..dims.drift_waves {
            for _ in 0..dims.reqs_per_wave {
                if burst_left == 0 {
                    set = (0..locality)
                        .map(|_| pool[rng.gen_usize(pool.len())])
                        .collect();
                    burst_left = burst_len;
                }
                burst_left -= 1;
                let seeds =
                    (0..dims.req_size).map(|_| set[rng.gen_usize(set.len())]).collect();
                events.push(TraceEvent {
                    wave: (dims.warm_waves + wave) as u32,
                    class: TenantClass::Standard,
                    seeds,
                });
            }
        }
        let mut knobs = base_knobs(dims, pool.len());
        knobs.insert("burst_len".into(), burst_len as f64);
        knobs.insert("locality".into(), locality as f64);
        Trace { scenario_id: self.id().into(), seed, knobs, events }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn pool(n: usize) -> Vec<NodeId> {
        (0..n as NodeId).collect()
    }

    #[test]
    fn registry_matches_ids() {
        let zoo = registry();
        assert_eq!(zoo.len(), SCENARIO_IDS.len());
        for (sc, id) in zoo.iter().zip(SCENARIO_IDS) {
            assert_eq!(sc.id(), id);
            assert!(is_known(id));
            assert!(by_id(id).is_some());
            assert!(!sc.describe().is_empty());
        }
        assert!(by_id("nope").is_none());
        assert!(!is_known("nope"));
    }

    #[test]
    fn traces_have_expected_shape() {
        let dims = TraceDims::quick();
        let p = pool(400);
        for sc in registry() {
            let t = sc.generate(&p, 7, &dims);
            assert_eq!(t.scenario_id, sc.id());
            assert_eq!(t.seed, 7);
            assert_eq!(
                t.events.len(),
                (dims.warm_waves + dims.drift_waves) * dims.reqs_per_wave,
                "{}",
                sc.id()
            );
            assert_eq!(
                t.warm_events().len(),
                dims.warm_waves * dims.reqs_per_wave,
                "{}",
                sc.id()
            );
            assert_eq!(t.last_wave_events().len(), dims.reqs_per_wave, "{}", sc.id());
            for e in &t.events {
                assert_eq!(e.seeds.len(), dims.req_size);
                assert!(e.seeds.iter().all(|&v| (v as usize) < p.len()));
            }
            // every seed the generator drew is in range and the knob
            // record is self-describing
            assert_eq!(t.knobs["pool"], p.len() as f64);
            assert_eq!(t.knobs["req_size"], dims.req_size as f64);
        }
    }

    #[test]
    fn scan_storm_tags_the_scan_class() {
        let t = ScanStorm.generate(&pool(300), 1, &TraceDims::quick());
        assert!(t.live_events().iter().any(|e| e.class == TenantClass::Scan));
        assert!(t.warm_events().iter().all(|e| e.class == TenantClass::Standard));
    }

    #[test]
    fn generation_is_pure() {
        let dims = TraceDims::quick();
        let p = pool(500);
        for sc in registry() {
            let a = sc.generate(&p, 42, &dims);
            let b = sc.generate(&p, 42, &dims);
            assert_eq!(a, b, "{} not pure", sc.id());
            assert_eq!(a.to_canonical_string(), b.to_canonical_string());
            // a different seed must actually change the stream
            let c = sc.generate(&p, 43, &dims);
            assert_ne!(
                a.to_canonical_string(),
                c.to_canonical_string(),
                "{} ignores its seed",
                sc.id()
            );
        }
    }

    #[test]
    fn serialize_parse_roundtrip() {
        let dims = TraceDims::quick();
        let p = pool(300);
        for sc in registry() {
            let t = sc.generate(&p, 9, &dims);
            let text = t.to_canonical_string();
            let back = Trace::parse(&text).unwrap();
            assert_eq!(back, t, "{}", sc.id());
            // canonical: re-serializing the parsed trace reproduces the
            // bytes
            assert_eq!(back.to_canonical_string(), text, "{}", sc.id());
        }
    }

    #[test]
    fn parse_rejects_malformed() {
        // wrong schema
        let bad = r#"{"schema":99,"scenario_id":"x","seed":1,"knobs":{},"events":[{"wave":0,"class":"standard","seeds":[1]}]}"#;
        assert!(Trace::parse(bad).is_err());
        // unknown class
        let bad = r#"{"schema":1,"scenario_id":"x","seed":1,"knobs":{},"events":[{"wave":0,"class":"vip","seeds":[1]}]}"#;
        assert!(Trace::parse(bad).is_err());
        // empty events / empty seeds
        let bad = r#"{"schema":1,"scenario_id":"x","seed":1,"knobs":{},"events":[]}"#;
        assert!(Trace::parse(bad).is_err());
        let bad = r#"{"schema":1,"scenario_id":"x","seed":1,"knobs":{},"events":[{"wave":0,"class":"scan","seeds":[]}]}"#;
        assert!(Trace::parse(bad).is_err());
        // missing keys
        assert!(Trace::parse(r#"{"schema":1}"#).is_err());
        assert!(Trace::parse("not json").is_err());
    }
}
