//! Shared bench harness: each `rust/benches/*.rs` binary regenerates
//! one of the paper's tables/figures through these helpers (no
//! criterion in the offline registry; these benches are comparative
//! system runs, not ns-level microbenches anyway).

pub mod bundle;
pub mod scenario;

use std::time::Instant;

use anyhow::Result;

use crate::config::RunConfig;
use crate::engine::{run_config, InferenceReport};
use crate::util::json::{num, obj, s, Json};
use crate::util::table::Table;

/// Bench-wide options from argv: `--quick` shrinks workloads (CI),
/// `--json <path>` additionally dumps machine-readable rows, and
/// `--bundle <dir>` seals the run's outputs into a manifest-hashed
/// [`bundle::RunBundle`].
#[derive(Debug, Clone)]
pub struct BenchOpts {
    pub quick: bool,
    pub json_path: Option<String>,
    pub bundle_dir: Option<String>,
}

impl BenchOpts {
    pub fn from_env() -> BenchOpts {
        let args: Vec<String> = std::env::args().collect();
        let quick = args.iter().any(|a| a == "--quick")
            || std::env::var("DCI_BENCH_QUICK").is_ok();
        let json_path = args
            .iter()
            .position(|a| a == "--json")
            .and_then(|i| args.get(i + 1).cloned());
        let bundle_dir = args
            .iter()
            .position(|a| a == "--bundle")
            .and_then(|i| args.get(i + 1).cloned())
            .or_else(|| std::env::var("DCI_BENCH_BUNDLE").ok());
        BenchOpts { quick, json_path, bundle_dir }
    }

    /// Batch cap for full runs vs. quick runs.
    pub fn max_batches(&self, full: usize, quick: usize) -> Option<usize> {
        Some(if self.quick { quick } else { full })
    }

    /// Like [`from_env`](Self::from_env), but the bench always writes
    /// machine-readable output — to `default_path` unless `--json
    /// <path>` overrides it. Benches that feed the cross-PR perf
    /// trajectory (`BENCH_*.json`) use this so the numbers exist on
    /// every run, not only when someone remembers the flag.
    pub fn from_env_default_json(default_path: &str) -> BenchOpts {
        let mut opts = Self::from_env();
        if opts.json_path.is_none() {
            opts.json_path = Some(default_path.to_string());
        }
        opts
    }
}

/// One labelled run: execute the config, return its report, and log a
/// one-liner so long benches show progress.
pub fn run_labelled(label: &str, cfg: &RunConfig) -> Result<InferenceReport> {
    let t0 = Instant::now();
    let report = run_config(cfg)?;
    eprintln!(
        "  [{label}] total={:.1}ms prep={:.1}ms preproc={:.1}ms hit(adj)={:.2} hit(feat)={:.2} ({:.1}s wall)",
        report.total_ns() / 1e6,
        report.prep_ns() / 1e6,
        report.preprocess_ns / 1e6,
        report.stats.adj_hit_ratio(),
        report.stats.feat_hit_ratio(),
        t0.elapsed().as_secs_f64(),
    );
    Ok(report)
}

/// Accumulates result rows for the table + optional JSON dump.
pub struct BenchReport {
    title: String,
    table: Table,
    rows_json: Vec<Json>,
}

impl BenchReport {
    pub fn new(title: &str, header: &[&str]) -> Self {
        BenchReport {
            title: title.to_string(),
            table: Table::new(header),
            rows_json: Vec::new(),
        }
    }

    pub fn row(&mut self, cells: &[String], json_pairs: Vec<(&str, Json)>) {
        self.table.row(cells);
        self.rows_json.push(obj(json_pairs));
    }

    /// Print the table; write JSON if requested. With `--bundle <dir>`
    /// (or `DCI_BENCH_BUNDLE`), the bench JSON is additionally sealed
    /// into a manifest-hashed run bundle — every bench gets
    /// reproducible artifacts without per-bench wiring.
    pub fn finish(self, opts: &BenchOpts) -> Result<()> {
        println!("\n=== {} ===", self.title);
        print!("{}", self.table.render());
        if let Some(path) = &opts.json_path {
            let doc = obj(vec![
                ("bench", s(&self.title)),
                ("quick", Json::Bool(opts.quick)),
                ("rows", Json::Arr(self.rows_json)),
            ]);
            std::fs::write(path, doc.to_string())?;
            eprintln!("wrote {path}");
            if let Some(dir) = &opts.bundle_dir {
                let name = std::path::Path::new(path)
                    .file_name()
                    .map(|n| n.to_string_lossy().to_string())
                    .unwrap_or_else(|| path.clone());
                let mut b = bundle::RunBundle::create(dir)?;
                b.copy_file(path, &name)?;
                b.set_meta("bench", s(&self.title));
                b.set_meta("quick", Json::Bool(opts.quick));
                let digest = b.finalize()?;
                eprintln!("sealed bundle {dir} (manifest_sha256 {digest})");
            }
        }
        Ok(())
    }
}

/// ns → "1.23s"/"45.6ms" strings for table cells.
pub fn fmt_ms(ns: f64) -> String {
    if ns >= 1e9 {
        format!("{:.2}s", ns / 1e9)
    } else {
        format!("{:.1}ms", ns / 1e6)
    }
}

/// speedup "×" cell.
pub fn fmt_speedup(base_ns: f64, other_ns: f64) -> String {
    if other_ns <= 0.0 {
        "-".into()
    } else {
        format!("{:.2}x", base_ns / other_ns)
    }
}

/// JSON number helper.
pub fn jnum(x: f64) -> Json {
    num(x)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::config::SystemKind;
    use crate::sampler::Fanout;

    #[test]
    fn formats() {
        assert_eq!(fmt_ms(1.5e9), "1.50s");
        assert_eq!(fmt_ms(2.5e6), "2.5ms");
        assert_eq!(fmt_speedup(10.0, 5.0), "2.00x");
        assert_eq!(fmt_speedup(10.0, 0.0), "-");
    }

    #[test]
    fn default_json_path_applies() {
        // (argv has no --json in the test harness)
        let opts = BenchOpts::from_env_default_json("BENCH_x.json");
        assert_eq!(opts.json_path.as_deref(), Some("BENCH_x.json"));
    }

    #[test]
    fn bench_report_renders() {
        let mut r = BenchReport::new("test", &["a", "b"]);
        r.row(&["x".into(), "1".into()], vec![("a", s("x")), ("b", jnum(1.0))]);
        // finish prints; just ensure no error without json
        r.finish(&BenchOpts { quick: true, json_path: None, bundle_dir: None })
            .unwrap();
    }

    #[test]
    fn finish_seals_a_verifiable_bundle() {
        let base = std::env::temp_dir()
            .join(format!("dci_finish_bundle_{}", std::process::id()));
        let _ = std::fs::remove_dir_all(&base);
        std::fs::create_dir_all(&base).unwrap();
        let json = base.join("BENCH_t.json");
        let bdir = base.join("bundle");
        let mut r = BenchReport::new("t", &["a"]);
        r.row(&["1".into()], vec![("a", jnum(1.0))]);
        r.finish(&BenchOpts {
            quick: true,
            json_path: Some(json.to_string_lossy().into_owned()),
            bundle_dir: Some(bdir.to_string_lossy().into_owned()),
        })
        .unwrap();
        bundle::verify(&bdir).unwrap();
        assert!(bdir.join("BENCH_t.json").exists());
        std::fs::remove_dir_all(&base).unwrap();
    }

    #[test]
    fn run_labelled_tiny() {
        let mut cfg = RunConfig::default();
        cfg.dataset = "tiny".into();
        cfg.system = SystemKind::Dgl;
        cfg.batch_size = 64;
        cfg.fanout = Fanout::parse("2,2").unwrap();
        cfg.max_batches = Some(2);
        let rep = run_labelled("t", &cfg).unwrap();
        assert_eq!(rep.n_batches, 2);
    }
}
