//! Deterministic run bundles: a bench run's trace, resolved knobs,
//! `BENCH_*.json` reports, and metrics snapshots in one directory with
//! a sha256 manifest.
//!
//! The manifest contract (golden-bundle discipline):
//! - `manifest.json` lists every other file in the bundle as
//!   `{path, bytes, sha256}`, sorted by path, plus a `meta` object of
//!   run identity (trace id, seed, config summary).
//! - `manifest_sha256` is the SHA-256 of the manifest's canonical JSON
//!   *without* the `manifest_sha256` field itself.
//! - The manifest is **float-free** (strings, booleans and integers
//!   only, enforced at [`RunBundle::finalize`]) so Python's
//!   `json.dumps(obj, sort_keys=True, separators=(",",":"),
//!   ensure_ascii=False)` reproduces the exact bytes and CI
//!   (`ci/verify_bundle.py`) can re-verify the hash from uploaded
//!   artifacts without a Rust toolchain.
//!
//! [`verify`] is the in-process mirror of that CI check: it recomputes
//! every file digest plus the manifest digest and fails on tampered,
//! missing, or extra files.

use std::collections::BTreeMap;
use std::fs;
use std::path::{Path, PathBuf};

use anyhow::{bail, ensure, Context, Result};

use crate::util::json::Json;
use crate::util::sha256_hex;

/// Manifest schema version (bumped on any breaking layout change).
pub const BUNDLE_SCHEMA: u64 = 1;

const MANIFEST: &str = "manifest.json";

/// A run-bundle directory being assembled. Files land via
/// [`RunBundle::write_file`] / [`RunBundle::copy_file`], run identity
/// via [`RunBundle::set_meta`]; [`RunBundle::finalize`] seals the
/// manifest.
pub struct RunBundle {
    dir: PathBuf,
    meta: BTreeMap<String, Json>,
}

impl RunBundle {
    /// Create (or wipe and re-create) the bundle directory. A stale
    /// bundle at the same path is removed so leftover files can never
    /// leak into the new manifest.
    pub fn create(dir: impl AsRef<Path>) -> Result<RunBundle> {
        let dir = dir.as_ref().to_path_buf();
        if dir.exists() {
            fs::remove_dir_all(&dir)
                .with_context(|| format!("removing stale bundle {}", dir.display()))?;
        }
        fs::create_dir_all(&dir)
            .with_context(|| format!("creating bundle {}", dir.display()))?;
        Ok(RunBundle { dir, meta: BTreeMap::new() })
    }

    /// The bundle directory.
    pub fn dir(&self) -> &Path {
        &self.dir
    }

    /// Absolute path of a bundle member (for callers that stream their
    /// own output, e.g. a bench pointing its `--json` at the bundle).
    pub fn path_of(&self, name: &str) -> PathBuf {
        self.dir.join(name)
    }

    /// Write `contents` as bundle member `name` (flat names only — the
    /// manifest scan is non-recursive by design).
    pub fn write_file(&self, name: &str, contents: &str) -> Result<()> {
        ensure!(
            !name.contains('/') && !name.contains('\\'),
            "bundle member {name:?} must be a flat file name"
        );
        ensure!(name != MANIFEST, "{MANIFEST} is reserved for finalize()");
        fs::write(self.dir.join(name), contents)
            .with_context(|| format!("writing bundle member {name}"))
    }

    /// Copy an existing file into the bundle under `name`.
    pub fn copy_file(&self, src: impl AsRef<Path>, name: &str) -> Result<()> {
        let text = fs::read_to_string(src.as_ref())
            .with_context(|| format!("reading {}", src.as_ref().display()))?;
        self.write_file(name, &text)
    }

    /// Record a run-identity key in the manifest `meta` object. Values
    /// must be strings, booleans or integers (checked again, with the
    /// key named, at finalize) — floats are banned from the manifest so
    /// its canonical bytes are reproducible from Python.
    pub fn set_meta(&mut self, key: &str, value: Json) {
        self.meta.insert(key.to_string(), value);
    }

    /// Seal the bundle: scan the directory (sorted, non-recursive),
    /// fingerprint every member, embed the meta object, compute
    /// `manifest_sha256` over the manifest-without-that-field, and
    /// write `manifest.json`. Returns the manifest digest.
    pub fn finalize(self) -> Result<String> {
        for (k, v) in &self.meta {
            ensure!(
                manifest_safe(v),
                "manifest meta {k:?} must be a string/bool/integer (floats break \
                 cross-language canonical JSON)"
            );
        }
        let mut names = list_members(&self.dir)?;
        names.sort();
        ensure!(!names.is_empty(), "bundle {} has no files", self.dir.display());

        let files: Vec<Json> = names
            .iter()
            .map(|name| {
                let bytes = fs::read(self.dir.join(name))
                    .with_context(|| format!("reading bundle member {name}"))?;
                Ok(Json::Obj(BTreeMap::from([
                    ("bytes".to_string(), Json::Num(bytes.len() as f64)),
                    ("path".to_string(), Json::Str(name.clone())),
                    ("sha256".to_string(), Json::Str(sha256_hex(&bytes))),
                ])))
            })
            .collect::<Result<_>>()?;

        let mut manifest = BTreeMap::from([
            ("bundle_schema".to_string(), Json::Num(BUNDLE_SCHEMA as f64)),
            ("files".to_string(), Json::Arr(files)),
            ("meta".to_string(), Json::Obj(self.meta.clone())),
        ]);
        let digest = sha256_hex(Json::Obj(manifest.clone()).to_string().as_bytes());
        manifest.insert("manifest_sha256".to_string(), Json::Str(digest.clone()));
        fs::write(self.dir.join(MANIFEST), Json::Obj(manifest).to_string())
            .with_context(|| format!("writing {MANIFEST}"))?;
        Ok(digest)
    }
}

/// Verify a sealed bundle: every listed file exists with the recorded
/// size and sha256, no unlisted files are present, and the recomputed
/// `manifest_sha256` matches the embedded one. Returns the digest.
pub fn verify(dir: impl AsRef<Path>) -> Result<String> {
    let dir = dir.as_ref();
    let text = fs::read_to_string(dir.join(MANIFEST))
        .with_context(|| format!("reading {}", dir.join(MANIFEST).display()))?;
    let manifest = Json::parse(&text).context("parsing manifest.json")?;
    let schema = manifest.req("bundle_schema")?.as_u64()?;
    ensure!(
        schema == BUNDLE_SCHEMA,
        "bundle schema {schema} unsupported (this build reads {BUNDLE_SCHEMA})"
    );
    let recorded = manifest.req("manifest_sha256")?.as_str()?.to_string();

    // recompute the manifest digest over the canonical bytes without
    // the manifest_sha256 field
    let without = match &manifest {
        Json::Obj(m) => {
            let mut m = m.clone();
            m.remove("manifest_sha256");
            Json::Obj(m)
        }
        _ => bail!("manifest.json is not an object"),
    };
    let digest = sha256_hex(without.to_string().as_bytes());
    ensure!(
        digest == recorded,
        "manifest_sha256 mismatch: recorded {recorded}, recomputed {digest}"
    );

    // recompute every member digest and catch extras
    let mut listed = Vec::new();
    for f in manifest.req("files")?.as_arr()? {
        let path = f.req("path")?.as_str()?.to_string();
        let want_sha = f.req("sha256")?.as_str()?;
        let want_bytes = f.req("bytes")?.as_u64()? as usize;
        let bytes = fs::read(dir.join(&path))
            .with_context(|| format!("bundle member {path} missing"))?;
        ensure!(
            bytes.len() == want_bytes,
            "bundle member {path}: {} bytes, manifest says {want_bytes}",
            bytes.len()
        );
        let got = sha256_hex(&bytes);
        ensure!(
            got == want_sha,
            "bundle member {path} tampered: sha256 {got}, manifest says {want_sha}"
        );
        listed.push(path);
    }
    let on_disk = list_members(dir)?;
    for name in &on_disk {
        ensure!(
            listed.contains(name),
            "unlisted file {name} in bundle {}",
            dir.display()
        );
    }
    ensure!(
        listed.len() == on_disk.len(),
        "manifest lists {} files, bundle has {}",
        listed.len(),
        on_disk.len()
    );
    Ok(digest)
}

/// Non-recursive member listing, excluding the manifest itself.
fn list_members(dir: &Path) -> Result<Vec<String>> {
    let mut names = Vec::new();
    for entry in
        fs::read_dir(dir).with_context(|| format!("listing {}", dir.display()))?
    {
        let entry = entry?;
        if !entry.file_type()?.is_file() {
            continue;
        }
        let name = entry.file_name().to_string_lossy().to_string();
        if name != MANIFEST {
            names.push(name);
        }
    }
    names.sort();
    Ok(names)
}

/// Whether `v` may appear in the manifest: strings, booleans, and
/// integral numbers the canonical writer emits without a decimal point.
fn manifest_safe(v: &Json) -> bool {
    match v {
        Json::Str(_) | Json::Bool(_) => true,
        Json::Num(x) => x.is_finite() && x.fract() == 0.0 && x.abs() < 1e15,
        _ => false,
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::json::{num, s};

    fn tmp(tag: &str) -> PathBuf {
        let d = std::env::temp_dir()
            .join(format!("dci_bundle_{tag}_{}", std::process::id()));
        let _ = fs::remove_dir_all(&d);
        d
    }

    fn make(tag: &str) -> (PathBuf, String) {
        let dir = tmp(tag);
        let mut b = RunBundle::create(&dir).unwrap();
        b.write_file("trace_flash_crowd.json", "{\"x\":1}").unwrap();
        b.write_file("BENCH_scenarios.json", "{\"bench\":\"scenarios\"}").unwrap();
        b.set_meta("scenario_id", s("flash_crowd"));
        b.set_meta("seed", num(7.0));
        let digest = b.finalize().unwrap();
        (dir, digest)
    }

    #[test]
    fn finalize_then_verify_roundtrips() {
        let (dir, digest) = make("roundtrip");
        assert_eq!(digest.len(), 64);
        assert_eq!(verify(&dir).unwrap(), digest);
        fs::remove_dir_all(&dir).unwrap();
    }

    #[test]
    fn finalize_is_deterministic() {
        let (d1, dg1) = make("det_a");
        let (d2, dg2) = make("det_b");
        assert_eq!(dg1, dg2);
        assert_eq!(
            fs::read_to_string(d1.join(MANIFEST)).unwrap(),
            fs::read_to_string(d2.join(MANIFEST)).unwrap()
        );
        fs::remove_dir_all(&d1).unwrap();
        fs::remove_dir_all(&d2).unwrap();
    }

    #[test]
    fn tampering_fails_verify() {
        let (dir, _) = make("tamper");
        fs::write(dir.join("BENCH_scenarios.json"), "{\"bench\":\"evil\"}").unwrap();
        let err = verify(&dir).unwrap_err().to_string();
        assert!(err.contains("tampered") || err.contains("bytes"), "{err}");
        fs::remove_dir_all(&dir).unwrap();
    }

    #[test]
    fn extra_file_fails_verify() {
        let (dir, _) = make("extra");
        fs::write(dir.join("stray.json"), "{}").unwrap();
        let err = verify(&dir).unwrap_err().to_string();
        assert!(err.contains("unlisted"), "{err}");
        fs::remove_dir_all(&dir).unwrap();
    }

    #[test]
    fn missing_file_fails_verify() {
        let (dir, _) = make("missing");
        fs::remove_file(dir.join("trace_flash_crowd.json")).unwrap();
        assert!(verify(&dir).is_err());
        fs::remove_dir_all(&dir).unwrap();
    }

    #[test]
    fn edited_manifest_fails_verify() {
        let (dir, _) = make("manifest_edit");
        let text = fs::read_to_string(dir.join(MANIFEST)).unwrap();
        fs::write(dir.join(MANIFEST), text.replace("flash_crowd", "flash_cr0wd"))
            .unwrap();
        let err = verify(&dir).unwrap_err().to_string();
        assert!(err.contains("manifest_sha256 mismatch"), "{err}");
        fs::remove_dir_all(&dir).unwrap();
    }

    #[test]
    fn float_meta_is_rejected() {
        let dir = tmp("floatmeta");
        let mut b = RunBundle::create(&dir).unwrap();
        b.write_file("x.json", "{}").unwrap();
        b.set_meta("ratio", num(0.5));
        let err = b.finalize().unwrap_err().to_string();
        assert!(err.contains("floats"), "{err}");
        fs::remove_dir_all(&dir).unwrap();
    }

    #[test]
    fn nested_and_reserved_names_are_rejected() {
        let dir = tmp("names");
        let b = RunBundle::create(&dir).unwrap();
        assert!(b.write_file("sub/dir.json", "{}").is_err());
        assert!(b.write_file(MANIFEST, "{}").is_err());
        fs::remove_dir_all(&dir).unwrap();
    }

    #[test]
    fn create_wipes_stale_bundles() {
        let dir = tmp("stale");
        fs::create_dir_all(&dir).unwrap();
        fs::write(dir.join("leftover.json"), "{}").unwrap();
        let mut b = RunBundle::create(&dir).unwrap();
        b.write_file("fresh.json", "{}").unwrap();
        b.set_meta("run", s("second"));
        b.finalize().unwrap();
        assert!(!dir.join("leftover.json").exists());
        verify(&dir).unwrap();
        fs::remove_dir_all(&dir).unwrap();
    }
}
