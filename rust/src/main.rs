//! `dci` — the leader binary.
//!
//! ```text
//! dci infer   [key=value ...]   run one inference configuration, print the report
//! dci serve   [key=value ...]   start the serving coordinator + synthetic clients
//! dci presample [key=value ...] show the pre-sampling profile + Eq.(1) split
//! dci datasets                  list registered datasets
//! dci inspect [dataset=NAME]    dataset statistics
//! ```
//!
//! Config keys are shared with the bench harness — see
//! `rust/src/config.rs` (`dataset=`, `model=`, `fanout=`, `bs=`,
//! `system=`, `budget=`, `compute=`, ...) plus per-command extras
//! documented below.

use std::sync::Arc;
use std::time::Duration;

use anyhow::{bail, Result};

use dci::bench_support::scenario;
use dci::config::RunConfig;
use dci::coordinator::{BatcherConfig, Server, ServerConfig};
use dci::engine::run_config;
use dci::graph::{datasets, mutation_stream, MutationSpec};
use dci::mem::DeviceMemory;
use dci::sampler::presample_threads;
use dci::util::{format_bytes, Rng};

fn main() {
    let args: Vec<String> = std::env::args().skip(1).collect();
    if let Err(e) = dispatch(&args) {
        eprintln!("error: {e:#}");
        std::process::exit(1);
    }
}

fn dispatch(args: &[String]) -> Result<()> {
    let Some(cmd) = args.first() else {
        print_usage();
        return Ok(());
    };
    let rest = &args[1..];
    match cmd.as_str() {
        "infer" => cmd_infer(rest),
        "serve" => cmd_serve(rest),
        "presample" => cmd_presample(rest),
        "datasets" => cmd_datasets(),
        "inspect" => cmd_inspect(rest),
        "generate" => cmd_generate(rest),
        "help" | "--help" | "-h" => {
            print_usage();
            Ok(())
        }
        other => bail!("unknown command {other:?}; try `dci help`"),
    }
}

fn print_usage() {
    println!(
        "dci — workload-aware dual-cache GNN inference\n\n\
         commands:\n\
         \x20 infer     [key=value ...]  run one configuration, print stage report\n\
         \x20 serve     [key=value ...]  serving coordinator + synthetic clients\n\
         \x20 presample [key=value ...]  pre-sampling profile + Eq.(1) split\n\
         \x20 datasets                   list datasets\n\
         \x20 inspect   [dataset=NAME]   dataset statistics\n\
         \x20 generate  dataset=NAME out=FILE   materialize + serialize a dataset\n\n\
         common keys: dataset= model= fanout= bs= system= budget= presample=\n\
         \x20            compute= max-batches= device= seed= artifacts=\n\
         \x20            pipeline= sample-threads=   (pipeline=1 is serial)\n\
         \x20            shards=   (cache snapshot sharded over N devices; 1 = single)\n\
         \x20            transfer-ring=   (staged H2D copies in flight; 0 = per-row\n\
         \x20             UVA misses, >=1 stages misses through the pinned pool)\n\
         \x20            staging-buffers=   (pinned staging pool size; floored at\n\
         \x20             pipeline depth + ring + 2 when the ring is on)\n\
         \x20            device-tiers=CAP[:GBPS],...   (heterogeneous shard devices:\n\
         \x20             per-shard capacity + H2D bandwidth; off = uniform)\n\
         serve keys:  workers= requests= req-size= batch-wait-ms= tenant-mix=on|off\n\
         \x20            refresh=on|off refresh-check-ms= refresh-min-batches=\n\
         \x20            refresh-decay= drift-threshold=   (online re-planning)\n\
         \x20            shard-refresh=on|off   (re-plan only drifted shards | all)\n\
         \x20            rebalance=on|off rebalance-threshold= rebalance-floor=\n\
         \x20            (elastic budgets: re-split the global budget across\n\
         \x20             shards when the shard-level load mass skews)\n\
         \x20            auto-budget-refresh=on|off   (budget=auto runs re-track\n\
         \x20             the workload's peak claim per epoch)\n\
         \x20            tracker=dense|sketch sketch-width= sketch-depth=\n\
         \x20            (workload tracker: exact counters | count-min sketch\n\
         \x20             with O(touched) drain; sketch-* keys imply tracker=sketch)\n\
         \x20            tenant.weights=P,S,C   (class-weighted refresh planning)\n\
         \x20            tenant.shed-standard= tenant.shed-scan=   (per-class queue\n\
         \x20             fraction in [0,1]; the class sheds above it under load)\n\
         \x20            scenario=flash_crowd|diurnal|scan_storm|powerlaw_fanout|\n\
         \x20             burst_locality   (workload-zoo request stream; scenario.seed=\n\
         \x20             reseeds generation) trace=FILE   (replay a canonical JSON\n\
         \x20             trace instead; wins over scenario=)\n\
         \x20            graph.mutate=N[@SEED]   (live graph: apply N seeded edge\n\
         \x20             inserts concurrent with serving, epoch-swapped snapshots)\n\
         \x20            graph.compact-batches=K   (fold the delta into a new base\n\
         \x20             CSR every K mutation waves; unset = compact once at end)\n\
         \x20            refresh.mutation-boost=B   (tracker mass multiplier for\n\
         \x20             mutated nodes; drives re-caching at the next re-plan)\n\n\
         config keys accept dotted namespaces (cache.* refresh.* transfer.*\n\
         fault.* tenant.* scenario.*); the flat spellings above remain as aliases."
    );
}

fn cmd_infer(args: &[String]) -> Result<()> {
    let cfg = RunConfig::from_args(args)?;
    if cfg.refresh.is_some() {
        println!("note: refresh= applies to `dci serve` only; a batch run's \
                  workload cannot drift, so the knobs are ignored here");
    }
    println!("running: {}", cfg.summary());
    let report = run_config(&cfg)?;
    println!("\n== report ({}) ==", report.system.as_str());
    if let Some(oom) = &report.oom {
        println!("!! aborted after {} batches: {oom}", report.n_batches);
        return Ok(());
    }
    println!(
        "batches={} seeds={} loaded-nodes={} (x{:.1} redundancy)",
        report.n_batches,
        report.n_seeds,
        report.loaded_nodes,
        report.loaded_nodes as f64 / report.n_seeds.max(1) as f64
    );
    if let Some(a) = report.alloc {
        println!(
            "cache split: adj={} feat={} (used {})",
            format_bytes(a.c_adj),
            format_bytes(a.c_feat),
            format_bytes(report.cache_bytes)
        );
    }
    let t = report.total_ns();
    let pct = |x: f64| 100.0 * x / t.max(1.0);
    println!(
        "preprocess {:9.1}ms  (excluded from total, as in §V.B)",
        report.preprocess_ns / 1e6
    );
    println!(
        "sampling   {:9.1}ms  ({:4.1}%)  hit-ratio {:.3}",
        report.sample.total_ns() / 1e6,
        pct(report.sample.total_ns()),
        report.stats.adj_hit_ratio()
    );
    println!(
        "loading    {:9.1}ms  ({:4.1}%)  hit-ratio {:.3}",
        report.feature.total_ns() / 1e6,
        pct(report.feature.total_ns()),
        report.stats.feat_hit_ratio()
    );
    println!(
        "compute    {:9.1}ms  ({:4.1}%)",
        report.compute.total_ns() / 1e6,
        pct(report.compute.total_ns())
    );
    println!(
        "total      {:9.1}ms  (prep fraction {:.1}%)",
        t / 1e6,
        100.0 * report.prep_fraction()
    );
    if cfg.pipeline_depth > 1 {
        println!(
            "pipeline   depth={} threads={}  wall {:.1}ms  occupancy: \
             sample {:.0}% load {:.0}% compute {:.0}%",
            cfg.pipeline_depth,
            cfg.sample_threads,
            report.run_wall_ns / 1e6,
            100.0 * report.occupancy(&report.sample),
            100.0 * report.occupancy(&report.feature),
            100.0 * report.occupancy(&report.compute),
        );
    }
    if cfg.transfer_ring >= 1 {
        println!(
            "transfer   ring={}  staged {:.1}ms hidden {:.1}ms (occupancy {:.2})",
            cfg.transfer_ring,
            report.transfer_staged_ns / 1e6,
            report.transfer_hidden_ns / 1e6,
            report.transfer_occupancy(),
        );
        if let Some(s) = &report.staging {
            println!(
                "staging    pool={} leases={} overflow={} peak-leased={} (reuse {:.2})",
                s.pool_buffers,
                s.leases,
                s.fresh_allocs,
                s.peak_leased,
                s.reuse_ratio()
            );
        }
    }
    if report.logits_checksum > 0.0 {
        println!("logits checksum {:.3e}", report.logits_checksum);
    }
    Ok(())
}

fn cmd_serve(args: &[String]) -> Result<()> {
    // split serve-specific keys from engine config keys
    let mut n_workers = 1usize;
    let mut n_requests = 200usize;
    let mut req_size = 16usize;
    let mut batch_wait_ms = 5u64;
    let mut tenant_mix = false;
    let mut cfg_args = Vec::new();
    for a in args {
        match a.split_once('=') {
            Some(("workers", v)) => n_workers = v.parse()?,
            Some(("requests", v)) => n_requests = v.parse()?,
            Some(("req-size", v)) => req_size = v.parse()?,
            Some(("batch-wait-ms", v)) => batch_wait_ms = v.parse()?,
            Some(("tenant-mix", v)) => {
                tenant_mix = match v {
                    "on" => true,
                    "off" => false,
                    _ => bail!("tenant-mix must be on|off, got {v:?}"),
                }
            }
            _ => cfg_args.push(a.clone()),
        }
    }
    let cfg = RunConfig::from_args(&cfg_args)?;
    println!(
        "serving: {} workers={} requests={} req-size={}",
        cfg.summary(),
        n_workers,
        n_requests,
        req_size
    );

    let ds = Arc::new(datasets::spec(&cfg.dataset)?.build());
    let server = Server::start(
        Arc::clone(&ds),
        cfg.clone(),
        ServerConfig {
            n_workers,
            batcher: BatcherConfig {
                batch_size: cfg.batch_size,
                max_wait: Duration::from_millis(batch_wait_ms),
            },
            policy: dci::coordinator::router::RoutePolicy::RoundRobin,
            admission: dci::coordinator::AdmissionConfig {
                class_queue_fraction: cfg.class_queue_fraction,
                ..Default::default()
            },
        },
    )?;

    // live-graph mutation driver: graph.mutate=N[@SEED] applies a
    // seeded insert stream in waves, concurrent with the request
    // stream, and compacts the delta every graph.compact-batches
    // waves. Workers keep serving through every epoch swap — the
    // snapshot handles never block (see graph/delta.rs).
    let mutator = if let Some(spec) = &cfg.graph_mutate {
        let spec = MutationSpec::parse(spec)?;
        let lg = server
            .live_graph()
            .expect("graph.mutate= armed but the server has no live graph");
        let stream = mutation_stream(
            ds.csc.n_nodes(),
            spec.edges,
            spec.seed.unwrap_or(cfg.seed),
        );
        let compact_every = cfg.graph_compact_batches;
        println!(
            "mutating: {} edge inserts in waves (compact every {} waves)",
            stream.len(),
            compact_every.map_or_else(|| "∞".into(), |k| k.to_string()),
        );
        Some(std::thread::spawn(move || {
            let waves = 16usize.min(stream.len().max(1));
            let per = stream.len().div_ceil(waves).max(1);
            for (i, chunk) in stream.chunks(per).enumerate() {
                lg.mutate(chunk);
                if compact_every.is_some_and(|k| (i + 1) % k == 0) {
                    lg.compact();
                }
                std::thread::sleep(Duration::from_millis(1));
            }
            // end compacted: the final epoch's base is the full graph
            lg.compact();
        }))
    } else {
        None
    };

    // request stream: a trace file wins, then a scenario generator,
    // then the uniform synthetic default
    let trace = if let Some(path) = &cfg.trace {
        Some(scenario::Trace::read_file(path)?)
    } else if let Some(name) = &cfg.scenario {
        let sc = scenario::by_id(name)
            .ok_or_else(|| anyhow::anyhow!("unknown scenario {name:?}"))?;
        // geometry from the serve knobs: ~n_requests events total, in
        // 10 waves (2 warm + 8 drift)
        let dims = scenario::TraceDims {
            warm_waves: 2,
            drift_waves: 8,
            reqs_per_wave: (n_requests / 10).max(1),
            req_size,
        };
        Some(sc.generate(&ds.test_nodes, cfg.scenario_seed.unwrap_or(cfg.seed), &dims))
    } else {
        None
    };

    let mut rxs = Vec::with_capacity(n_requests);
    match &trace {
        Some(t) => {
            // trace replay: each event's class prefixes the identity,
            // so the admission frontend sees the scenario's QoS mix
            println!(
                "replaying {} events from {} trace (seed {})",
                t.events.len(),
                t.scenario_id,
                t.seed
            );
            for e in &t.events {
                let identity = format!("{}:trace", e.class.as_str());
                rxs.push(server.submit_as(&identity, e.seeds.clone())?);
            }
        }
        None => {
            // synthetic clients: random test-node requests. With
            // tenant-mix=on the identities cycle through the three
            // admission classes (the prefix is the class tag),
            // exercising the per-class batcher lanes and the tenant
            // ledgers in the final report.
            let clients: &[&str] = if tenant_mix {
                &["priority:svc", "dashboard", "scan:crawler"]
            } else {
                &["anonymous"]
            };
            let mut rng = Rng::new(cfg.seed ^ 0xC11E17);
            for i in 0..n_requests {
                let nodes: Vec<u32> = (0..req_size)
                    .map(|_| ds.test_nodes[rng.gen_usize(ds.test_nodes.len())])
                    .collect();
                rxs.push(server.submit_as(clients[i % clients.len()], nodes)?);
            }
        }
    }
    for rx in rxs {
        rx.recv_timeout(Duration::from_secs(600))
            .map_err(|_| anyhow::anyhow!("response timed out"))?;
    }
    if let Some(j) = mutator {
        j.join()
            .map_err(|_| anyhow::anyhow!("mutation driver panicked"))?;
    }
    let (metrics, elapsed) = server.shutdown()?;
    println!("\n== serving metrics ==\n{}", metrics.report(elapsed));
    if cfg.refresh.is_some() && metrics.refreshes == 0 {
        println!("(refresh enabled; no drift crossed the threshold)");
    }
    Ok(())
}

fn cmd_presample(args: &[String]) -> Result<()> {
    let cfg = RunConfig::from_args(args)?;
    let ds = datasets::spec(&cfg.dataset)?.build();
    let mut rng = Rng::new(cfg.seed);
    let stats = presample_threads(
        &ds.csc,
        &ds.features,
        &ds.test_nodes,
        cfg.batch_size,
        &cfg.fanout,
        cfg.n_presample,
        &cfg.cost,
        &mut rng,
        cfg.sample_threads,
    );
    let device = match cfg.device_capacity {
        Some(cap) => DeviceMemory::new(cap, cap / 24),
        None => DeviceMemory::rtx4090_scaled(ds.spec.scale),
    };
    let total = cfg.budget.unwrap_or_else(|| {
        dci::baselines::auto_budget(
            &device,
            &stats,
            ds.features.row_bytes(),
            cfg.hidden,
            ds.spec.scale,
        )
    });
    let split = dci::cache::allocate(total, &stats);
    println!(
        "pre-sampled {} batches in {:.1}ms wall",
        stats.n_batches,
        stats.wall_ns / 1e6
    );
    println!(
        "t_sample={:.1}ms t_feature={:.1}ms -> sampling fraction {:.3}",
        stats.t_sample_ns / 1e6,
        stats.t_feature_ns / 1e6,
        stats.sample_fraction()
    );
    println!(
        "peak batch inputs={} loaded-nodes={} avg-visits={:.2}",
        stats.max_input_nodes, stats.loaded_nodes, stats.avg_node_visits()
    );
    println!(
        "budget {} -> Eq.(1): C_adj={} C_feat={}",
        format_bytes(total),
        format_bytes(split.c_adj),
        format_bytes(split.c_feat)
    );
    Ok(())
}

fn cmd_generate(args: &[String]) -> Result<()> {
    let mut name = "products-sim".to_string();
    let mut out = None;
    for a in args {
        match a.split_once('=') {
            Some(("dataset", v)) => name = v.to_string(),
            Some(("out", v)) => out = Some(v.to_string()),
            _ => bail!("generate takes dataset= and out= (got {a:?})"),
        }
    }
    let out = out.unwrap_or_else(|| format!("{name}.dci"));
    let spec = datasets::spec(&name)?;
    println!("building {name} ({} nodes)...", spec.n_nodes);
    let ds = spec.build();
    dci::graph::io::save(&ds, &out)?;
    let meta = std::fs::metadata(&out)?;
    println!("wrote {out} ({})", format_bytes(meta.len()));
    Ok(())
}

fn cmd_datasets() -> Result<()> {
    println!(
        "{:<18} {:>10} {:>9} {:>6} {:>8} {:>6}  stands in for",
        "name",
        "nodes",
        "avg-deg",
        "feat",
        "classes",
        "scale"
    );
    for spec in datasets::registry() {
        println!(
            "{:<18} {:>10} {:>9} {:>6} {:>8} {:>6}  {}",
            spec.name,
            spec.n_nodes,
            format!("{:?}", spec.gen).chars().take(9).collect::<String>(),
            spec.feat_dim,
            spec.classes,
            spec.scale,
            spec.stands_in_for
        );
    }
    Ok(())
}

fn cmd_inspect(args: &[String]) -> Result<()> {
    let mut name = "products-sim".to_string();
    for a in args {
        if let Some(("dataset", v)) = a.split_once('=') {
            name = v.to_string();
        }
    }
    let spec = datasets::spec(&name)?;
    println!("building {name}...");
    let ds = spec.build();
    println!(
        "nodes={} edges={} avg-deg={:.1} max-deg={}",
        ds.csc.n_nodes(),
        ds.csc.n_edges(),
        ds.csc.avg_degree(),
        ds.csc.max_degree()
    );
    println!(
        "features: dim={} total={}",
        ds.features.dim(),
        format_bytes(ds.features.bytes_total())
    );
    println!("adjacency: {}", format_bytes(ds.csc.bytes_total()));
    println!("test nodes: {}", ds.test_nodes.len());
    println!("degree gini: {:.3}", dci::graph::generator::degree_gini(&ds.csc));
    println!(
        "simulated device: {}",
        format_bytes(DeviceMemory::rtx4090_scaled(spec.scale).capacity())
    );
    Ok(())
}
