//! Model runtime: loads the AOT HLO artifacts produced by
//! `python/compile/aot.py` and executes them through the `xla` crate's
//! PJRT CPU client. Python is never on this path — the artifacts are
//! self-contained HLO text.
//!
//! - [`artifacts`]: `manifest.json` schema + artifact selection.
//! - [`padding`]: maps dynamic sampled mini-batches onto the fixed
//!   padded shapes the AOT executables expect.
//! - [`pjrt`]: compile + execute via PJRT.
//! - [`reference`]: a pure-Rust GraphSAGE/GCN forward used as a
//!   numerics cross-check and artifact-free fallback in tests.

pub mod artifacts;
pub mod padding;
pub mod pjrt;
pub mod reference;

pub use artifacts::{ArtifactMeta, Manifest};
pub use padding::{pad_batch, PaddedBatch};
pub use pjrt::PjrtRuntime;
pub use reference::RefModel;

use anyhow::Result;

use crate::config::{ComputeKind, ModelKind};
use crate::sampler::MiniBatch;

/// The engine-facing compute backend.
pub enum Compute {
    /// No model execution (preparation-only studies).
    Skip,
    /// Pure-Rust reference forward.
    Reference(RefModel),
    /// AOT artifacts over PJRT.
    Pjrt(PjrtRuntime),
}

impl Compute {
    /// Build the backend for a dataset/model combination.
    pub fn build(
        kind: ComputeKind,
        model: ModelKind,
        feat_dim: usize,
        hidden: usize,
        classes: usize,
        artifacts_dir: &str,
    ) -> Result<Compute> {
        Ok(match kind {
            ComputeKind::Skip => Compute::Skip,
            ComputeKind::Reference => {
                Compute::Reference(RefModel::new(model, feat_dim, hidden, classes, 7))
            }
            ComputeKind::Pjrt => Compute::Pjrt(PjrtRuntime::load(artifacts_dir)?),
        })
    }

    /// Run the model on a gathered mini-batch; returns logits
    /// `[n_seeds, classes]` (row-major), or `None` for `Skip`.
    pub fn run(
        &mut self,
        model: ModelKind,
        x: &[f32],
        feat_dim: usize,
        mb: &MiniBatch,
    ) -> Result<Option<Vec<f32>>> {
        match self {
            Compute::Skip => Ok(None),
            Compute::Reference(m) => Ok(Some(m.forward(x, mb))),
            Compute::Pjrt(rt) => rt.run(model, x, feat_dim, mb).map(Some),
        }
    }
}
