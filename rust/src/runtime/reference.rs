//! Pure-Rust reference GNN forward (same block semantics as
//! `python/compile/model.py`).
//!
//! Two uses: (1) an artifact-free compute backend for tests and small
//! runs; (2) a semantic cross-check that the Rust block/padding
//! conventions agree with the JAX model (shape behaviour, padding
//! invariance). Weights are deterministic from a seed but *not* equal
//! to the JAX weights — bit-level numerics vs. PJRT are pinned by the
//! golden-file test instead (`rust/tests/runtime_pjrt.rs`).

use crate::config::ModelKind;
use crate::sampler::MiniBatch;
use crate::util::Rng;

/// One dense layer's weights.
struct Layer {
    w_self: Vec<f32>,  // [d_in, d_out], graphsage only
    w_neigh: Vec<f32>, // [d_in, d_out]
    b: Vec<f32>,       // [d_out]
    d_in: usize,
    d_out: usize,
}

/// Frozen reference model.
pub struct RefModel {
    kind: ModelKind,
    layers: Vec<Layer>,
    pub feat_dim: usize,
    pub classes: usize,
}

impl RefModel {
    pub fn new(
        kind: ModelKind,
        feat_dim: usize,
        hidden: usize,
        classes: usize,
        seed: u64,
    ) -> RefModel {
        let mut rng = Rng::new(seed ^ 0x9e37);
        let n_layers = 3;
        let mut dims = vec![feat_dim];
        dims.extend(std::iter::repeat(hidden).take(n_layers - 1));
        dims.push(classes);
        let mut layers = Vec::new();
        for l in 0..n_layers {
            let (d_in, d_out) = (dims[l], dims[l + 1]);
            let scale = (2.0 / (d_in + d_out) as f64).sqrt();
            let mut mk = |n: usize| -> Vec<f32> {
                (0..n).map(|_| (rng.normal() * scale) as f32).collect()
            };
            layers.push(Layer {
                w_self: if kind == ModelKind::GraphSage { mk(d_in * d_out) } else { Vec::new() },
                w_neigh: mk(d_in * d_out),
                b: vec![0.0; d_out],
                d_in,
                d_out,
            });
        }
        RefModel { kind, layers, feat_dim, classes }
    }

    /// Forward over gathered input features; returns logits
    /// `[n_seeds, classes]` row-major.
    pub fn forward(&self, x: &[f32], mb: &MiniBatch) -> Vec<f32> {
        let n0 = mb.input_nodes().len();
        assert_eq!(x.len(), n0 * self.feat_dim, "gathered features shape");
        let mut h = x.to_vec();
        let mut h_rows = n0;
        for (l, (layer, blk)) in self.layers.iter().zip(&mb.layers).enumerate() {
            let last = l == self.layers.len() - 1;
            let n_dst = blk.n_dst;
            let d_in = layer.d_in;
            let d_out = layer.d_out;
            debug_assert!(n_dst <= h_rows);
            // aggregate neighbors
            let mut agg = vec![0.0f32; n_dst * d_in];
            for d in 0..n_dst {
                let row = &mut agg[d * d_in..(d + 1) * d_in];
                let mut cnt = 0.0f32;
                for s in 0..blk.k {
                    let at = d * blk.k + s;
                    if blk.mask[at] != 0.0 {
                        let src = blk.idx[at] as usize;
                        let hrow = &h[src * d_in..(src + 1) * d_in];
                        for (r, &v) in row.iter_mut().zip(hrow) {
                            *r += v;
                        }
                        cnt += 1.0;
                    }
                }
                if self.kind == ModelKind::Gcn {
                    // average including self
                    let selfrow: Vec<f32> =
                        h[d * d_in..(d + 1) * d_in].to_vec();
                    for (r, &v) in row.iter_mut().zip(&selfrow) {
                        *r = (*r + v) / (cnt + 1.0);
                    }
                }
            }
            // transform
            let mut out = vec![0.0f32; n_dst * d_out];
            matmul_acc(&agg, &layer.w_neigh, &mut out, n_dst, d_in, d_out);
            if self.kind == ModelKind::GraphSage {
                matmul_acc(&h[..n_dst * d_in], &layer.w_self, &mut out, n_dst, d_in, d_out);
            }
            for d in 0..n_dst {
                for j in 0..d_out {
                    let v = out[d * d_out + j] + layer.b[j];
                    out[d * d_out + j] = if last { v } else { v.max(0.0) };
                }
            }
            h = out;
            h_rows = n_dst;
        }
        debug_assert_eq!(h_rows, mb.seeds().len());
        h
    }
}

/// out += a @ w  (a: [n, k], w: [k, m]) — ikj loop order for locality.
fn matmul_acc(a: &[f32], w: &[f32], out: &mut [f32], n: usize, k: usize, m: usize) {
    for i in 0..n {
        let arow = &a[i * k..(i + 1) * k];
        let orow = &mut out[i * m..(i + 1) * m];
        for (kk, &av) in arow.iter().enumerate() {
            if av == 0.0 {
                continue;
            }
            let wrow = &w[kk * m..(kk + 1) * m];
            for (o, &wv) in orow.iter_mut().zip(wrow) {
                *o += av * wv;
            }
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::graph::datasets;
    use crate::mem::TransferLedger;
    use crate::sampler::{Fanout, NeighborSampler, UvaAdj};

    fn sampled_mb() -> (crate::graph::Dataset, MiniBatch) {
        let ds = datasets::spec("tiny").unwrap().build();
        let mut s = NeighborSampler::new(Fanout::parse("3,2,2").unwrap());
        let adj = UvaAdj { csc: &ds.csc };
        let mut rng = Rng::new(1);
        let mut ledger = TransferLedger::new();
        let seeds: Vec<u32> = ds.test_nodes[..32].to_vec();
        let mb = s.sample_batch(&adj, &seeds, &mut rng, &mut ledger);
        (ds, mb)
    }

    fn gather(ds: &crate::graph::Dataset, mb: &MiniBatch) -> Vec<f32> {
        let dim = ds.features.dim();
        let mut x = vec![0.0; mb.input_nodes().len() * dim];
        for (i, &v) in mb.input_nodes().iter().enumerate() {
            ds.features.copy_row_into(v, &mut x[i * dim..(i + 1) * dim]);
        }
        x
    }

    #[test]
    fn forward_shapes_and_finite() {
        let (ds, mb) = sampled_mb();
        for kind in [ModelKind::GraphSage, ModelKind::Gcn] {
            let m = RefModel::new(kind, ds.features.dim(), 16, 4, 7);
            let x = gather(&ds, &mb);
            let logits = m.forward(&x, &mb);
            assert_eq!(logits.len(), 32 * 4);
            assert!(logits.iter().all(|v| v.is_finite()));
            // logits vary across seeds
            assert_ne!(&logits[..4], &logits[4..8]);
        }
    }

    #[test]
    fn deterministic() {
        let (ds, mb) = sampled_mb();
        let m1 = RefModel::new(ModelKind::GraphSage, ds.features.dim(), 16, 4, 7);
        let m2 = RefModel::new(ModelKind::GraphSage, ds.features.dim(), 16, 4, 7);
        let x = gather(&ds, &mb);
        assert_eq!(m1.forward(&x, &mb), m2.forward(&x, &mb));
    }

    #[test]
    fn masked_slots_do_not_affect_output() {
        // same invariance the JAX test pins: retargeting dead idx slots
        // must not change logits
        let (ds, mb) = sampled_mb();
        let m = RefModel::new(ModelKind::GraphSage, ds.features.dim(), 16, 4, 7);
        let x = gather(&ds, &mb);
        let base = m.forward(&x, &mb);
        let mut mb2 = mb.clone();
        for blk in &mut mb2.layers {
            for i in 0..blk.idx.len() {
                if blk.mask[i] == 0.0 {
                    blk.idx[i] = 0;
                }
            }
        }
        assert_eq!(m.forward(&x, &mb2), base);
    }

    #[test]
    fn matmul_acc_correct() {
        // [2x3] @ [3x2]
        let a = [1.0, 2.0, 3.0, 4.0, 5.0, 6.0];
        let w = [7.0, 8.0, 9.0, 10.0, 11.0, 12.0];
        let mut out = vec![0.0; 4];
        matmul_acc(&a, &w, &mut out, 2, 3, 2);
        assert_eq!(out, vec![58.0, 64.0, 139.0, 154.0]);
    }
}
