//! PJRT execution of the AOT HLO artifacts (the `xla` crate; CPU
//! client). Executables are compiled once per artifact and reused for
//! every batch — the request path is: pick artifact → pad → execute.
//!
//! The `xla` crate is not in the offline registry, so the real client
//! is gated behind **two** cargo features: `xla` selects the PJRT gate
//! plumbing (CI builds it — still on the stub, so the gate itself
//! cannot rot), and `xla-vendored` additionally switches in the real
//! client once the crate has been vendored as a path dependency.
//! Default and `--features xla` builds get the signature-compatible
//! stub below, which fails at `load` time with an actionable message —
//! tests skip when `artifacts/` is absent, and the engine's other
//! backends (`compute=skip|reference`) cover every non-PJRT
//! configuration.

#[cfg(all(feature = "xla", feature = "xla-vendored"))]
mod real {
    use std::collections::HashMap;

    use anyhow::{anyhow, Context, Result};

    use crate::config::ModelKind;
    use crate::runtime::artifacts::{ArtifactMeta, Manifest};
    use crate::runtime::padding::{pad_batch, unpad_logits};
    use crate::sampler::MiniBatch;

    /// PJRT CPU runtime over a manifest of artifacts.
    pub struct PjrtRuntime {
        client: xla::PjRtClient,
        manifest: Manifest,
        /// Compiled executables, keyed by artifact name (lazy).
        exes: HashMap<String, xla::PjRtLoadedExecutable>,
    }

    impl PjrtRuntime {
        /// Open the artifacts directory (compiles nothing yet).
        pub fn load(dir: &str) -> Result<PjrtRuntime> {
            let manifest = Manifest::load(dir)?;
            let client = xla::PjRtClient::cpu().map_err(wrap)?;
            Ok(PjrtRuntime { client, manifest, exes: HashMap::new() })
        }

        pub fn manifest(&self) -> &Manifest {
            &self.manifest
        }

        /// Compile (once) and return the executable for `meta`.
        fn compile(&mut self, meta: &ArtifactMeta) -> Result<&xla::PjRtLoadedExecutable> {
            if !self.exes.contains_key(&meta.name) {
                let path = self.manifest.hlo_path(meta);
                let proto = xla::HloModuleProto::from_text_file(
                    path.to_str().ok_or_else(|| anyhow!("non-utf8 path {path:?}"))?,
                )
                .map_err(wrap)
                .with_context(|| format!("loading HLO text {path:?}"))?;
                let comp = xla::XlaComputation::from_proto(&proto);
                let exe = self.client.compile(&comp).map_err(wrap)?;
                self.exes.insert(meta.name.clone(), exe);
            }
            Ok(&self.exes[&meta.name])
        }

        /// Eagerly compile every artifact matching `model` (serving warmup).
        pub fn warmup(&mut self, model: ModelKind) -> Result<usize> {
            let metas: Vec<ArtifactMeta> = self
                .manifest
                .artifacts
                .iter()
                .filter(|a| a.model == model)
                .cloned()
                .collect();
            for meta in &metas {
                self.compile(meta)?;
            }
            Ok(metas.len())
        }

        /// Pick the smallest fitting artifact for a sampled batch.
        pub fn select(
            &self,
            model: ModelKind,
            feat_dim: usize,
            classes: usize,
            mb: &MiniBatch,
        ) -> Result<ArtifactMeta> {
            let sizes: Vec<usize> = mb.nodes.iter().map(|a| a.len()).collect();
            let ks: Vec<usize> = mb.layers.iter().map(|b| b.k).collect();
            self.manifest
                .find(model, feat_dim, classes, &sizes, &ks)
                .cloned()
                .ok_or_else(|| {
                    anyhow!(
                        "no artifact fits model={} feat_dim={feat_dim} classes={classes} \
                         sizes={sizes:?} ks={ks:?}; add a variant to aot.py VARIANTS",
                        model.as_str()
                    )
                })
        }

        /// Full request-path execution: select → pad → execute → unpad.
        /// Returns logits `[n_seeds, classes]`.
        pub fn run(
            &mut self,
            model: ModelKind,
            x_gathered: &[f32],
            feat_dim: usize,
            mb: &MiniBatch,
        ) -> Result<Vec<f32>> {
            let meta =
                self.select(model, feat_dim, mb_classes(self, model, feat_dim, mb)?, mb)?;
            self.run_with(&meta, x_gathered, feat_dim, mb)
        }

        /// Execute against a specific artifact.
        pub fn run_with(
            &mut self,
            meta: &ArtifactMeta,
            x_gathered: &[f32],
            feat_dim: usize,
            mb: &MiniBatch,
        ) -> Result<Vec<f32>> {
            let padded = pad_batch(mb, x_gathered, feat_dim, meta)?;
            let classes = meta.classes;
            let n_seeds = padded.n_seeds;

            // Build literals: x, then (idx, mask) per layer.
            let mut literals: Vec<xla::Literal> =
                Vec::with_capacity(1 + 2 * padded.blocks.len());
            literals.push(
                xla::Literal::vec1(&padded.x)
                    .reshape(&[meta.dims[0] as i64, feat_dim as i64])
                    .map_err(wrap)?,
            );
            for (l, (idx, mask)) in padded.blocks.iter().enumerate() {
                let (n, k) = (meta.dims[l + 1] as i64, meta.ks[l] as i64);
                literals.push(
                    xla::Literal::vec1(idx.as_slice()).reshape(&[n, k]).map_err(wrap)?,
                );
                literals.push(
                    xla::Literal::vec1(mask.as_slice()).reshape(&[n, k]).map_err(wrap)?,
                );
            }

            let exe = self.compile(meta)?;
            let result = exe.execute::<xla::Literal>(&literals).map_err(wrap)?[0][0]
                .to_literal_sync()
                .map_err(wrap)?;
            // aot.py lowers with return_tuple=True → unwrap the 1-tuple
            let out = result.to_tuple1().map_err(wrap)?;
            let logits: Vec<f32> = out.to_vec().map_err(wrap)?;
            anyhow::ensure!(
                logits.len() == meta.batch_size * classes,
                "unexpected logits len {} (expected {}x{})",
                logits.len(),
                meta.batch_size,
                classes
            );
            Ok(unpad_logits(&logits, classes, n_seeds))
        }
    }

    /// classes are artifact-determined; look up by model/feat_dim + shape.
    fn mb_classes(
        rt: &PjrtRuntime,
        model: ModelKind,
        feat_dim: usize,
        mb: &MiniBatch,
    ) -> Result<usize> {
        let sizes: Vec<usize> = mb.nodes.iter().map(|a| a.len()).collect();
        let ks: Vec<usize> = mb.layers.iter().map(|b| b.k).collect();
        rt.manifest
            .artifacts
            .iter()
            .find(|a| {
                a.model == model
                    && a.feat_dim == feat_dim
                    && a.fits(model, feat_dim, a.classes, &sizes, &ks)
            })
            .map(|a| a.classes)
            .ok_or_else(|| anyhow!("no artifact candidates for model/feat_dim"))
    }

    fn wrap(e: xla::Error) -> anyhow::Error {
        anyhow!("xla: {e}")
    }
}

#[cfg(all(feature = "xla", feature = "xla-vendored"))]
pub use real::PjrtRuntime;

#[cfg(not(all(feature = "xla", feature = "xla-vendored")))]
mod stub {
    use anyhow::{bail, Result};

    use crate::config::ModelKind;
    use crate::runtime::artifacts::{ArtifactMeta, Manifest};
    use crate::sampler::MiniBatch;

    const UNAVAILABLE: &str = "PJRT backend unavailable: built without the `xla` + \
                               `xla-vendored` cargo features (use compute=reference; \
                               the real client requires vendoring the external `xla` \
                               crate as a path dependency — it is not in the offline \
                               registry — then building with --features xla,xla-vendored)";

    /// Signature-compatible stand-in for the PJRT runtime; every entry
    /// point fails with [`UNAVAILABLE`], starting at `load`, so no
    /// value of this type ever exists. The field and `manifest()`
    /// accessor are kept solely so callers (engine, tests) typecheck
    /// identically against both flavors.
    pub struct PjrtRuntime {
        manifest: Manifest,
    }

    impl PjrtRuntime {
        pub fn load(_dir: &str) -> Result<PjrtRuntime> {
            bail!(UNAVAILABLE)
        }

        pub fn manifest(&self) -> &Manifest {
            &self.manifest
        }

        pub fn warmup(&mut self, _model: ModelKind) -> Result<usize> {
            bail!(UNAVAILABLE)
        }

        pub fn select(
            &self,
            _model: ModelKind,
            _feat_dim: usize,
            _classes: usize,
            _mb: &MiniBatch,
        ) -> Result<ArtifactMeta> {
            bail!(UNAVAILABLE)
        }

        pub fn run(
            &mut self,
            _model: ModelKind,
            _x_gathered: &[f32],
            _feat_dim: usize,
            _mb: &MiniBatch,
        ) -> Result<Vec<f32>> {
            bail!(UNAVAILABLE)
        }

        pub fn run_with(
            &mut self,
            _meta: &ArtifactMeta,
            _x_gathered: &[f32],
            _feat_dim: usize,
            _mb: &MiniBatch,
        ) -> Result<Vec<f32>> {
            bail!(UNAVAILABLE)
        }
    }
}

#[cfg(not(all(feature = "xla", feature = "xla-vendored")))]
pub use stub::PjrtRuntime;
