//! Padding: maps a dynamically-shaped sampled mini-batch onto the fixed
//! shapes of an AOT artifact.
//!
//! Semantics guaranteed by the model convention (padded feature rows
//! are zero, padded idx slots have mask 0) mean padding never changes
//! the logits of the real rows — `python/tests/test_model.py::
//! test_padding_rows_do_not_leak` pins this on the JAX side and the
//! golden test pins it end-to-end through PJRT.

use anyhow::{bail, Result};

use crate::sampler::MiniBatch;

use super::artifacts::ArtifactMeta;

/// A mini-batch padded to an artifact's fixed shapes, in the flat
/// layouts the PJRT executable expects.
#[derive(Debug, Clone)]
pub struct PaddedBatch {
    /// `[dims[0], feat_dim]` row-major.
    pub x: Vec<f32>,
    /// Per layer (input-most first): (`idx [n_l, K_l]`, `mask [n_l, K_l]`).
    pub blocks: Vec<(Vec<i32>, Vec<f32>)>,
    /// Real (unpadded) seed count — rows of the logits to keep.
    pub n_seeds: usize,
}

/// Pad gathered features + blocks to `meta`'s shapes.
///
/// `x_gathered` is the feature-loading stage's output:
/// `[mb.input_nodes().len(), feat_dim]` row-major.
pub fn pad_batch(
    mb: &MiniBatch,
    x_gathered: &[f32],
    feat_dim: usize,
    meta: &ArtifactMeta,
) -> Result<PaddedBatch> {
    let sizes: Vec<usize> = mb.nodes.iter().map(|a| a.len()).collect();
    let ks: Vec<usize> = mb.layers.iter().map(|b| b.k).collect();
    if meta.feat_dim != feat_dim {
        bail!("artifact feat_dim {} != {}", meta.feat_dim, feat_dim);
    }
    if !meta.fits(meta.model, feat_dim, meta.classes, &sizes, &ks) {
        bail!(
            "mini-batch sizes {sizes:?}/ks {ks:?} exceed artifact {} dims {:?}/ks {:?}",
            meta.name,
            meta.dims,
            meta.ks
        );
    }
    let n_in = mb.input_nodes().len();
    if x_gathered.len() != n_in * feat_dim {
        bail!(
            "gathered features len {} != {} inputs × {} dims",
            x_gathered.len(),
            n_in,
            feat_dim
        );
    }

    // features: real rows then zero padding
    let mut x = vec![0.0f32; meta.dims[0] * feat_dim];
    x[..x_gathered.len()].copy_from_slice(x_gathered);

    // blocks: copy k-wide rows into K-wide rows, zero elsewhere
    let mut blocks = Vec::with_capacity(mb.layers.len());
    for (l, blk) in mb.layers.iter().enumerate() {
        let (n_pad, k_pad) = (meta.dims[l + 1], meta.ks[l]);
        let mut idx = vec![0i32; n_pad * k_pad];
        let mut mask = vec![0.0f32; n_pad * k_pad];
        for d in 0..blk.n_dst {
            let src = d * blk.k;
            let dst = d * k_pad;
            idx[dst..dst + blk.k].copy_from_slice(&blk.idx[src..src + blk.k]);
            mask[dst..dst + blk.k].copy_from_slice(&blk.mask[src..src + blk.k]);
        }
        blocks.push((idx, mask));
    }

    Ok(PaddedBatch { x, blocks, n_seeds: mb.seeds().len() })
}

/// Strip logits back to the real seed rows.
pub fn unpad_logits(logits: &[f32], classes: usize, n_seeds: usize) -> Vec<f32> {
    logits[..n_seeds * classes].to_vec()
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::config::ModelKind;
    use crate::sampler::block::Block;

    fn meta() -> ArtifactMeta {
        ArtifactMeta {
            name: "t".into(),
            file: "t.hlo.txt".into(),
            model: ModelKind::GraphSage,
            feat_dim: 3,
            hidden: 8,
            classes: 4,
            batch_size: 4,
            ks: vec![2, 2],
            dims: vec![36, 12, 4],
        }
    }

    fn tiny_mb() -> MiniBatch {
        // 2 seeds <- 3 mids <- 5 inputs
        let mut b1 = Block::new(3, 2); // mids from inputs
        b1.set(0, 0, 3);
        b1.set(1, 0, 4);
        b1.set(2, 1, 0);
        let mut b2 = Block::new(2, 1); // seeds from mids (k=1 < K=2)
        b2.set(0, 0, 2);
        b2.set(1, 0, 1);
        MiniBatch {
            nodes: vec![
                vec![10, 11, 12, 13, 14],
                vec![10, 11, 12],
                vec![10, 11],
            ],
            layers: vec![b1, b2],
        }
    }

    #[test]
    fn pads_shapes_and_preserves_payload() {
        let mb = tiny_mb();
        mb.validate().unwrap();
        let x: Vec<f32> = (0..5 * 3).map(|i| i as f32).collect();
        let p = pad_batch(&mb, &x, 3, &meta()).unwrap();
        assert_eq!(p.x.len(), 36 * 3);
        assert_eq!(&p.x[..15], x.as_slice());
        assert!(p.x[15..].iter().all(|&v| v == 0.0));
        assert_eq!(p.blocks.len(), 2);
        let (idx1, mask1) = &p.blocks[0];
        assert_eq!(idx1.len(), 12 * 2);
        // row 0 of layer 1: idx (3, 0), mask (1, 0)
        assert_eq!(&idx1[..2], &[3, 0]);
        assert_eq!(&mask1[..2], &[1.0, 0.0]);
        // layer 2 rows are k=1 copied into K=2 slots
        let (idx2, mask2) = &p.blocks[1];
        assert_eq!(idx2[0], 2);
        assert_eq!(mask2[0], 1.0);
        assert_eq!(mask2[1], 0.0);
        assert_eq!(p.n_seeds, 2);
    }

    #[test]
    fn rejects_oversize_and_bad_gather() {
        let mb = tiny_mb();
        let x = vec![0.0; 5 * 3];
        let mut small = meta();
        small.dims = vec![4, 2, 1];
        assert!(pad_batch(&mb, &x, 3, &small).is_err());
        assert!(pad_batch(&mb, &x[..6], 3, &meta()).is_err());
        assert!(pad_batch(&mb, &x, 7, &meta()).is_err());
    }

    #[test]
    fn unpad_keeps_seed_rows() {
        let logits: Vec<f32> = (0..4 * 4).map(|i| i as f32).collect();
        let out = unpad_logits(&logits, 4, 2);
        assert_eq!(out.len(), 8);
        assert_eq!(out[7], 7.0);
    }
}
