//! `artifacts/manifest.json` — the contract between `aot.py` and the
//! Rust runtime. One entry per compiled (model, shape) variant.

use std::path::{Path, PathBuf};

use anyhow::{Context, Result};

use crate::config::ModelKind;
use crate::util::json::Json;

/// One AOT artifact's metadata (mirrors the dict written by aot.py).
#[derive(Debug, Clone)]
pub struct ArtifactMeta {
    pub name: String,
    pub file: String,
    pub model: ModelKind,
    pub feat_dim: usize,
    pub hidden: usize,
    pub classes: usize,
    pub batch_size: usize,
    /// Neighbor slots per layer, input-most first.
    pub ks: Vec<usize>,
    /// Padded node-array sizes per layer, input-most first
    /// (`dims.len() == ks.len() + 1`).
    pub dims: Vec<usize>,
}

impl ArtifactMeta {
    fn from_json(j: &Json) -> Result<ArtifactMeta> {
        let model = ModelKind::parse(j.req("model")?.as_str()?)?;
        let meta = ArtifactMeta {
            name: j.req("name")?.as_str()?.to_string(),
            file: j.req("file")?.as_str()?.to_string(),
            model,
            feat_dim: j.req("feat_dim")?.as_usize()?,
            hidden: j.req("hidden")?.as_usize()?,
            classes: j.req("classes")?.as_usize()?,
            batch_size: j.req("batch_size")?.as_usize()?,
            ks: j.req("ks")?.as_usize_vec()?,
            dims: j.req("dims")?.as_usize_vec()?,
        };
        if meta.dims.len() != meta.ks.len() + 1 {
            anyhow::bail!("artifact {}: dims/ks length mismatch", meta.name);
        }
        Ok(meta)
    }

    /// Can this artifact hold a batch with the given per-layer node
    /// counts (`sizes`, input-most first) and per-layer neighbor slots?
    pub fn fits(
        &self,
        model: ModelKind,
        feat_dim: usize,
        classes: usize,
        sizes: &[usize],
        ks: &[usize],
    ) -> bool {
        self.model == model
            && self.feat_dim == feat_dim
            && self.classes == classes
            && sizes.len() == self.dims.len()
            && ks.len() == self.ks.len()
            && sizes.iter().zip(&self.dims).all(|(a, c)| a <= c)
            && ks.iter().zip(&self.ks).all(|(a, c)| a <= c)
    }

    /// Padded element count of the input feature tensor (cost proxy for
    /// choosing the smallest fitting artifact).
    pub fn padded_cost(&self) -> usize {
        self.dims[0] * self.feat_dim
    }
}

/// Parsed manifest + its directory (for resolving artifact files).
#[derive(Debug, Clone)]
pub struct Manifest {
    pub dir: PathBuf,
    pub artifacts: Vec<ArtifactMeta>,
}

impl Manifest {
    pub fn load(dir: impl AsRef<Path>) -> Result<Manifest> {
        let dir = dir.as_ref().to_path_buf();
        let path = dir.join("manifest.json");
        let text = std::fs::read_to_string(&path)
            .with_context(|| format!("reading {path:?} (run `make artifacts`)"))?;
        let j = Json::parse(&text).context("parsing manifest.json")?;
        let version = j.req("version")?.as_u64()?;
        anyhow::ensure!(version == 1, "unsupported manifest version {version}");
        let artifacts = j
            .req("artifacts")?
            .as_arr()?
            .iter()
            .map(ArtifactMeta::from_json)
            .collect::<Result<Vec<_>>>()?;
        Ok(Manifest { dir, artifacts })
    }

    /// Smallest artifact that fits the request, or None.
    pub fn find(
        &self,
        model: ModelKind,
        feat_dim: usize,
        classes: usize,
        sizes: &[usize],
        ks: &[usize],
    ) -> Option<&ArtifactMeta> {
        self.artifacts
            .iter()
            .filter(|a| a.fits(model, feat_dim, classes, sizes, ks))
            .min_by_key(|a| a.padded_cost())
    }

    pub fn by_name(&self, name: &str) -> Option<&ArtifactMeta> {
        self.artifacts.iter().find(|a| a.name == name)
    }

    /// Absolute path of an artifact's HLO text.
    pub fn hlo_path(&self, meta: &ArtifactMeta) -> PathBuf {
        self.dir.join(&meta.file)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn sample_manifest(dir: &Path) {
        let text = r#"{
          "version": 1,
          "artifacts": [
            {"name": "a", "file": "a.hlo.txt", "model": "graphsage",
             "feat_dim": 8, "hidden": 16, "classes": 4, "batch_size": 8,
             "ks": [2, 2, 2], "dims": [216, 72, 24, 8], "seed": 7},
            {"name": "b", "file": "b.hlo.txt", "model": "graphsage",
             "feat_dim": 8, "hidden": 16, "classes": 4, "batch_size": 16,
             "ks": [2, 2, 2], "dims": [432, 144, 48, 16], "seed": 7}
          ]
        }"#;
        std::fs::write(dir.join("manifest.json"), text).unwrap();
    }

    fn tmpdir(tag: &str) -> PathBuf {
        let d = std::env::temp_dir().join(format!("dci-manifest-{tag}-{}", std::process::id()));
        std::fs::create_dir_all(&d).unwrap();
        d
    }

    #[test]
    fn load_and_find_smallest_fitting() {
        let d = tmpdir("find");
        sample_manifest(&d);
        let m = Manifest::load(&d).unwrap();
        assert_eq!(m.artifacts.len(), 2);
        let hit = m
            .find(ModelKind::GraphSage, 8, 4, &[100, 50, 20, 8], &[2, 2, 2])
            .unwrap();
        assert_eq!(hit.name, "a"); // smallest fitting
        let hit = m
            .find(ModelKind::GraphSage, 8, 4, &[300, 100, 30, 12], &[2, 2, 2])
            .unwrap();
        assert_eq!(hit.name, "b"); // only b fits
        assert!(m
            .find(ModelKind::GraphSage, 8, 4, &[9999, 100, 30, 12], &[2, 2, 2])
            .is_none());
        assert!(m
            .find(ModelKind::Gcn, 8, 4, &[100, 50, 20, 8], &[2, 2, 2])
            .is_none());
        assert!(m.by_name("a").is_some());
        assert!(m.by_name("zz").is_none());
        assert!(m.hlo_path(m.by_name("a").unwrap()).ends_with("a.hlo.txt"));
    }

    #[test]
    fn real_manifest_parses_if_built() {
        // Integration with the actual aot.py output when artifacts exist.
        if let Ok(m) = Manifest::load("artifacts") {
            assert!(m.by_name("smoke_sage").is_some());
            let a = m.by_name("smoke_sage").unwrap();
            assert_eq!(a.dims, vec![216, 72, 24, 8]);
            assert_eq!(a.ks, vec![2, 2, 2]);
        }
    }

    #[test]
    fn missing_dir_errors_helpfully() {
        let err = Manifest::load("/nonexistent-dci").unwrap_err();
        assert!(err.to_string().contains("make artifacts"));
    }
}
