//! Minimal JSON: a value model, a recursive-descent parser (used for
//! `artifacts/manifest.json` and golden files), and a writer (used by
//! the bench harness to emit machine-readable reports). No serde in the
//! offline registry — see DESIGN.md.

use std::collections::BTreeMap;
use std::fmt::Write as _;

use anyhow::{anyhow, bail, Result};

/// A parsed JSON value. Objects keep sorted key order (BTreeMap) so the
/// writer output is deterministic.
#[derive(Debug, Clone, PartialEq)]
pub enum Json {
    Null,
    Bool(bool),
    Num(f64),
    Str(String),
    Arr(Vec<Json>),
    Obj(BTreeMap<String, Json>),
}

impl Json {
    pub fn parse(text: &str) -> Result<Json> {
        let mut p = Parser { bytes: text.as_bytes(), pos: 0 };
        p.skip_ws();
        let v = p.value()?;
        p.skip_ws();
        if p.pos != p.bytes.len() {
            bail!("trailing garbage at byte {}", p.pos);
        }
        Ok(v)
    }

    // -- typed accessors -------------------------------------------------

    pub fn get(&self, key: &str) -> Option<&Json> {
        match self {
            Json::Obj(m) => m.get(key),
            _ => None,
        }
    }

    pub fn req(&self, key: &str) -> Result<&Json> {
        self.get(key).ok_or_else(|| anyhow!("missing key {key:?}"))
    }

    pub fn as_f64(&self) -> Result<f64> {
        match self {
            Json::Num(x) => Ok(*x),
            _ => bail!("expected number, got {self:?}"),
        }
    }

    pub fn as_u64(&self) -> Result<u64> {
        let x = self.as_f64()?;
        if x < 0.0 || x.fract() != 0.0 || x > u64::MAX as f64 {
            bail!("expected unsigned integer, got {x}");
        }
        Ok(x as u64)
    }

    pub fn as_usize(&self) -> Result<usize> {
        Ok(self.as_u64()? as usize)
    }

    pub fn as_str(&self) -> Result<&str> {
        match self {
            Json::Str(s) => Ok(s),
            _ => bail!("expected string, got {self:?}"),
        }
    }

    pub fn as_arr(&self) -> Result<&[Json]> {
        match self {
            Json::Arr(v) => Ok(v),
            _ => bail!("expected array, got {self:?}"),
        }
    }

    pub fn as_f32_vec(&self) -> Result<Vec<f32>> {
        self.as_arr()?.iter().map(|x| Ok(x.as_f64()? as f32)).collect()
    }

    pub fn as_i32_vec(&self) -> Result<Vec<i32>> {
        self.as_arr()?.iter().map(|x| Ok(x.as_f64()? as i32)).collect()
    }

    pub fn as_usize_vec(&self) -> Result<Vec<usize>> {
        self.as_arr()?.iter().map(|x| x.as_usize()).collect()
    }

    // -- writer ----------------------------------------------------------

    pub fn to_string(&self) -> String {
        let mut s = String::new();
        self.write(&mut s);
        s
    }

    fn write(&self, out: &mut String) {
        match self {
            Json::Null => out.push_str("null"),
            Json::Bool(b) => out.push_str(if *b { "true" } else { "false" }),
            Json::Num(x) => {
                if !x.is_finite() {
                    // JSON has no NaN/Infinity; emit null rather than an
                    // unparseable token (matches Python's strictest mode)
                    out.push_str("null");
                } else if x.fract() == 0.0 && x.abs() < 1e15 {
                    let _ = write!(out, "{}", *x as i64);
                } else {
                    let _ = write!(out, "{x}");
                }
            }
            Json::Str(s) => write_escaped(s, out),
            Json::Arr(v) => {
                out.push('[');
                for (i, x) in v.iter().enumerate() {
                    if i > 0 {
                        out.push(',');
                    }
                    x.write(out);
                }
                out.push(']');
            }
            Json::Obj(m) => {
                out.push('{');
                for (i, (k, v)) in m.iter().enumerate() {
                    if i > 0 {
                        out.push(',');
                    }
                    write_escaped(k, out);
                    out.push(':');
                    v.write(out);
                }
                out.push('}');
            }
        }
    }
}

/// Convenience constructors for report emission.
pub fn obj(pairs: Vec<(&str, Json)>) -> Json {
    Json::Obj(pairs.into_iter().map(|(k, v)| (k.to_string(), v)).collect())
}

pub fn num(x: f64) -> Json {
    Json::Num(x)
}

pub fn s(x: &str) -> Json {
    Json::Str(x.to_string())
}

fn write_escaped(s: &str, out: &mut String) {
    out.push('"');
    for c in s.chars() {
        match c {
            '"' => out.push_str("\\\""),
            '\\' => out.push_str("\\\\"),
            '\n' => out.push_str("\\n"),
            '\r' => out.push_str("\\r"),
            '\t' => out.push_str("\\t"),
            c if (c as u32) < 0x20 => {
                let _ = write!(out, "\\u{:04x}", c as u32);
            }
            c => out.push(c),
        }
    }
    out.push('"');
}

struct Parser<'a> {
    bytes: &'a [u8],
    pos: usize,
}

impl<'a> Parser<'a> {
    fn skip_ws(&mut self) {
        while self.pos < self.bytes.len()
            && matches!(self.bytes[self.pos], b' ' | b'\t' | b'\n' | b'\r')
        {
            self.pos += 1;
        }
    }

    fn peek(&self) -> Result<u8> {
        self.bytes
            .get(self.pos)
            .copied()
            .ok_or_else(|| anyhow!("unexpected end of input"))
    }

    fn expect(&mut self, b: u8) -> Result<()> {
        if self.peek()? != b {
            bail!("expected {:?} at byte {}", b as char, self.pos);
        }
        self.pos += 1;
        Ok(())
    }

    fn literal(&mut self, word: &str, v: Json) -> Result<Json> {
        if self.bytes[self.pos..].starts_with(word.as_bytes()) {
            self.pos += word.len();
            Ok(v)
        } else {
            bail!("invalid literal at byte {}", self.pos)
        }
    }

    fn value(&mut self) -> Result<Json> {
        match self.peek()? {
            b'n' => self.literal("null", Json::Null),
            b't' => self.literal("true", Json::Bool(true)),
            b'f' => self.literal("false", Json::Bool(false)),
            b'"' => Ok(Json::Str(self.string()?)),
            b'[' => self.array(),
            b'{' => self.object(),
            b'-' | b'0'..=b'9' => self.number(),
            c => bail!("unexpected byte {:?} at {}", c as char, self.pos),
        }
    }

    fn string(&mut self) -> Result<String> {
        self.expect(b'"')?;
        let mut out = String::new();
        loop {
            let c = self.peek()?;
            self.pos += 1;
            match c {
                b'"' => return Ok(out),
                b'\\' => {
                    let e = self.peek()?;
                    self.pos += 1;
                    match e {
                        b'"' => out.push('"'),
                        b'\\' => out.push('\\'),
                        b'/' => out.push('/'),
                        b'n' => out.push('\n'),
                        b't' => out.push('\t'),
                        b'r' => out.push('\r'),
                        b'b' => out.push('\u{8}'),
                        b'f' => out.push('\u{c}'),
                        b'u' => {
                            if self.pos + 4 > self.bytes.len() {
                                bail!("truncated \\u escape");
                            }
                            let hex =
                                std::str::from_utf8(&self.bytes[self.pos..self.pos + 4])?;
                            let code = u32::from_str_radix(hex, 16)?;
                            self.pos += 4;
                            out.push(char::from_u32(code).unwrap_or('\u{fffd}'));
                        }
                        e => bail!("bad escape \\{}", e as char),
                    }
                }
                c if c < 0x80 => out.push(c as char),
                c => {
                    // re-decode multi-byte utf8
                    let start = self.pos - 1;
                    let len = utf8_len(c);
                    let end = start + len;
                    if end > self.bytes.len() {
                        bail!("truncated utf8");
                    }
                    out.push_str(std::str::from_utf8(&self.bytes[start..end])?);
                    self.pos = end;
                }
            }
        }
    }

    fn number(&mut self) -> Result<Json> {
        let start = self.pos;
        while self.pos < self.bytes.len()
            && matches!(
                self.bytes[self.pos],
                b'-' | b'+' | b'.' | b'e' | b'E' | b'0'..=b'9'
            )
        {
            self.pos += 1;
        }
        let text = std::str::from_utf8(&self.bytes[start..self.pos])?;
        Ok(Json::Num(text.parse()?))
    }

    fn array(&mut self) -> Result<Json> {
        self.expect(b'[')?;
        let mut out = Vec::new();
        self.skip_ws();
        if self.peek()? == b']' {
            self.pos += 1;
            return Ok(Json::Arr(out));
        }
        loop {
            self.skip_ws();
            out.push(self.value()?);
            self.skip_ws();
            match self.peek()? {
                b',' => self.pos += 1,
                b']' => {
                    self.pos += 1;
                    return Ok(Json::Arr(out));
                }
                c => bail!("expected ',' or ']' got {:?}", c as char),
            }
        }
    }

    fn object(&mut self) -> Result<Json> {
        self.expect(b'{')?;
        let mut out = BTreeMap::new();
        self.skip_ws();
        if self.peek()? == b'}' {
            self.pos += 1;
            return Ok(Json::Obj(out));
        }
        loop {
            self.skip_ws();
            let key = self.string()?;
            self.skip_ws();
            self.expect(b':')?;
            self.skip_ws();
            let val = self.value()?;
            out.insert(key, val);
            self.skip_ws();
            match self.peek()? {
                b',' => self.pos += 1,
                b'}' => {
                    self.pos += 1;
                    return Ok(Json::Obj(out));
                }
                c => bail!("expected ',' or '}}' got {:?}", c as char),
            }
        }
    }
}

fn utf8_len(first: u8) -> usize {
    match first {
        0xc0..=0xdf => 2,
        0xe0..=0xef => 3,
        0xf0..=0xf7 => 4,
        _ => 1,
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn parses_scalars() {
        assert_eq!(Json::parse("null").unwrap(), Json::Null);
        assert_eq!(Json::parse("true").unwrap(), Json::Bool(true));
        assert_eq!(Json::parse("-1.5e2").unwrap(), Json::Num(-150.0));
        assert_eq!(Json::parse(r#""a\nb""#).unwrap(), Json::Str("a\nb".into()));
    }

    #[test]
    fn parses_nested() {
        let v = Json::parse(r#"{"a": [1, 2, {"b": "x"}], "c": null}"#).unwrap();
        assert_eq!(v.req("a").unwrap().as_arr().unwrap().len(), 3);
        assert_eq!(
            v.req("a").unwrap().as_arr().unwrap()[2].req("b").unwrap().as_str().unwrap(),
            "x"
        );
    }

    #[test]
    fn rejects_malformed() {
        for bad in ["", "{", "[1,", "{\"a\" 1}", "tru", "1 2", "{\"a\":}"] {
            assert!(Json::parse(bad).is_err(), "accepted {bad:?}");
        }
    }

    #[test]
    fn roundtrips() {
        let src = r#"{"arr":[1,2.5,"s"],"n":null,"o":{"b":true}}"#;
        let v = Json::parse(src).unwrap();
        let out = v.to_string();
        assert_eq!(Json::parse(&out).unwrap(), v);
    }

    #[test]
    fn typed_accessors() {
        let v = Json::parse(r#"{"n": 3, "xs": [1.5, 2.5], "is": [1, -2]}"#).unwrap();
        assert_eq!(v.req("n").unwrap().as_usize().unwrap(), 3);
        assert_eq!(v.req("xs").unwrap().as_f32_vec().unwrap(), vec![1.5, 2.5]);
        assert_eq!(v.req("is").unwrap().as_i32_vec().unwrap(), vec![1, -2]);
        assert!(v.req("missing").is_err());
        assert!(v.req("xs").unwrap().as_u64().is_err());
    }

    // -- canonical-encoding invariants: manifest_sha256 and the trace
    // determinism property tests silently depend on every one of these

    #[test]
    fn canonical_key_order_is_sorted() {
        // insertion order must not leak into the encoding
        let a = Json::parse(r#"{"zebra":1,"apple":2,"mango":3}"#).unwrap();
        let b = Json::parse(r#"{"mango":3,"apple":2,"zebra":1}"#).unwrap();
        assert_eq!(a.to_string(), b.to_string());
        assert_eq!(a.to_string(), r#"{"apple":2,"mango":3,"zebra":1}"#);
    }

    #[test]
    fn canonical_float_formatting_is_stable() {
        // integral values (and -0.0) collapse to integer tokens;
        // fractional values use Rust's shortest-round-trip formatting,
        // which is platform-independent
        assert_eq!(Json::Num(0.0).to_string(), "0");
        assert_eq!(Json::Num(-0.0).to_string(), "0");
        assert_eq!(Json::Num(42.0).to_string(), "42");
        assert_eq!(Json::Num(-7.0).to_string(), "-7");
        assert_eq!(Json::Num(0.9).to_string(), "0.9");
        assert_eq!(Json::Num(0.1 + 0.2).to_string(), "0.30000000000000004");
        // huge magnitudes print as plain decimals (Rust Display never
        // uses exponent notation) but must still parse back exactly
        assert_eq!(Json::parse(&Json::Num(1e300).to_string()).unwrap(), Json::Num(1e300));
        // non-finite values must never produce unparseable tokens
        assert_eq!(Json::Num(f64::NAN).to_string(), "null");
        assert_eq!(Json::Num(f64::INFINITY).to_string(), "null");
        assert_eq!(Json::Num(f64::NEG_INFINITY).to_string(), "null");
    }

    #[test]
    fn canonical_encoding_is_a_fixed_point() {
        // to_string(parse(t)) == t for already-canonical text, so
        // serialize → parse → serialize can never drift
        for t in [
            r#"{"a":1,"b":[true,null,"x"],"c":{"d":0.25}}"#,
            r#"[1,2.5,-3,"s\n\t\"q\""]"#,
            r#"{"events":[{"class":"scan","seeds":[1,2,3],"wave":0}]}"#,
            "0.30000000000000004",
        ] {
            let v = Json::parse(t).unwrap();
            assert_eq!(v.to_string(), t);
            assert_eq!(Json::parse(&v.to_string()).unwrap(), v);
        }
    }

    #[test]
    fn canonical_encoding_has_no_whitespace() {
        let v = Json::parse("{ \"a\" : [ 1 , 2 ] ,\n\"b\" : { } }").unwrap();
        assert_eq!(v.to_string(), r#"{"a":[1,2],"b":{}}"#);
    }

    #[test]
    fn unicode_and_escapes() {
        let v = Json::parse(r#""café ☕""#).unwrap();
        assert_eq!(v, Json::Str("café ☕".into()));
        let out = Json::Str("tab\ttick\"".into()).to_string();
        assert_eq!(Json::parse(&out).unwrap(), Json::Str("tab\ttick\"".into()));
    }
}
