//! Deterministic PRNG: SplitMix64 seeding a PCG-XSH-RR-like generator.
//!
//! Every stochastic component in the system (graph generation, neighbor
//! sampling, request arrival) takes an explicit [`Rng`] so whole runs
//! are reproducible from a single seed — a requirement for regenerating
//! the paper's tables deterministically.

/// The SplitMix64 step: add the golden-ratio increment, then the
/// finalizer (two xor-shift-multiplies + a final xor-shift). One
/// implementation for every fixed-key hash in the crate — RNG seeding
/// here, the `ShardRouter` node→shard partition, and the workload
/// tracker's sketch/touched-set hashing all call this, so they cannot
/// drift apart.
#[inline]
pub fn splitmix64(x: u64) -> u64 {
    let mut z = x.wrapping_add(0x9e37_79b9_7f4a_7c15);
    z = (z ^ (z >> 30)).wrapping_mul(0xbf58_476d_1ce4_e5b9);
    z = (z ^ (z >> 27)).wrapping_mul(0x94d0_49bb_1331_11eb);
    z ^ (z >> 31)
}

/// 64-bit deterministic PRNG (PCG64-mcg style: 128-bit LCG state,
/// xorshift-rotate output). Not cryptographic.
#[derive(Debug, Clone)]
pub struct Rng {
    state: u128,
}

const MUL: u128 = 0x2360_ed05_1fc6_5da4_4385_df64_9fcc_f645;
const INC: u128 = 0x5851_f42d_4c95_7f2d_1405_7b7e_f767_814f;

impl Rng {
    /// Seed via SplitMix64 so nearby seeds give unrelated streams.
    /// (Two [`splitmix64`] draws of the incrementing state — bit-
    /// identical to the classic stateful formulation.)
    pub fn new(seed: u64) -> Self {
        let hi = splitmix64(seed) as u128;
        let lo = splitmix64(seed.wrapping_add(0x9e37_79b9_7f4a_7c15)) as u128;
        let mut rng = Rng { state: (hi << 64) | lo | 1 };
        rng.next_u64(); // burn-in
        rng
    }

    /// Derive an independent child stream (for per-worker rngs).
    /// Consumes one draw from `self`.
    pub fn fork(&mut self, tag: u64) -> Rng {
        Rng::fork_stream(self.next_u64(), tag)
    }

    /// Stream `stream` of the fork base `base` — the pure core of
    /// [`Rng::fork`], exposed so many streams can be derived from one
    /// base without advancing any generator between derivations.
    pub fn fork_stream(base: u64, stream: u64) -> Rng {
        Rng::new(base ^ stream.wrapping_mul(0x9e37_79b9_7f4a_7c15))
    }

    /// Stream `stream` of root `seed`: a pure function of both values,
    /// so per-batch generators can be constructed from any thread in
    /// any order and still be reproducible. The pipeline executor
    /// derives batch `i`'s sampling RNG as `for_stream(cfg.seed, i)`,
    /// which is what makes pipelined runs bit-identical to serial ones
    /// at any thread count; the pre-sampling profiler derives the very
    /// same per-batch streams (via [`Rng::fork_stream`] of its root's
    /// first draw), so profiling replays the run's sampling streams
    /// whenever the batch geometry matches.
    pub fn for_stream(seed: u64, stream: u64) -> Rng {
        Rng::new(seed).fork(stream)
    }

    #[inline]
    pub fn next_u64(&mut self) -> u64 {
        self.state = self.state.wrapping_mul(MUL).wrapping_add(INC);
        let rot = (self.state >> 122) as u32;
        let xsl = ((self.state >> 64) as u64) ^ (self.state as u64);
        xsl.rotate_right(rot)
    }

    #[inline]
    pub fn next_u32(&mut self) -> u32 {
        (self.next_u64() >> 32) as u32
    }

    /// Uniform in `[0, bound)`. Lemire's unbiased method.
    #[inline]
    pub fn gen_range(&mut self, bound: u64) -> u64 {
        assert!(bound > 0, "gen_range bound must be > 0");
        let mut x = self.next_u64();
        let mut m = (x as u128) * (bound as u128);
        let mut lo = m as u64;
        if lo < bound {
            let t = bound.wrapping_neg() % bound;
            while lo < t {
                x = self.next_u64();
                m = (x as u128) * (bound as u128);
                lo = m as u64;
            }
        }
        (m >> 64) as u64
    }

    #[inline]
    pub fn gen_usize(&mut self, bound: usize) -> usize {
        self.gen_range(bound as u64) as usize
    }

    /// Uniform f64 in `[0, 1)`.
    #[inline]
    pub fn f64(&mut self) -> f64 {
        (self.next_u64() >> 11) as f64 * (1.0 / (1u64 << 53) as f64)
    }

    /// Uniform f32 in `[0, 1)`.
    #[inline]
    pub fn f32(&mut self) -> f32 {
        (self.next_u32() >> 8) as f32 * (1.0 / (1u32 << 24) as f32)
    }

    /// Standard normal via Box–Muller.
    pub fn normal(&mut self) -> f64 {
        let u1 = self.f64().max(1e-12);
        let u2 = self.f64();
        (-2.0 * u1.ln()).sqrt() * (std::f64::consts::TAU * u2).cos()
    }

    /// Fisher–Yates shuffle.
    pub fn shuffle<T>(&mut self, xs: &mut [T]) {
        for i in (1..xs.len()).rev() {
            let j = self.gen_usize(i + 1);
            xs.swap(i, j);
        }
    }

    /// Sample `k` distinct indices from `[0, n)` (reservoir when k << n,
    /// partial Fisher–Yates otherwise). Returns fewer than `k` iff n < k.
    pub fn sample_indices(&mut self, n: usize, k: usize, out: &mut Vec<u32>) {
        out.clear();
        if n == 0 || k == 0 {
            return;
        }
        if k >= n {
            out.extend(0..n as u32);
            return;
        }
        if k * 4 >= n {
            // reservoir sampling: uniform k-subset, no scratch allocation
            // (the hot path — most nodes have degree within 4x of the
            // fan-out, and the previous partial Fisher–Yates allocated a
            // degree-sized scratch per node; EXPERIMENTS.md §Perf)
            out.extend(0..k as u32);
            for j in k..n {
                let r = self.gen_usize(j + 1);
                if r < k {
                    out[r] = j as u32;
                }
            }
        } else {
            // Floyd's algorithm: k distinct draws without O(n) scratch
            for j in (n - k)..n {
                let t = self.gen_usize(j + 1) as u32;
                if out.contains(&t) {
                    out.push(j as u32);
                } else {
                    out.push(t);
                }
            }
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn deterministic_across_instances() {
        let mut a = Rng::new(42);
        let mut b = Rng::new(42);
        for _ in 0..100 {
            assert_eq!(a.next_u64(), b.next_u64());
        }
    }

    #[test]
    fn different_seeds_diverge() {
        let mut a = Rng::new(1);
        let mut b = Rng::new(2);
        let same = (0..64).filter(|_| a.next_u64() == b.next_u64()).count();
        assert_eq!(same, 0);
    }

    #[test]
    fn gen_range_bounds() {
        let mut r = Rng::new(7);
        for bound in [1u64, 2, 3, 10, 1000, u64::MAX] {
            for _ in 0..200 {
                assert!(r.gen_range(bound) < bound);
            }
        }
    }

    #[test]
    fn gen_range_roughly_uniform() {
        let mut r = Rng::new(9);
        let mut counts = [0usize; 10];
        for _ in 0..100_000 {
            counts[r.gen_usize(10)] += 1;
        }
        for &c in &counts {
            assert!((8_000..12_000).contains(&c), "skewed bucket: {c}");
        }
    }

    #[test]
    fn f64_in_unit_interval_with_spread() {
        let mut r = Rng::new(3);
        let xs: Vec<f64> = (0..1000).map(|_| r.f64()).collect();
        assert!(xs.iter().all(|&x| (0.0..1.0).contains(&x)));
        let mean = xs.iter().sum::<f64>() / xs.len() as f64;
        assert!((mean - 0.5).abs() < 0.05, "mean={mean}");
    }

    #[test]
    fn normal_moments() {
        let mut r = Rng::new(4);
        let xs: Vec<f64> = (0..50_000).map(|_| r.normal()).collect();
        let mean = xs.iter().sum::<f64>() / xs.len() as f64;
        let var = xs.iter().map(|x| (x - mean).powi(2)).sum::<f64>() / xs.len() as f64;
        assert!(mean.abs() < 0.03, "mean={mean}");
        assert!((var - 1.0).abs() < 0.05, "var={var}");
    }

    #[test]
    fn sample_indices_distinct_and_in_range() {
        let mut r = Rng::new(5);
        let mut out = Vec::new();
        for (n, k) in [(10, 3), (10, 10), (10, 15), (1000, 5), (100, 90), (0, 3), (5, 0)] {
            r.sample_indices(n, k, &mut out);
            assert_eq!(out.len(), k.min(n));
            let mut seen = out.clone();
            seen.sort_unstable();
            seen.dedup();
            assert_eq!(seen.len(), out.len(), "duplicates for n={n} k={k}");
            assert!(out.iter().all(|&i| (i as usize) < n));
        }
    }

    #[test]
    fn shuffle_is_permutation() {
        let mut r = Rng::new(6);
        let mut xs: Vec<u32> = (0..100).collect();
        r.shuffle(&mut xs);
        let mut sorted = xs.clone();
        sorted.sort_unstable();
        assert_eq!(sorted, (0..100).collect::<Vec<_>>());
        assert_ne!(xs, (0..100).collect::<Vec<_>>()); // astronomically unlikely
    }

    #[test]
    fn for_stream_pure_and_divergent() {
        // same (seed, stream) -> identical sequence, from anywhere
        let mut a = Rng::for_stream(42, 3);
        let mut b = Rng::for_stream(42, 3);
        for _ in 0..64 {
            assert_eq!(a.next_u64(), b.next_u64());
        }
        // different streams of the same seed diverge
        let mut c = Rng::for_stream(42, 4);
        let same = (0..64).filter(|_| a.next_u64() == c.next_u64()).count();
        assert_eq!(same, 0);
        // matches forking a fresh root (the definition)
        let mut root = Rng::new(42);
        let mut d = root.fork(9);
        let mut e = Rng::for_stream(42, 9);
        assert_eq!(d.next_u64(), e.next_u64());
        // fork_stream of the root's first draw is the same derivation —
        // this is what lets the presample profiler replay the run's
        // per-batch streams
        let mut root = Rng::new(42);
        let base = root.next_u64();
        let mut f = Rng::fork_stream(base, 9);
        let mut g = Rng::for_stream(42, 9);
        assert_eq!(f.next_u64(), g.next_u64());
    }

    #[test]
    fn fork_streams_independent() {
        let mut root = Rng::new(10);
        let mut a = root.fork(1);
        let mut b = root.fork(2);
        let same = (0..64).filter(|_| a.next_u64() == b.next_u64()).count();
        assert_eq!(same, 0);
    }
}
