//! In-repo property-testing harness (the offline registry carries no
//! proptest crate). Runs a predicate over many seeded random cases and
//! reports the failing seed so a failure reproduces deterministically:
//!
//! ```no_run
//! // (no_run: doctest binaries lack the xla rpath in this image)
//! use dci::util::proptest::check;
//! check("sum is commutative", 256, |rng| {
//!     let (a, b) = (rng.next_u32() as u64, rng.next_u32() as u64);
//!     if a + b == b + a { Ok(()) } else { Err(format!("{a} {b}")) }
//! });
//! ```

use super::rng::Rng;

/// Run `cases` random trials of `prop`. Panics with the seed + message of
/// the first failing case. `DCI_PROP_SEED` pins the base seed (useful to
/// replay a CI failure locally).
pub fn check<F>(name: &str, cases: u64, mut prop: F)
where
    F: FnMut(&mut Rng) -> Result<(), String>,
{
    let base = std::env::var("DCI_PROP_SEED")
        .ok()
        .and_then(|s| s.parse().ok())
        .unwrap_or(0xDC1u64);
    for case in 0..cases {
        let seed = base
            .wrapping_mul(0x9e37_79b9_7f4a_7c15)
            .wrapping_add(case);
        let mut rng = Rng::new(seed);
        if let Err(msg) = prop(&mut rng) {
            panic!(
                "property {name:?} failed on case {case}/{cases} \
                 (DCI_PROP_SEED={base}, case seed {seed}): {msg}"
            );
        }
    }
}

/// Uniform usize in [lo, hi] — convenience for property generators.
pub fn range(rng: &mut Rng, lo: usize, hi: usize) -> usize {
    assert!(hi >= lo);
    lo + rng.gen_usize(hi - lo + 1)
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn passing_property_runs_all_cases() {
        let mut n = 0;
        check("trivial", 50, |_| {
            n += 1;
            Ok(())
        });
        assert_eq!(n, 50);
    }

    #[test]
    #[should_panic(expected = "property \"fails\" failed")]
    fn failing_property_panics_with_seed() {
        check("fails", 10, |rng| {
            if rng.next_u64() % 2 == 0 || true {
                Err("boom".into())
            } else {
                Ok(())
            }
        });
    }

    #[test]
    fn range_is_inclusive() {
        let mut rng = Rng::new(1);
        let mut seen_lo = false;
        let mut seen_hi = false;
        for _ in 0..1000 {
            let x = range(&mut rng, 3, 5);
            assert!((3..=5).contains(&x));
            seen_lo |= x == 3;
            seen_hi |= x == 5;
        }
        assert!(seen_lo && seen_hi);
    }
}
