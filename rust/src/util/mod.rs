//! Small self-contained utilities (the offline registry has no rand /
//! serde / criterion / proptest, so these live in-repo).

pub mod fault;
pub mod human;
pub mod json;
pub mod proptest;
pub mod rng;
pub mod sha256;
pub mod stats;
pub mod table;
pub mod timer;

pub use fault::{lock_unpoisoned, FaultPlan};
pub use human::{format_bytes, parse_bytes};
pub use rng::{splitmix64, Rng};
pub use sha256::{sha256, sha256_hex};
pub use timer::Stopwatch;
