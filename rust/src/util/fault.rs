//! Deterministic fault injection for chaos testing (DESIGN.md §Fault
//! tolerance).
//!
//! A [`FaultPlan`] is parsed from the `fault=` knob and injected at a
//! handful of *named sites* in the refresh/serving stack. Sites that
//! hold an `Option<Arc<FaultPlan>>` pay one pointer null-check when the
//! knob is off — the plan is zero-cost when disabled and fully
//! deterministic when enabled: every fault carries an explicit trigger
//! count that is decremented atomically, so a given spec fires the same
//! faults in the same order on every run regardless of thread timing.
//!
//! Spec grammar (comma-separated entries, each `kind[@target][xN][~MS]`):
//!
//! | entry          | site                | effect                                    |
//! |----------------|---------------------|-------------------------------------------|
//! | `oom@S[xN]`    | install claim       | shard `S`'s device claim reports OOM      |
//! | `err@S[xN]`    | install transfer    | shard `S`'s cache fill fails (I/O error)  |
//! | `hang@S~MS`    | install transfer    | shard `S`'s fill sleeps `MS` ms           |
//! | `drain[xN]`    | tracker drain       | the refresh loop panics mid-drain         |
//! | `batch@B[xN]`  | batch execution     | serving/pipeline batch `B` panics         |
//! | `stage@B[xN]`  | staged transfer     | batch `B`'s coalesced staged copy fails → per-row fallback |
//!
//! `xN` defaults to 1; a count of 0 never fires (useful for templating
//! specs). Example: `fault=oom@0x6,err@1x4,hang@2~300,drain` — shard
//! 0's next six claims OOM, shard 1's next four fills error, shard 2's
//! next fill hangs 300 ms, and one tracker drain panics.

use std::sync::atomic::{AtomicU64, Ordering};
use std::sync::{Mutex, MutexGuard, PoisonError};

use anyhow::{bail, Context, Result};

/// Which named site a fault entry attaches to.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
enum FaultKind {
    /// Device-memory claim for a shard install reports OOM.
    InstallOom,
    /// Host→device fill for a shard install fails with a transfer error.
    InstallErr,
    /// Host→device fill for a shard install stalls (slow/hung install).
    InstallHang,
    /// The workload tracker's drain panics inside the refresh loop.
    DrainPanic,
    /// A serving/pipeline batch panics mid-execution.
    BatchPanic,
    /// A batch's coalesced staged H2D copy fails; the gather degrades
    /// to the per-row UVA fallback (same bytes, per-row pricing).
    StageCopyErr,
}

/// One parsed fault entry with its remaining trigger budget.
#[derive(Debug)]
struct Fault {
    kind: FaultKind,
    /// Shard index (`oom`/`err`/`hang`), batch index (`batch`), or
    /// `None` for untargeted kinds (`drain`).
    target: Option<u64>,
    /// Remaining triggers; decremented atomically so concurrent sites
    /// consume the budget deterministically (never fires twice for one
    /// decrement, never over-fires).
    remaining: AtomicU64,
    /// Sleep length for `hang` entries (ms).
    delay_ms: u64,
}

/// A deterministic, count-limited fault schedule (see module docs for
/// the `fault=` spec grammar).
#[derive(Debug)]
pub struct FaultPlan {
    spec: String,
    faults: Vec<Fault>,
}

impl FaultPlan {
    /// Parse a `fault=` spec. Errors name the offending entry so CLI
    /// typos fail fast instead of silently never firing.
    pub fn parse(spec: &str) -> Result<FaultPlan> {
        let mut faults = Vec::new();
        for entry in spec.split(',').map(str::trim).filter(|e| !e.is_empty()) {
            faults.push(Self::parse_entry(entry)?);
        }
        if faults.is_empty() {
            bail!("fault spec {spec:?} contains no entries");
        }
        Ok(FaultPlan { spec: spec.to_string(), faults })
    }

    fn parse_entry(entry: &str) -> Result<Fault> {
        // split off `~MS` then `xN` then `@T`, leaving the bare kind
        let (rest, delay_ms) = match entry.split_once('~') {
            Some((head, ms)) => {
                let ms: u64 = ms
                    .parse()
                    .with_context(|| format!("fault entry {entry:?}: bad ~ms delay"))?;
                (head, ms)
            }
            None => (entry, 0),
        };
        let (rest, count) = match rest.rsplit_once('x') {
            Some((head, n)) if n.chars().all(|c| c.is_ascii_digit()) && !n.is_empty() => {
                let n: u64 = n
                    .parse()
                    .with_context(|| format!("fault entry {entry:?}: bad xN count"))?;
                (head, n)
            }
            _ => (rest, 1),
        };
        let (kind_str, target) = match rest.split_once('@') {
            Some((k, t)) => {
                let t: u64 = t
                    .parse()
                    .with_context(|| format!("fault entry {entry:?}: bad @target index"))?;
                (k, Some(t))
            }
            None => (rest, None),
        };
        let kind = match kind_str {
            "oom" => FaultKind::InstallOom,
            "err" => FaultKind::InstallErr,
            "hang" => FaultKind::InstallHang,
            "drain" => FaultKind::DrainPanic,
            "batch" => FaultKind::BatchPanic,
            "stage" => FaultKind::StageCopyErr,
            other => bail!(
                "fault entry {entry:?}: unknown kind {other:?} \
                 (expected oom|err|hang|drain|batch|stage)"
            ),
        };
        match kind {
            FaultKind::InstallOom | FaultKind::InstallErr | FaultKind::InstallHang
            | FaultKind::BatchPanic | FaultKind::StageCopyErr => {
                if target.is_none() {
                    bail!("fault entry {entry:?}: {kind_str} needs an @index target");
                }
            }
            FaultKind::DrainPanic => {
                if target.is_some() {
                    bail!("fault entry {entry:?}: drain takes no @target");
                }
            }
        }
        if kind == FaultKind::InstallHang && delay_ms == 0 {
            bail!("fault entry {entry:?}: hang needs a ~ms delay");
        }
        Ok(Fault { kind, target, remaining: AtomicU64::new(count), delay_ms })
    }

    /// The spec this plan was parsed from (config summaries, logs).
    pub fn spec(&self) -> &str {
        &self.spec
    }

    /// Consume one trigger of the first matching live entry. Returns
    /// the entry's delay (always 0 for non-`hang` kinds).
    fn fire(&self, kind: FaultKind, target: Option<u64>) -> Option<u64> {
        for f in &self.faults {
            if f.kind != kind || f.target != target {
                continue;
            }
            // claim exactly one trigger; CAS-loop so two racing sites
            // can't both consume the last one
            if f.remaining
                .fetch_update(Ordering::AcqRel, Ordering::Acquire, |n| n.checked_sub(1))
                .is_ok()
            {
                return Some(f.delay_ms);
            }
        }
        None
    }

    /// Site: device-memory claim while installing shard `shard`.
    /// True → the caller must treat the claim as OOM.
    pub fn install_oom(&self, shard: usize) -> bool {
        self.fire(FaultKind::InstallOom, Some(shard as u64)).is_some()
    }

    /// Site: host→device fill while installing shard `shard`.
    /// True → the caller must treat the fill as a transfer error.
    pub fn install_error(&self, shard: usize) -> bool {
        self.fire(FaultKind::InstallErr, Some(shard as u64)).is_some()
    }

    /// Site: host→device fill while installing shard `shard`.
    /// `Some(ms)` → the caller must stall `ms` ms (hung install).
    pub fn install_hang_ms(&self, shard: usize) -> Option<u64> {
        self.fire(FaultKind::InstallHang, Some(shard as u64))
    }

    /// Site: tracker drain inside the refresh loop. True → the caller
    /// must panic (the watchdog is expected to absorb it).
    pub fn drain_panic(&self) -> bool {
        self.fire(FaultKind::DrainPanic, None).is_some()
    }

    /// Site: serving/pipeline execution of batch `index`. True → the
    /// caller must panic (batch isolation is expected to absorb it).
    pub fn batch_panic(&self, index: usize) -> bool {
        self.fire(FaultKind::BatchPanic, Some(index as u64)).is_some()
    }

    /// Site: coalesced staged H2D copy for batch `index`. True → the
    /// caller must degrade that batch to the per-row transfer fallback
    /// (results must be byte-identical; only the pricing degrades).
    pub fn staged_copy_error(&self, index: usize) -> bool {
        self.fire(FaultKind::StageCopyErr, Some(index as u64)).is_some()
    }

    /// Triggers left across every entry (tests / bench sanity checks).
    pub fn remaining(&self) -> u64 {
        self.faults.iter().map(|f| f.remaining.load(Ordering::Acquire)).sum()
    }
}

/// Lock a mutex, recovering from poison.
///
/// Every mutex this repo takes through here guards state that stays
/// consistent across a panic (monotonic counters, whole-value snapshot
/// swaps, channel handles) — a panicking peer can never leave it
/// half-updated, so the poison flag carries no information and
/// propagating it would turn one isolated batch panic into a cascade
/// across every thread sharing the lock. See DESIGN.md §Fault
/// tolerance.
pub fn lock_unpoisoned<T>(m: &Mutex<T>) -> MutexGuard<'_, T> {
    m.lock().unwrap_or_else(PoisonError::into_inner)
}

#[cfg(test)]
mod tests {
    use super::*;
    use std::sync::Arc;

    #[test]
    fn parses_the_full_grammar() {
        let p = FaultPlan::parse("oom@0x6,err@1x4,hang@2~300,drain,batch@7x2,stage@3x2").unwrap();
        assert_eq!(p.faults.len(), 6);
        assert_eq!(p.spec(), "oom@0x6,err@1x4,hang@2~300,drain,batch@7x2,stage@3x2");
        assert_eq!(p.remaining(), 6 + 4 + 1 + 1 + 2 + 2);
        assert_eq!(p.faults[2].delay_ms, 300);
        assert_eq!(p.faults[3].target, None);
        assert_eq!(p.faults[5].target, Some(3));
    }

    #[test]
    fn staged_copy_site_targets_one_batch() {
        let p = FaultPlan::parse("stage@2x2").unwrap();
        assert!(!p.staged_copy_error(0), "other batches never fire");
        assert!(p.staged_copy_error(2));
        assert!(p.staged_copy_error(2));
        assert!(!p.staged_copy_error(2), "x2 fires exactly twice");
        assert!(!p.batch_panic(2), "stage never crosses into the panic site");
    }

    #[test]
    fn counts_decrement_and_exhaust() {
        let p = FaultPlan::parse("oom@3x2").unwrap();
        assert!(p.install_oom(3));
        assert!(p.install_oom(3));
        assert!(!p.install_oom(3), "x2 must fire exactly twice");
        assert!(!p.install_oom(0), "other shards never fire");
        assert_eq!(p.remaining(), 0);
    }

    #[test]
    fn sites_are_independent() {
        let p = FaultPlan::parse("oom@1,err@1,hang@1~50,drain,batch@1").unwrap();
        assert!(!p.install_oom(0));
        assert!(p.install_oom(1));
        assert!(p.install_error(1));
        assert_eq!(p.install_hang_ms(1), Some(50));
        assert_eq!(p.install_hang_ms(1), None);
        assert!(p.drain_panic());
        assert!(!p.drain_panic());
        assert!(p.batch_panic(1));
        assert!(!p.batch_panic(2));
    }

    #[test]
    fn zero_count_never_fires() {
        let p = FaultPlan::parse("oom@0x0").unwrap();
        assert!(!p.install_oom(0));
    }

    #[test]
    fn rejects_malformed_specs() {
        for bad in
            ["", " , ", "frobnicate@0", "oom", "drain@2", "hang@1", "oom@x2", "hang@1~ms", "stage"]
        {
            assert!(FaultPlan::parse(bad).is_err(), "{bad:?} must not parse");
        }
    }

    #[test]
    fn concurrent_firing_never_overcounts() {
        let p = Arc::new(FaultPlan::parse("batch@0x100").unwrap());
        let fired: usize = std::thread::scope(|s| {
            (0..4)
                .map(|_| {
                    let p = Arc::clone(&p);
                    s.spawn(move || (0..100).filter(|_| p.batch_panic(0)).count())
                })
                .collect::<Vec<_>>()
                .into_iter()
                .map(|h| h.join().unwrap())
                .sum()
        });
        assert_eq!(fired, 100, "exactly the budgeted count fires across threads");
    }

    #[test]
    fn lock_unpoisoned_recovers_from_a_panicked_holder() {
        let m = Arc::new(Mutex::new(41u32));
        let m2 = Arc::clone(&m);
        let _ = std::thread::spawn(move || {
            let _g = m2.lock().unwrap();
            panic!("poison it");
        })
        .join();
        assert!(m.is_poisoned());
        *lock_unpoisoned(&m) += 1;
        assert_eq!(*lock_unpoisoned(&m), 42);
    }
}
