//! Byte-size parsing/formatting for cache budgets ("0.4GB", "512MB").

use anyhow::{bail, Result};

pub const KIB: u64 = 1 << 10;
pub const MIB: u64 = 1 << 20;
pub const GIB: u64 = 1 << 30;

/// `1536 -> "1.5KiB"`, `0.4 GiB -> "409.6MiB"` style formatting.
pub fn format_bytes(bytes: u64) -> String {
    if bytes >= GIB {
        format!("{:.2}GiB", bytes as f64 / GIB as f64)
    } else if bytes >= MIB {
        format!("{:.1}MiB", bytes as f64 / MIB as f64)
    } else if bytes >= KIB {
        format!("{:.1}KiB", bytes as f64 / KIB as f64)
    } else {
        format!("{bytes}B")
    }
}

/// Parse "1GB", "0.4GiB", "512mb", "1024", "16k" into bytes.
/// Decimal and binary suffixes are both treated as binary (the paper's
/// capacities are nominal GPU-memory sizes).
pub fn parse_bytes(s: &str) -> Result<u64> {
    let s = s.trim();
    let split = s
        .find(|c: char| !(c.is_ascii_digit() || c == '.'))
        .unwrap_or(s.len());
    let (num, suffix) = s.split_at(split);
    if num.is_empty() {
        bail!("no numeric part in byte size {s:?}");
    }
    let value: f64 = num.parse()?;
    if value < 0.0 || !value.is_finite() {
        bail!("invalid byte size {s:?}");
    }
    let mult = match suffix.trim().to_ascii_lowercase().as_str() {
        "" | "b" => 1,
        "k" | "kb" | "kib" => KIB,
        "m" | "mb" | "mib" => MIB,
        "g" | "gb" | "gib" => GIB,
        other => bail!("unknown byte suffix {other:?} in {s:?}"),
    };
    Ok((value * mult as f64).round() as u64)
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn parse_roundtrip() {
        assert_eq!(parse_bytes("1024").unwrap(), 1024);
        assert_eq!(parse_bytes("1GB").unwrap(), GIB);
        assert_eq!(parse_bytes("0.5 GiB").unwrap(), GIB / 2);
        assert_eq!(parse_bytes("512mb").unwrap(), 512 * MIB);
        assert_eq!(parse_bytes("16k").unwrap(), 16 * KIB);
        assert_eq!(parse_bytes("0").unwrap(), 0);
    }

    #[test]
    fn parse_rejects_garbage() {
        assert!(parse_bytes("").is_err());
        assert!(parse_bytes("GB").is_err());
        assert!(parse_bytes("1XB").is_err());
        assert!(parse_bytes("-1GB").is_err());
    }

    #[test]
    fn formats() {
        assert_eq!(format_bytes(12), "12B");
        assert_eq!(format_bytes(2048), "2.0KiB");
        assert_eq!(format_bytes(3 * MIB + MIB / 2), "3.5MiB");
        assert_eq!(format_bytes(GIB), "1.00GiB");
    }
}
