//! Wall-clock timing helpers used by the stage decomposition (Fig. 1)
//! and the bench harness.

use std::time::{Duration, Instant};

/// A restartable stopwatch accumulating elapsed wall time.
#[derive(Debug, Clone)]
pub struct Stopwatch {
    accum: Duration,
    started: Option<Instant>,
}

impl Default for Stopwatch {
    fn default() -> Self {
        Self::new()
    }
}

impl Stopwatch {
    pub fn new() -> Self {
        Stopwatch { accum: Duration::ZERO, started: None }
    }

    /// Start (or resume) timing. Idempotent while running.
    pub fn start(&mut self) {
        if self.started.is_none() {
            self.started = Some(Instant::now());
        }
    }

    /// Stop timing and fold the elapsed span into the accumulator.
    pub fn stop(&mut self) {
        if let Some(t0) = self.started.take() {
            self.accum += t0.elapsed();
        }
    }

    /// Total accumulated time (includes the in-flight span if running).
    pub fn elapsed(&self) -> Duration {
        match self.started {
            Some(t0) => self.accum + t0.elapsed(),
            None => self.accum,
        }
    }

    pub fn reset(&mut self) {
        self.accum = Duration::ZERO;
        self.started = None;
    }

    /// Time a closure, accumulating its wall time.
    pub fn time<T>(&mut self, f: impl FnOnce() -> T) -> T {
        self.start();
        let out = f();
        self.stop();
        out
    }
}

/// `1.234s` / `56.7ms` / `890us` style rendering for reports.
pub fn format_duration(d: Duration) -> String {
    let ns = d.as_nanos();
    if ns >= 1_000_000_000 {
        format!("{:.3}s", ns as f64 / 1e9)
    } else if ns >= 1_000_000 {
        format!("{:.1}ms", ns as f64 / 1e6)
    } else if ns >= 1_000 {
        format!("{:.0}us", ns as f64 / 1e3)
    } else {
        format!("{ns}ns")
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn accumulates_across_spans() {
        let mut sw = Stopwatch::new();
        sw.time(|| std::thread::sleep(Duration::from_millis(2)));
        let t1 = sw.elapsed();
        assert!(t1 >= Duration::from_millis(2));
        sw.time(|| std::thread::sleep(Duration::from_millis(2)));
        assert!(sw.elapsed() >= t1 + Duration::from_millis(2));
    }

    #[test]
    fn reset_clears() {
        let mut sw = Stopwatch::new();
        sw.time(|| ());
        sw.reset();
        assert_eq!(sw.elapsed(), Duration::ZERO);
    }

    #[test]
    fn stop_without_start_is_noop() {
        let mut sw = Stopwatch::new();
        sw.stop();
        assert_eq!(sw.elapsed(), Duration::ZERO);
    }

    #[test]
    fn formats() {
        assert_eq!(format_duration(Duration::from_secs(2)), "2.000s");
        assert_eq!(format_duration(Duration::from_millis(56)), "56.0ms");
        assert_eq!(format_duration(Duration::from_micros(890)), "890us");
        assert_eq!(format_duration(Duration::from_nanos(12)), "12ns");
    }
}
