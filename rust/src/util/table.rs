//! Aligned text tables — the bench harness prints the paper's tables
//! and figure series in this format.

/// Builds a column-aligned table with a header row.
#[derive(Debug, Clone)]
pub struct Table {
    header: Vec<String>,
    rows: Vec<Vec<String>>,
}

impl Table {
    pub fn new(header: &[&str]) -> Self {
        Table {
            header: header.iter().map(|s| s.to_string()).collect(),
            rows: Vec::new(),
        }
    }

    pub fn row(&mut self, cells: &[String]) -> &mut Self {
        assert_eq!(
            cells.len(),
            self.header.len(),
            "row width {} != header width {}",
            cells.len(),
            self.header.len()
        );
        self.rows.push(cells.to_vec());
        self
    }

    pub fn row_strs(&mut self, cells: &[&str]) -> &mut Self {
        let owned: Vec<String> = cells.iter().map(|s| s.to_string()).collect();
        self.row(&owned)
    }

    pub fn n_rows(&self) -> usize {
        self.rows.len()
    }

    pub fn render(&self) -> String {
        let ncols = self.header.len();
        let mut widths: Vec<usize> = self.header.iter().map(|h| h.len()).collect();
        for row in &self.rows {
            for (i, cell) in row.iter().enumerate() {
                widths[i] = widths[i].max(cell.len());
            }
        }
        let mut out = String::new();
        let fmt_row = |cells: &[String], widths: &[usize]| -> String {
            let mut line = String::new();
            for i in 0..ncols {
                if i > 0 {
                    line.push_str("  ");
                }
                let cell = &cells[i];
                // left-align first column, right-align the rest
                if i == 0 {
                    line.push_str(&format!("{:<w$}", cell, w = widths[i]));
                } else {
                    line.push_str(&format!("{:>w$}", cell, w = widths[i]));
                }
            }
            line.trim_end().to_string()
        };
        out.push_str(&fmt_row(&self.header, &widths));
        out.push('\n');
        let total: usize = widths.iter().sum::<usize>() + 2 * (ncols - 1);
        out.push_str(&"-".repeat(total));
        out.push('\n');
        for row in &self.rows {
            out.push_str(&fmt_row(row, &widths));
            out.push('\n');
        }
        out
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn renders_aligned() {
        let mut t = Table::new(&["name", "value"]);
        t.row_strs(&["a", "1"]);
        t.row_strs(&["longer-name", "12345"]);
        let s = t.render();
        let lines: Vec<&str> = s.lines().collect();
        assert_eq!(lines.len(), 4);
        assert!(lines[0].starts_with("name"));
        assert!(lines[2].ends_with("1"));
        assert!(lines[3].ends_with("12345"));
        assert_eq!(t.n_rows(), 2);
    }

    #[test]
    #[should_panic]
    fn wrong_width_panics() {
        let mut t = Table::new(&["a", "b"]);
        t.row_strs(&["only-one"]);
    }
}
