//! Summary statistics for the bench harness and serving metrics.

/// Mean of a sample (0 for empty).
pub fn mean(xs: &[f64]) -> f64 {
    if xs.is_empty() {
        0.0
    } else {
        xs.iter().sum::<f64>() / xs.len() as f64
    }
}

/// Population standard deviation.
pub fn std_dev(xs: &[f64]) -> f64 {
    if xs.len() < 2 {
        return 0.0;
    }
    let m = mean(xs);
    (xs.iter().map(|x| (x - m).powi(2)).sum::<f64>() / xs.len() as f64).sqrt()
}

/// Percentile by linear interpolation (p in [0, 100]).
pub fn percentile(sorted: &[f64], p: f64) -> f64 {
    assert!(!sorted.is_empty(), "percentile of empty sample");
    assert!((0.0..=100.0).contains(&p));
    if sorted.len() == 1 {
        return sorted[0];
    }
    let rank = p / 100.0 * (sorted.len() - 1) as f64;
    let lo = rank.floor() as usize;
    let hi = rank.ceil() as usize;
    let frac = rank - lo as f64;
    sorted[lo] * (1.0 - frac) + sorted[hi] * frac
}

/// Streaming latency histogram (fixed log-spaced buckets, ns domain).
#[derive(Debug, Clone)]
pub struct LatencyHist {
    samples: Vec<f64>, // ns; serving volumes here are small enough to keep raw
}

impl Default for LatencyHist {
    fn default() -> Self {
        Self::new()
    }
}

impl LatencyHist {
    pub fn new() -> Self {
        LatencyHist { samples: Vec::new() }
    }

    pub fn record_ns(&mut self, ns: u64) {
        self.samples.push(ns as f64);
    }

    pub fn count(&self) -> usize {
        self.samples.len()
    }

    pub fn mean_ns(&self) -> f64 {
        mean(&self.samples)
    }

    /// (p50, p90, p99) in ns.
    pub fn quantiles_ns(&self) -> (f64, f64, f64) {
        if self.samples.is_empty() {
            return (0.0, 0.0, 0.0);
        }
        let mut s = self.samples.clone();
        s.sort_by(|a, b| a.partial_cmp(b).unwrap());
        (percentile(&s, 50.0), percentile(&s, 90.0), percentile(&s, 99.0))
    }

    pub fn merge(&mut self, other: &LatencyHist) {
        self.samples.extend_from_slice(&other.samples);
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn mean_and_std() {
        assert_eq!(mean(&[]), 0.0);
        assert_eq!(mean(&[2.0, 4.0]), 3.0);
        assert!((std_dev(&[2.0, 4.0]) - 1.0).abs() < 1e-12);
        assert_eq!(std_dev(&[5.0]), 0.0);
    }

    #[test]
    fn percentiles_interpolate() {
        let xs = [1.0, 2.0, 3.0, 4.0];
        assert_eq!(percentile(&xs, 0.0), 1.0);
        assert_eq!(percentile(&xs, 100.0), 4.0);
        assert_eq!(percentile(&xs, 50.0), 2.5);
        assert_eq!(percentile(&[7.0], 99.0), 7.0);
    }

    #[test]
    #[should_panic]
    fn percentile_empty_panics() {
        percentile(&[], 50.0);
    }

    #[test]
    fn latency_hist_quantiles() {
        let mut h = LatencyHist::new();
        for i in 1..=100u64 {
            h.record_ns(i * 1000);
        }
        let (p50, p90, p99) = h.quantiles_ns();
        assert!((p50 - 50_500.0).abs() < 1e-6, "p50={p50}");
        assert!(p90 > p50 && p99 > p90);
        assert_eq!(h.count(), 100);

        let mut h2 = LatencyHist::new();
        h2.record_ns(1);
        h2.merge(&h);
        assert_eq!(h2.count(), 101);
    }
}
