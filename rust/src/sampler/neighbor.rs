//! Fan-out neighbor sampling over an [`AdjSource`].
//!
//! The sampler walks seed-side first (hop 0 expands the seeds), builds
//! each layer's source node array with the dst nodes as a prefix
//! ("dst-first"), dedups via a node→local-index map, and emits blocks
//! in input-most-first order (the model convention).

use std::sync::Mutex;

use crate::graph::{Csc, NodeId};
use crate::mem::TransferLedger;
use crate::util::Rng;

use super::block::{Block, MiniBatch};
use super::fanout::Fanout;
use super::AdjSource;

/// Plain host adjacency accessed over UVA — the DGL baseline path.
/// Every element read is a random PCIe transaction.
pub struct UvaAdj<'a> {
    pub csc: &'a Csc,
}

impl<'a> AdjSource for UvaAdj<'a> {
    #[inline]
    fn degree(&self, v: NodeId) -> usize {
        self.csc.degree(v)
    }

    #[inline]
    fn neighbor_at(&self, v: NodeId, pos: usize, ledger: &mut TransferLedger) -> NodeId {
        ledger.miss(std::mem::size_of::<NodeId>() as u64, 1);
        self.csc.neighbors(v)[pos]
    }
}

/// Multi-layer neighbor sampler.
///
/// Dedup within each hop uses an epoch-stamped direct-array map instead
/// of a `HashMap` — the perf pass measured the SipHash + allocation
/// overhead at ~6x the cost of the adjacency read itself
/// (EXPERIMENTS.md §Perf). The stamp arrays are reused across batches,
/// so steady-state sampling does no per-batch allocation beyond the
/// output arrays.
#[derive(Debug, Clone)]
pub struct NeighborSampler {
    pub fanout: Fanout,
    /// node -> epoch of last sighting (len grows to the max node id).
    stamp: Vec<u32>,
    /// node -> local index, valid iff `stamp[node] == epoch`.
    slot: Vec<u32>,
    epoch: u32,
}

impl NeighborSampler {
    pub fn new(fanout: Fanout) -> Self {
        NeighborSampler { fanout, stamp: Vec::new(), slot: Vec::new(), epoch: 0 }
    }

    /// Pre-size the dedup scratch for a known graph (avoids growth
    /// stalls on the first batches).
    pub fn with_nodes(fanout: Fanout, n_nodes: usize) -> Self {
        NeighborSampler {
            fanout,
            stamp: vec![0; n_nodes],
            slot: vec![0; n_nodes],
            epoch: 0,
        }
    }

    /// Intern a dst node at src-array construction (seeds are unique by
    /// construction, so no membership check is needed — just stamp).
    #[inline]
    fn intern_known_new(&mut self, u: NodeId, src: &mut Vec<NodeId>) {
        let i = u as usize;
        if i >= self.stamp.len() {
            self.stamp.resize(i + 1, 0);
            self.slot.resize(i + 1, 0);
        }
        debug_assert_ne!(self.stamp[i], self.epoch, "duplicate dst node {u}");
        self.stamp[i] = self.epoch;
        self.slot[i] = src.len() as u32;
        src.push(u);
    }

    #[inline]
    fn intern(&mut self, u: NodeId, src: &mut Vec<NodeId>) -> u32 {
        let i = u as usize;
        if i >= self.stamp.len() {
            self.stamp.resize(i + 1, 0);
            self.slot.resize(i + 1, 0);
        }
        if self.stamp[i] == self.epoch {
            self.slot[i]
        } else {
            self.stamp[i] = self.epoch;
            let li = src.len() as u32;
            self.slot[i] = li;
            src.push(u);
            li
        }
    }

    fn next_epoch(&mut self) {
        if self.epoch == u32::MAX {
            self.stamp.iter_mut().for_each(|s| *s = 0);
            self.epoch = 0;
        }
        self.epoch += 1;
    }

    /// Sample one mini-batch for `seeds`.
    pub fn sample_batch<A: AdjSource>(
        &mut self,
        adj: &A,
        seeds: &[NodeId],
        rng: &mut Rng,
        ledger: &mut TransferLedger,
    ) -> MiniBatch {
        self.sample_batch_inner(adj, seeds, rng, ledger, &mut |_, _| {})
    }

    /// Sample one mini-batch while invoking `on_access(node, pos)` for
    /// every element read — the pre-sampling counting hook.
    pub fn sample_batch_counting<A: AdjSource>(
        &mut self,
        adj: &A,
        seeds: &[NodeId],
        rng: &mut Rng,
        ledger: &mut TransferLedger,
        on_access: &mut dyn FnMut(NodeId, usize),
    ) -> MiniBatch {
        self.sample_batch_inner(adj, seeds, rng, ledger, on_access)
    }

    fn sample_batch_inner<A: AdjSource>(
        &mut self,
        adj: &A,
        seeds: &[NodeId],
        rng: &mut Rng,
        ledger: &mut TransferLedger,
        on_access: &mut dyn FnMut(NodeId, usize),
    ) -> MiniBatch {
        let n_layers = self.fanout.layers();
        // seed-side first; reversed at the end. `current` is the hop's
        // dst array; it moves into node_arrays when its src is built
        // (no per-hop clone).
        let mut node_arrays: Vec<Vec<NodeId>> = Vec::with_capacity(n_layers + 1);
        let mut blocks_rev: Vec<Block> = Vec::with_capacity(n_layers);
        let mut pos_scratch: Vec<u32> = Vec::new();
        let mut current: Vec<NodeId> = seeds.to_vec();

        for hop in 0..n_layers {
            ledger.launch(); // one sampling kernel per hop
            let dst = &current;
            let k = self.fanout.for_hop(hop);
            let mut block = Block::new(dst.len(), k);
            // dst-first source array + epoch-stamped dedup
            self.next_epoch();
            let mut src: Vec<NodeId> = Vec::with_capacity(dst.len() * (k + 1));
            for &v in dst {
                self.intern_known_new(v, &mut src);
            }

            for (di, &v) in dst.iter().enumerate() {
                let deg = adj.degree(v);
                if deg == 0 {
                    continue;
                }
                if deg <= k {
                    // take all neighbors
                    for pos in 0..deg {
                        let u = adj.neighbor_at(v, pos, ledger);
                        on_access(v, pos);
                        let li = self.intern(u, &mut src);
                        block.set(di, pos, li);
                    }
                } else {
                    rng.sample_indices(deg, k, &mut pos_scratch);
                    for (slot, &pos) in pos_scratch.iter().enumerate() {
                        let u = adj.neighbor_at(v, pos as usize, ledger);
                        on_access(v, pos as usize);
                        let li = self.intern(u, &mut src);
                        block.set(di, slot, li);
                    }
                }
            }
            node_arrays.push(std::mem::replace(&mut current, src));
            blocks_rev.push(block);
        }
        node_arrays.push(current);

        node_arrays.reverse();
        blocks_rev.reverse();
        let mb = MiniBatch { nodes: node_arrays, layers: blocks_rev };
        debug_assert_eq!(mb.validate(), Ok(()));
        mb
    }
}

/// Checkout/checkin pool of [`NeighborSampler`] scratch state.
///
/// A sampler's epoch-stamp arrays are two O(n_nodes) allocations, but
/// sampling output is independent of their prior contents (the epoch
/// counter invalidates stale entries), so samplers are safely reusable
/// across batches, requests, and threads. The engine keeps one pool and
/// hands a sampler to each pipeline worker / served request instead of
/// zeroing two node-sized arrays per use — the coordinator hot path
/// does no per-request allocation.
pub struct SamplerPool {
    fanout: Fanout,
    n_nodes: usize,
    free: Mutex<Vec<NeighborSampler>>,
}

impl SamplerPool {
    pub fn new(fanout: Fanout, n_nodes: usize) -> Self {
        SamplerPool { fanout, n_nodes, free: Mutex::new(Vec::new()) }
    }

    /// Take a sampler; allocates a fresh one only when the pool is dry.
    /// Recovers from a poisoned free list (the `Vec` is consistent
    /// whenever the lock is free), so one panicked worker never takes
    /// the pool down with it.
    pub fn checkout(&self) -> NeighborSampler {
        match crate::util::lock_unpoisoned(&self.free).pop() {
            Some(s) => s,
            None => NeighborSampler::with_nodes(self.fanout.clone(), self.n_nodes),
        }
    }

    /// Return a sampler for reuse.
    pub fn checkin(&self, sampler: NeighborSampler) {
        crate::util::lock_unpoisoned(&self.free).push(sampler);
    }

    /// Samplers currently idle in the pool.
    pub fn idle(&self) -> usize {
        crate::util::lock_unpoisoned(&self.free).len()
    }
}

/// Convenience: chunk a seed list into consecutive batches of
/// `batch_size` (the last batch may be short), mirroring DGL's
/// test-set DataLoader (Fig. 3).
pub fn seed_batches(test_nodes: &[NodeId], batch_size: usize) -> Vec<&[NodeId]> {
    assert!(batch_size > 0);
    test_nodes.chunks(batch_size).collect()
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::graph::datasets;

    fn tiny() -> crate::graph::Dataset {
        datasets::spec("tiny").unwrap().build()
    }

    #[test]
    fn sample_batch_structure() {
        let ds = tiny();
        let mut s = NeighborSampler::new(Fanout::parse("3,2,2").unwrap());
        let adj = UvaAdj { csc: &ds.csc };
        let mut rng = Rng::new(1);
        let mut ledger = TransferLedger::new();
        let seeds: Vec<NodeId> = ds.test_nodes[..64].to_vec();
        let mb = s.sample_batch(&adj, &seeds, &mut rng, &mut ledger);
        mb.validate().unwrap();
        assert_eq!(mb.n_layers(), 3);
        assert_eq!(mb.seeds(), seeds.as_slice());
        // widest array is the input
        assert!(mb.input_nodes().len() >= mb.seeds().len());
        // sampling recorded UVA traffic
        assert!(ledger.uva_txns > 0);
        assert_eq!(ledger.launches, 3);
    }

    #[test]
    fn fanout_respected_and_low_degree_takes_all() {
        let ds = tiny();
        let mut s = NeighborSampler::new(Fanout::parse("2").unwrap());
        let adj = UvaAdj { csc: &ds.csc };
        let mut rng = Rng::new(2);
        let mut ledger = TransferLedger::new();
        let seeds: Vec<NodeId> = (0..100).collect();
        let mb = s.sample_batch(&adj, &seeds, &mut rng, &mut ledger);
        let blk = &mb.layers[0];
        for (di, &v) in seeds.iter().enumerate() {
            let valid: usize = (0..blk.k)
                .filter(|&sl| blk.mask[di * blk.k + sl] != 0.0)
                .count();
            assert_eq!(valid, ds.csc.degree(v).min(2), "node {v}");
        }
    }

    #[test]
    fn dedup_within_batch() {
        let ds = tiny();
        let mut s = NeighborSampler::new(Fanout::parse("4,4").unwrap());
        let adj = UvaAdj { csc: &ds.csc };
        let mut rng = Rng::new(3);
        let mut ledger = TransferLedger::new();
        let seeds: Vec<NodeId> = ds.test_nodes[..128].to_vec();
        let mb = s.sample_batch(&adj, &seeds, &mut rng, &mut ledger);
        for arr in &mb.nodes {
            let mut sorted = arr.clone();
            sorted.sort_unstable();
            sorted.dedup();
            assert_eq!(sorted.len(), arr.len(), "duplicate nodes in array");
        }
    }

    #[test]
    fn deterministic_given_seed() {
        let ds = tiny();
        let mut s = NeighborSampler::new(Fanout::parse("3,3").unwrap());
        let adj = UvaAdj { csc: &ds.csc };
        let seeds: Vec<NodeId> = ds.test_nodes[..32].to_vec();
        let mut l1 = TransferLedger::new();
        let mut l2 = TransferLedger::new();
        let a = s.sample_batch(&adj, &seeds, &mut Rng::new(9), &mut l1);
        let b = s.sample_batch(&adj, &seeds, &mut Rng::new(9), &mut l2);
        assert_eq!(a.nodes, b.nodes);
        assert_eq!(l1, l2);
    }

    #[test]
    fn counting_hook_sees_every_access() {
        let ds = tiny();
        let mut s = NeighborSampler::new(Fanout::parse("3,2").unwrap());
        let adj = UvaAdj { csc: &ds.csc };
        let mut rng = Rng::new(4);
        let mut ledger = TransferLedger::new();
        let seeds: Vec<NodeId> = ds.test_nodes[..64].to_vec();
        let mut n = 0u64;
        let _ = s.sample_batch_counting(&adj, &seeds, &mut rng, &mut ledger, &mut |_, _| {
            n += 1;
        });
        assert_eq!(n, ledger.uva_txns);
        assert!(n > 0);
    }

    #[test]
    fn pool_reuses_scratch_without_changing_output() {
        let ds = tiny();
        let pool = SamplerPool::new(Fanout::parse("3,2").unwrap(), ds.csc.n_nodes());
        let adj = UvaAdj { csc: &ds.csc };
        let seeds: Vec<NodeId> = ds.test_nodes[..32].to_vec();

        let mut s1 = pool.checkout();
        let mut l1 = TransferLedger::new();
        let a = s1.sample_batch(&adj, &seeds, &mut Rng::new(5), &mut l1);
        pool.checkin(s1);
        assert_eq!(pool.idle(), 1);

        // the recycled sampler (dirty scratch) must sample identically
        let mut s2 = pool.checkout();
        assert_eq!(pool.idle(), 0);
        let mut l2 = TransferLedger::new();
        let b = s2.sample_batch(&adj, &seeds, &mut Rng::new(5), &mut l2);
        pool.checkin(s2);
        assert_eq!(a.nodes, b.nodes);
        assert_eq!(l1, l2);
    }

    #[test]
    fn seed_batches_chunks() {
        let ids: Vec<NodeId> = (0..10).collect();
        let b = seed_batches(&ids, 4);
        assert_eq!(b.len(), 3);
        assert_eq!(b[2], &[8, 9]);
    }
}
