//! Neighbor sampling: fan-out parsing, mini-batch block construction,
//! and the pre-sampling workload profiler (§IV.A).
//!
//! Adjacency reads go through the [`AdjSource`] trait so the same
//! sampler runs over plain host CSC via UVA (DGL baseline), or through
//! DCI's adjacency cache — each implementation records its transfer
//! behaviour in a [`TransferLedger`].

pub mod block;
pub mod fanout;
pub mod neighbor;
pub mod presample;

pub use block::{Block, MiniBatch};
pub use fanout::Fanout;
pub use neighbor::{seed_batches, NeighborSampler, SamplerPool, UvaAdj};
pub use presample::{presample, presample_threads, PresampleStats};

use crate::graph::NodeId;
use crate::mem::TransferLedger;

/// Where the sampler reads adjacency from. `pos` is a position within
/// `v`'s (possibly reordered — see `cache::adj_cache`) neighbor list.
pub trait AdjSource {
    /// In-degree of `v` (degree metadata is always device-resident:
    /// `col_ptr` is small and both DCI and DUCATI keep it on-device).
    fn degree(&self, v: NodeId) -> usize;

    /// Read the neighbor at `pos ∈ [0, degree(v))`, accounting the
    /// transfer in `ledger`.
    fn neighbor_at(&self, v: NodeId, pos: usize, ledger: &mut TransferLedger) -> NodeId;
}
