//! Pre-sampling workload profiler (§IV.A).
//!
//! Runs `n_batches` mini-batches of the *actual inference workload*
//! (test seeds, real fan-out) and records:
//!
//! - per-node feature visit counts (feature-cache filling input),
//! - per-CSC-element access counts — the `Counts` array of Fig. 6
//!   (adjacency-cache filling input, Algorithm 1),
//! - `T_sample` and `T_feature`, the two stage times whose ratio drives
//!   the Eq. (1) capacity split,
//! - the peak per-batch memory footprint (workload-awareness: how much
//!   device memory inference itself needs before caching).

use std::time::Instant;

use crate::graph::{Csc, FeatureStore, NodeId};
use crate::mem::{CostModel, TransferLedger};
use crate::util::Rng;

use super::fanout::Fanout;
use super::neighbor::{seed_batches, NeighborSampler, UvaAdj};

/// Everything the DCI preprocessing pipeline needs from pre-sampling.
#[derive(Debug, Clone)]
pub struct PresampleStats {
    /// Batches actually profiled.
    pub n_batches: usize,
    /// Per-node visit counts in the feature-loading stage.
    pub node_visits: Vec<u32>,
    /// Per-CSC-element access counts (parallel to `csc.row_index`) —
    /// Fig. 6's `Counts`.
    pub elem_counts: Vec<u32>,
    /// Sampling-stage time over the profiled batches, ns. This is the
    /// *simulated* (modeled-transfer) time — the stand-in for the GPU
    /// stage time the paper measures; using it makes the Eq. (1) split
    /// deterministic and independent of the simulator's CPU speed.
    pub t_sample_ns: f64,
    /// Feature-stage time over the profiled batches, ns (modeled).
    pub t_feature_ns: f64,
    /// Peak input-node count in one batch (drives the workload's own
    /// device-memory claim).
    pub max_input_nodes: usize,
    /// Total input-node loads (Table I "Loaded-nodes", over the profiled
    /// prefix).
    pub loaded_nodes: u64,
    /// Wall time the profiling itself took, ns (the preprocessing cost
    /// DCI keeps small — Tables IV / Fig. 10).
    pub wall_ns: f64,
}

impl PresampleStats {
    /// Eq. (1) ratio input: fraction of prep time spent sampling.
    pub fn sample_fraction(&self) -> f64 {
        let total = self.t_sample_ns + self.t_feature_ns;
        if total == 0.0 {
            0.5
        } else {
            self.t_sample_ns / total
        }
    }

    /// Mean visits per node over nodes visited at least once — the
    /// "average number of visits" threshold of §IV.B (computed over all
    /// nodes, as the paper's tensor-mean does).
    pub fn avg_node_visits(&self) -> f64 {
        if self.node_visits.is_empty() {
            return 0.0;
        }
        let total: u64 = self.node_visits.iter().map(|&c| c as u64).sum();
        total as f64 / self.node_visits.len() as f64
    }
}

/// Profile `n_batches` batches of the workload. Deterministic given
/// `rng`. The profiled batches use the same seed stream the real run
/// will use (the paper pre-samples the actual inference workload).
pub fn presample(
    csc: &Csc,
    features: &FeatureStore,
    test_nodes: &[NodeId],
    batch_size: usize,
    fanout: &Fanout,
    n_batches: usize,
    cost: &CostModel,
    rng: &mut Rng,
) -> PresampleStats {
    let wall_start = Instant::now();
    let mut sampler = NeighborSampler::with_nodes(fanout.clone(), csc.n_nodes());
    let adj = UvaAdj { csc };

    let mut node_visits = vec![0u32; csc.n_nodes()];
    let mut elem_counts = vec![0u32; csc.n_edges()];

    let mut t_sample_ns = 0.0;
    let mut t_feature_ns = 0.0;
    let mut max_input_nodes = 0usize;
    let mut loaded_nodes = 0u64;

    let batches = seed_batches(test_nodes, batch_size);
    let n_batches = n_batches.min(batches.len());
    for seeds in batches.iter().take(n_batches) {
        // --- sampling stage (counted) ---
        let mut s_ledger = TransferLedger::new();
        let mb = sampler.sample_batch_counting(
            &adj,
            seeds,
            rng,
            &mut s_ledger,
            &mut |v, pos| {
                let at = csc.neighbor_offset(v) as usize + pos;
                elem_counts[at] += 1;
            },
        );
        t_sample_ns += s_ledger.modeled_ns(cost);

        // --- feature-loading stage (UVA, no cache yet) ---
        // profiling needs visit counts + modeled load cost; the actual
        // row copies would be pure simulator overhead, so they are
        // accounted (modeled) but not performed here
        let inputs = mb.input_nodes();
        max_input_nodes = max_input_nodes.max(inputs.len());
        loaded_nodes += inputs.len() as u64;
        let mut f_ledger = TransferLedger::new();
        f_ledger.launch();
        let txns = row_txns(features.row_bytes(), cost);
        for &v in inputs {
            node_visits[v as usize] += 1;
            f_ledger.miss(features.row_bytes(), txns);
        }
        t_feature_ns += f_ledger.modeled_ns(cost);
    }

    PresampleStats {
        n_batches,
        node_visits,
        elem_counts,
        t_sample_ns,
        t_feature_ns,
        max_input_nodes,
        loaded_nodes,
        wall_ns: wall_start.elapsed().as_nanos() as f64,
    }
}

/// UVA transactions needed for one feature row.
#[inline]
pub fn row_txns(row_bytes: u64, cost: &CostModel) -> u64 {
    row_bytes.div_ceil(cost.uva_line_bytes).max(1)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::graph::datasets;

    #[test]
    fn presample_counts_and_times() {
        let ds = datasets::spec("tiny").unwrap().build();
        let fanout = Fanout::parse("3,2").unwrap();
        let cost = CostModel::default();
        let mut rng = Rng::new(1);
        let st = presample(
            &ds.csc, &ds.features, &ds.test_nodes, 64, &fanout, 4, &cost, &mut rng,
        );
        assert_eq!(st.n_batches, 4);
        assert!(st.t_sample_ns > 0.0 && st.t_feature_ns > 0.0);
        assert!(st.max_input_nodes >= 64);
        assert!(st.loaded_nodes >= 4 * 64);
        // visit counts total == loaded nodes
        let visits: u64 = st.node_visits.iter().map(|&c| c as u64).sum();
        assert_eq!(visits, st.loaded_nodes);
        // element accesses happened
        assert!(st.elem_counts.iter().any(|&c| c > 0));
        let frac = st.sample_fraction();
        assert!((0.0..=1.0).contains(&frac));
        assert!(st.avg_node_visits() > 0.0);
    }

    #[test]
    fn presample_caps_at_available_batches() {
        let ds = datasets::spec("tiny").unwrap().build();
        let fanout = Fanout::parse("2").unwrap();
        let cost = CostModel::default();
        let mut rng = Rng::new(2);
        let st = presample(
            &ds.csc, &ds.features, &ds.test_nodes[..100], 64, &fanout, 99, &cost,
            &mut rng,
        );
        assert_eq!(st.n_batches, 2); // 100 seeds / 64 = 2 chunks
    }

    #[test]
    fn deterministic() {
        let ds = datasets::spec("tiny").unwrap().build();
        let fanout = Fanout::parse("3,2").unwrap();
        let cost = CostModel::default();
        let a = presample(&ds.csc, &ds.features, &ds.test_nodes, 32, &fanout, 3,
                          &cost, &mut Rng::new(7));
        let b = presample(&ds.csc, &ds.features, &ds.test_nodes, 32, &fanout, 3,
                          &cost, &mut Rng::new(7));
        assert_eq!(a.node_visits, b.node_visits);
        assert_eq!(a.elem_counts, b.elem_counts);
        assert_eq!(a.loaded_nodes, b.loaded_nodes);
    }

    #[test]
    fn skewed_graph_has_skewed_visits() {
        let ds = datasets::spec("tiny").unwrap().build();
        // small batches + small fan-out so the 2k-node graph does not
        // saturate (every batch touching every node hides the skew)
        let fanout = Fanout::parse("2,2").unwrap();
        let cost = CostModel::default();
        let mut rng = Rng::new(3);
        let st = presample(&ds.csc, &ds.features, &ds.test_nodes, 32, &fanout, 8,
                           &cost, &mut rng);
        let max = *st.node_visits.iter().max().unwrap() as f64;
        assert!(max >= 3.0 * st.avg_node_visits(),
                "power-law graph should have hot nodes (max={max}, avg={})",
                st.avg_node_visits());
    }
}
