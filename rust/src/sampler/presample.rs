//! Pre-sampling workload profiler (§IV.A).
//!
//! Runs `n_batches` mini-batches of the *actual inference workload*
//! (test seeds, real fan-out) and records:
//!
//! - per-node feature visit counts (feature-cache filling input),
//! - per-CSC-element access counts — the `Counts` array of Fig. 6
//!   (adjacency-cache filling input, Algorithm 1),
//! - `T_sample` and `T_feature`, the two stage times whose ratio drives
//!   the Eq. (1) capacity split,
//! - the peak per-batch memory footprint (workload-awareness: how much
//!   device memory inference itself needs before caching).
//!
//! Profiling parallelizes over batches ([`presample_threads`]): each
//! worker owns a sampler, counts accumulate into one *shared* pair of
//! `node_visits`/`elem_counts` arrays (plain `Cell` adds serially,
//! relaxed atomics in parallel — u32 adds commute, and one copy keeps
//! profiler memory flat in the thread count), and every batch's
//! RNG is a pure function of the caller's root and the batch index —
//! so the profile is **bit-identical at any thread count** (and, given
//! the preparation root `Rng::new(cfg.seed)`, identical to the run's
//! own sampling streams). This attacks the paper's
//! headline preprocessing-time metric (Tables IV, Fig. 10) directly:
//! pre-sampling dominates DCI's preprocessing wall time.

use std::cell::Cell;
use std::sync::atomic::{AtomicU32, Ordering};
use std::time::Instant;

use crate::graph::{Csc, FeatureStore, NodeId};
use crate::mem::{CostModel, TransferLedger};
use crate::util::Rng;

use super::fanout::Fanout;
use super::neighbor::{seed_batches, NeighborSampler, UvaAdj};

/// Everything the DCI preprocessing pipeline needs from pre-sampling.
#[derive(Debug, Clone)]
pub struct PresampleStats {
    /// Batches actually profiled.
    pub n_batches: usize,
    /// Per-node visit counts in the feature-loading stage.
    pub node_visits: Vec<u32>,
    /// Per-CSC-element access counts (parallel to `csc.row_index`) —
    /// Fig. 6's `Counts`.
    pub elem_counts: Vec<u32>,
    /// Sampling-stage time over the profiled batches, ns. This is the
    /// *simulated* (modeled-transfer) time — the stand-in for the GPU
    /// stage time the paper measures; using it makes the Eq. (1) split
    /// deterministic and independent of the simulator's CPU speed.
    pub t_sample_ns: f64,
    /// Feature-stage time over the profiled batches, ns (modeled).
    pub t_feature_ns: f64,
    /// Peak input-node count in one batch (drives the workload's own
    /// device-memory claim).
    pub max_input_nodes: usize,
    /// Total input-node loads (Table I "Loaded-nodes", over the profiled
    /// prefix).
    pub loaded_nodes: u64,
    /// Wall time the profiling itself took, ns (the preprocessing cost
    /// DCI keeps small — Tables IV / Fig. 10).
    pub wall_ns: f64,
}

impl PresampleStats {
    /// Eq. (1) ratio input: fraction of prep time spent sampling.
    pub fn sample_fraction(&self) -> f64 {
        let total = self.t_sample_ns + self.t_feature_ns;
        if total == 0.0 {
            0.5
        } else {
            self.t_sample_ns / total
        }
    }

    /// Mean visits per node over nodes visited at least once — the
    /// "average number of visits" threshold of §IV.B (computed over all
    /// nodes, as the paper's tensor-mean does).
    pub fn avg_node_visits(&self) -> f64 {
        if self.node_visits.is_empty() {
            return 0.0;
        }
        let total: u64 = self.node_visits.iter().map(|&c| c as u64).sum();
        total as f64 / self.node_visits.len() as f64
    }
}

/// Shared count-array increment, `&self` in both flavors so one
/// `profile_batch` serves the serial path (plain `Cell` adds) and the
/// parallel path (relaxed atomic adds — commutative, so the totals are
/// thread-schedule-invariant) without paying lock-prefixed RMWs in the
/// profiler's innermost loop when `threads == 1`.
trait CountSink {
    fn bump(&self, at: usize);
}

impl CountSink for [Cell<u32>] {
    #[inline]
    fn bump(&self, at: usize) {
        self[at].set(self[at].get() + 1);
    }
}

impl CountSink for [AtomicU32] {
    #[inline]
    fn bump(&self, at: usize) {
        self[at].fetch_add(1, Ordering::Relaxed);
    }
}

/// Profile one batch into the count sinks. Returns
/// `(t_sample_ns, t_feature_ns, n_inputs)` for the batch.
#[allow(clippy::too_many_arguments)]
fn profile_batch<S: CountSink + ?Sized>(
    csc: &Csc,
    seeds: &[NodeId],
    row_bytes: u64,
    cost: &CostModel,
    sampler: &mut NeighborSampler,
    rng: &mut Rng,
    node_visits: &S,
    elem_counts: &S,
) -> (f64, f64, usize) {
    // --- sampling stage (counted) ---
    let adj = UvaAdj { csc };
    let mut s_ledger = TransferLedger::new();
    let mb = sampler.sample_batch_counting(&adj, seeds, rng, &mut s_ledger, &mut |v, pos| {
        let at = csc.neighbor_offset(v) as usize + pos;
        elem_counts.bump(at);
    });

    // --- feature-loading stage (UVA, no cache yet) ---
    // profiling needs visit counts + modeled load cost; the actual row
    // copies would be pure simulator overhead, so they are accounted
    // (modeled) but not performed here
    let inputs = mb.input_nodes();
    let mut f_ledger = TransferLedger::new();
    f_ledger.launch();
    let txns = row_txns(row_bytes, cost);
    for &v in inputs {
        node_visits.bump(v as usize);
        f_ledger.miss(row_bytes, txns);
    }
    (s_ledger.modeled_ns(cost), f_ledger.modeled_ns(cost), inputs.len())
}

/// Serial convenience wrapper around [`presample_threads`].
#[allow(clippy::too_many_arguments)]
pub fn presample(
    csc: &Csc,
    features: &FeatureStore,
    test_nodes: &[NodeId],
    batch_size: usize,
    fanout: &Fanout,
    n_batches: usize,
    cost: &CostModel,
    rng: &mut Rng,
) -> PresampleStats {
    presample_threads(csc, features, test_nodes, batch_size, fanout, n_batches, cost, rng, 1)
}

/// Profile `n_batches` batches of the workload over `threads` workers.
///
/// Deterministic given `rng` *and invariant in `threads`*: per-batch
/// RNGs derive purely from `rng`'s first draw and the batch index,
/// counts accumulate by commutative addition into one shared pair of
/// arrays, and the scalar stage times fold in batch-index order. The profiled batches
/// draw on the same seed-node chunks and per-batch sampling streams
/// the real run derives (the paper pre-samples the actual inference
/// workload); note the serving batch geometry may still differ — see
/// the assignment comment below.
#[allow(clippy::too_many_arguments)]
pub fn presample_threads(
    csc: &Csc,
    features: &FeatureStore,
    test_nodes: &[NodeId],
    batch_size: usize,
    fanout: &Fanout,
    n_batches: usize,
    cost: &CostModel,
    rng: &mut Rng,
    threads: usize,
) -> PresampleStats {
    let wall_start = Instant::now();
    let batches = seed_batches(test_nodes, batch_size);
    let n_batches = n_batches.min(batches.len());
    let row_bytes = features.row_bytes();

    // Round-robin batch assignment. Batch `bi`'s RNG is derived from
    // the root's first draw, exactly as the engine derives the run's
    // batch RNGs from `cfg.seed` (`Rng::for_stream`): given the
    // preparation root `Rng::new(cfg.seed)`, profile batch `bi` uses
    // the very stream run batch `bi` will use — the paper's
    // "pre-sample the actual inference workload". (The *batches* still
    // differ whenever the geometry does: prepare caps the profile
    // batch size at `PRESAMPLE_BS_CAP`, and RAIN permutes its run
    // order.)
    let threads = threads.max(1).min(n_batches.max(1));
    let fork_base = rng.next_u64();
    let mut assignments: Vec<Vec<(usize, Rng)>> = (0..threads).map(|_| Vec::new()).collect();
    for bi in 0..n_batches {
        assignments[bi % threads].push((bi, Rng::fork_stream(fork_base, bi as u64)));
    }

    // one shared copy of the count arrays, whatever the thread count;
    // the serial path uses plain `Cell` adds, the parallel path atomics
    let batch_views: &[&[NodeId]] = &batches;
    type Profiled = Vec<Vec<(usize, f64, f64, usize)>>;
    let (node_visits, elem_counts, outs): (Vec<u32>, Vec<u32>, Profiled) =
        if threads == 1 {
            let visits: Vec<Cell<u32>> = vec![Cell::new(0); csc.n_nodes()];
            let counts: Vec<Cell<u32>> = vec![Cell::new(0); csc.n_edges()];
            let outs = assignments
                .into_iter()
                .map(|work| {
                    profile_chunk(
                        csc,
                        batch_views,
                        fanout,
                        row_bytes,
                        cost,
                        work,
                        visits.as_slice(),
                        counts.as_slice(),
                    )
                })
                .collect();
            (reclaim_counts(visits), reclaim_counts(counts), outs)
        } else {
            let visits: Vec<AtomicU32> =
                (0..csc.n_nodes()).map(|_| AtomicU32::new(0)).collect();
            let counts: Vec<AtomicU32> =
                (0..csc.n_edges()).map(|_| AtomicU32::new(0)).collect();
            let outs = std::thread::scope(|scope| {
                let (visits, counts) = (visits.as_slice(), counts.as_slice());
                let handles: Vec<_> = assignments
                    .into_iter()
                    .map(|work| {
                        scope.spawn(move || {
                            profile_chunk(
                                csc,
                                batch_views,
                                fanout,
                                row_bytes,
                                cost,
                                work,
                                visits,
                                counts,
                            )
                        })
                    })
                    .collect();
                handles
                    .into_iter()
                    .map(|h| h.join().expect("presample worker panicked"))
                    .collect()
            });
            (reclaim_counts(visits), reclaim_counts(counts), outs)
        };

    // fold per-batch scalars in batch order
    let mut per_batch = vec![(0.0f64, 0.0f64, 0usize); n_batches];
    for out in outs {
        for (bi, ts, tf, n) in out {
            per_batch[bi] = (ts, tf, n);
        }
    }
    let mut t_sample_ns = 0.0;
    let mut t_feature_ns = 0.0;
    let mut max_input_nodes = 0usize;
    let mut loaded_nodes = 0u64;
    for &(ts, tf, n) in &per_batch {
        t_sample_ns += ts;
        t_feature_ns += tf;
        max_input_nodes = max_input_nodes.max(n);
        loaded_nodes += n as u64;
    }

    PresampleStats {
        n_batches,
        node_visits,
        elem_counts,
        t_sample_ns,
        t_feature_ns,
        max_input_nodes,
        loaded_nodes,
        wall_ns: wall_start.elapsed().as_nanos() as f64,
    }
}

/// Profile one worker's share of the batches (its own sampler scratch,
/// shared count sinks).
#[allow(clippy::too_many_arguments)]
fn profile_chunk<S: CountSink + ?Sized>(
    csc: &Csc,
    batches: &[&[NodeId]],
    fanout: &Fanout,
    row_bytes: u64,
    cost: &CostModel,
    work: Vec<(usize, Rng)>,
    node_visits: &S,
    elem_counts: &S,
) -> Vec<(usize, f64, f64, usize)> {
    let mut sampler = NeighborSampler::with_nodes(fanout.clone(), csc.n_nodes());
    let mut profiled = Vec::with_capacity(work.len());
    for (bi, mut brng) in work {
        let (ts, tf, n_inputs) = profile_batch(
            csc,
            batches[bi],
            row_bytes,
            cost,
            &mut sampler,
            &mut brng,
            node_visits,
            elem_counts,
        );
        profiled.push((bi, ts, tf, n_inputs));
    }
    profiled
}

/// Reclaim a count array's allocation as plain `u32`s without copying:
/// the edge-count array is the profiler's dominant allocation, and a
/// collect-based unwrap would transiently double peak memory during
/// the very phase whose cost this profiler is built to minimize.
/// Only instantiated with `Cell<u32>` and `AtomicU32`.
fn reclaim_counts<T>(v: Vec<T>) -> Vec<u32> {
    debug_assert_eq!(std::mem::size_of::<T>(), std::mem::size_of::<u32>());
    debug_assert_eq!(std::mem::align_of::<T>(), std::mem::align_of::<u32>());
    let mut v = std::mem::ManuallyDrop::new(v);
    let (ptr, len, cap) = (v.as_mut_ptr(), v.len(), v.capacity());
    // SAFETY: both instantiations are std-documented to have the same
    // memory layout as `u32` (`Cell<T>` "has the same memory layout
    // ... as T"; `AtomicU32` "has the same size, alignment, and bit
    // validity as the underlying integer type"); all worker threads
    // have been joined, the allocation is uniquely owned, and
    // `ManuallyDrop` ensures it is freed exactly once — by the
    // returned Vec.
    unsafe { Vec::from_raw_parts(ptr.cast::<u32>(), len, cap) }
}

/// UVA transactions needed for one feature row.
#[inline]
pub fn row_txns(row_bytes: u64, cost: &CostModel) -> u64 {
    row_bytes.div_ceil(cost.uva_line_bytes).max(1)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::graph::datasets;

    #[test]
    fn presample_counts_and_times() {
        let ds = datasets::spec("tiny").unwrap().build();
        let fanout = Fanout::parse("3,2").unwrap();
        let cost = CostModel::default();
        let mut rng = Rng::new(1);
        let st = presample(
            &ds.csc,
            &ds.features,
            &ds.test_nodes,
            64,
            &fanout,
            4,
            &cost,
            &mut rng,
        );
        assert_eq!(st.n_batches, 4);
        assert!(st.t_sample_ns > 0.0 && st.t_feature_ns > 0.0);
        assert!(st.max_input_nodes >= 64);
        assert!(st.loaded_nodes >= 4 * 64);
        // visit counts total == loaded nodes
        let visits: u64 = st.node_visits.iter().map(|&c| c as u64).sum();
        assert_eq!(visits, st.loaded_nodes);
        // element accesses happened
        assert!(st.elem_counts.iter().any(|&c| c > 0));
        let frac = st.sample_fraction();
        assert!((0.0..=1.0).contains(&frac));
        assert!(st.avg_node_visits() > 0.0);
    }

    #[test]
    fn presample_caps_at_available_batches() {
        let ds = datasets::spec("tiny").unwrap().build();
        let fanout = Fanout::parse("2").unwrap();
        let cost = CostModel::default();
        let mut rng = Rng::new(2);
        let st = presample(
            &ds.csc,
            &ds.features,
            &ds.test_nodes[..100],
            64,
            &fanout,
            99,
            &cost,
            &mut rng,
        );
        assert_eq!(st.n_batches, 2); // 100 seeds / 64 = 2 chunks
    }

    #[test]
    fn deterministic() {
        let ds = datasets::spec("tiny").unwrap().build();
        let fanout = Fanout::parse("3,2").unwrap();
        let cost = CostModel::default();
        let a = presample(
            &ds.csc,
            &ds.features,
            &ds.test_nodes,
            32,
            &fanout,
            3,
            &cost,
            &mut Rng::new(7),
        );
        let b = presample(
            &ds.csc,
            &ds.features,
            &ds.test_nodes,
            32,
            &fanout,
            3,
            &cost,
            &mut Rng::new(7),
        );
        assert_eq!(a.node_visits, b.node_visits);
        assert_eq!(a.elem_counts, b.elem_counts);
        assert_eq!(a.loaded_nodes, b.loaded_nodes);
    }

    #[test]
    fn parallel_profile_is_thread_count_invariant() {
        let ds = datasets::spec("tiny").unwrap().build();
        let fanout = Fanout::parse("3,2").unwrap();
        let cost = CostModel::default();
        let serial = presample_threads(
            &ds.csc,
            &ds.features,
            &ds.test_nodes,
            32,
            &fanout,
            6,
            &cost,
            &mut Rng::new(7),
            1,
        );
        for threads in [2usize, 4, 9] {
            let par = presample_threads(
                &ds.csc,
                &ds.features,
                &ds.test_nodes,
                32,
                &fanout,
                6,
                &cost,
                &mut Rng::new(7),
                threads,
            );
            assert_eq!(serial.node_visits, par.node_visits, "threads={threads}");
            assert_eq!(serial.elem_counts, par.elem_counts, "threads={threads}");
            assert_eq!(serial.loaded_nodes, par.loaded_nodes, "threads={threads}");
            assert_eq!(serial.max_input_nodes, par.max_input_nodes, "threads={threads}");
            // scalar folds happen in batch order: bit-identical, not just close
            assert_eq!(serial.t_sample_ns.to_bits(), par.t_sample_ns.to_bits());
            assert_eq!(serial.t_feature_ns.to_bits(), par.t_feature_ns.to_bits());
        }
    }

    #[test]
    fn skewed_graph_has_skewed_visits() {
        let ds = datasets::spec("tiny").unwrap().build();
        // small batches + small fan-out so the 2k-node graph does not
        // saturate (every batch touching every node hides the skew)
        let fanout = Fanout::parse("2,2").unwrap();
        let cost = CostModel::default();
        let mut rng = Rng::new(3);
        let st =
            presample(&ds.csc, &ds.features, &ds.test_nodes, 32, &fanout, 8, &cost, &mut rng);
        let max = *st.node_visits.iter().max().unwrap() as f64;
        assert!(
            max >= 3.0 * st.avg_node_visits(),
            "power-law graph should have hot nodes (max={max}, avg={})",
            st.avg_node_visits()
        );
    }
}
