//! Mini-batch blocks: the bipartite per-layer structures fed to the
//! model runtime (mirrors `python/compile/model.py`'s convention).

use crate::graph::NodeId;

/// One layer's sampled bipartite block.
///
/// `idx` is a row-major `[n_dst, k]` matrix of indices into the
/// *previous* (source) layer's node array; `mask` marks valid slots.
/// Destination nodes are, by construction, the first `n_dst` entries of
/// the source array ("dst-first"), so the model's self/residual term
/// needs no extra index input.
#[derive(Debug, Clone, PartialEq)]
pub struct Block {
    pub n_dst: usize,
    pub k: usize,
    pub idx: Vec<i32>,
    pub mask: Vec<f32>,
}

impl Block {
    pub fn new(n_dst: usize, k: usize) -> Self {
        Block {
            n_dst,
            k,
            idx: vec![0; n_dst * k],
            mask: vec![0.0; n_dst * k],
        }
    }

    #[inline]
    pub fn set(&mut self, dst: usize, slot: usize, src_local: u32) {
        let at = dst * self.k + slot;
        self.idx[at] = src_local as i32;
        self.mask[at] = 1.0;
    }

    /// Valid (unmasked) entries.
    pub fn n_valid(&self) -> usize {
        self.mask.iter().filter(|&&m| m != 0.0).count()
    }

    /// Structural check: masked-in indices in range, consistent lengths.
    pub fn validate(&self, n_src: usize) -> Result<(), String> {
        if self.idx.len() != self.n_dst * self.k || self.mask.len() != self.idx.len() {
            return Err(format!(
                "block arrays len {} / {} != n_dst*k {}",
                self.idx.len(),
                self.mask.len(),
                self.n_dst * self.k
            ));
        }
        for (i, (&ix, &m)) in self.idx.iter().zip(&self.mask).enumerate() {
            if m != 0.0 && (ix < 0 || ix as usize >= n_src) {
                return Err(format!("valid idx {ix} out of range {n_src} at {i}"));
            }
        }
        Ok(())
    }
}

/// A sampled mini-batch: per-layer node arrays and blocks, ordered
/// **input-most first** (`nodes[0]` is the widest array whose features
/// must be loaded; `nodes.last()` are the seeds).
#[derive(Debug, Clone)]
pub struct MiniBatch {
    pub nodes: Vec<Vec<NodeId>>,
    pub layers: Vec<Block>,
}

impl MiniBatch {
    /// The nodes whose features the feature-loading stage must produce.
    pub fn input_nodes(&self) -> &[NodeId] {
        &self.nodes[0]
    }

    /// The seed nodes this batch answers for.
    pub fn seeds(&self) -> &[NodeId] {
        self.nodes.last().unwrap()
    }

    pub fn n_layers(&self) -> usize {
        self.layers.len()
    }

    /// Full structural validation (dst-first property included).
    pub fn validate(&self) -> Result<(), String> {
        if self.nodes.len() != self.layers.len() + 1 {
            return Err("node arrays must be layers+1".into());
        }
        for l in 0..self.layers.len() {
            let src = &self.nodes[l];
            let dst = &self.nodes[l + 1];
            let blk = &self.layers[l];
            if blk.n_dst != dst.len() {
                return Err(format!("layer {l}: n_dst {} != {}", blk.n_dst, dst.len()));
            }
            blk.validate(src.len())?;
            // dst-first: dst ids are a prefix of src ids
            if src.len() < dst.len() || &src[..dst.len()] != dst.as_slice() {
                return Err(format!("layer {l}: dst nodes are not a prefix of src"));
            }
        }
        Ok(())
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn block_set_and_valid_count() {
        let mut b = Block::new(2, 3);
        b.set(0, 0, 5);
        b.set(1, 2, 1);
        assert_eq!(b.n_valid(), 2);
        assert_eq!(b.idx[0], 5);
        assert_eq!(b.mask[5], 1.0);
        b.validate(6).unwrap();
        assert!(b.validate(3).is_err()); // 5 out of range
    }

    #[test]
    fn minibatch_validate_dst_first() {
        let src = vec![7, 8, 9, 1];
        let dst = vec![7, 8];
        let mut blk = Block::new(2, 2);
        blk.set(0, 0, 2);
        let mb = MiniBatch { nodes: vec![src.clone(), dst.clone()], layers: vec![blk.clone()] };
        mb.validate().unwrap();
        assert_eq!(mb.input_nodes(), &[7, 8, 9, 1]);
        assert_eq!(mb.seeds(), &[7, 8]);

        // violate prefix property
        let mb_bad = MiniBatch { nodes: vec![vec![9, 8, 7, 1], dst], layers: vec![blk] };
        assert!(mb_bad.validate().is_err());
    }

    #[test]
    fn minibatch_layer_count_mismatch() {
        let mb = MiniBatch { nodes: vec![vec![1]], layers: vec![Block::new(1, 1)] };
        assert!(mb.validate().is_err());
    }
}
