//! Fan-out specifications ("15,10,5") — §V's parameter grids.
//!
//! Order convention matches DGL and the paper's "left-to-right"
//! strings: `fanouts[0]` is the *input-most* layer's fan-out and
//! `fanouts.last()` is the fan-out applied to the seed nodes'
//! immediate neighbors.

use std::fmt;

use anyhow::{bail, Result};

/// A per-layer fan-out specification.
#[derive(Debug, Clone, PartialEq, Eq, Hash)]
pub struct Fanout(Vec<usize>);

impl Fanout {
    pub fn new(fanouts: Vec<usize>) -> Result<Self> {
        if fanouts.is_empty() {
            bail!("fan-out must have at least one layer");
        }
        if fanouts.iter().any(|&f| f == 0 || f > 1024) {
            bail!("fan-outs must be in 1..=1024, got {fanouts:?}");
        }
        Ok(Fanout(fanouts))
    }

    /// Parse "15,10,5".
    pub fn parse(s: &str) -> Result<Self> {
        let v: Result<Vec<usize>, _> =
            s.split(',').map(|t| t.trim().parse::<usize>()).collect();
        match v {
            Ok(v) => Fanout::new(v),
            Err(e) => bail!("bad fan-out {s:?}: {e}"),
        }
    }

    pub fn layers(&self) -> usize {
        self.0.len()
    }

    /// Input-most first (model block order).
    pub fn per_layer(&self) -> &[usize] {
        &self.0
    }

    /// Fan-out for sampling hop `h`, where hop 0 expands the seeds.
    /// (Sampling walks seed-side first, i.e. the reverse of `per_layer`.)
    pub fn for_hop(&self, h: usize) -> usize {
        self.0[self.0.len() - 1 - h]
    }

    /// Worst-case padded node-array sizes per layer, input-most first —
    /// must agree with `python/compile/aot.py::worst_case_dims`.
    pub fn worst_case_dims(&self, batch_size: usize) -> Vec<usize> {
        let mut dims = vec![batch_size];
        for &k in self.0.iter().rev() {
            dims.push(dims.last().unwrap() * (k + 1));
        }
        dims.reverse();
        dims
    }

    /// The paper's three standard grids.
    pub fn paper_grids() -> Vec<Fanout> {
        vec![
            Fanout::new(vec![2, 2, 2]).unwrap(),
            Fanout::new(vec![8, 4, 2]).unwrap(),
            Fanout::new(vec![15, 10, 5]).unwrap(),
        ]
    }
}

impl fmt::Display for Fanout {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        let strs: Vec<String> = self.0.iter().map(|x| x.to_string()).collect();
        write!(f, "{}", strs.join(","))
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn parse_and_display() {
        let f = Fanout::parse("15, 10,5").unwrap();
        assert_eq!(f.per_layer(), &[15, 10, 5]);
        assert_eq!(f.to_string(), "15,10,5");
        assert_eq!(f.layers(), 3);
    }

    #[test]
    fn hop_order_is_seed_side_first() {
        let f = Fanout::parse("15,10,5").unwrap();
        assert_eq!(f.for_hop(0), 5); // seeds sample 5
        assert_eq!(f.for_hop(1), 10);
        assert_eq!(f.for_hop(2), 15);
    }

    #[test]
    fn worst_case_matches_aot() {
        // python: worst_case_dims(8, [2,2,2]) == [216, 72, 24, 8]
        let f = Fanout::parse("2,2,2").unwrap();
        assert_eq!(f.worst_case_dims(8), vec![216, 72, 24, 8]);
        // python: worst_case_dims(256, [8,4,2]) == [34560, 3840, 768, 256]
        let f = Fanout::parse("8,4,2").unwrap();
        assert_eq!(f.worst_case_dims(256), vec![34560, 3840, 768, 256]);
    }

    #[test]
    fn rejects_bad() {
        assert!(Fanout::parse("").is_err());
        assert!(Fanout::parse("1,0,1").is_err());
        assert!(Fanout::parse("a,b").is_err());
        assert!(Fanout::parse("2000").is_err());
    }
}
