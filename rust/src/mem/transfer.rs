//! Transfer cost model: translates byte/transaction counts into modeled
//! time for the virtual clock.
//!
//! Calibration targets (NVIDIA RTX 4090, the paper's platform):
//! - device (GDDR6X) bandwidth ≈ 1008 GB/s;
//! - PCIe 4.0 x16 bulk H2D ≈ 21 GB/s effective;
//! - UVA *random* access (zero-copy reads issued by sampling/gather
//!   kernels) lands far lower — ~6 GB/s effective — and each touched
//!   cache line costs a full 128 B transaction regardless of payload,
//!   plus amortized issue overhead.
//!
//! These four knobs are deliberately coarse: the paper's comparisons are
//! *ratios* between systems under the same model, so the shape of every
//! table/figure is insensitive to ±2× on any knob (see EXPERIMENTS.md
//! §Calibration for the sensitivity check).

/// Cost model knobs. All bandwidths in GB/s (1e9 bytes).
#[derive(Debug, Clone)]
pub struct CostModel {
    /// Bulk host→device copies (cache fills, batched feature uploads).
    pub h2d_gbps: f64,
    /// Random UVA reads over PCIe (cache misses).
    pub uva_rand_gbps: f64,
    /// Device-memory reads (cache hits).
    pub device_gbps: f64,
    /// Amortized per-transaction overhead for random UVA reads, ns.
    pub uva_txn_ns: f64,
    /// Minimum granule of a UVA transaction, bytes (GPU cache line).
    pub uva_line_bytes: u64,
    /// Fixed per-stage launch overhead (kernel launch + driver), ns.
    pub launch_ns: f64,
    /// Per-copy issue cost of one coalesced staged H2D copy (DMA
    /// descriptor setup + doorbell), ns. Much cheaper than a kernel
    /// launch — descriptors are queued on an already-running copy
    /// engine — but not free, which is exactly why the staging path
    /// run-length-merges the miss set before issuing (fewer, larger
    /// copies). ~0.4 µs matches measured cudaMemcpyAsync small-copy
    /// overhead on PCIe 4.0.
    pub h2d_copy_ns: f64,
    /// Effective GPU compute throughput for the modeled compute stage.
    /// RTX 4090 peaks at ~82 f32 TFLOPS, but 3-layer GNN inference on
    /// a few-thousand-row mini-batch is launch- and bandwidth-bound:
    /// measured effective throughput for DGL-style GraphSAGE layers is
    /// O(1) TFLOPS. 0.5 effective TFLOPS keeps the modeled compute
    /// share of total time inside the paper's observed 8–44% (Fig. 1).
    pub gpu_tflops: f64,
}

impl Default for CostModel {
    fn default() -> Self {
        CostModel {
            h2d_gbps: 21.0,
            uva_rand_gbps: 6.0,
            device_gbps: 1008.0,
            uva_txn_ns: 20.0,
            uva_line_bytes: 128,
            launch_ns: 10_000.0,
            h2d_copy_ns: 400.0,
            gpu_tflops: 0.5,
        }
    }
}

impl CostModel {
    /// Modeled ns for a bulk host→device copy of `bytes`.
    #[inline]
    pub fn h2d_ns(&self, bytes: u64) -> f64 {
        bytes as f64 / self.h2d_gbps
    }

    /// Modeled ns for random UVA reads: `txns` transactions moving
    /// `bytes` payload (each transaction pays line granularity + issue
    /// overhead).
    #[inline]
    pub fn uva_ns(&self, bytes: u64, txns: u64) -> f64 {
        let moved = bytes.max(txns * self.uva_line_bytes);
        moved as f64 / self.uva_rand_gbps + txns as f64 * self.uva_txn_ns
    }

    /// Modeled ns for a batched staged H2D transfer: `copies` coalesced
    /// copies moving `bytes` total at bulk PCIe bandwidth, each copy
    /// paying the DMA-descriptor issue cost. This is what replaces N
    /// per-row [`CostModel::uva_ns`] miss charges when the staging path
    /// is on — the win is bulk bandwidth (21 vs 6 GB/s) plus issue
    /// costs proportional to *coalesced runs*, not rows.
    #[inline]
    pub fn h2d_batched_ns(&self, bytes: u64, copies: u64) -> f64 {
        self.h2d_ns(bytes) + copies as f64 * self.h2d_copy_ns
    }

    /// Modeled ns for device-memory reads of `bytes` (cache hits).
    #[inline]
    pub fn device_ns(&self, bytes: u64) -> f64 {
        bytes as f64 / self.device_gbps
    }

    /// Modeled ns for `flops` floating-point operations on the GPU.
    #[inline]
    pub fn compute_ns(&self, flops: f64) -> f64 {
        flops / (self.gpu_tflops * 1e3) // TFLOPS = flops/ns * 1e3
    }
    // NB: bandwidths are GB/s = bytes/ns, so bytes / gbps is ns directly.
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn unit_sanity() {
        let m = CostModel::default();
        // 21 GB over PCIe at 21 GB/s = 1 s = 1e9 ns
        let ns = m.h2d_ns(21_000_000_000);
        assert!((ns - 1e9).abs() / 1e9 < 1e-9);
        // device reads ~48x faster than bulk PCIe
        assert!(m.h2d_ns(1 << 20) / m.device_ns(1 << 20) > 40.0);
    }

    #[test]
    fn uva_pays_line_granularity() {
        let m = CostModel::default();
        // 4-byte payload still moves a 128B line
        let small = m.uva_ns(4, 1);
        let line = m.uva_ns(128, 1);
        assert_eq!(small, line);
        // many txns scale roughly linearly
        let many = m.uva_ns(128 * 1000, 1000);
        assert!(many > 900.0 * (line - 0.0) / 1.0 * 0.9);
    }

    #[test]
    fn staged_beats_per_row_even_uncoalesced() {
        let m = CostModel::default();
        // 500 scattered 2408-byte rows (reddit-sim shape), zero merges:
        // the worst case for staging still beats per-row UVA
        let rows = 500u64;
        let row_bytes = 2408u64;
        let txns = row_bytes.div_ceil(m.uva_line_bytes);
        let per_row: f64 = rows as f64 * m.uva_ns(row_bytes, txns);
        let staged = m.h2d_batched_ns(rows * row_bytes, rows);
        assert!(per_row / staged > 1.3, "per_row {per_row} staged {staged}");
    }

    #[test]
    fn hit_vs_miss_gap_is_large() {
        let m = CostModel::default();
        // one 400-byte feature row: miss ≫ hit
        let miss = m.uva_ns(400, 4);
        let hit = m.device_ns(400);
        assert!(miss / hit > 50.0, "miss {miss} hit {hit}");
    }
}
