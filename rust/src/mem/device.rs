//! Simulated device (GPU) memory arena: capacity accounting with the
//! paper's 1 GB safety reserve (§IV.A: "reserving 1GB of memory is
//! completely sufficient"), scaled by the dataset's scale factor.
//!
//! Allocation failures surface as [`OomError`] — this is how the
//! Table V "RAIN: CUDA out of memory" row reproduces.
//!
//! [`DeviceGroup`] is the multi-device (sharded) arena set. Since the
//! elastic-budget work it is **epoch-aware**: claims and releases go
//! through interior mutability (`&self`), so the background refresh
//! loop can account a hot-swap install — claim the incoming snapshot's
//! bytes *before* releasing the outgoing one (both are resident during
//! a swap) — while the engine keeps serving through the same group. A
//! per-device high-water mark ([`DeviceMemory::peak_used`]) records the
//! transient double-residency so benches can assert it stays bounded.

use std::sync::Mutex;

use anyhow::{bail, Context, Result};
use thiserror::Error;

use crate::util::{format_bytes, lock_unpoisoned, parse_bytes};

/// The paper's testbed capacity (RTX 4090).
pub const RTX4090_BYTES: u64 = 24 * (1 << 30);

/// The paper's pre-sampling safety reserve (PaGraph convention).
pub const PAPER_RESERVE_BYTES: u64 = 1 << 30;

/// Per-input-node device bytes of the workload's own peak claim:
/// features + first-layer activations + block index/mask overhead.
/// One formula shared by the startup [`auto_budget`] and the refresh
/// loop's per-epoch re-evaluation ([`AutoBudgetPolicy`]) so the two
/// can never drift apart.
///
/// [`auto_budget`]: crate::baselines::auto_budget
/// [`AutoBudgetPolicy`]: crate::cache::refresh::AutoBudgetPolicy
pub fn per_node_claim_bytes(row_bytes: u64, hidden: usize) -> u64 {
    row_bytes + (hidden * 4) as u64 + 64
}

/// §IV.A workload peak-claim model: bytes the workload itself pins on
/// the device for its largest observed batch, with 2x slack for the
/// allocator's transient copies. The batch footprint does not shrink
/// with the dataset stand-in, but the simulated device does
/// ([`DeviceMemory::rtx4090_scaled`]); scaling the claim by the same
/// factor keeps the claim/device *ratio* at the paper's testbed value
/// (≈5% of a 24 GB card). See DESIGN.md §Substitutions.
pub fn workload_claim_bytes(peak_inputs: u64, per_node_bytes: u64, scale: f64) -> u64 {
    let workload = 2.0 * (peak_inputs * per_node_bytes) as f64;
    (workload * scale.min(1.0)) as u64
}

/// One device of a heterogeneous (mixed-GPU) node: its memory capacity
/// and its host→device link bandwidth. Parsed from the `device-tiers=`
/// knob ([`parse_device_tiers`]) and threaded through budget planning
/// so big/fast devices earn proportionally more cache budget.
#[derive(Debug, Clone, Copy, PartialEq)]
pub struct DeviceTier {
    /// Device memory capacity, bytes.
    pub capacity: u64,
    /// Effective bulk H2D bandwidth of this device's link, GB/s.
    pub h2d_gbps: f64,
}

impl DeviceTier {
    /// Build this tier's memory arena. The reserve scales with
    /// capacity (1/24th, the paper's 1 GB on a 24 GB card) but never
    /// exceeds the paper's absolute reserve — mirroring how explicit
    /// `device=` capacities are reserved.
    pub fn device(&self) -> DeviceMemory {
        DeviceMemory::new(self.capacity, (self.capacity / 24).min(PAPER_RESERVE_BYTES))
    }

    /// Static cache headroom of this tier (capacity − reserve).
    pub fn headroom(&self) -> u64 {
        self.device().headroom()
    }
}

/// Parse a `device-tiers=` spec: comma-separated `CAP[:GBPS]` entries,
/// one per shard — e.g. `24GB:26,8GB:21,8GB:21` for one big/fast card
/// and two small ones. Capacity accepts the usual byte suffixes;
/// bandwidth defaults to the cost model's bulk H2D rate (21 GB/s).
pub fn parse_device_tiers(spec: &str) -> Result<Vec<DeviceTier>> {
    let mut tiers = Vec::new();
    for entry in spec.split(',').map(str::trim).filter(|e| !e.is_empty()) {
        let (cap_str, gbps) = match entry.split_once(':') {
            Some((c, g)) => {
                let g: f64 = g
                    .parse()
                    .with_context(|| format!("device tier {entry:?}: bad :GBPS bandwidth"))?;
                if !g.is_finite() || g <= 0.0 {
                    bail!("device tier {entry:?}: bandwidth must be positive");
                }
                (c, g)
            }
            None => (entry, 21.0),
        };
        let capacity = parse_bytes(cap_str)
            .with_context(|| format!("device tier {entry:?}: bad capacity"))?;
        if capacity == 0 {
            bail!("device tier {entry:?}: capacity must be nonzero");
        }
        tiers.push(DeviceTier { capacity, h2d_gbps: gbps });
    }
    if tiers.is_empty() {
        bail!("device-tiers spec {spec:?} contains no entries");
    }
    Ok(tiers)
}

/// Simulated GPU out-of-memory (mirrors `RuntimeError: CUDA out of
/// memory` in the paper's RAIN experiment).
#[derive(Debug, Error, Clone, PartialEq)]
#[error(
    "simulated CUDA out of memory: tried to allocate {} ({} requested, {} in use, {} capacity)",
    format_bytes(*.requested),
    format_bytes(*.requested),
    format_bytes(*.in_use),
    format_bytes(*.capacity)
)]
pub struct OomError {
    pub requested: u64,
    pub in_use: u64,
    pub capacity: u64,
}

/// Capacity-accounting arena for simulated device memory.
#[derive(Debug, Clone)]
pub struct DeviceMemory {
    capacity: u64,
    reserve: u64,
    used: u64,
    peak_used: u64,
}

impl DeviceMemory {
    /// Arena with explicit capacity and safety reserve.
    pub fn new(capacity: u64, reserve: u64) -> Self {
        DeviceMemory { capacity, reserve: reserve.min(capacity), used: 0, peak_used: 0 }
    }

    /// The paper's testbed scaled to a dataset's scale factor: a 1/10
    /// scale dataset sees a 2.4 GB device with a 100 MB reserve, so the
    /// paper's GB-denominated sweeps translate directly.
    pub fn rtx4090_scaled(scale: f64) -> Self {
        let capacity = (RTX4090_BYTES as f64 * scale) as u64;
        let reserve = (PAPER_RESERVE_BYTES as f64 * scale) as u64;
        DeviceMemory::new(capacity, reserve)
    }

    pub fn capacity(&self) -> u64 {
        self.capacity
    }

    pub fn used(&self) -> u64 {
        self.used
    }

    /// High-water mark of `used` over the arena's lifetime — what the
    /// claim-before-release swap accounting transiently peaks at.
    pub fn peak_used(&self) -> u64 {
        self.peak_used
    }

    /// Static cache headroom: capacity − reserve, independent of the
    /// current claims. This is the budget basis the workload-aware
    /// auto budget subtracts the peak claim from — at startup (nothing
    /// claimed) it equals [`DeviceMemory::available_for_cache`].
    pub fn headroom(&self) -> u64 {
        self.capacity.saturating_sub(self.reserve)
    }

    /// Bytes available for caches: capacity − reserve − used. This is
    /// the "C" of Eq. (1) once the workload's own peak usage has been
    /// claimed via [`DeviceMemory::alloc`].
    pub fn available_for_cache(&self) -> u64 {
        self.headroom().saturating_sub(self.used)
    }

    /// Claim `bytes` (workload tensors, caches). Fails with [`OomError`]
    /// if it would exceed capacity (the reserve is *not* allocatable —
    /// that is its purpose).
    pub fn alloc(&mut self, bytes: u64) -> Result<(), OomError> {
        if self.used + bytes > self.headroom() {
            return Err(OomError {
                requested: bytes,
                in_use: self.used,
                capacity: self.capacity,
            });
        }
        self.used += bytes;
        self.peak_used = self.peak_used.max(self.used);
        Ok(())
    }

    /// Hard allocation that may also consume the reserve (baselines
    /// that reserve no headroom, e.g. RAIN — and the refresh loop's
    /// transient double-residency during a snapshot swap, which is
    /// exactly the kind of short-lived allocation the reserve exists
    /// to absorb).
    pub fn alloc_unreserved(&mut self, bytes: u64) -> Result<(), OomError> {
        if self.used + bytes > self.capacity {
            return Err(OomError {
                requested: bytes,
                in_use: self.used,
                capacity: self.capacity,
            });
        }
        self.used += bytes;
        self.peak_used = self.peak_used.max(self.used);
        Ok(())
    }

    /// Release previously claimed bytes.
    pub fn free(&mut self, bytes: u64) {
        self.used = self.used.saturating_sub(bytes);
    }
}

/// A node's set of simulated devices: one [`DeviceMemory`] arena per
/// cache shard. Every shard's snapshot claims bytes against the device
/// that actually holds it — a shard cannot borrow headroom from a
/// sibling device, which is exactly the constraint that makes the
/// per-shard budget split ([`crate::cache::split_budget`]) load-bearing
/// rather than cosmetic.
///
/// The group is shared between the serving engine and the background
/// refresh loop (both hold an `Arc`), so every accessor takes `&self`
/// and each device sits behind its own lock. An epoch swap accounts as
/// **claim-before-release**: the incoming snapshot's bytes are claimed
/// while the outgoing snapshot is still resident (readers may serve
/// one more batch from it), then the outgoing bytes are released — so
/// a shard shrinking its budget frees device bytes a later (larger)
/// epoch of the same device can claim, and the transient peak is
/// visible via [`DeviceGroup::peak_used`].
///
/// Ledger locks recover from poison
/// ([`lock_unpoisoned`](crate::util::lock_unpoisoned)): each guards a
/// bare counter arena no panicking holder can leave half-updated, and
/// the accounting must stay readable after an injected refresh-worker
/// panic (DESIGN.md §Fault tolerance).
#[derive(Debug)]
pub struct DeviceGroup {
    devices: Vec<Mutex<DeviceMemory>>,
    /// Per-device bulk H2D bandwidth (GB/s) for heterogeneous tiers;
    /// `None` = uniform legacy group (every device at the cost model's
    /// default rate).
    bandwidths: Option<Vec<f64>>,
}

impl DeviceGroup {
    /// `n` identical devices cloned from a freshly built prototype
    /// (capacity and reserve copied, nothing allocated yet).
    pub fn replicate(proto: &DeviceMemory, n: usize) -> Self {
        assert_eq!(proto.used(), 0, "replicate from an unused prototype");
        DeviceGroup {
            devices: (0..n.max(1)).map(|_| Mutex::new(proto.clone())).collect(),
            bandwidths: None,
        }
    }

    /// The single-device group (the PR 2 shape).
    pub fn single(device: DeviceMemory) -> Self {
        DeviceGroup { devices: vec![Mutex::new(device)], bandwidths: None }
    }

    /// A heterogeneous group: one device per tier, each with its own
    /// capacity, reserve, and link bandwidth.
    pub fn tiered(tiers: &[DeviceTier]) -> Self {
        assert!(!tiers.is_empty(), "tiered group needs at least one tier");
        DeviceGroup {
            devices: tiers.iter().map(|t| Mutex::new(t.device())).collect(),
            bandwidths: Some(tiers.iter().map(|t| t.h2d_gbps).collect()),
        }
    }

    /// Whether this group carries per-device bandwidth tiers.
    pub fn is_tiered(&self) -> bool {
        self.bandwidths.is_some()
    }

    /// Device `i`'s H2D bandwidth relative to the group's fastest link
    /// (1.0 for every device in a uniform group). Used to bias budget
    /// shares toward fast devices: a shard on a slow link re-fills its
    /// cache slower, so parking more budget there costs more install
    /// time per byte.
    pub fn bandwidth_share(&self, i: usize) -> f64 {
        match &self.bandwidths {
            None => 1.0,
            Some(b) => {
                let max = b.iter().cloned().fold(f64::MIN, f64::max);
                if max > 0.0 {
                    b[i] / max
                } else {
                    1.0
                }
            }
        }
    }

    /// Every device's static cache headroom, in device order — the
    /// per-device caps a budget split must respect.
    pub fn headrooms(&self) -> Vec<u64> {
        (0..self.devices.len()).map(|i| self.headroom(i)).collect()
    }

    /// Per-device budget weights for a tiered split: headroom ×
    /// bandwidth share, so budget flows toward devices that are both
    /// big (can hold it) and fast (can re-fill it cheaply). Uniform
    /// groups weight every device equally.
    pub fn tier_weights(&self) -> Vec<u64> {
        (0..self.devices.len())
            .map(|i| (self.headroom(i) as f64 * self.bandwidth_share(i)) as u64)
            .collect()
    }

    pub fn n_devices(&self) -> usize {
        self.devices.len()
    }

    /// A point-in-time copy of device `i`'s arena (reporting, tests).
    pub fn device(&self, i: usize) -> DeviceMemory {
        lock_unpoisoned(&self.devices[i]).clone()
    }

    /// Bytes currently claimed on device `i`.
    pub fn used(&self, i: usize) -> u64 {
        lock_unpoisoned(&self.devices[i]).used()
    }

    /// High-water mark of device `i`'s claims (includes the transient
    /// double-residency of claim-before-release snapshot swaps).
    pub fn peak_used(&self, i: usize) -> u64 {
        lock_unpoisoned(&self.devices[i]).peak_used()
    }

    /// Device `i`'s static cache headroom (capacity − reserve) — the
    /// per-device cap no shard's budget share may exceed.
    pub fn headroom(&self, i: usize) -> u64 {
        lock_unpoisoned(&self.devices[i]).headroom()
    }

    /// The smallest per-device headroom across the group — with
    /// identical replicated devices this is *the* per-shard budget cap.
    pub fn min_headroom(&self) -> u64 {
        (0..self.devices.len()).map(|i| self.headroom(i)).min().unwrap_or(0)
    }

    /// Bytes claimed across all devices (conservation checks).
    pub fn total_used(&self) -> u64 {
        (0..self.devices.len()).map(|i| self.used(i)).sum()
    }

    /// Claim `bytes` on device `i` only; fails with that device's
    /// [`OomError`] — sibling capacity is never consulted.
    pub fn alloc(&self, i: usize, bytes: u64) -> Result<(), OomError> {
        lock_unpoisoned(&self.devices[i]).alloc(bytes)
    }

    /// Reserve-consuming claim on device `i` (RAIN's staged tensor,
    /// and the refresh loop's transient swap double-residency).
    pub fn alloc_unreserved(&self, i: usize, bytes: u64) -> Result<(), OomError> {
        lock_unpoisoned(&self.devices[i]).alloc_unreserved(bytes)
    }

    /// Release previously claimed bytes on device `i`.
    pub fn free(&self, i: usize, bytes: u64) {
        lock_unpoisoned(&self.devices[i]).free(bytes)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn alloc_respects_reserve() {
        let mut m = DeviceMemory::new(100, 10);
        assert_eq!(m.available_for_cache(), 90);
        assert_eq!(m.headroom(), 90);
        m.alloc(80).unwrap();
        assert_eq!(m.available_for_cache(), 10);
        assert_eq!(m.headroom(), 90, "headroom is static");
        let err = m.alloc(20).unwrap_err();
        assert_eq!(err.in_use, 80);
        // unreserved path may take the headroom
        m.alloc_unreserved(20).unwrap();
        assert_eq!(m.used(), 100);
        assert!(m.alloc_unreserved(1).is_err());
    }

    #[test]
    fn free_returns_capacity_and_peak_sticks() {
        let mut m = DeviceMemory::new(100, 0);
        m.alloc(60).unwrap();
        m.free(50);
        assert_eq!(m.used(), 10);
        assert_eq!(m.peak_used(), 60, "peak records the high-water mark");
        m.alloc(20).unwrap();
        assert_eq!(m.peak_used(), 60, "peak only moves on a new high");
        m.free(1000); // saturates, never underflows
        assert_eq!(m.used(), 0);
        assert_eq!(m.peak_used(), 60);
    }

    #[test]
    fn scaled_testbed() {
        let m = DeviceMemory::rtx4090_scaled(0.1);
        assert_eq!(m.capacity(), (RTX4090_BYTES as f64 * 0.1) as u64);
        assert!(m.available_for_cache() > 2 * (1 << 30));
    }

    #[test]
    fn oom_message_mentions_cuda() {
        let mut m = DeviceMemory::new(10, 0);
        let err = m.alloc(100).unwrap_err();
        assert!(err.to_string().contains("CUDA out of memory"));
    }

    #[test]
    fn claim_model_is_shared_and_scaled() {
        let per_node = per_node_claim_bytes(256, 128);
        assert_eq!(per_node, 256 + 512 + 64);
        // 2x slack at full scale
        assert_eq!(workload_claim_bytes(10, per_node, 1.0), 2 * 10 * per_node);
        // the scale factor shrinks the claim with the simulated device
        assert_eq!(workload_claim_bytes(10, per_node, 0.5), 10 * per_node);
        // scale never inflates it past the testbed ratio
        assert_eq!(workload_claim_bytes(10, per_node, 3.0), 2 * 10 * per_node);
    }

    #[test]
    fn group_accounts_each_device_separately() {
        let proto = DeviceMemory::new(100, 10);
        let g = DeviceGroup::replicate(&proto, 3);
        assert_eq!(g.n_devices(), 3);
        g.alloc(0, 90).unwrap();
        // device 0 is full for cache purposes; devices 1-2 untouched
        assert!(g.alloc(0, 1).is_err(), "no borrowing from siblings");
        g.alloc(1, 50).unwrap();
        assert_eq!(g.device(0).used(), 90);
        assert_eq!(g.device(1).used(), 50);
        assert_eq!(g.device(2).used(), 0);
        assert_eq!(g.total_used(), 140);
        g.free(1, 50);
        assert_eq!(g.used(1), 0);
        assert_eq!(g.peak_used(1), 50, "peak survives the release");
        // unreserved path still per-device
        g.alloc_unreserved(0, 10).unwrap();
        assert!(g.alloc_unreserved(0, 1).is_err());
        assert_eq!(g.min_headroom(), 90);
    }

    #[test]
    fn group_release_and_reclaim_across_epochs() {
        // the elastic-budget swap pattern: claim the incoming epoch's
        // bytes before releasing the outgoing one, on the same device
        let g = DeviceGroup::single(DeviceMemory::new(100, 20));
        g.alloc(0, 50).unwrap(); // epoch 0 snapshot
        // claim-before-release dips into the reserve transiently
        g.alloc_unreserved(0, 40).unwrap(); // epoch 1 snapshot
        assert_eq!(g.used(0), 90);
        g.free(0, 50); // epoch 0 released once swapped out
        assert_eq!(g.used(0), 40);
        assert_eq!(g.peak_used(0), 90, "transient double-residency recorded");
        // the released bytes are reclaimable by a larger epoch 2
        g.alloc(0, 40).unwrap();
        assert_eq!(g.used(0), 80);
    }

    #[test]
    fn parses_tier_specs() {
        let tiers = parse_device_tiers("24GB:26,8GB,8GB:21").unwrap();
        assert_eq!(tiers.len(), 3);
        assert_eq!(tiers[0].capacity, 24 * (1 << 30));
        assert_eq!(tiers[0].h2d_gbps, 26.0);
        assert_eq!(tiers[1].h2d_gbps, 21.0, "bandwidth defaults to bulk H2D");
        // reserve scales with capacity but caps at the paper's 1 GB
        assert_eq!(tiers[0].device().capacity(), 24 * (1 << 30));
        assert_eq!(tiers[0].headroom(), 23 * (1 << 30));
        let small = parse_device_tiers("240MB").unwrap();
        assert_eq!(small[0].headroom(), 240 * (1 << 20) - 10 * (1 << 20));
        for bad in ["", " , ", "0:21", "8GB:-1", "8GB:nan", "8GB:0", "xyz"] {
            assert!(parse_device_tiers(bad).is_err(), "{bad:?} must not parse");
        }
    }

    #[test]
    fn tiered_group_weights_by_size_and_speed() {
        let tiers = parse_device_tiers("1GB:20,1GB:10,2GB:20").unwrap();
        let g = DeviceGroup::tiered(&tiers);
        assert!(g.is_tiered());
        assert_eq!(g.n_devices(), 3);
        assert_eq!(g.bandwidth_share(0), 1.0);
        assert_eq!(g.bandwidth_share(1), 0.5);
        let w = g.tier_weights();
        assert_eq!(w[0], 2 * w[1], "half the bandwidth → half the weight");
        assert!(w[2] > w[0], "bigger device at equal speed outweighs");
        assert_eq!(g.headrooms(), vec![g.headroom(0), g.headroom(1), g.headroom(2)]);
        // uniform groups report neutral tiers
        let u = DeviceGroup::replicate(&DeviceMemory::new(100, 10), 2);
        assert!(!u.is_tiered());
        assert_eq!(u.bandwidth_share(1), 1.0);
        assert_eq!(u.tier_weights(), vec![90, 90]);
    }

    #[test]
    fn group_single_and_degenerate_replicate() {
        let g = DeviceGroup::single(DeviceMemory::new(50, 5));
        assert_eq!(g.n_devices(), 1);
        assert_eq!(g.device(0).available_for_cache(), 45);
        let g = DeviceGroup::replicate(&DeviceMemory::new(50, 5), 0);
        assert_eq!(g.n_devices(), 1, "a group has at least one device");
    }
}
