//! Simulated device (GPU) memory arena: capacity accounting with the
//! paper's 1 GB safety reserve (§IV.A: "reserving 1GB of memory is
//! completely sufficient"), scaled by the dataset's scale factor.
//!
//! Allocation failures surface as [`OomError`] — this is how the
//! Table V "RAIN: CUDA out of memory" row reproduces.

use thiserror::Error;

use crate::util::format_bytes;

/// The paper's testbed capacity (RTX 4090).
pub const RTX4090_BYTES: u64 = 24 * (1 << 30);

/// The paper's pre-sampling safety reserve (PaGraph convention).
pub const PAPER_RESERVE_BYTES: u64 = 1 << 30;

/// Simulated GPU out-of-memory (mirrors `RuntimeError: CUDA out of
/// memory` in the paper's RAIN experiment).
#[derive(Debug, Error, Clone, PartialEq)]
#[error(
    "simulated CUDA out of memory: tried to allocate {} ({} requested, {} in use, {} capacity)",
    format_bytes(*.requested),
    format_bytes(*.requested),
    format_bytes(*.in_use),
    format_bytes(*.capacity)
)]
pub struct OomError {
    pub requested: u64,
    pub in_use: u64,
    pub capacity: u64,
}

/// Capacity-accounting arena for simulated device memory.
#[derive(Debug, Clone)]
pub struct DeviceMemory {
    capacity: u64,
    reserve: u64,
    used: u64,
}

impl DeviceMemory {
    /// Arena with explicit capacity and safety reserve.
    pub fn new(capacity: u64, reserve: u64) -> Self {
        DeviceMemory { capacity, reserve: reserve.min(capacity), used: 0 }
    }

    /// The paper's testbed scaled to a dataset's scale factor: a 1/10
    /// scale dataset sees a 2.4 GB device with a 100 MB reserve, so the
    /// paper's GB-denominated sweeps translate directly.
    pub fn rtx4090_scaled(scale: f64) -> Self {
        let capacity = (RTX4090_BYTES as f64 * scale) as u64;
        let reserve = (PAPER_RESERVE_BYTES as f64 * scale) as u64;
        DeviceMemory::new(capacity, reserve)
    }

    pub fn capacity(&self) -> u64 {
        self.capacity
    }

    pub fn used(&self) -> u64 {
        self.used
    }

    /// Bytes available for caches: capacity − reserve − used. This is
    /// the "C" of Eq. (1) once the workload's own peak usage has been
    /// claimed via [`DeviceMemory::alloc`].
    pub fn available_for_cache(&self) -> u64 {
        self.capacity.saturating_sub(self.reserve).saturating_sub(self.used)
    }

    /// Claim `bytes` (workload tensors, caches). Fails with [`OomError`]
    /// if it would exceed capacity (the reserve is *not* allocatable —
    /// that is its purpose).
    pub fn alloc(&mut self, bytes: u64) -> Result<(), OomError> {
        if self.used + bytes > self.capacity.saturating_sub(self.reserve) {
            return Err(OomError {
                requested: bytes,
                in_use: self.used,
                capacity: self.capacity,
            });
        }
        self.used += bytes;
        Ok(())
    }

    /// Hard allocation that may also consume the reserve (used to model
    /// baselines that do not reserve headroom, e.g. RAIN).
    pub fn alloc_unreserved(&mut self, bytes: u64) -> Result<(), OomError> {
        if self.used + bytes > self.capacity {
            return Err(OomError {
                requested: bytes,
                in_use: self.used,
                capacity: self.capacity,
            });
        }
        self.used += bytes;
        Ok(())
    }

    /// Release previously claimed bytes.
    pub fn free(&mut self, bytes: u64) {
        self.used = self.used.saturating_sub(bytes);
    }
}

/// A node's set of simulated devices: one [`DeviceMemory`] arena per
/// cache shard. Every shard's snapshot claims bytes against the device
/// that actually holds it — a shard cannot borrow headroom from a
/// sibling device, which is exactly the constraint that makes the
/// per-shard budget split ([`crate::cache::split_budget`]) load-bearing
/// rather than cosmetic.
#[derive(Debug, Clone)]
pub struct DeviceGroup {
    devices: Vec<DeviceMemory>,
}

impl DeviceGroup {
    /// `n` identical devices cloned from a freshly built prototype
    /// (capacity and reserve copied, nothing allocated yet).
    pub fn replicate(proto: &DeviceMemory, n: usize) -> Self {
        assert_eq!(proto.used(), 0, "replicate from an unused prototype");
        DeviceGroup { devices: vec![proto.clone(); n.max(1)] }
    }

    /// The single-device group (the PR 2 shape).
    pub fn single(device: DeviceMemory) -> Self {
        DeviceGroup { devices: vec![device] }
    }

    pub fn n_devices(&self) -> usize {
        self.devices.len()
    }

    pub fn device(&self, i: usize) -> &DeviceMemory {
        &self.devices[i]
    }

    /// Claim `bytes` on device `i` only; fails with that device's
    /// [`OomError`] — sibling capacity is never consulted.
    pub fn alloc(&mut self, i: usize, bytes: u64) -> Result<(), OomError> {
        self.devices[i].alloc(bytes)
    }

    /// Reserve-consuming claim on device `i` (RAIN's staged tensor).
    pub fn alloc_unreserved(&mut self, i: usize, bytes: u64) -> Result<(), OomError> {
        self.devices[i].alloc_unreserved(bytes)
    }

    /// Release previously claimed bytes on device `i`.
    pub fn free(&mut self, i: usize, bytes: u64) {
        self.devices[i].free(bytes)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn alloc_respects_reserve() {
        let mut m = DeviceMemory::new(100, 10);
        assert_eq!(m.available_for_cache(), 90);
        m.alloc(80).unwrap();
        assert_eq!(m.available_for_cache(), 10);
        let err = m.alloc(20).unwrap_err();
        assert_eq!(err.in_use, 80);
        // unreserved path may take the headroom
        m.alloc_unreserved(20).unwrap();
        assert_eq!(m.used(), 100);
        assert!(m.alloc_unreserved(1).is_err());
    }

    #[test]
    fn free_returns_capacity() {
        let mut m = DeviceMemory::new(100, 0);
        m.alloc(60).unwrap();
        m.free(50);
        assert_eq!(m.used(), 10);
        m.free(1000); // saturates, never underflows
        assert_eq!(m.used(), 0);
    }

    #[test]
    fn scaled_testbed() {
        let m = DeviceMemory::rtx4090_scaled(0.1);
        assert_eq!(m.capacity(), (RTX4090_BYTES as f64 * 0.1) as u64);
        assert!(m.available_for_cache() > 2 * (1 << 30));
    }

    #[test]
    fn oom_message_mentions_cuda() {
        let mut m = DeviceMemory::new(10, 0);
        let err = m.alloc(100).unwrap_err();
        assert!(err.to_string().contains("CUDA out of memory"));
    }

    #[test]
    fn group_accounts_each_device_separately() {
        let proto = DeviceMemory::new(100, 10);
        let mut g = DeviceGroup::replicate(&proto, 3);
        assert_eq!(g.n_devices(), 3);
        g.alloc(0, 90).unwrap();
        // device 0 is full for cache purposes; devices 1-2 untouched
        assert!(g.alloc(0, 1).is_err(), "no borrowing from siblings");
        g.alloc(1, 50).unwrap();
        assert_eq!(g.device(0).used(), 90);
        assert_eq!(g.device(1).used(), 50);
        assert_eq!(g.device(2).used(), 0);
        g.free(1, 50);
        assert_eq!(g.device(1).used(), 0);
        // unreserved path still per-device
        g.alloc_unreserved(0, 10).unwrap();
        assert!(g.alloc_unreserved(0, 1).is_err());
    }

    #[test]
    fn group_single_and_degenerate_replicate() {
        let g = DeviceGroup::single(DeviceMemory::new(50, 5));
        assert_eq!(g.n_devices(), 1);
        assert_eq!(g.device(0).available_for_cache(), 45);
        let g = DeviceGroup::replicate(&DeviceMemory::new(50, 5), 0);
        assert_eq!(g.n_devices(), 1, "a group has at least one device");
    }
}
