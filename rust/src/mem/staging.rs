//! Pinned staging pool + coalesced copy plans — the zero-copy transfer
//! engine's host side (DESIGN.md §Transfer engine).
//!
//! A real deployment gathers cache-miss feature rows into *pinned*
//! (page-locked) host buffers so the H2D DMA engine can move them at
//! bulk PCIe bandwidth instead of issuing one random UVA transaction
//! per row. Pinned memory is expensive to allocate/register, so it is
//! pooled: a fixed set of fixed-size buffers is leased to a batch,
//! filled by the gather stage, handed to the transfer ring, and
//! returned after the consuming compute finishes (zero-copy: the
//! staged buffer *is* the compute input, so its lease spans compute).
//!
//! This repo's testbed is a CPU (DESIGN.md §Substitutions), so the
//! buffers here are ordinary `Vec<f32>`s — the *data path* (rows really
//! are written once into the leased buffer) and the *lease/return
//! accounting* are real, while pinning itself is part of the modeled
//! substrate. [`CopyPlan`] records the miss set as sorted,
//! run-length-merged row ranges: the shape of the DMA descriptor list
//! a staged copy issues, which [`CostModel::h2d_batched_ns`] prices as
//! per-copy launch latency + bulk bandwidth.
//!
//! [`CostModel::h2d_batched_ns`]: super::transfer::CostModel::h2d_batched_ns

use std::sync::atomic::{AtomicU64, Ordering};
use std::sync::Mutex;

use crate::util::lock_unpoisoned;

/// One contiguous run of feature-table rows in a [`CopyPlan`].
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub struct CopyRange {
    /// First row id of the run.
    pub start_row: u64,
    /// Number of consecutive rows.
    pub rows: u64,
}

/// A batch's miss set as a coalesced copy plan: sorted,
/// run-length-merged row ranges that exactly partition the (deduped)
/// miss rows. The plan is what the staged H2D copy is priced from —
/// `n_copies` DMA descriptors moving `total_bytes` at bulk bandwidth.
#[derive(Debug, Clone, PartialEq, Eq)]
pub struct CopyPlan {
    ranges: Vec<CopyRange>,
    row_bytes: u64,
    total_rows: u64,
}

impl CopyPlan {
    /// Coalesce `rows` (miss-row ids, any order; duplicates merged)
    /// into sorted run-length ranges. Sorting happens in place —
    /// callers hand over their scratch.
    pub fn coalesce(rows: &mut Vec<u64>, row_bytes: u64) -> CopyPlan {
        rows.sort_unstable();
        rows.dedup();
        let mut ranges: Vec<CopyRange> = Vec::new();
        for &r in rows.iter() {
            match ranges.last_mut() {
                Some(last) if last.start_row + last.rows == r => last.rows += 1,
                _ => ranges.push(CopyRange { start_row: r, rows: 1 }),
            }
        }
        let plan = CopyPlan { ranges, row_bytes, total_rows: rows.len() as u64 };
        debug_assert!(plan.is_partition(), "coalesced ranges must partition the miss set");
        plan
    }

    /// Number of coalesced copies (DMA descriptors) the plan issues.
    pub fn n_copies(&self) -> u64 {
        self.ranges.len() as u64
    }

    /// Distinct rows the plan moves.
    pub fn total_rows(&self) -> u64 {
        self.total_rows
    }

    /// Total payload bytes the plan moves.
    pub fn total_bytes(&self) -> u64 {
        self.total_rows * self.row_bytes
    }

    /// The sorted, merged ranges.
    pub fn ranges(&self) -> &[CopyRange] {
        &self.ranges
    }

    /// Invariant check (also the property the plan tests gate): ranges
    /// are sorted, non-overlapping, non-adjacent (maximally merged),
    /// and their lengths sum to exactly the distinct-row count.
    pub fn is_partition(&self) -> bool {
        let mut covered = 0u64;
        let mut prev_end: Option<u64> = None;
        for r in &self.ranges {
            if r.rows == 0 {
                return false;
            }
            if let Some(end) = prev_end {
                // `>` alone would allow an adjacent (unmerged) pair
                if r.start_row <= end {
                    return false;
                }
            }
            prev_end = Some(r.start_row + r.rows - 1);
            covered += r.rows;
        }
        covered == self.total_rows
    }
}

/// Lease/return counters of a [`StagingPool`], point-in-time.
#[derive(Debug, Clone, Copy, Default, PartialEq)]
pub struct StagingStats {
    /// Buffers the pool was built with (the pinned set).
    pub pool_buffers: u64,
    /// Lifetime leases handed out.
    pub leases: u64,
    /// Leases returned so far (`leases - returns` = in flight).
    pub returns: u64,
    /// Leases served by a fresh (unpinned, overflow) allocation
    /// because every pooled buffer was in flight.
    pub fresh_allocs: u64,
    /// High-water mark of concurrently leased buffers.
    pub peak_leased: u64,
}

impl StagingStats {
    /// Fraction of leases served from the pinned pool (1.0 = every
    /// lease reused a pooled buffer; the transfer bench gates this).
    pub fn reuse_ratio(&self) -> f64 {
        if self.leases == 0 {
            1.0
        } else {
            (self.leases - self.fresh_allocs) as f64 / self.leases as f64
        }
    }
}

/// Fixed-size pool of reusable staging buffers with explicit
/// lease/return accounting.
///
/// Sizing follows the auto-budget claim formula (§IV.A / DESIGN.md
/// §Elastic budgets): each buffer holds the features of the largest
/// pre-sampled batch (`peak_inputs × dim` floats) — the same
/// `peak_inputs` whose per-node claim the workload-aware budget
/// subtracts from device headroom, so the pool's host bytes mirror the
/// device bytes the claim already reserves. Leases never block: when
/// every pooled buffer is in flight the pool hands out a fresh
/// (overflow) allocation and counts it, so a mis-sized pool degrades
/// to per-batch allocation visibly (`fresh_allocs`) instead of
/// deadlocking the pipeline.
#[derive(Debug)]
pub struct StagingPool {
    free: Mutex<Vec<Vec<f32>>>,
    pool_buffers: u64,
    leases: AtomicU64,
    returns: AtomicU64,
    fresh_allocs: AtomicU64,
    in_flight: AtomicU64,
    peak_leased: AtomicU64,
}

impl StagingPool {
    /// A pool of `n_buffers` buffers, each pre-sized to `buf_floats`
    /// f32 capacity (0 = size on first use; capacity then sticks with
    /// the buffer across leases, so steady state is allocation-flat
    /// either way).
    pub fn new(n_buffers: usize, buf_floats: usize) -> StagingPool {
        let n = n_buffers.max(1);
        StagingPool {
            free: Mutex::new((0..n).map(|_| Vec::with_capacity(buf_floats)).collect()),
            pool_buffers: n as u64,
            leases: AtomicU64::new(0),
            returns: AtomicU64::new(0),
            fresh_allocs: AtomicU64::new(0),
            in_flight: AtomicU64::new(0),
            peak_leased: AtomicU64::new(0),
        }
    }

    /// Pool sized from the auto-budget claim inputs: each buffer holds
    /// `peak_inputs` rows of `dim` floats.
    pub fn for_workload(n_buffers: usize, peak_inputs: usize, dim: usize) -> StagingPool {
        StagingPool::new(n_buffers, peak_inputs.saturating_mul(dim))
    }

    /// Lease a buffer (cleared, capacity preserved). Never blocks: an
    /// exhausted pool serves a counted fresh allocation.
    pub fn lease(&self) -> Vec<f32> {
        self.leases.fetch_add(1, Ordering::Relaxed);
        let now = self.in_flight.fetch_add(1, Ordering::Relaxed) + 1;
        self.peak_leased.fetch_max(now, Ordering::Relaxed);
        match lock_unpoisoned(&self.free).pop() {
            Some(mut b) => {
                b.clear();
                b
            }
            None => {
                self.fresh_allocs.fetch_add(1, Ordering::Relaxed);
                Vec::new()
            }
        }
    }

    /// Return a leased buffer. The pool keeps at most its built size
    /// (`pool_buffers`); overflow buffers are dropped on return, so a
    /// burst never permanently grows the pinned set.
    pub fn give_back(&self, buf: Vec<f32>) {
        self.returns.fetch_add(1, Ordering::Relaxed);
        let prev = self.in_flight.load(Ordering::Relaxed);
        if prev > 0 {
            self.in_flight.fetch_sub(1, Ordering::Relaxed);
        }
        let mut free = lock_unpoisoned(&self.free);
        if (free.len() as u64) < self.pool_buffers {
            free.push(buf);
        }
    }

    /// Point-in-time counters.
    pub fn stats(&self) -> StagingStats {
        StagingStats {
            pool_buffers: self.pool_buffers,
            leases: self.leases.load(Ordering::Relaxed),
            returns: self.returns.load(Ordering::Relaxed),
            fresh_allocs: self.fresh_allocs.load(Ordering::Relaxed),
            peak_leased: self.peak_leased.load(Ordering::Relaxed),
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn coalesce_merges_runs_and_dedups() {
        let mut rows = vec![7, 3, 4, 5, 9, 9, 12];
        let plan = CopyPlan::coalesce(&mut rows, 100);
        assert_eq!(
            plan.ranges(),
            &[
                CopyRange { start_row: 3, rows: 3 },
                CopyRange { start_row: 7, rows: 1 },
                CopyRange { start_row: 9, rows: 1 },
                CopyRange { start_row: 12, rows: 1 },
            ]
        );
        assert_eq!(plan.n_copies(), 4);
        assert_eq!(plan.total_rows(), 6);
        assert_eq!(plan.total_bytes(), 600);
        assert!(plan.is_partition());
    }

    #[test]
    fn coalesce_is_order_independent() {
        let mut a = vec![10, 2, 3, 1, 40];
        let mut b = vec![40, 1, 2, 3, 10];
        assert_eq!(CopyPlan::coalesce(&mut a, 64), CopyPlan::coalesce(&mut b, 64));
    }

    #[test]
    fn empty_and_single_plans() {
        let mut none: Vec<u64> = vec![];
        let plan = CopyPlan::coalesce(&mut none, 64);
        assert_eq!(plan.n_copies(), 0);
        assert_eq!(plan.total_bytes(), 0);
        assert!(plan.is_partition());
        let mut one = vec![5];
        let plan = CopyPlan::coalesce(&mut one, 64);
        assert_eq!(plan.n_copies(), 1);
        assert_eq!(plan.total_bytes(), 64);
    }

    #[test]
    fn pool_reuses_buffers_and_counts_overflow() {
        let pool = StagingPool::new(2, 8);
        let a = pool.lease();
        let b = pool.lease();
        assert_eq!(a.capacity(), 8);
        // pool exhausted: third lease is a counted fresh alloc
        let c = pool.lease();
        assert_eq!(c.capacity(), 0);
        let s = pool.stats();
        assert_eq!(s.leases, 3);
        assert_eq!(s.fresh_allocs, 1);
        assert_eq!(s.peak_leased, 3);
        assert!((s.reuse_ratio() - 2.0 / 3.0).abs() < 1e-12);
        pool.give_back(a);
        pool.give_back(b);
        pool.give_back(c); // overflow return is dropped, pool stays at 2
        assert_eq!(pool.stats().returns, 3);
        let d = pool.lease();
        assert_eq!(d.capacity(), 8, "returned pooled buffer is reused");
        assert_eq!(pool.stats().fresh_allocs, 1);
    }

    #[test]
    fn pool_capacity_sticks_across_leases() {
        let pool = StagingPool::for_workload(1, 0, 16);
        let mut b = pool.lease();
        assert_eq!(b.capacity(), 0, "unsized pool grows on first use");
        b.extend_from_slice(&[1.0; 64]);
        pool.give_back(b);
        let b = pool.lease();
        assert!(b.capacity() >= 64, "grown capacity survives the return");
        assert!(b.is_empty(), "lease clears contents");
    }

    #[test]
    fn workload_sizing_matches_claim_inputs() {
        let pool = StagingPool::for_workload(3, 100, 16);
        assert_eq!(pool.lease().capacity(), 1600);
        assert_eq!(pool.stats().pool_buffers, 3);
    }
}
