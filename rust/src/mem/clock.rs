//! Transfer ledger: per-stage byte/transaction accounting that the cost
//! model converts into modeled time.
//!
//! Cache implementations record *what moved where* (device bytes vs.
//! PCIe bytes vs. UVA transactions); [`TransferLedger::modeled_ns`]
//! turns that into virtual time. Keeping raw counts (not pre-multiplied
//! time) lets benches re-evaluate one run under perturbed cost models
//! for the sensitivity analysis.

use super::transfer::CostModel;

/// Byte/transaction counters for one pipeline stage (or one batch).
#[derive(Debug, Clone, Default, PartialEq)]
pub struct TransferLedger {
    /// Bytes read from simulated device memory (cache hits).
    pub device_bytes: u64,
    /// Payload bytes fetched over UVA (cache misses).
    pub uva_bytes: u64,
    /// UVA transactions issued (misses; line-granular).
    pub uva_txns: u64,
    /// Bulk host→device bytes (batched uploads, cache fills).
    pub h2d_bytes: u64,
    /// Fixed launches (kernel invocations) in this stage.
    pub launches: u64,
    /// Cache-hit events (device-served reads).
    pub hits: u64,
    /// Cache-miss events (UVA-served reads).
    pub misses: u64,
    /// Bytes moved by coalesced staged H2D copies (miss rows gathered
    /// into a pinned staging buffer and shipped in bulk instead of
    /// per-row UVA reads).
    pub staged_bytes: u64,
    /// Coalesced copies issued for the staged bytes (the copy plan's
    /// range count; each pays [`CostModel::h2d_copy_ns`]).
    pub staged_copies: u64,
    /// Staged copies that failed and degraded to the per-row UVA
    /// fallback (fault injection / chaos testing).
    pub staged_fallbacks: u64,
}

impl TransferLedger {
    pub fn new() -> Self {
        Self::default()
    }

    /// Record a cache hit served from device memory.
    #[inline]
    pub fn hit(&mut self, bytes: u64) {
        self.device_bytes += bytes;
        self.hits += 1;
    }

    /// Record a cache miss served by `txns` random UVA transactions.
    #[inline]
    pub fn miss(&mut self, bytes: u64, txns: u64) {
        self.uva_bytes += bytes;
        self.uva_txns += txns;
        self.misses += 1;
    }

    /// Record a bulk host→device upload.
    #[inline]
    pub fn upload(&mut self, bytes: u64) {
        self.h2d_bytes += bytes;
    }

    /// Record a batch's coalesced staged transfer: `rows` miss rows
    /// moving `bytes` total in `copies` coalesced copies. Counts the
    /// rows as misses (hit/miss ratios are staging-agnostic) but prices
    /// them as one batched H2D instead of per-row UVA reads.
    #[inline]
    pub fn staged(&mut self, rows: u64, bytes: u64, copies: u64) {
        self.misses += rows;
        self.staged_bytes += bytes;
        self.staged_copies += copies;
    }

    /// Record a staged copy that failed and was re-issued per-row (the
    /// caller re-records those rows via [`TransferLedger::miss`]).
    #[inline]
    pub fn staged_fallback(&mut self) {
        self.staged_fallbacks += 1;
    }

    /// Record a kernel/stage launch.
    #[inline]
    pub fn launch(&mut self) {
        self.launches += 1;
    }

    /// Fold another ledger into this one.
    pub fn merge(&mut self, other: &TransferLedger) {
        self.device_bytes += other.device_bytes;
        self.uva_bytes += other.uva_bytes;
        self.uva_txns += other.uva_txns;
        self.h2d_bytes += other.h2d_bytes;
        self.launches += other.launches;
        self.hits += other.hits;
        self.misses += other.misses;
        self.staged_bytes += other.staged_bytes;
        self.staged_copies += other.staged_copies;
        self.staged_fallbacks += other.staged_fallbacks;
    }

    /// Modeled time under `m`, in ns.
    pub fn modeled_ns(&self, m: &CostModel) -> f64 {
        m.device_ns(self.device_bytes)
            + m.uva_ns(self.uva_bytes, self.uva_txns)
            + m.h2d_ns(self.h2d_bytes)
            + m.h2d_batched_ns(self.staged_bytes, self.staged_copies)
            + self.launches as f64 * m.launch_ns
    }

    /// Modeled ns of just the staged H2D portion — the slice the
    /// transfer ring can hide under compute.
    pub fn staged_ns(&self, m: &CostModel) -> f64 {
        m.h2d_batched_ns(self.staged_bytes, self.staged_copies)
    }

    /// Total payload bytes that crossed PCIe (the quantity DCI
    /// minimizes).
    pub fn pcie_bytes(&self) -> u64 {
        self.uva_bytes.max(self.uva_txns * 128) + self.h2d_bytes + self.staged_bytes
    }

    /// Cache hit ratio over hit/miss events (Fig. 9's y-axis).
    pub fn hit_ratio(&self) -> f64 {
        let total = self.hits + self.misses;
        if total == 0 {
            0.0
        } else {
            self.hits as f64 / total as f64
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn accumulates_and_merges() {
        let mut a = TransferLedger::new();
        a.hit(100);
        a.miss(400, 4);
        a.upload(1000);
        a.launch();
        let mut b = TransferLedger::new();
        b.hit(1);
        b.merge(&a);
        assert_eq!(b.device_bytes, 101);
        assert_eq!(b.uva_bytes, 400);
        assert_eq!(b.uva_txns, 4);
        assert_eq!(b.h2d_bytes, 1000);
        assert_eq!(b.launches, 1);
        assert_eq!(b.hits, 2);
        assert_eq!(b.misses, 1);
        assert!((b.hit_ratio() - 2.0 / 3.0).abs() < 1e-12);
        assert_eq!(TransferLedger::new().hit_ratio(), 0.0);
    }

    #[test]
    fn modeled_time_orders_hit_below_miss() {
        let m = CostModel::default();
        let mut hits = TransferLedger::new();
        hits.hit(1 << 20);
        let mut misses = TransferLedger::new();
        misses.miss(1 << 20, (1 << 20) / 128);
        assert!(misses.modeled_ns(&m) > 50.0 * hits.modeled_ns(&m));
    }

    #[test]
    fn staged_counts_misses_but_prices_bulk() {
        let m = CostModel::default();
        let row_bytes = 2408u64;
        let txns = 19u64;
        let mut per_row = TransferLedger::new();
        let mut staged = TransferLedger::new();
        for _ in 0..100 {
            per_row.miss(row_bytes, txns);
        }
        staged.staged(100, 100 * row_bytes, 37);
        // same miss count and PCIe payload, cheaper modeled time
        assert_eq!(per_row.misses, staged.misses);
        assert_eq!(staged.pcie_bytes(), 100 * row_bytes);
        assert!(per_row.modeled_ns(&m) > 1.3 * staged.modeled_ns(&m));
        assert_eq!(staged.staged_ns(&m), staged.modeled_ns(&m));
        // merge carries the staged counters
        let mut sum = TransferLedger::new();
        sum.merge(&staged);
        sum.staged_fallback();
        assert_eq!(sum.staged_bytes, 100 * row_bytes);
        assert_eq!(sum.staged_copies, 37);
        assert_eq!(sum.staged_fallbacks, 1);
    }

    #[test]
    fn pcie_bytes_line_granular() {
        let mut l = TransferLedger::new();
        l.miss(4, 1); // 4 payload bytes, one 128B line
        assert_eq!(l.pcie_bytes(), 128);
        l.upload(100);
        assert_eq!(l.pcie_bytes(), 228);
    }
}
