//! Simulated GPU memory + host↔device transfer cost model.
//!
//! The paper's testbed is an RTX 4090 over PCIe with UVA; this repo's
//! testbed is a CPU. DCI's wins come from *which bytes cross PCIe*, so
//! we keep the data path real (actual copies) and account the transfer
//! cost on a virtual clock (DESIGN.md §Substitutions): every reported
//! stage time is `measured CPU wall + modeled transfer time`.

pub mod clock;
pub mod device;
pub mod staging;
pub mod transfer;

pub use clock::TransferLedger;
pub use device::{
    parse_device_tiers, per_node_claim_bytes, workload_claim_bytes, DeviceGroup, DeviceMemory,
    DeviceTier, OomError, PAPER_RESERVE_BYTES, RTX4090_BYTES,
};
pub use staging::{CopyPlan, CopyRange, StagingPool, StagingStats};
pub use transfer::CostModel;
