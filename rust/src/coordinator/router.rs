//! Request router: spreads client requests across worker queues.
//!
//! Policies: round-robin (default) and least-loaded (by queued seed
//! count). With one CPU core the fleet is usually one worker, but the
//! topology (router → N workers, each with private caches + PJRT
//! executables) is the deployment shape the paper's system would run
//! behind a real inference frontend.

use std::sync::atomic::{AtomicU64, AtomicUsize, Ordering};
use std::sync::{mpsc, Arc};

use anyhow::{bail, Result};

use super::Request;

/// Routing policy.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum RoutePolicy {
    /// Cycle through workers in order.
    RoundRobin,
    /// Send to the worker with the fewest queued seeds.
    LeastLoaded,
}

/// Per-worker handle: queue sender + load gauge.
pub struct WorkerHandle {
    /// The worker's request queue.
    pub tx: mpsc::Sender<Request>,
    /// Seeds currently queued (decremented by the worker).
    pub queued_seeds: Arc<AtomicUsize>,
}

/// The router.
pub struct Router {
    workers: Vec<WorkerHandle>,
    policy: RoutePolicy,
    next: AtomicU64,
}

impl Router {
    /// A router over at least one worker.
    pub fn new(workers: Vec<WorkerHandle>, policy: RoutePolicy) -> Result<Router> {
        if workers.is_empty() {
            bail!("router needs at least one worker");
        }
        Ok(Router { workers, policy, next: AtomicU64::new(0) })
    }

    /// Number of workers behind this router.
    pub fn n_workers(&self) -> usize {
        self.workers.len()
    }

    /// Total seeds currently queued across workers (backpressure input).
    pub fn queued_seeds(&self) -> usize {
        self.workers
            .iter()
            .map(|w| w.queued_seeds.load(Ordering::Relaxed))
            .sum()
    }

    /// Pick a worker index for a request of `n_seeds`.
    pub fn pick(&self, n_seeds: usize) -> usize {
        let i = match self.policy {
            RoutePolicy::RoundRobin => {
                (self.next.fetch_add(1, Ordering::Relaxed) as usize) % self.workers.len()
            }
            RoutePolicy::LeastLoaded => self
                .workers
                .iter()
                .enumerate()
                .min_by_key(|(_, w)| w.queued_seeds.load(Ordering::Relaxed))
                .map(|(i, _)| i)
                .unwrap(),
        };
        self.workers[i].queued_seeds.fetch_add(n_seeds, Ordering::Relaxed);
        i
    }

    /// Route a request (send into the picked worker's queue).
    pub fn route(&self, req: Request) -> Result<()> {
        let i = self.pick(req.nodes.len());
        self.workers[i]
            .tx
            .send(req)
            .map_err(|_| anyhow::anyhow!("worker {i} hung up"))
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use std::time::Instant;

    fn workers(n: usize) -> (Vec<WorkerHandle>, Vec<mpsc::Receiver<Request>>) {
        let mut hs = Vec::new();
        let mut rxs = Vec::new();
        for _ in 0..n {
            let (tx, rx) = mpsc::channel();
            hs.push(WorkerHandle { tx, queued_seeds: Arc::new(AtomicUsize::new(0)) });
            rxs.push(rx);
        }
        (hs, rxs)
    }

    fn req(n: usize) -> Request {
        let (tx, _rx) = mpsc::channel();
        std::mem::forget(_rx);
        Request {
            nodes: vec![0; n],
            class: super::super::TenantClass::Standard,
            submitted: Instant::now(),
            reply: tx,
        }
    }

    #[test]
    fn round_robin_cycles() {
        let (hs, _rxs) = workers(3);
        let r = Router::new(hs, RoutePolicy::RoundRobin).unwrap();
        let picks: Vec<usize> = (0..6).map(|_| r.pick(1)).collect();
        assert_eq!(picks, vec![0, 1, 2, 0, 1, 2]);
    }

    #[test]
    fn least_loaded_prefers_idle() {
        let (hs, _rxs) = workers(2);
        let r = Router::new(hs, RoutePolicy::LeastLoaded).unwrap();
        assert_eq!(r.pick(100), 0); // both idle -> first
        assert_eq!(r.pick(1), 1);   // worker 0 now has 100 queued
        assert_eq!(r.pick(1), 1);   // worker 1 has 1 < 100
    }

    #[test]
    fn route_delivers() {
        let (hs, rxs) = workers(1);
        let r = Router::new(hs, RoutePolicy::RoundRobin).unwrap();
        r.route(req(5)).unwrap();
        let got = rxs[0].try_recv().unwrap();
        assert_eq!(got.nodes.len(), 5);
    }

    #[test]
    fn empty_router_rejected() {
        assert!(Router::new(Vec::new(), RoutePolicy::RoundRobin).is_err());
    }
}
