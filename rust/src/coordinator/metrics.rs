//! Serving metrics: request latency distribution, batch sizes, seed
//! throughput, live cache hit ratios, per-tenant SLO ledgers, and the
//! online-refresh / snapshot-swap counters — the numbers the
//! end-to-end example and the cache-runtime bench report.
//!
//! Two consumption surfaces, one source of truth: [`ServingMetrics`]
//! accumulates raw counters; [`ServingMetrics::snapshot`] derives the
//! typed [`MetricsSnapshot`] tree (ratios, quantiles, throughput) from
//! them; and both the human [`ServingMetrics::report`] text and the
//! canonical-JSON [`MetricsSnapshot::to_json`] encoding are thin views
//! over that snapshot — a number can never disagree between the text
//! and JSON forms because both read the same derived struct.

use std::time::Duration;

use crate::cache::CacheStats;
use crate::util::json::{num, obj, s, Json};
use crate::util::stats::LatencyHist;

use super::admission::{TenantClass, N_CLASSES};

/// Per-class serving ledger: the SLO surface for one admission class
/// (requests, seeds, end-to-end latency distribution, feature-cache
/// hit events attributed to the class's batches, and frontend sheds).
///
/// One ledger per [`TenantClass`], indexed by [`TenantClass::index`]
/// in [`ServingMetrics::tenants`]. Batches never mix classes (the
/// batcher keeps per-class lanes), so a batch's transfer ledger
/// attributes cleanly to exactly one class.
#[derive(Debug, Clone, Default)]
pub struct TenantLedger {
    /// Client requests served under this class.
    pub requests: u64,
    /// Seed nodes served under this class.
    pub seeds: u64,
    /// Request latency distribution (submit → reply) for this class.
    pub latency: LatencyHist,
    /// Feature-cache hit events from this class's batches.
    pub feat_hits: u64,
    /// Feature-cache miss events from this class's batches.
    pub feat_misses: u64,
    /// Requests the admission frontend shed for this class (queue
    /// ceiling; scan sheds first — see `AdmissionConfig`).
    pub sheds: u64,
}

impl TenantLedger {
    /// Feature-cache hit ratio over this class's traffic (0 when the
    /// class served nothing).
    pub fn feat_hit_ratio(&self) -> f64 {
        let total = self.feat_hits + self.feat_misses;
        if total == 0 {
            0.0
        } else {
            self.feat_hits as f64 / total as f64
        }
    }

    /// Fold another worker's ledger for the same class into this one.
    pub fn merge(&mut self, other: &TenantLedger) {
        self.requests += other.requests;
        self.seeds += other.seeds;
        self.latency.merge(&other.latency);
        self.feat_hits += other.feat_hits;
        self.feat_misses += other.feat_misses;
        self.sheds += other.sheds;
    }
}

/// Accumulated serving-side metrics (one per worker; merged at report
/// time).
#[derive(Debug, Clone, Default)]
pub struct ServingMetrics {
    /// Client requests served.
    pub requests: u64,
    /// Seed nodes served across all requests.
    pub seeds: u64,
    /// Engine batches executed.
    pub batches: u64,
    /// Request latency distribution (submit → reply).
    pub latency: LatencyHist,
    /// Per-class SLO ledgers, indexed by [`TenantClass::index`].
    pub tenants: [TenantLedger; N_CLASSES],
    /// Sampling-stage total (ns, wall + modeled).
    pub sample_ns: f64,
    /// Feature-stage total (ns, wall + modeled).
    pub feature_ns: f64,
    /// Compute-stage total (ns, wall + modeled).
    pub compute_ns: f64,
    /// Modeled staged-H2D time shipped through the transfer ring, ns
    /// (zero with `transfer-ring=0`; see DESIGN.md §Transfer engine).
    pub transfer_staged_ns: f64,
    /// Portion of `transfer_staged_ns` the ring hid under compute, ns.
    pub transfer_hidden_ns: f64,
    /// Staging-buffer leases handed out across workers (serving +
    /// refresh refills).
    pub staging_leases: u64,
    /// Leases the pinned pools could not serve (overflow allocations —
    /// persistent nonzero values mean `staging-buffers` is too small).
    pub staging_fresh_allocs: u64,
    /// High-water mark of concurrently leased staging buffers on any
    /// one worker.
    pub staging_peak_leased: u64,
    /// Serving-time transfer stats (per-batch ledgers folded in:
    /// live hit ratios, plus online-refresh refill traffic).
    pub cache: CacheStats,
    /// Re-plans the refresh loop installed.
    pub refreshes: u64,
    /// Drift checks the refresh loop evaluated.
    pub drift_checks: u64,
    /// Background wall time spent re-planning, ns (never on the
    /// serving path).
    pub refresh_ns: f64,
    /// Snapshot acquires that had to block on a concurrent install
    /// (the runtime's swap-stall counter; 0 in a healthy deployment).
    pub swap_stalls: u64,
    /// Background wall time the refresh loop spent draining the
    /// workload tracker and folding windows into the decayed profile,
    /// ns — the cost `tracker=sketch` shrinks from O(nodes + edges) to
    /// O(touched) per poll.
    pub tracker_drain_ns: f64,
    /// Sparse keys (nodes + CSC elements) drained across all windows.
    pub tracker_drained_keys: u64,
    /// Touches the tracker's bounded touched set could not enumerate
    /// (sketch only; persistent nonzero values mean the drain interval
    /// is too long for the traffic — shorten `refresh.check-ms`).
    pub tracker_dropped_touches: u64,
    /// Cross-shard budget re-split events applied by the refresh loop
    /// (`cache.rebalance=on`; see DESIGN.md §Elastic budgets).
    pub shard_rebalances: u64,
    /// Σ bytes gained by growing shards across all re-splits — the
    /// cache capacity that actually moved between devices.
    pub budget_moved_bytes: u64,
    /// Final global budget minus the startup global budget, summed
    /// over workers (nonzero only with `refresh.auto-budget=on` on a
    /// `budget=auto` run).
    pub auto_budget_delta: i64,
    /// Shard installs retried after a transient device-claim or
    /// transfer failure (each retry waits out one backoff pause).
    pub install_retries: u64,
    /// Background wall time spent in install retry backoff, ns (never
    /// on the serving path).
    pub backoff_ns: f64,
    /// Shards that entered degraded (host-memory fallback) mode after
    /// an install failed terminally.
    pub shard_degrades: u64,
    /// Degraded shards the background repair loop promoted back to a
    /// healthy device-resident cache.
    pub shard_repairs: u64,
    /// Σ wall time shards spent degraded before repair, ns.
    pub repair_ns: f64,
    /// Refresh-loop generations the watchdog respawned (after a panic
    /// or a hang past `fault.watchdog-ms`).
    pub watchdog_restarts: u64,
    /// Refresh-loop panics the watchdog absorbed.
    pub refresh_panics: u64,
    /// Serving batches retried after an isolated panic (the retry
    /// replays the identical request; see DESIGN.md §Fault tolerance).
    pub batch_retries: u64,
    /// Serving batches that failed after the one retry (clients got an
    /// error response; the worker kept serving).
    pub batch_failures: u64,
    /// Live-graph epochs published during the run (mutation waves +
    /// compactions; 0 on frozen-graph runs). Folded once from the
    /// shared [`LiveGraph`](crate::graph::LiveGraph), not per worker.
    pub graph_epochs: u64,
    /// Edges the mutation driver inserted into the live graph.
    pub graph_edges_inserted: u64,
    /// Delta-into-base compactions the live graph performed.
    pub graph_compactions: u64,
    /// Graph-epoch acquires that blocked on a swap (the live graph's
    /// never-block gate; 0 in a healthy deployment).
    pub graph_swap_stalls: u64,
}

impl ServingMetrics {
    /// Zeroed metrics.
    pub fn new() -> Self {
        Self::default()
    }

    /// Count one served batch of `n_requests` requests / `n_seeds`
    /// seeds.
    pub fn record_batch(&mut self, n_requests: usize, n_seeds: usize) {
        self.batches += 1;
        self.requests += n_requests as u64;
        self.seeds += n_seeds as u64;
    }

    /// Attribute one served batch — its requests, seeds, and feature
    /// hit/miss events — to its admission class's SLO ledger.
    pub fn record_tenant_batch(
        &mut self,
        class: TenantClass,
        n_requests: usize,
        n_seeds: usize,
        feat_hits: u64,
        feat_misses: u64,
    ) {
        let t = &mut self.tenants[class.index()];
        t.requests += n_requests as u64;
        t.seeds += n_seeds as u64;
        t.feat_hits += feat_hits;
        t.feat_misses += feat_misses;
    }

    /// Record one request's end-to-end latency.
    pub fn record_latency(&mut self, ns: u64) {
        self.latency.record_ns(ns);
    }

    /// Record one request's end-to-end latency, both globally and in
    /// its class's SLO ledger.
    pub fn record_latency_as(&mut self, class: TenantClass, ns: u64) {
        self.latency.record_ns(ns);
        self.tenants[class.index()].latency.record_ns(ns);
    }

    /// Fold the admission frontend's per-class shed totals in (called
    /// once per report/shutdown on a freshly merged snapshot — sheds
    /// live in the controller, not in any worker's metrics).
    pub fn record_sheds(&mut self, sheds: [u64; N_CLASSES]) {
        for (t, n) in self.tenants.iter_mut().zip(sheds.iter()) {
            t.sheds += n;
        }
    }

    /// Fold another worker's metrics into this one.
    pub fn merge(&mut self, other: &ServingMetrics) {
        self.requests += other.requests;
        self.seeds += other.seeds;
        self.batches += other.batches;
        self.latency.merge(&other.latency);
        for (t, o) in self.tenants.iter_mut().zip(other.tenants.iter()) {
            t.merge(o);
        }
        self.sample_ns += other.sample_ns;
        self.feature_ns += other.feature_ns;
        self.compute_ns += other.compute_ns;
        self.transfer_staged_ns += other.transfer_staged_ns;
        self.transfer_hidden_ns += other.transfer_hidden_ns;
        self.staging_leases += other.staging_leases;
        self.staging_fresh_allocs += other.staging_fresh_allocs;
        self.staging_peak_leased = self.staging_peak_leased.max(other.staging_peak_leased);
        self.cache.merge(&other.cache);
        self.refreshes += other.refreshes;
        self.drift_checks += other.drift_checks;
        self.refresh_ns += other.refresh_ns;
        self.swap_stalls += other.swap_stalls;
        self.tracker_drain_ns += other.tracker_drain_ns;
        self.tracker_drained_keys += other.tracker_drained_keys;
        self.tracker_dropped_touches += other.tracker_dropped_touches;
        self.shard_rebalances += other.shard_rebalances;
        self.budget_moved_bytes += other.budget_moved_bytes;
        self.auto_budget_delta += other.auto_budget_delta;
        self.install_retries += other.install_retries;
        self.backoff_ns += other.backoff_ns;
        self.shard_degrades += other.shard_degrades;
        self.shard_repairs += other.shard_repairs;
        self.repair_ns += other.repair_ns;
        self.watchdog_restarts += other.watchdog_restarts;
        self.refresh_panics += other.refresh_panics;
        self.batch_retries += other.batch_retries;
        self.batch_failures += other.batch_failures;
        self.graph_epochs += other.graph_epochs;
        self.graph_edges_inserted += other.graph_edges_inserted;
        self.graph_compactions += other.graph_compactions;
        self.graph_swap_stalls += other.graph_swap_stalls;
    }

    /// Fold the shared live graph's lifetime counters in (called once
    /// per report/shutdown on a freshly merged snapshot — the graph is
    /// shared across workers, so folding it per worker would
    /// double-count).
    pub fn record_graph(&mut self, lg: &crate::graph::LiveGraph) {
        self.graph_epochs += lg.swaps();
        self.graph_edges_inserted += lg.edges_inserted();
        self.graph_compactions += lg.compactions();
        self.graph_swap_stalls += lg.swap_stalls();
    }

    /// Fraction of staged-H2D time the transfer ring hid under compute
    /// (0.0 when nothing staged; the overlap bench gates this).
    pub fn transfer_occupancy(&self) -> f64 {
        if self.transfer_staged_ns <= 0.0 {
            0.0
        } else {
            self.transfer_hidden_ns / self.transfer_staged_ns
        }
    }

    /// Seeds served per second of elapsed wall time.
    pub fn throughput(&self, elapsed: Duration) -> f64 {
        if elapsed.is_zero() {
            0.0
        } else {
            self.seeds as f64 / elapsed.as_secs_f64()
        }
    }

    /// Derive the typed snapshot tree: every ratio, quantile, and rate
    /// the report and JSON surfaces expose, computed once.
    pub fn snapshot(&self, elapsed: Duration) -> MetricsSnapshot {
        let (p50, p90, p99) = self.latency.quantiles_ns();
        let tenants = std::array::from_fn(|i| {
            let t = &self.tenants[i];
            let (t50, _, t99) = t.latency.quantiles_ns();
            TenantSnapshot {
                class: TenantClass::ALL[i].as_str(),
                requests: t.requests,
                seeds: t.seeds,
                p50_ms: t50 / 1e6,
                p99_ms: t99 / 1e6,
                feat_hit_ratio: t.feat_hit_ratio(),
                sheds: t.sheds,
            }
        });
        MetricsSnapshot {
            traffic: TrafficSnapshot {
                requests: self.requests,
                seeds: self.seeds,
                batches: self.batches,
                avg_batch_seeds: self.seeds as f64 / self.batches.max(1) as f64,
                p50_ms: p50 / 1e6,
                p90_ms: p90 / 1e6,
                p99_ms: p99 / 1e6,
                mean_ms: self.latency.mean_ns() / 1e6,
                throughput_seeds_per_s: self.throughput(elapsed),
            },
            stages: StageSnapshot {
                sample_ms: self.sample_ns / 1e6,
                feature_ms: self.feature_ns / 1e6,
                compute_ms: self.compute_ns / 1e6,
            },
            cache: CacheHealthSnapshot {
                adj_hit_ratio: self.cache.adj_hit_ratio(),
                feat_hit_ratio: self.cache.feat_hit_ratio(),
                refreshes: self.refreshes,
                refresh_bg_ms: self.refresh_ns / 1e6,
                drift_checks: self.drift_checks,
                swap_stalls: self.swap_stalls,
            },
            transfer: TransferSnapshot {
                staged_ms: self.transfer_staged_ns / 1e6,
                hidden_ms: self.transfer_hidden_ns / 1e6,
                occupancy: self.transfer_occupancy(),
                leases: self.staging_leases,
                overflow_allocs: self.staging_fresh_allocs,
                peak_leased: self.staging_peak_leased,
                fallbacks: self.cache.feature.staged_fallbacks,
            },
            tracker: TrackerSnapshot {
                drain_ms: self.tracker_drain_ns / 1e6,
                drained_keys: self.tracker_drained_keys,
                dropped_touches: self.tracker_dropped_touches,
            },
            elastic: ElasticSnapshot {
                rebalances: self.shard_rebalances,
                moved_bytes: self.budget_moved_bytes,
                auto_budget_delta: self.auto_budget_delta,
            },
            fault: FaultSnapshot {
                install_retries: self.install_retries,
                backoff_ms: self.backoff_ns / 1e6,
                degrades: self.shard_degrades,
                repairs: self.shard_repairs,
                degraded_ms: self.repair_ns / 1e6,
                watchdog_restarts: self.watchdog_restarts,
                refresh_panics: self.refresh_panics,
                batch_retries: self.batch_retries,
                batch_failures: self.batch_failures,
            },
            tenants,
        }
    }

    /// Multi-line human report — a thin text rendering of
    /// [`ServingMetrics::snapshot`].
    pub fn report(&self, elapsed: Duration) -> String {
        let snap = self.snapshot(elapsed);
        let tenant_line = snap
            .tenants
            .iter()
            .map(|t| {
                format!(
                    "{} req={} p50={:.2}ms p99={:.2}ms feat-hit={:.3} shed={}",
                    t.class, t.requests, t.p50_ms, t.p99_ms, t.feat_hit_ratio, t.sheds
                )
            })
            .collect::<Vec<_>>()
            .join(" | ");
        let mut out = format!(
            "requests={} seeds={} batches={} (avg batch {:.1} seeds)\n\
             latency p50={:.2}ms p90={:.2}ms p99={:.2}ms mean={:.2}ms\n\
             throughput={:.0} seeds/s\n\
             stage totals: sample={:.1}ms feature={:.1}ms compute={:.1}ms\n\
             cache: adj-hit={:.3} feat-hit={:.3} refreshes={} (bg {:.1}ms, {} checks) swap-stalls={}\n\
             transfer: staged={:.2}ms hidden={:.2}ms occupancy={:.2} \
             leases={} overflow={} peak-leased={} fallbacks={}\n\
             tracker: drain={:.2}ms drained-keys={} dropped-touches={}\n\
             elastic: rebalances={} moved={} auto-budget-delta={}\n\
             fault: retries={} backoff={:.1}ms degrades={} repairs={} ({:.1}ms degraded) \
             watchdog={} panics={} batch-retry={} batch-fail={}\n\
             tenant: {}",
            snap.traffic.requests,
            snap.traffic.seeds,
            snap.traffic.batches,
            snap.traffic.avg_batch_seeds,
            snap.traffic.p50_ms,
            snap.traffic.p90_ms,
            snap.traffic.p99_ms,
            snap.traffic.mean_ms,
            snap.traffic.throughput_seeds_per_s,
            snap.stages.sample_ms,
            snap.stages.feature_ms,
            snap.stages.compute_ms,
            snap.cache.adj_hit_ratio,
            snap.cache.feat_hit_ratio,
            snap.cache.refreshes,
            snap.cache.refresh_bg_ms,
            snap.cache.drift_checks,
            snap.cache.swap_stalls,
            snap.transfer.staged_ms,
            snap.transfer.hidden_ms,
            snap.transfer.occupancy,
            snap.transfer.leases,
            snap.transfer.overflow_allocs,
            snap.transfer.peak_leased,
            snap.transfer.fallbacks,
            snap.tracker.drain_ms,
            snap.tracker.drained_keys,
            snap.tracker.dropped_touches,
            snap.elastic.rebalances,
            crate::util::format_bytes(snap.elastic.moved_bytes),
            snap.elastic.auto_budget_delta,
            snap.fault.install_retries,
            snap.fault.backoff_ms,
            snap.fault.degrades,
            snap.fault.repairs,
            snap.fault.degraded_ms,
            snap.fault.watchdog_restarts,
            snap.fault.refresh_panics,
            snap.fault.batch_retries,
            snap.fault.batch_failures,
            tenant_line,
        );
        if self.graph_epochs > 0 {
            out.push_str(&format!(
                "\ngraph: epochs={} inserted={} compactions={} swap-stalls={}",
                self.graph_epochs,
                self.graph_edges_inserted,
                self.graph_compactions,
                self.graph_swap_stalls
            ));
        }
        out
    }
}

/// The typed, derived view of [`ServingMetrics`]: groups mirror the
/// namespaced config surface (`cache.*`, `transfer.*`, `fault.*`,
/// `tenant.*`) so a dashboard key and the knob that tunes it share a
/// vocabulary. Serialize with [`MetricsSnapshot::to_json`].
#[derive(Debug, Clone)]
pub struct MetricsSnapshot {
    /// Request/seed/batch volume and latency quantiles.
    pub traffic: TrafficSnapshot,
    /// Per-stage time totals.
    pub stages: StageSnapshot,
    /// Cache hit ratios and refresh-loop health.
    pub cache: CacheHealthSnapshot,
    /// Transfer-engine ring and staging-pool health.
    pub transfer: TransferSnapshot,
    /// Workload-tracker drain health.
    pub tracker: TrackerSnapshot,
    /// Elastic cross-shard budget movement.
    pub elastic: ElasticSnapshot,
    /// Fault-tolerance counters.
    pub fault: FaultSnapshot,
    /// Per-class SLO views, in [`TenantClass::ALL`] order.
    pub tenants: [TenantSnapshot; N_CLASSES],
}

/// Request volume and end-to-end latency quantiles.
#[derive(Debug, Clone)]
pub struct TrafficSnapshot {
    /// Client requests served.
    pub requests: u64,
    /// Seed nodes served.
    pub seeds: u64,
    /// Engine batches executed.
    pub batches: u64,
    /// Mean seeds per batch.
    pub avg_batch_seeds: f64,
    /// Median request latency, ms.
    pub p50_ms: f64,
    /// 90th-percentile request latency, ms.
    pub p90_ms: f64,
    /// 99th-percentile request latency, ms.
    pub p99_ms: f64,
    /// Mean request latency, ms.
    pub mean_ms: f64,
    /// Seeds served per second of elapsed wall time.
    pub throughput_seeds_per_s: f64,
}

/// Per-stage time totals (wall + modeled), ms.
#[derive(Debug, Clone)]
pub struct StageSnapshot {
    /// Sampling-stage total, ms.
    pub sample_ms: f64,
    /// Feature-stage total, ms.
    pub feature_ms: f64,
    /// Compute-stage total, ms.
    pub compute_ms: f64,
}

/// Cache hit ratios and online-refresh health.
#[derive(Debug, Clone)]
pub struct CacheHealthSnapshot {
    /// Adjacency-cache hit ratio.
    pub adj_hit_ratio: f64,
    /// Feature-cache hit ratio.
    pub feat_hit_ratio: f64,
    /// Re-plans installed.
    pub refreshes: u64,
    /// Background re-planning wall time, ms.
    pub refresh_bg_ms: f64,
    /// Drift checks evaluated.
    pub drift_checks: u64,
    /// Snapshot acquires that blocked on an install (0 when healthy).
    pub swap_stalls: u64,
}

/// Transfer-ring and staging-pool health.
#[derive(Debug, Clone)]
pub struct TransferSnapshot {
    /// Modeled staged-H2D time, ms.
    pub staged_ms: f64,
    /// Staged time the ring hid under compute, ms.
    pub hidden_ms: f64,
    /// `hidden / staged` (0 when nothing staged).
    pub occupancy: f64,
    /// Staging-buffer leases handed out.
    pub leases: u64,
    /// Leases the pinned pools could not serve.
    pub overflow_allocs: u64,
    /// High-water mark of concurrently leased buffers.
    pub peak_leased: u64,
    /// Staged copies that degraded to per-row fallback.
    pub fallbacks: u64,
}

/// Workload-tracker drain health.
#[derive(Debug, Clone)]
pub struct TrackerSnapshot {
    /// Background drain wall time, ms.
    pub drain_ms: f64,
    /// Sparse keys drained across all windows.
    pub drained_keys: u64,
    /// Touches the bounded touched set could not enumerate.
    pub dropped_touches: u64,
}

/// Elastic cross-shard budget movement.
#[derive(Debug, Clone)]
pub struct ElasticSnapshot {
    /// Budget re-split events applied.
    pub rebalances: u64,
    /// Σ bytes gained by growing shards across re-splits.
    pub moved_bytes: u64,
    /// Final minus startup global budget (auto-budget runs only).
    pub auto_budget_delta: i64,
}

/// Fault-tolerance counters.
#[derive(Debug, Clone)]
pub struct FaultSnapshot {
    /// Shard installs retried after transient failures.
    pub install_retries: u64,
    /// Install retry backoff wall time, ms.
    pub backoff_ms: f64,
    /// Shards that entered degraded mode.
    pub degrades: u64,
    /// Degraded shards repaired back to device residency.
    pub repairs: u64,
    /// Σ wall time spent degraded, ms.
    pub degraded_ms: f64,
    /// Refresh-loop generations the watchdog respawned.
    pub watchdog_restarts: u64,
    /// Refresh-loop panics absorbed.
    pub refresh_panics: u64,
    /// Serving batches retried after an isolated panic.
    pub batch_retries: u64,
    /// Serving batches that failed after the one retry.
    pub batch_failures: u64,
}

/// One class's derived SLO view.
#[derive(Debug, Clone)]
pub struct TenantSnapshot {
    /// Class name (`"priority"` / `"standard"` / `"scan"`).
    pub class: &'static str,
    /// Requests served under this class.
    pub requests: u64,
    /// Seeds served under this class.
    pub seeds: u64,
    /// Median request latency, ms.
    pub p50_ms: f64,
    /// 99th-percentile request latency, ms.
    pub p99_ms: f64,
    /// Feature-cache hit ratio over this class's batches.
    pub feat_hit_ratio: f64,
    /// Requests the frontend shed for this class.
    pub sheds: u64,
}

impl MetricsSnapshot {
    /// Canonical JSON encoding (sorted keys, deterministic writer —
    /// `util::json`): the machine-readable twin of
    /// [`ServingMetrics::report`].
    pub fn to_json(&self) -> Json {
        let n = |x: u64| num(x as f64);
        obj(vec![
            (
                "traffic",
                obj(vec![
                    ("requests", n(self.traffic.requests)),
                    ("seeds", n(self.traffic.seeds)),
                    ("batches", n(self.traffic.batches)),
                    ("avg_batch_seeds", num(self.traffic.avg_batch_seeds)),
                    ("p50_ms", num(self.traffic.p50_ms)),
                    ("p90_ms", num(self.traffic.p90_ms)),
                    ("p99_ms", num(self.traffic.p99_ms)),
                    ("mean_ms", num(self.traffic.mean_ms)),
                    ("throughput_seeds_per_s", num(self.traffic.throughput_seeds_per_s)),
                ]),
            ),
            (
                "stages",
                obj(vec![
                    ("sample_ms", num(self.stages.sample_ms)),
                    ("feature_ms", num(self.stages.feature_ms)),
                    ("compute_ms", num(self.stages.compute_ms)),
                ]),
            ),
            (
                "cache",
                obj(vec![
                    ("adj_hit_ratio", num(self.cache.adj_hit_ratio)),
                    ("feat_hit_ratio", num(self.cache.feat_hit_ratio)),
                    ("refreshes", n(self.cache.refreshes)),
                    ("refresh_bg_ms", num(self.cache.refresh_bg_ms)),
                    ("drift_checks", n(self.cache.drift_checks)),
                    ("swap_stalls", n(self.cache.swap_stalls)),
                ]),
            ),
            (
                "transfer",
                obj(vec![
                    ("staged_ms", num(self.transfer.staged_ms)),
                    ("hidden_ms", num(self.transfer.hidden_ms)),
                    ("occupancy", num(self.transfer.occupancy)),
                    ("leases", n(self.transfer.leases)),
                    ("overflow_allocs", n(self.transfer.overflow_allocs)),
                    ("peak_leased", n(self.transfer.peak_leased)),
                    ("fallbacks", n(self.transfer.fallbacks)),
                ]),
            ),
            (
                "tracker",
                obj(vec![
                    ("drain_ms", num(self.tracker.drain_ms)),
                    ("drained_keys", n(self.tracker.drained_keys)),
                    ("dropped_touches", n(self.tracker.dropped_touches)),
                ]),
            ),
            (
                "elastic",
                obj(vec![
                    ("rebalances", n(self.elastic.rebalances)),
                    ("moved_bytes", n(self.elastic.moved_bytes)),
                    ("auto_budget_delta", num(self.elastic.auto_budget_delta as f64)),
                ]),
            ),
            (
                "fault",
                obj(vec![
                    ("install_retries", n(self.fault.install_retries)),
                    ("backoff_ms", num(self.fault.backoff_ms)),
                    ("degrades", n(self.fault.degrades)),
                    ("repairs", n(self.fault.repairs)),
                    ("degraded_ms", num(self.fault.degraded_ms)),
                    ("watchdog_restarts", n(self.fault.watchdog_restarts)),
                    ("refresh_panics", n(self.fault.refresh_panics)),
                    ("batch_retries", n(self.fault.batch_retries)),
                    ("batch_failures", n(self.fault.batch_failures)),
                ]),
            ),
            (
                "tenants",
                Json::Arr(
                    self.tenants
                        .iter()
                        .map(|t| {
                            obj(vec![
                                ("class", s(t.class)),
                                ("requests", n(t.requests)),
                                ("seeds", n(t.seeds)),
                                ("p50_ms", num(t.p50_ms)),
                                ("p99_ms", num(t.p99_ms)),
                                ("feat_hit_ratio", num(t.feat_hit_ratio)),
                                ("sheds", n(t.sheds)),
                            ])
                        })
                        .collect(),
                ),
            ),
        ])
    }

    /// [`to_json`](Self::to_json) with a top-level `"scenario"` tag —
    /// the per-scenario metrics artifact inside a run bundle, and the
    /// row shape `ci/check_bench.py`'s scenario matrix keys on.
    pub fn to_json_for_scenario(&self, scenario_id: &str) -> Json {
        match self.to_json() {
            Json::Obj(mut m) => {
                m.insert("scenario".to_string(), s(scenario_id));
                Json::Obj(m)
            }
            other => other,
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn records_and_reports() {
        let mut m = ServingMetrics::new();
        m.record_batch(3, 100);
        m.record_batch(2, 50);
        for i in 1..=10 {
            m.record_latency(i * 1_000_000);
        }
        assert_eq!(m.requests, 5);
        assert_eq!(m.seeds, 150);
        assert_eq!(m.batches, 2);
        let rep = m.report(Duration::from_secs(1));
        assert!(rep.contains("seeds=150"));
        assert!(rep.contains("throughput=150"));
        assert!(rep.contains("swap-stalls=0"));
        assert!((m.throughput(Duration::from_secs(2)) - 75.0).abs() < 1e-9);
        assert_eq!(m.throughput(Duration::ZERO), 0.0);
    }

    #[test]
    fn merge_accumulates() {
        let mut a = ServingMetrics::new();
        a.record_batch(1, 10);
        a.record_latency(5);
        let mut b = ServingMetrics::new();
        b.record_batch(2, 20);
        b.record_latency(7);
        b.sample_ns = 3.0;
        b.transfer_staged_ns = 40.0;
        b.transfer_hidden_ns = 30.0;
        b.staging_leases = 9;
        b.staging_fresh_allocs = 2;
        b.staging_peak_leased = 5;
        b.refreshes = 2;
        b.swap_stalls = 1;
        b.shard_rebalances = 3;
        b.budget_moved_bytes = 4096;
        b.auto_budget_delta = -512;
        b.install_retries = 4;
        b.backoff_ns = 9.0;
        b.shard_degrades = 2;
        b.shard_repairs = 1;
        b.repair_ns = 11.0;
        b.watchdog_restarts = 1;
        b.refresh_panics = 1;
        b.batch_retries = 5;
        b.batch_failures = 1;
        b.cache.feature.hit(64);
        a.merge(&b);
        assert_eq!(a.requests, 3);
        assert_eq!(a.seeds, 30);
        assert_eq!(a.latency.count(), 2);
        assert_eq!(a.sample_ns, 3.0);
        assert_eq!(a.transfer_staged_ns, 40.0);
        assert_eq!(a.transfer_hidden_ns, 30.0);
        assert!((a.transfer_occupancy() - 0.75).abs() < 1e-12);
        assert_eq!(a.staging_leases, 9);
        assert_eq!(a.staging_fresh_allocs, 2);
        assert_eq!(a.staging_peak_leased, 5);
        assert_eq!(a.refreshes, 2);
        assert_eq!(a.swap_stalls, 1);
        assert_eq!(a.shard_rebalances, 3);
        assert_eq!(a.budget_moved_bytes, 4096);
        assert_eq!(a.auto_budget_delta, -512);
        assert_eq!(a.cache.feature.hits, 1);
        assert_eq!(a.install_retries, 4);
        assert_eq!(a.backoff_ns, 9.0);
        assert_eq!(a.shard_degrades, 2);
        assert_eq!(a.shard_repairs, 1);
        assert_eq!(a.repair_ns, 11.0);
        assert_eq!(a.watchdog_restarts, 1);
        assert_eq!(a.refresh_panics, 1);
        assert_eq!(a.batch_retries, 5);
        assert_eq!(a.batch_failures, 1);
        let rep = a.report(Duration::from_secs(1));
        assert!(rep.contains("occupancy=0.75") && rep.contains("peak-leased=5"), "{rep}");
        assert!(rep.contains("rebalances=3"), "{rep}");
        assert!(rep.contains("auto-budget-delta=-512"), "{rep}");
        assert!(rep.contains("degrades=2") && rep.contains("batch-fail=1"), "{rep}");
    }

    #[test]
    fn tenant_ledgers_track_per_class_slo() {
        let mut m = ServingMetrics::new();
        // a priority batch: 2 requests, 20 seeds, mostly hits
        m.record_batch(2, 20);
        m.record_tenant_batch(TenantClass::Priority, 2, 20, 90, 10);
        m.record_latency_as(TenantClass::Priority, 1_000_000);
        m.record_latency_as(TenantClass::Priority, 2_000_000);
        // a scan batch: 1 request, 40 seeds, mostly misses
        m.record_batch(1, 40);
        m.record_tenant_batch(TenantClass::Scan, 1, 40, 5, 95);
        m.record_latency_as(TenantClass::Scan, 50_000_000);
        m.record_sheds([0, 0, 7]);

        let p = &m.tenants[TenantClass::Priority.index()];
        assert_eq!(p.requests, 2);
        assert_eq!(p.seeds, 20);
        assert!((p.feat_hit_ratio() - 0.9).abs() < 1e-12);
        assert_eq!(p.sheds, 0);
        let sc = &m.tenants[TenantClass::Scan.index()];
        assert_eq!(sc.requests, 1);
        assert!((sc.feat_hit_ratio() - 0.05).abs() < 1e-12);
        assert_eq!(sc.sheds, 7);
        // standard saw nothing
        assert_eq!(m.tenants[TenantClass::Standard.index()].requests, 0);
        assert_eq!(m.tenants[TenantClass::Standard.index()].feat_hit_ratio(), 0.0);
        // the global hist saw every class's latencies
        assert_eq!(m.latency.count(), 3);

        // merge folds ledgers class-by-class
        let mut other = ServingMetrics::new();
        other.record_tenant_batch(TenantClass::Priority, 1, 5, 10, 0);
        other.record_sheds([1, 0, 0]);
        m.merge(&other);
        let p = &m.tenants[TenantClass::Priority.index()];
        assert_eq!(p.requests, 3);
        assert_eq!(p.seeds, 25);
        assert_eq!(p.sheds, 1);

        let rep = m.report(Duration::from_secs(1));
        assert!(rep.contains("tenant: priority"), "{rep}");
        assert!(rep.contains("shed=7"), "{rep}");
    }

    #[test]
    fn snapshot_json_is_canonical_and_complete() {
        let mut m = ServingMetrics::new();
        m.record_batch(4, 100);
        m.record_tenant_batch(TenantClass::Standard, 4, 100, 75, 25);
        for _ in 0..4 {
            m.record_latency_as(TenantClass::Standard, 3_000_000);
        }
        m.shard_rebalances = 2;
        m.budget_moved_bytes = 1 << 20;
        m.auto_budget_delta = -256;
        m.batch_retries = 1;

        let snap = m.snapshot(Duration::from_secs(2));
        assert_eq!(snap.traffic.requests, 4);
        assert!((snap.traffic.throughput_seeds_per_s - 50.0).abs() < 1e-9);
        assert_eq!(snap.tenants[TenantClass::Standard.index()].seeds, 100);
        assert!(
            (snap.tenants[TenantClass::Standard.index()].feat_hit_ratio - 0.75).abs() < 1e-12
        );

        // the JSON encoding round-trips and exposes every group
        let text = snap.to_json().to_string();
        let parsed = Json::parse(&text).unwrap();
        for group in ["traffic", "stages", "cache", "transfer", "tracker", "elastic", "fault"] {
            assert!(parsed.get(group).is_some(), "missing {group} in {text}");
        }
        assert_eq!(parsed.req("traffic").unwrap().req("requests").unwrap().as_u64().unwrap(), 4);
        assert_eq!(
            parsed.req("elastic").unwrap().req("auto_budget_delta").unwrap().as_f64().unwrap(),
            -256.0
        );
        let tenants = parsed.req("tenants").unwrap().as_arr().unwrap();
        assert_eq!(tenants.len(), N_CLASSES);
        assert_eq!(tenants[0].req("class").unwrap().as_str().unwrap(), "priority");
        assert_eq!(tenants[1].req("seeds").unwrap().as_u64().unwrap(), 100);
        // canonical: serializing the parsed value reproduces the text
        assert_eq!(parsed.to_string(), text);

        // the human report renders the same snapshot (thin-view check)
        let rep = m.report(Duration::from_secs(2));
        assert!(rep.contains("throughput=50"), "{rep}");
        assert!(rep.contains("tenant: priority"), "{rep}");
    }

    #[test]
    fn scenario_tagged_snapshot_json() {
        let mut m = ServingMetrics::new();
        m.record_batch(2, 50);
        let snap = m.snapshot(Duration::from_secs(1));
        let tagged = snap.to_json_for_scenario("flash_crowd");
        assert_eq!(tagged.req("scenario").unwrap().as_str().unwrap(), "flash_crowd");
        // the tag is additive: every group of the untagged encoding is
        // still present, and the encoding stays canonical
        let plain = snap.to_json();
        for group in ["traffic", "stages", "cache", "tenants"] {
            assert!(tagged.get(group).is_some(), "missing {group}");
            assert_eq!(tagged.get(group), plain.get(group));
        }
        let text = tagged.to_string();
        assert_eq!(Json::parse(&text).unwrap().to_string(), text);
    }
}
