//! Serving metrics: request latency distribution, batch sizes, seed
//! throughput, live cache hit ratios, and the online-refresh /
//! snapshot-swap counters — the numbers the end-to-end example and the
//! cache-runtime bench report.

use std::time::Duration;

use crate::cache::CacheStats;
use crate::util::stats::LatencyHist;

/// Accumulated serving-side metrics (one per worker; merged at report
/// time).
#[derive(Debug, Clone, Default)]
pub struct ServingMetrics {
    /// Client requests served.
    pub requests: u64,
    /// Seed nodes served across all requests.
    pub seeds: u64,
    /// Engine batches executed.
    pub batches: u64,
    /// Request latency distribution (submit → reply).
    pub latency: LatencyHist,
    /// Sampling-stage total (ns, wall + modeled).
    pub sample_ns: f64,
    /// Feature-stage total (ns, wall + modeled).
    pub feature_ns: f64,
    /// Compute-stage total (ns, wall + modeled).
    pub compute_ns: f64,
    /// Modeled staged-H2D time shipped through the transfer ring, ns
    /// (zero with `transfer-ring=0`; see DESIGN.md §Transfer engine).
    pub transfer_staged_ns: f64,
    /// Portion of `transfer_staged_ns` the ring hid under compute, ns.
    pub transfer_hidden_ns: f64,
    /// Staging-buffer leases handed out across workers (serving +
    /// refresh refills).
    pub staging_leases: u64,
    /// Leases the pinned pools could not serve (overflow allocations —
    /// persistent nonzero values mean `staging-buffers` is too small).
    pub staging_fresh_allocs: u64,
    /// High-water mark of concurrently leased staging buffers on any
    /// one worker.
    pub staging_peak_leased: u64,
    /// Serving-time transfer stats (per-batch ledgers folded in:
    /// live hit ratios, plus online-refresh refill traffic).
    pub cache: CacheStats,
    /// Re-plans the refresh loop installed.
    pub refreshes: u64,
    /// Drift checks the refresh loop evaluated.
    pub drift_checks: u64,
    /// Background wall time spent re-planning, ns (never on the
    /// serving path).
    pub refresh_ns: f64,
    /// Snapshot acquires that had to block on a concurrent install
    /// (the runtime's swap-stall counter; 0 in a healthy deployment).
    pub swap_stalls: u64,
    /// Background wall time the refresh loop spent draining the
    /// workload tracker and folding windows into the decayed profile,
    /// ns — the cost `tracker=sketch` shrinks from O(nodes + edges) to
    /// O(touched) per poll.
    pub tracker_drain_ns: f64,
    /// Sparse keys (nodes + CSC elements) drained across all windows.
    pub tracker_drained_keys: u64,
    /// Touches the tracker's bounded touched set could not enumerate
    /// (sketch only; persistent nonzero values mean the drain interval
    /// is too long for the traffic — shorten `refresh-check-ms`).
    pub tracker_dropped_touches: u64,
    /// Cross-shard budget re-split events applied by the refresh loop
    /// (`rebalance=on`; see DESIGN.md §Elastic budgets).
    pub shard_rebalances: u64,
    /// Σ bytes gained by growing shards across all re-splits — the
    /// cache capacity that actually moved between devices.
    pub budget_moved_bytes: u64,
    /// Final global budget minus the startup global budget, summed
    /// over workers (nonzero only with `auto-budget-refresh=on` on a
    /// `budget=auto` run).
    pub auto_budget_delta: i64,
    /// Shard installs retried after a transient device-claim or
    /// transfer failure (each retry waits out one backoff pause).
    pub install_retries: u64,
    /// Background wall time spent in install retry backoff, ns (never
    /// on the serving path).
    pub backoff_ns: f64,
    /// Shards that entered degraded (host-memory fallback) mode after
    /// an install failed terminally.
    pub shard_degrades: u64,
    /// Degraded shards the background repair loop promoted back to a
    /// healthy device-resident cache.
    pub shard_repairs: u64,
    /// Σ wall time shards spent degraded before repair, ns.
    pub repair_ns: f64,
    /// Refresh-loop generations the watchdog respawned (after a panic
    /// or a hang past `watchdog-ms`).
    pub watchdog_restarts: u64,
    /// Refresh-loop panics the watchdog absorbed.
    pub refresh_panics: u64,
    /// Serving batches retried after an isolated panic (the retry
    /// replays the identical request; see DESIGN.md §Fault tolerance).
    pub batch_retries: u64,
    /// Serving batches that failed after the one retry (clients got an
    /// error response; the worker kept serving).
    pub batch_failures: u64,
}

impl ServingMetrics {
    /// Zeroed metrics.
    pub fn new() -> Self {
        Self::default()
    }

    /// Count one served batch of `n_requests` requests / `n_seeds`
    /// seeds.
    pub fn record_batch(&mut self, n_requests: usize, n_seeds: usize) {
        self.batches += 1;
        self.requests += n_requests as u64;
        self.seeds += n_seeds as u64;
    }

    /// Record one request's end-to-end latency.
    pub fn record_latency(&mut self, ns: u64) {
        self.latency.record_ns(ns);
    }

    /// Fold another worker's metrics into this one.
    pub fn merge(&mut self, other: &ServingMetrics) {
        self.requests += other.requests;
        self.seeds += other.seeds;
        self.batches += other.batches;
        self.latency.merge(&other.latency);
        self.sample_ns += other.sample_ns;
        self.feature_ns += other.feature_ns;
        self.compute_ns += other.compute_ns;
        self.transfer_staged_ns += other.transfer_staged_ns;
        self.transfer_hidden_ns += other.transfer_hidden_ns;
        self.staging_leases += other.staging_leases;
        self.staging_fresh_allocs += other.staging_fresh_allocs;
        self.staging_peak_leased = self.staging_peak_leased.max(other.staging_peak_leased);
        self.cache.merge(&other.cache);
        self.refreshes += other.refreshes;
        self.drift_checks += other.drift_checks;
        self.refresh_ns += other.refresh_ns;
        self.swap_stalls += other.swap_stalls;
        self.tracker_drain_ns += other.tracker_drain_ns;
        self.tracker_drained_keys += other.tracker_drained_keys;
        self.tracker_dropped_touches += other.tracker_dropped_touches;
        self.shard_rebalances += other.shard_rebalances;
        self.budget_moved_bytes += other.budget_moved_bytes;
        self.auto_budget_delta += other.auto_budget_delta;
        self.install_retries += other.install_retries;
        self.backoff_ns += other.backoff_ns;
        self.shard_degrades += other.shard_degrades;
        self.shard_repairs += other.shard_repairs;
        self.repair_ns += other.repair_ns;
        self.watchdog_restarts += other.watchdog_restarts;
        self.refresh_panics += other.refresh_panics;
        self.batch_retries += other.batch_retries;
        self.batch_failures += other.batch_failures;
    }

    /// Fraction of staged-H2D time the transfer ring hid under compute
    /// (0.0 when nothing staged; the overlap bench gates this).
    pub fn transfer_occupancy(&self) -> f64 {
        if self.transfer_staged_ns <= 0.0 {
            0.0
        } else {
            self.transfer_hidden_ns / self.transfer_staged_ns
        }
    }

    /// Seeds served per second of elapsed wall time.
    pub fn throughput(&self, elapsed: Duration) -> f64 {
        if elapsed.is_zero() {
            0.0
        } else {
            self.seeds as f64 / elapsed.as_secs_f64()
        }
    }

    /// Multi-line human report.
    pub fn report(&self, elapsed: Duration) -> String {
        let (p50, p90, p99) = self.latency.quantiles_ns();
        format!(
            "requests={} seeds={} batches={} (avg batch {:.1} seeds)\n\
             latency p50={:.2}ms p90={:.2}ms p99={:.2}ms mean={:.2}ms\n\
             throughput={:.0} seeds/s\n\
             stage totals: sample={:.1}ms feature={:.1}ms compute={:.1}ms\n\
             cache: adj-hit={:.3} feat-hit={:.3} refreshes={} (bg {:.1}ms, {} checks) swap-stalls={}\n\
             transfer: staged={:.2}ms hidden={:.2}ms occupancy={:.2} \
             leases={} overflow={} peak-leased={} fallbacks={}\n\
             tracker: drain={:.2}ms drained-keys={} dropped-touches={}\n\
             elastic: rebalances={} moved={} auto-budget-delta={}\n\
             fault: retries={} backoff={:.1}ms degrades={} repairs={} ({:.1}ms degraded) \
             watchdog={} panics={} batch-retry={} batch-fail={}",
            self.requests,
            self.seeds,
            self.batches,
            self.seeds as f64 / self.batches.max(1) as f64,
            p50 / 1e6,
            p90 / 1e6,
            p99 / 1e6,
            self.latency.mean_ns() / 1e6,
            self.throughput(elapsed),
            self.sample_ns / 1e6,
            self.feature_ns / 1e6,
            self.compute_ns / 1e6,
            self.cache.adj_hit_ratio(),
            self.cache.feat_hit_ratio(),
            self.refreshes,
            self.refresh_ns / 1e6,
            self.drift_checks,
            self.swap_stalls,
            self.transfer_staged_ns / 1e6,
            self.transfer_hidden_ns / 1e6,
            self.transfer_occupancy(),
            self.staging_leases,
            self.staging_fresh_allocs,
            self.staging_peak_leased,
            self.cache.feature.staged_fallbacks,
            self.tracker_drain_ns / 1e6,
            self.tracker_drained_keys,
            self.tracker_dropped_touches,
            self.shard_rebalances,
            crate::util::format_bytes(self.budget_moved_bytes),
            self.auto_budget_delta,
            self.install_retries,
            self.backoff_ns / 1e6,
            self.shard_degrades,
            self.shard_repairs,
            self.repair_ns / 1e6,
            self.watchdog_restarts,
            self.refresh_panics,
            self.batch_retries,
            self.batch_failures,
        )
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn records_and_reports() {
        let mut m = ServingMetrics::new();
        m.record_batch(3, 100);
        m.record_batch(2, 50);
        for i in 1..=10 {
            m.record_latency(i * 1_000_000);
        }
        assert_eq!(m.requests, 5);
        assert_eq!(m.seeds, 150);
        assert_eq!(m.batches, 2);
        let rep = m.report(Duration::from_secs(1));
        assert!(rep.contains("seeds=150"));
        assert!(rep.contains("throughput=150"));
        assert!(rep.contains("swap-stalls=0"));
        assert!((m.throughput(Duration::from_secs(2)) - 75.0).abs() < 1e-9);
        assert_eq!(m.throughput(Duration::ZERO), 0.0);
    }

    #[test]
    fn merge_accumulates() {
        let mut a = ServingMetrics::new();
        a.record_batch(1, 10);
        a.record_latency(5);
        let mut b = ServingMetrics::new();
        b.record_batch(2, 20);
        b.record_latency(7);
        b.sample_ns = 3.0;
        b.transfer_staged_ns = 40.0;
        b.transfer_hidden_ns = 30.0;
        b.staging_leases = 9;
        b.staging_fresh_allocs = 2;
        b.staging_peak_leased = 5;
        b.refreshes = 2;
        b.swap_stalls = 1;
        b.shard_rebalances = 3;
        b.budget_moved_bytes = 4096;
        b.auto_budget_delta = -512;
        b.install_retries = 4;
        b.backoff_ns = 9.0;
        b.shard_degrades = 2;
        b.shard_repairs = 1;
        b.repair_ns = 11.0;
        b.watchdog_restarts = 1;
        b.refresh_panics = 1;
        b.batch_retries = 5;
        b.batch_failures = 1;
        b.cache.feature.hit(64);
        a.merge(&b);
        assert_eq!(a.requests, 3);
        assert_eq!(a.seeds, 30);
        assert_eq!(a.latency.count(), 2);
        assert_eq!(a.sample_ns, 3.0);
        assert_eq!(a.transfer_staged_ns, 40.0);
        assert_eq!(a.transfer_hidden_ns, 30.0);
        assert!((a.transfer_occupancy() - 0.75).abs() < 1e-12);
        assert_eq!(a.staging_leases, 9);
        assert_eq!(a.staging_fresh_allocs, 2);
        assert_eq!(a.staging_peak_leased, 5);
        assert_eq!(a.refreshes, 2);
        assert_eq!(a.swap_stalls, 1);
        assert_eq!(a.shard_rebalances, 3);
        assert_eq!(a.budget_moved_bytes, 4096);
        assert_eq!(a.auto_budget_delta, -512);
        assert_eq!(a.cache.feature.hits, 1);
        assert_eq!(a.install_retries, 4);
        assert_eq!(a.backoff_ns, 9.0);
        assert_eq!(a.shard_degrades, 2);
        assert_eq!(a.shard_repairs, 1);
        assert_eq!(a.repair_ns, 11.0);
        assert_eq!(a.watchdog_restarts, 1);
        assert_eq!(a.refresh_panics, 1);
        assert_eq!(a.batch_retries, 5);
        assert_eq!(a.batch_failures, 1);
        let rep = a.report(Duration::from_secs(1));
        assert!(rep.contains("occupancy=0.75") && rep.contains("peak-leased=5"), "{rep}");
        assert!(rep.contains("rebalances=3"), "{rep}");
        assert!(rep.contains("auto-budget-delta=-512"), "{rep}");
        assert!(rep.contains("degrades=2") && rep.contains("batch-fail=1"), "{rep}");
    }
}
