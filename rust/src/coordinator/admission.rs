//! Admission control + backpressure for the serving frontend.
//!
//! The engine's throughput is bounded by mini-batch preparation; when
//! clients outrun it, unbounded queues turn into unbounded latency. The
//! [`AdmissionController`] enforces (a) a queued-seed ceiling (hard
//! backpressure — reject with `Overloaded` so clients can retry with
//! jitter) and (b) an optional per-client token bucket (rate limit).
//!
//! Every request also carries a [`TenantClass`] (`priority` /
//! `standard` / `scan`), derived from the client identity at admission
//! time. The class travels with the request through the batcher, the
//! engine's tracker records, the refresh loop's per-class profiles, and
//! the per-tenant metric ledgers — see DESIGN.md §Multi-tenant QoS.
//! Under overload the controller sheds classes in QoS order: `scan`
//! hits its (lower) queue ceiling first, `standard` next, `priority`
//! last — so a drive-by scan tenant is turned away before it can queue
//! behind paying traffic.

use std::collections::HashMap;
use std::sync::atomic::{AtomicU64, Ordering};
use std::sync::Mutex;
use std::time::Instant;

use thiserror::Error;

/// Number of admission classes ([`TenantClass`] variants). Class-keyed
/// arrays throughout the stack (tracker strides, refresh profiles,
/// planner weights, metric ledgers) are sized by this constant.
pub const N_CLASSES: usize = 3;

/// The admission class a request is served under. Classes change *what
/// is cached* (tracker weighting, shed order) — never *what is
/// computed*: logits are bit-identical to class-blind serving.
#[derive(Debug, Clone, Copy, PartialEq, Eq, Hash, Default)]
pub enum TenantClass {
    /// Paying interactive traffic: highest cache weight, sheds last.
    Priority,
    /// Unlabelled traffic (the pre-tenancy behavior).
    #[default]
    Standard,
    /// Bulk / drive-by scans: near-zero cache weight, sheds first.
    Scan,
}

impl TenantClass {
    /// All classes in QoS order (highest first).
    pub const ALL: [TenantClass; N_CLASSES] =
        [TenantClass::Priority, TenantClass::Standard, TenantClass::Scan];

    /// Parse `priority` | `standard` | `scan`.
    pub fn parse(s: &str) -> anyhow::Result<Self> {
        match s.to_ascii_lowercase().as_str() {
            "priority" | "p" => Ok(TenantClass::Priority),
            "standard" | "s" => Ok(TenantClass::Standard),
            "scan" | "c" => Ok(TenantClass::Scan),
            other => anyhow::bail!("unknown tenant class {other:?} (priority|standard|scan)"),
        }
    }

    /// Canonical name (`priority` | `standard` | `scan`).
    pub fn as_str(&self) -> &'static str {
        match self {
            TenantClass::Priority => "priority",
            TenantClass::Standard => "standard",
            TenantClass::Scan => "scan",
        }
    }

    /// Stable index into class-keyed arrays (`0..`[`N_CLASSES`]).
    #[inline]
    pub fn index(&self) -> usize {
        match self {
            TenantClass::Priority => 0,
            TenantClass::Standard => 1,
            TenantClass::Scan => 2,
        }
    }

    /// Derive the class from a client identity: a `priority:` /
    /// `standard:` / `scan:` prefix names the class (`"scan:crawler"`
    /// → [`TenantClass::Scan`]); anything else — including every
    /// pre-tenancy client string — is [`TenantClass::Standard`].
    pub fn of_client(client: &str) -> TenantClass {
        match client.split_once(':') {
            Some((prefix, _)) => Self::parse(prefix).unwrap_or(TenantClass::Standard),
            None => TenantClass::Standard,
        }
    }
}

impl std::fmt::Display for TenantClass {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        f.write_str(self.as_str())
    }
}

/// Why a request was not admitted.
#[derive(Debug, Error, Clone, PartialEq)]
pub enum AdmissionError {
    /// The global queued-seed ceiling would be exceeded (backpressure;
    /// retry with jittered backoff).
    #[error("overloaded: {queued} seeds queued (limit {limit}); retry with backoff")]
    Overloaded {
        /// Seeds queued across all workers at rejection time.
        queued: usize,
        /// The configured ceiling.
        limit: usize,
    },
    /// The request's class hit its (reduced) share of the queue ceiling
    /// while higher classes still fit — class-aware load shedding.
    #[error(
        "shed: class {class} over its queue share ({queued} queued, class limit {limit}); \
         retry with backoff or upgrade the class"
    )]
    Shed {
        /// The shed request's admission class.
        class: TenantClass,
        /// Seeds queued across all workers at rejection time.
        queued: usize,
        /// The class's effective queue ceiling.
        limit: usize,
    },
    /// The client's token bucket ran dry (per-client rate limit).
    #[error("rate limited: client {client:?} exceeded {rate_per_s:.0} seeds/s")]
    RateLimited {
        /// The rate-limited client identity.
        client: String,
        /// The configured sustained rate.
        rate_per_s: f64,
    },
}

/// Admission policy knobs.
#[derive(Debug, Clone)]
pub struct AdmissionConfig {
    /// Hard ceiling on queued seeds across all workers.
    pub max_queued_seeds: usize,
    /// Optional per-client sustained rate (seeds/second) + burst.
    pub per_client_rate: Option<(f64, f64)>,
    /// Per-class fraction of `max_queued_seeds` the class may occupy
    /// (indexed by [`TenantClass::index`]). A fraction below 1.0 sheds
    /// that class before the global ceiling is reached; the defaults
    /// (`[1.0, 1.0, 0.5]`) shed only `scan`, leaving pre-tenancy
    /// admission behavior untouched for everyone else.
    pub class_queue_fraction: [f64; N_CLASSES],
}

impl Default for AdmissionConfig {
    fn default() -> Self {
        AdmissionConfig {
            max_queued_seeds: 100_000,
            per_client_rate: None,
            class_queue_fraction: [1.0, 1.0, 0.5],
        }
    }
}

/// Token bucket state for one client.
#[derive(Debug, Clone)]
struct Bucket {
    tokens: f64,
    last: Instant,
}

/// Thread-safe admission controller (shared by submitters).
pub struct AdmissionController {
    cfg: AdmissionConfig,
    buckets: Mutex<HashMap<String, Bucket>>,
    /// Seeds rejected at a queue ceiling, per class (shed ledger).
    sheds: [AtomicU64; N_CLASSES],
}

impl AdmissionController {
    /// A controller enforcing `cfg` (no per-client state yet).
    pub fn new(cfg: AdmissionConfig) -> Self {
        AdmissionController {
            cfg,
            buckets: Mutex::new(HashMap::new()),
            sheds: Default::default(),
        }
    }

    /// Decide whether a request of `n_seeds` from `client` may enter,
    /// given the current total queue depth. The class is derived from
    /// the client identity ([`TenantClass::of_client`]).
    pub fn admit(
        &self,
        client: &str,
        n_seeds: usize,
        queued_seeds: usize,
    ) -> Result<TenantClass, AdmissionError> {
        let class = TenantClass::of_client(client);
        self.admit_as(client, class, n_seeds, queued_seeds)?;
        Ok(class)
    }

    /// [`AdmissionController::admit`] with an explicit class (the
    /// server's tagged submission path).
    pub fn admit_as(
        &self,
        client: &str,
        class: TenantClass,
        n_seeds: usize,
        queued_seeds: usize,
    ) -> Result<(), AdmissionError> {
        let frac = self.cfg.class_queue_fraction[class.index()].clamp(0.0, 1.0);
        let class_limit = (self.cfg.max_queued_seeds as f64 * frac) as usize;
        if queued_seeds + n_seeds > class_limit {
            self.sheds[class.index()].fetch_add(1, Ordering::Relaxed);
            // a reduced ceiling is a class shed; the full ceiling is
            // plain overload (identical to pre-tenancy behavior)
            return Err(if class_limit < self.cfg.max_queued_seeds {
                AdmissionError::Shed { class, queued: queued_seeds, limit: class_limit }
            } else {
                AdmissionError::Overloaded {
                    queued: queued_seeds,
                    limit: self.cfg.max_queued_seeds,
                }
            });
        }
        if let Some((rate, burst)) = self.cfg.per_client_rate {
            // recoverable on poison: a bucket is always internally
            // consistent (tokens + stamp updated under one guard)
            let mut buckets = crate::util::lock_unpoisoned(&self.buckets);
            let now = Instant::now();
            let b = buckets.entry(client.to_string()).or_insert(Bucket {
                tokens: burst,
                last: now,
            });
            let dt = now.duration_since(b.last).as_secs_f64();
            b.tokens = (b.tokens + dt * rate).min(burst);
            b.last = now;
            if b.tokens < n_seeds as f64 {
                return Err(AdmissionError::RateLimited {
                    client: client.to_string(),
                    rate_per_s: rate,
                });
            }
            b.tokens -= n_seeds as f64;
        }
        Ok(())
    }

    /// Requests rejected at a queue ceiling since startup, per class
    /// (indexed by [`TenantClass::index`]).
    pub fn shed_counts(&self) -> [u64; N_CLASSES] {
        let mut out = [0u64; N_CLASSES];
        for (o, c) in out.iter_mut().zip(self.sheds.iter()) {
            *o = c.load(Ordering::Relaxed);
        }
        out
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn rejects_over_queue_ceiling() {
        let ac = AdmissionController::new(AdmissionConfig {
            max_queued_seeds: 100,
            ..AdmissionConfig::default()
        });
        assert!(ac.admit("a", 50, 0).is_ok());
        assert!(ac.admit("a", 50, 50).is_ok());
        let err = ac.admit("a", 51, 50).unwrap_err();
        assert!(matches!(err, AdmissionError::Overloaded { .. }));
        assert!(err.to_string().contains("retry with backoff"));
    }

    #[test]
    fn token_bucket_limits_burst_then_refills() {
        let ac = AdmissionController::new(AdmissionConfig {
            max_queued_seeds: usize::MAX,
            per_client_rate: Some((1000.0, 100.0)), // 1000/s, burst 100
            ..AdmissionConfig::default()
        });
        // burst of 100 admitted
        assert!(ac.admit("c1", 100, 0).is_ok());
        // next request rejected (bucket drained)
        assert!(matches!(
            ac.admit("c1", 50, 0),
            Err(AdmissionError::RateLimited { .. })
        ));
        // other clients unaffected
        assert!(ac.admit("c2", 100, 0).is_ok());
        // refill after 60ms -> ~60 tokens
        std::thread::sleep(std::time::Duration::from_millis(60));
        assert!(ac.admit("c1", 40, 0).is_ok());
    }

    #[test]
    fn zero_seed_requests_always_admitted() {
        let ac = AdmissionController::new(AdmissionConfig::default());
        assert!(ac.admit("x", 0, 0).is_ok());
    }

    #[test]
    fn class_derives_from_client_prefix() {
        assert_eq!(TenantClass::of_client("priority:acme"), TenantClass::Priority);
        assert_eq!(TenantClass::of_client("scan:crawler"), TenantClass::Scan);
        assert_eq!(TenantClass::of_client("standard:web"), TenantClass::Standard);
        // no prefix, unknown prefix, and the pre-tenancy default are
        // all standard
        assert_eq!(TenantClass::of_client("anonymous"), TenantClass::Standard);
        assert_eq!(TenantClass::of_client("svc:etl"), TenantClass::Standard);
        assert_eq!(TenantClass::default(), TenantClass::Standard);
        // parse/as_str round-trips; index is a permutation of 0..N
        let mut seen = [false; N_CLASSES];
        for c in TenantClass::ALL {
            assert_eq!(TenantClass::parse(c.as_str()).unwrap(), c);
            assert_eq!(format!("{c}"), c.as_str());
            seen[c.index()] = true;
        }
        assert!(seen.iter().all(|&s| s));
        assert!(TenantClass::parse("vip").is_err());
    }

    #[test]
    fn scan_sheds_before_standard_and_priority() {
        let ac = AdmissionController::new(AdmissionConfig {
            max_queued_seeds: 100,
            ..AdmissionConfig::default()
        });
        // at 60 queued seeds: scan (limit 50) sheds, others still admit
        let err = ac.admit("scan:bot", 10, 60).unwrap_err();
        assert!(
            matches!(err, AdmissionError::Shed { class: TenantClass::Scan, limit: 50, .. }),
            "{err:?}"
        );
        assert!(err.to_string().contains("class scan"));
        assert!(ac.admit("standard:web", 10, 60).is_ok());
        assert!(ac.admit("priority:acme", 10, 60).is_ok());
        // past the global ceiling everyone is rejected, priority with
        // plain Overloaded (it never "sheds early")
        let err = ac.admit("priority:acme", 10, 95).unwrap_err();
        assert!(matches!(err, AdmissionError::Overloaded { .. }));
        // the shed ledger attributed both rejections to their classes
        let sheds = ac.shed_counts();
        assert_eq!(sheds[TenantClass::Scan.index()], 1);
        assert_eq!(sheds[TenantClass::Priority.index()], 1);
        assert_eq!(sheds[TenantClass::Standard.index()], 0);
    }

    #[test]
    fn shed_order_follows_queue_fractions() {
        // a config that staggers all three ceilings sheds strictly in
        // QoS order as the queue grows
        let ac = AdmissionController::new(AdmissionConfig {
            max_queued_seeds: 100,
            per_client_rate: None,
            class_queue_fraction: [1.0, 0.8, 0.3],
        });
        let admits = |queued: usize| -> Vec<&'static str> {
            TenantClass::ALL
                .iter()
                .filter(|c| ac.admit_as("t", **c, 1, queued).is_ok())
                .map(|c| c.as_str())
                .collect()
        };
        assert_eq!(admits(10), vec!["priority", "standard", "scan"]);
        assert_eq!(admits(50), vec!["priority", "standard"]);
        assert_eq!(admits(90), vec!["priority"]);
        assert!(admits(100).is_empty());
    }
}
