//! Admission control + backpressure for the serving frontend.
//!
//! The engine's throughput is bounded by mini-batch preparation; when
//! clients outrun it, unbounded queues turn into unbounded latency. The
//! [`AdmissionController`] enforces (a) a queued-seed ceiling (hard
//! backpressure — reject with `Overloaded` so clients can retry with
//! jitter) and (b) an optional per-client token bucket (rate limit).

use std::collections::HashMap;
use std::sync::Mutex;
use std::time::Instant;

use thiserror::Error;

/// Why a request was not admitted.
#[derive(Debug, Error, Clone, PartialEq)]
pub enum AdmissionError {
    /// The global queued-seed ceiling would be exceeded (backpressure;
    /// retry with jittered backoff).
    #[error("overloaded: {queued} seeds queued (limit {limit}); retry with backoff")]
    Overloaded {
        /// Seeds queued across all workers at rejection time.
        queued: usize,
        /// The configured ceiling.
        limit: usize,
    },
    /// The client's token bucket ran dry (per-client rate limit).
    #[error("rate limited: client {client:?} exceeded {rate_per_s:.0} seeds/s")]
    RateLimited {
        /// The rate-limited client identity.
        client: String,
        /// The configured sustained rate.
        rate_per_s: f64,
    },
}

/// Admission policy knobs.
#[derive(Debug, Clone)]
pub struct AdmissionConfig {
    /// Hard ceiling on queued seeds across all workers.
    pub max_queued_seeds: usize,
    /// Optional per-client sustained rate (seeds/second) + burst.
    pub per_client_rate: Option<(f64, f64)>,
}

impl Default for AdmissionConfig {
    fn default() -> Self {
        AdmissionConfig { max_queued_seeds: 100_000, per_client_rate: None }
    }
}

/// Token bucket state for one client.
#[derive(Debug, Clone)]
struct Bucket {
    tokens: f64,
    last: Instant,
}

/// Thread-safe admission controller (shared by submitters).
pub struct AdmissionController {
    cfg: AdmissionConfig,
    buckets: Mutex<HashMap<String, Bucket>>,
}

impl AdmissionController {
    /// A controller enforcing `cfg` (no per-client state yet).
    pub fn new(cfg: AdmissionConfig) -> Self {
        AdmissionController { cfg, buckets: Mutex::new(HashMap::new()) }
    }

    /// Decide whether a request of `n_seeds` from `client` may enter,
    /// given the current total queue depth.
    pub fn admit(
        &self,
        client: &str,
        n_seeds: usize,
        queued_seeds: usize,
    ) -> Result<(), AdmissionError> {
        if queued_seeds + n_seeds > self.cfg.max_queued_seeds {
            return Err(AdmissionError::Overloaded {
                queued: queued_seeds,
                limit: self.cfg.max_queued_seeds,
            });
        }
        if let Some((rate, burst)) = self.cfg.per_client_rate {
            // recoverable on poison: a bucket is always internally
            // consistent (tokens + stamp updated under one guard)
            let mut buckets = crate::util::lock_unpoisoned(&self.buckets);
            let now = Instant::now();
            let b = buckets.entry(client.to_string()).or_insert(Bucket {
                tokens: burst,
                last: now,
            });
            let dt = now.duration_since(b.last).as_secs_f64();
            b.tokens = (b.tokens + dt * rate).min(burst);
            b.last = now;
            if b.tokens < n_seeds as f64 {
                return Err(AdmissionError::RateLimited {
                    client: client.to_string(),
                    rate_per_s: rate,
                });
            }
            b.tokens -= n_seeds as f64;
        }
        Ok(())
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn rejects_over_queue_ceiling() {
        let ac = AdmissionController::new(AdmissionConfig {
            max_queued_seeds: 100,
            per_client_rate: None,
        });
        assert!(ac.admit("a", 50, 0).is_ok());
        assert!(ac.admit("a", 50, 50).is_ok());
        let err = ac.admit("a", 51, 50).unwrap_err();
        assert!(matches!(err, AdmissionError::Overloaded { .. }));
        assert!(err.to_string().contains("retry with backoff"));
    }

    #[test]
    fn token_bucket_limits_burst_then_refills() {
        let ac = AdmissionController::new(AdmissionConfig {
            max_queued_seeds: usize::MAX,
            per_client_rate: Some((1000.0, 100.0)), // 1000/s, burst 100
        });
        // burst of 100 admitted
        assert!(ac.admit("c1", 100, 0).is_ok());
        // next request rejected (bucket drained)
        assert!(matches!(
            ac.admit("c1", 50, 0),
            Err(AdmissionError::RateLimited { .. })
        ));
        // other clients unaffected
        assert!(ac.admit("c2", 100, 0).is_ok());
        // refill after 60ms -> ~60 tokens
        std::thread::sleep(std::time::Duration::from_millis(60));
        assert!(ac.admit("c1", 40, 0).is_ok());
    }

    #[test]
    fn zero_seed_requests_always_admitted() {
        let ac = AdmissionController::new(AdmissionConfig::default());
        assert!(ac.admit("x", 0, 0).is_ok());
    }
}
