//! Serving coordinator: request router → dynamic batcher → worker
//! threads running the DCI engine → latency/throughput metrics.
//!
//! This is the L3 deployment surface: clients submit node-id inference
//! requests; the batcher coalesces them into mini-batches (size- or
//! timeout-triggered, vLLM-router style); each worker owns a full
//! [`crate::engine::InferenceEngine`] (its own caches + PJRT
//! executables) and serves batches off an mpsc queue. std threads —
//! the offline registry has no tokio, and the workload is CPU-bound
//! anyway.

// Deployment surface: fully documented, gated by the CI `cargo doc`
// step (`RUSTDOCFLAGS="-D warnings"`).
#![warn(missing_docs)]

pub mod admission;
pub mod batcher;
pub mod metrics;
pub mod router;
pub mod server;

pub use admission::{
    AdmissionConfig, AdmissionController, AdmissionError, TenantClass, N_CLASSES,
};
pub use batcher::{Batcher, BatcherConfig};
pub use metrics::{MetricsSnapshot, ServingMetrics, TenantLedger, TenantSnapshot};
pub use router::Router;
pub use server::{Server, ServerConfig};

use crate::graph::NodeId;
use std::sync::mpsc;
use std::time::Instant;

/// One client inference request.
pub struct Request {
    /// Nodes to classify.
    pub nodes: Vec<NodeId>,
    /// Admission class assigned at submit time (batching lane, tracker
    /// tagging, metric ledger). Classes never change the computed
    /// logits — only what the cache layer learns from the request.
    pub class: TenantClass,
    /// Submission time (latency measurement).
    pub submitted: Instant,
    /// Where the response goes.
    pub reply: mpsc::Sender<Response>,
}

/// The served answer.
#[derive(Debug, Clone)]
pub struct Response {
    /// Logits, `[n_nodes, classes]` row-major (None when compute=skip).
    pub logits: Option<Vec<f32>>,
    /// End-to-end latency (submit → reply).
    pub latency_ns: u64,
    /// Batch the request was served in (observability).
    pub batch_id: u64,
    /// `Some(reason)` when the batch failed after its one panic-retry:
    /// `logits` is `None` and the request should be resubmitted. The
    /// worker itself keeps serving (see DESIGN.md §Fault tolerance).
    pub error: Option<String>,
}
