//! Dynamic batcher: coalesces client requests into engine-sized
//! mini-batches. Flush triggers: (a) pending seed count reaches
//! `batch_size`, (b) the oldest pending request exceeds `max_wait`.

use std::time::{Duration, Instant};

use crate::graph::NodeId;

use super::Request;

/// Batching policy knobs.
#[derive(Debug, Clone)]
pub struct BatcherConfig {
    /// Seed count that triggers an immediate flush.
    pub batch_size: usize,
    /// Oldest-request age that forces a flush.
    pub max_wait: Duration,
}

impl Default for BatcherConfig {
    fn default() -> Self {
        BatcherConfig { batch_size: 256, max_wait: Duration::from_millis(5) }
    }
}

/// A flushed batch: concatenated seeds + the requests (with their seed
/// spans) it serves.
pub struct PendingBatch {
    /// All member requests' seeds, concatenated in arrival order.
    pub seeds: Vec<NodeId>,
    /// (request, start, len) spans into `seeds`.
    pub members: Vec<(Request, usize, usize)>,
}

/// Accumulates requests until a flush trigger fires.
pub struct Batcher {
    cfg: BatcherConfig,
    seeds: Vec<NodeId>,
    members: Vec<(Request, usize, usize)>,
    oldest: Option<Instant>,
}

impl Batcher {
    /// An empty batcher with `cfg`'s flush triggers.
    pub fn new(cfg: BatcherConfig) -> Self {
        Batcher { cfg, seeds: Vec::new(), members: Vec::new(), oldest: None }
    }

    /// Seeds currently pending (not yet flushed).
    pub fn pending_seeds(&self) -> usize {
        self.seeds.len()
    }

    /// Whether no request is pending.
    pub fn is_empty(&self) -> bool {
        self.members.is_empty()
    }

    /// Queue a request; returns a batch if the size trigger fired.
    pub fn push(&mut self, req: Request) -> Option<PendingBatch> {
        let start = self.seeds.len();
        let len = req.nodes.len();
        self.seeds.extend_from_slice(&req.nodes);
        if self.oldest.is_none() {
            self.oldest = Some(req.submitted);
        }
        self.members.push((req, start, len));
        if self.seeds.len() >= self.cfg.batch_size {
            Some(self.flush())
        } else {
            None
        }
    }

    /// Time left until the timeout trigger would fire (None if empty).
    pub fn time_until_deadline(&self, now: Instant) -> Option<Duration> {
        self.oldest.map(|t| {
            let age = now.duration_since(t);
            self.cfg.max_wait.saturating_sub(age)
        })
    }

    /// Flush if the timeout trigger fired.
    pub fn poll_deadline(&mut self, now: Instant) -> Option<PendingBatch> {
        match self.time_until_deadline(now) {
            Some(d) if d.is_zero() && !self.is_empty() => Some(self.flush()),
            _ => None,
        }
    }

    /// Unconditional flush of whatever is pending.
    pub fn flush(&mut self) -> PendingBatch {
        self.oldest = None;
        PendingBatch {
            seeds: std::mem::take(&mut self.seeds),
            members: std::mem::take(&mut self.members),
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use std::sync::mpsc;

    fn req(nodes: Vec<NodeId>) -> (Request, mpsc::Receiver<super::super::Response>) {
        let (tx, rx) = mpsc::channel();
        (Request { nodes, submitted: Instant::now(), reply: tx }, rx)
    }

    #[test]
    fn size_trigger() {
        let mut b = Batcher::new(BatcherConfig { batch_size: 4, max_wait: Duration::from_secs(1) });
        let (r1, _k1) = req(vec![1, 2]);
        assert!(b.push(r1).is_none());
        assert_eq!(b.pending_seeds(), 2);
        let (r2, _k2) = req(vec![3, 4, 5]);
        let batch = b.push(r2).expect("size trigger");
        assert_eq!(batch.seeds, vec![1, 2, 3, 4, 5]);
        assert_eq!(batch.members.len(), 2);
        assert_eq!(batch.members[0].1, 0);
        assert_eq!(batch.members[0].2, 2);
        assert_eq!(batch.members[1].1, 2);
        assert!(b.is_empty());
    }

    #[test]
    fn timeout_trigger() {
        let mut b = Batcher::new(BatcherConfig {
            batch_size: 100,
            max_wait: Duration::from_millis(1),
        });
        let (r, _k) = req(vec![9]);
        assert!(b.push(r).is_none());
        assert!(b.poll_deadline(Instant::now()).is_none() || true);
        std::thread::sleep(Duration::from_millis(2));
        let batch = b.poll_deadline(Instant::now()).expect("timeout trigger");
        assert_eq!(batch.seeds, vec![9]);
        assert!(b.poll_deadline(Instant::now()).is_none(), "empty after flush");
    }

    #[test]
    fn deadline_accounting() {
        let mut b = Batcher::new(BatcherConfig {
            batch_size: 100,
            max_wait: Duration::from_millis(50),
        });
        assert!(b.time_until_deadline(Instant::now()).is_none());
        let (r, _k) = req(vec![1]);
        b.push(r);
        let d = b.time_until_deadline(Instant::now()).unwrap();
        assert!(d <= Duration::from_millis(50));
    }
}
