//! Dynamic batcher: coalesces client requests into engine-sized
//! mini-batches. Flush triggers: (a) pending seed count reaches
//! `batch_size`, (b) the oldest pending request exceeds `max_wait`.
//!
//! Requests accumulate in one lane per [`TenantClass`], so a flushed
//! batch never mixes classes: the batch's class tags its tracker
//! records and metric ledgers unambiguously, and — because logits
//! depend on batch composition — class-aware serving stays bit-
//! identical to class-blind serving whenever the request stream itself
//! is served in the same batch groupings (see DESIGN.md §Multi-tenant
//! QoS).

use std::time::{Duration, Instant};

use crate::graph::NodeId;

use super::{Request, TenantClass, N_CLASSES};

/// Batching policy knobs.
#[derive(Debug, Clone)]
pub struct BatcherConfig {
    /// Seed count that triggers an immediate flush.
    pub batch_size: usize,
    /// Oldest-request age that forces a flush.
    pub max_wait: Duration,
}

impl Default for BatcherConfig {
    fn default() -> Self {
        BatcherConfig { batch_size: 256, max_wait: Duration::from_millis(5) }
    }
}

/// A flushed batch: concatenated seeds + the requests (with their seed
/// spans) it serves. All members share one admission class.
pub struct PendingBatch {
    /// All member requests' seeds, concatenated in arrival order.
    pub seeds: Vec<NodeId>,
    /// (request, start, len) spans into `seeds`.
    pub members: Vec<(Request, usize, usize)>,
    /// The class every member was admitted under (lanes never mix).
    pub class: TenantClass,
}

/// One class's accumulation lane.
#[derive(Default)]
struct Lane {
    seeds: Vec<NodeId>,
    members: Vec<(Request, usize, usize)>,
    oldest: Option<Instant>,
}

/// Accumulates requests until a flush trigger fires, one lane per
/// [`TenantClass`].
pub struct Batcher {
    cfg: BatcherConfig,
    lanes: [Lane; N_CLASSES],
}

impl Batcher {
    /// An empty batcher with `cfg`'s flush triggers.
    pub fn new(cfg: BatcherConfig) -> Self {
        Batcher { cfg, lanes: Default::default() }
    }

    /// Seeds currently pending (not yet flushed), across all lanes.
    pub fn pending_seeds(&self) -> usize {
        self.lanes.iter().map(|l| l.seeds.len()).sum()
    }

    /// Whether no request is pending in any lane.
    pub fn is_empty(&self) -> bool {
        self.lanes.iter().all(|l| l.members.is_empty())
    }

    /// Queue a request into its class's lane; returns a batch if that
    /// lane's size trigger fired.
    pub fn push(&mut self, req: Request) -> Option<PendingBatch> {
        let class = req.class;
        let lane = &mut self.lanes[class.index()];
        let start = lane.seeds.len();
        let len = req.nodes.len();
        lane.seeds.extend_from_slice(&req.nodes);
        if lane.oldest.is_none() {
            lane.oldest = Some(req.submitted);
        }
        lane.members.push((req, start, len));
        if lane.seeds.len() >= self.cfg.batch_size {
            Some(Self::flush_lane(&mut self.lanes[class.index()], class))
        } else {
            None
        }
    }

    /// Time left until the earliest lane's timeout trigger would fire
    /// (None if every lane is empty).
    pub fn time_until_deadline(&self, now: Instant) -> Option<Duration> {
        self.lanes
            .iter()
            .filter_map(|l| l.oldest)
            .map(|t| self.cfg.max_wait.saturating_sub(now.duration_since(t)))
            .min()
    }

    /// Flush the lane whose timeout trigger fired (oldest request
    /// first, QoS order breaking ties). Call again for further expired
    /// lanes.
    pub fn poll_deadline(&mut self, now: Instant) -> Option<PendingBatch> {
        let due = TenantClass::ALL.into_iter().filter(|c| {
            let lane = &self.lanes[c.index()];
            match lane.oldest {
                Some(t) => {
                    !lane.members.is_empty()
                        && self.cfg.max_wait.saturating_sub(now.duration_since(t)).is_zero()
                }
                None => false,
            }
        });
        let class = due.min_by_key(|c| self.lanes[c.index()].oldest)?;
        Some(Self::flush_lane(&mut self.lanes[class.index()], class))
    }

    /// Unconditional flush of the first non-empty lane, in QoS order
    /// (priority, standard, scan). Loop `while !is_empty()` to drain
    /// every lane — a single call no longer empties the batcher now
    /// that classes batch separately.
    pub fn flush(&mut self) -> PendingBatch {
        let class = TenantClass::ALL
            .into_iter()
            .find(|c| !self.lanes[c.index()].members.is_empty())
            .unwrap_or(TenantClass::Standard);
        Self::flush_lane(&mut self.lanes[class.index()], class)
    }

    fn flush_lane(lane: &mut Lane, class: TenantClass) -> PendingBatch {
        lane.oldest = None;
        PendingBatch {
            seeds: std::mem::take(&mut lane.seeds),
            members: std::mem::take(&mut lane.members),
            class,
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use std::sync::mpsc;

    fn req(nodes: Vec<NodeId>) -> (Request, mpsc::Receiver<super::super::Response>) {
        req_as(nodes, TenantClass::Standard)
    }

    fn req_as(
        nodes: Vec<NodeId>,
        class: TenantClass,
    ) -> (Request, mpsc::Receiver<super::super::Response>) {
        let (tx, rx) = mpsc::channel();
        (Request { nodes, class, submitted: Instant::now(), reply: tx }, rx)
    }

    #[test]
    fn size_trigger() {
        let mut b = Batcher::new(BatcherConfig { batch_size: 4, max_wait: Duration::from_secs(1) });
        let (r1, _k1) = req(vec![1, 2]);
        assert!(b.push(r1).is_none());
        assert_eq!(b.pending_seeds(), 2);
        let (r2, _k2) = req(vec![3, 4, 5]);
        let batch = b.push(r2).expect("size trigger");
        assert_eq!(batch.seeds, vec![1, 2, 3, 4, 5]);
        assert_eq!(batch.members.len(), 2);
        assert_eq!(batch.members[0].1, 0);
        assert_eq!(batch.members[0].2, 2);
        assert_eq!(batch.members[1].1, 2);
        assert_eq!(batch.class, TenantClass::Standard);
        assert!(b.is_empty());
    }

    #[test]
    fn timeout_trigger() {
        let mut b = Batcher::new(BatcherConfig {
            batch_size: 100,
            max_wait: Duration::from_millis(1),
        });
        let (r, _k) = req(vec![9]);
        assert!(b.push(r).is_none());
        assert!(b.poll_deadline(Instant::now()).is_none() || true);
        std::thread::sleep(Duration::from_millis(2));
        let batch = b.poll_deadline(Instant::now()).expect("timeout trigger");
        assert_eq!(batch.seeds, vec![9]);
        assert!(b.poll_deadline(Instant::now()).is_none(), "empty after flush");
    }

    #[test]
    fn deadline_accounting() {
        let mut b = Batcher::new(BatcherConfig {
            batch_size: 100,
            max_wait: Duration::from_millis(50),
        });
        assert!(b.time_until_deadline(Instant::now()).is_none());
        let (r, _k) = req(vec![1]);
        b.push(r);
        let d = b.time_until_deadline(Instant::now()).unwrap();
        assert!(d <= Duration::from_millis(50));
    }

    #[test]
    fn classes_batch_in_separate_lanes() {
        let mut b = Batcher::new(BatcherConfig { batch_size: 4, max_wait: Duration::from_secs(1) });
        let (r1, _k1) = req_as(vec![1, 2, 3], TenantClass::Priority);
        let (r2, _k2) = req_as(vec![10, 11, 12], TenantClass::Scan);
        assert!(b.push(r1).is_none());
        assert!(b.push(r2).is_none(), "scan seeds must not trip priority's trigger");
        assert_eq!(b.pending_seeds(), 6);
        // one more priority seed fills only the priority lane
        let (r3, _k3) = req_as(vec![4], TenantClass::Priority);
        let batch = b.push(r3).expect("priority lane size trigger");
        assert_eq!(batch.class, TenantClass::Priority);
        assert_eq!(batch.seeds, vec![1, 2, 3, 4]);
        // the scan lane still holds its request; drain via flush loop
        assert!(!b.is_empty());
        let rest = b.flush();
        assert_eq!(rest.class, TenantClass::Scan);
        assert_eq!(rest.seeds, vec![10, 11, 12]);
        assert!(b.is_empty());
    }

    #[test]
    fn flush_drains_lanes_in_qos_order() {
        let mut b = Batcher::new(BatcherConfig::default());
        let (r1, _k1) = req_as(vec![7], TenantClass::Scan);
        let (r2, _k2) = req_as(vec![8], TenantClass::Priority);
        b.push(r1);
        b.push(r2);
        let mut order = Vec::new();
        while !b.is_empty() {
            order.push(b.flush().class);
        }
        assert_eq!(order, vec![TenantClass::Priority, TenantClass::Scan]);
    }
}
