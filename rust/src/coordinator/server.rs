//! The serving loop: worker threads own an engine each; a leader-side
//! router feeds their queues; responses flow back over per-request
//! channels.
//!
//! When `RunConfig::refresh` is set (and the system has a
//! [`planner_for`] strategy), each worker also runs the online refresh
//! loop: the engine's serving path feeds a
//! [`WorkloadTracker`](crate::cache::WorkloadTracker) (dense counters
//! or the count-min sketch, per `RunConfig::tracker`), and a background
//! [`Refresher`] thread re-plans the worker's caches on workload drift,
//! hot-swapping the snapshot the worker reads per batch. The swap never
//! stalls serving (see `cache::runtime`); refresh counters — including
//! the tracker's drain cost and drained/dropped key totals — surface
//! in [`ServingMetrics`] at shutdown (the serving-observability story
//! DESIGN.md §Workload tracking documents).

use std::panic::{catch_unwind, AssertUnwindSafe};
use std::sync::atomic::{AtomicUsize, Ordering};
use std::sync::{mpsc, Arc, Mutex};
use std::thread::JoinHandle;
use std::time::{Duration, Instant};

use anyhow::Result;

use crate::baselines::planner_for;
use crate::cache::refresh::{AutoBudgetPolicy, RefreshJob, Refresher};
use crate::config::RunConfig;
use crate::engine::InferenceEngine;
use crate::graph::{Dataset, LiveGraph};
use crate::mem::per_node_claim_bytes;
use crate::util::lock_unpoisoned;

use super::admission::{AdmissionConfig, AdmissionController};
use super::batcher::{Batcher, BatcherConfig, PendingBatch};
use super::metrics::ServingMetrics;
use super::router::{RoutePolicy, Router, WorkerHandle};
use super::{Request, Response};

/// Server deployment knobs.
#[derive(Debug, Clone)]
pub struct ServerConfig {
    /// Worker threads (each owns a full engine + caches).
    pub n_workers: usize,
    /// Dynamic-batching policy.
    pub batcher: BatcherConfig,
    /// How requests are spread across workers.
    pub policy: RoutePolicy,
    /// Frontend admission/backpressure policy.
    pub admission: AdmissionConfig,
}

impl Default for ServerConfig {
    fn default() -> Self {
        ServerConfig {
            n_workers: 1,
            batcher: BatcherConfig::default(),
            policy: RoutePolicy::RoundRobin,
            admission: AdmissionConfig::default(),
        }
    }
}

/// A running server: router + worker threads.
pub struct Server {
    router: Router,
    admission: AdmissionController,
    workers: Vec<JoinHandle<Result<()>>>,
    metrics: Vec<Arc<Mutex<ServingMetrics>>>,
    started: Instant,
    /// The one live graph every worker samples through (`graph.mutate=`
    /// runs; `None` = frozen graph). Shared, not per worker: mutation
    /// epochs are graph state, and all workers must see one history.
    live_graph: Option<Arc<LiveGraph>>,
}

impl Server {
    /// Start workers. Each worker runs its system's preprocessing on
    /// its own engine before serving (caches are per-worker, as they
    /// would be per-GPU), and — with refresh configured — its own
    /// refresh thread (drift is per-worker, too: workers see the
    /// request slices the router gives them).
    pub fn start(ds: Arc<Dataset>, run_cfg: RunConfig, cfg: ServerConfig) -> Result<Server> {
        let mut handles = Vec::new();
        let mut joins = Vec::new();
        let mut metrics = Vec::new();
        // graph.mutate= promotes the dataset's CSC into a live graph
        // shared by every worker; the caller drives mutations against
        // it (Server::live_graph) concurrent with serving
        let live_graph = run_cfg
            .graph_mutate
            .as_ref()
            .map(|_| Arc::new(LiveGraph::new(ds.csc.clone())));
        for w in 0..cfg.n_workers.max(1) {
            let (tx, rx) = mpsc::channel::<Request>();
            let queued = Arc::new(AtomicUsize::new(0));
            let m = Arc::new(Mutex::new(ServingMetrics::new()));
            let ds = Arc::clone(&ds);
            let mut rc = run_cfg.clone();
            rc.seed = run_cfg.seed.wrapping_add(w as u64);
            // Sampling threads (pipeline workers + presample profiling)
            // are per-engine; divide the configured budget across the
            // workers so `n_workers` engines don't oversubscribe the
            // host with `n_workers × sample_threads` samplers. Results
            // are thread-count-invariant, so this only shifts wall time.
            rc.sample_threads = (run_cfg.sample_threads / cfg.n_workers.max(1)).max(1);
            let batcher_cfg = cfg.batcher.clone();
            let queued2 = Arc::clone(&queued);
            let m2 = Arc::clone(&m);
            let lg2 = live_graph.clone();
            let join = std::thread::Builder::new()
                .name(format!("dci-worker-{w}"))
                .spawn(move || worker_loop(&ds, rc, batcher_cfg, rx, queued2, m2, lg2))?;
            handles.push(WorkerHandle { tx, queued_seeds: queued });
            joins.push(join);
            metrics.push(m);
        }
        Ok(Server {
            router: Router::new(handles, cfg.policy)?,
            admission: AdmissionController::new(cfg.admission),
            workers: joins,
            metrics,
            started: Instant::now(),
            live_graph,
        })
    }

    /// The shared live graph (`graph.mutate=` runs): the caller's
    /// mutation driver inserts edges and triggers compactions on it
    /// while the workers serve. `None` on frozen-graph runs.
    pub fn live_graph(&self) -> Option<Arc<LiveGraph>> {
        self.live_graph.clone()
    }

    /// Submit a request; the response arrives on the returned receiver.
    pub fn submit(&self, nodes: Vec<crate::graph::NodeId>) -> Result<mpsc::Receiver<Response>> {
        self.submit_as("anonymous", nodes)
    }

    /// Submit with a client identity (admission control applies). The
    /// client's [`TenantClass`](super::TenantClass) — derived from the
    /// identity's `priority:`/`scan:` prefix — rides the request into
    /// the batcher's per-class lanes and the cache layer's class-tagged
    /// workload profile; it never changes the computed logits.
    pub fn submit_as(
        &self,
        client: &str,
        nodes: Vec<crate::graph::NodeId>,
    ) -> Result<mpsc::Receiver<Response>> {
        let class = self
            .admission
            .admit(client, nodes.len(), self.router.queued_seeds())?;
        let (tx, rx) = mpsc::channel();
        self.router
            .route(Request { nodes, class, submitted: Instant::now(), reply: tx })?;
        Ok(rx)
    }

    /// Merged metrics snapshot + elapsed time. Live view: the
    /// refresh/swap counters are folded in when workers exit, so read
    /// the `shutdown` result for final totals.
    pub fn metrics(&self) -> (ServingMetrics, Duration) {
        let mut all = ServingMetrics::new();
        for m in &self.metrics {
            all.merge(&lock_unpoisoned(m));
        }
        all.record_sheds(self.admission.shed_counts());
        // once, not per worker: the live graph is shared, so its
        // counters are graph totals rather than per-worker deltas
        if let Some(lg) = &self.live_graph {
            all.record_graph(lg);
        }
        (all, self.started.elapsed())
    }

    /// Stop accepting work, join the workers, and return the final
    /// metrics (including each worker's refresh + swap counters and
    /// the frontend's per-class shed totals).
    pub fn shutdown(self) -> Result<(ServingMetrics, Duration)> {
        let Server { router, admission, workers, metrics, started, live_graph } = self;
        drop(router); // closes queues; workers drain + exit
        for j in workers {
            match j.join() {
                Ok(r) => r?,
                Err(_) => anyhow::bail!("worker panicked"),
            }
        }
        let mut all = ServingMetrics::new();
        for m in &metrics {
            all.merge(&lock_unpoisoned(m));
        }
        all.record_sheds(admission.shed_counts());
        if let Some(lg) = &live_graph {
            all.record_graph(lg);
        }
        Ok((all, started.elapsed()))
    }
}

fn worker_loop(
    ds: &Arc<Dataset>,
    run_cfg: RunConfig,
    batcher_cfg: BatcherConfig,
    rx: mpsc::Receiver<Request>,
    queued: Arc<AtomicUsize>,
    metrics: Arc<Mutex<ServingMetrics>>,
    live_graph: Option<Arc<LiveGraph>>,
) -> Result<()> {
    let refresh_cfg = run_cfg.refresh.clone();
    let tracker_cfg = run_cfg.tracker.clone();
    let system = run_cfg.system;
    let budget_is_auto = run_cfg.budget.is_none();
    let hidden = run_cfg.hidden;
    let mut engine = InferenceEngine::prepare(ds.as_ref(), run_cfg)?;
    if let Some(lg) = &live_graph {
        engine.set_live_graph(Arc::clone(lg));
    }

    // online refresh: tracker on the serving path (dense or sketch,
    // per `RunConfig::tracker`), re-planner on a background thread,
    // per worker (cacheless systems skip it). With a sharded runtime
    // the refresher detects drift per shard and hot-swaps only the
    // drifted shards, each within its own budget — and with
    // `rebalance=on` the budgets themselves follow the shard-level
    // load (plus, for `budget=auto` runs with `auto-budget-refresh=on`,
    // the global budget re-tracks the workload's peak claim). Installs
    // are accounted against the engine's own device arenas in
    // claim-before-release order.
    let mut refresher: Option<Refresher> = None;
    if let Some(rcfg) = refresh_cfg {
        if let Some(planner) = planner_for(system) {
            let tracker = tracker_cfg.build(ds.csc.n_nodes(), ds.csc.n_edges());
            engine.set_tracker(Arc::clone(&tracker));
            // mutation-aware invalidation: mutated nodes get boosted
            // tracker mass so the next drift re-plan re-caches them
            if let Some(lg) = &live_graph {
                lg.set_tracker(Arc::clone(&tracker), rcfg.mutation_boost);
            }
            // drift baseline: the pre-sample profile the startup plan
            // was built from
            let baseline = engine
                .prepared
                .presample
                .as_ref()
                .map(|s| s.node_visits.clone())
                .unwrap_or_default();
            let wire_auto = rcfg.auto_budget_refresh && budget_is_auto;
            let mut job = RefreshJob::new(
                Arc::clone(ds),
                engine.runtime(),
                tracker,
                planner,
                engine.prepared.shard_budgets.clone(),
                baseline,
                rcfg,
            )
            .device(engine.device_group())
            // refill gathers stage through the engine's pinned pool, so
            // refresh traffic and serving share one buffer economy
            .staging(engine.staging_pool());
            // the worker's fault schedule covers its refresh loop too:
            // one spec, one shared trigger budget across all sites
            if let Some(f) = engine.fault_plan() {
                job = job.fault(f);
            }
            if wire_auto {
                job = job.auto_budget(AutoBudgetPolicy {
                    headroom_per_device: engine.device.headroom(0),
                    per_node_bytes: per_node_claim_bytes(ds.features.row_bytes(), hidden),
                    scale: ds.spec.scale,
                    // heterogeneous tiers re-track the claim per device
                    tier_headrooms: engine
                        .device
                        .is_tiered()
                        .then(|| engine.device.headrooms()),
                });
            }
            refresher = Some(job.spawn());
        }
    }

    let result = serve_requests(&mut engine, batcher_cfg, rx, queued, &metrics);

    // fold the refresh loop's lifetime stats into this worker's
    // metrics before the server joins us (stop first, merge after:
    // stop blocks up to one poll interval)
    let refresh_stats = refresher.map(|r| r.stop());
    let stalls = engine.runtime().swap_stalls();
    let staging = engine.staging_pool().stats();
    let mut m = lock_unpoisoned(&metrics);
    if let Some(rs) = refresh_stats {
        m.refreshes += rs.replans;
        m.drift_checks += rs.checks;
        m.refresh_ns += rs.replan_wall_ns;
        m.tracker_drain_ns += rs.drain_ns;
        m.tracker_drained_keys += rs.drained_keys;
        m.tracker_dropped_touches += rs.dropped_touches;
        m.shard_rebalances += rs.shard_rebalances;
        m.budget_moved_bytes += rs.budget_moved_bytes;
        m.auto_budget_delta += rs.auto_budget_delta;
        m.install_retries += rs.install_retries;
        m.backoff_ns += rs.backoff_ns;
        m.shard_degrades += rs.shard_degrades;
        m.shard_repairs += rs.shard_repairs;
        m.repair_ns += rs.repair_wall_ns;
        m.watchdog_restarts += rs.watchdog_restarts;
        m.refresh_panics += rs.refresh_panics;
        m.cache.refresh.upload(rs.fill_h2d_bytes);
    }
    m.swap_stalls += stalls;
    m.staging_leases += staging.leases;
    m.staging_fresh_allocs += staging.fresh_allocs;
    m.staging_peak_leased = m.staging_peak_leased.max(staging.peak_leased);
    drop(m);

    result
}

fn serve_requests(
    engine: &mut InferenceEngine<'_>,
    batcher_cfg: BatcherConfig,
    rx: mpsc::Receiver<Request>,
    queued: Arc<AtomicUsize>,
    metrics: &Arc<Mutex<ServingMetrics>>,
) -> Result<()> {
    let mut batcher = Batcher::new(batcher_cfg);
    let mut batch_id = 0u64;

    loop {
        // wait for work, bounded by the batcher deadline
        let timeout = batcher
            .time_until_deadline(Instant::now())
            .unwrap_or(Duration::from_millis(50));
        let msg = rx.recv_timeout(timeout);
        let flushed: Option<PendingBatch> = match msg {
            Ok(req) => {
                queued.fetch_sub(
                    req.nodes.len().min(queued.load(Ordering::Relaxed)),
                    Ordering::Relaxed,
                );
                batcher.push(req)
            }
            Err(mpsc::RecvTimeoutError::Timeout) => batcher.poll_deadline(Instant::now()),
            Err(mpsc::RecvTimeoutError::Disconnected) => {
                // drain and exit: flush() empties one class lane per
                // call (QoS order), so loop until every lane is dry
                while !batcher.is_empty() {
                    let b = batcher.flush();
                    serve_batch(engine, b, &mut batch_id, metrics)?;
                }
                return Ok(());
            }
        };
        if let Some(b) = flushed {
            serve_batch(engine, b, &mut batch_id, metrics)?;
        }
    }
}

fn serve_batch(
    engine: &mut InferenceEngine<'_>,
    batch: PendingBatch,
    batch_id: &mut u64,
    metrics: &Arc<Mutex<ServingMetrics>>,
) -> Result<()> {
    *batch_id += 1;
    // panic isolation: an inference panic (injected fault or real bug)
    // is retried once — the engine's fault site fires before any batch
    // state moves, so the retry replays the identical request stream —
    // and a second panic becomes error responses, never a dead worker
    let first =
        catch_unwind(AssertUnwindSafe(|| engine.infer_once_as(&batch.seeds, batch.class)));
    let caught = match first {
        Ok(r) => Ok(r),
        Err(_) => {
            lock_unpoisoned(metrics).batch_retries += 1;
            catch_unwind(AssertUnwindSafe(|| engine.infer_once_as(&batch.seeds, batch.class)))
        }
    };
    let out = match caught {
        Ok(r) => r?,
        Err(_) => {
            lock_unpoisoned(metrics).batch_failures += 1;
            for (req, _, _) in batch.members {
                let latency_ns = req.submitted.elapsed().as_nanos() as u64;
                let _ = req.reply.send(Response {
                    logits: None,
                    latency_ns,
                    batch_id: *batch_id,
                    error: Some(format!("batch {batch_id} panicked twice; resubmit")),
                });
            }
            return Ok(());
        }
    };
    let classes = engine.ds.spec.classes;
    let mut m = lock_unpoisoned(metrics);
    m.record_batch(batch.members.len(), batch.seeds.len());
    // per-tenant SLO ledger: the whole batch is one class (the batcher
    // never mixes lanes), so its feature ledger attributes cleanly
    m.record_tenant_batch(
        batch.class,
        batch.members.len(),
        batch.seeds.len(),
        out.stats.feature.hits,
        out.stats.feature.misses,
    );
    m.sample_ns += out.sample.total_ns();
    m.feature_ns += out.feature.total_ns();
    m.compute_ns += out.compute.total_ns();
    m.transfer_staged_ns += out.transfer_staged_ns;
    m.transfer_hidden_ns += out.transfer_hidden_ns;
    m.cache.merge(&out.stats);
    drop(m);

    for (req, start, len) in batch.members {
        let latency_ns = req.submitted.elapsed().as_nanos() as u64;
        lock_unpoisoned(metrics).record_latency_as(batch.class, latency_ns);
        let logits = out.logits.as_ref().map(|l| {
            l[start * classes..(start + len) * classes].to_vec()
        });
        // receiver may have gone away; that's the client's business
        let _ = req.reply.send(Response { logits, latency_ns, batch_id: *batch_id, error: None });
    }
    Ok(())
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::cache::RefreshConfig;
    use crate::config::{ComputeKind, SystemKind};
    use crate::graph::datasets;
    use crate::sampler::Fanout;

    fn serving_cfg() -> RunConfig {
        let mut cfg = RunConfig::default();
        cfg.dataset = "tiny".into();
        cfg.system = SystemKind::Dci;
        cfg.batch_size = 32;
        cfg.fanout = Fanout::parse("3,2").unwrap();
        cfg.budget = Some(300_000);
        cfg.compute = ComputeKind::Reference;
        cfg.hidden = 16;
        cfg
    }

    #[test]
    fn serves_requests_end_to_end() {
        let ds = Arc::new(datasets::spec("tiny").unwrap().build());
        let server = Server::start(
            Arc::clone(&ds),
            serving_cfg(),
            ServerConfig {
                n_workers: 1,
                batcher: BatcherConfig {
                    batch_size: 16,
                    max_wait: Duration::from_millis(2),
                },
                policy: RoutePolicy::RoundRobin,
                admission: AdmissionConfig::default(),
            },
        )
        .unwrap();

        let mut rxs = Vec::new();
        for i in 0..10 {
            let nodes: Vec<u32> = ds.test_nodes[i * 4..(i + 1) * 4].to_vec();
            rxs.push((nodes.len(), server.submit(nodes).unwrap()));
        }
        for (n, rx) in rxs {
            let resp = rx.recv_timeout(Duration::from_secs(30)).unwrap();
            let logits = resp.logits.expect("reference compute returns logits");
            assert_eq!(logits.len(), n * ds.spec.classes);
            assert!(logits.iter().all(|v| v.is_finite()));
            assert!(resp.latency_ns > 0);
        }
        let (m, _elapsed) = server.shutdown().unwrap();
        assert_eq!(m.requests, 10);
        assert_eq!(m.seeds, 40);
        assert!(m.batches >= 1);
        assert!(m.compute_ns > 0.0);
        // serving-time ledgers flowed into the metrics
        assert!(m.cache.feature.hits + m.cache.feature.misses > 0);
        // refresh was not configured
        assert_eq!(m.refreshes, 0);
        assert_eq!(m.swap_stalls, 0);
    }

    #[test]
    fn multiple_workers_share_load() {
        let ds = Arc::new(datasets::spec("tiny").unwrap().build());
        let server = Server::start(
            Arc::clone(&ds),
            serving_cfg(),
            ServerConfig {
                n_workers: 2,
                batcher: BatcherConfig {
                    batch_size: 4,
                    max_wait: Duration::from_millis(1),
                },
                policy: RoutePolicy::RoundRobin,
                admission: AdmissionConfig::default(),
            },
        )
        .unwrap();
        let mut rxs = Vec::new();
        for i in 0..8 {
            rxs.push(server.submit(vec![ds.test_nodes[i]]).unwrap());
        }
        for rx in rxs {
            rx.recv_timeout(Duration::from_secs(30)).unwrap();
        }
        let (m, _) = server.shutdown().unwrap();
        assert_eq!(m.requests, 8);
    }

    #[test]
    fn refresh_loop_replans_while_serving() {
        let ds = Arc::new(datasets::spec("tiny").unwrap().build());
        let mut cfg = serving_cfg();
        // force constant re-planning: negative threshold means every
        // drift check (min 1 batch) triggers, however small the drift
        cfg.refresh = Some(RefreshConfig {
            check_interval: Duration::from_millis(5),
            min_batches: 1,
            decay: 0.5,
            drift_threshold: -1.0,
            ..RefreshConfig::default()
        });
        let server = Server::start(
            Arc::clone(&ds),
            cfg,
            ServerConfig {
                n_workers: 1,
                batcher: BatcherConfig {
                    batch_size: 8,
                    max_wait: Duration::from_millis(1),
                },
                policy: RoutePolicy::RoundRobin,
                admission: AdmissionConfig::default(),
            },
        )
        .unwrap();
        // serve in paced rounds so the refresher gets poll windows
        // with traffic in between
        for round in 0..6 {
            let mut rxs = Vec::new();
            for i in 0..4 {
                let at = (round * 4 + i) % (ds.test_nodes.len() - 4);
                rxs.push(server.submit(ds.test_nodes[at..at + 4].to_vec()).unwrap());
            }
            for rx in rxs {
                let resp = rx.recv_timeout(Duration::from_secs(30)).unwrap();
                assert!(resp.logits.is_some());
            }
            std::thread::sleep(Duration::from_millis(15));
        }
        let (m, _) = server.shutdown().unwrap();
        assert!(m.refreshes >= 1, "forced drift must re-plan: {m:?}");
        assert!(m.drift_checks >= m.refreshes);
        assert_eq!(m.swap_stalls, 0, "serving must never block on a swap");
        assert!(m.cache.refresh.h2d_bytes > 0, "refills upload features");
    }

    #[test]
    fn sketch_tracked_worker_replans_while_serving() {
        use crate::cache::TrackerKind;
        let ds = Arc::new(datasets::spec("tiny").unwrap().build());
        let mut cfg = serving_cfg();
        cfg.tracker.kind = TrackerKind::Sketch;
        cfg.refresh = Some(RefreshConfig {
            check_interval: Duration::from_millis(5),
            min_batches: 1,
            decay: 0.5,
            drift_threshold: -1.0,
            ..RefreshConfig::default()
        });
        let server = Server::start(
            Arc::clone(&ds),
            cfg,
            ServerConfig {
                n_workers: 1,
                batcher: BatcherConfig {
                    batch_size: 8,
                    max_wait: Duration::from_millis(1),
                },
                policy: RoutePolicy::RoundRobin,
                admission: AdmissionConfig::default(),
            },
        )
        .unwrap();
        for round in 0..6 {
            let mut rxs = Vec::new();
            for i in 0..4 {
                let at = (round * 4 + i) % (ds.test_nodes.len() - 4);
                rxs.push(server.submit(ds.test_nodes[at..at + 4].to_vec()).unwrap());
            }
            for rx in rxs {
                let resp = rx.recv_timeout(Duration::from_secs(30)).unwrap();
                assert!(resp.logits.is_some());
            }
            std::thread::sleep(Duration::from_millis(15));
        }
        let (m, _) = server.shutdown().unwrap();
        assert!(m.refreshes >= 1, "sketch-tracked drift must re-plan: {m:?}");
        assert_eq!(m.swap_stalls, 0, "serving must never block on a swap");
        assert!(m.tracker_drained_keys > 0, "sketch windows must drain keys: {m:?}");
        assert!(m.tracker_drain_ns > 0.0);
    }

    #[test]
    fn sharded_worker_serves_and_refreshes_per_shard() {
        let ds = Arc::new(datasets::spec("tiny").unwrap().build());
        let mut cfg = serving_cfg();
        cfg.shards = 2;
        cfg.refresh = Some(RefreshConfig {
            check_interval: Duration::from_millis(5),
            min_batches: 1,
            decay: 0.5,
            drift_threshold: 0.05,
            ..RefreshConfig::default()
        });
        let server = Server::start(
            Arc::clone(&ds),
            cfg,
            ServerConfig {
                n_workers: 1,
                batcher: BatcherConfig {
                    batch_size: 8,
                    max_wait: Duration::from_millis(1),
                },
                policy: RoutePolicy::RoundRobin,
                admission: AdmissionConfig::default(),
            },
        )
        .unwrap();
        for round in 0..6 {
            let mut rxs = Vec::new();
            for i in 0..4 {
                let at = (round * 4 + i) % (ds.test_nodes.len() - 4);
                rxs.push(server.submit(ds.test_nodes[at..at + 4].to_vec()).unwrap());
            }
            for rx in rxs {
                let resp = rx.recv_timeout(Duration::from_secs(30)).unwrap();
                let logits = resp.logits.expect("sharded gather returns logits");
                assert!(logits.iter().all(|v| v.is_finite()));
            }
            std::thread::sleep(Duration::from_millis(15));
        }
        let (m, _) = server.shutdown().unwrap();
        assert_eq!(m.requests, 24);
        assert_eq!(m.swap_stalls, 0, "no shard may ever block serving");
        assert!(m.cache.feature.hits + m.cache.feature.misses > 0);
    }

    #[test]
    fn rebalancing_worker_moves_budget_toward_the_hot_shard() {
        use crate::cache::ShardRouter;
        let ds = Arc::new(datasets::spec("tiny").unwrap().build());
        // same hash as the engine's router: pick seeds owned by shard 0
        let router = ShardRouter::new(2);
        let shard0: Vec<u32> = ds
            .test_nodes
            .iter()
            .copied()
            .filter(|&v| router.shard_of(v) == 0)
            .take(32)
            .collect();
        assert!(shard0.len() >= 16, "tiny must have shard-0 test seeds");

        let mut cfg = serving_cfg();
        cfg.shards = 2;
        // single-hop fanout: seeds are 1/3 of the visit mass, so
        // confining seeds to shard 0 skews the shard mass to ~2/3 —
        // well past the threshold (multi-hop neighbor visits are
        // hash-spread and would dilute the signal)
        cfg.fanout = Fanout::parse("2").unwrap();
        cfg.refresh = Some(RefreshConfig {
            check_interval: Duration::from_millis(5),
            min_batches: 1,
            decay: 0.5,
            drift_threshold: 0.05,
            rebalance: true,
            rebalance_threshold: 0.05,
            rebalance_floor: 0.1,
            ..RefreshConfig::default()
        });
        let server = Server::start(
            Arc::clone(&ds),
            cfg,
            ServerConfig {
                n_workers: 1,
                batcher: BatcherConfig {
                    batch_size: 8,
                    max_wait: Duration::from_millis(1),
                },
                policy: RoutePolicy::RoundRobin,
                admission: AdmissionConfig::default(),
            },
        )
        .unwrap();
        // every request targets shard 0's seeds: the load mass skews
        // far past the threshold, so the worker's refresher re-splits
        for round in 0..8 {
            let mut rxs = Vec::new();
            for i in 0..4 {
                let at = (round + i) % (shard0.len() - 4);
                rxs.push(server.submit(shard0[at..at + 4].to_vec()).unwrap());
            }
            for rx in rxs {
                let resp = rx.recv_timeout(Duration::from_secs(30)).unwrap();
                assert!(resp.logits.is_some());
            }
            std::thread::sleep(Duration::from_millis(15));
        }
        let (m, _) = server.shutdown().unwrap();
        assert!(
            m.shard_rebalances >= 1,
            "skewed traffic must trigger a budget re-split: {m:?}"
        );
        assert!(m.budget_moved_bytes > 0, "a re-split moves capacity: {m:?}");
        assert_eq!(m.auto_budget_delta, 0, "explicit budget: auto stays off");
        assert_eq!(m.swap_stalls, 0, "rebalancing must never block serving");
        let rep = m.report(Duration::from_secs(1));
        assert!(rep.contains("rebalances=") && rep.contains("moved="), "{rep}");
    }

    #[test]
    fn staged_worker_overlaps_transfers_and_reuses_buffers() {
        let ds = Arc::new(datasets::spec("tiny").unwrap().build());
        let mut cfg = serving_cfg();
        // miss-heavy budget so batches actually stage; ring of 2 lets
        // batch N+1's copy overlap batch N's compute in the model
        cfg.budget = Some(50_000);
        cfg.transfer_ring = 2;
        let server = Server::start(
            Arc::clone(&ds),
            cfg,
            ServerConfig {
                n_workers: 1,
                batcher: BatcherConfig {
                    batch_size: 4,
                    max_wait: Duration::from_millis(1),
                },
                policy: RoutePolicy::RoundRobin,
                admission: AdmissionConfig::default(),
            },
        )
        .unwrap();
        for i in 0..8 {
            let nodes = ds.test_nodes[i * 4..(i + 1) * 4].to_vec();
            let rx = server.submit(nodes).unwrap();
            let resp = rx.recv_timeout(Duration::from_secs(30)).unwrap();
            let logits = resp.logits.expect("staged serving returns logits");
            assert!(logits.iter().all(|v| v.is_finite()));
        }
        let (m, _) = server.shutdown().unwrap();
        assert!(m.cache.feature.staged_bytes > 0, "misses must stage: {m:?}");
        assert!(m.transfer_staged_ns > 0.0, "staged copies are priced: {m:?}");
        assert!(m.transfer_hidden_ns >= 0.0);
        assert!(m.transfer_occupancy() <= 1.0);
        assert!(m.staging_leases >= 8, "one lease per batch: {m:?}");
        assert_eq!(
            m.staging_fresh_allocs, 0,
            "serial serving never outruns the pinned pool: {m:?}"
        );
        assert_eq!(m.cache.feature.staged_fallbacks, 0);
        let rep = m.report(Duration::from_secs(1));
        assert!(rep.contains("staged=") && rep.contains("occupancy="), "{rep}");
    }

    #[test]
    fn worker_survives_injected_batch_panics() {
        let ds = Arc::new(datasets::spec("tiny").unwrap().build());
        let mut cfg = serving_cfg();
        // engine batch 1 panics once (retry succeeds); batch 2 panics
        // on both attempts (clients get an error response); the worker
        // keeps serving throughout
        cfg.fault = Some("batch@1,batch@2x2".into());
        let server = Server::start(
            Arc::clone(&ds),
            cfg,
            ServerConfig {
                n_workers: 1,
                batcher: BatcherConfig {
                    batch_size: 4,
                    max_wait: Duration::from_millis(1),
                },
                policy: RoutePolicy::RoundRobin,
                admission: AdmissionConfig::default(),
            },
        )
        .unwrap();
        // one 4-seed request per batch, submitted serially so the
        // engine's batch indices line up with the fault schedule
        let mut responses = Vec::new();
        for i in 0..4 {
            let nodes = ds.test_nodes[i * 4..(i + 1) * 4].to_vec();
            let rx = server.submit(nodes).unwrap();
            responses.push(rx.recv_timeout(Duration::from_secs(30)).unwrap());
        }
        for (i, resp) in responses.iter().enumerate() {
            if i == 2 {
                assert!(resp.error.is_some(), "double panic must surface: {resp:?}");
                assert!(resp.logits.is_none());
            } else {
                assert!(resp.error.is_none(), "batch {i} must serve: {resp:?}");
                let logits = resp.logits.as_ref().expect("reference compute returns logits");
                assert!(logits.iter().all(|v| v.is_finite()));
            }
        }
        let (m, _) = server.shutdown().unwrap();
        assert_eq!(m.batch_retries, 2, "one retry per panicked batch: {m:?}");
        assert_eq!(m.batch_failures, 1, "only the x2 batch fails: {m:?}");
        assert_eq!(m.requests, 3, "failed batches are not counted as served");
        assert_eq!(m.batches, 3);
        let rep = m.report(Duration::from_secs(1));
        assert!(rep.contains("batch-retry=2") && rep.contains("batch-fail=1"), "{rep}");
    }
}
