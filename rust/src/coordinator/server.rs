//! The serving loop: worker threads own an engine each; a leader-side
//! router feeds their queues; responses flow back over per-request
//! channels.

use std::sync::atomic::{AtomicUsize, Ordering};
use std::sync::{mpsc, Arc, Mutex};
use std::thread::JoinHandle;
use std::time::{Duration, Instant};

use anyhow::Result;

use crate::config::RunConfig;
use crate::engine::InferenceEngine;
use crate::graph::Dataset;

use super::admission::{AdmissionConfig, AdmissionController};
use super::batcher::{Batcher, BatcherConfig, PendingBatch};
use super::metrics::ServingMetrics;
use super::router::{RoutePolicy, Router, WorkerHandle};
use super::{Request, Response};

/// Server deployment knobs.
#[derive(Debug, Clone)]
pub struct ServerConfig {
    pub n_workers: usize,
    pub batcher: BatcherConfig,
    pub policy: RoutePolicy,
    pub admission: AdmissionConfig,
}

impl Default for ServerConfig {
    fn default() -> Self {
        ServerConfig {
            n_workers: 1,
            batcher: BatcherConfig::default(),
            policy: RoutePolicy::RoundRobin,
            admission: AdmissionConfig::default(),
        }
    }
}

/// A running server: router + worker threads.
pub struct Server {
    router: Router,
    admission: AdmissionController,
    workers: Vec<JoinHandle<Result<()>>>,
    metrics: Vec<Arc<Mutex<ServingMetrics>>>,
    started: Instant,
}

impl Server {
    /// Start workers. Each worker runs its system's preprocessing on
    /// its own engine before serving (caches are per-worker, as they
    /// would be per-GPU).
    pub fn start(ds: Arc<Dataset>, run_cfg: RunConfig, cfg: ServerConfig) -> Result<Server> {
        let mut handles = Vec::new();
        let mut joins = Vec::new();
        let mut metrics = Vec::new();
        for w in 0..cfg.n_workers.max(1) {
            let (tx, rx) = mpsc::channel::<Request>();
            let queued = Arc::new(AtomicUsize::new(0));
            let m = Arc::new(Mutex::new(ServingMetrics::new()));
            let ds = Arc::clone(&ds);
            let mut rc = run_cfg.clone();
            rc.seed = run_cfg.seed.wrapping_add(w as u64);
            // Sampling threads (pipeline workers + presample profiling)
            // are per-engine; divide the configured budget across the
            // workers so `n_workers` engines don't oversubscribe the
            // host with `n_workers × sample_threads` samplers. Results
            // are thread-count-invariant, so this only shifts wall time.
            rc.sample_threads = (run_cfg.sample_threads / cfg.n_workers.max(1)).max(1);
            let batcher_cfg = cfg.batcher.clone();
            let queued2 = Arc::clone(&queued);
            let m2 = Arc::clone(&m);
            let join = std::thread::Builder::new()
                .name(format!("dci-worker-{w}"))
                .spawn(move || worker_loop(&ds, rc, batcher_cfg, rx, queued2, m2))?;
            handles.push(WorkerHandle { tx, queued_seeds: queued });
            joins.push(join);
            metrics.push(m);
        }
        Ok(Server {
            router: Router::new(handles, cfg.policy)?,
            admission: AdmissionController::new(cfg.admission),
            workers: joins,
            metrics,
            started: Instant::now(),
        })
    }

    /// Submit a request; the response arrives on the returned receiver.
    pub fn submit(&self, nodes: Vec<crate::graph::NodeId>) -> Result<mpsc::Receiver<Response>> {
        self.submit_as("anonymous", nodes)
    }

    /// Submit with a client identity (admission control applies).
    pub fn submit_as(
        &self,
        client: &str,
        nodes: Vec<crate::graph::NodeId>,
    ) -> Result<mpsc::Receiver<Response>> {
        self.admission
            .admit(client, nodes.len(), self.router.queued_seeds())?;
        let (tx, rx) = mpsc::channel();
        self.router.route(Request { nodes, submitted: Instant::now(), reply: tx })?;
        Ok(rx)
    }

    /// Merged metrics snapshot + elapsed time.
    pub fn metrics(&self) -> (ServingMetrics, Duration) {
        let mut all = ServingMetrics::new();
        for m in &self.metrics {
            all.merge(&m.lock().unwrap());
        }
        (all, self.started.elapsed())
    }

    /// Stop accepting work and join the workers.
    pub fn shutdown(self) -> Result<(ServingMetrics, Duration)> {
        let snapshot = self.metrics();
        drop(self.router); // closes queues; workers drain + exit
        for j in self.workers {
            match j.join() {
                Ok(r) => r?,
                Err(_) => anyhow::bail!("worker panicked"),
            }
        }
        Ok(snapshot)
    }
}

fn worker_loop(
    ds: &Dataset,
    run_cfg: RunConfig,
    batcher_cfg: BatcherConfig,
    rx: mpsc::Receiver<Request>,
    queued: Arc<AtomicUsize>,
    metrics: Arc<Mutex<ServingMetrics>>,
) -> Result<()> {
    let mut engine = InferenceEngine::prepare(ds, run_cfg)?;
    let mut batcher = Batcher::new(batcher_cfg);
    let mut batch_id = 0u64;

    loop {
        // wait for work, bounded by the batcher deadline
        let timeout = batcher
            .time_until_deadline(Instant::now())
            .unwrap_or(Duration::from_millis(50));
        let msg = rx.recv_timeout(timeout);
        let flushed: Option<PendingBatch> = match msg {
            Ok(req) => {
                queued.fetch_sub(req.nodes.len().min(queued.load(Ordering::Relaxed)),
                                 Ordering::Relaxed);
                batcher.push(req)
            }
            Err(mpsc::RecvTimeoutError::Timeout) => batcher.poll_deadline(Instant::now()),
            Err(mpsc::RecvTimeoutError::Disconnected) => {
                // drain and exit
                if !batcher.is_empty() {
                    let b = batcher.flush();
                    serve_batch(&mut engine, b, &mut batch_id, &metrics)?;
                }
                return Ok(());
            }
        };
        if let Some(b) = flushed {
            serve_batch(&mut engine, b, &mut batch_id, &metrics)?;
        }
    }
}

fn serve_batch(
    engine: &mut InferenceEngine<'_>,
    batch: PendingBatch,
    batch_id: &mut u64,
    metrics: &Arc<Mutex<ServingMetrics>>,
) -> Result<()> {
    *batch_id += 1;
    let out = engine.infer_once(&batch.seeds)?;
    let classes = engine.ds.spec.classes;
    let mut m = metrics.lock().unwrap();
    m.record_batch(batch.members.len(), batch.seeds.len());
    m.sample_ns += out.sample.total_ns();
    m.feature_ns += out.feature.total_ns();
    m.compute_ns += out.compute.total_ns();
    drop(m);

    for (req, start, len) in batch.members {
        let latency_ns = req.submitted.elapsed().as_nanos() as u64;
        metrics.lock().unwrap().record_latency(latency_ns);
        let logits = out.logits.as_ref().map(|l| {
            l[start * classes..(start + len) * classes].to_vec()
        });
        // receiver may have gone away; that's the client's business
        let _ = req.reply.send(Response { logits, latency_ns, batch_id: *batch_id });
    }
    Ok(())
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::config::{ComputeKind, SystemKind};
    use crate::graph::datasets;
    use crate::sampler::Fanout;

    fn serving_cfg() -> RunConfig {
        let mut cfg = RunConfig::default();
        cfg.dataset = "tiny".into();
        cfg.system = SystemKind::Dci;
        cfg.batch_size = 32;
        cfg.fanout = Fanout::parse("3,2").unwrap();
        cfg.budget = Some(300_000);
        cfg.compute = ComputeKind::Reference;
        cfg.hidden = 16;
        cfg
    }

    #[test]
    fn serves_requests_end_to_end() {
        let ds = Arc::new(datasets::spec("tiny").unwrap().build());
        let server = Server::start(
            Arc::clone(&ds),
            serving_cfg(),
            ServerConfig {
                n_workers: 1,
                batcher: BatcherConfig {
                    batch_size: 16,
                    max_wait: Duration::from_millis(2),
                },
                policy: RoutePolicy::RoundRobin,
                admission: AdmissionConfig::default(),
            },
        )
        .unwrap();

        let mut rxs = Vec::new();
        for i in 0..10 {
            let nodes: Vec<u32> = ds.test_nodes[i * 4..(i + 1) * 4].to_vec();
            rxs.push((nodes.len(), server.submit(nodes).unwrap()));
        }
        for (n, rx) in rxs {
            let resp = rx.recv_timeout(Duration::from_secs(30)).unwrap();
            let logits = resp.logits.expect("reference compute returns logits");
            assert_eq!(logits.len(), n * ds.spec.classes);
            assert!(logits.iter().all(|v| v.is_finite()));
            assert!(resp.latency_ns > 0);
        }
        let (m, _elapsed) = server.shutdown().unwrap();
        assert_eq!(m.requests, 10);
        assert_eq!(m.seeds, 40);
        assert!(m.batches >= 1);
        assert!(m.compute_ns > 0.0);
    }

    #[test]
    fn multiple_workers_share_load() {
        let ds = Arc::new(datasets::spec("tiny").unwrap().build());
        let server = Server::start(
            Arc::clone(&ds),
            serving_cfg(),
            ServerConfig {
                n_workers: 2,
                batcher: BatcherConfig {
                    batch_size: 4,
                    max_wait: Duration::from_millis(1),
                },
                policy: RoutePolicy::RoundRobin,
                admission: AdmissionConfig::default(),
            },
        )
        .unwrap();
        let mut rxs = Vec::new();
        for i in 0..8 {
            rxs.push(server.submit(vec![ds.test_nodes[i]]).unwrap());
        }
        for rx in rxs {
            rx.recv_timeout(Duration::from_secs(30)).unwrap();
        }
        let (m, _) = server.shutdown().unwrap();
        assert_eq!(m.requests, 8);
    }
}
