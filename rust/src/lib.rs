//! # DCI — workload-aware dual-cache GNN inference acceleration
//!
//! Reproduction of *"DCI: A Coordinated Allocation and Filling
//! Workload-Aware Dual-Cache Allocation GNN Inference Acceleration
//! System"* as a three-layer Rust + JAX + Pallas stack:
//!
//! - **L3 (this crate)**: the paper's contribution — CSC graph store,
//!   fan-out neighbor sampler, pre-sampling profiler, the workload-aware
//!   dual-cache allocator (Eq. 1) and lightweight fillers (Algorithm 1),
//!   the DGL/SCI/RAIN/DUCATI baselines, a serving coordinator, and a
//!   simulated GPU memory + UVA transfer cost model (see DESIGN.md
//!   §Substitutions).
//! - **L2/L1 (python/compile)**: GraphSAGE/GCN forward over padded
//!   mini-batch blocks calling a Pallas gather+aggregate kernel, lowered
//!   once to HLO text artifacts.
//! - **Runtime** ([`runtime`]): loads the artifacts through the `xla`
//!   crate's PJRT CPU client; Python is never on the request path.
//!
//! Start with [`engine::InferenceEngine`] (single-process pipeline) or
//! [`coordinator::Server`] (request router + dynamic batcher).

pub mod baselines;
pub mod bench_support;
pub mod cache;
pub mod config;
pub mod coordinator;
pub mod engine;
pub mod graph;
pub mod mem;
pub mod runtime;
pub mod sampler;
pub mod util;

pub use config::RunConfig;
pub use engine::InferenceEngine;

