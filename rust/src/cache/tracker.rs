//! Serving-time workload tracking behind one trait: dense exact
//! counters or a count-min sketch with an O(touched) drain.
//!
//! The online refresh loop (see [`super::refresh`]) needs per-node and
//! per-CSC-element access counts from the serving hot path. Two
//! implementations of [`WorkloadTracker`] provide them:
//!
//! - [`AccessTracker`] (`tracker=dense`) — two full count arrays,
//!   O(nodes + edges) memory and drain cost. Exact: every recorded
//!   touch is counted once, whatever the thread interleaving. This is
//!   the accuracy reference the sketch is benchmarked against.
//! - [`SketchTracker`] (`tracker=sketch`) — a conservative-update
//!   count-min sketch per key space (nodes, CSC elements) plus a
//!   bounded *touched-since-last-drain* set, so the background drain
//!   enumerates only the keys the window actually touched: O(touched)
//!   instead of O(nodes + edges), with constant memory (~19 MiB at the
//!   defaults, touched sets and per-class node sketches included)
//!   independent of graph size. Estimates are conservative (≥ the true
//!   count; the property tests hold this single-threaded) and within
//!   ε·total with probability 1−δ — see [`cms_dims`] for the ε/δ →
//!   width/depth derivation, and DESIGN.md §Workload tracking for why
//!   that error bound is sufficient for drift detection and re-plans.
//!
//! Trackers are recorded from the serving thread and drained from the
//! refresh thread. The dense tracker's per-counter atomics make its
//! window boundaries exact; the sketch flips between two lanes on
//! drain, so a handful of touches racing the flip may land on either
//! side of the boundary — and a straggler that slips into the lane
//! mid-drain is detected and discarded with that window (see
//! `TouchedSet::drain`) rather than ever corrupting a later one. Both
//! are approximations drift detection tolerates by construction.

use std::sync::atomic::{AtomicBool, AtomicU32, AtomicU64, AtomicUsize, Ordering};

use crate::coordinator::admission::{TenantClass, N_CLASSES};
use crate::graph::NodeId;
use crate::util::splitmix64;

/// One drained window of tracker counts, sparse: only the keys touched
/// since the previous drain appear. The dense tracker emits its
/// nonzero entries; the sketch emits its touched set's estimates.
pub struct DrainedWindow {
    /// `(node, visits)` pairs for the feature-loading stage, summed
    /// over all admission classes.
    pub node_visits: Vec<(NodeId, u32)>,
    /// Per-class node-visit split (`[u32; N_CLASSES]` indexed by
    /// [`TenantClass::index`]), same node order as `node_visits`.
    /// **Empty when the window saw only `standard` touches** — the
    /// common untagged case pays nothing and the refresh loop folds
    /// the aggregate into the standard profile exactly.
    pub class_node_visits: Vec<(NodeId, [u32; N_CLASSES])>,
    /// `(CSC offset, accesses)` pairs for the sampling stage.
    pub elem_counts: Vec<(u64, u32)>,
    /// Served batches in the window.
    pub batches: u64,
    /// Modeled sampling-stage ns accumulated over the window.
    pub t_sample_ns: f64,
    /// Modeled feature-stage ns accumulated over the window.
    pub t_feature_ns: f64,
    /// Largest single-batch input-node count seen in the window — the
    /// workload's peak device claim, which the refresh loop's
    /// per-epoch auto-budget re-evaluation tracks (see
    /// [`super::refresh::AutoBudgetPolicy`]).
    pub peak_input_nodes: u32,
    /// Touches whose key could not be logged because the bounded
    /// touched set saturated (sketch only). A saturated window is
    /// closed with a full sketch clear, so the unenumerated keys'
    /// counts are **discarded with it** — a one-window undercount the
    /// decayed drift profile absorbs. Persistent nonzero values mean
    /// the drain interval is too long for the traffic.
    pub dropped_touches: u64,
}

/// Serving-time access accumulator: the hot path records, the
/// background [`Refresher`](super::Refresher) drains.
///
/// Implementations must be cheap enough for one call per gathered node
/// / sampled element on the serving path, and safe to drain
/// concurrently with recording.
pub trait WorkloadTracker: Send + Sync {
    /// Implementation name (`"dense"` | `"sketch"`), for logs/benches.
    fn name(&self) -> &'static str;

    /// Record one feature-stage visit of `v` (gather stage), untagged —
    /// equivalent to `record_node_as(TenantClass::Standard, v)`.
    fn record_node(&self, v: NodeId) {
        self.record_node_as(TenantClass::Standard, v);
    }

    /// Record one feature-stage visit of `v` under an admission class.
    /// The class changes which per-class profile the refresh loop
    /// credits, never the aggregate count.
    fn record_node_as(&self, class: TenantClass, v: NodeId);

    /// Record a whole batch's feature-stage visits in one virtual call.
    /// The gather hot path hands its entire input slice here instead of
    /// paying one dynamic dispatch per node — the default forwards to
    /// [`WorkloadTracker::record_node_as`] in a static inner loop, so
    /// implementations inherit identical counts for free and may
    /// override only if they can batch more cheaply.
    fn record_nodes(&self, nodes: &[NodeId]) {
        self.record_nodes_as(TenantClass::Standard, nodes);
    }

    /// Class-tagged [`WorkloadTracker::record_nodes`].
    fn record_nodes_as(&self, class: TenantClass, nodes: &[NodeId]) {
        for &v in nodes {
            self.record_node_as(class, v);
        }
    }

    /// Record `boost` untagged visits of every node — the live-graph
    /// mutation bump (`refresh.mutation-boost=`): mutated nodes get
    /// extra mass in the drift profile so the next re-plan re-caches
    /// them even before organic traffic finds the new edges. Off the
    /// serving hot path (mutations are rare), so the default loop is
    /// fine for both implementations.
    fn record_nodes_boosted(&self, nodes: &[NodeId], boost: u32) {
        for _ in 0..boost {
            self.record_nodes(nodes);
        }
    }

    /// Record one adjacency-element access at CSC offset `at`
    /// (sampling stage). Deliberately class-blind: a per-class elem
    /// split would multiply the O(n_edges) counter memory by
    /// `N_CLASSES` for a signal the planner's adjacency fill barely
    /// uses — class weighting acts on node visits only.
    fn record_elem(&self, at: usize);

    /// Record a served batch's modeled stage times (Eq. 1 ratio input)
    /// and its input-node count (the workload peak-claim input of the
    /// per-epoch auto-budget re-evaluation).
    fn record_batch(&self, t_sample_ns: f64, t_feature_ns: f64, input_nodes: u32);

    /// Batches recorded since the last drain.
    fn batches(&self) -> u64;

    /// Take the window's counts, resetting the tracker.
    fn drain(&self) -> DrainedWindow;

    /// `(node, elem)` heavy-hitter caps the refresh accumulator should
    /// prune to, or `None` for exact (unbounded) accumulation. A
    /// sketch bounds its own drain, so it also bounds the decayed
    /// profile built from it — keeping the whole refresh path
    /// O(touched + caps) in memory and time.
    fn heavy_hitter_caps(&self) -> Option<(usize, usize)>;
}

/// Batch counter + modeled stage-time accumulators shared by both
/// tracker implementations (integer ns so relaxed adds commute).
#[derive(Default)]
struct StageClock {
    batches: AtomicU64,
    t_sample_ns: AtomicU64,
    t_feature_ns: AtomicU64,
    /// `fetch_max` of per-batch input-node counts (peak-claim input).
    peak_inputs: AtomicU32,
}

impl StageClock {
    fn record_batch(&self, t_sample_ns: f64, t_feature_ns: f64, input_nodes: u32) {
        self.batches.fetch_add(1, Ordering::Relaxed);
        self.t_sample_ns
            .fetch_add(t_sample_ns.max(0.0) as u64, Ordering::Relaxed);
        self.t_feature_ns
            .fetch_add(t_feature_ns.max(0.0) as u64, Ordering::Relaxed);
        self.peak_inputs.fetch_max(input_nodes, Ordering::Relaxed);
    }

    fn batches(&self) -> u64 {
        self.batches.load(Ordering::Relaxed)
    }

    /// Drain into `(batches, t_sample_ns, t_feature_ns, peak_inputs)`.
    fn drain(&self) -> (u64, f64, f64, u32) {
        (
            self.batches.swap(0, Ordering::Relaxed),
            self.t_sample_ns.swap(0, Ordering::Relaxed) as f64,
            self.t_feature_ns.swap(0, Ordering::Relaxed) as f64,
            self.peak_inputs.swap(0, Ordering::Relaxed),
        )
    }
}

// ---------------------------------------------------------------------------
// Dense tracker (the PR 2 shape, now one of two implementations)
// ---------------------------------------------------------------------------

/// Exact dense tracker: one `AtomicU32` per node and per CSC element.
/// O(nodes + edges) memory and drain cost — the accuracy reference
/// `tracker=sketch` is measured against (`benches/sketch_tracker.rs`).
///
/// The hot path adds with relaxed atomics (u32 adds commute, so counts
/// are exact whatever the thread interleaving); the refresher drains
/// with `swap(0)`, so a touch racing the drain lands in exactly one
/// window.
pub struct AccessTracker {
    /// `N_CLASSES` interleaved counters per node
    /// (`v * N_CLASSES + class.index()`), so a class-tagged record is
    /// still one relaxed add.
    node_visits: Vec<AtomicU32>,
    elem_counts: Vec<AtomicU32>,
    /// Set by any non-`standard` touch; swapped at drain. An untagged
    /// window skips materializing the per-class split entirely.
    tagged: AtomicBool,
    clock: StageClock,
}

impl AccessTracker {
    /// A tracker sized for `n_nodes` nodes and `n_edges` CSC elements.
    pub fn new(n_nodes: usize, n_edges: usize) -> Self {
        AccessTracker {
            node_visits: (0..n_nodes * N_CLASSES).map(|_| AtomicU32::new(0)).collect(),
            elem_counts: (0..n_edges).map(|_| AtomicU32::new(0)).collect(),
            tagged: AtomicBool::new(false),
            clock: StageClock::default(),
        }
    }
}

impl WorkloadTracker for AccessTracker {
    fn name(&self) -> &'static str {
        "dense"
    }

    #[inline]
    fn record_node_as(&self, class: TenantClass, v: NodeId) {
        self.node_visits[v as usize * N_CLASSES + class.index()]
            .fetch_add(1, Ordering::Relaxed);
        if class != TenantClass::Standard {
            self.tagged.store(true, Ordering::Relaxed);
        }
    }

    #[inline]
    fn record_elem(&self, at: usize) {
        self.elem_counts[at].fetch_add(1, Ordering::Relaxed);
    }

    fn record_batch(&self, t_sample_ns: f64, t_feature_ns: f64, input_nodes: u32) {
        self.clock.record_batch(t_sample_ns, t_feature_ns, input_nodes);
    }

    fn batches(&self) -> u64 {
        self.clock.batches()
    }

    /// O(nodes + edges): scans both arrays, emitting nonzero entries.
    fn drain(&self) -> DrainedWindow {
        let tagged = self.tagged.swap(false, Ordering::Relaxed);
        let n_nodes = self.node_visits.len() / N_CLASSES;
        let mut node_visits = Vec::new();
        let mut class_node_visits = Vec::new();
        for v in 0..n_nodes {
            let mut per = [0u32; N_CLASSES];
            let mut total = 0u32;
            for (c, slot) in per.iter_mut().enumerate() {
                *slot = self.node_visits[v * N_CLASSES + c].swap(0, Ordering::Relaxed);
                total = total.saturating_add(*slot);
            }
            if total > 0 {
                node_visits.push((v as NodeId, total));
                if tagged {
                    class_node_visits.push((v as NodeId, per));
                }
            }
        }
        let elem_counts = self
            .elem_counts
            .iter()
            .enumerate()
            .filter_map(|(e, c)| {
                let c = c.swap(0, Ordering::Relaxed);
                (c > 0).then_some((e as u64, c))
            })
            .collect();
        let (batches, t_sample_ns, t_feature_ns, peak_input_nodes) = self.clock.drain();
        DrainedWindow {
            node_visits,
            class_node_visits,
            elem_counts,
            batches,
            t_sample_ns,
            t_feature_ns,
            peak_input_nodes,
            dropped_touches: 0,
        }
    }

    fn heavy_hitter_caps(&self) -> Option<(usize, usize)> {
        None
    }
}

// ---------------------------------------------------------------------------
// Count-min sketch
// ---------------------------------------------------------------------------

/// Default point-query error target: estimates within `ε·total` of the
/// true count. `1e-4` makes the absolute error ≤ 1% of any key holding
/// ≥ 1% of the window's mass — the "≤ 1% relative error on hot nodes"
/// target (hot nodes are the only ones a cache plan acts on).
pub const DEFAULT_EPSILON: f64 = 1e-4;

/// Default failure probability of the ε bound per query.
pub const DEFAULT_DELTA: f64 = 1e-2;

/// Hard ceiling on sketch depth (rows). δ = e^-16 ≈ 1e-7 is far past
/// any useful failure probability, and the bound lets the hot-path
/// update keep its row indices on the stack.
pub const MAX_SKETCH_DEPTH: usize = 16;

/// The standard count-min dimensioning: `width = ⌈e/ε⌉` rows wide (one
/// row's expected overcount is `total/width ≤ ε·total/e`, so Markov
/// gives `P[overcount > ε·total] ≤ 1/e` per row) and `depth =
/// ⌈ln(1/δ)⌉` independent rows (the estimate is the row minimum, so
/// all rows must fail at once: `(1/e)^depth ≤ δ`), capped at
/// [`MAX_SKETCH_DEPTH`].
pub fn cms_dims(epsilon: f64, delta: f64) -> (usize, usize) {
    let width = (std::f64::consts::E / epsilon).ceil().max(1.0) as usize;
    let depth = (1.0 / delta).ln().ceil().max(1.0) as usize;
    (width, depth.min(MAX_SKETCH_DEPTH))
}

/// A conservative-update count-min sketch over `u64` keys.
///
/// `add` reads the key's current estimate (minimum over its `depth`
/// cells) and raises only the cells below `estimate + 1` — the
/// conservative-update variant, which never undercounts a
/// single-writer stream and overcounts strictly less than the textbook
/// `fetch_add` update. Cells are atomics so a concurrent reader
/// (the draining refresher) sees consistent `u32`s.
pub struct CountMinSketch {
    width: usize,
    depth: usize,
    /// `depth` rows of `width` cells, row-major.
    cells: Vec<AtomicU32>,
}

impl CountMinSketch {
    /// A sketch with explicit dimensions (see [`cms_dims`]; `depth` is
    /// clamped to `1..=`[`MAX_SKETCH_DEPTH`]).
    pub fn new(width: usize, depth: usize) -> Self {
        let width = width.max(1);
        let depth = depth.clamp(1, MAX_SKETCH_DEPTH);
        CountMinSketch {
            width,
            depth,
            cells: (0..width * depth).map(|_| AtomicU32::new(0)).collect(),
        }
    }

    /// A sketch dimensioned from error bounds (see [`cms_dims`]).
    pub fn from_error_bounds(epsilon: f64, delta: f64) -> Self {
        let (w, d) = cms_dims(epsilon, delta);
        Self::new(w, d)
    }

    /// Cells per row.
    pub fn width(&self) -> usize {
        self.width
    }

    /// Independent rows.
    pub fn depth(&self) -> usize {
        self.depth
    }

    /// Flat cell index of `key` in `row`: per-row seed folded into the
    /// key before the shared splitmix64 mix (same avalanche
    /// `ShardRouter` relies on).
    #[inline]
    fn index(&self, row: usize, key: u64) -> usize {
        let h = splitmix64(key ^ (((row as u64) << 56) | 0x5bd1_e995));
        row * self.width + (h % self.width as u64) as usize
    }

    #[inline]
    fn cell(&self, row: usize, key: u64) -> &AtomicU32 {
        &self.cells[self.index(row, key)]
    }

    /// Conservative-update increment of `key` by one. Hashes each row
    /// once: the indices found while taking the minimum are reused for
    /// the raise — this runs once per gathered node / sampled element
    /// on the serving hot path.
    #[inline]
    pub fn add(&self, key: u64) {
        let mut idx = [0usize; MAX_SKETCH_DEPTH];
        let mut est = u32::MAX;
        for row in 0..self.depth {
            let i = self.index(row, key);
            idx[row] = i;
            est = est.min(self.cells[i].load(Ordering::Relaxed));
        }
        let target = est.saturating_add(1);
        for &i in &idx[..self.depth] {
            self.cells[i].fetch_max(target, Ordering::Relaxed);
        }
    }

    /// Point estimate: minimum over the key's cells (never below the
    /// true count of a single-writer stream).
    #[inline]
    pub fn estimate(&self, key: u64) -> u32 {
        (0..self.depth)
            .map(|row| self.cell(row, key).load(Ordering::Relaxed))
            .min()
            .unwrap_or(0)
    }

    /// Zero only `key`'s cells — O(depth). Draining a window clears
    /// exactly the cells its touched keys hash into (collided keys
    /// share cells; zeroing twice is harmless), so no O(width·depth)
    /// sweep is needed on the common path.
    pub fn clear_key(&self, key: u64) {
        for row in 0..self.depth {
            self.cell(row, key).store(0, Ordering::Relaxed);
        }
    }

    /// Zero every cell — the fallback when the touched set saturated
    /// and the per-key clear cannot reach every written cell.
    pub fn clear_all(&self) {
        for c in &self.cells {
            c.store(0, Ordering::Relaxed);
        }
    }
}

// ---------------------------------------------------------------------------
// Bounded touched-set
// ---------------------------------------------------------------------------

/// Linear probes before an insert gives up and counts a drop.
const MAX_PROBES: usize = 64;

/// Log slot that holds no key this window (keys must be < `u64::MAX`;
/// node ids and CSC offsets always are).
const EMPTY_LOG: u64 = u64::MAX;

/// A bounded lock-free "keys touched since last drain" set: an
/// open-addressed table for dedup plus an append log for O(touched)
/// enumeration. Capacity is fixed at construction; an insert that
/// cannot find a slot (or a full log) increments `dropped` instead of
/// blocking — see [`DrainedWindow::dropped_touches`] for what a
/// saturated window costs.
struct TouchedSet {
    /// Open-addressed dedup table; a slot holds `key + 1` (0 = empty).
    table: Vec<AtomicU64>,
    /// Insertion-ordered log of unique keys; unwritten/retired slots
    /// hold [`EMPTY_LOG`].
    log: Vec<AtomicU64>,
    /// Log slots handed out to inserts (may briefly run ahead of
    /// `committed` while an insert's slot store is in flight).
    reserved: AtomicUsize,
    /// Log slots whose key store has completed (`Release`; the drain's
    /// `Acquire` load makes those stores visible).
    committed: AtomicUsize,
    dropped: AtomicU64,
}

impl TouchedSet {
    /// A set logging up to `cap` unique keys per window (rounded up to
    /// a power of two; the dedup table is twice that for load ≤ 0.5).
    fn new(cap: usize) -> Self {
        let cap = cap.max(8).next_power_of_two();
        TouchedSet {
            table: (0..cap * 2).map(|_| AtomicU64::new(0)).collect(),
            log: (0..cap).map(|_| AtomicU64::new(EMPTY_LOG)).collect(),
            reserved: AtomicUsize::new(0),
            committed: AtomicUsize::new(0),
            dropped: AtomicU64::new(0),
        }
    }

    fn capacity(&self) -> usize {
        self.log.len()
    }

    /// Record `key` as touched (idempotent per window).
    fn insert(&self, key: u64) {
        let tag = key + 1;
        let mask = self.table.len() - 1;
        let mut at = (splitmix64(key) as usize) & mask;
        for _ in 0..MAX_PROBES {
            let cur = self.table[at].load(Ordering::Relaxed);
            if cur == tag {
                return; // already logged this window
            }
            if cur == 0 {
                match self.table[at].compare_exchange(
                    0,
                    tag,
                    Ordering::Relaxed,
                    Ordering::Relaxed,
                ) {
                    Ok(_) => {
                        let i = self.reserved.fetch_add(1, Ordering::Relaxed);
                        if i < self.log.len() {
                            self.log[i].store(key, Ordering::Relaxed);
                            self.committed.fetch_add(1, Ordering::Release);
                        } else {
                            // log full: undo nothing (the table entry
                            // keeps dedup working), count the miss.
                            // `reserved` keeps growing until the drain
                            // resets it — pinning it back here could
                            // race a drain's reset and poison a later
                            // window's reservations.
                            self.dropped.fetch_add(1, Ordering::Relaxed);
                        }
                        return;
                    }
                    Err(now) if now == tag => return,
                    Err(_) => {} // someone else took the slot; keep probing
                }
            }
            at = (at + 1) & mask;
        }
        self.dropped.fetch_add(1, Ordering::Relaxed);
    }

    /// Enumerate the window's keys, clear the set, and return
    /// `(keys, dropped)`.
    ///
    /// Concurrency: a recorder racing this drain (it read the lane
    /// pointer just before the tracker flipped lanes) cannot ghost a
    /// key — a key is "ghosted" if its dedup-table tag survives a
    /// drain that never enumerated it, muting every later touch:
    /// - an insert still between its table CAS and its slot
    ///   reservation simply reserves in the *next* window (the drain
    ///   resets the counters, not the straggler's tag), so the key is
    ///   enumerated — and its table entry cleared — one window late;
    /// - an insert whose slot store is still in flight is caught by
    ///   `reserved != committed` (or by its slot still reading
    ///   [`EMPTY_LOG`] under the `Acquire`/`Release` pairing) and
    ///   forces the saturation path, whose full table sweep erases the
    ///   straggler's tag so the key re-logs on its next touch.
    fn drain(&self) -> (Vec<u64>, u64) {
        let c = self.committed.load(Ordering::Acquire);
        let r = self.reserved.load(Ordering::Relaxed);
        let n = c.min(self.log.len());
        let mut skipped = 0u64;
        let keys: Vec<u64> = (0..n)
            .filter_map(|i| {
                let k = self.log[i].swap(EMPTY_LOG, Ordering::Relaxed);
                if k == EMPTY_LOG {
                    skipped += 1;
                    None
                } else {
                    Some(k)
                }
            })
            .collect();
        let mut dropped = self.dropped.swap(0, Ordering::Relaxed) + skipped;
        // clean only if no insert was in flight across our snapshot
        let clean = r == c
            && self
                .reserved
                .compare_exchange(r, 0, Ordering::Relaxed, Ordering::Relaxed)
                .is_ok();
        if !clean {
            self.reserved.store(0, Ordering::Relaxed);
            dropped += 1;
        }
        self.committed.store(0, Ordering::Relaxed);
        if dropped > 0 {
            // some touched keys never made the log (or a straggler's
            // entry is unaccounted); only a full sweep clears their
            // table entries
            for slot in &self.table {
                slot.store(0, Ordering::Relaxed);
            }
        } else {
            let mask = self.table.len() - 1;
            for &key in &keys {
                let tag = key + 1;
                let mut at = (splitmix64(key) as usize) & mask;
                for _ in 0..MAX_PROBES {
                    if self.table[at]
                        .compare_exchange(tag, 0, Ordering::Relaxed, Ordering::Relaxed)
                        .is_ok()
                    {
                        break;
                    }
                    at = (at + 1) & mask;
                }
            }
        }
        (keys, dropped)
    }
}

// ---------------------------------------------------------------------------
// Sketch tracker
// ---------------------------------------------------------------------------

/// One key space's sketches + shared touched set. The node lane holds
/// one sketch per admission class (estimates split by class, one
/// touched-set insert per record); the element lane holds a single
/// class-blind sketch.
struct SketchLane {
    sketches: Vec<CountMinSketch>,
    touched: TouchedSet,
}

impl SketchLane {
    fn new(n_sketches: usize, width: usize, depth: usize, touch_cap: usize) -> Self {
        SketchLane {
            sketches: (0..n_sketches.clamp(1, N_CLASSES))
                .map(|_| CountMinSketch::new(width, depth))
                .collect(),
            touched: TouchedSet::new(touch_cap),
        }
    }

    /// Record `key` into sketch `which` (a class index, or 0 for the
    /// single-sketch element lane).
    #[inline]
    fn record_in(&self, which: usize, key: u64) {
        self.sketches[which].add(key);
        self.touched.insert(key);
    }

    /// Enumerate per-sketch estimates for the window's touched keys
    /// and reset the lane: O(touched · sketches · depth), never O(key
    /// space). Unused trailing class slots stay zero. A saturated
    /// window (dropped > 0) falls back to the full-sweep clear,
    /// discarding the unenumerated keys' counts with it — leaving them
    /// in place would inflate later windows' estimates forever, since
    /// no future enumeration would ever clear them.
    fn drain(&self) -> (Vec<(u64, [u32; N_CLASSES])>, u64) {
        let (keys, dropped) = self.touched.drain();
        let out = keys
            .iter()
            .map(|&k| {
                let mut ests = [0u32; N_CLASSES];
                for (e, s) in ests.iter_mut().zip(self.sketches.iter()) {
                    *e = s.estimate(k);
                }
                (k, ests)
            })
            .collect();
        if dropped > 0 {
            for s in &self.sketches {
                s.clear_all();
            }
        } else {
            for &k in &keys {
                for s in &self.sketches {
                    s.clear_key(k);
                }
            }
        }
        (out, dropped)
    }
}

/// Per-window log capacity for node touches (unique nodes per drain
/// interval; table + log = 3 × cap × 8 B ≈ 1.5 MiB per lane at the
/// default).
const NODE_TOUCH_CAP: usize = 1 << 16;

/// Per-window log capacity for CSC-element touches (sampling touches
/// several elements per node, so this is 2 bits larger — ≈ 6 MiB of
/// table + log per lane at the default).
const ELEM_TOUCH_CAP: usize = 1 << 18;

/// Sketch-based [`WorkloadTracker`]: constant memory, O(touched) drain.
///
/// Two [`CountMinSketch`]es (node visits, CSC-element accesses) paired
/// with bounded touched sets, double-buffered into two lanes: the hot
/// path records into the active lane, `drain` flips the active lane
/// and enumerates the previous one — so recording never waits on a
/// drain, and a drain never scans a structure sized by the graph.
/// Touches racing the flip land on either side of the window boundary
/// (the dense tracker is exact there; see the module docs).
pub struct SketchTracker {
    lanes: [[SketchLane; 2]; 2],
    /// Active lane index (0/1) for both key spaces.
    active: AtomicUsize,
    /// Any non-`standard` node touch since the last drain (see
    /// [`AccessTracker::drain`]'s untagged fast path).
    tagged: AtomicBool,
    clock: StageClock,
}

/// Which key space a lane pair tracks.
const NODES: usize = 0;
const ELEMS: usize = 1;

impl SketchTracker {
    /// A tracker with explicit sketch dimensions. `n_nodes` / `n_edges`
    /// only clamp the touched-set capacities (a key space smaller than
    /// the cap needs no larger log); no O(nodes) or O(edges) array is
    /// ever allocated.
    pub fn new(n_nodes: usize, n_edges: usize, width: usize, depth: usize) -> Self {
        let node_cap = NODE_TOUCH_CAP.min(n_nodes.next_power_of_two().max(8));
        let elem_cap = ELEM_TOUCH_CAP.min(n_edges.next_power_of_two().max(8));
        let lane = |n_sketches: usize, cap: usize| {
            [
                SketchLane::new(n_sketches, width, depth, cap),
                SketchLane::new(n_sketches, width, depth, cap),
            ]
        };
        SketchTracker {
            // the node lane splits estimates per admission class; the
            // element lane stays class-blind (one sketch)
            lanes: [lane(N_CLASSES, node_cap), lane(1, elem_cap)],
            active: AtomicUsize::new(0),
            tagged: AtomicBool::new(false),
            clock: StageClock::default(),
        }
    }

    /// A tracker at the default ε/δ ([`DEFAULT_EPSILON`],
    /// [`DEFAULT_DELTA`]).
    pub fn with_defaults(n_nodes: usize, n_edges: usize) -> Self {
        let (w, d) = cms_dims(DEFAULT_EPSILON, DEFAULT_DELTA);
        Self::new(n_nodes, n_edges, w, d)
    }

    /// Touched-set log capacities `(node, elem)` — also the heavy-
    /// hitter caps handed to the refresh accumulator.
    pub fn touch_caps(&self) -> (usize, usize) {
        (
            self.lanes[NODES][0].touched.capacity(),
            self.lanes[ELEMS][0].touched.capacity(),
        )
    }

    #[inline]
    fn lane(&self, space: usize) -> &SketchLane {
        &self.lanes[space][self.active.load(Ordering::Relaxed)]
    }
}

impl WorkloadTracker for SketchTracker {
    fn name(&self) -> &'static str {
        "sketch"
    }

    #[inline]
    fn record_node_as(&self, class: TenantClass, v: NodeId) {
        self.lane(NODES).record_in(class.index(), v as u64);
        if class != TenantClass::Standard {
            self.tagged.store(true, Ordering::Relaxed);
        }
    }

    #[inline]
    fn record_elem(&self, at: usize) {
        self.lane(ELEMS).record_in(0, at as u64);
    }

    fn record_batch(&self, t_sample_ns: f64, t_feature_ns: f64, input_nodes: u32) {
        self.clock.record_batch(t_sample_ns, t_feature_ns, input_nodes);
    }

    fn batches(&self) -> u64 {
        self.clock.batches()
    }

    /// Flip the active lane, then enumerate + reset the previous one:
    /// O(touched · depth) work, independent of nodes + edges.
    fn drain(&self) -> DrainedWindow {
        let prev = self.active.fetch_xor(1, Ordering::Relaxed);
        let tagged = self.tagged.swap(false, Ordering::Relaxed);
        let (nodes, nd) = self.lanes[NODES][prev].drain();
        let (elems, ed) = self.lanes[ELEMS][prev].drain();
        let (batches, t_sample_ns, t_feature_ns, peak_input_nodes) = self.clock.drain();
        let node_visits = nodes
            .iter()
            .map(|&(k, per)| {
                let total = per.iter().fold(0u32, |a, &c| a.saturating_add(c));
                (k as NodeId, total)
            })
            .collect();
        let class_node_visits = if tagged {
            nodes.iter().map(|&(k, per)| (k as NodeId, per)).collect()
        } else {
            Vec::new()
        };
        DrainedWindow {
            node_visits,
            class_node_visits,
            elem_counts: elems.into_iter().map(|(k, per)| (k, per[0])).collect(),
            batches,
            t_sample_ns,
            t_feature_ns,
            peak_input_nodes,
            dropped_touches: nd + ed,
        }
    }

    fn heavy_hitter_caps(&self) -> Option<(usize, usize)> {
        Some(self.touch_caps())
    }
}

// ---------------------------------------------------------------------------
// Selection knob
// ---------------------------------------------------------------------------

/// Which [`WorkloadTracker`] implementation the serving path records
/// into (`tracker=` knob).
#[derive(Debug, Clone, Copy, PartialEq, Eq, Default)]
pub enum TrackerKind {
    /// Exact O(nodes + edges) counters ([`AccessTracker`]).
    #[default]
    Dense,
    /// Count-min sketch + bounded touched set ([`SketchTracker`]).
    Sketch,
}

impl TrackerKind {
    /// Parse `dense` | `sketch`.
    pub fn parse(s: &str) -> anyhow::Result<Self> {
        match s.to_ascii_lowercase().as_str() {
            "dense" => Ok(TrackerKind::Dense),
            "sketch" | "cms" => Ok(TrackerKind::Sketch),
            other => anyhow::bail!("unknown tracker {other:?} (dense|sketch)"),
        }
    }

    /// Canonical knob value.
    pub fn as_str(&self) -> &'static str {
        match self {
            TrackerKind::Dense => "dense",
            TrackerKind::Sketch => "sketch",
        }
    }
}

/// Workload-tracker construction knobs (`tracker=`, `sketch-width=`,
/// `sketch-depth=`).
#[derive(Debug, Clone, PartialEq, Eq, Default)]
pub struct TrackerConfig {
    /// Implementation to build.
    pub kind: TrackerKind,
    /// Sketch row width override (`None` = derive from
    /// [`DEFAULT_EPSILON`]).
    pub width: Option<usize>,
    /// Sketch depth override (`None` = derive from [`DEFAULT_DELTA`]).
    pub depth: Option<usize>,
}

impl TrackerConfig {
    /// Build the configured tracker for a graph with `n_nodes` nodes
    /// and `n_edges` CSC elements.
    pub fn build(
        &self,
        n_nodes: usize,
        n_edges: usize,
    ) -> std::sync::Arc<dyn WorkloadTracker> {
        match self.kind {
            TrackerKind::Dense => {
                std::sync::Arc::new(AccessTracker::new(n_nodes, n_edges))
            }
            TrackerKind::Sketch => {
                let (dw, dd) = cms_dims(DEFAULT_EPSILON, DEFAULT_DELTA);
                std::sync::Arc::new(SketchTracker::new(
                    n_nodes,
                    n_edges,
                    self.width.unwrap_or(dw),
                    self.depth.unwrap_or(dd),
                ))
            }
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use std::collections::HashMap;

    #[test]
    fn dense_tracker_counts_and_drains() {
        let t = AccessTracker::new(4, 6);
        t.record_node(1);
        t.record_node(1);
        t.record_node(3);
        t.record_elem(5);
        t.record_batch(100.0, 200.0, 37);
        t.record_batch(0.0, 0.0, 12);
        assert_eq!(t.batches(), 2);
        let d = t.drain();
        assert_eq!(d.node_visits, vec![(1, 2), (3, 1)]);
        assert!(
            d.class_node_visits.is_empty(),
            "untagged windows must skip the per-class split"
        );
        assert_eq!(d.elem_counts, vec![(5, 1)]);
        assert_eq!(d.batches, 2);
        assert_eq!(d.t_sample_ns, 100.0);
        assert_eq!(d.t_feature_ns, 200.0);
        assert_eq!(d.peak_input_nodes, 37, "peak is the max, not the last");
        assert_eq!(d.dropped_touches, 0);
        // drained: everything reset
        let d2 = t.drain();
        assert_eq!(d2.batches, 0);
        assert_eq!(d2.peak_input_nodes, 0);
        assert!(d2.node_visits.is_empty() && d2.elem_counts.is_empty());
        assert!(t.heavy_hitter_caps().is_none());
    }

    #[test]
    fn cms_dims_match_the_textbook_formulas() {
        let (w, d) = cms_dims(DEFAULT_EPSILON, DEFAULT_DELTA);
        assert_eq!(w, (std::f64::consts::E / DEFAULT_EPSILON).ceil() as usize);
        assert_eq!(d, 5); // ln(100) = 4.6 → 5
        let (w, d) = cms_dims(0.01, 0.001);
        assert_eq!(w, 272);
        assert_eq!(d, 7);
    }

    #[test]
    fn sketch_is_exact_without_collisions() {
        // width far above the key count: every estimate is exact
        let s = CountMinSketch::new(4096, 4);
        for k in 0..100u64 {
            for _ in 0..=k {
                s.add(k);
            }
        }
        for k in 0..100u64 {
            assert_eq!(s.estimate(k), k as u32 + 1, "key {k}");
        }
        s.clear_key(7);
        assert_eq!(s.estimate(7), 0);
        s.clear_all();
        assert_eq!(s.estimate(50), 0);
    }

    #[test]
    fn sketch_never_undercounts_under_collisions() {
        // tiny sketch: collisions guaranteed; conservative updates must
        // still never undercount
        let s = CountMinSketch::new(16, 2);
        let mut truth: HashMap<u64, u32> = HashMap::new();
        let mut x = 9u64;
        for _ in 0..5_000 {
            // skewed deterministic stream
            x = x.wrapping_mul(6364136223846793005).wrapping_add(1442695040888963407);
            let key = (x >> 33) % 64;
            let key = key * key / 8; // heavier head
            s.add(key);
            *truth.entry(key).or_insert(0) += 1;
        }
        for (&k, &c) in &truth {
            assert!(s.estimate(k) >= c, "key {k}: est {} < true {c}", s.estimate(k));
        }
    }

    #[test]
    fn touched_set_dedups_and_drains() {
        let t = TouchedSet::new(64);
        for _ in 0..3 {
            t.insert(10);
            t.insert(20);
        }
        t.insert(30);
        let (keys, dropped) = t.drain();
        assert_eq!(keys, vec![10, 20, 30]);
        assert_eq!(dropped, 0);
        // cleared: keys can be re-logged next window
        t.insert(20);
        let (keys, _) = t.drain();
        assert_eq!(keys, vec![20]);
    }

    #[test]
    fn touched_set_bounds_and_reports_drops() {
        let t = TouchedSet::new(8); // rounds to 8
        for k in 0..100u64 {
            t.insert(k);
        }
        let (keys, dropped) = t.drain();
        assert!(keys.len() <= 8);
        assert!(dropped > 0);
        // saturation recovered: the next window logs cleanly again
        t.insert(1);
        let (keys, dropped) = t.drain();
        assert_eq!(keys, vec![1]);
        assert_eq!(dropped, 0);
    }

    #[test]
    fn sketch_tracker_drains_in_o_touched_and_matches_dense() {
        let n_nodes = 1000;
        let n_edges = 5000;
        let dense = AccessTracker::new(n_nodes, n_edges);
        let sketch = SketchTracker::with_defaults(n_nodes, n_edges);
        // a sparse window: 20 nodes, 40 elements
        for v in (0..n_nodes as u32).step_by(50) {
            for _ in 0..3 {
                dense.record_node(v);
                sketch.record_node(v);
            }
        }
        for e in (0..n_edges).step_by(125) {
            dense.record_elem(e);
            sketch.record_elem(e);
        }
        dense.record_batch(10.0, 20.0, 60);
        sketch.record_batch(10.0, 20.0, 60);

        let dw = dense.drain();
        let sw = sketch.drain();
        assert_eq!(sw.batches, dw.batches);
        assert_eq!(sw.peak_input_nodes, dw.peak_input_nodes);
        assert_eq!(sw.dropped_touches, 0);
        let to_map = |w: &[(NodeId, u32)]| -> HashMap<NodeId, u32> {
            w.iter().copied().collect()
        };
        // default ε on 60 distinct keys: no collisions, exact equality
        assert_eq!(to_map(&sw.node_visits), to_map(&dw.node_visits));
        let ed: HashMap<u64, u32> = dw.elem_counts.iter().copied().collect();
        let es: HashMap<u64, u32> = sw.elem_counts.iter().copied().collect();
        assert_eq!(es, ed);
        // second drain is empty (lane flipped back and cleared)
        assert!(sketch.drain().node_visits.is_empty());
        assert!(sketch.heavy_hitter_caps().is_some());
    }

    #[test]
    fn dense_tracker_splits_counts_per_class() {
        let t = AccessTracker::new(4, 2);
        t.record_node_as(TenantClass::Priority, 1);
        t.record_node_as(TenantClass::Priority, 1);
        t.record_node_as(TenantClass::Scan, 1);
        t.record_node(2); // untagged = standard
        let d = t.drain();
        // aggregate is the class sum, in node order
        assert_eq!(d.node_visits, vec![(1, 3), (2, 1)]);
        let p = TenantClass::Priority.index();
        let s = TenantClass::Standard.index();
        let c = TenantClass::Scan.index();
        assert_eq!(d.class_node_visits.len(), 2);
        let (n1, per1) = d.class_node_visits[0];
        assert_eq!(n1, 1);
        assert_eq!((per1[p], per1[s], per1[c]), (2, 0, 1));
        let (n2, per2) = d.class_node_visits[1];
        assert_eq!(n2, 2);
        assert_eq!((per2[p], per2[s], per2[c]), (0, 1, 0));
        // the tag resets with the window: a standard-only window after
        // a tagged one is untagged again
        t.record_node(2);
        let d = t.drain();
        assert_eq!(d.node_visits, vec![(2, 1)]);
        assert!(d.class_node_visits.is_empty());
    }

    #[test]
    fn sketch_tracker_class_split_matches_dense() {
        let dense = AccessTracker::new(100, 10);
        let sketch = SketchTracker::with_defaults(100, 10);
        for t in [&dense as &dyn WorkloadTracker, &sketch as &dyn WorkloadTracker] {
            t.record_nodes_as(TenantClass::Priority, &[5, 5, 7]);
            t.record_nodes_as(TenantClass::Scan, &[5, 9]);
            t.record_nodes(&[9]);
        }
        let dw = dense.drain();
        let sw = sketch.drain();
        let to_map = |w: &[(NodeId, [u32; N_CLASSES])]| -> HashMap<NodeId, [u32; N_CLASSES]> {
            w.iter().copied().collect()
        };
        // few distinct keys at default ε: sketch estimates are exact
        assert_eq!(to_map(&sw.class_node_visits), to_map(&dw.class_node_visits));
        assert_eq!(
            dw.node_visits.iter().copied().collect::<HashMap<_, _>>(),
            sw.node_visits.iter().copied().collect::<HashMap<_, _>>()
        );
        // both saw a tagged window
        assert!(!dw.class_node_visits.is_empty());
        assert!(!sw.class_node_visits.is_empty());
        // next (untagged) windows skip the split again
        dense.record_node(1);
        sketch.record_node(1);
        assert!(dense.drain().class_node_visits.is_empty());
        assert!(sketch.drain().class_node_visits.is_empty());
    }

    #[test]
    fn tracker_config_builds_both_kinds() {
        let dense = TrackerConfig::default().build(10, 10);
        assert_eq!(dense.name(), "dense");
        let cfg = TrackerConfig {
            kind: TrackerKind::Sketch,
            width: Some(128),
            depth: Some(3),
        };
        let sketch = cfg.build(10, 10);
        assert_eq!(sketch.name(), "sketch");
        assert!(TrackerKind::parse("bloom").is_err());
        assert_eq!(TrackerKind::parse("CMS").unwrap(), TrackerKind::Sketch);
        assert_eq!(TrackerKind::Sketch.as_str(), "sketch");
    }
}
