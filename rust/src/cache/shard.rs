//! Sharded multi-device cache snapshots.
//!
//! PR 2's [`DualCacheRuntime`] mirrors one full snapshot per worker —
//! on a multi-device node that wastes `(devices − 1)×` of the
//! aggregate cache capacity, exactly the "low practical GPU memory
//! utilization" failure mode the paper targets. This module shards one
//! *logical* snapshot across N simulated devices instead:
//!
//! - [`ShardRouter`] — the stable node-id → shard hash partition.
//!   Every node routes to exactly one shard (total partition; the
//!   property tests hold this), and the assignment never changes for
//!   the life of a deployment, so a node's cached state always lives
//!   on a known device.
//! - [`ShardedRuntime`] — one epoch-swappable [`DualCacheRuntime`] per
//!   shard behind the router. Each shard installs independently: a
//!   re-plan of shard *k* hot-swaps only shard *k*'s snapshot while
//!   the other shards keep serving their current epoch — PR 2's
//!   never-block invariant holds *per shard*.
//! - [`ShardedHandle`] / [`ShardView`] — the per-batch acquire path.
//!   A handle owns one [`SnapshotHandle`] per shard; `acquire`
//!   refreshes them all (one atomic epoch load each) and hands out a
//!   [`ShardView`] that routes feature lookups and adjacency reads by
//!   shard. A batch sees one snapshot per shard end to end, so a
//!   mid-batch install on any shard cannot mix epochs within that
//!   shard's reads.
//! - [`plan_sharded`] — splits the Eq. (1) budget per shard with
//!   [`split_budget`] (exact integer arithmetic, remainder to the
//!   first shards) and plans each shard from the workload profile
//!   *masked* to the shard's own nodes, so shard capacity is spent on
//!   nodes the shard will actually be asked for.
//!
//! Sharding is transparent the same way the caches themselves are: it
//! changes *which device* a byte is read from, never which byte — so
//! gather results and logits are bit-identical to the unsharded
//! runtime at any shard count (held by the property tests).

use std::sync::atomic::{AtomicU64, Ordering};
use std::sync::Arc;

use crate::graph::{Csc, Dataset, NodeId};
use crate::mem::TransferLedger;
use crate::sampler::AdjSource;

use super::planner::{split_budget, CachePlan, CachePlanner, WorkloadProfile};
use super::runtime::{CacheSnapshot, DualCacheRuntime, SnapshotHandle};

/// Stable node-id → shard assignment (splitmix64 finalizer, then mod).
///
/// The hash is a pure function of the node id, so the partition is
/// total and stable: every node maps to exactly one shard, forever.
#[derive(Debug, Clone)]
pub struct ShardRouter {
    n_shards: usize,
}

impl ShardRouter {
    /// A router over `n_shards ≥ 1` shards.
    pub fn new(n_shards: usize) -> Self {
        assert!(n_shards >= 1, "a snapshot has at least one shard");
        ShardRouter { n_shards }
    }

    /// Number of shards this router partitions over.
    pub fn n_shards(&self) -> usize {
        self.n_shards
    }

    /// The shard that owns `v`'s cached state.
    #[inline]
    pub fn shard_of(&self, v: NodeId) -> usize {
        if self.n_shards == 1 {
            return 0;
        }
        // splitmix64: cheap, stable, and avalanches low bits so
        // consecutive node ids spread across shards (the one shared
        // implementation in util — the partition must never drift)
        (crate::util::splitmix64(v as u64) % self.n_shards as u64) as usize
    }
}

/// One logical snapshot sharded across N devices: a [`DualCacheRuntime`]
/// per shard plus the router that assigns nodes to shards, and a
/// degraded-shard bitmask for fault tolerance (DESIGN.md §Fault
/// tolerance): a shard whose device install failed terminally is marked
/// degraded, and every [`ShardView`] bypasses its caches — feature
/// lookups miss to host memory, adjacency reads take the UVA path — so
/// serving stays correct (same bytes, no cache) until the repair loop
/// re-installs the shard and promotes it back.
pub struct ShardedRuntime {
    router: ShardRouter,
    shards: Vec<Arc<DualCacheRuntime>>,
    /// Bit `s` set = shard `s` is degraded (config caps `shards ≤ 64`,
    /// so one word covers every deployment).
    degraded: AtomicU64,
}

impl ShardedRuntime {
    /// Wrap per-shard initial snapshots (`snapshots.len()` must match
    /// the router's shard count).
    pub fn new(router: ShardRouter, snapshots: Vec<CacheSnapshot>) -> Self {
        assert_eq!(
            router.n_shards(),
            snapshots.len(),
            "one initial snapshot per shard"
        );
        assert!(
            router.n_shards() <= 64,
            "the degraded bitmask models at most 64 shards (config enforces this)"
        );
        let shards = snapshots
            .into_iter()
            .map(|s| Arc::new(DualCacheRuntime::new(s)))
            .collect();
        ShardedRuntime { router, shards, degraded: AtomicU64::new(0) }
    }

    /// The unsharded (single-device) runtime — the PR 2 shape.
    pub fn single(snapshot: CacheSnapshot) -> Self {
        ShardedRuntime::new(ShardRouter::new(1), vec![snapshot])
    }

    /// Number of shards (= simulated devices) in this runtime.
    pub fn n_shards(&self) -> usize {
        self.shards.len()
    }

    /// The node → shard partition this runtime routes by.
    pub fn router(&self) -> &ShardRouter {
        &self.router
    }

    /// Shard `s`'s swappable runtime (refreshers install through this).
    pub fn shard(&self, s: usize) -> &Arc<DualCacheRuntime> {
        &self.shards[s]
    }

    /// All per-shard runtimes (handle construction).
    pub fn shards(&self) -> &[Arc<DualCacheRuntime>] {
        &self.shards
    }

    /// Publish a new snapshot on shard `s` only; the other shards keep
    /// serving their current epoch.
    pub fn install_shard(&self, s: usize, snapshot: CacheSnapshot) -> u64 {
        self.shards[s].install(snapshot)
    }

    /// Single-shard install (the PR 2 API). Panics when sharded — a
    /// sharded deployment must say which shard it is replacing.
    pub fn install(&self, snapshot: CacheSnapshot) -> u64 {
        assert_eq!(
            self.shards.len(),
            1,
            "install() is the single-shard path; use install_shard(s, ..)"
        );
        self.shards[0].install(snapshot)
    }

    /// Shard 0's live snapshot — the reporting/startup path for
    /// single-shard deployments (tests and offline tools). Sharded
    /// callers iterate [`ShardedRuntime::snapshots`] instead.
    pub fn load(&self) -> Arc<CacheSnapshot> {
        self.shards[0].load()
    }

    /// Every shard's live snapshot (reporting, device accounting).
    pub fn snapshots(&self) -> Vec<Arc<CacheSnapshot>> {
        self.shards.iter().map(|s| s.load()).collect()
    }

    /// Installs performed across all shards.
    pub fn swaps(&self) -> u64 {
        self.shards.iter().map(|s| s.swaps()).sum()
    }

    /// Reader stalls across all shards (0 in a healthy deployment —
    /// the per-shard never-block invariant).
    pub fn swap_stalls(&self) -> u64 {
        self.shards.iter().map(|s| s.swap_stalls()).sum()
    }

    /// Benign one-batch deferrals across all shards.
    pub fn swap_deferrals(&self) -> u64 {
        self.shards.iter().map(|s| s.swap_deferrals()).sum()
    }

    /// Mark shard `s` degraded: every view acquired from now on
    /// bypasses its caches and reads from host memory. Returns whether
    /// the shard was healthy before (false = it was already degraded).
    pub fn mark_degraded(&self, s: usize) -> bool {
        assert!(s < self.shards.len(), "shard {s} out of range");
        let prev = self.degraded.fetch_or(1 << s, Ordering::AcqRel);
        prev & (1 << s) == 0
    }

    /// Promote shard `s` back to healthy after a successful repair
    /// install. Returns whether the shard was degraded before.
    pub fn mark_repaired(&self, s: usize) -> bool {
        assert!(s < self.shards.len(), "shard {s} out of range");
        let prev = self.degraded.fetch_and(!(1 << s), Ordering::AcqRel);
        prev & (1 << s) != 0
    }

    /// Is shard `s` currently degraded?
    pub fn is_degraded(&self, s: usize) -> bool {
        self.degraded_mask() & (1 << s) != 0
    }

    /// The degraded-shard bitmask (bit `s` = shard `s` degraded).
    /// Views snapshot this once per batch, so a batch sees one
    /// consistent health state per shard end to end.
    pub fn degraded_mask(&self) -> u64 {
        self.degraded.load(Ordering::Acquire)
    }

    /// How many shards are currently degraded.
    pub fn degraded_count(&self) -> u32 {
        self.degraded_mask().count_ones()
    }
}

/// A per-thread cursor over every shard's epochs: one
/// [`SnapshotHandle`] per shard, refreshed together once per batch.
pub struct ShardedHandle {
    rt: Arc<ShardedRuntime>,
    handles: Vec<SnapshotHandle>,
}

impl ShardedHandle {
    /// A handle over every shard of `rt`, starting on their current
    /// snapshots.
    pub fn new(rt: &Arc<ShardedRuntime>) -> ShardedHandle {
        let handles = rt.shards().iter().map(SnapshotHandle::new).collect();
        ShardedHandle { rt: Arc::clone(rt), handles }
    }

    /// The snapshots to use for the next batch: refresh every shard's
    /// handle (one atomic epoch load each on the fast path) and hand
    /// out the routed view. Each shard's never-block acquire semantics
    /// are unchanged — an install-concurrent shard serves one batch on
    /// its previous epoch instead of waiting.
    #[inline]
    pub fn acquire(&mut self) -> ShardView<'_> {
        for h in &mut self.handles {
            h.acquire();
        }
        ShardView {
            router: self.rt.router(),
            handles: &self.handles,
            degraded: self.rt.degraded_mask(),
        }
    }
}

/// The per-batch read view over all shards: routes feature lookups and
/// adjacency reads to the shard that owns each node. Shards whose
/// degraded bit is set in the view's health mask are bypassed — their
/// reads fall back to host memory exactly like a cacheless shard.
#[derive(Clone, Copy)]
pub struct ShardView<'a> {
    router: &'a ShardRouter,
    handles: &'a [SnapshotHandle],
    /// Degraded-shard bitmask as of this batch's acquire.
    degraded: u64,
}

impl<'a> ShardView<'a> {
    /// A view over externally managed handles (stage-level tests); all
    /// shards healthy.
    pub fn over(router: &'a ShardRouter, handles: &'a [SnapshotHandle]) -> ShardView<'a> {
        assert_eq!(router.n_shards(), handles.len());
        ShardView { router, handles, degraded: 0 }
    }

    /// Is shard `s` degraded in this batch's view?
    #[inline]
    pub fn is_degraded(&self, s: usize) -> bool {
        self.degraded & (1 << s) != 0
    }

    /// Number of shards this view reads across.
    pub fn n_shards(&self) -> usize {
        self.handles.len()
    }

    /// The shard that owns `v` (delegates to the router).
    #[inline]
    pub fn shard_of(&self, v: NodeId) -> usize {
        self.router.shard_of(v)
    }

    /// Shard `s`'s snapshot as acquired for this batch.
    pub fn snapshot(&self, s: usize) -> &'a CacheSnapshot {
        self.handles[s].peek()
    }

    /// Does any healthy shard carry a feature cache? (`false` = the
    /// cacheless DGL/RAIN gather path.)
    pub fn has_feat_cache(&self) -> bool {
        self.handles
            .iter()
            .enumerate()
            .any(|(s, h)| !self.is_degraded(s) && h.peek().feat.is_some())
    }

    /// Routed feature lookup: `v`'s row from the shard that owns it.
    /// Degraded shards always miss (the gather stage then copies the
    /// identical bytes from the host store — correctness preserved,
    /// cache bypassed).
    #[inline]
    pub fn feat_lookup(&self, v: NodeId) -> Option<&'a [f32]> {
        let s = self.router.shard_of(v);
        if self.is_degraded(s) {
            return None;
        }
        self.handles[s].peek().feat.as_ref()?.lookup(v)
    }

    /// Routed adjacency reads over `csc` (misses and degraded shards
    /// fall back to UVA).
    pub fn adj_source<'b>(&'b self, csc: &'b Csc) -> RoutedAdj<'b> {
        RoutedAdj {
            router: self.router,
            handles: self.handles,
            csc,
            degraded: self.degraded,
        }
    }

    /// Highest epoch across the shards this batch reads
    /// (observability: which installs the batch has seen).
    pub fn max_epoch(&self) -> u64 {
        self.handles.iter().map(|h| h.peek().epoch()).max().unwrap_or(0)
    }

    /// Device bytes across all shards' snapshots.
    pub fn bytes_used(&self) -> u64 {
        self.handles.iter().map(|h| h.peek().bytes_used()).sum()
    }
}

/// Sampler-facing adjacency view that routes each node's reads to the
/// shard that owns it; shards without an adjacency cache serve their
/// nodes over UVA (per-element miss), exactly like the unsharded
/// cacheless path.
pub struct RoutedAdj<'a> {
    router: &'a ShardRouter,
    handles: &'a [SnapshotHandle],
    csc: &'a Csc,
    /// Degraded-shard bitmask as of the owning view's acquire.
    degraded: u64,
}

impl<'a> AdjSource for RoutedAdj<'a> {
    #[inline]
    fn degree(&self, v: NodeId) -> usize {
        self.csc.degree(v)
    }

    #[inline]
    fn neighbor_at(&self, v: NodeId, pos: usize, ledger: &mut TransferLedger) -> NodeId {
        let s = self.router.shard_of(v);
        if self.degraded & (1 << s) != 0 {
            // degraded shard: same neighbor, read over UVA
            ledger.miss(std::mem::size_of::<NodeId>() as u64, 1);
            return self.csc.neighbors(v)[pos];
        }
        match &self.handles[s].peek().adj {
            Some(cache) => cache.source(self.csc).neighbor_at(v, pos, ledger),
            None => {
                ledger.miss(std::mem::size_of::<NodeId>() as u64, 1);
                self.csc.neighbors(v)[pos]
            }
        }
    }
}

/// A sharded plan: one [`CachePlan`] per shard plus the exact-integer
/// budget split they were planned under.
pub struct ShardedPlan {
    /// One plan per shard, in shard order.
    pub plans: Vec<CachePlan>,
    /// The exact-integer budget each shard was planned under.
    pub budgets: Vec<u64>,
}

impl ShardedPlan {
    /// Total fill upload across the shards.
    pub fn fill_h2d_bytes(&self) -> u64 {
        self.plans.iter().map(|p| p.fill_ledger.h2d_bytes).sum()
    }
}

/// `counts` with every node outside `shard` zeroed — the feature-side
/// input of a per-shard plan. Generic over the count type because the
/// offline path masks raw `u32` profiles while the refresh loop masks
/// its decayed `f64` accumulators — one implementation of the
/// ownership rule, not two that can drift.
pub fn mask_node_counts<T: Copy + Default>(
    counts: &[T],
    router: &ShardRouter,
    shard: usize,
) -> Vec<T> {
    counts
        .iter()
        .enumerate()
        .map(|(v, &c)| {
            if router.shard_of(v as NodeId) == shard {
                c
            } else {
                T::default()
            }
        })
        .collect()
}

/// The node whose neighbor list CSC offset `at` sits in — the owner
/// whose shard an element's cached state (and its access counts)
/// belongs to. O(log n) binary search over `col_ptr`; the refresh
/// loop's sparse profiles resolve ownership per touched element with
/// this instead of scanning every span ([`mask_elem_counts`] is the
/// dense-slice form of the same rule).
#[inline]
pub fn elem_owner(csc: &Csc, at: u64) -> NodeId {
    debug_assert!((at as usize) < csc.n_edges());
    (csc.col_ptr.partition_point(|&p| p <= at) - 1) as NodeId
}

/// `counts` (parallel to `csc.row_index`) with every element whose
/// *column node* lives outside `shard` zeroed — the adjacency-side
/// input of a per-shard plan (elements belong to the shard of the node
/// whose neighbor list they sit in, because that is how sampling
/// routes them). Generic for the same reason as [`mask_node_counts`].
pub fn mask_elem_counts<T: Copy + Default>(
    counts: &[T],
    csc: &Csc,
    router: &ShardRouter,
    shard: usize,
) -> Vec<T> {
    let mut out = vec![T::default(); counts.len()];
    for v in 0..csc.n_nodes() {
        if router.shard_of(v as NodeId) == shard {
            let span = csc.col_ptr[v] as usize..csc.col_ptr[v + 1] as usize;
            out[span.clone()].copy_from_slice(&counts[span]);
        }
    }
    out
}

/// Split the Eq. (1) budget per shard ([`split_budget`]) and run the
/// planner once per shard over the shard-masked profile. With one
/// shard this is exactly `planner.plan(..)` — no masking, bit-for-bit
/// the PR 2 behavior.
pub fn plan_sharded(
    planner: &dyn CachePlanner,
    ds: &Dataset,
    profile: &WorkloadProfile<'_>,
    total_budget: u64,
    router: &ShardRouter,
) -> ShardedPlan {
    plan_sharded_with_budgets(
        planner,
        ds,
        profile,
        split_budget(total_budget, router.n_shards()),
        router,
    )
}

/// [`plan_sharded`] under caller-chosen per-shard budgets — the
/// elastic path: a weighted re-split
/// ([`split_budget_weighted`](super::planner::split_budget_weighted))
/// or any other exact partition of the global budget. `budgets.len()`
/// must match the router's shard count; the single-shard case skips
/// masking, bit-for-bit the unsharded plan.
pub fn plan_sharded_with_budgets(
    planner: &dyn CachePlanner,
    ds: &Dataset,
    profile: &WorkloadProfile<'_>,
    budgets: Vec<u64>,
    router: &ShardRouter,
) -> ShardedPlan {
    let n = router.n_shards();
    assert_eq!(budgets.len(), n, "one budget per shard");
    if n == 1 {
        return ShardedPlan { plans: vec![planner.plan(ds, profile, budgets[0])], budgets };
    }
    let mut plans = Vec::with_capacity(n);
    for (s, &b) in budgets.iter().enumerate() {
        let nv = mask_node_counts(profile.node_visits, router, s);
        let ec = mask_elem_counts(profile.elem_counts, &ds.csc, router, s);
        let shard_profile = WorkloadProfile {
            node_visits: &nv,
            elem_counts: &ec,
            t_sample_ns: profile.t_sample_ns,
            t_feature_ns: profile.t_feature_ns,
        };
        plans.push(planner.plan(ds, &shard_profile, b));
    }
    ShardedPlan { plans, budgets }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::cache::alloc::CacheAllocation;
    use crate::cache::feat_cache::FeatCache;
    use crate::cache::planner::DciPlanner;
    use crate::graph::{datasets, FeatureStore};
    use crate::mem::CostModel;
    use crate::sampler::{presample, Fanout};
    use crate::util::Rng;

    #[test]
    fn routing_is_stable_and_in_range() {
        for n in [1usize, 2, 3, 4, 7] {
            let r = ShardRouter::new(n);
            for v in 0..10_000u32 {
                let s = r.shard_of(v);
                assert!(s < n, "node {v} routed to shard {s} of {n}");
                assert_eq!(s, r.shard_of(v), "assignment must be stable");
            }
        }
    }

    #[test]
    fn routing_spreads_nodes() {
        let n = 4;
        let r = ShardRouter::new(n);
        let mut per_shard = vec![0usize; n];
        for v in 0..8_000u32 {
            per_shard[r.shard_of(v)] += 1;
        }
        for (s, &c) in per_shard.iter().enumerate() {
            assert!(c > 1_000, "shard {s} got only {c} of 8000 nodes");
        }
    }

    #[test]
    fn node_masks_partition_counts() {
        let r = ShardRouter::new(3);
        let counts: Vec<u32> = (0..500).map(|v| v as u32 + 1).collect();
        let masks: Vec<Vec<u32>> =
            (0..3).map(|s| mask_node_counts(&counts, &r, s)).collect();
        for v in 0..counts.len() {
            let nonzero = masks.iter().filter(|m| m[v] != 0).count();
            assert_eq!(nonzero, 1, "node {v} must live in exactly one shard mask");
            let s = r.shard_of(v as NodeId);
            assert_eq!(masks[s][v], counts[v]);
        }
    }

    #[test]
    fn elem_owner_matches_span_membership() {
        let ds = datasets::spec("tiny").unwrap().build();
        for v in 0..ds.csc.n_nodes() {
            let span = ds.csc.col_ptr[v]..ds.csc.col_ptr[v + 1];
            for at in span {
                assert_eq!(elem_owner(&ds.csc, at), v as NodeId, "offset {at}");
            }
        }
    }

    #[test]
    fn elem_masks_partition_by_column_node() {
        let ds = datasets::spec("tiny").unwrap().build();
        let r = ShardRouter::new(4);
        let counts: Vec<u32> = (0..ds.csc.n_edges()).map(|e| e as u32 % 7 + 1).collect();
        let masks: Vec<Vec<u32>> =
            (0..4).map(|s| mask_elem_counts(&counts, &ds.csc, &r, s)).collect();
        for v in 0..ds.csc.n_nodes() {
            let s = r.shard_of(v as NodeId);
            let span = ds.csc.col_ptr[v] as usize..ds.csc.col_ptr[v + 1] as usize;
            for e in span {
                for (m, mask) in masks.iter().enumerate() {
                    let want = if m == s { counts[e] } else { 0 };
                    assert_eq!(mask[e], want, "elem {e} of node {v} in mask {m}");
                }
            }
        }
    }

    #[test]
    fn sharded_plan_conserves_budget_and_masks_fills() {
        let ds = datasets::spec("tiny").unwrap().build();
        let stats = presample(
            &ds.csc,
            &ds.features,
            &ds.test_nodes,
            64,
            &Fanout::parse("3,2").unwrap(),
            6,
            &CostModel::default(),
            &mut Rng::new(11),
        );
        let profile = WorkloadProfile::from_presample(&stats);
        let router = ShardRouter::new(4);
        let total = 100_000u64;
        let sharded = plan_sharded(&DciPlanner, &ds, &profile, total, &router);
        assert_eq!(sharded.plans.len(), 4);
        assert_eq!(sharded.budgets.iter().sum::<u64>(), total);
        for (s, plan) in sharded.plans.iter().enumerate() {
            let split = plan.snapshot.alloc.unwrap();
            assert_eq!(split.total(), sharded.budgets[s], "shard {s} split");
            // the masked profile steers first-priority capacity to the
            // shard's own visited nodes (spill slots may then take
            // zero-count nodes from anywhere — routing never reads them
            // cross-shard, so they are dead weight, not corruption)
            let feat = plan.snapshot.feat.as_ref().unwrap();
            let in_shard_hot = (0..ds.csc.n_nodes() as u32)
                .filter(|&v| {
                    feat.contains(v)
                        && router.shard_of(v) == s
                        && stats.node_visits[v as usize] > 0
                })
                .count();
            assert!(in_shard_hot > 0, "shard {s} cached none of its own hot nodes");
        }
    }

    fn marker(c_adj: u64) -> CacheSnapshot {
        CacheSnapshot::new(None, None, Some(CacheAllocation { c_adj, c_feat: 0 }))
    }

    #[test]
    fn per_shard_installs_leave_other_shards_serving() {
        let rt = Arc::new(ShardedRuntime::new(
            ShardRouter::new(3),
            vec![marker(0), marker(1), marker(2)],
        ));
        let mut h = ShardedHandle::new(&rt);
        let before = h.acquire();
        assert_eq!(before.snapshot(1).alloc.unwrap().c_adj, 1);
        assert_eq!(before.max_epoch(), 1);

        rt.install_shard(1, marker(7));
        let after = h.acquire();
        assert_eq!(after.snapshot(0).alloc.unwrap().c_adj, 0, "shard 0 untouched");
        assert_eq!(after.snapshot(1).alloc.unwrap().c_adj, 7, "shard 1 swapped");
        assert_eq!(after.snapshot(2).alloc.unwrap().c_adj, 2, "shard 2 untouched");
        assert_eq!(after.snapshot(0).epoch(), 1);
        assert_eq!(after.snapshot(1).epoch(), 2);
        assert_eq!(after.max_epoch(), 2);
        assert_eq!(rt.swaps(), 1);
        assert_eq!(rt.swap_stalls(), 0);
    }

    #[test]
    fn routed_feat_lookup_matches_owning_shard() {
        let fs = FeatureStore::generate(64, 4, &mut Rng::new(3));
        let router = ShardRouter::new(2);
        // shard 0 caches its own nodes, shard 1 caches nothing
        let visits: Vec<u32> =
            (0..64).map(|v| u32::from(router.shard_of(v as NodeId) == 0)).collect();
        let cap = 64 * (fs.row_bytes() + 16);
        let (feat0, _) = FeatCache::fill(&fs, &mask_node_counts(&visits, &router, 0), cap);
        let rt = Arc::new(ShardedRuntime::new(
            ShardRouter::new(2),
            vec![
                CacheSnapshot::new(None, Some(feat0), None),
                CacheSnapshot::empty(),
            ],
        ));
        let mut h = ShardedHandle::new(&rt);
        let view = h.acquire();
        assert!(view.has_feat_cache());
        for v in 0..64u32 {
            match view.feat_lookup(v) {
                Some(row) => {
                    assert_eq!(view.shard_of(v), 0, "row for {v} served by wrong shard");
                    assert_eq!(row, fs.row(v));
                }
                None => {
                    // shard-1 nodes miss even if shard 0 spilled them in:
                    // routing only ever consults the owning shard
                    if view.shard_of(v) == 0 {
                        // shard 0 had capacity for all of its nodes
                        assert_eq!(visits[v as usize], 0);
                    }
                }
            }
        }
    }

    #[test]
    fn degraded_shard_bypasses_feat_cache_until_repaired() {
        let fs = FeatureStore::generate(64, 4, &mut Rng::new(3));
        let router = ShardRouter::new(2);
        let visits: Vec<u32> =
            (0..64).map(|v| u32::from(router.shard_of(v as NodeId) == 0)).collect();
        let cap = 64 * (fs.row_bytes() + 16);
        let (feat0, _) = FeatCache::fill(&fs, &mask_node_counts(&visits, &router, 0), cap);
        let rt = Arc::new(ShardedRuntime::new(
            ShardRouter::new(2),
            vec![
                CacheSnapshot::new(None, Some(feat0), None),
                CacheSnapshot::empty(),
            ],
        ));
        let mut h = ShardedHandle::new(&rt);
        let hot: NodeId = (0..64)
            .find(|&v| router.shard_of(v) == 0)
            .expect("shard 0 owns some node");
        assert!(h.acquire().feat_lookup(hot).is_some(), "healthy shard serves from cache");

        assert!(rt.mark_degraded(0), "first mark reports the transition");
        assert!(!rt.mark_degraded(0), "re-marking is idempotent");
        assert!(rt.is_degraded(0));
        assert_eq!(rt.degraded_count(), 1);
        let view = h.acquire();
        assert!(view.is_degraded(0));
        assert!(!view.has_feat_cache(), "the only cached shard is degraded");
        for v in 0..64u32 {
            assert!(view.feat_lookup(v).is_none(), "degraded reads must miss to host");
        }

        assert!(rt.mark_repaired(0), "repair reports the transition");
        assert!(!rt.mark_repaired(0), "re-repairing is idempotent");
        assert_eq!(rt.degraded_count(), 0);
        let view = h.acquire();
        assert!(view.has_feat_cache());
        assert!(view.feat_lookup(hot).is_some(), "repaired shard serves from cache again");
    }

    #[test]
    fn degraded_adj_reads_return_the_same_neighbors_over_uva() {
        use crate::cache::adj_cache::AdjCache;
        let ds = datasets::spec("tiny").unwrap().build();
        let counts = vec![1u32; ds.csc.n_edges()];
        let (adj, _) = AdjCache::fill(&ds.csc, &counts, ds.csc.bytes_total());
        assert!(adj.is_full_csc());
        let rt = Arc::new(ShardedRuntime::single(CacheSnapshot::new(
            Some(adj),
            None,
            None,
        )));
        let mut h = ShardedHandle::new(&rt);

        let view = h.acquire();
        let src = view.adj_source(&ds.csc);
        let mut healthy = TransferLedger::new();
        let before: Vec<NodeId> =
            (0..ds.csc.degree(0)).map(|p| src.neighbor_at(0, p, &mut healthy)).collect();
        assert!(healthy.hits > 0 && healthy.misses == 0, "full-CSC cache hits");

        rt.mark_degraded(0);
        let view = h.acquire();
        let src = view.adj_source(&ds.csc);
        let mut degraded = TransferLedger::new();
        let after: Vec<NodeId> =
            (0..ds.csc.degree(0)).map(|p| src.neighbor_at(0, p, &mut degraded)).collect();
        assert_eq!(before, after, "degraded reads return identical neighbors");
        assert!(degraded.hits == 0 && degraded.misses > 0, "…over the UVA miss path");
    }

    #[test]
    fn single_shard_runtime_is_the_pr2_shape() {
        let rt = Arc::new(ShardedRuntime::single(marker(5)));
        assert_eq!(rt.n_shards(), 1);
        assert_eq!(rt.load().alloc.unwrap().c_adj, 5);
        let e = rt.install(marker(6));
        assert_eq!(e, 2);
        assert_eq!(rt.swaps(), 1);
        let mut h = ShardedHandle::new(&rt);
        assert_eq!(h.acquire().snapshot(0).alloc.unwrap().c_adj, 6);
    }
}
