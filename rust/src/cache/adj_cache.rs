//! Adjacency-matrix cache — Algorithm 1 + Fig. 6 of the paper.
//!
//! Filling:
//! 1. If the whole CSC fits in `C_adj`, cache it entirely (Alg. 1 l.2-4).
//! 2. Otherwise compute per-node total visit counts from the
//!    pre-sampling `Counts` array (l.6-9), order nodes by total count
//!    descending (l.10-11), sort each node's elements by their own
//!    counts descending (l.12-15, Fig. 6(b)'s two-level sort), and cache
//!    a prefix of the reordered element stream until `C_adj` is
//!    exhausted (l.16, Fig. 6(c)).
//!
//! Hit rule (§IV.B): sampling addresses *positions* of a node's
//! (logically reordered) neighbor list; position `p` of node `v` hits
//! iff `p < cached_len(v)` — "if n is less than or equal to the cache
//! length then the cache hit".
//!
//! Implementation note: only the node where the budget runs out is
//! *partially* cached, so only that node's positions ever mix device
//! and host reads; its host fallback goes through the within-node
//! permutation so a logical position maps to the right original CSC
//! element. Within-node sorting of nodes that will never be cached is
//! skipped — it is unobservable (their positions always miss) and
//! keeping the fill O(cached · log) is exactly the "lightweight"
//! property §IV emphasizes.

use crate::graph::{Csc, NodeId};
use crate::mem::TransferLedger;
use crate::sampler::AdjSource;

/// Per-node metadata charge: cached length (u32) + device offset (u64).
const NODE_META_BYTES: u64 = 12;
const ELEM_BYTES: u64 = std::mem::size_of::<NodeId>() as u64;

/// The filled adjacency cache.
pub struct AdjCache {
    /// Whole CSC resident on device (Alg. 1 fast path).
    full: bool,
    /// Per-node cached prefix length (logical reordered positions).
    cached_len: Vec<u32>,
    /// Per-node offset into `cached_elems`.
    offsets: Vec<u64>,
    /// Device-resident reordered neighbor prefixes.
    cached_elems: Vec<NodeId>,
    /// For the (single) partially cached node: logical→original
    /// position map for its host-fallback tail.
    boundary: Option<(NodeId, Vec<u32>)>,
    /// Device bytes used (payload + metadata).
    bytes_used: u64,
}

impl AdjCache {
    /// Algorithm 1. `elem_counts` is parallel to `csc.row_index`.
    /// Returns the cache and the preprocessing upload ledger.
    pub fn fill(csc: &Csc, elem_counts: &[u32], capacity_bytes: u64) -> (Self, TransferLedger) {
        assert_eq!(elem_counts.len(), csc.n_edges());
        let n = csc.n_nodes();
        let mut ledger = TransferLedger::new();

        // l.1-4: whole-CSC fast path
        let volume = csc.bytes_total();
        if volume <= capacity_bytes {
            ledger.upload(volume);
            return (
                AdjCache {
                    full: true,
                    cached_len: Vec::new(),
                    offsets: Vec::new(),
                    cached_elems: Vec::new(),
                    boundary: None,
                    bytes_used: volume,
                },
                ledger,
            );
        }

        // l.6-9: per-node totals
        let mut node_totals: Vec<u64> = vec![0; n];
        for v in 0..n {
            let span = csc.col_ptr[v] as usize..csc.col_ptr[v + 1] as usize;
            node_totals[v] = elem_counts[span].iter().map(|&c| c as u64).sum();
        }

        // l.10-11: order nodes by total desc (stable tie-break on id),
        // dropping never-visited nodes (they contribute nothing)
        let mut order: Vec<u32> =
            (0..n as u32).filter(|&v| node_totals[v as usize] > 0).collect();
        order.sort_unstable_by(|&a, &b| {
            node_totals[b as usize]
                .cmp(&node_totals[a as usize])
                .then(a.cmp(&b))
        });

        Self::fill_with_order(csc, elem_counts, &order, capacity_bytes)
    }

    /// Fill with an externally chosen node priority order (DUCATI's
    /// knapsack produces one; Algorithm 1 produces the visit-total
    /// order). `capacity_bytes` must already exclude the full-CSC fast
    /// path (callers check `csc.bytes_total()` first).
    pub fn fill_with_order(
        csc: &Csc,
        elem_counts: &[u32],
        order: &[u32],
        capacity_bytes: u64,
    ) -> (Self, TransferLedger) {
        let n = csc.n_nodes();
        let mut ledger = TransferLedger::new();
        let meta = n as u64 * NODE_META_BYTES;
        if capacity_bytes <= meta {
            return (Self::empty(n), ledger);
        }
        let budget_elems = ((capacity_bytes - meta) / ELEM_BYTES) as usize;
        if budget_elems == 0 {
            return (Self::empty(n), ledger);
        }

        let mut cached_len = vec![0u32; n];
        let mut offsets = vec![0u64; n];
        let mut cached_elems: Vec<NodeId> = Vec::with_capacity(budget_elems);
        let mut boundary = None;

        for &v in order {
            if cached_elems.len() >= budget_elems {
                break;
            }
            let deg = csc.degree(v);
            if deg == 0 {
                continue;
            }
            let remaining = budget_elems - cached_elems.len();
            let neigh = csc.neighbors(v);
            let base = csc.neighbor_offset(v) as usize;
            offsets[v as usize] = cached_elems.len() as u64;
            if deg <= remaining {
                // whole list cached; device order can stay original
                // (every position hits — ordering unobservable)
                cached_elems.extend_from_slice(neigh);
                cached_len[v as usize] = deg as u32;
            } else {
                // l.12-15: within-node sort by element count desc, cache
                // the hottest prefix, keep the logical→original map
                let mut perm: Vec<u32> = (0..deg as u32).collect();
                perm.sort_unstable_by(|&a, &b| {
                    elem_counts[base + b as usize]
                        .cmp(&elem_counts[base + a as usize])
                        .then(a.cmp(&b))
                });
                for &p in perm.iter().take(remaining) {
                    cached_elems.push(neigh[p as usize]);
                }
                cached_len[v as usize] = remaining as u32;
                boundary = Some((v, perm));
                break;
            }
        }

        let bytes_used = meta + cached_elems.len() as u64 * ELEM_BYTES;
        ledger.upload(cached_elems.len() as u64 * ELEM_BYTES + meta);
        (
            AdjCache {
                full: false,
                cached_len,
                offsets,
                cached_elems,
                boundary,
                bytes_used,
            },
            ledger,
        )
    }

    /// Cache with zero payload (all positions miss).
    pub fn empty(n_nodes: usize) -> Self {
        AdjCache {
            full: false,
            cached_len: vec![0; n_nodes],
            offsets: vec![0; n_nodes],
            cached_elems: Vec::new(),
            boundary: None,
            bytes_used: 0,
        }
    }

    /// Whether the whole CSC fit in the budget (every read is a hit).
    pub fn is_full_csc(&self) -> bool {
        self.full
    }

    /// Device bytes this cache occupies (elements + prefix metadata).
    pub fn bytes_used(&self) -> u64 {
        self.bytes_used
    }

    /// Cached prefix length for `v`.
    pub fn cached_len(&self, v: NodeId) -> usize {
        if self.full {
            usize::MAX
        } else {
            self.cached_len[v as usize] as usize
        }
    }

    /// Number of fully or partially cached nodes.
    pub fn n_cached_nodes(&self) -> usize {
        if self.full {
            usize::MAX
        } else {
            self.cached_len.iter().filter(|&&l| l > 0).count()
        }
    }

    /// Bind to the host CSC to form an [`AdjSource`] for the sampler.
    pub fn source<'a>(&'a self, csc: &'a Csc) -> CachedAdjSource<'a> {
        CachedAdjSource { cache: self, csc }
    }
}

/// Sampler-facing adjacency view: device prefix hits, UVA tail misses.
pub struct CachedAdjSource<'a> {
    cache: &'a AdjCache,
    csc: &'a Csc,
}

impl<'a> AdjSource for CachedAdjSource<'a> {
    #[inline]
    fn degree(&self, v: NodeId) -> usize {
        self.csc.degree(v)
    }

    #[inline]
    fn neighbor_at(&self, v: NodeId, pos: usize, ledger: &mut TransferLedger) -> NodeId {
        let c = self.cache;
        if c.full {
            ledger.hit(ELEM_BYTES);
            return self.csc.neighbors(v)[pos];
        }
        let len = c.cached_len[v as usize] as usize;
        if pos < len {
            ledger.hit(ELEM_BYTES);
            c.cached_elems[c.offsets[v as usize] as usize + pos]
        } else {
            ledger.miss(ELEM_BYTES, 1);
            // host fallback: map the logical position back to the
            // original CSC position for the partially cached node
            match &c.boundary {
                Some((bv, perm)) if *bv == v => {
                    self.csc.neighbors(v)[perm[pos] as usize]
                }
                _ => self.csc.neighbors(v)[pos],
            }
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::graph::datasets;
    use crate::util::proptest::check;
    use crate::util::Rng;

    /// Fig. 4 CSC.
    fn fig4() -> Csc {
        Csc {
            col_ptr: vec![0, 3, 4, 6, 7, 8, 9],
            row_index: vec![1, 3, 4, 2, 0, 2, 2, 0, 3],
            values: None,
        }
    }

    #[test]
    fn full_csc_fast_path() {
        let g = fig4();
        let counts = vec![1u32; 9];
        let (c, ledger) = AdjCache::fill(&g, &counts, g.bytes_total());
        assert!(c.is_full_csc());
        assert_eq!(ledger.h2d_bytes, g.bytes_total());
        let src = c.source(&g);
        let mut l = TransferLedger::new();
        assert_eq!(src.neighbor_at(0, 1, &mut l), 3);
        assert_eq!(l.hits, 1);
        assert_eq!(l.misses, 0);
    }

    #[test]
    fn partial_fill_prefers_hot_nodes() {
        let g = fig4();
        // node 2 is hottest (22 visits), node 0 second (12)
        let counts = vec![4, 4, 4, 1, 12, 10, 2, 1, 1];
        // budget: metadata (6*12=72) + 4 elements
        let cap = 72 + 4 * 4;
        let (c, _) = AdjCache::fill(&g, &counts, cap);
        assert!(!c.is_full_csc());
        // node 2 total = 12+10 = 22 -> fully cached (2 elems)
        assert_eq!(c.cached_len(2), 2);
        // node 0 total = 12 -> next, 2 of 3 elements cached (boundary)
        assert_eq!(c.cached_len(0), 2);
        assert_eq!(c.n_cached_nodes(), 2);
        assert!(c.bytes_used() <= cap);

        // boundary node 0: hottest elements are positions 0,1 (counts 4,4)
        let src = c.source(&g);
        let mut l = TransferLedger::new();
        let a = src.neighbor_at(0, 0, &mut l);
        let b = src.neighbor_at(0, 1, &mut l);
        assert_eq!(l.hits, 2);
        assert_eq!((a, b), (1, 3)); // original order among equal counts
        // position 2 misses and maps to the coldest original element
        let t = src.neighbor_at(0, 2, &mut l);
        assert_eq!(l.misses, 1);
        assert_eq!(t, 4); // count 1 at original pos 2... wait counts[0..3]=[4,4,4]
    }

    #[test]
    fn boundary_perm_maps_tail_correctly() {
        let g = fig4();
        // node 0's elements have distinct counts: pos0=1, pos1=9, pos2=5
        let counts = vec![1, 9, 5, 0, 0, 0, 0, 0, 0];
        // budget for exactly 2 elements -> node 0 is boundary
        let cap = 72 + 2 * 4;
        let (c, _) = AdjCache::fill(&g, &counts, cap);
        assert_eq!(c.cached_len(0), 2);
        let src = c.source(&g);
        let mut l = TransferLedger::new();
        // logical order by count desc: pos1 (9) -> elem 3, pos2 (5) -> elem 4
        assert_eq!(src.neighbor_at(0, 0, &mut l), 3);
        assert_eq!(src.neighbor_at(0, 1, &mut l), 4);
        // tail logical pos 2 -> original pos 0 -> elem 1 (miss)
        assert_eq!(src.neighbor_at(0, 2, &mut l), 1);
        assert_eq!(l.hits, 2);
        assert_eq!(l.misses, 1);
    }

    #[test]
    fn zero_capacity_all_miss() {
        let g = fig4();
        let counts = vec![1u32; 9];
        let (c, _) = AdjCache::fill(&g, &counts, 0);
        assert_eq!(c.bytes_used(), 0);
        let src = c.source(&g);
        let mut l = TransferLedger::new();
        for v in 0..6u32 {
            for p in 0..g.degree(v) {
                assert_eq!(src.neighbor_at(v, p, &mut l), g.neighbors(v)[p]);
            }
        }
        assert_eq!(l.hits, 0);
        assert_eq!(l.misses, 9);
    }

    #[test]
    fn never_visited_nodes_not_cached() {
        let g = fig4();
        let mut counts = vec![0u32; 9];
        counts[3] = 7; // only node 1's single element visited
        // capacity below the full-CSC volume (92B) so the partial path runs
        let (c, _) = AdjCache::fill(&g, &counts, 72 + 4 * 4);
        assert_eq!(c.cached_len(1), 1);
        assert_eq!(c.n_cached_nodes(), 1);
    }

    #[test]
    fn neighbor_multiset_preserved_property() {
        // whatever the cache layout, reading all positions of any node
        // yields exactly the node's original neighbor multiset
        check("adj cache preserves neighbor multisets", 60, |rng| {
            let ds = datasets::spec("tiny").unwrap().build();
            let counts: Vec<u32> =
                (0..ds.csc.n_edges()).map(|_| rng.next_u32() % 8).collect();
            let cap = rng.next_u64() % (ds.csc.bytes_total() * 2);
            let (c, _) = AdjCache::fill(&ds.csc, &counts, cap);
            if !c.is_full_csc() && c.bytes_used() > cap {
                return Err(format!("used {} > cap {cap}", c.bytes_used()));
            }
            let src = c.source(&ds.csc);
            let mut l = TransferLedger::new();
            let mut r = Rng::new(rng.next_u64());
            for _ in 0..50 {
                let v = r.next_u32() % ds.csc.n_nodes() as u32;
                let deg = ds.csc.degree(v);
                let mut got: Vec<NodeId> =
                    (0..deg).map(|p| src.neighbor_at(v, p, &mut l)).collect();
                let mut want = ds.csc.neighbors(v).to_vec();
                got.sort_unstable();
                want.sort_unstable();
                if got != want {
                    return Err(format!("node {v}: multiset changed"));
                }
            }
            Ok(())
        });
    }

    #[test]
    fn hotter_budget_never_lowers_hits_property() {
        // hit count on a fixed access pattern is monotone in capacity
        check("adj hit count monotone in capacity", 20, |rng| {
            let ds = datasets::spec("tiny").unwrap().build();
            let counts: Vec<u32> =
                (0..ds.csc.n_edges()).map(|_| rng.next_u32() % 8).collect();
            let caps = [1000u64, 10_000, 100_000, ds.csc.bytes_total()];
            let seed = rng.next_u64();
            let mut prev_hits = 0u64;
            for cap in caps {
                let (c, _) = AdjCache::fill(&ds.csc, &counts, cap);
                let src = c.source(&ds.csc);
                let mut l = TransferLedger::new();
                let mut r = Rng::new(seed);
                for _ in 0..300 {
                    let v = r.next_u32() % ds.csc.n_nodes() as u32;
                    let deg = ds.csc.degree(v);
                    if deg == 0 {
                        continue;
                    }
                    let p = r.gen_usize(deg);
                    src.neighbor_at(v, p, &mut l);
                }
                if l.hits < prev_hits {
                    return Err(format!(
                        "hits dropped {} -> {} at cap {cap}",
                        prev_hits, l.hits
                    ));
                }
                prev_hits = l.hits;
            }
            Ok(())
        });
    }
}
