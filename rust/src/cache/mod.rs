//! The paper's contribution: workload-aware dual-cache allocation
//! (Eq. 1) and the lightweight cache-filling algorithms (§IV.B,
//! Algorithm 1).
//!
//! Both caches live in simulated device memory ([`crate::mem`]); hits
//! are device reads, misses fall back to UVA host reads. Capacity
//! accounting includes metadata (hash table / prefix-length arrays),
//! not just payload.

pub mod adj_cache;
pub mod alloc;
pub mod feat_cache;
pub mod stats;

pub use adj_cache::AdjCache;
pub use alloc::{allocate, CacheAllocation};
pub use feat_cache::FeatCache;
pub use stats::CacheStats;
