//! The paper's contribution: workload-aware dual-cache allocation
//! (Eq. 1) and the lightweight cache-filling algorithms (§IV.B,
//! Algorithm 1) — plus the runtime machinery that keeps them live in a
//! serving deployment.
//!
//! Layering:
//! - [`adj_cache`] / [`feat_cache`] — the immutable filled caches.
//! - [`alloc`] — the Eq. (1) capacity split.
//! - [`planner`] — `CachePlanner`: profile → allocation → fill, with
//!   the DCI, SCI, and DUCATI-knapsack strategies behind one trait.
//! - [`runtime`] — `DualCacheRuntime`: epoch-swappable immutable
//!   snapshots; every execution path reads caches through a per-thread
//!   `SnapshotHandle` acquired once per batch.
//! - [`refresh`] — the online loop that tracks serving-time accesses,
//!   detects workload drift, re-plans in the background, and hot-swaps
//!   the snapshot.
//! - [`stats`] — per-run transfer statistics, including online-refill
//!   traffic.
//!
//! Both caches live in simulated device memory ([`crate::mem`]); hits
//! are device reads, misses fall back to UVA host reads. Capacity
//! accounting includes metadata (hash table / prefix-length arrays),
//! not just payload.

pub mod adj_cache;
pub mod alloc;
pub mod feat_cache;
pub mod planner;
pub mod refresh;
pub mod runtime;
pub mod stats;

pub use adj_cache::AdjCache;
pub use alloc::{allocate, CacheAllocation};
pub use feat_cache::FeatCache;
pub use planner::{planner_for, CachePlan, CachePlanner, WorkloadProfile};
pub use refresh::{AccessTracker, RefreshConfig, RefreshStats, Refresher};
pub use runtime::{CacheSnapshot, DualCacheRuntime, SnapshotHandle};
pub use stats::CacheStats;
