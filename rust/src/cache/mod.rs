//! The paper's contribution: workload-aware dual-cache allocation
//! (Eq. 1) and the lightweight cache-filling algorithms (§IV.B,
//! Algorithm 1) — plus the runtime machinery that keeps them live in a
//! serving deployment.
//!
//! Layering:
//! - [`adj_cache`] / [`feat_cache`] — the immutable filled caches.
//! - [`alloc`] — the Eq. (1) capacity split.
//! - [`planner`] — `CachePlanner`: profile → allocation → fill, with
//!   the DCI, SCI, and DUCATI-knapsack strategies behind one trait.
//! - [`runtime`] — `DualCacheRuntime`: epoch-swappable immutable
//!   snapshots; every execution path reads caches through a per-thread
//!   `SnapshotHandle` acquired once per batch.
//! - [`shard`] — sharded multi-device snapshots: a stable node→shard
//!   hash partition, per-shard budget split (exact integer), and a
//!   `ShardedRuntime`/`ShardView` acquire path that routes lookups to
//!   the shard owning each node. One shard is the PR 2 behavior.
//! - [`tracker`] — serving-time access counting behind the
//!   `WorkloadTracker` trait: exact dense counters (`tracker=dense`)
//!   or a conservative-update count-min sketch with an O(touched)
//!   drain (`tracker=sketch`). See DESIGN.md §Workload tracking.
//! - [`refresh`] — the online loop that drains the tracker into a
//!   sparse decayed profile, detects workload drift *per shard*,
//!   re-plans in the background, and hot-swaps only the drifted shard.
//!   With `rebalance=on` the loop is also **elastic**: shard-level
//!   load skew re-splits the global budget across shards
//!   ([`split_budget_weighted`]), an `auto-budget-refresh=on` policy
//!   re-evaluates the workload-aware global budget per epoch, and
//!   every install is accounted against its device arena in
//!   claim-before-release order. See DESIGN.md §Elastic budgets.
//! - [`stats`] — per-run transfer statistics, including online-refill
//!   traffic.
//!
//! Both caches live in simulated device memory ([`crate::mem`]); hits
//! are device reads, misses fall back to UVA host reads. Capacity
//! accounting includes metadata (hash table / prefix-length arrays),
//! not just payload.

// The cache subsystem is the crate's documented public surface (three
// layers deep since the planner/runtime/refresh split); CI gates
// `cargo doc` with `-D warnings`, so an undocumented public item here
// fails the build.
#![warn(missing_docs)]

pub mod adj_cache;
pub mod alloc;
pub mod feat_cache;
pub mod planner;
pub mod refresh;
pub mod runtime;
pub mod shard;
pub mod stats;
pub mod tracker;

pub use adj_cache::AdjCache;
pub use alloc::{allocate, CacheAllocation};
pub use feat_cache::FeatCache;
pub use planner::{
    cap_shares, cap_shares_per_device, planner_for, split_budget, split_budget_weighted,
    CachePlan, CachePlanner, ClassWeights, WorkloadProfile,
};
pub use refresh::{AutoBudgetPolicy, RefreshConfig, RefreshJob, RefreshStats, Refresher};
pub use runtime::{CacheSnapshot, DualCacheRuntime, SnapshotHandle};
pub use shard::{
    plan_sharded, plan_sharded_with_budgets, ShardRouter, ShardView, ShardedHandle,
    ShardedPlan, ShardedRuntime,
};
pub use stats::CacheStats;
pub use tracker::{
    AccessTracker, DrainedWindow, SketchTracker, TrackerConfig, TrackerKind,
    WorkloadTracker,
};
