//! Epoch-swappable dual-cache runtime.
//!
//! The caches themselves ([`AdjCache`], [`FeatCache`]) are immutable
//! once filled; what changes over the life of a serving deployment is
//! *which* filled pair is live. [`DualCacheRuntime`] owns that choice
//! as a sequence of epochs: each [`CacheSnapshot`] is an immutable
//! `(adj, feat, alloc)` triple tagged with the epoch that installed it,
//! and every execution path (serial loop, pipeline workers, served
//! requests) reads cache state through a per-thread [`SnapshotHandle`]
//! acquired once per batch.
//!
//! Hot-path cost: `SnapshotHandle::acquire` is one atomic epoch load
//! per batch. The handle re-clones the shared `Arc` only when an
//! [`DualCacheRuntime::install`] has happened since its last acquire —
//! steady-state serving never touches the publish lock, and an
//! install-concurrent acquire only *tries* the lock, falling back to
//! its previous (still valid) epoch for one batch if an installer
//! holds it. A reader blocks only if an installer camps on the lock
//! across `MAX_DEFERRALS` consecutive batches — install critical
//! sections are a pointer swap, so that means someone regressed
//! `install` into doing real work under the lock. Those blocks are
//! counted by `swap_stalls()` (asserted zero by the drifting-workload
//! bench); `swap_deferrals()` counts the benign one-batch lags.
//!
//! Snapshot lifetime rules (see DESIGN.md §Cache runtime):
//! 1. A snapshot is immutable after `install`; refreshers build a new
//!    one and swap, they never patch the live one.
//! 2. A batch uses exactly one snapshot end to end — `acquire` once
//!    per batch, never per lookup — so a mid-batch install cannot mix
//!    epochs within a batch.
//! 3. Old snapshots die when the last in-flight batch holding their
//!    `Arc` finishes; nothing blocks on their retirement.
//! 4. Every snapshot's `bytes_used()` stays within the budget the
//!    runtime was planned for; installs never grow the device claim.
//!
//! The publish lock only ever guards a whole-`Arc` pointer swap, so a
//! reader or installer that panics mid-batch can never leave it
//! half-updated — every lock here goes through
//! [`lock_unpoisoned`](crate::util::lock_unpoisoned), and a panicked
//! refresh generation costs nothing to readers (DESIGN.md §Fault
//! tolerance; degraded-shard fallback lives one level up in
//! [`crate::cache::shard::ShardedRuntime`]).

use std::sync::atomic::{AtomicU64, Ordering};
use std::sync::{Arc, Mutex};

use crate::util::lock_unpoisoned;

use super::adj_cache::AdjCache;
use super::alloc::CacheAllocation;
use super::feat_cache::FeatCache;

/// One immutable epoch of dual-cache state.
pub struct CacheSnapshot {
    /// Epoch tag; assigned by [`DualCacheRuntime::install`] (the
    /// initial snapshot is epoch 1).
    epoch: u64,
    /// Adjacency cache (`None` = all sampling over UVA).
    pub adj: Option<AdjCache>,
    /// Feature cache (`None` = all gathers over UVA).
    pub feat: Option<FeatCache>,
    /// The allocation split this snapshot was filled under (reporting).
    pub alloc: Option<CacheAllocation>,
}

impl CacheSnapshot {
    /// A pre-install snapshot (epoch 0 until a runtime installs it).
    pub fn new(
        adj: Option<AdjCache>,
        feat: Option<FeatCache>,
        alloc: Option<CacheAllocation>,
    ) -> Self {
        CacheSnapshot { epoch: 0, adj, feat, alloc }
    }

    /// A cacheless snapshot (DGL/RAIN — every access goes to UVA).
    pub fn empty() -> Self {
        CacheSnapshot { epoch: 0, adj: None, feat: None, alloc: None }
    }

    /// The epoch that installed this snapshot (0 = never installed).
    pub fn epoch(&self) -> u64 {
        self.epoch
    }

    /// Device bytes the snapshot's caches occupy (payload + metadata).
    pub fn bytes_used(&self) -> u64 {
        self.adj.as_ref().map(|c| c.bytes_used()).unwrap_or(0)
            + self.feat.as_ref().map(|c| c.bytes_used()).unwrap_or(0)
    }
}

/// The swappable holder of the live [`CacheSnapshot`].
pub struct DualCacheRuntime {
    current: Mutex<Arc<CacheSnapshot>>,
    /// Published epoch of `current` — the readers' fast-path check.
    epoch: AtomicU64,
    swaps: AtomicU64,
    stalls: AtomicU64,
    deferrals: AtomicU64,
}

impl DualCacheRuntime {
    /// Wrap an initial snapshot (epoch 1).
    pub fn new(snapshot: CacheSnapshot) -> Self {
        let mut s = snapshot;
        s.epoch = 1;
        DualCacheRuntime {
            current: Mutex::new(Arc::new(s)),
            epoch: AtomicU64::new(1),
            swaps: AtomicU64::new(0),
            stalls: AtomicU64::new(0),
            deferrals: AtomicU64::new(0),
        }
    }

    /// Publish a new snapshot; returns its epoch. Readers pick it up on
    /// their next per-batch acquire without blocking; in-flight batches
    /// finish on the snapshot they already hold.
    pub fn install(&self, snapshot: CacheSnapshot) -> u64 {
        let mut s = snapshot;
        let mut guard = lock_unpoisoned(&self.current);
        let e = guard.epoch + 1;
        s.epoch = e;
        *guard = Arc::new(s);
        // publish while still holding the lock: concurrent installs
        // are serialized, so the published epoch can never lag the
        // live snapshot. A reader that observes `e` in this window
        // loses the `try_lock` race and defers one batch — benign
        // (see `SnapshotHandle::acquire`).
        self.epoch.store(e, Ordering::Release);
        drop(guard);
        self.swaps.fetch_add(1, Ordering::Relaxed);
        e
    }

    /// Current snapshot (takes the publish lock — reporting/startup
    /// path; batch loops go through a [`SnapshotHandle`] instead).
    pub fn load(&self) -> Arc<CacheSnapshot> {
        Arc::clone(&lock_unpoisoned(&self.current))
    }

    /// Published epoch of the live snapshot.
    pub fn epoch(&self) -> u64 {
        self.epoch.load(Ordering::Acquire)
    }

    /// Installs performed since construction.
    pub fn swaps(&self) -> u64 {
        self.swaps.load(Ordering::Relaxed)
    }

    /// Times a reader's acquire actually *blocked* on an install: a
    /// handle falls back to a blocking lock (and counts here) only
    /// after [`MAX_DEFERRALS`] consecutive `try_lock` losses — which
    /// requires an installer to hold the publish lock across that many
    /// of the reader's batch boundaries. Install critical sections are
    /// a pointer swap, so this stays zero unless someone regresses
    /// `install` into doing real work (e.g. planning) under the lock —
    /// exactly what the benches' `swap_stalls == 0` assertions exist
    /// to catch.
    pub fn swap_stalls(&self) -> u64 {
        self.stalls.load(Ordering::Relaxed)
    }

    /// Times a reader served one extra batch on its previous epoch
    /// because an install held the publish lock at acquire time
    /// (benign — the lag is one batch, observability only).
    pub fn swap_deferrals(&self) -> u64 {
        self.deferrals.load(Ordering::Relaxed)
    }
}

/// Consecutive deferred acquires after which a handle gives up on
/// `try_lock` and blocks (counting a swap stall): bounds how far a
/// reader can lag behind a pathologically slow installer.
const MAX_DEFERRALS: u32 = 8;

/// A per-thread cursor over the runtime's epochs: holds the last
/// acquired snapshot `Arc` and refreshes it only when the published
/// epoch moves.
pub struct SnapshotHandle {
    rt: Arc<DualCacheRuntime>,
    cached: Arc<CacheSnapshot>,
    /// Consecutive `try_lock` losses (resets on any successful
    /// refresh); at [`MAX_DEFERRALS`] the next refresh blocks.
    deferred_streak: u32,
}

impl SnapshotHandle {
    /// A handle starting on `rt`'s current snapshot.
    pub fn new(rt: &Arc<DualCacheRuntime>) -> SnapshotHandle {
        SnapshotHandle { cached: rt.load(), rt: Arc::clone(rt), deferred_streak: 0 }
    }

    /// The snapshot to use for the next batch. Fast path is a single
    /// atomic load; the lock is *tried* only when an install happened
    /// since this handle's previous acquire — if an install holds it
    /// right now, the batch runs on the handle's previous epoch
    /// (always valid) and the next acquire retries. Only a streak of
    /// [`MAX_DEFERRALS`] consecutive losses (an installer camping on
    /// the lock across that many batches) makes the handle block, and
    /// that is counted as a swap stall.
    #[inline]
    pub fn acquire(&mut self) -> &CacheSnapshot {
        let e = self.rt.epoch.load(Ordering::Acquire);
        if e != self.cached.epoch {
            self.refresh_slow();
        }
        &self.cached
    }

    /// Like [`acquire`](Self::acquire) but hands out an owning `Arc`
    /// (for batches whose lifetime outlives the handle borrow).
    pub fn acquire_arc(&mut self) -> Arc<CacheSnapshot> {
        self.acquire();
        Arc::clone(&self.cached)
    }

    /// The handle's current snapshot *without* checking for a newer
    /// epoch. Used by [`crate::cache::shard::ShardView`], which
    /// refreshes every shard handle up front in its own acquire and
    /// then reads the batch through these cached epochs.
    #[inline]
    pub fn peek(&self) -> &CacheSnapshot {
        &self.cached
    }

    #[cold]
    fn refresh_slow(&mut self) {
        if self.deferred_streak >= MAX_DEFERRALS {
            // pathological: an installer held the lock across
            // MAX_DEFERRALS of our batch boundaries — wait it out
            // rather than lag further, and record the stall
            self.rt.stalls.fetch_add(1, Ordering::Relaxed);
            self.cached = Arc::clone(&lock_unpoisoned(&self.rt.current));
            self.deferred_streak = 0;
            return;
        }
        match self.rt.current.try_lock() {
            Ok(guard) => {
                self.cached = Arc::clone(&guard);
                self.deferred_streak = 0;
            }
            Err(_) => {
                // an install is mid-publish: keep the previous epoch
                // for this one batch instead of waiting
                self.rt.deferrals.fetch_add(1, Ordering::Relaxed);
                self.deferred_streak += 1;
            }
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn marker_snapshot(c_adj: u64) -> CacheSnapshot {
        CacheSnapshot::new(None, None, Some(CacheAllocation { c_adj, c_feat: 0 }))
    }

    #[test]
    fn install_bumps_epoch_and_readers_follow() {
        let rt = Arc::new(DualCacheRuntime::new(CacheSnapshot::empty()));
        let mut h = SnapshotHandle::new(&rt);
        assert_eq!(h.acquire().epoch(), 1);
        assert_eq!(rt.swaps(), 0);
        let e = rt.install(marker_snapshot(7));
        assert_eq!(e, 2);
        let snap = h.acquire();
        assert_eq!(snap.epoch(), 2);
        assert_eq!(snap.alloc.unwrap().c_adj, 7);
        assert_eq!(rt.swaps(), 1);
        assert_eq!(rt.epoch(), 2);
    }

    #[test]
    fn stale_snapshot_survives_while_held() {
        let rt = Arc::new(DualCacheRuntime::new(marker_snapshot(1)));
        let mut h = SnapshotHandle::new(&rt);
        let old = h.acquire_arc();
        rt.install(marker_snapshot(2));
        // the old epoch's content is still intact for in-flight work
        assert_eq!(old.alloc.unwrap().c_adj, 1);
        assert_eq!(h.acquire().alloc.unwrap().c_adj, 2);
    }

    #[test]
    fn empty_snapshot_has_no_bytes() {
        let s = CacheSnapshot::empty();
        assert_eq!(s.bytes_used(), 0);
        assert!(s.adj.is_none() && s.feat.is_none() && s.alloc.is_none());
    }

    #[test]
    fn concurrent_installs_and_readers_stay_consistent() {
        let rt = Arc::new(DualCacheRuntime::new(marker_snapshot(0)));
        let n_installs = 500u64;
        std::thread::scope(|scope| {
            let rt_w = Arc::clone(&rt);
            scope.spawn(move || {
                for i in 1..=n_installs {
                    rt_w.install(marker_snapshot(i));
                }
            });
            for _ in 0..3 {
                let rt_r = Arc::clone(&rt);
                scope.spawn(move || {
                    let mut h = SnapshotHandle::new(&rt_r);
                    let mut last_epoch = 0u64;
                    for _ in 0..2000 {
                        let s = h.acquire();
                        // epochs only move forward for any one reader
                        assert!(s.epoch() >= last_epoch);
                        last_epoch = s.epoch();
                        // snapshot content matches its epoch: marker i
                        // was installed as epoch i + 1 (initial marker
                        // 0 is epoch 1), so content and tag never tear
                        let m = s.alloc.unwrap().c_adj;
                        assert_eq!(m + 1, s.epoch(), "marker {m} vs epoch {}", s.epoch());
                    }
                });
            }
        });
        assert_eq!(rt.swaps(), n_installs);
        assert_eq!(rt.epoch(), n_installs + 1);
    }
}
