//! Online workload-drift re-planning.
//!
//! A serving deployment whose request mix drifts keeps paying misses on
//! a stale plan (BGL's observation: feature-cache policy must track the
//! live access distribution). DCI's two-scan fills make re-planning
//! cheap enough to do *online*, so:
//!
//! - the serving hot path bumps an [`AccessTracker`] (relaxed atomic
//!   adds: per input node in the gather stage, per touched element in
//!   the sampling stage — same counters pre-sampling collects);
//! - a background [`Refresher`] thread drains the tracker on a poll
//!   interval into an exponentially decayed profile, measures drift as
//!   the total-variation distance between the node-visit distribution
//!   the live snapshot was planned from and the decayed observed one;
//! - past the drift threshold it re-plans through the same
//!   [`CachePlanner`] the offline path used and hot-swaps the result
//!   into the [`DualCacheRuntime`] — readers pick the new epoch up on
//!   their next per-batch acquire, never blocking (the runtime counts
//!   any reader that does block; the bench asserts zero).
//!
//! Cost: the tracker is two count arrays (O(nodes) + O(edges)) per
//! worker and one relaxed `fetch_add` per access; the drift check is
//! O(nodes + edges) on the background thread per poll that saw new
//! batches. Sharding these accumulators across devices is an open item
//! (ROADMAP).

use std::sync::atomic::{AtomicBool, AtomicU32, AtomicU64, Ordering};
use std::sync::{Arc, Mutex};
use std::thread::JoinHandle;
use std::time::{Duration, Instant};

use crate::graph::{Dataset, NodeId};

use super::planner::{CachePlanner, WorkloadProfile};
use super::runtime::DualCacheRuntime;

/// Knobs of the online refresh loop.
#[derive(Debug, Clone, PartialEq)]
pub struct RefreshConfig {
    /// Poll period of the background drift check.
    pub check_interval: Duration,
    /// Served batches that must accumulate before a drift check counts.
    pub min_batches: u64,
    /// Exponential decay applied to the accumulated profile on every
    /// poll that drained new data (0 = only the newest window counts,
    /// 1 = never forget).
    pub decay: f64,
    /// Total-variation distance (in [0, 1]) between the planned and
    /// observed node-visit distributions that triggers a re-plan.
    pub drift_threshold: f64,
}

impl Default for RefreshConfig {
    fn default() -> Self {
        RefreshConfig {
            check_interval: Duration::from_millis(100),
            min_batches: 8,
            decay: 0.5,
            drift_threshold: 0.15,
        }
    }
}

/// Serving-time access accumulator. One per engine; the hot path adds
/// with relaxed atomics (u32 adds commute, so counts are exact
/// whatever the thread interleaving), the refresher drains with
/// `swap(0)`.
pub struct AccessTracker {
    node_visits: Vec<AtomicU32>,
    elem_counts: Vec<AtomicU32>,
    batches: AtomicU64,
    /// Modeled stage ns accumulated as integer ns (Eq. 1 ratio input).
    t_sample_ns: AtomicU64,
    t_feature_ns: AtomicU64,
}

/// One drained window of tracker counts.
pub struct DrainedCounts {
    pub node_visits: Vec<u32>,
    pub elem_counts: Vec<u32>,
    pub batches: u64,
    pub t_sample_ns: f64,
    pub t_feature_ns: f64,
}

impl AccessTracker {
    pub fn new(n_nodes: usize, n_edges: usize) -> Self {
        AccessTracker {
            node_visits: (0..n_nodes).map(|_| AtomicU32::new(0)).collect(),
            elem_counts: (0..n_edges).map(|_| AtomicU32::new(0)).collect(),
            batches: AtomicU64::new(0),
            t_sample_ns: AtomicU64::new(0),
            t_feature_ns: AtomicU64::new(0),
        }
    }

    /// Record one feature-stage visit of `v` (gather stage).
    #[inline]
    pub fn record_node(&self, v: NodeId) {
        self.node_visits[v as usize].fetch_add(1, Ordering::Relaxed);
    }

    /// Record one adjacency-element access at CSC offset `at`
    /// (sampling stage).
    #[inline]
    pub fn record_elem(&self, at: usize) {
        self.elem_counts[at].fetch_add(1, Ordering::Relaxed);
    }

    /// Record a served batch's modeled stage times.
    pub fn record_batch(&self, t_sample_ns: f64, t_feature_ns: f64) {
        self.batches.fetch_add(1, Ordering::Relaxed);
        self.t_sample_ns
            .fetch_add(t_sample_ns.max(0.0) as u64, Ordering::Relaxed);
        self.t_feature_ns
            .fetch_add(t_feature_ns.max(0.0) as u64, Ordering::Relaxed);
    }

    /// Batches recorded since the last drain.
    pub fn batches(&self) -> u64 {
        self.batches.load(Ordering::Relaxed)
    }

    /// Take the counts, resetting them to zero.
    pub fn drain(&self) -> DrainedCounts {
        DrainedCounts {
            node_visits: self
                .node_visits
                .iter()
                .map(|c| c.swap(0, Ordering::Relaxed))
                .collect(),
            elem_counts: self
                .elem_counts
                .iter()
                .map(|c| c.swap(0, Ordering::Relaxed))
                .collect(),
            batches: self.batches.swap(0, Ordering::Relaxed),
            t_sample_ns: self.t_sample_ns.swap(0, Ordering::Relaxed) as f64,
            t_feature_ns: self.t_feature_ns.swap(0, Ordering::Relaxed) as f64,
        }
    }
}

/// What the refresh loop did over its lifetime.
#[derive(Debug, Clone, Default)]
pub struct RefreshStats {
    /// Drift checks that had enough data to evaluate.
    pub checks: u64,
    /// Re-plans installed.
    pub replans: u64,
    /// Last measured total-variation drift.
    pub last_drift: f64,
    /// Total background wall time spent planning + installing, ns.
    pub replan_wall_ns: f64,
    /// H2D bytes uploaded by online refills.
    pub fill_h2d_bytes: u64,
}

/// Handle to the background refresh thread.
pub struct Refresher {
    stop: Arc<AtomicBool>,
    join: JoinHandle<()>,
    stats: Arc<Mutex<RefreshStats>>,
}

impl Refresher {
    /// Spawn the refresh loop. `planned_visits` is the node-visit
    /// profile the runtime's live snapshot was planned from (the
    /// pre-sample profile at startup); `budget` is the byte budget
    /// every re-plan must stay within (installs never grow the device
    /// claim — see the snapshot lifetime rules).
    pub fn spawn(
        ds: Arc<Dataset>,
        runtime: Arc<DualCacheRuntime>,
        tracker: Arc<AccessTracker>,
        planner: Box<dyn CachePlanner>,
        budget: u64,
        planned_visits: Vec<u32>,
        cfg: RefreshConfig,
    ) -> Refresher {
        let stop = Arc::new(AtomicBool::new(false));
        let stats = Arc::new(Mutex::new(RefreshStats::default()));
        let stop2 = Arc::clone(&stop);
        let stats2 = Arc::clone(&stats);
        let join = std::thread::Builder::new()
            .name("dci-refresh".into())
            .spawn(move || {
                refresh_loop(&ds, &runtime, &tracker, planner.as_ref(), budget,
                             planned_visits, &cfg, &stop2, &stats2)
            })
            .expect("spawn refresh thread");
        Refresher { stop, join, stats }
    }

    /// Current stats (the loop keeps them up to date after every check).
    pub fn stats(&self) -> RefreshStats {
        self.stats.lock().unwrap().clone()
    }

    /// Stop the loop and return its final stats.
    pub fn stop(self) -> RefreshStats {
        self.stop.store(true, Ordering::Relaxed);
        let _ = self.join.join();
        let stats = self.stats.lock().unwrap().clone();
        stats
    }
}

/// Total-variation distance between a normalized distribution and a
/// raw (unnormalized) observation; 0 when the observation is empty.
fn tv_distance(planned: &[f64], observed: &[f64]) -> f64 {
    let total: f64 = observed.iter().sum();
    if total <= 0.0 {
        return 0.0;
    }
    let mut tv = 0.0;
    for (p, o) in planned.iter().zip(observed) {
        tv += (p - o / total).abs();
    }
    0.5 * tv
}

/// Normalize counts into a distribution (all-zero stays all-zero).
fn normalize(xs: &[f64]) -> Vec<f64> {
    let total: f64 = xs.iter().sum();
    if total <= 0.0 {
        return vec![0.0; xs.len()];
    }
    xs.iter().map(|&x| x / total).collect()
}

/// Quantize a decayed profile back to the u32 counts the fills consume,
/// under a caller-chosen `scale`. The same scale must be applied to the
/// node-visit and element-count arrays of one re-plan: planners like
/// DUCATI compare value densities *across* the two arrays, so
/// per-array scaling would skew the knapsack's feature-vs-adjacency
/// choice. Uniform scaling itself is fill-invariant (thresholds and
/// orderings compare relative magnitudes).
fn quantize(xs: &[f64], scale: f64) -> Vec<u32> {
    xs.iter().map(|&x| (x * scale).round().max(0.0) as u32).collect()
}

/// One common scale for a re-plan's two count arrays: lifts decayed
/// (sub-1) profiles to 10-bit resolution at the hottest entry so
/// rounding cannot zero a still-meaningful profile, and leaves large
/// counts untouched.
fn common_scale(a: &[f64], b: &[f64]) -> f64 {
    let maxv = a
        .iter()
        .chain(b)
        .cloned()
        .fold(0.0f64, f64::max);
    if maxv > 0.0 && maxv < 1024.0 {
        1024.0 / maxv
    } else {
        1.0
    }
}

/// Sleep up to `total`, waking early (within one 5 ms slice) when
/// `stop` is raised — keeps `Refresher::stop` latency bounded even
/// with multi-second poll intervals.
fn sleep_interruptibly(total: Duration, stop: &AtomicBool) {
    let slice = Duration::from_millis(5);
    let deadline = Instant::now() + total;
    while !stop.load(Ordering::Relaxed) {
        let now = Instant::now();
        if now >= deadline {
            break;
        }
        std::thread::sleep((deadline - now).min(slice));
    }
}

#[allow(clippy::too_many_arguments)]
fn refresh_loop(
    ds: &Dataset,
    runtime: &DualCacheRuntime,
    tracker: &AccessTracker,
    planner: &dyn CachePlanner,
    budget: u64,
    planned_visits: Vec<u32>,
    cfg: &RefreshConfig,
    stop: &AtomicBool,
    stats_out: &Mutex<RefreshStats>,
) {
    let n_nodes = ds.csc.n_nodes();
    let planned_f: Vec<f64> = planned_visits.iter().map(|&c| c as f64).collect();
    let mut planned = normalize(&planned_f);
    if planned.len() != n_nodes {
        planned = vec![0.0; n_nodes];
    }

    let mut acc_nv: Vec<f64> = vec![0.0; n_nodes];
    let mut acc_ec: Vec<f64> = vec![0.0; ds.csc.n_edges()];
    let mut acc_ts = 0.0f64;
    let mut acc_tf = 0.0f64;
    let mut batches_pending = 0u64;
    let mut stats = RefreshStats::default();

    while !stop.load(Ordering::Relaxed) {
        sleep_interruptibly(cfg.check_interval, stop);
        if stop.load(Ordering::Relaxed) {
            break;
        }
        // idle server: skip the O(nodes + edges) drain entirely
        if tracker.batches() == 0 && batches_pending == 0 {
            continue;
        }
        let d = tracker.drain();
        if d.batches > 0 {
            for a in acc_nv.iter_mut() {
                *a *= cfg.decay;
            }
            for a in acc_ec.iter_mut() {
                *a *= cfg.decay;
            }
            acc_ts = acc_ts * cfg.decay + d.t_sample_ns;
            acc_tf = acc_tf * cfg.decay + d.t_feature_ns;
            for (a, &c) in acc_nv.iter_mut().zip(&d.node_visits) {
                *a += c as f64;
            }
            for (a, &c) in acc_ec.iter_mut().zip(&d.elem_counts) {
                *a += c as f64;
            }
            batches_pending += d.batches;
        }
        if batches_pending < cfg.min_batches.max(1) {
            continue;
        }

        stats.checks += 1;
        // the min-batches window is per *check*: reset it whatever the
        // verdict, so a quiet server goes back to the idle skip above
        // instead of re-checking unchanged data every poll (drift that
        // builds slowly still accumulates in the decayed profile)
        batches_pending = 0;
        let drift = tv_distance(&planned, &acc_nv);
        stats.last_drift = drift;
        if drift <= cfg.drift_threshold {
            *stats_out.lock().unwrap() = stats.clone();
            continue;
        }

        // re-plan on this thread with the planner's (lightweight) fill
        // and hot-swap; the serving path never waits on any of this
        let t0 = Instant::now();
        let scale = common_scale(&acc_nv, &acc_ec);
        let nv = quantize(&acc_nv, scale);
        let ec = quantize(&acc_ec, scale);
        let profile = WorkloadProfile {
            node_visits: &nv,
            elem_counts: &ec,
            t_sample_ns: acc_ts,
            t_feature_ns: acc_tf,
        };
        let plan = planner.plan(ds, &profile, budget);
        stats.fill_h2d_bytes += plan.fill_ledger.h2d_bytes;
        runtime.install(plan.snapshot);
        stats.replan_wall_ns += t0.elapsed().as_nanos() as f64;
        stats.replans += 1;
        planned = normalize(&acc_nv);
        *stats_out.lock().unwrap() = stats.clone();
    }
    *stats_out.lock().unwrap() = stats;
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::cache::planner::DciPlanner;
    use crate::cache::runtime::CacheSnapshot;
    use crate::graph::datasets;

    #[test]
    fn tracker_counts_and_drains() {
        let t = AccessTracker::new(4, 6);
        t.record_node(1);
        t.record_node(1);
        t.record_node(3);
        t.record_elem(5);
        t.record_batch(100.0, 200.0);
        assert_eq!(t.batches(), 1);
        let d = t.drain();
        assert_eq!(d.node_visits, vec![0, 2, 0, 1]);
        assert_eq!(d.elem_counts[5], 1);
        assert_eq!(d.batches, 1);
        assert_eq!(d.t_sample_ns, 100.0);
        assert_eq!(d.t_feature_ns, 200.0);
        // drained: everything reset
        let d2 = t.drain();
        assert_eq!(d2.batches, 0);
        assert!(d2.node_visits.iter().all(|&c| c == 0));
    }

    #[test]
    fn tv_distance_bounds() {
        let p = vec![0.5, 0.5, 0.0];
        assert_eq!(tv_distance(&p, &[1.0, 1.0, 0.0]), 0.0);
        // fully disjoint mass -> 1.0
        let q = vec![0.0, 0.0, 7.0];
        assert!((tv_distance(&p, &q) - 1.0).abs() < 1e-12);
        // empty observation -> no drift signal
        assert_eq!(tv_distance(&p, &[0.0, 0.0, 0.0]), 0.0);
    }

    #[test]
    fn quantize_preserves_relative_magnitudes() {
        let nv = [0.1, 0.2, 0.4];
        let scale = common_scale(&nv, &[]);
        let q = quantize(&nv, scale);
        assert!(q[2] > q[1] && q[1] > q[0]);
        assert_eq!(q[2], 1024);
        assert_eq!(quantize(&[0.0, 0.0], common_scale(&[0.0, 0.0], &[])), vec![0, 0]);
        // large counts pass through unscaled
        let big = [2000.0, 4000.0];
        assert_eq!(quantize(&big, common_scale(&big, &[])), vec![2000, 4000]);
        // ONE scale across both arrays of a re-plan: the hotter array
        // pins it, so cross-array density ratios survive quantization
        let ec = [4000.0];
        let s = common_scale(&nv, &ec);
        assert_eq!(s, 1.0);
        assert_eq!(quantize(&nv, s), vec![0, 0, 0]);
        assert_eq!(quantize(&ec, s), vec![4000]);
    }

    #[test]
    fn refresher_replans_on_forced_drift() {
        let ds = Arc::new(datasets::spec("tiny").unwrap().build());
        let runtime = Arc::new(DualCacheRuntime::new(CacheSnapshot::empty()));
        let tracker = Arc::new(AccessTracker::new(ds.csc.n_nodes(), ds.csc.n_edges()));
        // a baseline profile concentrated on node 0; observe node 1
        let mut planned = vec![0u32; ds.csc.n_nodes()];
        planned[0] = 100;
        let r = Refresher::spawn(
            Arc::clone(&ds),
            Arc::clone(&runtime),
            Arc::clone(&tracker),
            Box::new(DciPlanner),
            200_000,
            planned,
            RefreshConfig {
                check_interval: Duration::from_millis(5),
                min_batches: 1,
                decay: 0.5,
                drift_threshold: 0.3,
            },
        );
        for _ in 0..50 {
            tracker.record_node(1);
        }
        tracker.record_elem(0);
        tracker.record_batch(50.0, 50.0);
        // wait for the loop to pick it up
        let deadline = Instant::now() + Duration::from_secs(10);
        while runtime.swaps() == 0 && Instant::now() < deadline {
            std::thread::sleep(Duration::from_millis(5));
        }
        let stats = r.stop();
        assert!(stats.replans >= 1, "drift should have forced a re-plan: {stats:?}");
        assert!(stats.last_drift > 0.3);
        assert!(runtime.swaps() >= 1);
        // the refreshed snapshot caches the observed hot node
        let snap = runtime.load();
        assert!(snap.feat.as_ref().unwrap().contains(1));
    }

    #[test]
    fn refresher_idle_without_traffic() {
        let ds = Arc::new(datasets::spec("tiny").unwrap().build());
        let runtime = Arc::new(DualCacheRuntime::new(CacheSnapshot::empty()));
        let tracker = Arc::new(AccessTracker::new(ds.csc.n_nodes(), ds.csc.n_edges()));
        let r = Refresher::spawn(
            Arc::clone(&ds),
            Arc::clone(&runtime),
            Arc::clone(&tracker),
            Box::new(DciPlanner),
            100_000,
            Vec::new(),
            RefreshConfig {
                check_interval: Duration::from_millis(2),
                min_batches: 1,
                decay: 0.5,
                drift_threshold: 0.0,
            },
        );
        std::thread::sleep(Duration::from_millis(30));
        let stats = r.stop();
        assert_eq!(stats.replans, 0, "no traffic, no re-plan");
        assert_eq!(runtime.swaps(), 0);
    }
}
