//! Online workload-drift re-planning, per shard.
//!
//! A serving deployment whose request mix drifts keeps paying misses on
//! a stale plan (BGL's observation: feature-cache policy must track the
//! live access distribution). DCI's two-scan fills make re-planning
//! cheap enough to do *online*, so:
//!
//! - the serving hot path bumps an [`AccessTracker`] (relaxed atomic
//!   adds: per input node in the gather stage, per touched element in
//!   the sampling stage — same counters pre-sampling collects);
//! - a background [`Refresher`] thread drains the tracker on a poll
//!   interval into an exponentially decayed profile and measures drift
//!   **per shard**: the total-variation distance between the
//!   within-shard node-visit distribution the shard's live snapshot was
//!   planned from and the decayed observed one;
//! - a shard past the drift threshold is re-planned through the same
//!   [`CachePlanner`] the offline path used — from the profile *masked*
//!   to the shard's own nodes, within the shard's own budget — and
//!   hot-swapped into that shard of the
//!   [`ShardedRuntime`](crate::cache::ShardedRuntime). The other shards
//!   keep serving their current epoch untouched, so a localized drift
//!   uploads ~1/N of what a full re-plan would (the `shard_runtime`
//!   bench holds this). Readers pick new epochs up on their next
//!   per-batch acquire, never blocking (the runtime counts any reader
//!   that does block; the benches assert zero).
//!
//! With one shard this is exactly the PR 2 global refresh loop. With
//! [`RefreshConfig::per_shard`] disabled, any shard's drift re-plans
//! every shard (the "full re-plan" comparison mode).
//!
//! Cost: the tracker is two count arrays (O(nodes) + O(edges)) per
//! worker and one relaxed `fetch_add` per access; the drift check is
//! O(nodes + edges) on the background thread per poll that saw new
//! batches, independent of shard count. Sparse/windowed tracking is an
//! open item (ROADMAP).

use std::sync::atomic::{AtomicBool, AtomicU32, AtomicU64, Ordering};
use std::sync::{Arc, Mutex};
use std::thread::JoinHandle;
use std::time::{Duration, Instant};

use crate::graph::{Dataset, NodeId};

use super::planner::{CachePlanner, WorkloadProfile};
use super::shard::{mask_elem_counts, mask_node_counts, ShardedRuntime};

/// Knobs of the online refresh loop.
#[derive(Debug, Clone, PartialEq)]
pub struct RefreshConfig {
    /// Poll period of the background drift check.
    pub check_interval: Duration,
    /// Served batches that must accumulate before a drift check counts.
    pub min_batches: u64,
    /// Exponential decay applied to the accumulated profile on every
    /// poll that drained new data (0 = only the newest window counts,
    /// 1 = never forget).
    pub decay: f64,
    /// Total-variation distance (in [0, 1]) between the planned and
    /// observed within-shard node-visit distributions that triggers a
    /// re-plan of that shard.
    pub drift_threshold: f64,
    /// Re-plan only the shards that drifted (`true`, the default).
    /// `false` re-plans every shard as soon as any one drifts — the
    /// full-re-plan comparison mode (`shard-refresh=off`).
    pub per_shard: bool,
}

impl Default for RefreshConfig {
    fn default() -> Self {
        RefreshConfig {
            check_interval: Duration::from_millis(100),
            min_batches: 8,
            decay: 0.5,
            drift_threshold: 0.15,
            per_shard: true,
        }
    }
}

/// Serving-time access accumulator. One per engine; the hot path adds
/// with relaxed atomics (u32 adds commute, so counts are exact
/// whatever the thread interleaving), the refresher drains with
/// `swap(0)`.
pub struct AccessTracker {
    node_visits: Vec<AtomicU32>,
    elem_counts: Vec<AtomicU32>,
    batches: AtomicU64,
    /// Modeled stage ns accumulated as integer ns (Eq. 1 ratio input).
    t_sample_ns: AtomicU64,
    t_feature_ns: AtomicU64,
}

/// One drained window of tracker counts.
pub struct DrainedCounts {
    pub node_visits: Vec<u32>,
    pub elem_counts: Vec<u32>,
    pub batches: u64,
    pub t_sample_ns: f64,
    pub t_feature_ns: f64,
}

impl AccessTracker {
    pub fn new(n_nodes: usize, n_edges: usize) -> Self {
        AccessTracker {
            node_visits: (0..n_nodes).map(|_| AtomicU32::new(0)).collect(),
            elem_counts: (0..n_edges).map(|_| AtomicU32::new(0)).collect(),
            batches: AtomicU64::new(0),
            t_sample_ns: AtomicU64::new(0),
            t_feature_ns: AtomicU64::new(0),
        }
    }

    /// Record one feature-stage visit of `v` (gather stage).
    #[inline]
    pub fn record_node(&self, v: NodeId) {
        self.node_visits[v as usize].fetch_add(1, Ordering::Relaxed);
    }

    /// Record one adjacency-element access at CSC offset `at`
    /// (sampling stage).
    #[inline]
    pub fn record_elem(&self, at: usize) {
        self.elem_counts[at].fetch_add(1, Ordering::Relaxed);
    }

    /// Record a served batch's modeled stage times.
    pub fn record_batch(&self, t_sample_ns: f64, t_feature_ns: f64) {
        self.batches.fetch_add(1, Ordering::Relaxed);
        self.t_sample_ns
            .fetch_add(t_sample_ns.max(0.0) as u64, Ordering::Relaxed);
        self.t_feature_ns
            .fetch_add(t_feature_ns.max(0.0) as u64, Ordering::Relaxed);
    }

    /// Batches recorded since the last drain.
    pub fn batches(&self) -> u64 {
        self.batches.load(Ordering::Relaxed)
    }

    /// Take the counts, resetting them to zero.
    pub fn drain(&self) -> DrainedCounts {
        DrainedCounts {
            node_visits: self
                .node_visits
                .iter()
                .map(|c| c.swap(0, Ordering::Relaxed))
                .collect(),
            elem_counts: self
                .elem_counts
                .iter()
                .map(|c| c.swap(0, Ordering::Relaxed))
                .collect(),
            batches: self.batches.swap(0, Ordering::Relaxed),
            t_sample_ns: self.t_sample_ns.swap(0, Ordering::Relaxed) as f64,
            t_feature_ns: self.t_feature_ns.swap(0, Ordering::Relaxed) as f64,
        }
    }
}

/// What the refresh loop did over its lifetime.
#[derive(Debug, Clone, Default)]
pub struct RefreshStats {
    /// Drift checks that had enough data to evaluate.
    pub checks: u64,
    /// Shard re-plans installed (every install counts one shard).
    pub replans: u64,
    /// Installs per shard (len = shard count).
    pub shard_replans: Vec<u64>,
    /// Largest per-shard drift measured by the last check.
    pub last_drift: f64,
    /// Total background wall time spent planning + installing, ns.
    pub replan_wall_ns: f64,
    /// H2D bytes uploaded by online refills, summed over installs.
    pub fill_h2d_bytes: u64,
    /// Largest single-install upload — what one drifted-shard refresh
    /// costs, vs `fill_h2d_bytes` for the cumulative story.
    pub max_install_h2d_bytes: u64,
}

/// Handle to the background refresh thread.
pub struct Refresher {
    stop: Arc<AtomicBool>,
    join: JoinHandle<()>,
    stats: Arc<Mutex<RefreshStats>>,
}

impl Refresher {
    /// Spawn the refresh loop over a (possibly sharded) runtime.
    /// `planned_visits` is the global node-visit profile the runtime's
    /// live snapshots were planned from (the pre-sample profile at
    /// startup); `shard_budgets` is the per-shard byte budget every
    /// re-plan must stay within (len = shard count — installs never
    /// grow any device's claim; see the snapshot lifetime rules).
    pub fn spawn(
        ds: Arc<Dataset>,
        runtime: Arc<ShardedRuntime>,
        tracker: Arc<AccessTracker>,
        planner: Box<dyn CachePlanner>,
        shard_budgets: Vec<u64>,
        planned_visits: Vec<u32>,
        cfg: RefreshConfig,
    ) -> Refresher {
        assert_eq!(
            shard_budgets.len(),
            runtime.n_shards(),
            "one budget per shard"
        );
        let stop = Arc::new(AtomicBool::new(false));
        let stats = Arc::new(Mutex::new(RefreshStats::default()));
        let stop2 = Arc::clone(&stop);
        let stats2 = Arc::clone(&stats);
        let join = std::thread::Builder::new()
            .name("dci-refresh".into())
            .spawn(move || {
                refresh_loop(
                    &ds,
                    &runtime,
                    &tracker,
                    planner.as_ref(),
                    &shard_budgets,
                    planned_visits,
                    &cfg,
                    &stop2,
                    &stats2,
                )
            })
            .expect("spawn refresh thread");
        Refresher { stop, join, stats }
    }

    /// Current stats (the loop keeps them up to date after every check).
    pub fn stats(&self) -> RefreshStats {
        self.stats.lock().unwrap().clone()
    }

    /// Stop the loop and return its final stats.
    pub fn stop(self) -> RefreshStats {
        self.stop.store(true, Ordering::Relaxed);
        let _ = self.join.join();
        let stats = self.stats.lock().unwrap().clone();
        stats
    }
}

/// Per-shard total-variation drift between the planned and observed
/// node-visit masses. Each shard's masses are normalized *within the
/// shard* — a shard with no observations reports zero drift (nothing
/// asked of it, nothing to re-plan), and a shard with observations but
/// no planned mass reports 0.5 (all of its traffic is new). With one
/// shard this is exactly the PR 2 global total-variation distance.
fn shard_drifts(
    planned: &[f64],
    observed: &[f64],
    shard_ids: &[u32],
    n_shards: usize,
) -> Vec<f64> {
    let mut psum = vec![0.0f64; n_shards];
    let mut osum = vec![0.0f64; n_shards];
    for (v, &s) in shard_ids.iter().enumerate() {
        psum[s as usize] += planned[v];
        osum[s as usize] += observed[v];
    }
    let mut tv = vec![0.0f64; n_shards];
    for (v, &s) in shard_ids.iter().enumerate() {
        let s = s as usize;
        if osum[s] <= 0.0 {
            continue;
        }
        let p = if psum[s] > 0.0 { planned[v] / psum[s] } else { 0.0 };
        tv[s] += (p - observed[v] / osum[s]).abs();
    }
    for (s, t) in tv.iter_mut().enumerate() {
        *t = if osum[s] <= 0.0 { 0.0 } else { 0.5 * *t };
    }
    tv
}

/// Quantize a decayed profile back to the u32 counts the fills consume,
/// under a caller-chosen `scale`. The same scale must be applied to the
/// node-visit and element-count arrays of one re-plan: planners like
/// DUCATI compare value densities *across* the two arrays, so
/// per-array scaling would skew the knapsack's feature-vs-adjacency
/// choice. Uniform scaling itself is fill-invariant (thresholds and
/// orderings compare relative magnitudes).
fn quantize(xs: &[f64], scale: f64) -> Vec<u32> {
    xs.iter().map(|&x| (x * scale).round().max(0.0) as u32).collect()
}

/// One common scale for a re-plan's two count arrays: lifts decayed
/// (sub-1) profiles to 10-bit resolution at the hottest entry so
/// rounding cannot zero a still-meaningful profile, and leaves large
/// counts untouched.
fn common_scale(a: &[f64], b: &[f64]) -> f64 {
    let maxv = a.iter().chain(b).cloned().fold(0.0f64, f64::max);
    if maxv > 0.0 && maxv < 1024.0 {
        1024.0 / maxv
    } else {
        1.0
    }
}

/// Sleep up to `total`, waking early (within one 5 ms slice) when
/// `stop` is raised — keeps `Refresher::stop` latency bounded even
/// with multi-second poll intervals.
fn sleep_interruptibly(total: Duration, stop: &AtomicBool) {
    let slice = Duration::from_millis(5);
    let deadline = Instant::now() + total;
    while !stop.load(Ordering::Relaxed) {
        let now = Instant::now();
        if now >= deadline {
            break;
        }
        std::thread::sleep((deadline - now).min(slice));
    }
}

#[allow(clippy::too_many_arguments)]
fn refresh_loop(
    ds: &Dataset,
    runtime: &ShardedRuntime,
    tracker: &AccessTracker,
    planner: &dyn CachePlanner,
    shard_budgets: &[u64],
    planned_visits: Vec<u32>,
    cfg: &RefreshConfig,
    stop: &AtomicBool,
    stats_out: &Mutex<RefreshStats>,
) {
    let n_nodes = ds.csc.n_nodes();
    let n_edges = ds.csc.n_edges();
    let n_shards = runtime.n_shards();
    let router = runtime.router();
    // node → shard once up front: the hash is cheap but the drift check
    // runs every poll over every node
    let shard_ids: Vec<u32> =
        (0..n_nodes).map(|v| router.shard_of(v as NodeId) as u32).collect();

    // raw planned masses; drifts normalize within each shard per check
    let mut planned: Vec<f64> = if planned_visits.len() == n_nodes {
        planned_visits.iter().map(|&c| c as f64).collect()
    } else {
        vec![0.0; n_nodes]
    };

    let mut acc_nv: Vec<f64> = vec![0.0; n_nodes];
    let mut acc_ec: Vec<f64> = vec![0.0; n_edges];
    let mut acc_ts = 0.0f64;
    let mut acc_tf = 0.0f64;
    let mut batches_pending = 0u64;
    let mut stats = RefreshStats { shard_replans: vec![0; n_shards], ..Default::default() };

    while !stop.load(Ordering::Relaxed) {
        sleep_interruptibly(cfg.check_interval, stop);
        if stop.load(Ordering::Relaxed) {
            break;
        }
        // idle server: skip the O(nodes + edges) drain entirely
        if tracker.batches() == 0 && batches_pending == 0 {
            continue;
        }
        let d = tracker.drain();
        if d.batches > 0 {
            for a in acc_nv.iter_mut() {
                *a *= cfg.decay;
            }
            for a in acc_ec.iter_mut() {
                *a *= cfg.decay;
            }
            acc_ts = acc_ts * cfg.decay + d.t_sample_ns;
            acc_tf = acc_tf * cfg.decay + d.t_feature_ns;
            for (a, &c) in acc_nv.iter_mut().zip(&d.node_visits) {
                *a += c as f64;
            }
            for (a, &c) in acc_ec.iter_mut().zip(&d.elem_counts) {
                *a += c as f64;
            }
            batches_pending += d.batches;
        }
        if batches_pending < cfg.min_batches.max(1) {
            continue;
        }

        stats.checks += 1;
        // the min-batches window is per *check*: reset it whatever the
        // verdict, so a quiet server goes back to the idle skip above
        // instead of re-checking unchanged data every poll (drift that
        // builds slowly still accumulates in the decayed profile)
        batches_pending = 0;
        let drifts = shard_drifts(&planned, &acc_nv, &shard_ids, n_shards);
        stats.last_drift = drifts.iter().cloned().fold(0.0, f64::max);
        let any_drifted = drifts.iter().any(|&d| d > cfg.drift_threshold);
        let drifted: Vec<usize> = if cfg.per_shard || n_shards == 1 {
            (0..n_shards).filter(|&s| drifts[s] > cfg.drift_threshold).collect()
        } else if any_drifted {
            (0..n_shards).collect()
        } else {
            Vec::new()
        };
        if drifted.is_empty() {
            *stats_out.lock().unwrap() = stats.clone();
            continue;
        }

        // re-plan each drifted shard on this thread from the profile
        // masked to the shard's own nodes, within the shard's own
        // budget, and hot-swap only that shard; the serving path — and
        // every *other* shard — never waits on any of this
        for s in drifted {
            let t0 = Instant::now();
            // same ownership rule as the offline sharded plan: one
            // masking implementation, shared with cache/shard.rs
            let nv_m = mask_node_counts(&acc_nv, router, s);
            let ec_m = mask_elem_counts(&acc_ec, &ds.csc, router, s);
            let scale = common_scale(&nv_m, &ec_m);
            let nv = quantize(&nv_m, scale);
            let ec = quantize(&ec_m, scale);
            let profile = WorkloadProfile {
                node_visits: &nv,
                elem_counts: &ec,
                t_sample_ns: acc_ts,
                t_feature_ns: acc_tf,
            };
            let plan = planner.plan(ds, &profile, shard_budgets[s]);
            let install_bytes = plan.fill_ledger.h2d_bytes;
            stats.fill_h2d_bytes += install_bytes;
            stats.max_install_h2d_bytes = stats.max_install_h2d_bytes.max(install_bytes);
            runtime.install_shard(s, plan.snapshot);
            stats.replan_wall_ns += t0.elapsed().as_nanos() as f64;
            stats.replans += 1;
            stats.shard_replans[s] += 1;
            // re-center this shard's drift baseline on what it now serves
            for v in 0..n_nodes {
                if shard_ids[v] == s as u32 {
                    planned[v] = acc_nv[v];
                }
            }
        }
        *stats_out.lock().unwrap() = stats.clone();
    }
    *stats_out.lock().unwrap() = stats;
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::cache::planner::{split_budget, DciPlanner};
    use crate::cache::runtime::CacheSnapshot;
    use crate::cache::shard::{plan_sharded, ShardRouter, ShardedRuntime};
    use crate::graph::datasets;
    use crate::mem::CostModel;
    use crate::sampler::{presample, Fanout};
    use crate::util::Rng;

    fn fast_cfg(threshold: f64) -> RefreshConfig {
        RefreshConfig {
            check_interval: Duration::from_millis(5),
            min_batches: 1,
            decay: 0.5,
            drift_threshold: threshold,
            per_shard: true,
        }
    }

    #[test]
    fn tracker_counts_and_drains() {
        let t = AccessTracker::new(4, 6);
        t.record_node(1);
        t.record_node(1);
        t.record_node(3);
        t.record_elem(5);
        t.record_batch(100.0, 200.0);
        assert_eq!(t.batches(), 1);
        let d = t.drain();
        assert_eq!(d.node_visits, vec![0, 2, 0, 1]);
        assert_eq!(d.elem_counts[5], 1);
        assert_eq!(d.batches, 1);
        assert_eq!(d.t_sample_ns, 100.0);
        assert_eq!(d.t_feature_ns, 200.0);
        // drained: everything reset
        let d2 = t.drain();
        assert_eq!(d2.batches, 0);
        assert!(d2.node_visits.iter().all(|&c| c == 0));
    }

    #[test]
    fn single_shard_drift_is_the_global_tv_distance() {
        let ids = vec![0u32; 3];
        let p = [1.0, 1.0, 0.0];
        // matched distribution → 0
        assert_eq!(shard_drifts(&p, &[2.0, 2.0, 0.0], &ids, 1), vec![0.0]);
        // fully disjoint mass → 1
        let d = shard_drifts(&p, &[0.0, 0.0, 7.0], &ids, 1);
        assert!((d[0] - 1.0).abs() < 1e-12);
        // empty observation → no drift signal
        assert_eq!(shard_drifts(&p, &[0.0, 0.0, 0.0], &ids, 1), vec![0.0]);
        // no planned mass but live traffic → 0.5 (half the mass is new)
        let d = shard_drifts(&[0.0, 0.0, 0.0], &[3.0, 1.0, 0.0], &ids, 1);
        assert!((d[0] - 0.5).abs() < 1e-12);
    }

    #[test]
    fn drift_is_isolated_to_the_observed_shard() {
        // nodes 0,1 on shard 0; nodes 2,3 on shard 1
        let ids = vec![0u32, 0, 1, 1];
        let planned = [10.0, 0.0, 5.0, 5.0];
        // shard 0's traffic flipped to node 1; shard 1 saw nothing
        let observed = [0.0, 8.0, 0.0, 0.0];
        let d = shard_drifts(&planned, &observed, &ids, 2);
        assert!((d[0] - 1.0).abs() < 1e-12, "shard 0 fully drifted: {d:?}");
        assert_eq!(d[1], 0.0, "unobserved shard must not drift: {d:?}");
        // shard 1's traffic matching its plan stays quiet while shard 0
        // drifts — per-shard normalization keeps them independent
        let observed = [0.0, 8.0, 4.0, 4.0];
        let d = shard_drifts(&planned, &observed, &ids, 2);
        assert!(d[0] > 0.9);
        assert!(d[1] < 1e-12);
    }

    #[test]
    fn quantize_preserves_relative_magnitudes() {
        let nv = [0.1, 0.2, 0.4];
        let scale = common_scale(&nv, &[]);
        let q = quantize(&nv, scale);
        assert!(q[2] > q[1] && q[1] > q[0]);
        assert_eq!(q[2], 1024);
        assert_eq!(quantize(&[0.0, 0.0], common_scale(&[0.0, 0.0], &[])), vec![0, 0]);
        // large counts pass through unscaled
        let big = [2000.0, 4000.0];
        assert_eq!(quantize(&big, common_scale(&big, &[])), vec![2000, 4000]);
        // ONE scale across both arrays of a re-plan: the hotter array
        // pins it, so cross-array density ratios survive quantization
        let ec = [4000.0];
        let s = common_scale(&nv, &ec);
        assert_eq!(s, 1.0);
        assert_eq!(quantize(&nv, s), vec![0, 0, 0]);
        assert_eq!(quantize(&ec, s), vec![4000]);
    }

    #[test]
    fn refresher_replans_on_forced_drift() {
        let ds = Arc::new(datasets::spec("tiny").unwrap().build());
        let runtime = Arc::new(ShardedRuntime::single(CacheSnapshot::empty()));
        let tracker = Arc::new(AccessTracker::new(ds.csc.n_nodes(), ds.csc.n_edges()));
        // a baseline profile concentrated on node 0; observe node 1
        let mut planned = vec![0u32; ds.csc.n_nodes()];
        planned[0] = 100;
        let r = Refresher::spawn(
            Arc::clone(&ds),
            Arc::clone(&runtime),
            Arc::clone(&tracker),
            Box::new(DciPlanner),
            vec![200_000],
            planned,
            fast_cfg(0.3),
        );
        for _ in 0..50 {
            tracker.record_node(1);
        }
        tracker.record_elem(0);
        tracker.record_batch(50.0, 50.0);
        // wait for the loop to pick it up
        let deadline = Instant::now() + Duration::from_secs(10);
        while runtime.swaps() == 0 && Instant::now() < deadline {
            std::thread::sleep(Duration::from_millis(5));
        }
        let stats = r.stop();
        assert!(stats.replans >= 1, "drift should have forced a re-plan: {stats:?}");
        assert!(stats.last_drift > 0.3);
        assert!(stats.max_install_h2d_bytes > 0);
        assert!(runtime.swaps() >= 1);
        // the refreshed snapshot caches the observed hot node
        let snap = runtime.load();
        assert!(snap.feat.as_ref().unwrap().contains(1));
    }

    #[test]
    fn refresher_idle_without_traffic() {
        let ds = Arc::new(datasets::spec("tiny").unwrap().build());
        let runtime = Arc::new(ShardedRuntime::single(CacheSnapshot::empty()));
        let tracker = Arc::new(AccessTracker::new(ds.csc.n_nodes(), ds.csc.n_edges()));
        let r = Refresher::spawn(
            Arc::clone(&ds),
            Arc::clone(&runtime),
            Arc::clone(&tracker),
            Box::new(DciPlanner),
            vec![100_000],
            Vec::new(),
            fast_cfg(0.0),
        );
        std::thread::sleep(Duration::from_millis(30));
        let stats = r.stop();
        assert_eq!(stats.replans, 0, "no traffic, no re-plan");
        assert_eq!(runtime.swaps(), 0);
    }

    /// The tentpole invariant: traffic that drifts inside one shard
    /// re-plans *only* that shard; every other shard keeps serving its
    /// original epoch.
    #[test]
    fn refresher_replans_only_the_drifted_shard() {
        let n_shards = 4;
        let ds = Arc::new(datasets::spec("tiny").unwrap().build());
        let router = ShardRouter::new(n_shards);
        let budget = 120_000u64;
        let budgets = split_budget(budget, n_shards);

        // startup plan: a presample profile sharded across 4 devices
        let stats0 = presample(
            &ds.csc,
            &ds.features,
            &ds.test_nodes,
            64,
            &Fanout::parse("3,2").unwrap(),
            4,
            &CostModel::default(),
            &mut Rng::new(7),
        );
        let profile = WorkloadProfile::from_presample(&stats0);
        let sharded = plan_sharded(&DciPlanner, &ds, &profile, budget, &router);
        let runtime = Arc::new(ShardedRuntime::new(
            ShardRouter::new(n_shards),
            sharded.plans.into_iter().map(|p| p.snapshot).collect(),
        ));
        let tracker = Arc::new(AccessTracker::new(ds.csc.n_nodes(), ds.csc.n_edges()));
        let r = Refresher::spawn(
            Arc::clone(&ds),
            Arc::clone(&runtime),
            Arc::clone(&tracker),
            Box::new(DciPlanner),
            budgets,
            stats0.node_visits.clone(),
            fast_cfg(0.3),
        );

        // drive traffic confined to shard 2's nodes, disjoint from the
        // planned profile's hot set as far as shard 2 is concerned
        let shard2: Vec<NodeId> = (0..ds.csc.n_nodes() as u32)
            .filter(|&v| router.shard_of(v) == 2 && stats0.node_visits[v as usize] == 0)
            .take(40)
            .collect();
        assert!(shard2.len() >= 10, "tiny must have unvisited shard-2 nodes");
        for _ in 0..20 {
            for &v in &shard2 {
                tracker.record_node(v);
            }
        }
        tracker.record_batch(50.0, 50.0);

        let deadline = Instant::now() + Duration::from_secs(10);
        while runtime.swaps() == 0 && Instant::now() < deadline {
            std::thread::sleep(Duration::from_millis(5));
        }
        let stats = r.stop();
        assert!(stats.replans >= 1, "shard 2's drift must re-plan: {stats:?}");
        assert!(stats.shard_replans[2] >= 1, "{stats:?}");
        for s in [0usize, 1, 3] {
            assert_eq!(
                stats.shard_replans[s],
                0,
                "shard {s} saw no drift and must keep its epoch: {stats:?}"
            );
            assert_eq!(runtime.shard(s).swaps(), 0);
        }
        assert!(runtime.shard(2).swaps() >= 1);
        assert_eq!(runtime.swap_stalls(), 0);
        // the refreshed shard caches its new hot nodes
        let snap = runtime.shard(2).load();
        let feat = snap.feat.as_ref().unwrap();
        let cached_hot = shard2.iter().filter(|&&v| feat.contains(v)).count();
        assert!(cached_hot > 0, "re-plan must cache shard 2's new working set");
    }
}
