//! Online workload-drift re-planning, per shard.
//!
//! A serving deployment whose request mix drifts keeps paying misses on
//! a stale plan (BGL's observation: feature-cache policy must track the
//! live access distribution). DCI's two-scan fills make re-planning
//! cheap enough to do *online*, so:
//!
//! - the serving hot path records into a
//!   [`WorkloadTracker`](super::tracker::WorkloadTracker) — per input
//!   node in the gather stage, per touched element in the sampling
//!   stage, the same counts pre-sampling collects. `tracker=dense` is
//!   the exact O(nodes + edges) counter pair; `tracker=sketch` is a
//!   count-min sketch with a bounded touched set (see
//!   [`super::tracker`]);
//! - a background [`Refresher`] thread drains the tracker on a poll
//!   interval into an exponentially decayed **sparse** profile — the
//!   drain + decay cost is O(touched keys this window), not
//!   O(nodes + edges): decay multiplies one scalar, new counts merge
//!   by key, and (with a sketch tracker) the profile is pruned to the
//!   tracker's heavy-hitter caps;
//! - drift is measured **per shard**: the total-variation distance
//!   between the within-shard node-visit distribution the shard's live
//!   snapshot was planned from and the decayed observed one, computed
//!   over the two sparse supports;
//! - a shard past the drift threshold is re-planned through the same
//!   [`CachePlanner`] the offline path used — from the decayed profile
//!   *masked* to the shard's own nodes (the heavy hitters the tracker
//!   recovered), within the shard's own budget — and hot-swapped into
//!   that shard of the [`ShardedRuntime`](crate::cache::ShardedRuntime).
//!   The other shards keep serving their current epoch untouched, so a
//!   localized drift uploads ~1/N of what a full re-plan would (the
//!   `shard_runtime` bench holds this). Readers pick new epochs up on
//!   their next per-batch acquire, never blocking (the runtime counts
//!   any reader that does block; the benches assert zero).
//!
//! With one shard this is exactly the PR 2 global refresh loop. With
//! [`RefreshConfig::per_shard`] disabled, any shard's drift re-plans
//! every shard (the "full re-plan" comparison mode).
//!
//! Cost: per poll that saw traffic, O(touched) drain + merge (plus the
//! tracker's own drain cost — O(nodes + edges) for `dense`,
//! O(touched) for `sketch`; `benches/sketch_tracker.rs` measures the
//! gap). Only an actual re-plan materializes dense count arrays for
//! the planner, and the planner itself is O(n) — the expensive path
//! runs exactly when a shard is about to be refilled anyway.

use std::collections::HashMap;
use std::sync::atomic::{AtomicBool, Ordering};
use std::sync::{Arc, Mutex};
use std::thread::JoinHandle;
use std::time::{Duration, Instant};

use crate::graph::{Csc, Dataset, NodeId};

use super::planner::{CachePlanner, WorkloadProfile};
use super::shard::{elem_owner, ShardRouter, ShardedRuntime};
use super::tracker::WorkloadTracker;

/// Knobs of the online refresh loop.
#[derive(Debug, Clone, PartialEq)]
pub struct RefreshConfig {
    /// Poll period of the background drift check.
    pub check_interval: Duration,
    /// Served batches that must accumulate before a drift check counts.
    pub min_batches: u64,
    /// Exponential decay applied to the accumulated profile on every
    /// poll that drained new data (0 = only the newest window counts,
    /// 1 = never forget).
    pub decay: f64,
    /// Total-variation distance (in [0, 1]) between the planned and
    /// observed within-shard node-visit distributions that triggers a
    /// re-plan of that shard.
    pub drift_threshold: f64,
    /// Re-plan only the shards that drifted (`true`, the default).
    /// `false` re-plans every shard as soon as any one drifts — the
    /// full-re-plan comparison mode (`shard-refresh=off`).
    pub per_shard: bool,
}

impl Default for RefreshConfig {
    fn default() -> Self {
        RefreshConfig {
            check_interval: Duration::from_millis(100),
            min_batches: 8,
            decay: 0.5,
            drift_threshold: 0.15,
            per_shard: true,
        }
    }
}

/// What the refresh loop did over its lifetime.
#[derive(Debug, Clone, Default)]
pub struct RefreshStats {
    /// Drift checks that had enough data to evaluate.
    pub checks: u64,
    /// Shard re-plans installed (every install counts one shard).
    pub replans: u64,
    /// Installs per shard (len = shard count).
    pub shard_replans: Vec<u64>,
    /// Largest per-shard drift measured by the last check.
    pub last_drift: f64,
    /// Total background wall time spent planning + installing, ns.
    pub replan_wall_ns: f64,
    /// H2D bytes uploaded by online refills, summed over installs.
    pub fill_h2d_bytes: u64,
    /// Largest single-install upload — what one drifted-shard refresh
    /// costs, vs `fill_h2d_bytes` for the cumulative story.
    pub max_install_h2d_bytes: u64,
    /// Background wall time spent draining the tracker and folding the
    /// window into the decayed profile, ns — the cost the sketch
    /// tracker shrinks from O(nodes + edges) to O(touched).
    pub drain_ns: f64,
    /// Sparse keys drained across all windows (nodes + elements).
    pub drained_keys: u64,
    /// Touches the tracker could not enumerate because its bounded
    /// touched set saturated (sketch only; 0 for dense).
    pub dropped_touches: u64,
}

/// Handle to the background refresh thread.
pub struct Refresher {
    stop: Arc<AtomicBool>,
    join: JoinHandle<()>,
    stats: Arc<Mutex<RefreshStats>>,
}

impl Refresher {
    /// Spawn the refresh loop over a (possibly sharded) runtime.
    /// `planned_visits` is the global node-visit profile the runtime's
    /// live snapshots were planned from (the pre-sample profile at
    /// startup); `shard_budgets` is the per-shard byte budget every
    /// re-plan must stay within (len = shard count — installs never
    /// grow any device's claim; see the snapshot lifetime rules).
    pub fn spawn(
        ds: Arc<Dataset>,
        runtime: Arc<ShardedRuntime>,
        tracker: Arc<dyn WorkloadTracker>,
        planner: Box<dyn CachePlanner>,
        shard_budgets: Vec<u64>,
        planned_visits: Vec<u32>,
        cfg: RefreshConfig,
    ) -> Refresher {
        assert_eq!(
            shard_budgets.len(),
            runtime.n_shards(),
            "one budget per shard"
        );
        let stop = Arc::new(AtomicBool::new(false));
        let stats = Arc::new(Mutex::new(RefreshStats::default()));
        let stop2 = Arc::clone(&stop);
        let stats2 = Arc::clone(&stats);
        let join = std::thread::Builder::new()
            .name("dci-refresh".into())
            .spawn(move || {
                refresh_loop(
                    &ds,
                    &runtime,
                    tracker.as_ref(),
                    planner.as_ref(),
                    &shard_budgets,
                    planned_visits,
                    &cfg,
                    &stop2,
                    &stats2,
                )
            })
            .expect("spawn refresh thread");
        Refresher { stop, join, stats }
    }

    /// Current stats (the loop keeps them up to date after every check).
    pub fn stats(&self) -> RefreshStats {
        self.stats.lock().unwrap().clone()
    }

    /// Stop the loop and return its final stats.
    pub fn stop(self) -> RefreshStats {
        self.stop.store(true, Ordering::Relaxed);
        let _ = self.join.join();
        let stats = self.stats.lock().unwrap().clone();
        stats
    }
}

/// A sparse exponentially decayed mass profile with O(touched) updates.
///
/// `acc = acc·decay + window` is implemented without touching
/// untouched keys: entries store *unscaled* mass `u` with one global
/// `scale` such that the actual mass is `u · scale`; a decay step
/// multiplies `scale` alone, and merging a window's count adds
/// `count / scale` to the key's entry. `scale` is rebased into the
/// entries before it can underflow.
///
/// With `cap = Some(k)` the profile is pruned to its top-k entries by
/// mass after every merge — the heavy-hitter recovery that keeps a
/// sketch-fed profile (and the re-plans built from it) bounded. The
/// pruned tail also bounds the drift-test error: dropped mass is at
/// most the smallest retained masses' total, a vanishing fraction of a
/// skewed workload (DESIGN.md §Workload tracking derives the bound).
struct DecayedSparse {
    mass: HashMap<u64, f64>,
    scale: f64,
    cap: Option<usize>,
}

/// Entries whose actual mass decays below this are dropped at prune
/// time: a decayed count this small cannot move a drift test or a fill
/// threshold, and dropping it keeps dense-tracker profiles from
/// accumulating every key ever touched.
const DUST: f64 = 1e-3;

impl DecayedSparse {
    fn new(cap: Option<usize>) -> Self {
        DecayedSparse { mass: HashMap::new(), scale: 1.0, cap }
    }

    /// One decay step (start of a window that saw traffic).
    fn decay(&mut self, decay: f64) {
        self.scale *= decay;
        if self.scale < 1e-12 {
            let s = self.scale;
            for u in self.mass.values_mut() {
                *u *= s;
            }
            self.scale = 1.0;
        }
    }

    /// Merge one drained count into the profile.
    fn add(&mut self, key: u64, count: f64) {
        *self.mass.entry(key).or_insert(0.0) += count / self.scale;
    }

    /// Drop dust and (when capped) everything below the top-`cap`
    /// masses. O(active entries).
    fn prune(&mut self) {
        let dust = DUST / self.scale;
        self.mass.retain(|_, u| *u >= dust);
        if let Some(cap) = self.cap {
            if self.mass.len() > cap {
                let mut us: Vec<f64> = self.mass.values().copied().collect();
                let cut = us.len() - cap;
                let (_, &mut thresh, _) =
                    us.select_nth_unstable_by(cut, |a, b| a.total_cmp(b));
                self.mass.retain(|_, u| *u >= thresh);
            }
        }
    }

    /// Actual (scaled) masses, sparse.
    fn iter(&self) -> impl Iterator<Item = (u64, f64)> + '_ {
        let s = self.scale;
        self.mass.iter().map(move |(&k, &u)| (k, u * s))
    }
}

/// Per-shard total-variation drift between the planned and observed
/// node-visit masses, computed over the two **sparse** supports — cost
/// O(|planned| + |observed|), independent of the graph size. Each
/// shard's masses are normalized *within the shard*: a shard with no
/// observations reports zero drift (nothing asked of it, nothing to
/// re-plan), and a shard with observations but no planned mass reports
/// 0.5 (all of its traffic is new). With one shard this is exactly the
/// PR 2 global total-variation distance.
fn shard_drifts_sparse(
    planned: &HashMap<u64, f64>,
    observed: &DecayedSparse,
    router: &ShardRouter,
    n_shards: usize,
) -> Vec<f64> {
    let mut psum = vec![0.0f64; n_shards];
    let mut osum = vec![0.0f64; n_shards];
    for (&v, &p) in planned {
        psum[router.shard_of(v as NodeId)] += p;
    }
    for (v, o) in observed.iter() {
        osum[router.shard_of(v as NodeId)] += o;
    }
    let mut tv = vec![0.0f64; n_shards];
    // Σ|p̂ − ô| over the union of supports: planned entries first, then
    // observed-only entries (their planned mass is zero)
    for (&v, &p) in planned {
        let s = router.shard_of(v as NodeId);
        if osum[s] <= 0.0 {
            continue;
        }
        let ph = if psum[s] > 0.0 { p / psum[s] } else { 0.0 };
        let oh = observed.mass.get(&v).copied().unwrap_or(0.0) * observed.scale
            / osum[s];
        tv[s] += (ph - oh).abs();
    }
    for (v, o) in observed.iter() {
        if planned.contains_key(&v) {
            continue;
        }
        let s = router.shard_of(v as NodeId);
        if osum[s] > 0.0 {
            tv[s] += o / osum[s];
        }
    }
    for t in tv.iter_mut() {
        *t *= 0.5;
    }
    tv
}

/// Quantize a decayed mass back to the u32 counts the fills consume,
/// under a caller-chosen `scale`. The same scale must be applied to the
/// node-visit and element-count arrays of one re-plan: planners like
/// DUCATI compare value densities *across* the two arrays, so
/// per-array scaling would skew the knapsack's feature-vs-adjacency
/// choice. Uniform scaling itself is fill-invariant (thresholds and
/// orderings compare relative magnitudes).
fn quantize(x: f64, scale: f64) -> u32 {
    (x * scale).round().max(0.0) as u32
}

/// One common scale for a re-plan's two count arrays: lifts decayed
/// (sub-1) profiles to 10-bit resolution at the hottest entry so
/// rounding cannot zero a still-meaningful profile, and leaves large
/// counts untouched.
fn common_scale(a: impl Iterator<Item = f64>, b: impl Iterator<Item = f64>) -> f64 {
    let maxv = a.chain(b).fold(0.0f64, f64::max);
    if maxv > 0.0 && maxv < 1024.0 {
        1024.0 / maxv
    } else {
        1.0
    }
}

/// Sleep up to `total`, waking early (within one 5 ms slice) when
/// `stop` is raised — keeps `Refresher::stop` latency bounded even
/// with multi-second poll intervals.
fn sleep_interruptibly(total: Duration, stop: &AtomicBool) {
    let slice = Duration::from_millis(5);
    let deadline = Instant::now() + total;
    while !stop.load(Ordering::Relaxed) {
        let now = Instant::now();
        if now >= deadline {
            break;
        }
        std::thread::sleep((deadline - now).min(slice));
    }
}

/// Materialize the dense masked `(node_visits, elem_counts)` arrays of
/// one shard's re-plan from the sparse decayed profiles. O(n) for the
/// zeroed allocations plus O(active) fills — only run when a shard is
/// actually re-planned (the planner itself is O(n) anyway).
fn masked_profile(
    csc: &Csc,
    acc_nv: &DecayedSparse,
    acc_ec: &DecayedSparse,
    router: &ShardRouter,
    shard: usize,
) -> (Vec<u32>, Vec<u32>) {
    let nv_m: Vec<(u64, f64)> = acc_nv
        .iter()
        .filter(|&(v, _)| router.shard_of(v as NodeId) == shard)
        .collect();
    let ec_m: Vec<(u64, f64)> = acc_ec
        .iter()
        .filter(|&(e, _)| router.shard_of(elem_owner(csc, e)) == shard)
        .collect();
    let scale = common_scale(
        nv_m.iter().map(|&(_, m)| m),
        ec_m.iter().map(|&(_, m)| m),
    );
    let mut nv = vec![0u32; csc.n_nodes()];
    for &(v, m) in &nv_m {
        nv[v as usize] = quantize(m, scale);
    }
    let mut ec = vec![0u32; csc.n_edges()];
    for &(e, m) in &ec_m {
        ec[e as usize] = quantize(m, scale);
    }
    (nv, ec)
}

#[allow(clippy::too_many_arguments)]
fn refresh_loop(
    ds: &Dataset,
    runtime: &ShardedRuntime,
    tracker: &dyn WorkloadTracker,
    planner: &dyn CachePlanner,
    shard_budgets: &[u64],
    planned_visits: Vec<u32>,
    cfg: &RefreshConfig,
    stop: &AtomicBool,
    stats_out: &Mutex<RefreshStats>,
) {
    let n_shards = runtime.n_shards();
    let router = runtime.router().clone();

    // sparse drift baseline: the nonzero planned masses
    let mut planned: HashMap<u64, f64> = planned_visits
        .iter()
        .enumerate()
        .filter(|&(_, &c)| c > 0)
        .map(|(v, &c)| (v as u64, c as f64))
        .collect();

    let caps = tracker.heavy_hitter_caps();
    let mut acc_nv = DecayedSparse::new(caps.map(|(n, _)| n));
    let mut acc_ec = DecayedSparse::new(caps.map(|(_, e)| e));
    let mut acc_ts = 0.0f64;
    let mut acc_tf = 0.0f64;
    let mut batches_pending = 0u64;
    let mut stats = RefreshStats { shard_replans: vec![0; n_shards], ..Default::default() };

    while !stop.load(Ordering::Relaxed) {
        sleep_interruptibly(cfg.check_interval, stop);
        if stop.load(Ordering::Relaxed) {
            break;
        }
        // idle server: skip the drain entirely
        if tracker.batches() == 0 && batches_pending == 0 {
            continue;
        }
        let drain0 = Instant::now();
        let w = tracker.drain();
        if w.batches > 0 {
            acc_nv.decay(cfg.decay);
            acc_ec.decay(cfg.decay);
            acc_ts = acc_ts * cfg.decay + w.t_sample_ns;
            acc_tf = acc_tf * cfg.decay + w.t_feature_ns;
            for &(v, c) in &w.node_visits {
                acc_nv.add(v as u64, c as f64);
            }
            for &(e, c) in &w.elem_counts {
                acc_ec.add(e, c as f64);
            }
            acc_nv.prune();
            acc_ec.prune();
            stats.drained_keys += (w.node_visits.len() + w.elem_counts.len()) as u64;
            stats.dropped_touches += w.dropped_touches;
            batches_pending += w.batches;
        }
        stats.drain_ns += drain0.elapsed().as_nanos() as f64;
        if batches_pending < cfg.min_batches.max(1) {
            continue;
        }

        stats.checks += 1;
        // the min-batches window is per *check*: reset it whatever the
        // verdict, so a quiet server goes back to the idle skip above
        // instead of re-checking unchanged data every poll (drift that
        // builds slowly still accumulates in the decayed profile)
        batches_pending = 0;
        let drifts = shard_drifts_sparse(&planned, &acc_nv, &router, n_shards);
        stats.last_drift = drifts.iter().cloned().fold(0.0, f64::max);
        let any_drifted = drifts.iter().any(|&d| d > cfg.drift_threshold);
        let drifted: Vec<usize> = if cfg.per_shard || n_shards == 1 {
            (0..n_shards).filter(|&s| drifts[s] > cfg.drift_threshold).collect()
        } else if any_drifted {
            (0..n_shards).collect()
        } else {
            Vec::new()
        };
        if drifted.is_empty() {
            *stats_out.lock().unwrap() = stats.clone();
            continue;
        }

        // re-plan each drifted shard on this thread from the decayed
        // profile masked to the shard's own nodes, within the shard's
        // own budget, and hot-swap only that shard; the serving path —
        // and every *other* shard — never waits on any of this
        for s in drifted {
            let t0 = Instant::now();
            let (nv, ec) = masked_profile(&ds.csc, &acc_nv, &acc_ec, &router, s);
            let profile = WorkloadProfile {
                node_visits: &nv,
                elem_counts: &ec,
                t_sample_ns: acc_ts,
                t_feature_ns: acc_tf,
            };
            let plan = planner.plan(ds, &profile, shard_budgets[s]);
            let install_bytes = plan.fill_ledger.h2d_bytes;
            stats.fill_h2d_bytes += install_bytes;
            stats.max_install_h2d_bytes = stats.max_install_h2d_bytes.max(install_bytes);
            runtime.install_shard(s, plan.snapshot);
            stats.replan_wall_ns += t0.elapsed().as_nanos() as f64;
            stats.replans += 1;
            stats.shard_replans[s] += 1;
            // re-center this shard's drift baseline on what it now
            // serves (sparse: drop the shard's old entries, insert the
            // observed masses)
            planned.retain(|&v, _| router.shard_of(v as NodeId) != s);
            for (v, m) in acc_nv.iter() {
                if router.shard_of(v as NodeId) == s {
                    planned.insert(v, m);
                }
            }
        }
        *stats_out.lock().unwrap() = stats.clone();
    }
    *stats_out.lock().unwrap() = stats;
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::cache::planner::{split_budget, DciPlanner};
    use crate::cache::runtime::CacheSnapshot;
    use crate::cache::shard::{plan_sharded, ShardRouter, ShardedRuntime};
    use crate::cache::tracker::{AccessTracker, SketchTracker};
    use crate::graph::datasets;
    use crate::mem::CostModel;
    use crate::sampler::{presample, Fanout};
    use crate::util::Rng;

    fn fast_cfg(threshold: f64) -> RefreshConfig {
        RefreshConfig {
            check_interval: Duration::from_millis(5),
            min_batches: 1,
            decay: 0.5,
            drift_threshold: threshold,
            per_shard: true,
        }
    }

    /// Helper: sparse observed profile from `(key, mass)` pairs.
    fn observed(pairs: &[(u64, f64)]) -> DecayedSparse {
        let mut o = DecayedSparse::new(None);
        for &(k, m) in pairs {
            o.add(k, m);
        }
        o
    }

    fn planned(pairs: &[(u64, f64)]) -> HashMap<u64, f64> {
        pairs.iter().copied().collect()
    }

    #[test]
    fn single_shard_drift_is_the_global_tv_distance() {
        let r = ShardRouter::new(1);
        let p = planned(&[(0, 1.0), (1, 1.0)]);
        // matched distribution → 0
        let d = shard_drifts_sparse(&p, &observed(&[(0, 2.0), (1, 2.0)]), &r, 1);
        assert!(d[0].abs() < 1e-12);
        // fully disjoint mass → 1
        let d = shard_drifts_sparse(&p, &observed(&[(2, 7.0)]), &r, 1);
        assert!((d[0] - 1.0).abs() < 1e-12);
        // empty observation → no drift signal
        let d = shard_drifts_sparse(&p, &observed(&[]), &r, 1);
        assert_eq!(d, vec![0.0]);
        // no planned mass but live traffic → 0.5 (half the mass is new)
        let d = shard_drifts_sparse(&planned(&[]), &observed(&[(0, 3.0), (1, 1.0)]), &r, 1);
        assert!((d[0] - 0.5).abs() < 1e-12);
    }

    #[test]
    fn drift_is_isolated_to_the_observed_shard() {
        // find two nodes per shard under the real router
        let r = ShardRouter::new(2);
        let pick = |s: usize, n: usize| -> Vec<u64> {
            (0u64..10_000).filter(|&v| r.shard_of(v as NodeId) == s).take(n).collect()
        };
        let s0 = pick(0, 2);
        let s1 = pick(1, 2);
        let p = planned(&[(s0[0], 10.0), (s1[0], 5.0), (s1[1], 5.0)]);
        // shard 0's traffic flipped to its other node; shard 1 silent
        let d = shard_drifts_sparse(&p, &observed(&[(s0[1], 8.0)]), &r, 2);
        assert!((d[0] - 1.0).abs() < 1e-12, "shard 0 fully drifted: {d:?}");
        assert_eq!(d[1], 0.0, "unobserved shard must not drift: {d:?}");
        // shard 1's traffic matching its plan stays quiet while shard 0
        // drifts — per-shard normalization keeps them independent
        let d = shard_drifts_sparse(
            &p,
            &observed(&[(s0[1], 8.0), (s1[0], 4.0), (s1[1], 4.0)]),
            &r,
            2,
        );
        assert!(d[0] > 0.9);
        assert!(d[1] < 1e-12);
    }

    #[test]
    fn decayed_sparse_matches_the_dense_recurrence() {
        // acc = acc*0.5 + window, three windows on one key
        let mut acc = DecayedSparse::new(None);
        for w in [8.0, 4.0, 2.0] {
            acc.decay(0.5);
            acc.add(7, w);
        }
        // dense: ((8*0.5)+4)*0.5 + 2 = 6
        let got: Vec<(u64, f64)> = acc.iter().collect();
        assert_eq!(got.len(), 1);
        assert!((got[0].1 - 6.0).abs() < 1e-9);
        // rebase path: many decay steps must not lose precision
        let mut acc = DecayedSparse::new(None);
        acc.add(1, 1024.0);
        for _ in 0..100 {
            acc.decay(0.7);
        }
        acc.add(1, 3.0);
        let m = acc.iter().next().unwrap().1;
        assert!((m - (1024.0 * 0.7f64.powi(100) + 3.0)).abs() < 1e-6, "{m}");
    }

    #[test]
    fn decayed_sparse_prunes_dust_and_keeps_heavy_hitters() {
        let mut acc = DecayedSparse::new(Some(3));
        acc.decay(0.5);
        for k in 0..10u64 {
            acc.add(k, (k + 1) as f64);
        }
        acc.prune();
        let kept: Vec<u64> = acc.iter().map(|(k, _)| k).collect();
        assert_eq!(kept.len(), 3, "top-k prune");
        assert!(kept.contains(&9) && kept.contains(&8) && kept.contains(&7));
        // dust: decay a lone small mass until it evaporates
        let mut acc = DecayedSparse::new(None);
        acc.add(5, 1.0);
        for _ in 0..40 {
            acc.decay(0.5);
        }
        acc.prune();
        assert_eq!(acc.iter().count(), 0, "decayed dust must be dropped");
    }

    #[test]
    fn masked_profile_respects_shard_ownership() {
        let ds = datasets::spec("tiny").unwrap().build();
        let router = ShardRouter::new(3);
        let mut nv = DecayedSparse::new(None);
        let mut ec = DecayedSparse::new(None);
        for v in 0..ds.csc.n_nodes() as u64 {
            nv.add(v, (v % 7 + 1) as f64);
        }
        for e in (0..ds.csc.n_edges() as u64).step_by(3) {
            ec.add(e, 2.0);
        }
        for s in 0..3 {
            let (nvd, ecd) = masked_profile(&ds.csc, &nv, &ec, &router, s);
            for (v, &c) in nvd.iter().enumerate() {
                if router.shard_of(v as NodeId) != s {
                    assert_eq!(c, 0, "node {v} leaked into shard {s}");
                }
            }
            for (e, &c) in ecd.iter().enumerate() {
                if c > 0 {
                    assert_eq!(
                        router.shard_of(elem_owner(&ds.csc, e as u64)),
                        s,
                        "elem {e} leaked into shard {s}"
                    );
                }
            }
        }
    }

    #[test]
    fn quantize_preserves_relative_magnitudes() {
        let nv = [0.1, 0.2, 0.4];
        let scale = common_scale(nv.iter().copied(), std::iter::empty());
        let q: Vec<u32> = nv.iter().map(|&x| quantize(x, scale)).collect();
        assert!(q[2] > q[1] && q[1] > q[0]);
        assert_eq!(q[2], 1024);
        // large counts pass through unscaled
        let big = [2000.0, 4000.0];
        let s = common_scale(big.iter().copied(), std::iter::empty());
        assert_eq!(s, 1.0);
        // ONE scale across both arrays of a re-plan: the hotter array
        // pins it, so cross-array density ratios survive quantization
        let ec = [4000.0];
        let s = common_scale(nv.iter().copied(), ec.iter().copied());
        assert_eq!(s, 1.0);
        assert_eq!(quantize(nv[0], s), 0);
        assert_eq!(quantize(ec[0], s), 4000);
    }

    #[test]
    fn refresher_replans_on_forced_drift() {
        let ds = Arc::new(datasets::spec("tiny").unwrap().build());
        let runtime = Arc::new(ShardedRuntime::single(CacheSnapshot::empty()));
        let tracker = Arc::new(AccessTracker::new(ds.csc.n_nodes(), ds.csc.n_edges()));
        // a baseline profile concentrated on node 0; observe node 1
        let mut planned = vec![0u32; ds.csc.n_nodes()];
        planned[0] = 100;
        let r = Refresher::spawn(
            Arc::clone(&ds),
            Arc::clone(&runtime),
            Arc::clone(&tracker) as Arc<dyn WorkloadTracker>,
            Box::new(DciPlanner),
            vec![200_000],
            planned,
            fast_cfg(0.3),
        );
        for _ in 0..50 {
            tracker.record_node(1);
        }
        tracker.record_elem(0);
        tracker.record_batch(50.0, 50.0);
        // wait for the loop to pick it up
        let deadline = Instant::now() + Duration::from_secs(10);
        while runtime.swaps() == 0 && Instant::now() < deadline {
            std::thread::sleep(Duration::from_millis(5));
        }
        let stats = r.stop();
        assert!(stats.replans >= 1, "drift should have forced a re-plan: {stats:?}");
        assert!(stats.last_drift > 0.3);
        assert!(stats.max_install_h2d_bytes > 0);
        assert!(stats.drained_keys >= 2, "node 1 + elem 0 drained: {stats:?}");
        assert!(stats.drain_ns > 0.0);
        assert_eq!(stats.dropped_touches, 0);
        assert!(runtime.swaps() >= 1);
        // the refreshed snapshot caches the observed hot node
        let snap = runtime.load();
        assert!(snap.feat.as_ref().unwrap().contains(1));
    }

    /// The tentpole guarantee: the sketch path drives the same re-plan
    /// decisions as the dense path on a sparse drift stream.
    #[test]
    fn sketch_refresher_replans_on_forced_drift() {
        let ds = Arc::new(datasets::spec("tiny").unwrap().build());
        let runtime = Arc::new(ShardedRuntime::single(CacheSnapshot::empty()));
        let tracker =
            Arc::new(SketchTracker::with_defaults(ds.csc.n_nodes(), ds.csc.n_edges()));
        let mut planned = vec![0u32; ds.csc.n_nodes()];
        planned[0] = 100;
        let r = Refresher::spawn(
            Arc::clone(&ds),
            Arc::clone(&runtime),
            Arc::clone(&tracker) as Arc<dyn WorkloadTracker>,
            Box::new(DciPlanner),
            vec![200_000],
            planned,
            fast_cfg(0.3),
        );
        for _ in 0..50 {
            tracker.record_node(1);
        }
        tracker.record_elem(0);
        tracker.record_batch(50.0, 50.0);
        let deadline = Instant::now() + Duration::from_secs(10);
        while runtime.swaps() == 0 && Instant::now() < deadline {
            std::thread::sleep(Duration::from_millis(5));
        }
        let stats = r.stop();
        assert!(stats.replans >= 1, "sketch drift must re-plan: {stats:?}");
        assert!(runtime.load().feat.as_ref().unwrap().contains(1));
    }

    #[test]
    fn refresher_idle_without_traffic() {
        let ds = Arc::new(datasets::spec("tiny").unwrap().build());
        let runtime = Arc::new(ShardedRuntime::single(CacheSnapshot::empty()));
        let tracker = Arc::new(AccessTracker::new(ds.csc.n_nodes(), ds.csc.n_edges()));
        let r = Refresher::spawn(
            Arc::clone(&ds),
            Arc::clone(&runtime),
            tracker,
            Box::new(DciPlanner),
            vec![100_000],
            Vec::new(),
            fast_cfg(0.0),
        );
        std::thread::sleep(Duration::from_millis(30));
        let stats = r.stop();
        assert_eq!(stats.replans, 0, "no traffic, no re-plan");
        assert_eq!(stats.drained_keys, 0, "idle polls must not drain");
        assert_eq!(runtime.swaps(), 0);
    }

    /// The PR 3 invariant, unchanged by the sparse rework: traffic that
    /// drifts inside one shard re-plans *only* that shard; every other
    /// shard keeps serving its original epoch.
    #[test]
    fn refresher_replans_only_the_drifted_shard() {
        let n_shards = 4;
        let ds = Arc::new(datasets::spec("tiny").unwrap().build());
        let router = ShardRouter::new(n_shards);
        let budget = 120_000u64;
        let budgets = split_budget(budget, n_shards);

        // startup plan: a presample profile sharded across 4 devices
        let stats0 = presample(
            &ds.csc,
            &ds.features,
            &ds.test_nodes,
            64,
            &Fanout::parse("3,2").unwrap(),
            4,
            &CostModel::default(),
            &mut Rng::new(7),
        );
        let profile = WorkloadProfile::from_presample(&stats0);
        let sharded = plan_sharded(&DciPlanner, &ds, &profile, budget, &router);
        let runtime = Arc::new(ShardedRuntime::new(
            ShardRouter::new(n_shards),
            sharded.plans.into_iter().map(|p| p.snapshot).collect(),
        ));
        let tracker = Arc::new(AccessTracker::new(ds.csc.n_nodes(), ds.csc.n_edges()));
        let r = Refresher::spawn(
            Arc::clone(&ds),
            Arc::clone(&runtime),
            Arc::clone(&tracker) as Arc<dyn WorkloadTracker>,
            Box::new(DciPlanner),
            budgets,
            stats0.node_visits.clone(),
            fast_cfg(0.3),
        );

        // drive traffic confined to shard 2's nodes, disjoint from the
        // planned profile's hot set as far as shard 2 is concerned
        let shard2: Vec<NodeId> = (0..ds.csc.n_nodes() as u32)
            .filter(|&v| router.shard_of(v) == 2 && stats0.node_visits[v as usize] == 0)
            .take(40)
            .collect();
        assert!(shard2.len() >= 10, "tiny must have unvisited shard-2 nodes");
        for _ in 0..20 {
            for &v in &shard2 {
                tracker.record_node(v);
            }
        }
        tracker.record_batch(50.0, 50.0);

        let deadline = Instant::now() + Duration::from_secs(10);
        while runtime.swaps() == 0 && Instant::now() < deadline {
            std::thread::sleep(Duration::from_millis(5));
        }
        let stats = r.stop();
        assert!(stats.replans >= 1, "shard 2's drift must re-plan: {stats:?}");
        assert!(stats.shard_replans[2] >= 1, "{stats:?}");
        for s in [0usize, 1, 3] {
            assert_eq!(
                stats.shard_replans[s],
                0,
                "shard {s} saw no drift and must keep its epoch: {stats:?}"
            );
            assert_eq!(runtime.shard(s).swaps(), 0);
        }
        assert!(runtime.shard(2).swaps() >= 1);
        assert_eq!(runtime.swap_stalls(), 0);
        // the refreshed shard caches its new hot nodes
        let snap = runtime.shard(2).load();
        let feat = snap.feat.as_ref().unwrap();
        let cached_hot = shard2.iter().filter(|&&v| feat.contains(v)).count();
        assert!(cached_hot > 0, "re-plan must cache shard 2's new working set");
    }
}
