//! Online workload-drift re-planning, per shard — with elastic
//! cross-shard budget rebalancing and epoch-aware device accounting.
//!
//! A serving deployment whose request mix drifts keeps paying misses on
//! a stale plan (BGL's observation: feature-cache policy must track the
//! live access distribution). DCI's two-scan fills make re-planning
//! cheap enough to do *online*, so:
//!
//! - the serving hot path records into a
//!   [`WorkloadTracker`](super::tracker::WorkloadTracker) — per input
//!   node in the gather stage, per touched element in the sampling
//!   stage, the same counts pre-sampling collects. `tracker=dense` is
//!   the exact O(nodes + edges) counter pair; `tracker=sketch` is a
//!   count-min sketch with a bounded touched set (see
//!   [`super::tracker`]);
//! - a background [`Refresher`] thread drains the tracker on a poll
//!   interval into an exponentially decayed **sparse** profile — the
//!   drain + decay cost is O(touched keys this window), not
//!   O(nodes + edges): decay multiplies one scalar, new counts merge
//!   by key, and (with a sketch tracker) the profile is pruned to the
//!   tracker's heavy-hitter caps;
//! - drift is measured **per shard**: the total-variation distance
//!   between the within-shard node-visit distribution the shard's live
//!   snapshot was planned from and the decayed observed one, computed
//!   over the two sparse supports;
//! - a shard past the drift threshold is re-planned through the same
//!   [`CachePlanner`] the offline path used — from the decayed profile
//!   *masked* to the shard's own nodes (the heavy hitters the tracker
//!   recovered), within the shard's own budget — and hot-swapped into
//!   that shard of the [`ShardedRuntime`](crate::cache::ShardedRuntime).
//!   The other shards keep serving their current epoch untouched, so a
//!   localized drift uploads ~1/N of what a full re-plan would (the
//!   `shard_runtime` bench holds this). Readers pick new epochs up on
//!   their next per-batch acquire, never blocking (the runtime counts
//!   any reader that does block; the benches assert zero).
//!
//! **Elastic budgets** (`rebalance=on`; DESIGN.md §Elastic budgets)
//! make the *capacity assignment itself* workload-aware, along two
//! axes the drift loop alone cannot move:
//!
//! - **Cross-shard rebalancing.** Separately from within-shard drift,
//!   the loop measures shard-level *skew*: the total-variation
//!   distance between the runtime's current per-shard budget shares
//!   (the even split, at startup) and the observed per-shard load-mass
//!   distribution. Past [`RefreshConfig::rebalance_threshold`] the
//!   global budget is re-split proportionally to the observed load
//!   ([`split_budget_weighted`]: exact integer arithmetic, a
//!   [`RefreshConfig::rebalance_floor`] minimum share per shard) and
//!   **only the shards whose budgets changed** are re-planned and
//!   hot-swapped — installs stay per-shard, the never-block invariant
//!   holds, and `Σ shard budgets == global budget` on every epoch.
//! - **Epoch-aware auto budget** (`auto-budget-refresh=on`). With an
//!   [`AutoBudgetPolicy`] wired, the loop re-evaluates the §IV.A
//!   workload-aware budget from the *decayed peak claim* the tracker
//!   observed (largest batch input count, decayed at the profile's own
//!   rate so a lightened workload returns memory to the caches), so
//!   the global budget tracks the workload instead of freezing at its
//!   pre-sampling estimate.
//!
//! **Multi-tenant QoS** (DESIGN.md §Multi-tenant QoS). Drained windows
//! carry an optional per-admission-class split of the node-visit
//! counts ([`DrainedWindow::class_node_visits`](super::tracker::DrainedWindow::class_node_visits)); the loop keeps one
//! decayed profile per [`TenantClass`] and composes what every drift
//! test, re-split, and re-plan consumes as the class-weighted sum
//! `Σ_c class_weights[c] · mass_c[v]`
//! ([`RefreshConfig::class_weights`]). Priority traffic therefore
//! outbids scan traffic for cache bytes at the same raw visit rate,
//! while an untagged (all-standard) stream — whose windows carry no
//! split — reproduces the unweighted profile bit-for-bit.
//!
//! Every install is accounted against the shard's own
//! [`DeviceGroup`](crate::mem::DeviceGroup) arena (when one is
//! attached) in **two-phase claim-before-release order**: the incoming
//! snapshot's bytes are claimed while the outgoing epoch is still
//! resident — the transient double-residency may dip into the paper's
//! 1 GB reserve, which is what the reserve is for — and the outgoing
//! bytes are released after the swap. The peak transient is therefore
//! bounded by `old epoch + new epoch` per device, recorded in
//! [`RefreshStats::max_transient_bytes`], and the ledger returns to
//! exactly the live snapshots' bytes at quiescence (the `rebalance`
//! bench asserts this conservation).
//!
//! With one shard this is exactly the PR 2 global refresh loop (and
//! `rebalance=on` still lets the *auto budget* track the workload).
//! With [`RefreshConfig::per_shard`] disabled, any shard's drift
//! re-plans every shard (the "full re-plan" comparison mode).
//!
//! Cost: per poll that saw traffic, O(touched) drain + merge (plus the
//! tracker's own drain cost — O(nodes + edges) for `dense`,
//! O(touched) for `sketch`; `benches/sketch_tracker.rs` measures the
//! gap). The skew test adds O(active profile entries) per check. Only
//! an actual re-plan materializes dense count arrays for the planner,
//! and the planner itself is O(n) — the expensive path runs exactly
//! when a shard is about to be refilled anyway.
//!
//! **Fault tolerance** (DESIGN.md §Fault tolerance). Installs retry
//! with bounded exponential backoff
//! ([`RefreshConfig::install_retries`] /
//! [`RefreshConfig::install_backoff`]): a claim that still OOMs after
//! the retry budget is given up (`install_ooms`, the old epoch keeps
//! serving — the PR 5 skip path), while a fill that still fails after
//! the budget is *terminal* — the shard is marked degraded in the
//! [`ShardedRuntime`], its device bytes are released, and every view
//! falls back to host reads for that shard until the per-check repair
//! pass re-plans it and promotes it back. The loop itself runs under a
//! **watchdog** supervisor: the worker thread beats a heartbeat every
//! iteration and checkpoints its durable state (budgets, drift
//! baseline, stats) after every check; a panicked or hung worker is
//! detected (`watchdog_timeout`), abandoned via a generation counter
//! (a hung thread that later wakes sees the stale generation and exits
//! without publishing), and respawned from the last checkpoint.
//! Deterministic faults for all of this come from the `fault=` knob
//! ([`FaultPlan`]); with no plan attached every site is a pointer
//! null-check.

use std::collections::HashMap;
use std::panic::AssertUnwindSafe;
use std::sync::atomic::{AtomicBool, AtomicU64, Ordering};
use std::sync::{Arc, Mutex};
use std::thread::JoinHandle;
use std::time::{Duration, Instant};

use crate::coordinator::admission::{TenantClass, N_CLASSES};
use crate::graph::{Csc, Dataset, NodeId};
use crate::mem::{DeviceGroup, StagingPool};
use crate::util::{lock_unpoisoned, FaultPlan};

use super::runtime::CacheSnapshot;

use super::planner::{
    cap_shares, cap_shares_per_device, split_budget, split_budget_weighted, CachePlanner,
    ClassWeights, WorkloadProfile,
};
use super::shard::{elem_owner, ShardRouter, ShardedRuntime};
use super::tracker::WorkloadTracker;

/// Knobs of the online refresh loop.
#[derive(Debug, Clone, PartialEq)]
pub struct RefreshConfig {
    /// Poll period of the background drift check.
    pub check_interval: Duration,
    /// Served batches that must accumulate before a drift check counts.
    pub min_batches: u64,
    /// Exponential decay applied to the accumulated profile on every
    /// poll that drained new data (0 = only the newest window counts,
    /// 1 = never forget).
    pub decay: f64,
    /// Total-variation distance (in [0, 1]) between the planned and
    /// observed within-shard node-visit distributions that triggers a
    /// re-plan of that shard.
    pub drift_threshold: f64,
    /// Re-plan only the shards that drifted (`true`, the default).
    /// `false` re-plans every shard as soon as any one drifts — the
    /// full-re-plan comparison mode (`shard-refresh=off`).
    pub per_shard: bool,
    /// Elastic budgets (`rebalance=on`): re-split the global budget
    /// across shards when the shard-level load mass skews away from
    /// the current budget shares, re-planning only the shards whose
    /// budgets changed. Off by default — budgets then stay frozen at
    /// their startup split, the PR 3 behavior.
    pub rebalance: bool,
    /// Total-variation distance (in [0, 1]) between the current budget
    /// shares and the observed shard-mass distribution that triggers a
    /// re-split (`rebalance-threshold=`). Also the hysteresis band for
    /// auto-budget changes: a re-evaluated global budget is applied
    /// only when it moves by more than this fraction of the current
    /// one.
    pub rebalance_threshold: f64,
    /// Minimum share per shard under a weighted re-split, as a
    /// fraction of the even base share (`rebalance-floor=`; see
    /// [`split_budget_weighted`]). Keeps a cold shard from being
    /// stranded with zero capacity for the traffic that still routes
    /// to it.
    pub rebalance_floor: f64,
    /// Re-evaluate the workload-aware global budget per epoch from the
    /// observed (decayed) peak claim (`auto-budget-refresh=on`). Takes
    /// effect only when an [`AutoBudgetPolicy`] is wired (the server
    /// does so for `budget=auto` runs). Independent of `rebalance`: a
    /// changed global re-splits by load with `rebalance=on`, and keeps
    /// the even split with it off — re-tracking the budget and
    /// redistributing it are separate decisions.
    pub auto_budget_refresh: bool,
    /// Retry budget per install phase (`install-retries=`): a failing
    /// device claim or fill is re-attempted up to this many times with
    /// exponential backoff before the install gives up (claim → skip
    /// and count `install_ooms`; fill → degrade the shard).
    pub install_retries: u32,
    /// Base backoff pause before the first install retry
    /// (`install-backoff-ms=`); doubles per further retry.
    pub install_backoff: Duration,
    /// How long the watchdog lets the refresh worker's heartbeat go
    /// stale before declaring it hung, abandoning its generation, and
    /// respawning from the last checkpoint (`watchdog-ms=`). Must
    /// exceed the worst-case duration of one full check (drain + every
    /// re-plan + retry backoffs), or a merely slow check is treated as
    /// hung.
    pub watchdog_timeout: Duration,
    /// Per-admission-class weights applied when composing the decayed
    /// per-class node-visit profiles into the single profile every
    /// re-plan, drift test, and re-split consumes
    /// (`tenant.weights=p,s,c`; see [`ClassWeights`]). Exactly
    /// irrelevant while no request carries a non-standard class: an
    /// untagged stream accumulates entirely in the standard class,
    /// whose default weight of 1 reproduces the unweighted profile
    /// bit-for-bit.
    pub class_weights: ClassWeights,
    /// Visits credited to each mutated node when the live graph takes
    /// an edge insert (`refresh.mutation-boost=`; see
    /// [`WorkloadTracker::record_nodes_boosted`] and
    /// `graph::LiveGraph::set_tracker`). Mutation never *invalidates*
    /// a cache entry — prefix stability keeps cached positions correct
    /// across compactions — it only raises the mutated nodes' mass in
    /// the decayed drift profile so the next re-plan re-caches their
    /// grown neighborhoods. `0` disables the bump.
    pub mutation_boost: u32,
}

impl Default for RefreshConfig {
    fn default() -> Self {
        RefreshConfig {
            check_interval: Duration::from_millis(100),
            min_batches: 8,
            decay: 0.5,
            drift_threshold: 0.15,
            per_shard: true,
            rebalance: false,
            rebalance_threshold: 0.25,
            rebalance_floor: 0.1,
            auto_budget_refresh: false,
            install_retries: 3,
            install_backoff: Duration::from_millis(5),
            watchdog_timeout: Duration::from_secs(2),
            class_weights: ClassWeights::default(),
            mutation_boost: 4,
        }
    }
}

/// The §IV.A workload-aware budget, re-evaluable per epoch: global
/// budget = `(per-device headroom − decayed peak claim) × shards`,
/// with the claim computed by the same
/// [`workload_claim_bytes`](crate::mem::workload_claim_bytes) model
/// the startup [`auto_budget`](crate::baselines::auto_budget) uses.
/// Heterogeneous nodes (`device-tiers=`) carry per-tier headrooms
/// instead: each device pays the claim out of its own headroom, and
/// the per-device caps on re-split shares come from the same vector.
#[derive(Debug, Clone)]
pub struct AutoBudgetPolicy {
    /// Per-device cache headroom basis (capacity − reserve — the
    /// budget basis *before* any claim, matching what the startup
    /// auto budget subtracted the pre-sampled claim from). With
    /// `tier_headrooms` set this is the uniform fallback only.
    pub headroom_per_device: u64,
    /// Device bytes the workload pins per input node
    /// ([`crate::mem::per_node_claim_bytes`]).
    pub per_node_bytes: u64,
    /// Dataset scale factor (claims scale with the simulated device;
    /// see [`crate::mem::workload_claim_bytes`]).
    pub scale: f64,
    /// Per-device headroom basis for heterogeneous nodes (len = shard
    /// count; `None` = uniform devices, use `headroom_per_device`).
    pub tier_headrooms: Option<Vec<u64>>,
}

impl AutoBudgetPolicy {
    /// The global budget implied by an observed peak batch claim.
    pub fn global_budget(&self, peak_inputs: u64, n_shards: usize) -> u64 {
        let claim = crate::mem::workload_claim_bytes(
            peak_inputs,
            self.per_node_bytes,
            self.scale,
        );
        match &self.tier_headrooms {
            Some(tiers) => tiers.iter().map(|h| h.saturating_sub(claim)).sum(),
            None => self
                .headroom_per_device
                .saturating_sub(claim)
                .saturating_mul(n_shards.max(1) as u64),
        }
    }
}

/// What the refresh loop did over its lifetime.
#[derive(Debug, Clone, Default)]
pub struct RefreshStats {
    /// Drift checks that had enough data to evaluate.
    pub checks: u64,
    /// Shard re-plans installed (every install counts one shard —
    /// drift-driven and rebalance-driven installs both land here).
    pub replans: u64,
    /// Installs per shard (len = shard count).
    pub shard_replans: Vec<u64>,
    /// Largest per-shard drift measured by the last check.
    pub last_drift: f64,
    /// Budget-vs-load skew (total-variation) measured by the last
    /// rebalance check (0 until the first check with `rebalance=on`).
    pub last_skew: f64,
    /// Budget re-split events applied (each may re-plan several
    /// shards).
    pub shard_rebalances: u64,
    /// Shard installs performed because the shard's *budget* changed
    /// (the rebalance-driven subset of `replans`).
    pub rebalance_installs: u64,
    /// Σ bytes gained by growing shards across all re-splits — the
    /// capacity that actually moved between devices.
    pub budget_moved_bytes: u64,
    /// Current global budget minus the startup global budget (nonzero
    /// only with auto-budget refresh, or when an install was skipped
    /// on OOM).
    pub auto_budget_delta: i64,
    /// Current per-shard budgets (Σ == current global budget; updated
    /// on every check).
    pub shard_budgets: Vec<u64>,
    /// Peak device bytes observed right after a claim-before-release
    /// install claim — the transient double-residency, bounded by
    /// `old epoch + new epoch` on one device.
    pub max_transient_bytes: u64,
    /// Installs skipped because even the reserve could not absorb the
    /// incoming snapshot (the snapshot is discarded, the old epoch
    /// keeps serving; persistent nonzero values mean the budget is
    /// mis-sized for the device).
    pub install_ooms: u64,
    /// Total background wall time spent planning + installing, ns.
    pub replan_wall_ns: f64,
    /// H2D bytes uploaded by online refills, summed over installs.
    pub fill_h2d_bytes: u64,
    /// Largest single-install upload — what one drifted-shard refresh
    /// costs, vs `fill_h2d_bytes` for the cumulative story.
    pub max_install_h2d_bytes: u64,
    /// Background wall time spent draining the tracker and folding the
    /// window into the decayed profile, ns — the cost the sketch
    /// tracker shrinks from O(nodes + edges) to O(touched).
    pub drain_ns: f64,
    /// Sparse keys drained across all windows (nodes + elements).
    pub drained_keys: u64,
    /// Touches the tracker could not enumerate because its bounded
    /// touched set saturated (sketch only; 0 for dense).
    pub dropped_touches: u64,
    /// Install attempts re-tried after a transient claim/fill failure
    /// (each retry paid one backoff pause).
    pub install_retries: u64,
    /// Wall time spent in retry backoff pauses, ns.
    pub backoff_ns: f64,
    /// Times a shard entered degraded mode (a fill failed terminally;
    /// the shard served from host memory until repaired).
    pub shard_degrades: u64,
    /// Degraded shards promoted back to healthy by the repair pass.
    pub shard_repairs: u64,
    /// Wall time shards spent degraded before their repair install
    /// landed, summed, ns — the repair latency the chaos bench bounds.
    pub repair_wall_ns: f64,
    /// Times the watchdog respawned the refresh worker (panicked or
    /// hung generations both count).
    pub watchdog_restarts: u64,
    /// Refresh-worker panics the watchdog absorbed (subset of
    /// `watchdog_restarts`; a silent swallowed panic is a bug).
    pub refresh_panics: u64,
}

/// Everything a [`Refresher`] needs: the mandatory serving-loop wiring
/// plus the optional elastic-budget attachments (device accounting,
/// auto-budget policy). Build with [`RefreshJob::new`], attach
/// extras with [`RefreshJob::device`] / [`RefreshJob::auto_budget`],
/// then [`RefreshJob::spawn`].
pub struct RefreshJob {
    /// The dataset re-plans fill from.
    pub ds: Arc<Dataset>,
    /// The (possibly sharded) runtime installs hot-swap into.
    pub runtime: Arc<ShardedRuntime>,
    /// The serving-path tracker the loop drains.
    pub tracker: Arc<dyn WorkloadTracker>,
    /// The strategy every re-plan runs (the one the startup plan used).
    pub planner: Box<dyn CachePlanner>,
    /// Per-shard byte budgets the loop starts from (len = shard count;
    /// with `rebalance=on` these move, always summing to the global).
    pub shard_budgets: Vec<u64>,
    /// The global node-visit profile the live snapshots were planned
    /// from (the pre-sample profile at startup) — the drift baseline.
    pub planned_visits: Vec<u32>,
    /// Per-shard device arenas for claim-before-release install
    /// accounting (`None` = unaccounted installs, the bench/test
    /// shortcut).
    pub device: Option<Arc<DeviceGroup>>,
    /// Per-epoch auto-budget re-evaluation policy (`None` = the global
    /// budget only moves if installs are skipped on OOM).
    pub auto_budget: Option<AutoBudgetPolicy>,
    /// Deterministic fault schedule for chaos testing (`None` = no
    /// faults; every injection site is one pointer null-check).
    pub fault: Option<Arc<FaultPlan>>,
    /// The engine's pinned staging pool (`None` = unstaged installs):
    /// each install's H2D fill leases one buffer for the transfer and
    /// returns it after, so refresh fills and serving gathers share
    /// the same pool and reuse counters.
    pub staging: Option<Arc<StagingPool>>,
    /// Loop knobs.
    pub cfg: RefreshConfig,
}

impl RefreshJob {
    /// A job with the mandatory wiring and no elastic attachments.
    pub fn new(
        ds: Arc<Dataset>,
        runtime: Arc<ShardedRuntime>,
        tracker: Arc<dyn WorkloadTracker>,
        planner: Box<dyn CachePlanner>,
        shard_budgets: Vec<u64>,
        planned_visits: Vec<u32>,
        cfg: RefreshConfig,
    ) -> RefreshJob {
        RefreshJob {
            ds,
            runtime,
            tracker,
            planner,
            shard_budgets,
            planned_visits,
            device: None,
            auto_budget: None,
            fault: None,
            staging: None,
            cfg,
        }
    }

    /// Attach the device group installs are accounted against.
    pub fn device(mut self, device: Arc<DeviceGroup>) -> RefreshJob {
        self.device = Some(device);
        self
    }

    /// Attach the per-epoch auto-budget policy.
    pub fn auto_budget(mut self, policy: AutoBudgetPolicy) -> RefreshJob {
        self.auto_budget = Some(policy);
        self
    }

    /// Attach a deterministic fault schedule (the `fault=` knob).
    pub fn fault(mut self, plan: Arc<FaultPlan>) -> RefreshJob {
        self.fault = Some(plan);
        self
    }

    /// Attach the engine's staging pool so install fills stage through
    /// the same leased buffers as serving gathers.
    pub fn staging(mut self, pool: Arc<StagingPool>) -> RefreshJob {
        self.staging = Some(pool);
        self
    }

    /// Spawn the supervised background refresh thread over this job.
    ///
    /// The returned [`Refresher`] owns the *watchdog* thread, which in
    /// turn owns the worker generation actually running the loop: a
    /// panicked or hung worker is detected, abandoned, and respawned
    /// from the last checkpoint without the serving path noticing
    /// (module docs, DESIGN.md §Fault tolerance).
    pub fn spawn(self) -> Refresher {
        assert_eq!(
            self.shard_budgets.len(),
            self.runtime.n_shards(),
            "one budget per shard"
        );
        if let Some(dev) = &self.device {
            assert_eq!(
                dev.n_devices(),
                self.runtime.n_shards(),
                "one device arena per shard"
            );
        }
        let stop = Arc::new(AtomicBool::new(false));
        let stats = Arc::new(Mutex::new(RefreshStats {
            shard_replans: vec![0; self.runtime.n_shards()],
            shard_budgets: self.shard_budgets.clone(),
            ..Default::default()
        }));
        let job = Arc::new(self);
        let stop2 = Arc::clone(&stop);
        let stats2 = Arc::clone(&stats);
        let join = std::thread::Builder::new()
            .name("dci-refresh-watchdog".into())
            .spawn(move || supervise(&job, &stop2, &stats2))
            .expect("spawn refresh watchdog: the OS refused a thread at startup");
        Refresher { stop, join, stats }
    }
}

/// Handle to the background refresh thread.
pub struct Refresher {
    stop: Arc<AtomicBool>,
    join: JoinHandle<()>,
    stats: Arc<Mutex<RefreshStats>>,
}

impl Refresher {
    /// Spawn the refresh loop over a (possibly sharded) runtime — the
    /// plain-wiring shorthand for [`RefreshJob::spawn`] (no device
    /// accounting, no auto-budget policy). `planned_visits` is the
    /// global node-visit profile the runtime's live snapshots were
    /// planned from; `shard_budgets` is the per-shard byte budget
    /// every re-plan starts within (len = shard count).
    ///
    /// Deprecated: there is now exactly one construction path for
    /// refresh loops, attachments or not — build the job with
    /// [`RefreshJob::new`] and call [`RefreshJob::spawn`]. This shim
    /// keeps pre-existing call sites compiling and behaves
    /// identically.
    #[deprecated(note = "build with RefreshJob::new(...) and call .spawn() instead")]
    pub fn spawn(
        ds: Arc<Dataset>,
        runtime: Arc<ShardedRuntime>,
        tracker: Arc<dyn WorkloadTracker>,
        planner: Box<dyn CachePlanner>,
        shard_budgets: Vec<u64>,
        planned_visits: Vec<u32>,
        cfg: RefreshConfig,
    ) -> Refresher {
        RefreshJob::new(ds, runtime, tracker, planner, shard_budgets, planned_visits, cfg)
            .spawn()
    }

    /// Current stats (the loop keeps them up to date after every check,
    /// and the watchdog republishes them on every restart it records).
    pub fn stats(&self) -> RefreshStats {
        lock_unpoisoned(&self.stats).clone()
    }

    /// Stop the loop and return its final stats. Worker death is never
    /// silent: a panic the watchdog absorbed is already folded into
    /// `refresh_panics`/`watchdog_restarts` by the time this join
    /// returns, and a worker hung mid-install at shutdown is abandoned
    /// (self-neutered via its generation) rather than blocking the
    /// caller on it.
    pub fn stop(self) -> RefreshStats {
        self.stop.store(true, Ordering::Relaxed);
        let _ = self.join.join();
        lock_unpoisoned(&self.stats).clone()
    }
}

/// Durable refresh-loop state, written by the worker after every
/// completed check and consumed by the watchdog to respawn a fresh
/// generation where the old one left off. The decayed traffic
/// accumulators are deliberately *not* checkpointed: they rebuild from
/// live windows within a few polls, while the budgets, drift baseline,
/// and stats counters here would silently reset without this.
#[derive(Clone)]
struct Checkpoint {
    budgets: Vec<u64>,
    planned: HashMap<u64, f64>,
    stats: RefreshStats,
}

/// Per-generation handles shared between one worker and the watchdog
/// that spawned it.
struct Supervision {
    /// Bumped by the worker every loop iteration and at every re-plan;
    /// the watchdog calls the worker hung when it stops moving for
    /// [`RefreshConfig::watchdog_timeout`].
    heartbeat: Arc<AtomicU64>,
    /// The live generation counter. A worker whose `my_gen` falls
    /// behind has been abandoned: it must exit without publishing, so
    /// a hung thread that eventually wakes cannot clobber its
    /// replacement's installs or drain its traffic.
    generation: Arc<AtomicU64>,
    my_gen: u64,
    /// The shared checkpoint slot respawns resume from.
    checkpoint: Arc<Mutex<Option<Checkpoint>>>,
}

impl Supervision {
    fn beat(&self) {
        self.heartbeat.fetch_add(1, Ordering::Release);
    }

    fn abandoned(&self) -> bool {
        self.generation.load(Ordering::Acquire) != self.my_gen
    }
}

/// Sparse drift baseline from the dense startup profile.
fn planned_map(planned_visits: &[u32]) -> HashMap<u64, f64> {
    planned_visits
        .iter()
        .enumerate()
        .filter(|&(_, &c)| c > 0)
        .map(|(v, &c)| (v as u64, c as f64))
        .collect()
}

/// The watchdog body: spawn a worker generation, watch its heartbeat,
/// and respawn from the last checkpoint when it panics or hangs. The
/// worker runs under `catch_unwind`, so an injected (or real) panic in
/// the loop costs one generation, never the process or the counters.
fn supervise(
    job: &Arc<RefreshJob>,
    stop: &Arc<AtomicBool>,
    stats_out: &Arc<Mutex<RefreshStats>>,
) {
    let generation = Arc::new(AtomicU64::new(0));
    let checkpoint: Arc<Mutex<Option<Checkpoint>>> = Arc::new(Mutex::new(None));
    let poll = Duration::from_millis(5);
    while !stop.load(Ordering::Relaxed) {
        let my_gen = generation.fetch_add(1, Ordering::AcqRel) + 1;
        let sup = Supervision {
            heartbeat: Arc::new(AtomicU64::new(0)),
            generation: Arc::clone(&generation),
            my_gen,
            checkpoint: Arc::clone(&checkpoint),
        };
        let heartbeat = Arc::clone(&sup.heartbeat);
        let worker = {
            let job = Arc::clone(job);
            let stop = Arc::clone(stop);
            let stats_out = Arc::clone(stats_out);
            std::thread::Builder::new()
                .name("dci-refresh".into())
                // the worker returns whether it panicked
                .spawn(move || {
                    std::panic::catch_unwind(AssertUnwindSafe(|| {
                        RefreshLoop::new(&job, &sup).run(&stop, &stats_out);
                    }))
                    .is_err()
                })
                .expect("spawn refresh worker: the OS refused a thread")
        };
        // monitor this generation until it exits, hangs, or stop rises
        let mut last_beat = heartbeat.load(Ordering::Acquire);
        let mut last_change = Instant::now();
        let hung = loop {
            if worker.is_finished() || stop.load(Ordering::Relaxed) {
                break false;
            }
            let beat = heartbeat.load(Ordering::Acquire);
            if beat != last_beat {
                last_beat = beat;
                last_change = Instant::now();
            } else if last_change.elapsed() > job.cfg.watchdog_timeout {
                break true;
            }
            std::thread::sleep(poll);
        };
        if hung {
            // stuck mid-install: bump the generation so the stuck
            // worker self-neuters when (if) it wakes, leave it detached
            // rather than joining a thread that may never return, and
            // respawn from the checkpoint
            generation.fetch_add(1, Ordering::AcqRel);
            record_restart(job, &checkpoint, stats_out, false);
            continue;
        }
        if stop.load(Ordering::Relaxed) {
            // orderly shutdown — but never block it on a worker that is
            // hung *right now*: abandon instead of joining
            if !worker.is_finished() && last_change.elapsed() > job.cfg.watchdog_timeout
            {
                generation.fetch_add(1, Ordering::AcqRel);
                return;
            }
            if worker.join().unwrap_or(true) {
                record_restart(job, &checkpoint, stats_out, true);
            }
            return;
        }
        // the worker exited on its own without stop: the only path here
        // for a live (non-abandoned) generation is an absorbed panic
        if worker.join().unwrap_or(true) {
            record_restart(job, &checkpoint, stats_out, true);
            continue;
        }
        return;
    }
}

/// Fold one watchdog restart (and, when `panicked`, the absorbed
/// panic) into the checkpoint the next generation resumes from, and
/// republish the stats so [`Refresher::stats`] never under-reports a
/// dead worker between generations — the satellite fix for silently
/// swallowed refresh-thread panics.
fn record_restart(
    job: &Arc<RefreshJob>,
    checkpoint: &Mutex<Option<Checkpoint>>,
    stats_out: &Mutex<RefreshStats>,
    panicked: bool,
) {
    let mut slot = lock_unpoisoned(checkpoint);
    let ck = slot.get_or_insert_with(|| Checkpoint {
        budgets: job.shard_budgets.clone(),
        planned: planned_map(&job.planned_visits),
        stats: RefreshStats {
            shard_replans: vec![0; job.runtime.n_shards()],
            shard_budgets: job.shard_budgets.clone(),
            ..Default::default()
        },
    });
    ck.stats.watchdog_restarts += 1;
    if panicked {
        ck.stats.refresh_panics += 1;
    }
    *lock_unpoisoned(stats_out) = ck.stats.clone();
}

/// A sparse exponentially decayed mass profile with O(touched) updates.
///
/// `acc = acc·decay + window` is implemented without touching
/// untouched keys: entries store *unscaled* mass `u` with one global
/// `scale` such that the actual mass is `u · scale`; a decay step
/// multiplies `scale` alone, and merging a window's count adds
/// `count / scale` to the key's entry. `scale` is rebased into the
/// entries before it can underflow.
///
/// With `cap = Some(k)` the profile is pruned to its top-k entries by
/// mass after every merge — the heavy-hitter recovery that keeps a
/// sketch-fed profile (and the re-plans built from it) bounded. The
/// pruned tail also bounds the drift-test error: dropped mass is at
/// most the smallest retained masses' total, a vanishing fraction of a
/// skewed workload (DESIGN.md §Workload tracking derives the bound).
struct DecayedSparse {
    mass: HashMap<u64, f64>,
    scale: f64,
    cap: Option<usize>,
}

/// Entries whose actual mass decays below this are dropped at prune
/// time: a decayed count this small cannot move a drift test or a fill
/// threshold, and dropping it keeps dense-tracker profiles from
/// accumulating every key ever touched.
const DUST: f64 = 1e-3;

impl DecayedSparse {
    fn new(cap: Option<usize>) -> Self {
        DecayedSparse { mass: HashMap::new(), scale: 1.0, cap }
    }

    /// One decay step (start of a window that saw traffic).
    fn decay(&mut self, decay: f64) {
        self.scale *= decay;
        if self.scale < 1e-12 {
            let s = self.scale;
            for u in self.mass.values_mut() {
                *u *= s;
            }
            self.scale = 1.0;
        }
    }

    /// Merge one drained count into the profile.
    fn add(&mut self, key: u64, count: f64) {
        *self.mass.entry(key).or_insert(0.0) += count / self.scale;
    }

    /// Drop dust and (when capped) everything below the top-`cap`
    /// masses. O(active entries).
    fn prune(&mut self) {
        let dust = DUST / self.scale;
        self.mass.retain(|_, u| *u >= dust);
        if let Some(cap) = self.cap {
            if self.mass.len() > cap {
                let mut us: Vec<f64> = self.mass.values().copied().collect();
                let cut = us.len() - cap;
                let (_, &mut thresh, _) =
                    us.select_nth_unstable_by(cut, |a, b| a.total_cmp(b));
                self.mass.retain(|_, u| *u >= thresh);
            }
        }
    }

    /// Actual (scaled) masses, sparse.
    fn iter(&self) -> impl Iterator<Item = (u64, f64)> + '_ {
        let s = self.scale;
        self.mass.iter().map(move |(&k, &u)| (k, u * s))
    }
}

/// Per-shard total-variation drift between the planned and observed
/// node-visit masses, computed over the two **sparse** supports — cost
/// O(|planned| + |observed|), independent of the graph size. Each
/// shard's masses are normalized *within the shard*: a shard with no
/// observations reports zero drift (nothing asked of it, nothing to
/// re-plan), and a shard with observations but no planned mass reports
/// 0.5 (all of its traffic is new). With one shard this is exactly the
/// PR 2 global total-variation distance.
fn shard_drifts_sparse(
    planned: &HashMap<u64, f64>,
    observed: &DecayedSparse,
    router: &ShardRouter,
    n_shards: usize,
) -> Vec<f64> {
    let mut psum = vec![0.0f64; n_shards];
    let mut osum = vec![0.0f64; n_shards];
    for (&v, &p) in planned {
        psum[router.shard_of(v as NodeId)] += p;
    }
    for (v, o) in observed.iter() {
        osum[router.shard_of(v as NodeId)] += o;
    }
    let mut tv = vec![0.0f64; n_shards];
    // Σ|p̂ − ô| over the union of supports: planned entries first, then
    // observed-only entries (their planned mass is zero)
    for (&v, &p) in planned {
        let s = router.shard_of(v as NodeId);
        if osum[s] <= 0.0 {
            continue;
        }
        let ph = if psum[s] > 0.0 { p / psum[s] } else { 0.0 };
        let oh = observed.mass.get(&v).copied().unwrap_or(0.0) * observed.scale
            / osum[s];
        tv[s] += (ph - oh).abs();
    }
    for (v, o) in observed.iter() {
        if planned.contains_key(&v) {
            continue;
        }
        let s = router.shard_of(v as NodeId);
        if osum[s] > 0.0 {
            tv[s] += o / osum[s];
        }
    }
    for t in tv.iter_mut() {
        *t *= 0.5;
    }
    tv
}

/// Shard-level budget-vs-load skew: the total-variation distance
/// between the current per-shard budget shares (normalized) and the
/// observed per-shard load-mass distribution (normalized). At startup
/// the budget shares are the even split, so this is exactly "TV
/// between the even split and the observed shard masses"; after a
/// re-split the comparison self-centers on the new shares, so the
/// signal measures *residual* skew and converges instead of firing
/// forever. Returns 0 when either side has no mass (no evidence, no
/// skew). Distinct from [`shard_drifts_sparse`]: drift is
/// *within-shard* distribution shape; skew is *between-shard* mass.
fn shard_skew(budgets: &[u64], mass: &[f64]) -> f64 {
    let b_total: u64 = budgets.iter().sum();
    let m_total: f64 = mass.iter().sum();
    if b_total == 0 || m_total <= 0.0 {
        return 0.0;
    }
    0.5 * budgets
        .iter()
        .zip(mass)
        .map(|(&b, &m)| (b as f64 / b_total as f64 - m / m_total).abs())
        .sum::<f64>()
}

/// Quantize a decayed mass back to the u32 counts the fills consume,
/// under a caller-chosen `scale`. The same scale must be applied to the
/// node-visit and element-count arrays of one re-plan: planners like
/// DUCATI compare value densities *across* the two arrays, so
/// per-array scaling would skew the knapsack's feature-vs-adjacency
/// choice. Uniform scaling itself is fill-invariant (thresholds and
/// orderings compare relative magnitudes).
fn quantize(x: f64, scale: f64) -> u32 {
    (x * scale).round().max(0.0) as u32
}

/// One common scale for a re-plan's two count arrays: lifts decayed
/// (sub-1) profiles to 10-bit resolution at the hottest entry so
/// rounding cannot zero a still-meaningful profile, and leaves large
/// counts untouched.
fn common_scale(a: impl Iterator<Item = f64>, b: impl Iterator<Item = f64>) -> f64 {
    let maxv = a.chain(b).fold(0.0f64, f64::max);
    if maxv > 0.0 && maxv < 1024.0 {
        1024.0 / maxv
    } else {
        1.0
    }
}

/// Sleep up to `total`, waking early (within one 5 ms slice) when
/// `stop` is raised — keeps `Refresher::stop` latency bounded even
/// with multi-second poll intervals.
fn sleep_interruptibly(total: Duration, stop: &AtomicBool) {
    let slice = Duration::from_millis(5);
    let deadline = Instant::now() + total;
    while !stop.load(Ordering::Relaxed) {
        let now = Instant::now();
        if now >= deadline {
            break;
        }
        std::thread::sleep((deadline - now).min(slice));
    }
}

/// Materialize the dense masked `(node_visits, elem_counts)` arrays of
/// one shard's re-plan from the sparse decayed profiles. O(n) for the
/// zeroed allocations plus O(active) fills — only run when a shard is
/// actually re-planned (the planner itself is O(n) anyway).
fn masked_profile(
    csc: &Csc,
    acc_nv: &DecayedSparse,
    acc_ec: &DecayedSparse,
    router: &ShardRouter,
    shard: usize,
) -> (Vec<u32>, Vec<u32>) {
    let nv_m: Vec<(u64, f64)> = acc_nv
        .iter()
        .filter(|&(v, _)| router.shard_of(v as NodeId) == shard)
        .collect();
    let ec_m: Vec<(u64, f64)> = acc_ec
        .iter()
        .filter(|&(e, _)| router.shard_of(elem_owner(csc, e)) == shard)
        .collect();
    let scale = common_scale(
        nv_m.iter().map(|&(_, m)| m),
        ec_m.iter().map(|&(_, m)| m),
    );
    let mut nv = vec![0u32; csc.n_nodes()];
    for &(v, m) in &nv_m {
        nv[v as usize] = quantize(m, scale);
    }
    let mut ec = vec![0u32; csc.n_edges()];
    for &(e, m) in &ec_m {
        ec[e as usize] = quantize(m, scale);
    }
    (nv, ec)
}

/// Compose the per-class decayed profiles into the single
/// class-weighted node profile every consumer reads:
/// `weighted[v] = Σ_c weights[c] · mass_c[v]`.
///
/// The bit-identity contract for class-blind streams rides on f64
/// exactness here: an untagged stream holds all its mass in the
/// standard class, the absent classes contribute no terms at all (not
/// even `+ 0.0`), and the standard term `1.0 · m` is exact — so under
/// the default weights the composition *is* the unweighted profile,
/// bit-for-bit.
fn weighted_profile(
    accs: &[DecayedSparse; N_CLASSES],
    weights: &ClassWeights,
) -> DecayedSparse {
    let mut out = DecayedSparse::new(None);
    for (acc, &w) in accs.iter().zip(weights.0.iter()) {
        for (k, m) in acc.iter() {
            *out.mass.entry(k).or_insert(0.0) += w * m;
        }
    }
    out
}

/// The refresh thread's owned state: the decayed profiles, the drift
/// baseline, and — elastic budgets — the live per-shard budget vector
/// and decayed peak claim.
struct RefreshLoop<'j> {
    job: &'j RefreshJob,
    /// This generation's watchdog handles (heartbeat, abandonment
    /// check, checkpoint slot).
    sup: &'j Supervision,
    router: ShardRouter,
    n_shards: usize,
    /// Current per-shard budgets (moves under `rebalance=on`).
    budgets: Vec<u64>,
    /// Σ `budgets` — the current global budget.
    global: u64,
    /// The startup global budget (`auto_budget_delta` baseline).
    startup_global: u64,
    /// Sparse drift baseline: the nonzero planned masses.
    planned: HashMap<u64, f64>,
    /// Per-admission-class decayed node-visit profiles (index =
    /// `TenantClass::index()`). Untagged windows fold entirely into
    /// the standard class; the class-weighted composition every
    /// consumer reads is built by [`RefreshLoop::weighted_nv`].
    acc_nv: [DecayedSparse; N_CLASSES],
    /// Decayed element-access profile — deliberately class-blind: a
    /// per-class split would multiply the O(touched-edges) drain state
    /// by `N_CLASSES` for a signal the adjacency fill barely uses (see
    /// `WorkloadTracker::record_elem`).
    acc_ec: DecayedSparse,
    acc_ts: f64,
    acc_tf: f64,
    /// Decayed peak batch input count (auto-budget claim input):
    /// raised immediately by a bigger batch, decayed at the profile's
    /// rate so a lightened workload returns memory to the caches.
    peak_inputs: f64,
    batches_pending: u64,
    /// When each currently degraded shard entered degraded mode
    /// (repair-latency accounting; `None` = healthy).
    degraded_since: Vec<Option<Instant>>,
    stats: RefreshStats,
}

impl<'j> RefreshLoop<'j> {
    fn new(job: &'j RefreshJob, sup: &'j Supervision) -> RefreshLoop<'j> {
        let n_shards = job.runtime.n_shards();
        let caps = job.tracker.heavy_hitter_caps();
        let global: u64 = job.shard_budgets.iter().sum();
        let mut l = RefreshLoop {
            job,
            sup,
            router: job.runtime.router().clone(),
            n_shards,
            budgets: job.shard_budgets.clone(),
            global,
            startup_global: global,
            planned: planned_map(&job.planned_visits),
            acc_nv: std::array::from_fn(|_| DecayedSparse::new(caps.map(|(n, _)| n))),
            acc_ec: DecayedSparse::new(caps.map(|(_, e)| e)),
            acc_ts: 0.0,
            acc_tf: 0.0,
            peak_inputs: 0.0,
            batches_pending: 0,
            degraded_since: (0..n_shards)
                .map(|s| job.runtime.is_degraded(s).then(Instant::now))
                .collect(),
            stats: RefreshStats {
                shard_replans: vec![0; n_shards],
                shard_budgets: job.shard_budgets.clone(),
                ..Default::default()
            },
        };
        // a respawned generation resumes from the previous one's
        // durable state; the decayed traffic accumulators rebuild from
        // live windows (startup_global stays the true startup value so
        // auto_budget_delta keeps its baseline across restarts)
        if let Some(ck) = lock_unpoisoned(&sup.checkpoint).clone() {
            l.budgets = ck.budgets;
            l.global = l.budgets.iter().sum();
            l.planned = ck.planned;
            l.stats = ck.stats;
        }
        l
    }

    fn run(&mut self, stop: &AtomicBool, stats_out: &Mutex<RefreshStats>) {
        let cfg = &self.job.cfg;
        while !stop.load(Ordering::Relaxed) {
            self.sup.beat();
            sleep_interruptibly(cfg.check_interval, stop);
            if stop.load(Ordering::Relaxed) || self.sup.abandoned() {
                break;
            }
            // idle server: skip the drain entirely
            if self.job.tracker.batches() == 0 && self.batches_pending == 0 {
                continue;
            }
            self.drain_window();
            if self.batches_pending < cfg.min_batches.max(1) {
                continue;
            }
            self.stats.checks += 1;
            // the min-batches window is per *check*: reset it whatever
            // the verdict, so a quiet server goes back to the idle skip
            // above instead of re-checking unchanged data every poll
            // (drift that builds slowly still accumulates in the
            // decayed profile)
            self.batches_pending = 0;
            // budgets first, contents second: a shard the re-split just
            // re-planned (at its NEW budget) also had its drift baseline
            // re-centered, so the drift pass right after skips it — the
            // typical hot-set migration (drift and skew firing on the
            // same check) costs one install per shard, not two
            if cfg.rebalance || cfg.auto_budget_refresh {
                self.rebalance_pass();
            }
            // repairs before drift: a degraded shard serves every read
            // from host memory, so promoting it back outranks re-tuning
            // healthy shards' contents
            self.repair_pass();
            self.drift_pass();
            if self.sup.abandoned() {
                return;
            }
            self.stats.shard_budgets = self.budgets.clone();
            *lock_unpoisoned(stats_out) = self.stats.clone();
            *lock_unpoisoned(&self.sup.checkpoint) = Some(Checkpoint {
                budgets: self.budgets.clone(),
                planned: self.planned.clone(),
                stats: self.stats.clone(),
            });
        }
        if self.sup.abandoned() {
            return;
        }
        self.stats.shard_budgets = self.budgets.clone();
        *lock_unpoisoned(stats_out) = self.stats.clone();
    }

    /// Drain the tracker and fold the window into the decayed state.
    fn drain_window(&mut self) {
        if let Some(f) = &self.job.fault {
            if f.drain_panic() {
                panic!("injected fault: tracker drain panic");
            }
        }
        let cfg = &self.job.cfg;
        let drain0 = Instant::now();
        let w = self.job.tracker.drain();
        if w.batches > 0 {
            for acc in self.acc_nv.iter_mut() {
                acc.decay(cfg.decay);
            }
            self.acc_ec.decay(cfg.decay);
            self.acc_ts = self.acc_ts * cfg.decay + w.t_sample_ns;
            self.acc_tf = self.acc_tf * cfg.decay + w.t_feature_ns;
            self.peak_inputs =
                (self.peak_inputs * cfg.decay).max(w.peak_input_nodes as f64);
            // a tagged window splits its node counts per class; an
            // untagged one (the common all-standard case) folds the
            // aggregate into the standard profile, so class-blind
            // serving never pays for — or is perturbed by — the split
            if w.class_node_visits.is_empty() {
                let std_acc = &mut self.acc_nv[TenantClass::Standard.index()];
                for &(v, c) in &w.node_visits {
                    std_acc.add(v as u64, c as f64);
                }
            } else {
                for &(v, per) in &w.class_node_visits {
                    for (acc, &c) in self.acc_nv.iter_mut().zip(per.iter()) {
                        if c > 0 {
                            acc.add(v as u64, c as f64);
                        }
                    }
                }
            }
            for &(e, c) in &w.elem_counts {
                self.acc_ec.add(e, c as f64);
            }
            for acc in self.acc_nv.iter_mut() {
                acc.prune();
            }
            self.acc_ec.prune();
            self.stats.drained_keys +=
                (w.node_visits.len() + w.elem_counts.len()) as u64;
            self.stats.dropped_touches += w.dropped_touches;
            self.batches_pending += w.batches;
        }
        self.stats.drain_ns += drain0.elapsed().as_nanos() as f64;
    }

    /// The class-weighted node profile consumed by every drift test,
    /// re-split, and re-plan (see [`weighted_profile`]).
    fn weighted_nv(&self) -> DecayedSparse {
        weighted_profile(&self.acc_nv, &self.job.cfg.class_weights)
    }

    /// The PR 3 within-shard drift detection + per-shard re-plans,
    /// measured on the class-weighted profile — drift in a
    /// high-weight tenant's traffic trips the threshold sooner than
    /// the same raw drift in scan traffic.
    fn drift_pass(&mut self) {
        let cfg = &self.job.cfg;
        let weighted = self.weighted_nv();
        let drifts =
            shard_drifts_sparse(&self.planned, &weighted, &self.router, self.n_shards);
        self.stats.last_drift = drifts.iter().cloned().fold(0.0, f64::max);
        let any_drifted = drifts.iter().any(|&d| d > cfg.drift_threshold);
        let mut drifted: Vec<usize> = if cfg.per_shard || self.n_shards == 1 {
            (0..self.n_shards)
                .filter(|&s| drifts[s] > cfg.drift_threshold)
                .collect()
        } else if any_drifted {
            (0..self.n_shards).collect()
        } else {
            Vec::new()
        };
        // degraded shards belong to the repair pass that already ran
        // this check — re-firing their install from here would burn a
        // second attempt (and its backoff) on the same shard
        drifted.retain(|&s| !self.job.runtime.is_degraded(s));
        // re-plan each drifted shard on this thread from the decayed
        // profile masked to the shard's own nodes, within the shard's
        // own (current) budget, and hot-swap only that shard; the
        // serving path — and every *other* shard — never waits on this
        for s in drifted {
            self.replan_shard(s, self.budgets[s]);
        }
    }

    /// Elastic budgets: measure budget-vs-load skew, re-evaluate the
    /// auto budget, and on either trigger re-split + re-plan only the
    /// shards whose budgets changed.
    fn rebalance_pass(&mut self) {
        let cfg = &self.job.cfg;
        // observed per-shard load mass (decayed, sparse,
        // class-weighted: budget follows the traffic the operator
        // values, not the loudest scanner)
        let mut mass = vec![0.0f64; self.n_shards];
        for (v, m) in self.weighted_nv().iter() {
            mass[self.router.shard_of(v as NodeId)] += m;
        }
        self.stats.last_skew = shard_skew(&self.budgets, &mass);

        // epoch-aware auto budget: re-evaluate §IV.A's "C" from the
        // decayed peak claim, with a hysteresis band so jitter in the
        // peak does not thrash re-plans
        let mut target_global = self.global;
        if cfg.auto_budget_refresh {
            if let Some(policy) = &self.job.auto_budget {
                let g =
                    policy.global_budget(self.peak_inputs.round() as u64, self.n_shards);
                let band = cfg.rebalance_threshold * self.global.max(1) as f64;
                if g.abs_diff(self.global) as f64 > band {
                    target_global = g;
                }
            }
        }
        let skew_triggered =
            cfg.rebalance && self.stats.last_skew > cfg.rebalance_threshold;
        if !skew_triggered && target_global == self.global {
            return;
        }

        // with rebalancing on, shares follow the observed load; with
        // only auto-budget refresh armed, the new global keeps the even
        // split — re-tracking the budget and redistributing it are
        // independent knobs
        let mut new_budgets = if cfg.rebalance {
            // heterogeneous groups bias the load mass by each device's
            // relative H2D bandwidth: budget parked behind a slow link
            // costs more install time per byte to keep fresh
            let mut mass = mass;
            if let Some(dev) = &self.job.device {
                if dev.is_tiered() {
                    for (s, m) in mass.iter_mut().enumerate() {
                        *m *= dev.bandwidth_share(s);
                    }
                }
            }
            split_budget_weighted(target_global, &mass, cfg.rebalance_floor)
        } else {
            split_budget(target_global, self.n_shards)
        };
        // no shard's share may exceed its own device's headroom — the
        // constraint that made the even split safe by construction
        // (resolve_budget clamps total ≤ Σ headrooms) must survive the
        // weighted split too, per device on heterogeneous nodes
        if let Some(dev) = &self.job.device {
            cap_shares_per_device(&mut new_budgets, &dev.headrooms());
        } else if let Some(policy) = &self.job.auto_budget {
            match &policy.tier_headrooms {
                Some(h) if h.len() == self.n_shards => {
                    cap_shares_per_device(&mut new_budgets, h)
                }
                _ => cap_shares(&mut new_budgets, policy.headroom_per_device),
            }
        }
        let changed: Vec<usize> = (0..self.n_shards)
            .filter(|&s| new_budgets[s] != self.budgets[s])
            .collect();
        if changed.is_empty() {
            return;
        }
        self.stats.shard_rebalances += 1;
        self.stats.budget_moved_bytes += changed
            .iter()
            .map(|&s| new_budgets[s].saturating_sub(self.budgets[s]))
            .sum::<u64>();
        // shrink-first order: shards giving up budget install their
        // smaller snapshots (releasing device bytes) before growing
        // shards claim theirs — the group-level analogue of the
        // per-device claim-before-release in `replan_shard`
        let mut order = changed;
        order.sort_by_key(|&s| new_budgets[s] as i128 - self.budgets[s] as i128);
        for s in order {
            if self.replan_shard(s, new_budgets[s]) {
                self.stats.rebalance_installs += 1;
                self.budgets[s] = new_budgets[s];
            }
        }
        // if an install was skipped on OOM the shard keeps its old
        // budget — re-derive the global from what actually holds
        self.global = self.budgets.iter().sum();
        self.stats.auto_budget_delta = self.global as i64 - self.startup_global as i64;
    }

    /// Degraded-mode repair: re-attempt a full install for every shard
    /// currently serving from host memory, promoting each back on
    /// success (inside [`RefreshLoop::replan_shard`]). Runs every
    /// check, so repair latency is bounded by the check cadence plus
    /// the install retries themselves — the bound `benches/chaos.rs`
    /// gates.
    fn repair_pass(&mut self) {
        for s in 0..self.n_shards {
            if self.job.runtime.is_degraded(s) {
                self.replan_shard(s, self.budgets[s]);
            }
        }
    }

    /// One exponential-backoff pause before (1-based) retry `attempt`.
    fn backoff(&mut self, attempt: u32) {
        self.stats.install_retries += 1;
        let pause = self.job.cfg.install_backoff * (1u32 << (attempt - 1).min(10));
        let b0 = Instant::now();
        std::thread::sleep(pause);
        self.stats.backoff_ns += b0.elapsed().as_nanos() as f64;
    }

    /// Check one injection site against the attached fault plan
    /// (always false — one pointer null-check — with no plan).
    fn injected(&self, site: impl Fn(&FaultPlan) -> bool) -> bool {
        self.job.fault.as_deref().is_some_and(site)
    }

    /// Re-plan shard `s` within `budget` from the masked decayed
    /// profile and hot-swap it, with two-phase claim-before-release
    /// device accounting when a device group is attached. Claim and
    /// fill failures (injected or real) retry under bounded exponential
    /// backoff; a claim that still fails is skipped (`install_ooms`,
    /// the old epoch keeps serving — the PR 5 path) while a fill that
    /// still fails is terminal and degrades the shard to host reads
    /// until the repair pass promotes it back. Returns whether the
    /// install happened.
    fn replan_shard(&mut self, s: usize, budget: u64) -> bool {
        if self.sup.abandoned() {
            // a newer generation owns the runtime: stop touching it
            return false;
        }
        self.sup.beat();
        let t0 = Instant::now();
        let repairing = self.job.runtime.is_degraded(s);
        let weighted_nv = self.weighted_nv();
        let (nv, ec) =
            masked_profile(&self.job.ds.csc, &weighted_nv, &self.acc_ec, &self.router, s);
        let profile = WorkloadProfile {
            node_visits: &nv,
            elem_counts: &ec,
            t_sample_ns: self.acc_ts,
            t_feature_ns: self.acc_tf,
        };
        let plan = self.job.planner.plan(&self.job.ds, &profile, budget);
        let install_bytes = plan.fill_ledger.h2d_bytes;
        let new_bytes = plan.snapshot.bytes_used();

        // injected hang: the stall sits before any claim, so a
        // generation the watchdog abandons mid-hang holds no device
        // bytes and exits without rollback when it wakes
        if let Some(ms) = self.job.fault.as_deref().and_then(|f| f.install_hang_ms(s))
        {
            std::thread::sleep(Duration::from_millis(ms));
            if self.sup.abandoned() {
                return false;
            }
        }

        // phase 1 — claim the incoming epoch while the outgoing one is
        // still resident (readers may serve one more batch from it).
        // The transient may dip into the reserve; that is the reserve's
        // job. Only this thread installs, so the live snapshot's bytes
        // cannot change between this read and the swap below.
        let dev = self.job.device.as_ref();
        let old_bytes = self.job.runtime.shard(s).load().bytes_used();
        let mut released_first = false;
        let mut claimed = false;
        for attempt in 0..=self.job.cfg.install_retries {
            if attempt > 0 {
                self.backoff(attempt);
            }
            if self.injected(|f| f.install_oom(s)) {
                continue;
            }
            let Some(d) = dev else {
                claimed = true;
                break;
            };
            if d.alloc_unreserved(s, new_bytes).is_ok() {
                claimed = true;
                break;
            }
            // the overlap exceeds even the reserve: fall back to
            // release-then-claim (the simulation keeps serving the old
            // Arc regardless; a real deployment would stage through
            // host memory here)
            if !released_first {
                d.free(s, old_bytes);
                released_first = true;
            }
            if d.alloc_unreserved(s, new_bytes).is_ok() {
                claimed = true;
                break;
            }
        }
        if !claimed {
            // cannot fit even alone after the retry budget: restore the
            // old claim and keep serving the old epoch
            if released_first {
                if let Some(d) = dev {
                    let _ = d.alloc_unreserved(s, old_bytes);
                }
            }
            self.stats.install_ooms += 1;
            return false;
        }
        if let Some(d) = dev {
            self.stats.max_transient_bytes =
                self.stats.max_transient_bytes.max(d.used(s));
        }

        // the host→device fill. The simulated transfer cannot fail on
        // its own, but the fault plan can make it: unlike a claim OOM,
        // a transfer that keeps failing leaves the device copy
        // untrustworthy, so exhausting the budget here is terminal.
        // When the engine's staging pool is wired, the fill stages
        // through one leased buffer (the same pinned pool — and reuse
        // counters — as the serving gathers).
        let stage_lease = self.job.staging.as_ref().map(|p| p.lease());
        let mut transferred = false;
        for attempt in 0..=self.job.cfg.install_retries {
            if attempt > 0 {
                self.backoff(attempt);
            }
            if self.injected(|f| f.install_error(s)) {
                continue;
            }
            transferred = true;
            break;
        }
        if let (Some(pool), Some(buf)) = (self.job.staging.as_ref(), stage_lease) {
            pool.give_back(buf);
        }
        if !transferred {
            // terminal: release every device claim, publish an empty
            // snapshot, and mark the shard degraded — views bypass the
            // cache and read host memory (correct, just slower) until
            // the repair pass lands
            if let Some(d) = dev {
                d.free(s, new_bytes);
                if !released_first {
                    d.free(s, old_bytes);
                }
            }
            if self.job.runtime.mark_degraded(s) {
                self.stats.shard_degrades += 1;
                self.degraded_since[s] = Some(Instant::now());
            }
            self.job.runtime.install_shard(s, CacheSnapshot::empty());
            return false;
        }

        self.job.runtime.install_shard(s, plan.snapshot);
        // phase 2 — release the outgoing epoch's claim
        if !released_first {
            if let Some(d) = dev {
                d.free(s, old_bytes);
            }
        }
        if repairing && self.job.runtime.mark_repaired(s) {
            self.stats.shard_repairs += 1;
            if let Some(since) = self.degraded_since[s].take() {
                self.stats.repair_wall_ns += since.elapsed().as_nanos() as f64;
            }
        }
        self.stats.fill_h2d_bytes += install_bytes;
        self.stats.max_install_h2d_bytes =
            self.stats.max_install_h2d_bytes.max(install_bytes);
        self.stats.replan_wall_ns += t0.elapsed().as_nanos() as f64;
        self.stats.replans += 1;
        self.stats.shard_replans[s] += 1;
        // re-center this shard's drift baseline on what it now serves
        // (sparse: drop the shard's old entries, insert the observed
        // masses)
        let router = &self.router;
        self.planned.retain(|&v, _| router.shard_of(v as NodeId) != s);
        for (v, m) in weighted_nv.iter() {
            if router.shard_of(v as NodeId) == s {
                self.planned.insert(v, m);
            }
        }
        true
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::cache::planner::{split_budget, DciPlanner};
    use crate::cache::runtime::CacheSnapshot;
    use crate::cache::shard::{plan_sharded, ShardRouter, ShardedRuntime};
    use crate::cache::tracker::{AccessTracker, SketchTracker};
    use crate::graph::datasets;
    use crate::mem::{CostModel, DeviceMemory};
    use crate::sampler::{presample, Fanout};
    use crate::util::Rng;

    fn fast_cfg(threshold: f64) -> RefreshConfig {
        RefreshConfig {
            check_interval: Duration::from_millis(5),
            min_batches: 1,
            decay: 0.5,
            drift_threshold: threshold,
            ..RefreshConfig::default()
        }
    }

    /// Helper: sparse observed profile from `(key, mass)` pairs.
    fn observed(pairs: &[(u64, f64)]) -> DecayedSparse {
        let mut o = DecayedSparse::new(None);
        for &(k, m) in pairs {
            o.add(k, m);
        }
        o
    }

    fn planned(pairs: &[(u64, f64)]) -> HashMap<u64, f64> {
        pairs.iter().copied().collect()
    }

    #[test]
    fn single_shard_drift_is_the_global_tv_distance() {
        let r = ShardRouter::new(1);
        let p = planned(&[(0, 1.0), (1, 1.0)]);
        // matched distribution → 0
        let d = shard_drifts_sparse(&p, &observed(&[(0, 2.0), (1, 2.0)]), &r, 1);
        assert!(d[0].abs() < 1e-12);
        // fully disjoint mass → 1
        let d = shard_drifts_sparse(&p, &observed(&[(2, 7.0)]), &r, 1);
        assert!((d[0] - 1.0).abs() < 1e-12);
        // empty observation → no drift signal
        let d = shard_drifts_sparse(&p, &observed(&[]), &r, 1);
        assert_eq!(d, vec![0.0]);
        // no planned mass but live traffic → 0.5 (half the mass is new)
        let d = shard_drifts_sparse(&planned(&[]), &observed(&[(0, 3.0), (1, 1.0)]), &r, 1);
        assert!((d[0] - 0.5).abs() < 1e-12);
    }

    #[test]
    fn drift_is_isolated_to_the_observed_shard() {
        // find two nodes per shard under the real router
        let r = ShardRouter::new(2);
        let pick = |s: usize, n: usize| -> Vec<u64> {
            (0u64..10_000).filter(|&v| r.shard_of(v as NodeId) == s).take(n).collect()
        };
        let s0 = pick(0, 2);
        let s1 = pick(1, 2);
        let p = planned(&[(s0[0], 10.0), (s1[0], 5.0), (s1[1], 5.0)]);
        // shard 0's traffic flipped to its other node; shard 1 silent
        let d = shard_drifts_sparse(&p, &observed(&[(s0[1], 8.0)]), &r, 2);
        assert!((d[0] - 1.0).abs() < 1e-12, "shard 0 fully drifted: {d:?}");
        assert_eq!(d[1], 0.0, "unobserved shard must not drift: {d:?}");
        // shard 1's traffic matching its plan stays quiet while shard 0
        // drifts — per-shard normalization keeps them independent
        let d = shard_drifts_sparse(
            &p,
            &observed(&[(s0[1], 8.0), (s1[0], 4.0), (s1[1], 4.0)]),
            &r,
            2,
        );
        assert!(d[0] > 0.9);
        assert!(d[1] < 1e-12);
    }

    #[test]
    fn skew_measures_between_shard_mass_not_shape() {
        // even budgets, even mass → no skew
        assert_eq!(shard_skew(&[10, 10, 10, 10], &[3.0, 3.0, 3.0, 3.0]), 0.0);
        // all the mass on one shard under even budgets → TV = 1 − 1/n
        let s = shard_skew(&[10, 10, 10, 10], &[0.0, 0.0, 8.0, 0.0]);
        assert!((s - 0.75).abs() < 1e-12, "{s}");
        // budgets already matching the mass → no skew (self-centering)
        let s = shard_skew(&[1, 1, 8, 1], &[1.0, 1.0, 8.0, 1.0]);
        assert!(s.abs() < 1e-12, "{s}");
        // no observations → no evidence → no skew
        assert_eq!(shard_skew(&[10, 10], &[0.0, 0.0]), 0.0);
        assert_eq!(shard_skew(&[0, 0], &[1.0, 1.0]), 0.0);
    }

    #[test]
    fn auto_budget_policy_tracks_the_peak_claim() {
        let policy = AutoBudgetPolicy {
            headroom_per_device: 1_000_000,
            per_node_bytes: 100,
            scale: 1.0,
            tier_headrooms: None,
        };
        // claim = 2 × peak × per_node (full scale)
        assert_eq!(policy.global_budget(0, 4), 4_000_000);
        assert_eq!(policy.global_budget(1_000, 4), 4 * (1_000_000 - 200_000));
        // claim beyond the headroom → zero budget, never underflow
        assert_eq!(policy.global_budget(10_000_000, 4), 0);
        // single shard is the global
        assert_eq!(policy.global_budget(1_000, 1), 800_000);
    }

    #[test]
    fn tiered_auto_budget_pays_the_claim_per_device() {
        let policy = AutoBudgetPolicy {
            headroom_per_device: 1_000_000,
            per_node_bytes: 100,
            scale: 1.0,
            tier_headrooms: Some(vec![1_000_000, 400_000, 400_000]),
        };
        // claim = 2 × 1_000 × 100 = 200_000, paid out of each tier
        assert_eq!(
            policy.global_budget(1_000, 3),
            (1_000_000 - 200_000) + 2 * (400_000 - 200_000)
        );
        // a claim that swamps the small tiers only zeroes them
        assert_eq!(policy.global_budget(2_500, 3), 1_000_000 - 500_000);
        // n_shards is ignored when the tier vector is authoritative
        assert_eq!(policy.global_budget(0, 99), 1_800_000);
    }

    #[test]
    fn decayed_sparse_matches_the_dense_recurrence() {
        // acc = acc*0.5 + window, three windows on one key
        let mut acc = DecayedSparse::new(None);
        for w in [8.0, 4.0, 2.0] {
            acc.decay(0.5);
            acc.add(7, w);
        }
        // dense: ((8*0.5)+4)*0.5 + 2 = 6
        let got: Vec<(u64, f64)> = acc.iter().collect();
        assert_eq!(got.len(), 1);
        assert!((got[0].1 - 6.0).abs() < 1e-9);
        // rebase path: many decay steps must not lose precision
        let mut acc = DecayedSparse::new(None);
        acc.add(1, 1024.0);
        for _ in 0..100 {
            acc.decay(0.7);
        }
        acc.add(1, 3.0);
        let m = acc.iter().next().unwrap().1;
        assert!((m - (1024.0 * 0.7f64.powi(100) + 3.0)).abs() < 1e-6, "{m}");
    }

    #[test]
    fn decayed_sparse_prunes_dust_and_keeps_heavy_hitters() {
        let mut acc = DecayedSparse::new(Some(3));
        acc.decay(0.5);
        for k in 0..10u64 {
            acc.add(k, (k + 1) as f64);
        }
        acc.prune();
        let kept: Vec<u64> = acc.iter().map(|(k, _)| k).collect();
        assert_eq!(kept.len(), 3, "top-k prune");
        assert!(kept.contains(&9) && kept.contains(&8) && kept.contains(&7));
        // dust: decay a lone small mass until it evaporates
        let mut acc = DecayedSparse::new(None);
        acc.add(5, 1.0);
        for _ in 0..40 {
            acc.decay(0.5);
        }
        acc.prune();
        assert_eq!(acc.iter().count(), 0, "decayed dust must be dropped");
    }

    #[test]
    fn masked_profile_respects_shard_ownership() {
        let ds = datasets::spec("tiny").unwrap().build();
        let router = ShardRouter::new(3);
        let mut nv = DecayedSparse::new(None);
        let mut ec = DecayedSparse::new(None);
        for v in 0..ds.csc.n_nodes() as u64 {
            nv.add(v, (v % 7 + 1) as f64);
        }
        for e in (0..ds.csc.n_edges() as u64).step_by(3) {
            ec.add(e, 2.0);
        }
        for s in 0..3 {
            let (nvd, ecd) = masked_profile(&ds.csc, &nv, &ec, &router, s);
            for (v, &c) in nvd.iter().enumerate() {
                if router.shard_of(v as NodeId) != s {
                    assert_eq!(c, 0, "node {v} leaked into shard {s}");
                }
            }
            for (e, &c) in ecd.iter().enumerate() {
                if c > 0 {
                    assert_eq!(
                        router.shard_of(elem_owner(&ds.csc, e as u64)),
                        s,
                        "elem {e} leaked into shard {s}"
                    );
                }
            }
        }
    }

    #[test]
    fn quantize_preserves_relative_magnitudes() {
        let nv = [0.1, 0.2, 0.4];
        let scale = common_scale(nv.iter().copied(), std::iter::empty());
        let q: Vec<u32> = nv.iter().map(|&x| quantize(x, scale)).collect();
        assert!(q[2] > q[1] && q[1] > q[0]);
        assert_eq!(q[2], 1024);
        // large counts pass through unscaled
        let big = [2000.0, 4000.0];
        let s = common_scale(big.iter().copied(), std::iter::empty());
        assert_eq!(s, 1.0);
        // ONE scale across both arrays of a re-plan: the hotter array
        // pins it, so cross-array density ratios survive quantization
        let ec = [4000.0];
        let s = common_scale(nv.iter().copied(), ec.iter().copied());
        assert_eq!(s, 1.0);
        assert_eq!(quantize(nv[0], s), 0);
        assert_eq!(quantize(ec[0], s), 4000);
    }

    #[test]
    fn refresher_replans_on_forced_drift() {
        let ds = Arc::new(datasets::spec("tiny").unwrap().build());
        let runtime = Arc::new(ShardedRuntime::single(CacheSnapshot::empty()));
        let tracker = Arc::new(AccessTracker::new(ds.csc.n_nodes(), ds.csc.n_edges()));
        // a baseline profile concentrated on node 0; observe node 1
        let mut planned = vec![0u32; ds.csc.n_nodes()];
        planned[0] = 100;
        let r = RefreshJob::new(
            Arc::clone(&ds),
            Arc::clone(&runtime),
            Arc::clone(&tracker) as Arc<dyn WorkloadTracker>,
            Box::new(DciPlanner),
            vec![200_000],
            planned,
            fast_cfg(0.3),
        )
        .spawn();
        for _ in 0..50 {
            tracker.record_node(1);
        }
        tracker.record_elem(0);
        tracker.record_batch(50.0, 50.0, 50);
        // wait for the loop to pick it up
        let deadline = Instant::now() + Duration::from_secs(10);
        while runtime.swaps() == 0 && Instant::now() < deadline {
            std::thread::sleep(Duration::from_millis(5));
        }
        let stats = r.stop();
        assert!(stats.replans >= 1, "drift should have forced a re-plan: {stats:?}");
        assert!(stats.last_drift > 0.3);
        assert!(stats.max_install_h2d_bytes > 0);
        assert!(stats.drained_keys >= 2, "node 1 + elem 0 drained: {stats:?}");
        assert!(stats.drain_ns > 0.0);
        assert_eq!(stats.dropped_touches, 0);
        assert_eq!(stats.shard_rebalances, 0, "rebalance defaults off");
        assert_eq!(stats.shard_budgets, vec![200_000], "budgets frozen");
        assert!(runtime.swaps() >= 1);
        // the refreshed snapshot caches the observed hot node
        let snap = runtime.load();
        assert!(snap.feat.as_ref().unwrap().contains(1));
    }

    /// The tentpole guarantee: the sketch path drives the same re-plan
    /// decisions as the dense path on a sparse drift stream.
    #[test]
    fn sketch_refresher_replans_on_forced_drift() {
        let ds = Arc::new(datasets::spec("tiny").unwrap().build());
        let runtime = Arc::new(ShardedRuntime::single(CacheSnapshot::empty()));
        let tracker =
            Arc::new(SketchTracker::with_defaults(ds.csc.n_nodes(), ds.csc.n_edges()));
        let mut planned = vec![0u32; ds.csc.n_nodes()];
        planned[0] = 100;
        let r = RefreshJob::new(
            Arc::clone(&ds),
            Arc::clone(&runtime),
            Arc::clone(&tracker) as Arc<dyn WorkloadTracker>,
            Box::new(DciPlanner),
            vec![200_000],
            planned,
            fast_cfg(0.3),
        )
        .spawn();
        for _ in 0..50 {
            tracker.record_node(1);
        }
        tracker.record_elem(0);
        tracker.record_batch(50.0, 50.0, 50);
        let deadline = Instant::now() + Duration::from_secs(10);
        while runtime.swaps() == 0 && Instant::now() < deadline {
            std::thread::sleep(Duration::from_millis(5));
        }
        let stats = r.stop();
        assert!(stats.replans >= 1, "sketch drift must re-plan: {stats:?}");
        assert!(runtime.load().feat.as_ref().unwrap().contains(1));
    }

    /// Doubles as the back-compat coverage for the deprecated
    /// [`Refresher::spawn`] shim: old call sites must keep compiling
    /// and behave identically to `RefreshJob::new(...).spawn()`.
    #[test]
    #[allow(deprecated)]
    fn refresher_idle_without_traffic() {
        let ds = Arc::new(datasets::spec("tiny").unwrap().build());
        let runtime = Arc::new(ShardedRuntime::single(CacheSnapshot::empty()));
        let tracker = Arc::new(AccessTracker::new(ds.csc.n_nodes(), ds.csc.n_edges()));
        let r = Refresher::spawn(
            Arc::clone(&ds),
            Arc::clone(&runtime),
            tracker,
            Box::new(DciPlanner),
            vec![100_000],
            Vec::new(),
            fast_cfg(0.0),
        );
        std::thread::sleep(Duration::from_millis(30));
        let stats = r.stop();
        assert_eq!(stats.replans, 0, "no traffic, no re-plan");
        assert_eq!(stats.drained_keys, 0, "idle polls must not drain");
        assert_eq!(runtime.swaps(), 0);
    }

    /// The PR 3 invariant, unchanged by the sparse rework: traffic that
    /// drifts inside one shard re-plans *only* that shard; every other
    /// shard keeps serving its original epoch.
    #[test]
    fn refresher_replans_only_the_drifted_shard() {
        let n_shards = 4;
        let ds = Arc::new(datasets::spec("tiny").unwrap().build());
        let router = ShardRouter::new(n_shards);
        let budget = 120_000u64;
        let budgets = split_budget(budget, n_shards);

        // startup plan: a presample profile sharded across 4 devices
        let stats0 = presample(
            &ds.csc,
            &ds.features,
            &ds.test_nodes,
            64,
            &Fanout::parse("3,2").unwrap(),
            4,
            &CostModel::default(),
            &mut Rng::new(7),
        );
        let profile = WorkloadProfile::from_presample(&stats0);
        let sharded = plan_sharded(&DciPlanner, &ds, &profile, budget, &router);
        let runtime = Arc::new(ShardedRuntime::new(
            ShardRouter::new(n_shards),
            sharded.plans.into_iter().map(|p| p.snapshot).collect(),
        ));
        let tracker = Arc::new(AccessTracker::new(ds.csc.n_nodes(), ds.csc.n_edges()));
        let r = RefreshJob::new(
            Arc::clone(&ds),
            Arc::clone(&runtime),
            Arc::clone(&tracker) as Arc<dyn WorkloadTracker>,
            Box::new(DciPlanner),
            budgets,
            stats0.node_visits.clone(),
            fast_cfg(0.3),
        )
        .spawn();

        // drive traffic confined to shard 2's nodes, disjoint from the
        // planned profile's hot set as far as shard 2 is concerned
        let shard2: Vec<NodeId> = (0..ds.csc.n_nodes() as u32)
            .filter(|&v| router.shard_of(v) == 2 && stats0.node_visits[v as usize] == 0)
            .take(40)
            .collect();
        assert!(shard2.len() >= 10, "tiny must have unvisited shard-2 nodes");
        for _ in 0..20 {
            for &v in &shard2 {
                tracker.record_node(v);
            }
        }
        tracker.record_batch(50.0, 50.0, 40);

        let deadline = Instant::now() + Duration::from_secs(10);
        while runtime.swaps() == 0 && Instant::now() < deadline {
            std::thread::sleep(Duration::from_millis(5));
        }
        let stats = r.stop();
        assert!(stats.replans >= 1, "shard 2's drift must re-plan: {stats:?}");
        assert!(stats.shard_replans[2] >= 1, "{stats:?}");
        for s in [0usize, 1, 3] {
            assert_eq!(
                stats.shard_replans[s],
                0,
                "shard {s} saw no drift and must keep its epoch: {stats:?}"
            );
            assert_eq!(runtime.shard(s).swaps(), 0);
        }
        assert!(runtime.shard(2).swaps() >= 1);
        assert_eq!(runtime.swap_stalls(), 0);
        // the refreshed shard caches its new hot nodes
        let snap = runtime.shard(2).load();
        let feat = snap.feat.as_ref().unwrap();
        let cached_hot = shard2.iter().filter(|&&v| feat.contains(v)).count();
        assert!(cached_hot > 0, "re-plan must cache shard 2's new working set");
    }

    /// The elastic-budget integration contract: a hot set migrating
    /// onto one shard triggers exactly one rebalance (the re-split
    /// self-centers, so steady traffic fires no second one), the
    /// budgets move to the hot shard while conserving the global sum,
    /// and the device ledgers balance after claim-before-release
    /// reclaim — every device holds exactly its live snapshot's bytes.
    #[test]
    fn migrating_hot_set_rebalances_once_and_ledgers_balance() {
        let n_shards = 4;
        let ds = Arc::new(datasets::spec("tiny").unwrap().build());
        let router = ShardRouter::new(n_shards);
        let global = 200_000u64;
        let budgets = split_budget(global, n_shards);
        let runtime = Arc::new(ShardedRuntime::new(
            ShardRouter::new(n_shards),
            (0..n_shards).map(|_| CacheSnapshot::empty()).collect(),
        ));
        // empty snapshots ↔ zeroed ledgers: consistent starting state
        let device = Arc::new(DeviceGroup::replicate(
            &DeviceMemory::new(10 << 20, 1 << 16),
            n_shards,
        ));
        let tracker = Arc::new(AccessTracker::new(ds.csc.n_nodes(), ds.csc.n_edges()));
        // drift baseline on a shard-0 node so shard 2's traffic is new
        let mut planned = vec![0u32; ds.csc.n_nodes()];
        let node0 = (0..ds.csc.n_nodes() as u32)
            .find(|&v| router.shard_of(v) == 0)
            .unwrap();
        planned[node0 as usize] = 100;
        let cfg = RefreshConfig {
            check_interval: Duration::from_millis(5),
            min_batches: 1,
            decay: 0.5,
            drift_threshold: 0.3,
            rebalance: true,
            rebalance_threshold: 0.3,
            rebalance_floor: 0.1,
            ..RefreshConfig::default()
        };
        let r = RefreshJob::new(
            Arc::clone(&ds),
            Arc::clone(&runtime),
            Arc::clone(&tracker) as Arc<dyn WorkloadTracker>,
            Box::new(DciPlanner),
            budgets,
            planned,
            cfg,
        )
        .device(Arc::clone(&device))
        .spawn();

        // the hot set: shard 2's nodes only, in steady waves
        let shard2: Vec<NodeId> = (0..ds.csc.n_nodes() as u32)
            .filter(|&v| router.shard_of(v) == 2)
            .take(30)
            .collect();
        assert!(shard2.len() >= 10);
        let deadline = Instant::now() + Duration::from_secs(10);
        while r.stats().shard_rebalances == 0 && Instant::now() < deadline {
            for _ in 0..10 {
                for &v in &shard2 {
                    tracker.record_node(v);
                }
            }
            tracker.record_batch(50.0, 50.0, 30);
            std::thread::sleep(Duration::from_millis(10));
        }
        // steady-state waves after the re-split: the self-centered skew
        // must stay under the threshold, so no second rebalance fires
        for _ in 0..6 {
            for _ in 0..10 {
                for &v in &shard2 {
                    tracker.record_node(v);
                }
            }
            tracker.record_batch(50.0, 50.0, 30);
            std::thread::sleep(Duration::from_millis(10));
        }
        let stats = r.stop();
        assert_eq!(
            stats.shard_rebalances, 1,
            "steady migrated traffic must re-split exactly once: {stats:?}"
        );
        assert!(stats.rebalance_installs >= 1, "{stats:?}");
        assert!(stats.last_skew < 0.3, "skew must self-center: {stats:?}");
        // deterministic split: floors of 0.1 × even share, rest to the
        // hot shard
        assert_eq!(stats.shard_budgets, vec![5_000, 5_000, 185_000, 5_000]);
        assert_eq!(stats.shard_budgets.iter().sum::<u64>(), global);
        assert_eq!(stats.budget_moved_bytes, 135_000, "50k → 185k on shard 2");
        assert_eq!(stats.install_ooms, 0);
        assert!(stats.max_transient_bytes > 0, "claims were accounted");
        assert_eq!(stats.auto_budget_delta, 0, "no auto policy, no delta");
        // ledgers balance after reclaim: each device holds exactly its
        // live snapshot's bytes, nothing leaked, nothing double-counted
        for s in 0..n_shards {
            assert_eq!(
                device.used(s),
                runtime.shard(s).load().bytes_used(),
                "device {s} ledger out of balance"
            );
        }
        assert_eq!(runtime.swap_stalls(), 0);
    }

    /// Auto-budget refresh: a shrinking observed peak claim grows the
    /// global budget (and vice versa), flowing through the same
    /// re-split machinery with the shard sum conserved.
    #[test]
    fn auto_budget_refresh_tracks_the_observed_peak() {
        let ds = Arc::new(datasets::spec("tiny").unwrap().build());
        let runtime = Arc::new(ShardedRuntime::single(CacheSnapshot::empty()));
        let tracker = Arc::new(AccessTracker::new(ds.csc.n_nodes(), ds.csc.n_edges()));
        let policy = AutoBudgetPolicy {
            headroom_per_device: 500_000,
            per_node_bytes: 1_000,
            scale: 1.0,
            tier_headrooms: None,
        };
        // startup budget assumed a peak of 100 inputs → 300_000
        let startup = policy.global_budget(100, 1);
        assert_eq!(startup, 300_000);
        let cfg = RefreshConfig {
            check_interval: Duration::from_millis(5),
            min_batches: 1,
            decay: 0.5,
            drift_threshold: 2.0, // drift never fires; isolate the budget path
            per_shard: true,
            // rebalance deliberately OFF: auto-budget refresh is an
            // independent knob (a changed global keeps the even split)
            rebalance: false,
            rebalance_threshold: 0.1,
            rebalance_floor: 0.1,
            auto_budget_refresh: true,
            ..RefreshConfig::default()
        };
        let r = RefreshJob::new(
            Arc::clone(&ds),
            Arc::clone(&runtime),
            Arc::clone(&tracker) as Arc<dyn WorkloadTracker>,
            Box::new(DciPlanner),
            vec![startup],
            Vec::new(),
            cfg,
        )
        .auto_budget(policy)
        .spawn();

        // live traffic peaks at only 20 inputs → claim shrinks 2kB →
        // budget grows to 460_000 (> 10% band → applied)
        let deadline = Instant::now() + Duration::from_secs(10);
        while r.stats().auto_budget_delta == 0 && Instant::now() < deadline {
            tracker.record_node(1);
            tracker.record_batch(10.0, 10.0, 20);
            std::thread::sleep(Duration::from_millis(10));
        }
        let stats = r.stop();
        assert_eq!(
            stats.shard_budgets,
            vec![policy.global_budget(20, 1)],
            "budget must track the observed peak: {stats:?}"
        );
        assert_eq!(stats.auto_budget_delta, 460_000 - 300_000);
        assert!(stats.shard_rebalances >= 1);
        assert!(runtime.swaps() >= 1, "the budget change re-plans the shard");
    }

    /// Forced-drift wiring shared by the fault tests: tiny dataset, a
    /// single-shard empty runtime, a dense tracker, and a baseline
    /// concentrated on node 0 so traffic on node 1 always drifts.
    fn drift_fixture() -> (Arc<Dataset>, Arc<ShardedRuntime>, Arc<AccessTracker>, Vec<u32>)
    {
        let ds = Arc::new(datasets::spec("tiny").unwrap().build());
        let runtime = Arc::new(ShardedRuntime::single(CacheSnapshot::empty()));
        let tracker = Arc::new(AccessTracker::new(ds.csc.n_nodes(), ds.csc.n_edges()));
        let mut planned = vec![0u32; ds.csc.n_nodes()];
        planned[0] = 100;
        (ds, runtime, tracker, planned)
    }

    fn drift_wave(tracker: &AccessTracker) {
        for _ in 0..50 {
            tracker.record_node(1);
        }
        tracker.record_batch(50.0, 50.0, 50);
    }

    #[test]
    fn install_retry_backs_off_through_transient_claim_ooms() {
        let (ds, runtime, tracker, planned) = drift_fixture();
        let cfg = RefreshConfig {
            install_backoff: Duration::from_millis(1),
            ..fast_cfg(0.3)
        };
        let r = RefreshJob::new(
            Arc::clone(&ds),
            Arc::clone(&runtime),
            Arc::clone(&tracker) as Arc<dyn WorkloadTracker>,
            Box::new(DciPlanner),
            vec![200_000],
            planned,
            cfg,
        )
        .fault(Arc::new(FaultPlan::parse("oom@0x2").unwrap()))
        .spawn();
        drift_wave(&tracker);
        let deadline = Instant::now() + Duration::from_secs(10);
        while runtime.swaps() == 0 && Instant::now() < deadline {
            std::thread::sleep(Duration::from_millis(5));
        }
        let stats = r.stop();
        assert!(stats.replans >= 1, "{stats:?}");
        assert_eq!(stats.install_ooms, 0, "retries must absorb transient OOMs: {stats:?}");
        assert_eq!(stats.install_retries, 2, "one backoff per injected OOM: {stats:?}");
        assert!(stats.backoff_ns > 0.0);
        assert_eq!(stats.shard_degrades, 0);
        assert!(runtime.swaps() >= 1, "the third attempt must land");
    }

    #[test]
    fn claim_oom_exhausting_retries_keeps_the_old_epoch() {
        let (ds, runtime, tracker, planned) = drift_fixture();
        let cfg = RefreshConfig {
            install_backoff: Duration::from_millis(1),
            ..fast_cfg(0.3)
        };
        let r = RefreshJob::new(
            Arc::clone(&ds),
            Arc::clone(&runtime),
            Arc::clone(&tracker) as Arc<dyn WorkloadTracker>,
            Box::new(DciPlanner),
            vec![200_000],
            planned,
            cfg,
        )
        .fault(Arc::new(FaultPlan::parse("oom@0x100").unwrap()))
        .spawn();
        drift_wave(&tracker);
        let deadline = Instant::now() + Duration::from_secs(10);
        while r.stats().install_ooms == 0 && Instant::now() < deadline {
            std::thread::sleep(Duration::from_millis(5));
        }
        let stats = r.stop();
        assert!(stats.install_ooms >= 1, "exhausted retries must count: {stats:?}");
        assert!(stats.install_retries >= 3, "the full retry budget was spent: {stats:?}");
        assert_eq!(stats.replans, 0, "no install may land: {stats:?}");
        assert_eq!(runtime.swaps(), 0, "the old epoch must keep serving");
        assert_eq!(stats.shard_degrades, 0, "a claim OOM skips, never degrades");
    }

    #[test]
    fn transfer_error_degrades_the_shard_and_repair_promotes_it_back() {
        let (ds, runtime, tracker, planned) = drift_fixture();
        let device =
            Arc::new(DeviceGroup::replicate(&DeviceMemory::new(10 << 20, 1 << 16), 1));
        let cfg = RefreshConfig {
            install_backoff: Duration::from_millis(1),
            ..fast_cfg(0.3)
        };
        // install_retries = 3 → 4 attempts; err@0x4 makes exactly the
        // first install terminal and lets the first repair succeed
        let r = RefreshJob::new(
            Arc::clone(&ds),
            Arc::clone(&runtime),
            Arc::clone(&tracker) as Arc<dyn WorkloadTracker>,
            Box::new(DciPlanner),
            vec![200_000],
            planned,
            cfg,
        )
        .device(Arc::clone(&device))
        .fault(Arc::new(FaultPlan::parse("err@0x4").unwrap()))
        .spawn();
        let deadline = Instant::now() + Duration::from_secs(10);
        while r.stats().shard_repairs == 0 && Instant::now() < deadline {
            drift_wave(&tracker);
            std::thread::sleep(Duration::from_millis(10));
        }
        let stats = r.stop();
        assert_eq!(stats.shard_degrades, 1, "{stats:?}");
        assert_eq!(stats.shard_repairs, 1, "{stats:?}");
        assert!(stats.repair_wall_ns > 0.0);
        assert!(stats.install_retries >= 3, "the fill burned its retry budget: {stats:?}");
        assert!(!runtime.is_degraded(0), "the shard must be promoted back");
        assert!(
            runtime.swaps() >= 2,
            "degrade installs empty, repair installs real: {stats:?}"
        );
        // device ledger consistent through degrade + repair: it holds
        // exactly the live snapshot's bytes, nothing leaked
        assert_eq!(device.used(0), runtime.shard(0).load().bytes_used());
        assert!(runtime.load().feat.as_ref().unwrap().contains(1));
    }

    #[test]
    fn drain_panic_is_absorbed_and_the_watchdog_respawns() {
        let (ds, runtime, tracker, planned) = drift_fixture();
        let r = RefreshJob::new(
            Arc::clone(&ds),
            Arc::clone(&runtime),
            Arc::clone(&tracker) as Arc<dyn WorkloadTracker>,
            Box::new(DciPlanner),
            vec![200_000],
            planned,
            fast_cfg(0.3),
        )
        .fault(Arc::new(FaultPlan::parse("drain").unwrap()))
        .spawn();
        let deadline = Instant::now() + Duration::from_secs(10);
        while runtime.swaps() == 0 && Instant::now() < deadline {
            drift_wave(&tracker);
            std::thread::sleep(Duration::from_millis(10));
        }
        let stats = r.stop();
        assert_eq!(stats.refresh_panics, 1, "the panic must be surfaced: {stats:?}");
        assert_eq!(stats.watchdog_restarts, 1, "{stats:?}");
        assert!(
            stats.replans >= 1,
            "the respawned generation must keep re-planning: {stats:?}"
        );
        assert!(runtime.swaps() >= 1);
    }

    #[test]
    fn hung_install_is_abandoned_and_a_fresh_generation_takes_over() {
        let (ds, runtime, tracker, planned) = drift_fixture();
        let cfg = RefreshConfig {
            watchdog_timeout: Duration::from_millis(100),
            ..fast_cfg(0.3)
        };
        let r = RefreshJob::new(
            Arc::clone(&ds),
            Arc::clone(&runtime),
            Arc::clone(&tracker) as Arc<dyn WorkloadTracker>,
            Box::new(DciPlanner),
            vec![200_000],
            planned,
            cfg,
        )
        .fault(Arc::new(FaultPlan::parse("hang@0~400").unwrap()))
        .spawn();
        // the first install stalls 400 ms; the 100 ms watchdog abandons
        // it and the respawn (fault exhausted) installs for real
        let deadline = Instant::now() + Duration::from_secs(10);
        while runtime.swaps() == 0 && Instant::now() < deadline {
            drift_wave(&tracker);
            std::thread::sleep(Duration::from_millis(10));
        }
        // let the hung generation wake up and self-neuter before
        // checking the counters
        std::thread::sleep(Duration::from_millis(450));
        let stats = r.stop();
        assert_eq!(stats.watchdog_restarts, 1, "{stats:?}");
        assert_eq!(stats.refresh_panics, 0, "a hang is not a panic: {stats:?}");
        assert!(stats.replans >= 1, "{stats:?}");
        assert!(runtime.swaps() >= 1);
        assert!(!runtime.is_degraded(0));
    }

    #[test]
    fn class_weighted_profile_outbids_raw_counts() {
        // priority node 1 visited 10×, scan node 2 visited 100×: the
        // default weights (4 / 1 / 0.05) still put node 1 far ahead —
        // the noisy scanner cannot outbid the priority tenant by QPS
        let mut accs: [DecayedSparse; N_CLASSES] =
            std::array::from_fn(|_| DecayedSparse::new(None));
        accs[TenantClass::Priority.index()].add(1, 10.0);
        accs[TenantClass::Scan.index()].add(2, 100.0);
        let w = weighted_profile(&accs, &ClassWeights::default());
        let m: HashMap<u64, f64> = w.iter().collect();
        assert!((m[&1] - 40.0).abs() < 1e-12, "{m:?}");
        assert!((m[&2] - 5.0).abs() < 1e-12, "{m:?}");
        // both classes touching one node sum their weighted masses
        accs[TenantClass::Standard.index()].add(1, 3.0);
        let w = weighted_profile(&accs, &ClassWeights::default());
        let m: HashMap<u64, f64> = w.iter().collect();
        assert!((m[&1] - 43.0).abs() < 1e-12, "{m:?}");
    }

    #[test]
    fn untagged_profile_is_bit_identical_under_any_weights() {
        // fold the same untagged windows into (a) the per-class accs
        // (all mass lands in the standard class, weight 1.0) and
        // (b) a class-blind acc, then compose under aggressive
        // priority/scan weights: every mass must match *exactly* — the
        // bit-identity contract for class-blind request streams
        let mut accs: [DecayedSparse; N_CLASSES] =
            std::array::from_fn(|_| DecayedSparse::new(None));
        let mut blind = DecayedSparse::new(None);
        let windows: [&[(u64, u32)]; 3] =
            [&[(3, 7), (9, 1)], &[(4, 123)], &[(3, 2), (4, 1)]];
        for w in windows {
            for acc in accs.iter_mut() {
                acc.decay(0.5);
            }
            blind.decay(0.5);
            for &(v, c) in w {
                accs[TenantClass::Standard.index()].add(v, c as f64);
                blind.add(v, c as f64);
            }
        }
        let weighted = weighted_profile(&accs, &ClassWeights([9.0, 1.0, 0.001]));
        let got: HashMap<u64, f64> = weighted.iter().collect();
        let want: HashMap<u64, f64> = blind.iter().collect();
        assert_eq!(got.len(), want.len());
        for (k, v) in &want {
            assert_eq!(got[k].to_bits(), v.to_bits(), "node {k} drifted in bits");
        }
    }

    /// The satellite property: with all-equal class weights the
    /// class-split pipeline reduces to the class-blind plan
    /// bit-identically, over randomized single-window class splits.
    /// (Counts are integers and the decay is dyadic, so the f64 sums
    /// on both sides are exact.)
    #[test]
    fn equal_weights_reduce_to_the_class_blind_plan() {
        let ds = datasets::spec("tiny").unwrap().build();
        let router = ShardRouter::new(1);
        let mut rng = Rng::new(42);
        for trial in 0..8 {
            let mut accs: [DecayedSparse; N_CLASSES] =
                std::array::from_fn(|_| DecayedSparse::new(None));
            let mut blind = DecayedSparse::new(None);
            for acc in accs.iter_mut() {
                acc.decay(0.5);
            }
            blind.decay(0.5);
            // one drained window: random nodes, random per-class counts
            for _ in 0..12 {
                let v = rng.gen_usize(ds.csc.n_nodes()) as u64;
                let per: [u32; N_CLASSES] =
                    std::array::from_fn(|_| rng.gen_range(8) as u32);
                for (acc, &c) in accs.iter_mut().zip(per.iter()) {
                    if c > 0 {
                        acc.add(v, c as f64);
                    }
                }
                let total: u32 = per.iter().sum();
                if total > 0 {
                    blind.add(v, total as f64);
                }
            }
            let weighted = weighted_profile(&accs, &ClassWeights::EQUAL);
            let ec = DecayedSparse::new(None);
            let (nv_w, ec_w) = masked_profile(&ds.csc, &weighted, &ec, &router, 0);
            let (nv_b, ec_b) = masked_profile(&ds.csc, &blind, &ec, &router, 0);
            assert_eq!(nv_w, nv_b, "trial {trial}: quantized profiles diverged");
            assert_eq!(ec_w, ec_b);
            // and the plans built from them match structurally: same
            // split, same fill traffic, same cached node set
            let profile_w = WorkloadProfile {
                node_visits: &nv_w,
                elem_counts: &ec_w,
                t_sample_ns: 10.0,
                t_feature_ns: 10.0,
            };
            let profile_b = WorkloadProfile {
                node_visits: &nv_b,
                elem_counts: &ec_b,
                t_sample_ns: 10.0,
                t_feature_ns: 10.0,
            };
            let plan_w = DciPlanner.plan(&ds, &profile_w, 100_000);
            let plan_b = DciPlanner.plan(&ds, &profile_b, 100_000);
            assert_eq!(plan_w.snapshot.alloc, plan_b.snapshot.alloc);
            assert_eq!(plan_w.fill_ledger.h2d_bytes, plan_b.fill_ledger.h2d_bytes);
            assert_eq!(plan_w.snapshot.bytes_used(), plan_b.snapshot.bytes_used());
            let (fw, fb) = (
                plan_w.snapshot.feat.as_ref().unwrap(),
                plan_b.snapshot.feat.as_ref().unwrap(),
            );
            for v in 0..ds.csc.n_nodes() as NodeId {
                assert_eq!(fw.contains(v), fb.contains(v), "trial {trial}, node {v}");
            }
        }
    }

    /// End-to-end through the loop's own drain: class-tagged tracker
    /// records split into per-class profiles, and the weighted
    /// composition ranks a lightly-touched priority node above a
    /// hammered scan node.
    #[test]
    fn tagged_windows_fold_into_per_class_profiles() {
        let (ds, runtime, tracker, planned) = drift_fixture();
        let job = RefreshJob::new(
            Arc::clone(&ds),
            Arc::clone(&runtime),
            Arc::clone(&tracker) as Arc<dyn WorkloadTracker>,
            Box::new(DciPlanner),
            vec![200_000],
            planned,
            RefreshConfig::default(),
        );
        let sup = Supervision {
            heartbeat: Arc::new(AtomicU64::new(0)),
            generation: Arc::new(AtomicU64::new(0)),
            my_gen: 0,
            checkpoint: Arc::new(Mutex::new(None)),
        };
        let mut l = RefreshLoop::new(&job, &sup);
        for _ in 0..10 {
            tracker.record_node_as(TenantClass::Priority, 1);
        }
        for _ in 0..100 {
            tracker.record_node_as(TenantClass::Scan, 2);
        }
        tracker.record_batch(50.0, 50.0, 110);
        l.drain_window();
        // per-class accs carry the split (dyadic decay → exact masses)
        let prio: HashMap<u64, f64> =
            l.acc_nv[TenantClass::Priority.index()].iter().collect();
        let scan: HashMap<u64, f64> =
            l.acc_nv[TenantClass::Scan.index()].iter().collect();
        assert_eq!(prio.get(&1).copied(), Some(10.0));
        assert!(!prio.contains_key(&2));
        assert_eq!(scan.get(&2).copied(), Some(100.0));
        // the weighted composition inverts the raw-count order
        let m: HashMap<u64, f64> = l.weighted_nv().iter().collect();
        assert!((m[&1] - 40.0).abs() < 1e-12, "{m:?}");
        assert!((m[&2] - 5.0).abs() < 1e-12, "{m:?}");
        assert!(m[&1] > m[&2], "priority must outbid the scanner");
    }
}
