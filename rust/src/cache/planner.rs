//! Cache planning behind one trait: profiling counts → capacity
//! allocation → fill.
//!
//! A [`CachePlanner`] turns a [`WorkloadProfile`] (per-node feature
//! visits, per-CSC-element accesses, and the two stage times of Eq. 1)
//! plus a byte budget into a filled [`CacheSnapshot`]. The same planner
//! runs in two places:
//!
//! - **offline** — `baselines::{dci,sci,ducati}::prepare` profile via
//!   pre-sampling and plan once at startup;
//! - **online** — [`crate::cache::refresh`] re-plans from decayed
//!   serving-time access counts and hot-swaps the result into the
//!   [`crate::cache::DualCacheRuntime`].
//!
//! DCI's two-scan fills are what make the online path affordable: a
//! re-plan costs O(n) scans plus the fill upload, not DUCATI's full
//! O(n log n) knapsack sort (Fig. 10) — though `DucatiPlanner` is
//! available behind the same trait for comparison runs.

use std::time::Instant;

use anyhow::{bail, Result};

use crate::config::SystemKind;
use crate::coordinator::admission::{TenantClass, N_CLASSES};
use crate::graph::{Dataset, NodeId};
use crate::mem::TransferLedger;
use crate::sampler::PresampleStats;

use super::adj_cache::AdjCache;
use super::alloc::{self, CacheAllocation};
use super::feat_cache::FeatCache;
use super::runtime::CacheSnapshot;

/// What every planner consumes: the access profile of a workload
/// window, borrowed from whoever measured it (pre-sampling stats or
/// the online refresh accumulator).
#[derive(Debug, Clone, Copy)]
pub struct WorkloadProfile<'a> {
    /// Per-node visit counts in the feature-loading stage.
    pub node_visits: &'a [u32],
    /// Per-CSC-element access counts (parallel to `csc.row_index`).
    pub elem_counts: &'a [u32],
    /// Sampling-stage time over the window, ns (modeled).
    pub t_sample_ns: f64,
    /// Feature-stage time over the window, ns (modeled).
    pub t_feature_ns: f64,
}

impl<'a> WorkloadProfile<'a> {
    /// View a pre-sampling profile as a planner input.
    pub fn from_presample(stats: &'a PresampleStats) -> WorkloadProfile<'a> {
        WorkloadProfile {
            node_visits: &stats.node_visits,
            elem_counts: &stats.elem_counts,
            t_sample_ns: stats.t_sample_ns,
            t_feature_ns: stats.t_feature_ns,
        }
    }

    /// Eq. (1) ratio input: fraction of prep time spent sampling.
    pub fn sample_fraction(&self) -> f64 {
        let total = self.t_sample_ns + self.t_feature_ns;
        if total == 0.0 {
            0.5
        } else {
            self.t_sample_ns / total
        }
    }
}

/// Per-admission-class profile weights the planner's input is composed
/// under (`tenant.weights=priority,standard,scan`). The refresh loop
/// keeps one decayed node-visit profile per
/// [`TenantClass`](crate::coordinator::TenantClass) and feeds every
/// planner the weighted sum `Σ_c weight[c] · mass_c[v]`, so the fills
/// maximize a *class-weighted* hit ratio rather than the raw one: one
/// priority touch outbids `w_priority / w_scan` scan touches for the
/// same cache bytes. Only ratios matter — the fills compare relative
/// magnitudes, so `[4, 1, 0.05]` and `[8, 2, 0.1]` plan identically.
#[derive(Debug, Clone, Copy, PartialEq)]
pub struct ClassWeights(
    /// Weights in [`TenantClass::ALL`](crate::coordinator::TenantClass::ALL)
    /// order: priority, standard, scan.
    pub [f64; N_CLASSES],
);

impl Default for ClassWeights {
    /// Priority 4, standard 1, scan 0.05 — scan traffic is floored
    /// near, deliberately not at, zero, so a scan-only deployment
    /// still caches its working set instead of nothing.
    fn default() -> Self {
        ClassWeights([4.0, 1.0, 0.05])
    }
}

impl ClassWeights {
    /// All classes weighted equally: reduces every plan to the
    /// class-blind one bit-for-bit (held by property tests in
    /// [`crate::cache::refresh`]).
    pub const EQUAL: ClassWeights = ClassWeights([1.0; N_CLASSES]);

    /// Parse `"p,s,c"` — one non-negative finite weight per class, in
    /// [`TenantClass::ALL`](crate::coordinator::TenantClass::ALL)
    /// (priority, standard, scan) order.
    pub fn parse(s: &str) -> Result<ClassWeights> {
        let parts: Vec<&str> = s.split(',').collect();
        if parts.len() != N_CLASSES {
            bail!(
                "tenant.weights needs exactly {N_CLASSES} comma-separated values \
                 (priority,standard,scan), got {s:?}"
            );
        }
        let mut w = [0.0f64; N_CLASSES];
        for (slot, part) in w.iter_mut().zip(&parts) {
            let v: f64 = part.trim().parse().map_err(|_| {
                anyhow::anyhow!("bad weight {part:?} in tenant.weights={s:?}")
            })?;
            if !v.is_finite() || v < 0.0 {
                bail!("tenant.weights entries must be finite and non-negative, got {part:?}");
            }
            *slot = v;
        }
        Ok(ClassWeights(w))
    }

    /// This class's weight.
    pub fn weight(&self, class: TenantClass) -> f64 {
        self.0[class.index()]
    }
}

/// A planner's output: the snapshot to install plus the fill's own
/// preprocessing traffic and host-side wall time.
pub struct CachePlan {
    /// Filled caches (epoch assigned at install time).
    pub snapshot: CacheSnapshot,
    /// H2D upload traffic of the fills.
    pub fill_ledger: TransferLedger,
    /// Host-side wall time of allocation + fill, ns.
    pub plan_wall_ns: f64,
}

/// Allocation + fill strategy. Implementations must be cheap enough to
/// run on the online refresh thread (or accept that refreshes with
/// them are slow — `DucatiPlanner`).
pub trait CachePlanner: Send + Sync {
    /// Strategy name (`"dci"` | `"sci"` | `"ducati"`), for logs.
    fn name(&self) -> &'static str;

    /// Split `budget` bytes and fill both caches from `profile`.
    fn plan(&self, ds: &Dataset, profile: &WorkloadProfile<'_>, budget: u64) -> CachePlan;
}

/// Split a global Eq. (1) budget across `n_shards` devices in exact
/// integer arithmetic: every shard gets `budget / n` and the remainder
/// `budget % n` goes one byte each to the first shards — the same
/// no-float discipline as the feature fill's `c * n > total` average
/// threshold, so no shard sum can ever exceed the global budget and no
/// byte is lost to rounding.
///
/// **Zero-shard contract** (shared with [`split_budget_weighted`]):
/// `n_shards == 0` is treated as one shard — the result is `[budget]`,
/// never an empty vector. A splitter that returned `[]` would silently
/// lose the whole budget; clamping to one logical shard keeps the
/// conservation invariant (`Σ shares == budget`) total, and every
/// degenerate caller (single-device runtimes, tests probing the edge)
/// gets the obviously-right answer.
pub fn split_budget(budget: u64, n_shards: usize) -> Vec<u64> {
    let n = n_shards.max(1) as u64;
    let base = budget / n;
    let rem = budget % n;
    let shares: Vec<u64> = (0..n).map(|s| base + u64::from(s < rem)).collect();
    debug_assert_eq!(
        shares.iter().sum::<u64>(),
        budget,
        "shard split must conserve the budget exactly"
    );
    shares
}

/// Resolution of the integer weight quantization in
/// [`split_budget_weighted`]: loads are mapped to `0..=2^20` buckets
/// relative to the hottest shard, so the quantization error is below
/// one part in a million of the dominant load.
const WEIGHT_BUCKETS: u64 = 1 << 20;

/// Split a global budget across shards **proportionally to their
/// observed load mass**, in exact integer arithmetic (largest-remainder
/// apportionment over `u128` products — `Σ shares == budget` always,
/// no float ever touches a byte count).
///
/// - `floor` ∈ [0, 1] is the guaranteed minimum share per shard,
///   expressed as a fraction of the even base share: every shard keeps
///   at least `⌊(budget / n) as f64 · floor⌋` bytes however cold it
///   goes, so a rebalance can never strand a shard with zero capacity
///   for the traffic that *does* route to it.
/// - Under a uniform load vector the result is byte-identical to
///   [`split_budget`] (even split, remainder front-loaded) — weighting
///   is a generalization, not a second code path that can drift.
/// - An **all-zero (or empty-support) load vector falls back to the
///   even split**: no observations is no evidence for skew.
/// - **Zero-shard contract** (shared with [`split_budget`]): an empty
///   load vector is treated as one shard and returns `[budget]`.
///
/// Negative or non-finite load entries are treated as zero.
pub fn split_budget_weighted(budget: u64, shard_loads: &[f64], floor: f64) -> Vec<u64> {
    let n = shard_loads.len();
    if n <= 1 {
        // the zero-shard contract: the budget is never silently lost
        return vec![budget];
    }
    let floor = floor.clamp(0.0, 1.0);
    // clamp against the even base share: the f64 round-trip can round
    // a u64-scale quotient *up*, and `floor_share · n > budget` must
    // be impossible by construction
    let even_base = budget / n as u64;
    let floor_share = (((even_base as f64) * floor) as u64).min(even_base);
    let mut shares = vec![floor_share; n];
    let remaining = budget - floor_share * n as u64;

    // quantize loads to integer weights relative to the hottest shard
    let max_load = shard_loads
        .iter()
        .filter(|l| l.is_finite())
        .fold(0.0f64, |a, &b| a.max(b));
    let weights: Vec<u128> = shard_loads
        .iter()
        .map(|&l| {
            if max_load > 0.0 && l.is_finite() && l > 0.0 {
                ((l / max_load) * WEIGHT_BUCKETS as f64).round() as u128
            } else {
                0
            }
        })
        .collect();
    let total: u128 = weights.iter().sum();
    if total == 0 {
        // no load evidence: the even split of what the floors left
        for (s, e) in shares.iter_mut().zip(split_budget(remaining, n)) {
            *s += e;
        }
        return shares;
    }

    // largest-remainder (Hamilton) apportionment of `remaining`:
    // integer quotients first, then one byte each to the largest
    // remainders (ties to the lower shard index, matching the even
    // split's front-loaded remainder)
    let mut assigned = 0u64;
    let mut rems: Vec<(u128, usize)> = Vec::with_capacity(n);
    for (s, &w) in weights.iter().enumerate() {
        let prod = remaining as u128 * w;
        let q = (prod / total) as u64;
        shares[s] += q;
        assigned += q;
        rems.push((prod % total, s));
    }
    rems.sort_by(|a, b| b.0.cmp(&a.0).then(a.1.cmp(&b.1)));
    for &(_, s) in rems.iter().take((remaining - assigned) as usize) {
        shares[s] += 1;
    }
    debug_assert_eq!(
        shares.iter().sum::<u64>(),
        budget,
        "weighted split must conserve the budget exactly"
    );
    shares
}

/// Clamp every share to `cap` (a per-device headroom), redistributing
/// the clipped excess evenly among the still-open shards — exact
/// integer arithmetic, conservation preserved whenever
/// `Σ shares ≤ n · cap` (the [`crate::baselines::resolve_budget`]
/// clamp guarantees exactly that for budget splits). Terminates in at
/// most `n` rounds: each non-final round closes at least one share at
/// the cap.
pub fn cap_shares(shares: &mut [u64], cap: u64) {
    let caps = vec![cap; shares.len()];
    cap_shares_per_device(shares, &caps);
}

/// [`cap_shares`] generalized to heterogeneous devices: clamp share
/// `i` to `caps[i]` (that device's headroom), redistributing clipped
/// excess evenly among the still-open shards. Same conservation and
/// termination properties — conservation holds whenever
/// `Σ shares ≤ Σ caps`, and each non-final round closes at least one
/// share at its cap. With a uniform cap vector this *is* `cap_shares`
/// (which now delegates here), so the two can never drift.
pub fn cap_shares_per_device(shares: &mut [u64], caps: &[u64]) {
    assert_eq!(shares.len(), caps.len(), "one cap per share");
    loop {
        let mut excess = 0u64;
        for (s, &cap) in shares.iter_mut().zip(caps) {
            if *s > cap {
                excess += *s - cap;
                *s = cap;
            }
        }
        if excess == 0 {
            return;
        }
        let open: Vec<usize> = (0..shares.len()).filter(|&i| shares[i] < caps[i]).collect();
        if open.is_empty() {
            // total exceeds Σ caps: everything is pinned at its cap and
            // the overflow is genuinely unplaceable — callers clamp the
            // global budget first, so this is the documented lossy edge
            return;
        }
        let n = open.len() as u64;
        let (base, rem) = (excess / n, excess % n);
        for (i, &s) in open.iter().enumerate() {
            shares[s] += base + u64::from((i as u64) < rem);
        }
    }
}

/// The planner behind each cache-owning system. `None` for systems
/// with no workload-driven cache plan (DGL caches nothing; RAIN's
/// state is its batch order, which cannot be re-planned mid-serve).
pub fn planner_for(kind: SystemKind) -> Option<Box<dyn CachePlanner>> {
    match kind {
        SystemKind::Dci => Some(Box::new(DciPlanner)),
        SystemKind::Sci => Some(Box::new(SciPlanner)),
        SystemKind::Ducati => Some(Box::new(DucatiPlanner)),
        SystemKind::Dgl | SystemKind::Rain => None,
    }
}

/// The paper's §IV pipeline: Eq. (1) split, then the two lightweight
/// fills (average-visit threshold + Algorithm 1).
pub struct DciPlanner;

impl CachePlanner for DciPlanner {
    fn name(&self) -> &'static str {
        "dci"
    }

    fn plan(&self, ds: &Dataset, profile: &WorkloadProfile<'_>, budget: u64) -> CachePlan {
        let split = alloc::allocate_profile(budget, profile);
        let wall0 = Instant::now();
        let (adj, adj_ledger) = AdjCache::fill(&ds.csc, profile.elem_counts, split.c_adj);
        let (feat, feat_ledger) =
            FeatCache::fill(&ds.features, profile.node_visits, split.c_feat);
        let mut fill_ledger = adj_ledger;
        fill_ledger.merge(&feat_ledger);
        CachePlan {
            snapshot: CacheSnapshot::new(Some(adj), Some(feat), Some(split)),
            fill_ledger,
            plan_wall_ns: wall0.elapsed().as_nanos() as f64,
        }
    }
}

/// Single-cache baseline: the whole budget goes to node features.
pub struct SciPlanner;

impl CachePlanner for SciPlanner {
    fn name(&self) -> &'static str {
        "sci"
    }

    fn plan(&self, ds: &Dataset, profile: &WorkloadProfile<'_>, budget: u64) -> CachePlan {
        let wall0 = Instant::now();
        let (feat, fill_ledger) =
            FeatCache::fill(&ds.features, profile.node_visits, budget);
        CachePlan {
            snapshot: CacheSnapshot::new(None, Some(feat), None),
            fill_ledger,
            plan_wall_ns: wall0.elapsed().as_nanos() as f64,
        }
    }
}

/// DUCATI's dual-cache population strategy (Zhang et al., SIGMOD
/// 2023), adapted to inference exactly as the paper's §V.C does:
/// value/size densities per entry, full sorts of both entry lists (the
/// O(n log n) knapsack), cumulative value curves with least-squares
/// decile slope fits, and a greedy merge by density until the budget
/// is spent.
pub struct DucatiPlanner;

/// Least-squares slope of (0..n, ys) — the curve-fitting step.
pub(crate) fn fit_slope(ys: &[f64]) -> f64 {
    let n = ys.len() as f64;
    if ys.len() < 2 {
        return 0.0;
    }
    let mean_x = (n - 1.0) / 2.0;
    let mean_y = ys.iter().sum::<f64>() / n;
    let mut num = 0.0;
    let mut den = 0.0;
    for (i, &y) in ys.iter().enumerate() {
        let dx = i as f64 - mean_x;
        num += dx * (y - mean_y);
        den += dx * dx;
    }
    if den == 0.0 {
        0.0
    } else {
        num / den
    }
}

impl CachePlanner for DucatiPlanner {
    fn name(&self) -> &'static str {
        "ducati"
    }

    fn plan(&self, ds: &Dataset, profile: &WorkloadProfile<'_>, budget: u64) -> CachePlan {
        let wall0 = Instant::now();

        // value curves: every entry gets a value/size density
        let n = ds.csc.n_nodes();
        let row_cost = (ds.features.row_bytes() + 16) as f64;
        let mut nfeat: Vec<(f64, NodeId)> = (0..n)
            .map(|v| (profile.node_visits[v] as f64 / row_cost, v as NodeId))
            .collect();
        let mut adj: Vec<(f64, NodeId)> = (0..n)
            .map(|v| {
                let span = ds.csc.col_ptr[v] as usize..ds.csc.col_ptr[v + 1] as usize;
                let total: u64 =
                    profile.elem_counts[span].iter().map(|&c| c as u64).sum();
                let size = (ds.csc.degree(v as NodeId) * 4 + 12) as f64;
                (total as f64 / size, v as NodeId)
            })
            .collect();
        // full sorts — the O(n log n) knapsack cost the paper cites
        nfeat.sort_unstable_by(|a, b| b.0.partial_cmp(&a.0).unwrap().then(a.1.cmp(&b.1)));
        adj.sort_unstable_by(|a, b| b.0.partial_cmp(&a.0).unwrap().then(a.1.cmp(&b.1)));

        // cumulative curves + decile slope fits (the split heuristic)
        let cum = |xs: &[(f64, NodeId)]| -> Vec<f64> {
            let mut acc = 0.0;
            xs.iter()
                .map(|&(d, _)| {
                    acc += d;
                    acc
                })
                .collect()
        };
        let nfeat_curve = cum(&nfeat);
        let adj_curve = cum(&adj);
        let decile_slopes = |curve: &[f64]| -> Vec<f64> {
            let step = (curve.len() / 10).max(1);
            curve.chunks(step).map(fit_slope).collect()
        };
        let _nf_slopes = decile_slopes(&nfeat_curve);
        let _adj_slopes = decile_slopes(&adj_curve);

        // greedy merge by density until the budget is spent
        let mut remaining = budget;
        let (mut fi, mut ai) = (0usize, 0usize);
        let mut feat_order: Vec<NodeId> = Vec::new();
        let mut adj_order: Vec<u32> = Vec::new();
        let mut c_feat = 0u64;
        let mut c_adj = n as u64 * 12; // adj metadata charged up front
        let adj_meta_ok = remaining > c_adj;
        if adj_meta_ok {
            remaining -= c_adj; // metadata must come out of the budget too
        }
        while remaining > 0 && (fi < nfeat.len() || ai < adj.len()) {
            let fd = nfeat.get(fi).map(|x| x.0).unwrap_or(f64::NEG_INFINITY);
            let ad = if adj_meta_ok {
                adj.get(ai).map(|x| x.0).unwrap_or(f64::NEG_INFINITY)
            } else {
                f64::NEG_INFINITY
            };
            if fd == f64::NEG_INFINITY && ad == f64::NEG_INFINITY {
                break;
            }
            if fd >= ad {
                let v = nfeat[fi].1;
                let sz = ds.features.row_bytes() + 16;
                if nfeat[fi].0 > 0.0 && remaining >= sz {
                    feat_order.push(v);
                    c_feat += sz;
                    remaining -= sz;
                }
                fi += 1;
                if nfeat.get(fi - 1).map(|x| x.0 <= 0.0).unwrap_or(true) && fd <= 0.0 {
                    // exhausted useful nfeat entries
                    if ad <= 0.0 {
                        break;
                    }
                }
            } else {
                let v = adj[ai].1;
                let sz = ds.csc.degree(v) as u64 * 4;
                if adj[ai].0 > 0.0 && remaining >= sz {
                    adj_order.push(v);
                    c_adj += sz;
                    remaining -= sz;
                }
                ai += 1;
            }
        }

        // fill caches with the knapsack-chosen orders
        let (adj_cache, adj_ledger) = if ds.csc.bytes_total() <= c_adj {
            AdjCache::fill(&ds.csc, profile.elem_counts, c_adj)
        } else {
            AdjCache::fill_with_order(&ds.csc, profile.elem_counts, &adj_order, c_adj)
        };
        let (feat_cache, feat_ledger) =
            FeatCache::fill_with_order(&ds.features, &feat_order, c_feat);
        let mut fill_ledger = adj_ledger;
        fill_ledger.merge(&feat_ledger);

        CachePlan {
            snapshot: CacheSnapshot::new(
                Some(adj_cache),
                Some(feat_cache),
                Some(CacheAllocation { c_adj, c_feat }),
            ),
            fill_ledger,
            plan_wall_ns: wall0.elapsed().as_nanos() as f64,
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::graph::datasets;
    use crate::mem::CostModel;
    use crate::sampler::{presample, Fanout};
    use crate::util::Rng;

    fn profile_tiny() -> (Dataset, PresampleStats) {
        let ds = datasets::spec("tiny").unwrap().build();
        let stats = presample(
            &ds.csc,
            &ds.features,
            &ds.test_nodes,
            64,
            &Fanout::parse("3,2").unwrap(),
            6,
            &CostModel::default(),
            &mut Rng::new(11),
        );
        (ds, stats)
    }

    #[test]
    fn fit_slope_exact_line() {
        let ys: Vec<f64> = (0..10).map(|i| 3.0 * i as f64 + 1.0).collect();
        assert!((fit_slope(&ys) - 3.0).abs() < 1e-9);
        assert_eq!(fit_slope(&[1.0]), 0.0);
        assert_eq!(fit_slope(&[2.0, 2.0, 2.0]), 0.0);
    }

    #[test]
    fn dci_plan_splits_and_fills_within_budget() {
        let (ds, stats) = profile_tiny();
        let profile = WorkloadProfile::from_presample(&stats);
        let plan = DciPlanner.plan(&ds, &profile, 300_000);
        let split = plan.snapshot.alloc.unwrap();
        assert_eq!(split.total(), 300_000);
        assert!(split.c_adj > 0 && split.c_feat > 0);
        assert!(plan.snapshot.feat.as_ref().unwrap().n_cached() > 0);
        assert!(plan.fill_ledger.h2d_bytes > 0);
        assert!(plan.snapshot.bytes_used() <= 300_000 + ds.csc.bytes_total());
    }

    #[test]
    fn sci_plan_is_feature_only() {
        let (ds, stats) = profile_tiny();
        let profile = WorkloadProfile::from_presample(&stats);
        let plan = SciPlanner.plan(&ds, &profile, 100_000);
        assert!(plan.snapshot.adj.is_none());
        let fc = plan.snapshot.feat.as_ref().unwrap();
        assert!(fc.bytes_used() <= 100_000);
        assert!(fc.n_cached() > 0);
    }

    #[test]
    fn ducati_plan_fills_dual_caches() {
        let (ds, stats) = profile_tiny();
        let profile = WorkloadProfile::from_presample(&stats);
        let plan = DucatiPlanner.plan(&ds, &profile, 400_000);
        let split = plan.snapshot.alloc.unwrap();
        assert!(split.total() <= 400_000 + ds.csc.n_nodes() as u64 * 12);
        assert!(plan.snapshot.feat.as_ref().unwrap().n_cached() > 0);
    }

    #[test]
    fn planner_registry_matches_systems() {
        assert_eq!(planner_for(SystemKind::Dci).unwrap().name(), "dci");
        assert_eq!(planner_for(SystemKind::Sci).unwrap().name(), "sci");
        assert_eq!(planner_for(SystemKind::Ducati).unwrap().name(), "ducati");
        assert!(planner_for(SystemKind::Dgl).is_none());
        assert!(planner_for(SystemKind::Rain).is_none());
    }

    #[test]
    fn split_budget_conserves_and_front_loads_remainder() {
        assert_eq!(split_budget(10, 3), vec![4, 3, 3]);
        assert_eq!(split_budget(9, 3), vec![3, 3, 3]);
        assert_eq!(split_budget(2, 4), vec![1, 1, 0, 0]);
        assert_eq!(split_budget(0, 4), vec![0, 0, 0, 0]);
        assert_eq!(split_budget(7, 1), vec![7]);
        // the documented zero-shard contract: zero shards is treated
        // as one logical shard — the budget is never silently lost
        // (shared with split_budget_weighted; see its test)
        assert_eq!(split_budget(7, 0), vec![7]);
        assert_eq!(split_budget(0, 0), vec![0]);
        for (budget, n) in [(u64::MAX, 7usize), (1 << 40, 13), (12_345, 6)] {
            let shares = split_budget(budget, n);
            assert_eq!(shares.len(), n);
            assert_eq!(shares.iter().sum::<u64>(), budget);
            let (min, max) = (
                *shares.iter().min().unwrap(),
                *shares.iter().max().unwrap(),
            );
            assert!(max - min <= 1, "split must be even to within one byte");
        }
    }

    #[test]
    fn weighted_split_zero_shard_contract_and_fallbacks() {
        // the shared zero-shard contract: empty load vector = one shard
        assert_eq!(split_budget_weighted(7, &[], 0.1), vec![7]);
        assert_eq!(split_budget_weighted(0, &[], 0.0), vec![0]);
        // one shard takes everything regardless of its load
        assert_eq!(split_budget_weighted(9, &[0.0], 0.5), vec![9]);
        // all-zero load vector falls back to the even split exactly
        assert_eq!(
            split_budget_weighted(10, &[0.0, 0.0, 0.0], 0.0),
            split_budget(10, 3)
        );
        assert_eq!(
            split_budget_weighted(11, &[0.0; 4], 0.5),
            split_budget(11, 4)
        );
        // non-finite / negative loads are treated as zero
        assert_eq!(
            split_budget_weighted(12, &[f64::NAN, -3.0, f64::INFINITY, 0.0], 0.0),
            split_budget(12, 4)
        );
    }

    #[test]
    fn weighted_split_is_proportional_and_exact() {
        // 3:1 load at zero floor: shares follow the ratio exactly
        assert_eq!(split_budget_weighted(400, &[3.0, 1.0], 0.0), vec![300, 100]);
        // uniform load reduces to the even split, remainder included
        for (budget, n) in [(10u64, 3usize), (7, 4), (1 << 40, 13)] {
            let loads = vec![2.5; n];
            assert_eq!(
                split_budget_weighted(budget, &loads, 0.0),
                split_budget(budget, n),
                "uniform load must reduce to the even split"
            );
        }
        // conservation holds at extreme skew and extreme budgets
        for budget in [0u64, 1, 999, u64::MAX] {
            let shares = split_budget_weighted(budget, &[1e12, 1e-9, 0.0, 5.0], 0.25);
            assert_eq!(shares.iter().sum::<u64>(), budget, "budget {budget}");
        }
    }

    #[test]
    fn weighted_split_respects_the_floor() {
        let budget = 100_000u64;
        let n = 4;
        let floor = 0.1;
        let floor_share = ((budget / n as u64) as f64 * floor) as u64;
        // all the load on one shard: the others keep their floor
        let shares = split_budget_weighted(budget, &[0.0, 0.0, 9.0, 0.0], floor);
        assert_eq!(shares.iter().sum::<u64>(), budget);
        for (s, &share) in shares.iter().enumerate() {
            assert!(share >= floor_share, "shard {s} fell below the floor");
        }
        assert_eq!(shares[2], budget - 3 * floor_share, "hot shard takes the rest");
        // floor=1 pins the even split whatever the skew
        assert_eq!(
            split_budget_weighted(budget, &[9.0, 0.0, 0.0, 0.0], 1.0),
            split_budget(budget, n)
        );
    }

    #[test]
    fn cap_shares_clamps_and_conserves() {
        let mut shares = vec![90u64, 10, 0, 0];
        cap_shares(&mut shares, 40);
        assert_eq!(shares.iter().sum::<u64>(), 100);
        assert!(shares.iter().all(|&s| s <= 40), "{shares:?}");
        assert_eq!(shares[0], 40);
        // second-round cascade: redistribution itself may hit the cap
        let mut shares = vec![100u64, 39, 0, 0];
        cap_shares(&mut shares, 40);
        assert_eq!(shares.iter().sum::<u64>(), 139);
        assert!(shares.iter().all(|&s| s <= 40), "{shares:?}");
        // no clipping needed: untouched
        let mut shares = vec![5u64, 6];
        cap_shares(&mut shares, 10);
        assert_eq!(shares, vec![5, 6]);
        // documented lossy edge: total > n·cap pins everything at cap
        let mut shares = vec![50u64, 50];
        cap_shares(&mut shares, 10);
        assert_eq!(shares, vec![10, 10]);
    }

    #[test]
    fn cap_shares_per_device_respects_each_cap() {
        // heterogeneous caps: excess from the big share flows to the
        // devices that still have room under *their own* cap
        let mut shares = vec![90u64, 10, 0];
        cap_shares_per_device(&mut shares, &[40, 100, 5]);
        assert_eq!(shares.iter().sum::<u64>(), 100);
        assert_eq!(shares[0], 40);
        assert!(shares[2] <= 5);
        // cascade: redistribution overflows the small device's cap and
        // lands on the one open share
        let mut shares = vec![100u64, 0, 0];
        cap_shares_per_device(&mut shares, &[10, 10, 1000]);
        assert_eq!(shares, vec![10, 10, 80]);
        // lossy edge: Σ caps < Σ shares pins everything at its cap
        let mut shares = vec![50u64, 50];
        cap_shares_per_device(&mut shares, &[10, 20]);
        assert_eq!(shares, vec![10, 20]);
        // uniform caps are byte-identical to cap_shares
        let mut a = vec![90u64, 10, 0, 0];
        let mut b = a.clone();
        cap_shares(&mut a, 40);
        cap_shares_per_device(&mut b, &[40; 4]);
        assert_eq!(a, b);
    }

    #[test]
    fn class_weights_parse_and_default() {
        let w = ClassWeights::default();
        assert_eq!(w.weight(TenantClass::Priority), 4.0);
        assert_eq!(w.weight(TenantClass::Standard), 1.0);
        assert_eq!(w.weight(TenantClass::Scan), 0.05);
        assert_eq!(ClassWeights::parse("4,1,0.05").unwrap(), w);
        assert_eq!(
            ClassWeights::parse(" 2, 1 , 0 ").unwrap(),
            ClassWeights([2.0, 1.0, 0.0])
        );
        assert_eq!(ClassWeights::EQUAL, ClassWeights([1.0, 1.0, 1.0]));
        // wrong arity, junk, negatives, and non-finite all fail loudly
        for bad in ["1,2", "1,2,3,4", "a,b,c", "1,-2,3", "1,inf,3", ""] {
            assert!(ClassWeights::parse(bad).is_err(), "{bad:?} must be rejected");
        }
    }

    #[test]
    fn zero_time_profile_splits_evenly() {
        let p = WorkloadProfile {
            node_visits: &[],
            elem_counts: &[],
            t_sample_ns: 0.0,
            t_feature_ns: 0.0,
        };
        assert_eq!(p.sample_fraction(), 0.5);
    }
}
