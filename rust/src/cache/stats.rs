//! Combined dual-cache statistics (the hit-ratio series of Fig. 9),
//! including the online-refresh refill traffic of the epoch-swappable
//! runtime.

use crate::mem::{CostModel, TransferLedger};

/// Aggregated transfer behaviour of one inference run, split by stage.
#[derive(Debug, Clone, Default)]
pub struct CacheStats {
    /// Sampling-stage traffic (adjacency cache).
    pub sample: TransferLedger,
    /// Feature-loading-stage traffic (feature cache).
    pub feature: TransferLedger,
    /// Preprocessing traffic (pre-sampling + initial cache fills).
    pub preprocess: TransferLedger,
    /// Online-refresh refill traffic (background re-plan uploads —
    /// charged separately from `preprocess` because it happens while
    /// serving and amortizes against the hit-ratio recovery it buys).
    pub refresh: TransferLedger,
}

impl CacheStats {
    /// Empty stats (all ledgers zero).
    pub fn new() -> Self {
        Self::default()
    }

    /// Adjacency-cache hit ratio (sampling stage).
    pub fn adj_hit_ratio(&self) -> f64 {
        self.sample.hit_ratio()
    }

    /// Feature-cache hit ratio (loading stage).
    pub fn feat_hit_ratio(&self) -> f64 {
        self.feature.hit_ratio()
    }

    /// Overall hit ratio across both caches — the Fig. 9 y-axis.
    pub fn overall_hit_ratio(&self) -> f64 {
        let hits = self.sample.hits + self.feature.hits;
        let total = hits + self.sample.misses + self.feature.misses;
        if total == 0 {
            0.0
        } else {
            hits as f64 / total as f64
        }
    }

    /// Modeled transfer ns of the two serving stages.
    pub fn serving_modeled_ns(&self, m: &CostModel) -> f64 {
        self.sample.modeled_ns(m) + self.feature.modeled_ns(m)
    }

    /// Fold `other`'s ledgers into this one, stage by stage.
    pub fn merge(&mut self, other: &CacheStats) {
        self.sample.merge(&other.sample);
        self.feature.merge(&other.feature);
        self.preprocess.merge(&other.preprocess);
        self.refresh.merge(&other.refresh);
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn ratios() {
        let mut s = CacheStats::new();
        s.sample.hit(4);
        s.sample.miss(4, 1);
        s.feature.hit(400);
        s.feature.hit(400);
        assert_eq!(s.adj_hit_ratio(), 0.5);
        assert_eq!(s.feat_hit_ratio(), 1.0);
        assert!((s.overall_hit_ratio() - 0.75).abs() < 1e-12);
        assert_eq!(CacheStats::new().overall_hit_ratio(), 0.0);
    }

    #[test]
    fn merge_accumulates() {
        let mut a = CacheStats::new();
        a.sample.hit(4);
        let mut b = CacheStats::new();
        b.sample.miss(4, 1);
        b.preprocess.upload(100);
        a.merge(&b);
        assert_eq!(a.sample.hits, 1);
        assert_eq!(a.sample.misses, 1);
        assert_eq!(a.preprocess.h2d_bytes, 100);
    }

    #[test]
    fn refresh_traffic_merges_separately() {
        let mut a = CacheStats::new();
        let mut b = CacheStats::new();
        b.refresh.upload(640);
        a.merge(&b);
        assert_eq!(a.refresh.h2d_bytes, 640);
        assert_eq!(a.preprocess.h2d_bytes, 0);
    }
}
