//! Workload-aware cache-capacity allocation — Eq. (1) of the paper.
//!
//! The available budget `C` is split between the adjacency cache and
//! the node-feature cache in proportion to the time each stage consumed
//! during pre-sampling:
//!
//! ```text
//! C_adj  = Σ t_sample / Σ (t_sample + t_feature) × C
//! C_feat = Σ t_feature / Σ (t_sample + t_feature) × C
//! ```

use crate::sampler::PresampleStats;

/// The Eq. (1) split.
#[derive(Debug, Clone, Copy, PartialEq)]
pub struct CacheAllocation {
    /// Adjacency-cache capacity, bytes.
    pub c_adj: u64,
    /// Feature-cache capacity, bytes.
    pub c_feat: u64,
}

impl CacheAllocation {
    /// The whole budget: `c_adj + c_feat`.
    pub fn total(&self) -> u64 {
        self.c_adj + self.c_feat
    }
}

/// Split `total` bytes per Eq. (1). Degenerate inputs (zero measured
/// time) fall back to an even split.
pub fn allocate(total: u64, stats: &PresampleStats) -> CacheAllocation {
    allocate_ratio(total, stats.sample_fraction())
}

/// Split by a planner [`WorkloadProfile`] — the same Eq. (1), fed by
/// either the offline pre-sample or the online refresh accumulator.
pub fn allocate_profile(
    total: u64,
    profile: &super::planner::WorkloadProfile<'_>,
) -> CacheAllocation {
    allocate_ratio(total, profile.sample_fraction())
}

/// Split by an explicit sampling-time fraction (exposed for sweeps and
/// property tests).
pub fn allocate_ratio(total: u64, sample_fraction: f64) -> CacheAllocation {
    let f = sample_fraction.clamp(0.0, 1.0);
    let c_adj = (total as f64 * f).round() as u64;
    let c_adj = c_adj.min(total);
    CacheAllocation { c_adj, c_feat: total - c_adj }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::proptest::check;

    #[test]
    fn proportional_split() {
        let a = allocate_ratio(1000, 0.25);
        assert_eq!(a.c_adj, 250);
        assert_eq!(a.c_feat, 750);
        assert_eq!(a.total(), 1000);
    }

    #[test]
    fn extremes() {
        assert_eq!(allocate_ratio(100, 0.0).c_adj, 0);
        assert_eq!(allocate_ratio(100, 1.0).c_feat, 0);
        assert_eq!(allocate_ratio(0, 0.7).total(), 0);
        // out-of-range fractions clamp
        assert_eq!(allocate_ratio(100, -3.0).c_adj, 0);
        assert_eq!(allocate_ratio(100, 9.0).c_adj, 100);
    }

    #[test]
    fn conservation_property() {
        check("allocation conserves budget", 500, |rng| {
            let total = rng.next_u64() % (1 << 40);
            let f = rng.f64() * 1.4 - 0.2; // includes out-of-range
            let a = allocate_ratio(total, f);
            if a.total() != total {
                return Err(format!("total {total} split to {a:?}"));
            }
            if a.c_adj > total {
                return Err("c_adj exceeds total".into());
            }
            Ok(())
        });
    }

    #[test]
    fn monotone_in_fraction_property() {
        check("c_adj monotone in sampling fraction", 200, |rng| {
            let total = 1 + rng.next_u64() % (1 << 32);
            let f1 = rng.f64();
            let f2 = rng.f64();
            let (lo, hi) = if f1 <= f2 { (f1, f2) } else { (f2, f1) };
            let a = allocate_ratio(total, lo);
            let b = allocate_ratio(total, hi);
            if a.c_adj > b.c_adj {
                return Err(format!("f={lo}->{} f={hi}->{}", a.c_adj, b.c_adj));
            }
            Ok(())
        });
    }
}
