//! Node-feature cache with the paper's lightweight fill (§IV.B):
//!
//! > "Instead of sorting the number of visits to a node, the nodes with
//! > a number of visits greater than the average are directly selected
//! > to populate their features into the node feature cache. If the
//! > feature cache still has capacity ... the node features with fewer
//! > accesses than the average are then filled."
//!
//! Two O(n) scans, no sort — this is what makes DCI's preprocessing
//! cheap relative to DUCATI's knapsack fill (Fig. 10).
//!
//! Lookup implementation: the paper locates rows "through a hash table"
//! on the GPU; here the index is a dense node→slot array (u32::MAX =
//! absent). Semantically identical, and O(1) without hashing overhead
//! on the simulation hot path (see EXPERIMENTS.md §Perf). Capacity
//! accounting still charges a per-entry index overhead.

use crate::graph::{FeatureStore, NodeId};
use crate::mem::TransferLedger;

/// Per-cached-node metadata charge: index entry (key + slot + bucket
/// overhead, amortized) — matches the paper's GPU hash table.
const ENTRY_OVERHEAD_BYTES: u64 = 16;

const ABSENT: u32 = u32::MAX;

/// Device-resident feature rows + node→slot index.
pub struct FeatCache {
    dim: usize,
    row_bytes: u64,
    /// Dense node→slot map; `ABSENT` for uncached nodes.
    slot_of: Vec<u32>,
    n_cached: usize,
    /// `slots × dim`, simulated device memory payload.
    data: Vec<f32>,
}

impl FeatCache {
    /// Fill per the average-visit-threshold rule. Returns the cache and
    /// the bulk H2D upload ledger of the fill itself (preprocessing
    /// traffic).
    pub fn fill(
        features: &FeatureStore,
        node_visits: &[u32],
        capacity_bytes: u64,
    ) -> (Self, TransferLedger) {
        assert_eq!(features.n_nodes(), node_visits.len());
        let row_bytes = features.row_bytes();
        let per_node = row_bytes + ENTRY_OVERHEAD_BYTES;
        let max_slots = (capacity_bytes / per_node) as usize;

        let total: u64 = node_visits.iter().map(|&c| c as u64).sum();
        // exact integer threshold: `c > total / n` compared as
        // `c * n > total` so no f64 rounding can flip a node at the
        // boundary (c ≤ u32::MAX and n ≤ node count keep the product
        // well inside u64)
        let n = node_visits.len().max(1) as u64;

        let mut selected: Vec<NodeId> =
            Vec::with_capacity(max_slots.min(node_visits.len()));
        // pass 1: visits strictly above average (no sort — O(n))
        for (v, &c) in node_visits.iter().enumerate() {
            if selected.len() >= max_slots {
                break;
            }
            if c as u64 * n > total {
                selected.push(v as NodeId);
            }
        }
        // pass 2: remaining capacity takes <=-average nodes — visited
        // ones first, then never-visited ones (free coverage when the
        // budget exceeds the observed working set; this is the Fig. 2
        // "flattens once everything hot is resident" regime)
        if selected.len() < max_slots {
            for (v, &c) in node_visits.iter().enumerate() {
                if selected.len() >= max_slots {
                    break;
                }
                if c as u64 * n <= total && c > 0 {
                    selected.push(v as NodeId);
                }
            }
        }
        if selected.len() < max_slots {
            for (v, &c) in node_visits.iter().enumerate() {
                if selected.len() >= max_slots {
                    break;
                }
                if c == 0 {
                    selected.push(v as NodeId);
                }
            }
        }

        let dim = features.dim();
        let mut data = vec![0.0f32; selected.len() * dim];
        let mut slot_of = vec![ABSENT; features.n_nodes()];
        let mut ledger = TransferLedger::new();
        for (slot, &v) in selected.iter().enumerate() {
            features.copy_row_into(v, &mut data[slot * dim..(slot + 1) * dim]);
            slot_of[v as usize] = slot as u32;
        }
        // one bulk upload for the whole fill
        ledger.upload(selected.len() as u64 * row_bytes);
        (
            FeatCache { dim, row_bytes, slot_of, n_cached: selected.len(), data },
            ledger,
        )
    }

    /// Fill with an externally chosen node priority order (DUCATI's
    /// knapsack path); caches rows in order until capacity is
    /// exhausted. A node id appearing more than once in `order` is
    /// cached once — duplicates cannot burn capacity slots.
    pub fn fill_with_order(
        features: &FeatureStore,
        order: &[NodeId],
        capacity_bytes: u64,
    ) -> (Self, TransferLedger) {
        let row_bytes = features.row_bytes();
        let per_node = row_bytes + ENTRY_OVERHEAD_BYTES;
        let max_slots = (capacity_bytes / per_node) as usize;
        let dim = features.dim();
        let mut slot_of = vec![ABSENT; features.n_nodes()];
        let mut selected: Vec<NodeId> =
            Vec::with_capacity(max_slots.min(order.len()));
        for &v in order {
            if selected.len() >= max_slots {
                break;
            }
            let slot = &mut slot_of[v as usize];
            if *slot != ABSENT {
                continue;
            }
            *slot = selected.len() as u32;
            selected.push(v);
        }
        let mut data = vec![0.0f32; selected.len() * dim];
        let mut ledger = TransferLedger::new();
        for (slot, &v) in selected.iter().enumerate() {
            features.copy_row_into(v, &mut data[slot * dim..(slot + 1) * dim]);
        }
        ledger.upload(selected.len() as u64 * row_bytes);
        (
            FeatCache { dim, row_bytes, slot_of, n_cached: selected.len(), data },
            ledger,
        )
    }

    /// An empty cache (capacity 0 — the DGL baseline's view).
    pub fn empty(dim: usize) -> Self {
        FeatCache {
            dim,
            row_bytes: (dim * std::mem::size_of::<f32>()) as u64,
            slot_of: Vec::new(),
            n_cached: 0,
            data: Vec::new(),
        }
    }

    /// `v`'s cached feature row, if resident.
    #[inline]
    pub fn lookup(&self, v: NodeId) -> Option<&[f32]> {
        let slot = *self.slot_of.get(v as usize)?;
        if slot == ABSENT {
            return None;
        }
        let i = slot as usize * self.dim;
        Some(&self.data[i..i + self.dim])
    }

    /// Whether `v`'s row is resident.
    pub fn contains(&self, v: NodeId) -> bool {
        self.lookup(v).is_some()
    }

    /// Number of resident rows.
    pub fn n_cached(&self) -> usize {
        self.n_cached
    }

    /// Device bytes this cache occupies (payload + index overhead).
    pub fn bytes_used(&self) -> u64 {
        self.n_cached as u64 * (self.row_bytes + ENTRY_OVERHEAD_BYTES)
    }

    /// Feature dimension of the cached rows.
    pub fn dim(&self) -> usize {
        self.dim
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::graph::FeatureStore;
    use crate::util::proptest::check;
    use crate::util::Rng;

    fn store(n: usize, dim: usize) -> FeatureStore {
        FeatureStore::generate(n, dim, &mut Rng::new(5))
    }

    #[test]
    fn prefers_above_average_nodes() {
        let fs = store(10, 4);
        // node 3 and 7 hot, rest cold
        let visits = [1, 1, 1, 50, 1, 1, 1, 40, 0, 0];
        // capacity for exactly 2 rows
        let cap = 2 * (fs.row_bytes() + super::ENTRY_OVERHEAD_BYTES);
        let (c, ledger) = FeatCache::fill(&fs, &visits, cap);
        assert_eq!(c.n_cached(), 2);
        assert!(c.contains(3) && c.contains(7));
        assert_eq!(ledger.h2d_bytes, 2 * fs.row_bytes());
        assert_eq!(c.bytes_used(), cap);
    }

    #[test]
    fn spills_to_below_average_then_unvisited() {
        let fs = store(6, 4);
        let visits = [10, 1, 1, 0, 1, 1];
        let cap = 5 * (fs.row_bytes() + super::ENTRY_OVERHEAD_BYTES);
        let (c, _) = FeatCache::fill(&fs, &visits, cap);
        assert_eq!(c.n_cached(), 5);
        assert!(c.contains(0)); // hot one
        // visited cold ones before the zero-visit node
        assert!(c.contains(1) && c.contains(2) && c.contains(4) && c.contains(5));
        assert!(!c.contains(3));
        // with room for all, the unvisited node gets in too (Fig. 2
        // full-budget regime)
        let (c2, _) = FeatCache::fill(&fs, &visits, 6 * (fs.row_bytes() + 16));
        assert!(c2.contains(3));
    }

    #[test]
    fn lookup_returns_exact_rows() {
        let fs = store(20, 8);
        let visits = vec![5u32; 20];
        let cap = 20 * (fs.row_bytes() + super::ENTRY_OVERHEAD_BYTES);
        let (c, _) = FeatCache::fill(&fs, &visits, cap);
        for v in 0..20u32 {
            assert_eq!(c.lookup(v).unwrap(), fs.row(v), "node {v}");
        }
        assert!(c.lookup(25).is_none());
    }

    #[test]
    fn zero_capacity_caches_nothing() {
        let fs = store(5, 4);
        let (c, ledger) = FeatCache::fill(&fs, &[9, 9, 9, 9, 9], 0);
        assert_eq!(c.n_cached(), 0);
        assert_eq!(ledger.h2d_bytes, 0);
        assert!(c.lookup(0).is_none());
        let e = FeatCache::empty(4);
        assert_eq!(e.n_cached(), 0);
        assert!(e.lookup(0).is_none());
    }

    #[test]
    fn fill_with_order_respects_order_and_budget() {
        let fs = store(10, 4);
        let order = [7u32, 3, 1];
        let cap = 2 * (fs.row_bytes() + super::ENTRY_OVERHEAD_BYTES);
        let (c, _) = FeatCache::fill_with_order(&fs, &order, cap);
        assert!(c.contains(7) && c.contains(3));
        assert!(!c.contains(1));
    }

    #[test]
    fn fill_with_order_skips_duplicates() {
        let fs = store(10, 4);
        // node 7 repeated: must occupy one slot, leaving room for 3 AND 1
        let order = [7u32, 7, 7, 3, 1];
        let cap = 3 * (fs.row_bytes() + super::ENTRY_OVERHEAD_BYTES);
        let (c, ledger) = FeatCache::fill_with_order(&fs, &order, cap);
        assert_eq!(c.n_cached(), 3);
        assert!(c.contains(7) && c.contains(3) && c.contains(1));
        assert_eq!(ledger.h2d_bytes, 3 * fs.row_bytes());
        assert_eq!(c.lookup(7).unwrap(), fs.row(7));
    }

    #[test]
    fn exact_integer_average_threshold() {
        let fs = store(4, 4);
        // all-equal visits: average equals every count, so pass 1
        // selects nothing and pass 2 fills in id order — the integer
        // compare (c * n > total) cannot be skewed by f64 rounding
        let visits = [3u32, 3, 3, 3];
        let cap = 2 * (fs.row_bytes() + super::ENTRY_OVERHEAD_BYTES);
        let (c, _) = FeatCache::fill(&fs, &visits, cap);
        assert_eq!(c.n_cached(), 2);
        assert!(c.contains(0) && c.contains(1));
    }

    #[test]
    fn capacity_respected_property() {
        check("feat cache never exceeds capacity", 100, |rng| {
            let n = 1 + rng.gen_usize(200);
            let dim = 1 + rng.gen_usize(16);
            let fs = FeatureStore::generate(n, dim, rng);
            let visits: Vec<u32> = (0..n).map(|_| rng.next_u32() % 20).collect();
            let cap = rng.next_u64() % (n as u64 * 2 * (fs.row_bytes() + 16));
            let (c, _) = FeatCache::fill(&fs, &visits, cap);
            if c.bytes_used() > cap {
                return Err(format!("used {} > cap {cap}", c.bytes_used()));
            }
            // every cached row matches the host row
            for v in 0..n as u32 {
                if let Some(row) = c.lookup(v) {
                    if row != fs.row(v) {
                        return Err(format!("row mismatch at {v}"));
                    }
                }
            }
            Ok(())
        });
    }

    #[test]
    fn hot_nodes_always_preferred_property() {
        check("above-avg nodes cached before below-avg", 50, |rng| {
            let n = 10 + rng.gen_usize(100);
            let fs = FeatureStore::generate(n, 4, rng);
            let visits: Vec<u32> = (0..n).map(|_| rng.next_u32() % 10).collect();
            let total: u64 = visits.iter().map(|&c| c as u64).sum();
            let avg = total as f64 / n as f64;
            let n_hot = visits.iter().filter(|&&c| (c as f64) > avg).count();
            let cap = n_hot as u64 * (fs.row_bytes() + 16);
            let (c, _) = FeatCache::fill(&fs, &visits, cap);
            for (v, &cnt) in visits.iter().enumerate() {
                if (cnt as f64) > avg && !c.contains(v as u32) && c.n_cached() < n_hot
                {
                    return Err(format!("hot node {v} (visits {cnt}) evicted"));
                }
            }
            Ok(())
        });
    }
}
